"""Layer-2 model: a LLaMA-style tiny GPT in pure JAX.

Build-time only — every entry point here is AOT-lowered by ``aot.py`` to
HLO text and executed from Rust via PJRT; python never runs at runtime.

Architecture (mirrors the layer taxonomy of the models the paper prunes):
pre-RMSNorm, multi-head attention with RoPE, SwiGLU MLP, untied LM head.
The seven prunable linears per block are named after the LLaMA modules
(``attn.{q,k,v,o}_proj``, ``mlp.{gate,up,down}_proj``); embeddings, norms
and the final head are never pruned (paper Sec. 3).

Parameters travel as a *flat list* of f32 arrays in the order defined by
``configs.ModelConfig.layer_shapes()`` — the same order the Rust parameter
store uses, so both sides index layers by position.

Entry points lowered to artifacts:
  * ``train_step``  — Adam step, returns updated (params, m, v, step, loss)
  * ``eval_step``   — summed token NLL + token count (perplexity)
  * ``seq_nll``     — per-sequence masked NLL (zero-shot choice scoring)
  * ``calib_step``  — forward pass that accumulates the four Gram streams
                      and feature sums per block (Sec 2.1.2 on-the-fly
                      accumulation; DSnoT's mean/variance surrogates need
                      the feature sums)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import gram as gram_kernels


# --- parameter helpers ----------------------------------------------------

def init_params(cfg: ModelConfig, seed: int | None = None):
    """Random initialisation, scaled per fan-in (returns the flat list)."""
    key = jax.random.PRNGKey(cfg.init_seed if seed is None else seed)
    params = []
    for name, shape in cfg.layer_shapes():
        key, sub = jax.random.split(key)
        if name.endswith("_norm"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[-1]
            scale = fan_in ** -0.5
            params.append(
                (jax.random.normal(sub, shape, jnp.float32) * scale))
    return params


def _unpack(cfg: ModelConfig, params):
    """Split the flat list into (tok_emb, blocks, final_norm, lm_head)."""
    idx = 0
    tok_emb = params[idx]; idx += 1
    blocks = []
    for _ in range(cfg.n_blocks):
        blk = {
            "attn_norm": params[idx + 0],
            "wq": params[idx + 1],
            "wk": params[idx + 2],
            "wv": params[idx + 3],
            "wo": params[idx + 4],
            "mlp_norm": params[idx + 5],
            "wg": params[idx + 6],
            "wu": params[idx + 7],
            "wd": params[idx + 8],
        }
        blocks.append(blk)
        idx += 9
    final_norm = params[idx]; idx += 1
    lm_head = params[idx]; idx += 1
    assert idx == len(params)
    return tok_emb, blocks, final_norm, lm_head


# --- building blocks -------------------------------------------------------

def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, theta: float):
    """Rotary position embedding over [B, H, L, Hd]."""
    b, h, l, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.arange(l, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]  # [L, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(cfg: ModelConfig, blk, h):
    """h: [B, L, dm] normed input -> attention output [B, L, dm]."""
    b, l, dm = h.shape
    nh, hd = cfg.n_heads, cfg.head_dim

    def proj(w):  # w: [d_out, d_in] paper layout
        return jnp.einsum("bld,od->blo", h, w)

    q = proj(blk["wq"]).reshape(b, l, nh, hd).transpose(0, 2, 1, 3)
    k = proj(blk["wk"]).reshape(b, l, nh, hd).transpose(0, 2, 1, 3)
    v = proj(blk["wv"]).reshape(b, l, nh, hd).transpose(0, 2, 1, 3)
    q = rope(q, cfg.rope_theta)
    k = rope(k, cfg.rope_theta)

    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((l, l), jnp.bool_))
    scores = jnp.where(causal[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    return out.transpose(0, 2, 1, 3).reshape(b, l, dm)


def forward(cfg: ModelConfig, params, tokens, capture: bool = False):
    """Forward pass.  tokens: [B, L] int32.

    Returns (logits [B, L, V], captures) where captures is a list of one
    dict per block with the four activation streams, flattened to
    [B*L, width] — only populated when ``capture`` is True.
    """
    tok_emb, blocks, final_norm, lm_head = _unpack(cfg, params)
    x = tok_emb[tokens]  # [B, L, dm]
    caps = []
    for blk in blocks:
        h = rmsnorm(x, blk["attn_norm"])
        attn_out = _attention(cfg, blk, h)
        x = x + jnp.einsum("bld,od->blo", attn_out, blk["wo"])
        h2 = rmsnorm(x, blk["mlp_norm"])
        g = jnp.einsum("bld,od->blo", h2, blk["wg"])
        u = jnp.einsum("bld,od->blo", h2, blk["wu"])
        d_in = jax.nn.silu(g) * u
        x = x + jnp.einsum("bld,od->blo", d_in, blk["wd"])
        if capture:
            flat = lambda a: a.reshape(-1, a.shape[-1])
            caps.append({
                "qkv": flat(h),
                "o": flat(attn_out),
                "gu": flat(h2),
                "down": flat(d_in),
            })
    x = rmsnorm(x, final_norm)
    logits = jnp.einsum("bld,vd->blv", x, lm_head)
    return logits, caps


# --- losses / entry points --------------------------------------------------

def token_nll(logits, targets):
    """Per-token negative log-likelihood. [B, L]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


def loss_fn(cfg: ModelConfig, params, tokens, targets):
    logits, _ = forward(cfg, params, tokens)
    return jnp.mean(token_nll(logits, targets))


def train_step(cfg: ModelConfig, params, m, v, step, tokens, targets, lr,
               b1=0.9, b2=0.999, adam_eps=1e-8, clip=1.0):
    """One Adam step with global-norm gradient clipping."""
    loss, grads = jax.value_and_grad(
        functools.partial(loss_fn, cfg))(params, tokens, targets)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads) + 1e-12)
    scale = jnp.minimum(1.0, clip / gnorm)
    grads = [g * scale for g in grads]
    step = step + 1
    stepf = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** stepf
    bc2 = 1.0 - b2 ** stepf
    new_p, new_m, new_v = [], [], []
    for p, mi, vi, g in zip(params, m, v, grads):
        mi = b1 * mi + (1.0 - b1) * g
        vi = b2 * vi + (1.0 - b2) * g * g
        upd = (mi / bc1) / (jnp.sqrt(vi / bc2) + adam_eps)
        new_p.append(p - lr * upd)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, step, loss


def eval_step(cfg: ModelConfig, params, tokens, targets):
    """Summed NLL and token count over the batch (perplexity building block)."""
    logits, _ = forward(cfg, params, tokens)
    nll = token_nll(logits, targets)
    return jnp.sum(nll), jnp.float32(nll.size)


def seq_nll(cfg: ModelConfig, params, tokens, targets, mask):
    """Masked per-sequence NLL [B] — lm-eval-style choice scoring."""
    logits, _ = forward(cfg, params, tokens)
    nll = token_nll(logits, targets)
    return jnp.sum(nll * mask, axis=1)


def calib_step(cfg: ModelConfig, params, tokens,
               g_qkv, g_o, g_gu, g_down, s_qkv, s_o, s_gu, s_down,
               use_pallas_gram: bool = False):
    """Accumulate the four Gram streams + feature sums for every block.

    g_qkv/g_o/g_gu: [n_blocks, dm, dm]; g_down: [n_blocks, dff, dff];
    s_*: matching [n_blocks, width] feature sums (for DSnoT's mean /
    variance surrogates; variances come from diag(G) and the sums).

    The Gram update itself is the L1 Pallas kernel when
    ``use_pallas_gram`` (TPU path / kernel-integration artifact variant);
    the default XLA dot is the fast CPU path — both are tested against
    ``kernels.ref.gram_accumulate``.
    """
    _, caps = forward(cfg, params, tokens, capture=True)
    gs = {"qkv": g_qkv, "o": g_o, "gu": g_gu, "down": g_down}
    ss = {"qkv": s_qkv, "o": s_o, "gu": s_gu, "down": s_down}
    for b, cap in enumerate(caps):
        for stream in ("qkv", "o", "gu", "down"):
            x = cap[stream]  # [T, width]
            if use_pallas_gram:
                upd = gram_kernels.gram_update_pallas(gs[stream][b], x)
            else:
                upd = gs[stream][b] + x.T @ x
            gs[stream] = gs[stream].at[b].set(upd)
            ss[stream] = ss[stream].at[b].add(jnp.sum(x, axis=0))
    return (gs["qkv"], gs["o"], gs["gu"], gs["down"],
            ss["qkv"], ss["o"], ss["gu"], ss["down"])
