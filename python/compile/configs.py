"""Model and artifact configuration registry.

The same configs are mirrored on the Rust side (`rust/src/model/config.rs`);
`aot.py` writes them into `artifacts/manifest.json` so the two sides can
never drift: Rust reads shapes from the manifest, not from its own math.

The "zoo" plays the role of the paper's five model families (LLaMA-3.1-8B,
Gemma-2-9B, Yi-1.5-9B, DeepSeek-7B, Qwen2.5-7B): distinct architectures /
seeds at a scale a CPU PJRT client can train and prune end-to-end.  See
DESIGN.md section 2 for the substitution rationale.
"""

from __future__ import annotations

from dataclasses import dataclass, field


# The seven prunable linears per transformer block, in the order their
# weights appear in the flat parameter list.  Names mirror the LLaMA
# taxonomy used by the paper's Figure 1.
PRUNABLE_LAYERS = (
    "attn.q_proj",
    "attn.k_proj",
    "attn.v_proj",
    "attn.o_proj",
    "mlp.gate_proj",
    "mlp.up_proj",
    "mlp.down_proj",
)

# Each prunable layer reads one of four distinct activation streams, so
# only four Gram matrices are accumulated per block:
#   qkv  — the attention RMSNorm output            (d_model wide)
#   o    — the concatenated attention head output  (d_model wide)
#   gu   — the MLP RMSNorm output                  (d_model wide)
#   down — the SwiGLU product                      (d_ff    wide)
GRAM_STREAMS = ("qkv", "o", "gu", "down")
LAYER_TO_STREAM = {
    "attn.q_proj": "qkv",
    "attn.k_proj": "qkv",
    "attn.v_proj": "qkv",
    "attn.o_proj": "o",
    "mlp.gate_proj": "gu",
    "mlp.up_proj": "gu",
    "mlp.down_proj": "down",
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_heads: int
    d_ff: int
    n_blocks: int
    seq_len: int
    batch: int
    rope_theta: float = 10000.0
    init_seed: int = 0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def layer_shapes(self):
        """Flat parameter list: (name, shape) in storage order.

        All linear weights use the paper layout [d_out, d_in] so that each
        row is an independently prunable unit.
        """
        dm, dff, v = self.d_model, self.d_ff, self.vocab
        shapes = [("tok_emb", (v, dm))]
        for b in range(self.n_blocks):
            p = f"blocks.{b}."
            shapes += [
                (p + "attn_norm", (dm,)),
                (p + "attn.q_proj", (dm, dm)),
                (p + "attn.k_proj", (dm, dm)),
                (p + "attn.v_proj", (dm, dm)),
                (p + "attn.o_proj", (dm, dm)),
                (p + "mlp_norm", (dm,)),
                (p + "mlp.gate_proj", (dff, dm)),
                (p + "mlp.up_proj", (dff, dm)),
                (p + "mlp.down_proj", (dm, dff)),
            ]
        shapes += [("final_norm", (dm,)), ("lm_head", (v, dm))]
        return shapes

    def stream_width(self, stream: str) -> int:
        return self.d_ff if stream == "down" else self.d_model

    def prunable_widths(self):
        """Distinct d_in values over all prunable layers."""
        return sorted({self.d_model, self.d_ff})


# --- The model zoo -------------------------------------------------------
# "tiny" is the test config (fast lowering, fast pytest); the three
# "gpt-*" configs are the Table-1 zoo; "gpt-mid" exists for scale benches.
MODEL_CONFIGS = {
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_heads=2,
                        d_ff=128, n_blocks=2, seq_len=32, batch=4,
                        init_seed=7),
    "gpt-a": ModelConfig("gpt-a", vocab=512, d_model=256, n_heads=4,
                         d_ff=512, n_blocks=4, seq_len=128, batch=8,
                         init_seed=1),
    "gpt-b": ModelConfig("gpt-b", vocab=512, d_model=320, n_heads=5,
                         d_ff=640, n_blocks=4, seq_len=128, batch=8,
                         init_seed=2),
    "gpt-c": ModelConfig("gpt-c", vocab=512, d_model=256, n_heads=4,
                         d_ff=512, n_blocks=6, seq_len=128, batch=8,
                         init_seed=3),
    "gpt-mid": ModelConfig("gpt-mid", vocab=512, d_model=512, n_heads=8,
                           d_ff=1024, n_blocks=6, seq_len=128, batch=8,
                           init_seed=4),
}

# Default configs whose artifacts `make artifacts` builds.  gpt-mid is
# opt-in (SPARSESWAPS_AOT_CONFIGS env var) to keep artifact builds fast.
DEFAULT_AOT_CONFIGS = ("tiny", "gpt-a", "gpt-b", "gpt-c")

# Sparsity-pattern variants baked into swap artifacts.
SWAP_PATTERNS = {"row": 0, "nm2_4": 4, "nm4_8": 8}

# Swap iterations fused into a single artifact call.  k1 keeps exact
# T_max bookkeeping; k8 amortises per-call overhead (engine ablation).
SWAP_KS = (1, 8)


def swap_chunk_rows(d: int, budget_bytes: int = 96 * 1024 * 1024) -> int:
    """Row-chunk size R for a swap artifact over width d.

    The fused-XLA search materialises an [R, D, D] f32 intermediate; pick
    the largest power of two keeping it under ``budget_bytes`` (clamped to
    [8, 256]).
    """
    r = budget_bytes // (d * d * 4)
    p = 8
    while p * 2 <= min(r, 256):
        p *= 2
    return max(p, 8)
