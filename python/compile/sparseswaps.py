"""Layer-2 SparseSwaps step: batched 1-swap refinement over a row chunk.

This is the function that gets AOT-lowered (via ``compile.aot``) into the
``swap_step_*`` artifacts the Rust coordinator executes on its hot path.

Semantics (paper Algorithm 1, vectorised over a chunk of rows):

  inputs   W [R, D]  weight rows (paper layout, d_in last)
           M [R, D]  warmstart masks in {0, 1}
           G [D, D]  Gram matrix of the layer's calibration inputs
  compute  c = G((1-m) * w) per row, then K best-swap iterations; each
           iteration evaluates all feasible (u, p) pairs via Eq. 5,
           accepts the best pair iff dL < 0 (strict decrease — the paper's
           stopping rule with eps = 0) and applies the Eq. 6 update to c.
  outputs  M'        refined masks
           L_before  exact per-row loss of the warmstart        [R]
           L_after   exact per-row loss of the refined mask     [R]
           swaps     number of accepted swaps per row (f32)     [R]

K is baked into the artifact (``k_iters``); the Rust coordinator chains
calls until every row converges or its T_max budget is exhausted, and
compacts converged rows out of the chunk between calls.

Two interchangeable implementations of the inner best-swap search:

  * ``impl="xla"``     — fused XLA broadcast + argmin (fast on CPU PJRT);
  * ``impl="pallas"``  — the L1 tiled kernel (``kernels.swap``), the
    TPU-shaped path, lowered with interpret=True on CPU.

Both decrease the *identical* exact objective; they may differ in
tie-breaking, so tests compare achieved losses, not indices.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import swap as swap_kernels

BIG = jnp.float32(1e30)


def _best_swap_xla(w, m, c, g, diag, nm_block):
    """Fused-XLA batched best-swap: returns (dl[R], u[R], p[R])."""
    r, d = w.shape
    a_u = jnp.where(m > 0.5, 2.0 * w * c + w * w * diag, BIG)  # [R, D]
    b_p = jnp.where(m < 0.5, -2.0 * w * c + w * w * diag, BIG)  # [R, D]
    tile = (a_u[:, :, None] + b_p[:, None, :]
            - 2.0 * (w[:, :, None] * w[:, None, :]) * g[None, :, :])
    if nm_block:
        blk = jnp.arange(d) // nm_block
        same = blk[:, None] == blk[None, :]
        tile = jnp.where(same[None, :, :], tile, BIG)
    flat = tile.reshape(r, d * d)
    idx = jnp.argmin(flat, axis=1)
    dl = jnp.take_along_axis(flat, idx[:, None], axis=1)[:, 0]
    return dl, (idx // d).astype(jnp.int32), (idx % d).astype(jnp.int32)


def swap_step(w, m, g, *, k_iters: int, nm_block: int = 0,
              impl: str = "xla", tile: int = 128, interpret: bool = True):
    """Run up to ``k_iters`` exact 1-swap iterations on a chunk of rows."""
    r, d = w.shape
    diag = jnp.diagonal(g)

    q0 = (1.0 - m) * w
    l_before = jnp.einsum("rd,rd->r", q0, q0 @ g)
    c0 = q0 @ g  # == G q per row (G symmetric)

    if impl == "xla":
        search = functools.partial(_best_swap_xla, g=g, diag=diag,
                                   nm_block=nm_block)
    elif impl == "pallas":
        def search(w_, m_, c_):
            return swap_kernels.best_swap_pallas(
                w_, m_, c_, g, nm_block=nm_block, tile=tile,
                interpret=interpret)
    else:
        raise ValueError(f"unknown impl {impl!r}")

    def body(_, state):
        m_, c_, nswaps = state
        dl, u, p = search(w, m_, c_)
        # Strict-decrease acceptance; rows at a local optimum (dl >= 0) or
        # without feasible pairs (u = -1, dl = BIG) become no-ops.
        accept = (dl < 0.0) & (u >= 0)
        acc = accept.astype(jnp.float32)
        u_safe = jnp.maximum(u, 0)
        p_safe = jnp.maximum(p, 0)
        oh_u = jax.nn.one_hot(u_safe, d, dtype=jnp.float32) * acc[:, None]
        oh_p = jax.nn.one_hot(p_safe, d, dtype=jnp.float32) * acc[:, None]
        m_new = m_ - oh_u + oh_p
        wu = jnp.take_along_axis(w, u_safe[:, None], axis=1)[:, 0] * acc
        wp = jnp.take_along_axis(w, p_safe[:, None], axis=1)[:, 0] * acc
        c_new = c_ + wu[:, None] * g[u_safe, :] - wp[:, None] * g[p_safe, :]
        return m_new, c_new, nswaps + acc

    m_out, _, nswaps = jax.lax.fori_loop(
        0, k_iters, body, (m, c0, jnp.zeros((r,), jnp.float32)))

    # Exact loss of the refined mask, recomputed from scratch so the
    # reported value carries no accumulated floating-point drift.
    q1 = (1.0 - m_out) * w
    l_after = jnp.einsum("rd,rd->r", q1, q1 @ g)
    return m_out, l_before, l_after, nswaps


def row_losses(w, m, g):
    """Standalone exact per-row loss (used by the `layer_loss` artifact)."""
    q = (1.0 - m) * w
    return jnp.einsum("rd,rd->r", q, q @ g)
