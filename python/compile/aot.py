"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest.json.

This is the only place python touches the filesystem for the runtime:
``make artifacts`` runs it once, and the Rust binary is self-contained
afterwards.  Interchange is HLO **text**, not serialized HloModuleProto —
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per model config (shapes baked in):
  train_step_{cfg}   (params.., m.., v.., step, tokens, targets, lr)
                     -> (params.., m.., v.., step, loss)
  eval_step_{cfg}    (params.., tokens, targets) -> (nll_sum, count)
  seq_nll_{cfg}      (params.., tokens, targets, mask) -> nll[B]
  calib_step_{cfg}   (params.., tokens, g_qkv, g_o, g_gu, g_down,
                      s_qkv, s_o, s_gu, s_down) -> updated stats

Artifacts per prunable width d (shared across configs):
  swap_step_d{d}_{pat}_{impl}_k{K}  (W[R,d], M[R,d], G[d,d])
                     -> (M', L_before[R], L_after[R], swaps[R])
  layer_loss_d{d}    (W[R,d], M[R,d], G[d,d]) -> L[R]

The manifest records every artifact's input/output signature plus the
full model-config metadata (flat parameter order, prunable layers, Gram
stream mapping, swap chunk sizes) so the Rust side derives *nothing*
about shapes on its own.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import sparseswaps as ss
from .configs import (DEFAULT_AOT_CONFIGS, LAYER_TO_STREAM, MODEL_CONFIGS,
                      PRUNABLE_LAYERS, SWAP_KS, SWAP_PATTERNS,
                      swap_chunk_rows)


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _sig(args):
    """JSON-able signature of a flat list of ShapeDtypeStructs."""
    flat, _ = jax.tree_util.tree_flatten(args)
    return [{"dims": list(a.shape), "dtype": str(a.dtype)} for a in flat]


class Builder:
    def __init__(self, out_dir: str, force: bool = False):
        self.out_dir = out_dir
        self.force = force
        self.artifacts = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, example_args, meta=None):
        """Lower ``fn(*example_args)`` and write ``{name}.hlo.txt``."""
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
        out_avals = lowered.out_info
        out_flat, _ = jax.tree_util.tree_flatten(out_avals)
        entry = {
            "file": fname,
            "inputs": _sig(example_args),
            "outputs": [{"dims": list(o.shape), "dtype": str(o.dtype)}
                        for o in out_flat],
        }
        if meta:
            entry.update(meta)
        self.artifacts[name] = entry
        if not self.force and os.path.exists(path):
            print(f"  [skip] {fname} (exists)")
            return
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  [ok] {fname} ({len(text) / 1024:.0f} KiB)")


def build_model_artifacts(b: Builder, cfg):
    shapes = [s for _, s in cfg.layer_shapes()]
    params = [_spec(s) for s in shapes]
    tokens = _spec((cfg.batch, cfg.seq_len), jnp.int32)
    targets = _spec((cfg.batch, cfg.seq_len), jnp.int32)
    scalar = _spec((), jnp.float32)
    step = _spec((), jnp.int32)

    def train(params, m, v, step, tok, tgt, lr):
        return model_lib.train_step(cfg, params, m, v, step, tok, tgt, lr)

    b.emit(f"train_step_{cfg.name}", train,
           (params, params, params, step, tokens, targets, scalar),
           meta={"kind": "train_step", "config": cfg.name})

    def evals(params, tok, tgt):
        return model_lib.eval_step(cfg, params, tok, tgt)

    b.emit(f"eval_step_{cfg.name}", evals, (params, tokens, targets),
           meta={"kind": "eval_step", "config": cfg.name})

    def seqnll(params, tok, tgt, mask):
        return model_lib.seq_nll(cfg, params, tok, tgt, mask)

    b.emit(f"seq_nll_{cfg.name}", seqnll,
           (params, tokens, targets, _spec((cfg.batch, cfg.seq_len))),
           meta={"kind": "seq_nll", "config": cfg.name})

    nb, dm, dff = cfg.n_blocks, cfg.d_model, cfg.d_ff
    g_args = (_spec((nb, dm, dm)), _spec((nb, dm, dm)), _spec((nb, dm, dm)),
              _spec((nb, dff, dff)))
    s_args = (_spec((nb, dm)), _spec((nb, dm)), _spec((nb, dm)),
              _spec((nb, dff)))

    def calib(params, tok, gq, go, gg, gd, sq, so, sg, sd):
        return model_lib.calib_step(cfg, params, tok, gq, go, gg, gd,
                                    sq, so, sg, sd)

    b.emit(f"calib_step_{cfg.name}", calib,
           (params, tokens) + g_args + s_args,
           meta={"kind": "calib_step", "config": cfg.name})


def build_swap_artifacts(b: Builder, widths, pallas_widths=()):
    for d in sorted(widths):
        r = swap_chunk_rows(d)
        w = _spec((r, d))
        m = _spec((r, d))
        g = _spec((d, d))

        def loss_fn(w_, m_, g_):
            return ss.row_losses(w_, m_, g_)

        b.emit(f"layer_loss_d{d}", loss_fn, (w, m, g),
               meta={"kind": "layer_loss", "width": d, "chunk_rows": r})

        for pat, nm_block in SWAP_PATTERNS.items():
            if nm_block and d % nm_block != 0:
                continue
            impls = ["xla"] + (["pallas"] if d in pallas_widths else [])
            for impl in impls:
                ks = SWAP_KS if impl == "xla" else (1,)
                for k in ks:
                    def step_fn(w_, m_, g_, k=k, nm=nm_block, impl=impl):
                        return ss.swap_step(w_, m_, g_, k_iters=k,
                                            nm_block=nm, impl=impl)

                    name = f"swap_step_d{d}_{pat}_{impl}_k{k}"
                    b.emit(name, step_fn, (w, m, g),
                           meta={"kind": "swap_step", "width": d,
                                 "chunk_rows": r, "pattern": pat,
                                 "nm_block": nm_block, "impl": impl,
                                 "k_iters": k})


def config_meta(cfg):
    params = []
    prunable = []
    for idx, (name, shape) in enumerate(cfg.layer_shapes()):
        params.append({"name": name, "dims": list(shape)})
        short = name.split(".", 2)[-1] if name.startswith("blocks.") else name
        if short in PRUNABLE_LAYERS:
            block = int(name.split(".")[1])
            prunable.append({
                "param_index": idx,
                "name": name,
                "layer_type": short,
                "block": block,
                "d_out": shape[0],
                "d_in": shape[1],
                "stream": LAYER_TO_STREAM[short],
            })
    return {
        "vocab": cfg.vocab, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff, "n_blocks": cfg.n_blocks, "seq_len": cfg.seq_len,
        "batch": cfg.batch, "rope_theta": cfg.rope_theta,
        "init_seed": cfg.init_seed,
        "params": params, "prunable": prunable,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--configs", default=None,
                    help="comma-separated config names (default: registry)")
    ap.add_argument("--force", action="store_true",
                    help="regenerate even if the .hlo.txt already exists")
    args = ap.parse_args(argv)

    names = (args.configs.split(",") if args.configs
             else os.environ.get("SPARSESWAPS_AOT_CONFIGS",
                                 ",".join(DEFAULT_AOT_CONFIGS)).split(","))
    cfgs = [MODEL_CONFIGS[n] for n in names]

    b = Builder(args.out, force=args.force)
    widths = set()
    for cfg in cfgs:
        print(f"config {cfg.name}:")
        build_model_artifacts(b, cfg)
        widths.update(cfg.prunable_widths())

    # Pallas swap variants only for the smallest non-test width: they are
    # the TPU-structure path; the fused-XLA variant is the CPU fast path.
    pallas_widths = {min(w for w in widths if w >= 128)} if any(
        w >= 128 for w in widths) else set(widths)
    print("swap artifacts:")
    build_swap_artifacts(b, widths, pallas_widths)

    manifest = {
        "version": 1,
        "configs": {cfg.name: config_meta(cfg) for cfg in cfgs},
        "artifacts": b.artifacts,
        "swap_patterns": SWAP_PATTERNS,
        "swap_ks": list(SWAP_KS),
        "pallas_widths": sorted(pallas_widths),
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest.json: {len(b.artifacts)} artifacts")
    return 0


if __name__ == "__main__":
    sys.exit(main())
