"""Pallas fused Wanda-saliency kernel: S = |W| * sqrt(diag(G)).

The Wanda criterion (Sun et al., 2024) falls out of the paper's row-wise
objective as a Jensen upper bound (Sec 2.1.1); with the Gram matrix in
hand the feature norms are just sqrt(G_jj), so the saliency is a cheap
fused elementwise kernel — included mostly to exercise the full
warmstart path through Pallas and as a simple tiling example.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _saliency_kernel(w_ref, d_ref, out_ref):
    w = w_ref[...]
    diag = d_ref[0, :]
    out_ref[...] = jnp.abs(w) * jnp.sqrt(jnp.maximum(diag, 0.0))[None, :]


def wanda_saliency_pallas(w, g, *, tile_r: int = 128, tile_d: int = 128,
                          interpret: bool = True):
    """Wanda saliency for weight rows w [R, D] given Gram matrix g [D, D]."""
    r, d = w.shape
    tr = min(tile_r, r)
    td = min(tile_d, d)
    assert r % tr == 0 and d % td == 0, (r, d, tile_r, tile_d)
    diag = jnp.diagonal(g).reshape(1, d)

    grid = (r // tr, d // td)
    return pl.pallas_call(
        _saliency_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, td), lambda i, j: (i, j)),
            pl.BlockSpec((1, td), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tr, td), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=interpret,
    )(w, diag)
