"""Pallas Gram-accumulation kernel: G <- G + X^T X, tiled.

Used on the calibration path (Sec 2.1.2 of the paper): the Gram matrix is
accumulated on-the-fly as calibration batches stream through a layer, so
the O(B * d_in) activations are never cached — only the O(d_in^2) Gram
matrix is kept.

TPU mapping: grid ``(d/TI, d/TJ, T/TT)``; each program multiplies a
TT x TI tile of X with a TT x TJ tile (MXU matmul after transpose) and
accumulates into a revisited TI x TJ output block initialised from the
incoming Gram tile.  The token axis is the innermost (sequential) grid
dimension, so the output block stays resident in VMEM across the whole
accumulation — one HBM write per tile.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(gin_ref, xi_ref, xj_ref, out_ref):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = gin_ref[...]

    xi = xi_ref[...]  # [TT, TI]
    xj = xj_ref[...]  # [TT, TJ]
    out_ref[...] += jnp.dot(xi.T, xj, preferred_element_type=jnp.float32)


def gram_update_pallas(g, x, *, tile_d: int = 128, tile_t: int = 128,
                       interpret: bool = True):
    """Accumulate one activation batch into the Gram matrix.

    Args:
      g: [D, D] float32 current Gram matrix.
      x: [T, D] float32 activations (T tokens).
    Returns:
      [D, D] float32 updated Gram matrix G + X^T X.
    """
    t, d = x.shape
    ti = tj = min(tile_d, d)
    tt = min(tile_t, t)
    assert d % ti == 0 and t % tt == 0, (t, d, tile_d, tile_t)

    grid = (d // ti, d // tj, t // tt)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, tj), lambda i, j, k: (i, j)),
            pl.BlockSpec((tt, ti), lambda i, j, k: (k, i)),
            pl.BlockSpec((tt, tj), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((ti, tj), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(g, x, x)
