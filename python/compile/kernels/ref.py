"""Pure-jnp reference oracles for the SparseSwaps kernels.

Every Pallas kernel in this package (and the fused-XLA variants in
``compile.sparseswaps``) is checked against these functions by pytest /
hypothesis.  The math follows the paper exactly:

  * per-row loss       L(m)     = (w - m*w)^T G (w - m*w)           (Sec 2.1.2)
  * correlation vector c        = G ((1 - m) * w)                   (Sec 2.1.3)
  * 1-swap cost        dL(u, p) = 2 w_u c_u + w_u^2 G_uu
                                  - 2 w_p c_p + w_p^2 G_pp
                                  - 2 w_u w_p G_up                  (Eq. 5)
  * c update after accepting (u*, p*):
                       c <- c + w_u* G[:,u*] - w_p* G[:,p*]         (Eq. 6)

Conventions:
  * Activations are ``X`` of shape ``[T, D]`` (T = B tokens in the paper's
    notation, D = d_in); the Gram matrix is ``G = X^T X`` of shape [D, D].
  * Weight rows follow the paper layout: ``w`` has length d_in; a full
    weight matrix ``W`` is ``[d_out, d_in]`` so each *row* is pruned
    independently.
  * Masks are float arrays of {0.0, 1.0}; ``m_j = 1`` keeps weight j.
"""

from __future__ import annotations

import jax.numpy as jnp

# Sentinel for infeasible swaps.  Large but finite so that arithmetic with
# realistic swap costs (|dL| << 1e20) can never make an infeasible pair win.
BIG = jnp.float32(1e30)


def gram(x):
    """Gram matrix G = X^T X for activations x of shape [T, D]."""
    x = jnp.asarray(x, jnp.float32)
    return x.T @ x


def gram_accumulate(g, x):
    """One calibration-batch update: G <- G + X^T X."""
    return g + gram(x)


def row_loss(w, m, g):
    """Per-row pruning loss (w - m*w)^T G (w - m*w)."""
    q = (1.0 - m) * w
    return q @ (g @ q)


def batched_row_loss(w, m, g):
    """Row losses for W, M of shape [R, D]: returns [R]."""
    q = (1.0 - m) * w
    return jnp.einsum("rd,rd->r", q, q @ g)


def corr(w, m, g):
    """Correlation vector c = G ((1-m) * w) for a single row."""
    return g @ ((1.0 - m) * w)


def batched_corr(w, m, g):
    """Correlation vectors for W, M of shape [R, D]: returns [R, D]."""
    return ((1.0 - m) * w) @ g  # G symmetric: (G q)^T = q^T G


def wanda_saliency(w, g):
    """Wanda criterion |W_ij| * ||X_j||_2 = |W_ij| * sqrt(G_jj).

    w: [R, D] weight rows, g: [D, D] Gram matrix.  (Paper Sec 2.1.1: Wanda
    is the Jensen upper bound of the row-wise objective.)
    """
    return jnp.abs(w) * jnp.sqrt(jnp.clip(jnp.diagonal(g), 0.0))[None, :]


def swap_validity(m, nm_block=0):
    """Boolean validity matrix V[u, p] for 1-swaps on mask row m ([D]).

    u must currently be kept (m_u = 1), p pruned (m_p = 0).  For N:M
    patterns (nm_block = M > 0), u and p must fall in the same block of
    ``nm_block`` consecutive indices.
    """
    d = m.shape[-1]
    valid = (m[:, None] > 0.5) & (m[None, :] < 0.5)
    if nm_block:
        blk = jnp.arange(d) // nm_block
        valid = valid & (blk[:, None] == blk[None, :])
    return valid


def delta_matrix(w, m, g, c=None, nm_block=0):
    """Full dL[u, p] matrix (Eq. 5) for one row; infeasible pairs = BIG."""
    if c is None:
        c = corr(w, m, g)
    diag = jnp.diagonal(g)
    a_u = 2.0 * w * c + w * w * diag  # term of the newly pruned u
    b_p = -2.0 * w * c + w * w * diag  # term of the newly kept p
    inter = -2.0 * jnp.outer(w, w) * g
    dl = a_u[:, None] + b_p[None, :] + inter
    return jnp.where(swap_validity(m, nm_block), dl, BIG)


def best_swap(w, m, g, c=None, nm_block=0):
    """Returns (dl, u, p) of the best 1-swap for one row.

    Tie-breaking: first occurrence in row-major (u-major) order, matching
    ``jnp.argmin`` over the flattened matrix.
    """
    d = m.shape[-1]
    dl = delta_matrix(w, m, g, c, nm_block)
    idx = jnp.argmin(dl.reshape(-1))
    return dl.reshape(-1)[idx], idx // d, idx % d


def apply_swap(w, m, c, u, p, g):
    """Accept swap (u, p): flip mask entries and update c per Eq. 6."""
    m = m.at[u].set(0.0).at[p].set(1.0)
    c = c + w[u] * g[:, u] - w[p] * g[:, p]
    return m, c


def sparseswaps_row(w, m, g, t_max, nm_block=0, eps=0.0):
    """Reference Algorithm 1 on a single row (python loop, eager).

    Returns (m, losses) where losses[t] is the loss after t accepted swaps
    (losses[0] is the warmstart loss).  Terminates early at a 1-swap local
    optimum.  Used only in tests.
    """
    losses = [float(row_loss(w, m, g))]
    c = corr(w, m, g)
    for _ in range(t_max):
        dl, u, p = best_swap(w, m, g, c, nm_block)
        if not bool(dl < -eps):
            break
        m, c = apply_swap(w, m, c, u, p, g)
        losses.append(float(row_loss(w, m, g)))
    return m, losses


def topk_mask(scores, keep):
    """Per-row mask keeping the ``keep`` highest-score entries. [R, D]."""
    order = jnp.argsort(-scores, axis=1)
    ranks = jnp.argsort(order, axis=1)
    return (ranks < keep).astype(jnp.float32)


def nm_mask(scores, n, m_blk):
    """N:M mask: keep the N highest-score entries per block of M. [R, D]."""
    r, d = scores.shape
    assert d % m_blk == 0
    s = scores.reshape(r, d // m_blk, m_blk)
    order = jnp.argsort(-s, axis=2)
    ranks = jnp.argsort(order, axis=2)
    return (ranks < n).astype(jnp.float32).reshape(r, d)
