"""Pallas best-swap kernel: the compute hot-spot of SparseSwaps.

For every row r of a chunk, find

    argmin_{u,p}  dL(u, p) = 2 w_u c_u + w_u^2 G_uu
                             - 2 w_p c_p + w_p^2 G_pp - 2 w_u w_p G_up

subject to m_u = 1, m_p = 0 (and, for N:M sparsity, block(u) == block(p)).

TPU-oriented design (see DESIGN.md "Hardware adaptation"): the candidate
matrix dL is *never materialised* in HBM.  The grid is
``(rows, d/TU, d/TP)``; each program streams one TU x TP tile of G into
VMEM, forms the Eq.-5 tile with rank-1 broadcasts (VPU work), reduces it
to a tile-local (min, argmin), and folds that into a per-row running
minimum held in revisited output blocks — the shared-memory reduction a
CUDA implementation would use maps onto grid-revisited outputs.

VMEM per program: TU*TP*4B for the G tile plus O(TU+TP) vectors; with the
default 128x128 tile that is ~64 KiB, far below the ~16 MiB budget, so
tiles can be raised to 256/512 for production TPUs (block-shape sweep in
EXPERIMENTS.md section Perf).

On CPU the kernel must run with ``interpret=True`` (Mosaic custom-calls
cannot execute on the CPU PJRT plugin); the grid then lowers to a
sequential XLA loop, which is the correctness path, not the perf path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Plain python float: a jnp scalar would be captured as a traced constant
# inside the pallas kernel, which pallas_call rejects.
BIG = 1e30


def _best_swap_kernel(
    # inputs (refs)
    wu_ref, wp_ref, mu_ref, mp_ref, cu_ref, cp_ref, du_ref, dp_ref, g_ref,
    # outputs (refs, revisited across the two tile axes)
    dl_ref, u_ref, p_ref,
    *, tu: int, tp: int, nm_block: int,
):
    iu = pl.program_id(1)
    ip = pl.program_id(2)

    @pl.when((iu == 0) & (ip == 0))
    def _init():
        dl_ref[...] = jnp.full_like(dl_ref, BIG)
        u_ref[...] = jnp.full_like(u_ref, -1)
        p_ref[...] = jnp.full_like(p_ref, -1)

    wu = wu_ref[0, :]  # [TU] weights in the u-slice of this row
    wp = wp_ref[0, :]  # [TP] weights in the p-slice
    mu = mu_ref[0, :]
    mp = mp_ref[0, :]
    cu = cu_ref[0, :]
    cp = cp_ref[0, :]
    du = du_ref[0, :]  # diag(G) over the u-slice
    dp = dp_ref[0, :]
    g = g_ref[...]  # [TU, TP] tile of G

    # Eq. 5 terms.  a_u: cost contribution of pruning kept index u;
    # b_p: contribution of reviving pruned index p.
    a_u = jnp.where(mu > 0.5, 2.0 * wu * cu + wu * wu * du, BIG)
    b_p = jnp.where(mp < 0.5, -2.0 * wp * cp + wp * wp * dp, BIG)
    tile = a_u[:, None] + b_p[None, :] - 2.0 * (wu[:, None] * wp[None, :]) * g

    if nm_block:
        gu = iu * tu + jax.lax.iota(jnp.int32, tu)  # global u indices
        gp = ip * tp + jax.lax.iota(jnp.int32, tp)
        same = (gu[:, None] // nm_block) == (gp[None, :] // nm_block)
        tile = jnp.where(same, tile, BIG)

    flat = tile.reshape(-1)
    loc = jnp.argmin(flat)
    tmin = flat[loc]
    u_loc = (loc // tp).astype(jnp.int32)
    p_loc = (loc % tp).astype(jnp.int32)

    cur = dl_ref[0]
    better = tmin < cur
    dl_ref[0] = jnp.where(better, tmin, cur)
    u_ref[0] = jnp.where(better, iu * tu + u_loc, u_ref[0])
    p_ref[0] = jnp.where(better, ip * tp + p_loc, p_ref[0])


def best_swap_pallas(w, m, c, g, *, nm_block: int = 0, tile: int = 128,
                     interpret: bool = True):
    """Batched best 1-swap search.

    Args:
      w, m, c: [R, D] float32 — weight rows, masks (0/1), correlation
        vectors c = G((1-m)*w).
      g: [D, D] float32 Gram matrix.
      nm_block: 0 for per-row sparsity, otherwise the M of an N:M pattern.
      tile: tile edge for both the u and p axes of G.

    Returns:
      (dl[R] f32, u[R] i32, p[R] i32): the best swap per row; u = p = -1
      and dl = BIG when the row has no feasible pair.
    """
    r, d = w.shape
    tu = tp = min(tile, d)
    assert d % tu == 0 and d % tp == 0, (d, tile)
    if nm_block:
        assert tu % nm_block == 0, "tile must align with N:M blocks"
    diag = jnp.diagonal(g).reshape(1, d)
    w2 = w.reshape(r, d)

    grid = (r, d // tu, d // tp)
    row_u = pl.BlockSpec((1, tu), lambda i, j, k: (i, j))
    row_p = pl.BlockSpec((1, tp), lambda i, j, k: (i, k))
    vec_u = pl.BlockSpec((1, tu), lambda i, j, k: (0, j))
    vec_p = pl.BlockSpec((1, tp), lambda i, j, k: (0, k))
    g_spec = pl.BlockSpec((tu, tp), lambda i, j, k: (j, k))
    out_spec = pl.BlockSpec((1,), lambda i, j, k: (i,))

    kernel = functools.partial(_best_swap_kernel, tu=tu, tp=tp,
                               nm_block=nm_block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row_u, row_p, row_u, row_p, row_u, row_p, vec_u, vec_p,
                  g_spec],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
        ],
        interpret=interpret,
    )(w2, w2, m, m, c, c, diag, diag, g)
