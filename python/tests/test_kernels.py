"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes / sparsities / tile sizes; every property asserts
allclose against the reference.  These tests are the core correctness
signal for the kernels that get AOT-lowered into the runtime artifacts.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, ref, saliency, swap

SETTINGS = dict(deadline=None, max_examples=15)


def _instance(seed, rows, d, t, keep_frac=0.5, nm=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(t, d)).astype(np.float32)
    g = np.asarray(ref.gram(x))
    w = rng.normal(size=(rows, d)).astype(np.float32)
    scores = np.abs(w) * np.sqrt(np.diag(g))[None]
    if nm:
        m = np.asarray(ref.nm_mask(jnp.asarray(scores), nm // 2, nm))
    else:
        m = np.asarray(ref.topk_mask(jnp.asarray(scores),
                                     max(1, int(d * keep_frac))))
    c = np.asarray(ref.batched_corr(w, m, g))
    return w, m, c, g


class TestBestSwapKernel:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 10_000),
           rows=st.sampled_from([1, 2, 5]),
           d=st.sampled_from([32, 64, 128]),
           keep=st.sampled_from([0.25, 0.5, 0.75]))
    def test_matches_reference_row_pattern(self, seed, rows, d, keep):
        w, m, c, g = _instance(seed, rows, d, t=48, keep_frac=keep)
        dl, u, p = swap.best_swap_pallas(
            jnp.asarray(w), jnp.asarray(m), jnp.asarray(c), jnp.asarray(g),
            tile=32)
        for r in range(rows):
            dl_ref, _, _ = ref.best_swap(jnp.asarray(w[r]), jnp.asarray(m[r]),
                                         jnp.asarray(g))
            np.testing.assert_allclose(float(dl[r]), float(dl_ref),
                                       rtol=1e-4, atol=1e-2)
            # Returned indices must describe a feasible pair achieving dl.
            uu, pp = int(u[r]), int(p[r])
            assert m[r, uu] == 1.0 and m[r, pp] == 0.0
            full = np.asarray(ref.delta_matrix(jnp.asarray(w[r]),
                                               jnp.asarray(m[r]),
                                               jnp.asarray(g)))
            np.testing.assert_allclose(float(dl[r]), full[uu, pp],
                                       rtol=1e-4, atol=1e-2)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 10_000), d=st.sampled_from([32, 64, 128]),
           nm=st.sampled_from([4, 8]))
    def test_matches_reference_nm_pattern(self, seed, d, nm):
        w, m, c, g = _instance(seed, 3, d, t=48, nm=nm)
        dl, u, p = swap.best_swap_pallas(
            jnp.asarray(w), jnp.asarray(m), jnp.asarray(c), jnp.asarray(g),
            nm_block=nm, tile=32)
        for r in range(3):
            dl_ref, _, _ = ref.best_swap(jnp.asarray(w[r]), jnp.asarray(m[r]),
                                         jnp.asarray(g), nm_block=nm)
            np.testing.assert_allclose(float(dl[r]), float(dl_ref),
                                       rtol=1e-4, atol=1e-2)
            uu, pp = int(u[r]), int(p[r])
            assert uu // nm == pp // nm, "swap crossed an N:M block"

    @pytest.mark.parametrize("tile", [16, 32, 64, 128])
    def test_tile_size_invariance(self, tile):
        w, m, c, g = _instance(3, 4, 128, t=64)
        dl, _, _ = swap.best_swap_pallas(
            jnp.asarray(w), jnp.asarray(m), jnp.asarray(c), jnp.asarray(g),
            tile=tile)
        dl_ref = np.array([
            float(ref.best_swap(jnp.asarray(w[r]), jnp.asarray(m[r]),
                                jnp.asarray(g))[0]) for r in range(4)])
        np.testing.assert_allclose(np.asarray(dl), dl_ref, rtol=1e-4,
                                   atol=1e-2)

    def test_all_kept_row_has_no_feasible_swap(self):
        w, m, c, g = _instance(0, 2, 32, t=16)
        m = np.array(m)
        m[0, :] = 1.0  # nothing pruned: no (u, p) pair exists
        c = np.asarray(ref.batched_corr(w, m, g))
        dl, u, p = swap.best_swap_pallas(
            jnp.asarray(w), jnp.asarray(m), jnp.asarray(c), jnp.asarray(g),
            tile=32)
        assert float(dl[0]) >= 1e29 and int(u[0]) == -1 and int(p[0]) == -1

    def test_under_jit(self):
        w, m, c, g = _instance(9, 2, 64, t=32)
        f = jax.jit(lambda *a: swap.best_swap_pallas(*a, tile=32))
        dl, _, _ = f(jnp.asarray(w), jnp.asarray(m), jnp.asarray(c),
                     jnp.asarray(g))
        dl_ref, _, _ = ref.best_swap(jnp.asarray(w[0]), jnp.asarray(m[0]),
                                     jnp.asarray(g))
        np.testing.assert_allclose(float(dl[0]), float(dl_ref), rtol=1e-4,
                                   atol=1e-2)


class TestGramKernel:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 10_000),
           d=st.sampled_from([32, 64, 128]),
           t=st.sampled_from([32, 64, 128]))
    def test_matches_reference(self, seed, d, t):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(t, d)).astype(np.float32)
        g0 = rng.normal(size=(d, d)).astype(np.float32)
        g0 = g0 + g0.T
        out = gram.gram_update_pallas(jnp.asarray(g0), jnp.asarray(x),
                                      tile_d=32, tile_t=32)
        want = np.asarray(ref.gram_accumulate(jnp.asarray(g0),
                                              jnp.asarray(x)))
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4,
                                   atol=1e-2)

    def test_accumulation_chain_matches_single_shot(self):
        rng = np.random.default_rng(1)
        xs = [rng.normal(size=(64, 64)).astype(np.float32) for _ in range(4)]
        g = jnp.zeros((64, 64), jnp.float32)
        for x in xs:
            g = gram.gram_update_pallas(g, jnp.asarray(x), tile_d=32,
                                        tile_t=32)
        whole = np.concatenate(xs, axis=0)
        np.testing.assert_allclose(np.asarray(g), whole.T @ whole, rtol=1e-4,
                                   atol=1e-1)


class TestSaliencyKernel:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 10_000), rows=st.sampled_from([16, 64]),
           d=st.sampled_from([32, 128]))
    def test_matches_reference(self, seed, rows, d):
        rng = np.random.default_rng(seed)
        w = rng.normal(size=(rows, d)).astype(np.float32)
        x = rng.normal(size=(64, d)).astype(np.float32)
        g = np.asarray(ref.gram(x))
        out = saliency.wanda_saliency_pallas(jnp.asarray(w), jnp.asarray(g),
                                             tile_r=16, tile_d=32)
        want = np.asarray(ref.wanda_saliency(jnp.asarray(w), jnp.asarray(g)))
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5,
                                   atol=1e-5)


class TestReferenceInternals:
    """Sanity checks on the oracle itself (it anchors everything else)."""

    def test_loss_equals_residual_norm(self):
        # L = ||(w - m*w)^T X||^2 must equal the Gram form (Sec 2.1.2).
        rng = np.random.default_rng(0)
        x = rng.normal(size=(40, 16)).astype(np.float32)
        w = rng.normal(size=16).astype(np.float32)
        m = (rng.random(16) > 0.5).astype(np.float32)
        direct = float(np.sum(((1 - m) * w @ x.T) ** 2))
        viagram = float(ref.row_loss(jnp.asarray(w), jnp.asarray(m),
                                     jnp.asarray(ref.gram(x))))
        np.testing.assert_allclose(direct, viagram, rtol=1e-4)

    def test_delta_matches_recomputed_loss(self):
        # dL(u,p) from Eq. 5 must equal L(m') - L(m) exactly.
        rng = np.random.default_rng(4)
        x = rng.normal(size=(32, 12)).astype(np.float32)
        g = jnp.asarray(ref.gram(x))
        w = jnp.asarray(rng.normal(size=12).astype(np.float32))
        m = np.ones(12, np.float32)
        m[[1, 5, 7, 8]] = 0.0
        m = jnp.asarray(m)
        dl = np.asarray(ref.delta_matrix(w, m, g))
        base = float(ref.row_loss(w, m, g))
        for u in range(12):
            for p in range(12):
                if m[u] == 1.0 and m[p] == 0.0:
                    m2 = m.at[u].set(0.0).at[p].set(1.0)
                    np.testing.assert_allclose(
                        dl[u, p], float(ref.row_loss(w, m2, g)) - base,
                        rtol=1e-3, atol=1e-2)

    def test_corr_update_consistency(self):
        # Eq. 6 incremental update == recomputation from scratch.
        rng = np.random.default_rng(5)
        x = rng.normal(size=(32, 16)).astype(np.float32)
        g = jnp.asarray(ref.gram(x))
        w = jnp.asarray(rng.normal(size=16).astype(np.float32))
        m, _ = jnp.asarray(np.r_[np.ones(8), np.zeros(8)].astype(np.float32)), None
        c = ref.corr(w, m, g)
        m2, c2 = ref.apply_swap(w, m, c, 2, 11, g)
        np.testing.assert_allclose(np.asarray(c2),
                                   np.asarray(ref.corr(w, m2, g)),
                                   rtol=1e-4, atol=1e-2)

    def test_paper_counterexample_greedy_vs_joint(self):
        """The paper's Sec 2.1.3 example: greedy separate (p, u) choice is
        detrimental; the joint best 1-swap reaches L = 1 from L = 81."""
        # B = 1, d_in = 4: pruned contributions {+10, -1}, unpruned {+9, -9}.
        # Take X = ones so w_j phi_j = w_j.
        x = np.ones((1, 4), np.float32)
        g = jnp.asarray(ref.gram(x))
        w = jnp.asarray(np.array([10.0, -1.0, 9.0, -9.0], np.float32))
        m = jnp.asarray(np.array([0.0, 0.0, 1.0, 1.0], np.float32))
        assert float(ref.row_loss(w, m, g)) == pytest.approx(81.0)
        dl, u, p = ref.best_swap(w, m, g)
        # Best joint swap: prune w_3 = -9 (index 3), keep w_1 = -1 (index 1).
        assert (int(u), int(p)) == (3, 1)
        m2, _ = ref.apply_swap(w, m, ref.corr(w, m, g), int(u), int(p), g)
        assert float(ref.row_loss(w, m2, g)) == pytest.approx(1.0)
