"""L2 model tests: shapes, trainability, calibration statistics."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model as model_lib
from compile.configs import MODEL_CONFIGS
from compile.kernels import ref

CFG = MODEL_CONFIGS["tiny"]


def _params():
    return model_lib.init_params(CFG)


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, CFG.vocab, size=(CFG.batch, CFG.seq_len))
    targets = np.roll(tokens, -1, axis=1)
    return jnp.asarray(tokens, jnp.int32), jnp.asarray(targets, jnp.int32)


class TestForward:
    def test_shapes(self):
        tokens, _ = _batch()
        logits, caps = model_lib.forward(CFG, _params(), tokens,
                                         capture=True)
        assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
        assert len(caps) == CFG.n_blocks
        t = CFG.batch * CFG.seq_len
        for cap in caps:
            assert cap["qkv"].shape == (t, CFG.d_model)
            assert cap["o"].shape == (t, CFG.d_model)
            assert cap["gu"].shape == (t, CFG.d_model)
            assert cap["down"].shape == (t, CFG.d_ff)

    def test_causality(self):
        """Changing a future token must not change past logits."""
        tokens, _ = _batch()
        logits1, _ = model_lib.forward(CFG, _params(), tokens)
        tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % CFG.vocab)
        logits2, _ = model_lib.forward(CFG, _params(), tokens2)
        np.testing.assert_allclose(np.asarray(logits1[:, :-1]),
                                   np.asarray(logits2[:, :-1]),
                                   rtol=1e-4, atol=1e-4)

    def test_initial_loss_near_uniform(self):
        tokens, targets = _batch()
        loss = model_lib.loss_fn(CFG, _params(), tokens, targets)
        assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


class TestTrainStep:
    def test_loss_decreases(self):
        params = _params()
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        step = jnp.int32(0)
        tokens, targets = _batch()
        fn = jax.jit(lambda p, m_, v_, s, tk, tg: model_lib.train_step(
            CFG, p, m_, v_, s, tk, tg, jnp.float32(1e-3)))
        first = None
        for _ in range(12):
            params, m, v, step, loss = fn(params, m, v, step, tokens,
                                          targets)
            first = first if first is not None else float(loss)
        assert float(loss) < first - 0.3, (first, float(loss))
        assert int(step) == 12


class TestEval:
    def test_eval_matches_loss(self):
        params = _params()
        tokens, targets = _batch()
        nll_sum, count = model_lib.eval_step(CFG, params, tokens, targets)
        loss = model_lib.loss_fn(CFG, params, tokens, targets)
        np.testing.assert_allclose(float(nll_sum) / float(count),
                                   float(loss), rtol=1e-4)

    def test_seq_nll_consistency(self):
        """seq_nll with an all-ones mask sums to eval_step's total."""
        params = _params()
        tokens, targets = _batch()
        mask = jnp.ones((CFG.batch, CFG.seq_len), jnp.float32)
        per_seq = model_lib.seq_nll(CFG, params, tokens, targets, mask)
        nll_sum, _ = model_lib.eval_step(CFG, params, tokens, targets)
        np.testing.assert_allclose(float(jnp.sum(per_seq)), float(nll_sum),
                                   rtol=1e-4)

    def test_seq_nll_mask_zeroes_out(self):
        params = _params()
        tokens, targets = _batch()
        mask = jnp.zeros((CFG.batch, CFG.seq_len), jnp.float32)
        per_seq = model_lib.seq_nll(CFG, params, tokens, targets, mask)
        np.testing.assert_allclose(np.asarray(per_seq), 0.0, atol=1e-6)


class TestCalibStep:
    def _zeros_stats(self):
        nb, dm, dff = CFG.n_blocks, CFG.d_model, CFG.d_ff
        gs = (jnp.zeros((nb, dm, dm)), jnp.zeros((nb, dm, dm)),
              jnp.zeros((nb, dm, dm)), jnp.zeros((nb, dff, dff)))
        ss_ = (jnp.zeros((nb, dm)), jnp.zeros((nb, dm)), jnp.zeros((nb, dm)),
               jnp.zeros((nb, dff)))
        return gs, ss_

    def test_grams_match_captured_activations(self):
        params = _params()
        tokens, _ = _batch()
        gs, ss_ = self._zeros_stats()
        out = model_lib.calib_step(CFG, params, tokens, *gs, *ss_)
        g_qkv, g_o, g_gu, g_down = out[:4]
        s_qkv = out[4]
        _, caps = model_lib.forward(CFG, params, tokens, capture=True)
        for b, cap in enumerate(caps):
            np.testing.assert_allclose(
                np.asarray(g_qkv[b]), np.asarray(ref.gram(cap["qkv"])),
                rtol=1e-3, atol=1e-2)
            np.testing.assert_allclose(
                np.asarray(g_down[b]), np.asarray(ref.gram(cap["down"])),
                rtol=1e-3, atol=1e-2)
            np.testing.assert_allclose(
                np.asarray(s_qkv[b]),
                np.asarray(jnp.sum(cap["qkv"], axis=0)), rtol=1e-3,
                atol=1e-2)

    def test_accumulates_across_batches(self):
        params = _params()
        gs, ss_ = self._zeros_stats()
        t1, _ = _batch(1)
        t2, _ = _batch(2)
        out1 = model_lib.calib_step(CFG, params, t1, *gs, *ss_)
        out2 = model_lib.calib_step(CFG, params, t2, *out1)
        # Same as summing the two single-batch updates.
        outb = model_lib.calib_step(CFG, params, t2, *gs, *ss_)
        np.testing.assert_allclose(
            np.asarray(out2[0]), np.asarray(out1[0]) + np.asarray(outb[0])
            - 0.0, rtol=1e-3, atol=5e-2)

    def test_grams_are_psd(self):
        params = _params()
        tokens, _ = _batch()
        gs, ss_ = self._zeros_stats()
        out = model_lib.calib_step(CFG, params, tokens, *gs, *ss_)
        for g_stack in out[:4]:
            for b in range(CFG.n_blocks):
                evals = np.linalg.eigvalsh(np.asarray(g_stack[b]))
                assert evals.min() > -1e-1, evals.min()

    def test_pallas_gram_variant_matches(self):
        params = _params()
        tokens, _ = _batch()
        gs, ss_ = self._zeros_stats()
        out_x = model_lib.calib_step(CFG, params, tokens, *gs, *ss_)
        out_p = model_lib.calib_step(CFG, params, tokens, *gs, *ss_,
                                     use_pallas_gram=True)
        for a, b in zip(out_x, out_p):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-2)


class TestInit:
    def test_param_order_matches_config(self):
        params = _params()
        shapes = [tuple(s) for _, s in CFG.layer_shapes()]
        assert [p.shape for p in params] == shapes

    def test_deterministic(self):
        a = model_lib.init_params(CFG)
        b = model_lib.init_params(CFG)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
