"""L2 swap-step semantics: the batched Algorithm 1 that becomes the
``swap_step_*`` runtime artifacts.

Invariants checked (mirroring the Rust property tests so the native and
offload engines agree on semantics):
  * monotone, exact loss decrease (paper Prop 2.1);
  * sparsity pattern preserved (per-row counts / N:M block counts);
  * both impls ("xla" fused vs "pallas" L1 kernel) achieve the same loss;
  * a converged chunk is a 1-swap local optimum (exhaustively verified);
  * results match the eager single-row reference loop.
"""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import sparseswaps as ss
from compile.kernels import ref

SETTINGS = dict(deadline=None, max_examples=12)


def _instance(seed, rows, d, t=48, keep_frac=0.5, nm=0, warmstart="wanda"):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(t, d)).astype(np.float32)
    g = np.asarray(ref.gram(x))
    w = rng.normal(size=(rows, d)).astype(np.float32)
    if warmstart == "wanda":
        scores = np.abs(w) * np.sqrt(np.diag(g))[None]
    elif warmstart == "magnitude":
        scores = np.abs(w)
    else:  # random
        scores = rng.random((rows, d)).astype(np.float32)
    if nm:
        m = np.asarray(ref.nm_mask(jnp.asarray(scores), nm // 2, nm))
    else:
        m = np.asarray(ref.topk_mask(jnp.asarray(scores),
                                     max(1, int(d * keep_frac))))
    return (jnp.asarray(w), jnp.asarray(m), jnp.asarray(g))


class TestSwapStepInvariants:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 10_000), d=st.sampled_from([32, 64]),
           k=st.sampled_from([1, 3, 8]),
           warmstart=st.sampled_from(["wanda", "magnitude", "random"]))
    def test_monotone_and_pattern_preserving(self, seed, d, k, warmstart):
        w, m, g = _instance(seed, 4, d, warmstart=warmstart)
        m2, lb, la, ns = ss.swap_step(w, m, g, k_iters=k)
        la, lb = np.asarray(la), np.asarray(lb)
        assert np.all(la <= lb * (1 + 1e-5) + 1e-4)
        np.testing.assert_array_equal(np.asarray(m2).sum(1),
                                      np.asarray(m).sum(1))
        assert set(np.unique(np.asarray(m2))) <= {0.0, 1.0}

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 10_000), nm=st.sampled_from([4, 8]))
    def test_nm_block_counts_preserved(self, seed, nm):
        w, m, g = _instance(seed, 4, 64, nm=nm)
        m2, lb, la, _ = ss.swap_step(w, m, g, k_iters=5, nm_block=nm)
        blocks = np.asarray(m2).reshape(4, 64 // nm, nm).sum(2)
        assert np.all(blocks == nm // 2)
        assert np.all(np.asarray(la) <= np.asarray(lb) + 1e-4)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 10_000), d=st.sampled_from([32, 64]))
    def test_impl_equivalence(self, seed, d):
        """Fused-XLA and Pallas engines reach the same loss (tie-breaking
        may differ, so masks can differ; the objective may not)."""
        w, m, g = _instance(seed, 3, d)
        _, _, la_x, ns_x = ss.swap_step(w, m, g, k_iters=6, impl="xla")
        _, _, la_p, ns_p = ss.swap_step(w, m, g, k_iters=6, impl="pallas",
                                        tile=32)
        np.testing.assert_allclose(np.asarray(la_x), np.asarray(la_p),
                                   rtol=1e-3, atol=1e-2)
        np.testing.assert_array_equal(np.asarray(ns_x), np.asarray(ns_p))

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 10_000))
    def test_matches_eager_reference_loop(self, seed):
        w, m, g = _instance(seed, 2, 32)
        k = 4
        m2, _, la, _ = ss.swap_step(w, m, g, k_iters=k)
        for r in range(2):
            _, losses = ref.sparseswaps_row(w[r], m[r], g, t_max=k)
            np.testing.assert_allclose(float(la[r]), losses[-1], rtol=1e-3,
                                       atol=1e-2)

    def test_convergence_to_local_optimum(self):
        """After enough iterations no single swap can improve (eps = 0
        local optimum, Def. A.1) — verified exhaustively per row."""
        w, m, g = _instance(11, 3, 24, t=32)
        m2, _, la, ns = ss.swap_step(w, m, g, k_iters=200)
        for r in range(3):
            dl = np.asarray(ref.delta_matrix(w[r], jnp.asarray(m2[r]), g))
            feasible = dl[dl < 1e29]
            # Allow tiny negative slack for f32 accumulation noise.
            assert feasible.min() >= -1e-2, feasible.min()

    def test_swap_count_bounded_by_k(self):
        w, m, g = _instance(2, 4, 32)
        for k in (1, 2, 5):
            _, _, _, ns = ss.swap_step(w, m, g, k_iters=k)
            assert np.all(np.asarray(ns) <= k)

    def test_zero_loss_warmstart_is_fixed_point(self):
        """A mask pruning only zero weights has L = 0; nothing to do."""
        d = 16
        w = np.zeros((1, d), np.float32)
        w[0, : d // 2] = np.arange(1, d // 2 + 1, dtype=np.float32)
        m = np.zeros((1, d), np.float32)
        m[0, : d // 2] = 1.0  # keep all non-zeros, prune only zeros
        x = np.random.default_rng(0).normal(size=(32, d)).astype(np.float32)
        g = np.asarray(ref.gram(x))
        m2, lb, la, ns = ss.swap_step(jnp.asarray(w), jnp.asarray(m),
                                      jnp.asarray(g), k_iters=5)
        assert float(lb[0]) < 1e-5 and float(la[0]) < 1e-5
        assert float(ns[0]) == 0.0
        np.testing.assert_array_equal(np.asarray(m2), m)

    def test_jit_and_shapes(self):
        w, m, g = _instance(0, 8, 32)
        f = jax.jit(lambda w_, m_, g_: ss.swap_step(w_, m_, g_, k_iters=2))
        m2, lb, la, ns = f(w, m, g)
        assert m2.shape == (8, 32) and lb.shape == (8,)
        assert la.shape == (8,) and ns.shape == (8,)


class TestErrorReductionScale:
    def test_wanda_warmstart_reduction_in_paper_ballpark(self):
        """Table 3 / Fig. 1 shape: on correlated data, ~dozens of swaps
        cut the Wanda per-row error by tens of percent."""
        rng = np.random.default_rng(0)
        d, t = 128, 256
        # Correlated features (random mixing) — the regime where Wanda's
        # diagonal bound is loose and swaps help most.
        base = rng.normal(size=(t, d)).astype(np.float32)
        mix = rng.normal(size=(d, d)).astype(np.float32) / np.sqrt(d)
        x = base @ (np.eye(d, dtype=np.float32) + 0.9 * mix)
        g = np.asarray(ref.gram(x))
        w = rng.normal(size=(16, d)).astype(np.float32)
        scores = np.abs(w) * np.sqrt(np.diag(g))[None]
        m = np.asarray(ref.topk_mask(jnp.asarray(scores), int(d * 0.4)))
        _, lb, la, _ = ss.swap_step(jnp.asarray(w), jnp.asarray(m),
                                    jnp.asarray(g), k_iters=50)
        reduction = 1.0 - float(np.asarray(la).sum() / np.asarray(lb).sum())
        assert reduction > 0.2, reduction  # paper reports up to ~0.6
