"""AOT pipeline tests: manifest consistency and HLO text sanity.

Full lowering of the zoo takes minutes, so these tests lower only the
`tiny` config into a temp dir and validate the manifest contract the Rust
loader depends on.
"""

import json
import os

import pytest

from compile import aot
from compile.configs import (MODEL_CONFIGS, PRUNABLE_LAYERS,
                             swap_chunk_rows)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    rc = aot.main(["--out", str(out), "--configs", "tiny"])
    assert rc == 0
    with open(out / "manifest.json") as f:
        return str(out), json.load(f)


class TestManifest:
    def test_artifact_files_exist(self, built):
        out, manifest = built
        for name, entry in manifest["artifacts"].items():
            path = os.path.join(out, entry["file"])
            assert os.path.exists(path), name
            head = open(path).read(200)
            assert head.startswith("HloModule"), name

    def test_config_metadata(self, built):
        _, manifest = built
        cfg = manifest["configs"]["tiny"]
        mc = MODEL_CONFIGS["tiny"]
        assert cfg["d_model"] == mc.d_model
        assert len(cfg["params"]) == len(mc.layer_shapes())
        prunable = cfg["prunable"]
        assert len(prunable) == mc.n_blocks * len(PRUNABLE_LAYERS)
        # Every prunable entry points at a weight with matching dims.
        for p in prunable:
            dims = cfg["params"][p["param_index"]]["dims"]
            assert dims == [p["d_out"], p["d_in"]]
            assert p["layer_type"] in PRUNABLE_LAYERS

    def test_train_step_signature_round_trip(self, built):
        _, manifest = built
        cfg = manifest["configs"]["tiny"]
        entry = manifest["artifacts"]["train_step_tiny"]
        n_params = len(cfg["params"])
        # inputs: params + m + v + step + tokens + targets + lr
        assert len(entry["inputs"]) == 3 * n_params + 4
        # outputs: params + m + v + step + loss
        assert len(entry["outputs"]) == 3 * n_params + 2

    def test_swap_step_signatures(self, built):
        _, manifest = built
        mc = MODEL_CONFIGS["tiny"]
        for d in mc.prunable_widths():
            r = swap_chunk_rows(d)
            name = f"swap_step_d{d}_row_xla_k1"
            entry = manifest["artifacts"][name]
            assert entry["inputs"][0]["dims"] == [r, d]
            assert entry["inputs"][2]["dims"] == [d, d]
            assert [o["dims"] for o in entry["outputs"]] == [
                [r, d], [r], [r], [r]]
            assert entry["chunk_rows"] == r

    def test_layer_loss_artifacts_present(self, built):
        _, manifest = built
        for d in MODEL_CONFIGS["tiny"].prunable_widths():
            assert f"layer_loss_d{d}" in manifest["artifacts"]

    def test_calib_step_signature(self, built):
        _, manifest = built
        cfg = manifest["configs"]["tiny"]
        entry = manifest["artifacts"]["calib_step_tiny"]
        n_params = len(cfg["params"])
        # params + tokens + 4 gram stacks + 4 sum stacks
        assert len(entry["inputs"]) == n_params + 1 + 8
        assert len(entry["outputs"]) == 8
        nb, dm, dff = cfg["n_blocks"], cfg["d_model"], cfg["d_ff"]
        assert entry["inputs"][n_params + 1]["dims"] == [nb, dm, dm]
        assert entry["inputs"][n_params + 4]["dims"] == [nb, dff, dff]


class TestChunkRows:
    def test_budget_respected(self):
        for d in (64, 128, 256, 512, 640, 1024):
            r = swap_chunk_rows(d)
            assert 8 <= r <= 256
            assert r * d * d * 4 <= 96 * 1024 * 1024 or r == 8

    def test_power_of_two(self):
        for d in (64, 256, 512):
            r = swap_chunk_rows(d)
            assert r & (r - 1) == 0
