//! Ablation C: cross-row sparsity reallocation (the paper's named
//! future-work direction) vs uniform per-row budgets, on layers with
//! heterogeneous row energies.
use std::time::Instant;

use sparseswaps::pruning::error::layer_loss;
use sparseswaps::pruning::mask::{mask_from_scores, Pattern};
use sparseswaps::pruning::realloc::{reallocate_layer, ReallocConfig};
use sparseswaps::pruning::saliency;
use sparseswaps::pruning::sparseswaps::{refine_layer, SwapConfig};
use sparseswaps::util::benchlib::Table;
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;

fn main() {
    let t0 = Instant::now();
    let mut table = Table::new(
        "Ablation C — cross-row budget reallocation (64x64, keep 40%, \
         20 instances)",
        &["row heterogeneity", "wanda/opt-ish", "uniform+SS",
          "realloc+SS", "extra gain", "mean moves"]);
    for hetero in [0.0f32, 1.0, 3.0] {
        let (mut sum_warm, mut sum_uni, mut sum_re) = (0.0, 0.0, 0.0);
        let mut moves = 0usize;
        let n = 20;
        for seed in 0..n {
            let mut rng = Rng::new(5000 + seed);
            let d = 64;
            let x = Matrix::from_fn(3 * d, d, |_, _| rng.gaussian_f32());
            let mut g = Matrix::zeros(d, d);
            g.gram_accumulate(&x);
            let w = Matrix::from_fn(16, d, |r, _| {
                rng.gaussian_f32() * (1.0 + hetero * r as f32 / 16.0)
            });
            let pattern = Pattern::PerRow { keep: (d * 2) / 5 };
            let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                        pattern);
            sum_warm += layer_loss(&w, &warm, &g);
            let mut uni = warm.clone();
            refine_layer(&w, &mut uni, &g, pattern,
                         &SwapConfig { t_max: 50, eps: 0.0 }, 1);
            sum_uni += layer_loss(&w, &uni, &g);
            let mut re = warm.clone();
            let out = reallocate_layer(&w, &mut re, &g, &ReallocConfig {
                max_moves: 512, min_keep: 2, t_max: 50,
            });
            sum_re += layer_loss(&w, &re, &g);
            moves += out.moves;
        }
        table.row(vec![
            format!("{hetero:.0}x"),
            format!("{:.0}", sum_warm / n as f64),
            format!("{:.0}", sum_uni / n as f64),
            format!("{:.0}", sum_re / n as f64),
            format!("{:.2}%", 100.0 * (1.0 - sum_re / sum_uni)),
            format!("{:.0}", moves as f64 / n as f64),
        ]);
    }
    table.print();
    table.append_to("reports/benchmarks.md").ok();
    println!("[ablation_realloc] done in {:.1}s",
             t0.elapsed().as_secs_f64());
}
