//! Regenerates paper Figure 2: perplexity vs the number of calibration
//! batches, Wanda vs Wanda+SparseSwaps at 50% / 60% sparsity.
mod common;

fn main() {
    common::run_bench("fig2", |ctx| {
        let model = if ctx.quick { "tiny" } else { "gpt-a" };
        let (t, plot) = sparseswaps::report::fig2(ctx, model)
            .map_err(|e| e.to_string())?;
        t.print();
        println!("{plot}");
        Ok(vec![t.to_markdown(), format!("\n```\n{plot}```\n")])
    });
}
