//! Regenerates paper Figure 1: per-layer relative reduction in local
//! pruning error vs Wanda, grouped by block and layer type.
mod common;

fn main() {
    common::run_bench("fig1", |ctx| {
        let model = if ctx.quick { "tiny" } else { "gpt-a" };
        let (t, plot) = sparseswaps::report::fig1(ctx, model)
            .map_err(|e| e.to_string())?;
        t.print();
        println!("{plot}");
        Ok(vec![t.to_markdown(), format!("\n```\n{plot}```\n")])
    });
}
