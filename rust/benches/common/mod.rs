//! Shared bench scaffolding: every bench target regenerates one paper
//! table/figure via the report module, times it, and appends the result
//! to reports/benchmarks.md.

use std::time::Instant;

use sparseswaps::report::Ctx;

pub const REPORT_PATH: &str = "reports/benchmarks.md";

/// Run one bench body with timing + report plumbing.  Skips (successfully)
/// when artifacts are missing so `cargo bench` works on fresh checkouts.
pub fn run_bench(name: &str,
                 body: impl FnOnce(&Ctx) -> Result<Vec<String>, String>) {
    sparseswaps::util::logging::init_from_env();
    let ctx = match Ctx::from_env() {
        Ok(c) => c,
        Err(e) => {
            println!("[{name}] SKIP: no artifacts ({e}); run `make \
                      artifacts` first");
            return;
        }
    };
    println!("[{name}] starting (quick={})", ctx.quick);
    let t0 = Instant::now();
    match body(&ctx) {
        Ok(blocks) => {
            let secs = t0.elapsed().as_secs_f64();
            println!("[{name}] done in {secs:.1}s");
            let mut out = format!("\n## bench {name} ({secs:.1}s)\n");
            for b in blocks {
                out.push_str(&b);
            }
            if let Some(dir) = std::path::Path::new(REPORT_PATH).parent() {
                std::fs::create_dir_all(dir).ok();
            }
            use std::io::Write;
            if let Ok(mut f) = std::fs::OpenOptions::new()
                .create(true).append(true).open(REPORT_PATH) {
                let _ = f.write_all(out.as_bytes());
            }
        }
        Err(e) => {
            println!("[{name}] FAILED: {e}");
            std::process::exit(1);
        }
    }
}
