//! Ablation B (DESIGN.md): swap-engine comparison.
//!
//! Part 1 (artifact-free, always runs): the legacy full-rescan native
//! loop vs the incremental active-set engine on each kernel dispatch
//! arm, on a realistic layer (d=1024 outside quick mode).  Verifies
//! every arm's masks are bit-identical to the rescan oracle, measures
//! wall-clock per accepted swap plus rows/s and swaps/s, and emits the
//! numbers to `reports/ablation_engine.json` and the "engine" section
//! of `reports/bench_kernels.json` so the speedup trajectory
//! (incremental-vs-rescan and SIMD-vs-scalar) is tracked per PR.
//!
//! Part 2 (artifact-free, always runs): the runtime-pool sweep — the
//! offload engine over the interp backend, fanning a block of layers
//! across 1/2/4 device workers.  Gates on pooled masks being
//! bit-identical to the serial schedule and reports rows/s, buffer-
//! cache hit rate and steal counts to the "pool" section of
//! `reports/bench_kernels.json`.
//!
//! Part 3 (artifact-free, always runs): the shard-granularity sweep —
//! the native engine through the shared shard dispatch on a skewed
//! synthetic block (one layer 4x the rows of the rest), comparing
//! layer-granular scheduling against row shards.  Gates on bit-
//! identical masks across granularities and reports rows/s plus the
//! worker load-imbalance (max/mean busy time) to the "shards" section
//! of `reports/bench_kernels.json`.
//!
//! Part 4 (artifact-free, always runs): the raw-speed wave-2 sweep —
//! shared gmax skip-bound tables vs per-shard recompute (per kernel
//! arm), key-only device-cache probes through the block scheduler,
//! resident trainer state upload bytes vs the full-set baseline, and
//! the f32 pair-scan arm against its f64 oracle.  Gates on
//! bit-identical masks / bounded f32 drift and writes the "wave2"
//! section of `reports/bench_kernels.json`.
//!
//! Part 5 (artifact-free, always runs): the fault-recovery sweep —
//! the offload[interp] block refinement under the deterministic fault
//! harness (one worker killed mid-run plus a bounded transient
//! storm).  Gates on the faulted run completing with masks
//! bit-identical to the fault-free run and reports the recovery
//! overhead to the "faults" section of `reports/bench_kernels.json`.
//!
//! Part 6 (artifact-free, always runs): the sparsity-sweep gate —
//! the warm-started curve through one `PruneSession` vs a cold
//! fresh-session prune per grid point.  Gates on the warm sweep
//! paying exactly one calibration pass and coming in at least 2x
//! faster than cold-per-point at equal-or-better refined loss, and
//! writes `reports/sweep.json` (the CI curve artifact) plus the
//! "sweep" section of `reports/bench_kernels.json`.
//!
//! Part 7 (artifact-free, always runs): the out-of-core streaming
//! gate — the staged streamed pipeline (weights leased per block from
//! a checkpoint, block b+1 prefetched while block b refines) vs the
//! fully-resident baseline on a deep skewed model.  Gates on bitwise
//! mask parity, accounted peak residency within the 2-block staging
//! bound, and streamed wall clock under 1.15x resident, and writes
//! the "stream" section of `reports/bench_kernels.json`.
//!
//! Part 8 (artifact-free, always runs): the pooled-calibration gate —
//! the striped Gram accumulation and fanned perplexity eval across
//! 1/2/4 device workers vs the serial baseline.  Gates on bit-
//! identical Grams, refined masks and ppl at every device count, on
//! the resident-accumulator upload bytes matching the tokens-only
//! steady-state model exactly, and on the 4-device wall coming in
//! under 0.9x serial; writes the "calib" section of
//! `reports/bench_kernels.json`.
//!
//! Part 9 (needs artifacts): the fused-XLA and Pallas offload engines
//! on their own artifact-width layer.
mod common;

use std::sync::Mutex;
use std::time::Instant;

use sparseswaps::coordinator::scheduler::{
    refine_block, BlockSchedule, LayerWork,
};
use sparseswaps::coordinator::{
    refine_layer_offload, sweep, train, MaskSpec, OffloadConfig,
    OffloadEngine, PatternKind, PruneSession, Refiner, RunOptions,
    SweepConfig, TrainConfig,
};
use sparseswaps::data::{Dataset, Split};
use sparseswaps::eval::{perplexity, perplexity_pool};
use sparseswaps::gram::{
    accumulate, accumulate_pool, expected_upload_bytes, STREAMS,
};
use sparseswaps::model::testutil::{meta_for, tiny_manifest, tiny_meta};
use sparseswaps::model::{checkpoint, ParamStore, StreamingStore,
                         WeightStore};
use sparseswaps::pruning::engine::{LayerContext, RefineEngine};
use sparseswaps::pruning::Criterion;
use sparseswaps::pruning::mask::{mask_from_scores, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::pruning::sparseswaps::{
    gmax_table, refine_layer_rescan, LayerOutcome, NativeEngine,
    SwapConfig,
};
use sparseswaps::runtime::testutil::{
    faulty_interp_pool, interp_pool, interp_runtime, model_manifest,
    swap_manifest,
};
use sparseswaps::runtime::{FaultPlan, Runtime, RuntimeOptions};
use sparseswaps::util::benchlib::{merge_json_section, Table};
use sparseswaps::util::jsonlite::Json;
use sparseswaps::util::kernels;
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;
use sparseswaps::util::threadpool::ThreadPool;

fn record(table: &mut Table, engines_json: &mut Vec<Json>, label: &str,
          rows: usize, secs: f64, outcome: &LayerOutcome) -> f64 {
    let secs_safe = secs.max(1e-9);
    let swaps = outcome.total_swaps().max(1);
    let rows_per_s = rows as f64 / secs_safe;
    let swaps_per_s = swaps as f64 / secs_safe;
    table.row(vec![
        label.to_string(),
        format!("{secs:.3}"),
        swaps.to_string(),
        format!("{:.1}", 1e6 * secs / swaps as f64),
        format!("{rows_per_s:.0}"),
        format!("{swaps_per_s:.0}"),
        format!("{:.2}%", 100.0 * outcome.relative_reduction()),
    ]);
    engines_json.push(Json::obj(vec![
        ("engine", Json::str(label)),
        ("seconds", Json::num(secs)),
        ("swaps", Json::num(outcome.total_swaps() as f64)),
        ("rows_per_s", Json::num(rows_per_s)),
        ("swaps_per_s", Json::num(swaps_per_s)),
        ("rel_reduction", Json::num(outcome.relative_reduction())),
    ]));
    rows_per_s
}

/// Artifact-free engine comparison; exits non-zero if any arm's mask
/// diverges from the rescan oracle.
fn native_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
    let (d, rows, t_max) =
        if quick { (128usize, 64usize, 10usize) }
        else { (1024, 256, 25) };
    let mut rng = Rng::new(7);
    let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
    let mut g = Matrix::zeros(d, d);
    g.gram_accumulate_par(&x, 4);
    let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
    let pattern = Pattern::PerRow { keep: d * 2 / 5 };
    let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()), pattern);
    let cfg = SwapConfig { t_max, eps: 0.0 };

    let mut table = Table::new(
        format!("Ablation B — native engines on one layer ({rows}x{d}, \
                 60%, T_max={t_max})"),
        &["Engine", "seconds", "total swaps", "µs/swap", "rows/s",
          "swaps/s", "rel. reduction"]);
    let mut engines_json: Vec<Json> = Vec::new();

    // Baseline: the legacy full-rescan loop (bit-exact oracle).
    let mut rescan_1t = f64::NAN;
    let mut mask_rescan: Option<Matrix> = None;
    for threads in [1usize, 4] {
        let mut mask = warm.clone();
        let t0 = Instant::now();
        let outcome = refine_layer_rescan(&w, &mut mask, &g, pattern,
                                          &cfg, threads);
        let secs = t0.elapsed().as_secs_f64();
        if threads == 1 {
            rescan_1t = secs;
            mask_rescan = Some(mask.clone());
        }
        record(&mut table, &mut engines_json,
               &format!("rescan[{threads}t]"), rows, secs, &outcome);
    }
    let mask_rescan = mask_rescan.expect("rescan ran at 1 thread");

    // Incremental active-set engine, per kernel arm x thread count.
    let mut rows_per_s_1t: Vec<(String, f64)> = Vec::new();
    let mut secs_1t: Vec<(String, f64)> = Vec::new();
    for arm in kernels::arms() {
        for threads in [1usize, 4] {
            let engine = NativeEngine { eps: 0.0, arm: Some(arm) };
            let ctx = LayerContext {
                w: w.view(), g: g.as_gram(), stats: None, pattern,
                t_max, threads,
                gmax: None,
            };
            let mut mask = warm.clone();
            let t0 = Instant::now();
            let outcome = engine.refine(&ctx, &mut mask, &[])
                .expect("native engine is infallible");
            let secs = t0.elapsed().as_secs_f64();
            if mask.data != mask_rescan.data {
                eprintln!("[ablation_engine] PARITY FAILURE: \
                           incremental[{}][{threads}t] mask diverged \
                           from the rescan oracle", arm.name());
                std::process::exit(1);
            }
            let label = format!("incremental[{}][{threads}t]",
                                arm.name());
            let rps = record(&mut table, &mut engines_json, &label,
                             rows, secs, &outcome.layer);
            if threads == 1 {
                rows_per_s_1t.push((arm.name().to_string(), rps));
                secs_1t.push((arm.name().to_string(), secs));
            }
        }
    }

    let secs_of = |name: &str| {
        secs_1t.iter().find(|(n, _)| n == name).map(|(_, s)| *s)
    };
    let scalar_1t = secs_of("scalar").unwrap_or(f64::NAN);
    let incremental_speedup = rescan_1t / scalar_1t.max(1e-9);
    println!("incremental active-set speedup vs rescan (scalar, 1t): \
              {incremental_speedup:.2}x");
    let simd_speedup = match secs_of("simd") {
        Some(simd_1t) => {
            let s = scalar_1t / simd_1t.max(1e-9);
            println!("SIMD arm speedup vs scalar (1t): {s:.2}x");
            Some(s)
        }
        None => {
            println!("SIMD arm unavailable on this host");
            None
        }
    };
    table.print();

    let mut fields = vec![
        ("bench", Json::str("ablation_engine")),
        ("rows", Json::num(rows as f64)),
        ("d", Json::num(d as f64)),
        ("t_max", Json::num(t_max as f64)),
        ("engines", Json::Arr(engines_json.clone())),
        ("incremental_speedup_1t", Json::num(incremental_speedup)),
    ];
    if let Some(s) = simd_speedup {
        fields.push(("simd_speedup_1t", Json::num(s)));
    }
    let json = Json::obj(fields);
    std::fs::create_dir_all("reports").ok();
    if let Err(e) = std::fs::write("reports/ablation_engine.json",
                                   format!("{json}\n")) {
        eprintln!("[ablation_engine] FAILED writing report: {e}");
        std::process::exit(1);
    }

    let engine_section = Json::obj(vec![
        ("d", Json::num(d as f64)),
        ("rows", Json::num(rows as f64)),
        ("t_max", Json::num(t_max as f64)),
        ("rescan_rows_per_s_1t",
         Json::num(rows as f64 / rescan_1t.max(1e-9))),
        ("rows_per_s_1t", Json::Obj(
            rows_per_s_1t.iter()
                .map(|(n, v)| (n.clone(), Json::num(*v)))
                .collect())),
        ("incremental_speedup_vs_rescan_1t",
         Json::num(incremental_speedup)),
        ("simd_speedup_vs_scalar_1t",
         simd_speedup.map(Json::num).unwrap_or(Json::Null)),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "engine", engine_section) {
        eprintln!("[ablation_engine] FAILED writing bench_kernels: {e}");
        std::process::exit(1);
    }
    println!("[ablation_engine] engine section written to \
              reports/bench_kernels.json");
}

/// Artifact-free runtime-pool sweep: the offload engine over the
/// interp backend, one block of layers fanned across 1/2/4 device
/// workers.  Exits non-zero if any pooled mask diverges from the
/// serial schedule (the CI bench smoke job gates on this).
fn pool_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
    let (d, chunk, rows, layers, t_max) =
        if quick { (64usize, 32usize, 64usize, 4usize, 10usize) }
        else { (256, 64, 192, 8, 25) };
    let manifest = swap_manifest(d, chunk);
    let pattern = Pattern::PerRow { keep: d * 2 / 5 };
    let mut rng = Rng::new(11);
    let work: Vec<(Matrix, Matrix, Matrix)> = (0..layers).map(|_| {
        let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate_par(&x, 4);
        let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);
        (w, g, warm)
    }).collect();

    let mut table = Table::new(
        format!("Runtime pool — offload[interp] layer fan-out \
                 ({layers} layers x {rows}x{d}, T_max={t_max})"),
        &["devices", "seconds", "rows/s", "cache hit rate", "steals",
          "speedup vs 1"]);
    let mut sweeps: Vec<Json> = Vec::new();
    let mut reference: Option<Vec<Matrix>> = None;
    let mut serial_secs = f64::NAN;
    for devices in [1usize, 2, 4] {
        let pool = interp_pool(&manifest, devices,
                               RuntimeOptions::default());
        let slots: Vec<Mutex<Option<Matrix>>> =
            (0..layers).map(|_| Mutex::new(None)).collect();
        let t0 = Instant::now();
        let jobs: Vec<Box<dyn FnOnce(&Runtime) + Send + '_>> = work
            .iter()
            .zip(&slots)
            .map(|((w, g, warm), slot)| {
                Box::new(move |rt: &Runtime| {
                    let ctx = LayerContext {
                        w: w.view(), g: g.as_gram(), stats: None,
                        pattern, t_max, threads: 1,
                        gmax: None,
                    };
                    let mut mask = warm.clone();
                    OffloadEngine::new(rt, "interp")
                        .refine(&ctx, &mut mask, &[])
                        .expect("interp offload refine");
                    *slot.lock().unwrap() = Some(mask);
                }) as Box<dyn FnOnce(&Runtime) + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let masks: Vec<Matrix> = slots.into_iter()
            .map(|s| s.into_inner().unwrap().expect("job completed"))
            .collect();
        match &reference {
            None => {
                serial_secs = secs;
                reference = Some(masks);
            }
            Some(want) => {
                for (li, (a, b)) in want.iter().zip(&masks).enumerate() {
                    if a.data != b.data {
                        eprintln!("[ablation_engine] PARITY FAILURE: \
                                   pool[{devices}] layer {li} mask \
                                   diverged from the serial schedule");
                        std::process::exit(1);
                    }
                }
            }
        }
        let stats = pool.stats_total();
        let rows_per_s = (layers * rows) as f64 / secs;
        let speedup = serial_secs / secs;
        table.row(vec![
            devices.to_string(),
            format!("{secs:.3}"),
            format!("{rows_per_s:.0}"),
            format!("{:.0}%", 100.0 * stats.cache_hit_rate()),
            pool.steals().to_string(),
            format!("{speedup:.2}x"),
        ]);
        sweeps.push(Json::obj(vec![
            ("devices", Json::num(devices as f64)),
            ("seconds", Json::num(secs)),
            ("rows_per_s", Json::num(rows_per_s)),
            ("cache_hit_rate", Json::num(stats.cache_hit_rate())),
            ("cache_evictions", Json::num(stats.cache_evictions as f64)),
            ("steals", Json::num(pool.steals() as f64)),
            ("speedup_vs_serial", Json::num(speedup)),
        ]));
    }
    table.print();
    let section = Json::obj(vec![
        ("d", Json::num(d as f64)),
        ("rows", Json::num(rows as f64)),
        ("layers", Json::num(layers as f64)),
        ("t_max", Json::num(t_max as f64)),
        ("sweeps", Json::Arr(sweeps)),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "pool", section) {
        eprintln!("[ablation_engine] FAILED writing bench_kernels: {e}");
        std::process::exit(1);
    }
    println!("[ablation_engine] pool section written to \
              reports/bench_kernels.json (serial parity OK)");
}

/// Artifact-free shard-granularity sweep on a skewed synthetic block:
/// one layer with 4x the rows of the rest pins a whole-layer worker
/// while the others idle; row shards split it.  Exits non-zero if any
/// granularity's masks diverge from the layer-granular schedule (the
/// CI bench smoke job gates on this).
fn shards_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
    let (d, base_rows, t_max) =
        if quick { (64usize, 24usize, 8usize) } else { (256, 96, 20) };
    let wide_rows = 4 * base_rows;
    let n_small = 7usize;
    let workers = 4usize;
    let pattern = Pattern::PerRow { keep: d * 2 / 5 };
    let mut rng = Rng::new(21);
    let mut row_counts = vec![wide_rows];
    row_counts.extend(vec![base_rows; n_small]);
    let layers: Vec<(Matrix, Matrix, Matrix)> = row_counts.iter()
        .map(|&rows| {
            let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
            let mut g = Matrix::zeros(d, d);
            g.gram_accumulate_par(&x, 4);
            let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
            let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                        pattern);
            (w, g, warm)
        })
        .collect();
    let total_rows: usize = row_counts.iter().sum();

    let mut table = Table::new(
        format!("Shard granularity — native engine, skewed block \
                 (1x{wide_rows} + {n_small}x{base_rows} rows, d={d}, \
                  {workers} workers, T_max={t_max})"),
        &["granularity", "seconds", "rows/s", "imbalance (max/mean)",
          "speedup vs layer"]);
    let mut sweeps: Vec<Json> = Vec::new();
    let mut reference: Option<Vec<Matrix>> = None;
    let mut layer_secs = f64::NAN;
    for (label, shard_rows) in
        [("layer", usize::MAX), ("shard-adaptive", 0usize),
         ("shard-16", 16)]
    {
        // Fresh pool per config so busy-time counters start at zero.
        let tp = ThreadPool::new(workers);
        let works: Vec<LayerWork> = layers.iter().enumerate()
            .map(|(li, (w, g, warm))| LayerWork {
                li,
                label: format!("layer{li}"),
                w: w.view(),
                g: g.as_gram(),
                stats: None,
                pattern,
                warm: warm.clone(),
                shard_align: 1,
                gram_key: sparseswaps::coordinator::swaploop::
                    next_refinement_id(),
            })
            .collect();
        let plan = BlockSchedule {
            t_max,
            threads_per_shard: 1,
            checkpoints: Vec::new(),
            shard_rows,
            serial: false,
            max_retries: 2,
        };
        let t0 = Instant::now();
        let res = refine_block(&tp, &Refiner::SparseSwapsNative,
                               &works, &plan)
            .expect("native shard refinement");
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let busy = tp.busy_nanos();
        let mean = busy.iter().sum::<u64>() as f64
            / busy.len().max(1) as f64;
        let imbalance = busy.iter().copied().max().unwrap_or(0) as f64
            / mean.max(1.0);
        let masks: Vec<Matrix> =
            res.into_iter().map(|r| r.mask).collect();
        match &reference {
            None => {
                layer_secs = secs;
                reference = Some(masks);
            }
            Some(want) => {
                for (li, (a, b)) in
                    want.iter().zip(&masks).enumerate() {
                    if a.data != b.data {
                        eprintln!("[ablation_engine] PARITY FAILURE: \
                                   {label} layer {li} mask diverged \
                                   from the layer-granular schedule");
                        std::process::exit(1);
                    }
                }
            }
        }
        let rows_per_s = total_rows as f64 / secs;
        let speedup = layer_secs / secs;
        table.row(vec![
            label.to_string(),
            format!("{secs:.3}"),
            format!("{rows_per_s:.0}"),
            format!("{imbalance:.2}"),
            format!("{speedup:.2}x"),
        ]);
        sweeps.push(Json::obj(vec![
            ("granularity", Json::str(label)),
            ("seconds", Json::num(secs)),
            ("rows_per_s", Json::num(rows_per_s)),
            ("imbalance_max_over_mean", Json::num(imbalance)),
            ("speedup_vs_layer", Json::num(speedup)),
        ]));
    }
    table.print();
    let section = Json::obj(vec![
        ("d", Json::num(d as f64)),
        ("rows_wide", Json::num(wide_rows as f64)),
        ("rows_small", Json::num(base_rows as f64)),
        ("layers", Json::num((1 + n_small) as f64)),
        ("workers", Json::num(workers as f64)),
        ("t_max", Json::num(t_max as f64)),
        ("sweeps", Json::Arr(sweeps)),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "shards", section) {
        eprintln!("[ablation_engine] FAILED writing bench_kernels: {e}");
        std::process::exit(1);
    }
    println!("[ablation_engine] shards section written to \
              reports/bench_kernels.json (granularity parity OK)");
}

/// Raw-speed wave-2 sweep (artifact-free): one subsection per wave-2
/// optimisation, each gated on bit-identical masks (or bounded f32
/// drift) with a non-zero exit on failure, all merged into the
/// "wave2" section of `reports/bench_kernels.json` so the CI bench
/// smoke job tracks the numbers per PR.
fn wave2_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();

    // -- gmax: shared skip-bound table vs per-shard recompute --------
    let (d, rows, t_max) =
        if quick { (64usize, 32usize, 8usize) } else { (384, 96, 12) };
    let shard_rows = (rows / 16).max(1);
    let mut rng = Rng::new(29);
    let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
    let mut g = Matrix::zeros(d, d);
    g.gram_accumulate_par(&x, 4);
    let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
    let pattern = Pattern::PerRow { keep: d * 2 / 5 };
    let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()), pattern);

    // Manual shard walk (the scheduler adds queueing noise; this
    // isolates the per-shard gmax recompute cost itself).
    let run = |arm: kernels::Arm, gmax: Option<&[f64]>| {
        let engine = NativeEngine { eps: 0.0, arm: Some(arm) };
        let mut mask = warm.clone();
        let t0 = Instant::now();
        let mut r0 = 0usize;
        while r0 < rows {
            let r1 = (r0 + shard_rows).min(rows);
            let ctx = LayerContext {
                w: w.view(), g: g.as_gram(), stats: None, pattern,
                t_max, threads: 1, gmax,
            };
            let mut shard = Matrix::zeros(r1 - r0, d);
            for r in r0..r1 {
                shard.row_mut(r - r0).copy_from_slice(mask.row(r));
            }
            engine.refine_rows(&ctx, r0..r1, &mut shard, &[])
                .expect("native refine_rows is infallible");
            for r in r0..r1 {
                mask.row_mut(r).copy_from_slice(shard.row(r - r0));
            }
            r0 = r1;
        }
        (mask, t0.elapsed().as_secs_f64().max(1e-9))
    };

    let tt0 = Instant::now();
    let table_vals = gmax_table(g.as_gram(), pattern.nm_block(), 1);
    let table_secs = tt0.elapsed().as_secs_f64();

    let mut table = Table::new(
        format!("Wave 2 — shared gmax table, {shard_rows}-row shards \
                 ({rows}x{d}, T_max={t_max})"),
        &["arm", "per-shard rows/s", "shared rows/s", "speedup"]);
    let mut gmax_json: Vec<Json> = Vec::new();
    for arm in kernels::arms() {
        let (mask_local, secs_local) = run(arm, None);
        let (mask_shared, secs_shared) = run(arm, Some(&table_vals));
        if mask_local.data != mask_shared.data {
            eprintln!("[ablation_engine] PARITY FAILURE: wave2 \
                       shared-gmax mask diverged from per-shard \
                       recompute on arm {}", arm.name());
            std::process::exit(1);
        }
        // Charge the one-off table build to the shared timing so the
        // speedup is end-to-end honest.
        let shared_total = (secs_shared + table_secs).max(1e-9);
        let local_rps = rows as f64 / secs_local;
        let shared_rps = rows as f64 / shared_total;
        let speedup = secs_local / shared_total;
        table.row(vec![
            arm.name().to_string(),
            format!("{local_rps:.0}"),
            format!("{shared_rps:.0}"),
            format!("{speedup:.2}x"),
        ]);
        gmax_json.push(Json::obj(vec![
            ("arm", Json::str(arm.name())),
            ("per_shard_rows_per_s", Json::num(local_rps)),
            ("shared_rows_per_s", Json::num(shared_rps)),
            ("speedup", Json::num(speedup)),
        ]));
    }
    table.print();

    // -- probes: key-only G lookups through the block scheduler ------
    let (pd, chunk, prows, players, pt_max, devices) =
        if quick { (64usize, 32usize, 256usize, 2usize, 6usize, 2usize) }
        else { (128, 32, 512, 2, 10, 2) };
    let manifest = swap_manifest(pd, chunk);
    let ppattern = Pattern::PerRow { keep: pd * 2 / 5 };
    let pwork: Vec<(Matrix, Matrix, Matrix)> = (0..players).map(|_| {
        let x = Matrix::from_fn(2 * pd, pd, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(pd, pd);
        g.gram_accumulate_par(&x, 4);
        let w = Matrix::from_fn(prows, pd, |_, _| rng.gaussian_f32());
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    ppattern);
        (w, g, warm)
    }).collect();
    let make_works = || {
        pwork.iter().enumerate()
            .map(|(li, (w, g, warm))| LayerWork {
                li,
                label: format!("layer{li}"),
                w: w.view(),
                g: g.as_gram(),
                stats: None,
                pattern: ppattern,
                warm: warm.clone(),
                shard_align: chunk,
                gram_key: sparseswaps::coordinator::swaploop::
                    next_refinement_id(),
            })
            .collect::<Vec<LayerWork>>()
    };
    let plan = BlockSchedule {
        t_max: pt_max,
        threads_per_shard: 1,
        checkpoints: Vec::new(),
        shard_rows: chunk,
        serial: false,
        max_retries: 2,
    };
    let pool = interp_pool(&manifest, devices, RuntimeOptions::default());
    let t0 = Instant::now();
    let res = refine_block(
        &pool,
        &Refiner::SparseSwapsOffload { impl_name: "interp".into() },
        &make_works(), &plan)
        .expect("interp offload block refinement");
    let psecs = t0.elapsed().as_secs_f64().max(1e-9);
    let tp = ThreadPool::new(devices);
    let nres = refine_block(&tp, &Refiner::SparseSwapsNative,
                            &make_works(), &plan)
        .expect("native block refinement");
    for (li, (a, b)) in nres.iter().zip(&res).enumerate() {
        if a.mask.data != b.mask.data {
            eprintln!("[ablation_engine] PARITY FAILURE: wave2 \
                       offload[interp] layer {li} mask diverged from \
                       the native engine");
            std::process::exit(1);
        }
    }
    let pstats = pool.stats_total();
    let n_shards: usize = res.iter().map(|r| r.shards).sum();
    let g_host_bytes = pstats.probe_misses * (pd * pd * 4) as u64;
    println!("wave2 probes: {}/{} G probes resident ({:.0}%), \
              {} host-copy bytes over {} shards",
             pstats.probe_hits,
             pstats.probe_hits + pstats.probe_misses,
             100.0 * pstats.probe_hit_rate(),
             g_host_bytes, n_shards);
    let probes_json = Json::obj(vec![
        ("d", Json::num(pd as f64)),
        ("layers", Json::num(players as f64)),
        ("rows", Json::num(prows as f64)),
        ("devices", Json::num(devices as f64)),
        ("shards", Json::num(n_shards as f64)),
        ("probe_hits", Json::num(pstats.probe_hits as f64)),
        ("probe_misses", Json::num(pstats.probe_misses as f64)),
        ("probe_hit_rate", Json::num(pstats.probe_hit_rate())),
        ("g_host_bytes", Json::num(g_host_bytes as f64)),
        ("g_host_bytes_per_shard",
         Json::num(g_host_bytes as f64 / n_shards.max(1) as f64)),
        ("rows_per_s",
         Json::num((players * prows) as f64 / psecs)),
    ]);

    // -- trainer: resident state vs full-set re-upload ---------------
    let meta = tiny_meta();
    let tmanifest = model_manifest(&meta);
    let rt = interp_runtime(&tmanifest, RuntimeOptions::default());
    let ds = Dataset::build(&meta, 42);
    let mut store = ParamStore::init(&meta, 3);
    let tcfg = TrainConfig {
        steps: if quick { 4 } else { 12 },
        lr: 1e-3,
        n_batches: 2,
        log_every: 1_000_000,
    };
    let ps_bytes: u64 = store.tensors.iter()
        .map(|t| t.byte_size() as u64).sum();
    let batch_pairs = ds.batches(&meta, Split::Train, tcfg.n_batches);
    let pair_bytes = (batch_pairs[0].0.byte_size()
                      + batch_pairs[0].1.byte_size()) as u64;
    let all_batch_bytes: u64 = batch_pairs.iter()
        .map(|(t, g)| (t.byte_size() + g.byte_size()) as u64)
        .sum();
    let steps = tcfg.steps as u64;
    let rep = train(&rt, &mut store, &ds, &tcfg).expect("interp train");
    let measured = rt.stats().upload_bytes;
    // Full-set baseline: params/m/v/step AND batch/lr shipped every
    // step.  Returned-set model: batches and lr go up once; only the
    // tensors the step returns (params/m/v/step) re-upload.
    let naive = steps * (3 * ps_bytes + 4 + pair_bytes + 4);
    let returned_set = steps * (3 * ps_bytes + 4) + all_batch_bytes + 4;
    if measured >= naive {
        eprintln!("[ablation_engine] PERF GATE FAILURE: wave2 trainer \
                   uploaded {measured} B over {steps} steps, not below \
                   the full-set baseline {naive} B");
        std::process::exit(1);
    }
    println!("wave2 trainer uploads: {measured} B measured vs {naive} B \
              full-set baseline ({returned_set} B returned-set model), \
              final loss {:.3}", rep.final_loss);
    let trainer_json = Json::obj(vec![
        ("steps", Json::num(steps as f64)),
        ("upload_bytes", Json::num(measured as f64)),
        ("full_set_bytes", Json::num(naive as f64)),
        ("returned_set_bytes", Json::num(returned_set as f64)),
        ("final_loss", Json::num(rep.final_loss)),
    ]);

    // -- pair_scan_f32: per-arm throughput vs the f64 oracle ---------
    let n = if quick { 4096usize } else { 65_536 };
    let iters = if quick { 50u32 } else { 400 };
    let b32: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
    let wp32: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
    let gp32: Vec<f32> =
        (0..n).map(|_| 1.0 + rng.gaussian_f32().abs()).collect();
    let b64: Vec<f64> = b32.iter().map(|&v| v as f64).collect();
    let wp64: Vec<f64> = wp32.iter().map(|&v| v as f64).collect();
    let gp64: Vec<f64> = gp32.iter().map(|&v| v as f64).collect();
    let (au, wu2) = (0.3f32, -1.1f32);
    let oracle = kernels::pair_scan_arm(
        kernels::Arm::Scalar, au as f64, wu2 as f64, &b64, &wp64,
        &gp64, f64::INFINITY)
        .expect("non-empty scan");
    let want32 = kernels::pair_scan_f32_arm(
        kernels::Arm::Scalar, au, wu2, &b32, &wp32, &gp32,
        f32::INFINITY)
        .expect("non-empty scan");
    let mut scan_json: Vec<Json> = Vec::new();
    for arm in kernels::arms() {
        let got = kernels::pair_scan_f32_arm(
            arm, au, wu2, &b32, &wp32, &gp32, f32::INFINITY)
            .expect("non-empty scan");
        if got.0.to_bits() != want32.0.to_bits() || got.1 != want32.1 {
            eprintln!("[ablation_engine] PARITY FAILURE: \
                       pair_scan_f32[{}] diverged from the scalar f32 \
                       arm", arm.name());
            std::process::exit(1);
        }
        if (got.0 as f64 - oracle.0).abs()
            > 1e-3 * oracle.0.abs().max(1.0) {
            eprintln!("[ablation_engine] PARITY FAILURE: \
                       pair_scan_f32[{}] drifted past 1e-3 of the f64 \
                       oracle", arm.name());
            std::process::exit(1);
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(kernels::pair_scan_f32_arm(
                arm, au, wu2, &b32, &wp32, &gp32, f32::INFINITY));
        }
        let f32_secs = t0.elapsed().as_secs_f64().max(1e-9);
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(kernels::pair_scan_arm(
                arm, au as f64, wu2 as f64, &b64, &wp64, &gp64,
                f64::INFINITY));
        }
        let f64_secs = t0.elapsed().as_secs_f64().max(1e-9);
        let total = n as f64 * iters as f64;
        scan_json.push(Json::obj(vec![
            ("arm", Json::str(arm.name())),
            ("f32_elems_per_s", Json::num(total / f32_secs)),
            ("f64_elems_per_s", Json::num(total / f64_secs)),
            ("f32_speedup", Json::num(f64_secs / f32_secs)),
        ]));
    }

    let section = Json::obj(vec![
        ("gmax", Json::obj(vec![
            ("d", Json::num(d as f64)),
            ("rows", Json::num(rows as f64)),
            ("shard_rows", Json::num(shard_rows as f64)),
            ("t_max", Json::num(t_max as f64)),
            ("table_secs", Json::num(table_secs)),
            ("arms", Json::Arr(gmax_json)),
        ])),
        ("probes", probes_json),
        ("trainer", trainer_json),
        ("pair_scan_f32", Json::obj(vec![
            ("n", Json::num(n as f64)),
            ("arms", Json::Arr(scan_json)),
        ])),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "wave2", section) {
        eprintln!("[ablation_engine] FAILED writing bench_kernels: {e}");
        std::process::exit(1);
    }
    println!("[ablation_engine] wave2 section written to \
              reports/bench_kernels.json (gmax/probe/trainer/f32 \
              parity OK)");
}

/// Artifact-free fault-recovery sweep: the offload[interp] block
/// refinement under the deterministic fault harness — device 1 killed
/// mid-run plus a bounded transient storm on the survivor.  Exits
/// non-zero unless the faulted run completes, its masks are
/// bit-identical to the fault-free run, and the plan actually forced
/// retries + a quarantine (the CI bench smoke job gates on this).
fn faults_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
    let (d, chunk, rows, layers, t_max, devices) =
        if quick { (64usize, 32usize, 128usize, 2usize, 6usize, 2usize) }
        else { (128, 32, 256, 4, 10, 2) };
    let manifest = swap_manifest(d, chunk);
    let pattern = Pattern::PerRow { keep: d * 2 / 5 };
    let mut rng = Rng::new(17);
    let work: Vec<(Matrix, Matrix, Matrix)> = (0..layers).map(|_| {
        let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate_par(&x, 4);
        let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);
        (w, g, warm)
    }).collect();
    let make_works = || {
        work.iter().enumerate()
            .map(|(li, (w, g, warm))| LayerWork {
                li,
                label: format!("layer{li}"),
                w: w.view(),
                g: g.as_gram(),
                stats: None,
                pattern,
                warm: warm.clone(),
                shard_align: chunk,
                gram_key: sparseswaps::coordinator::swaploop::
                    next_refinement_id(),
            })
            .collect::<Vec<LayerWork>>()
    };
    let plan = BlockSchedule {
        t_max,
        threads_per_shard: 1,
        checkpoints: Vec::new(),
        shard_rows: chunk,
        serial: false,
        max_retries: 8,
    };
    let refiner =
        Refiner::SparseSwapsOffload { impl_name: "interp".into() };

    let pool = interp_pool(&manifest, devices, RuntimeOptions::default());
    let t0 = Instant::now();
    let clean = refine_block(&pool, &refiner, &make_works(), &plan)
        .expect("clean interp block refinement");
    let clean_secs = t0.elapsed().as_secs_f64().max(1e-9);

    // `max_faults=1` keeps the survivor below the quarantine
    // threshold, so completion on device 0 is guaranteed with
    // `max_retries` above the total fault supply.
    let fplan = FaultPlan::parse(
        "seed=7;rate=0.05;max_faults=1;kill=1;kill_after=2")
        .expect("bench fault plan");
    let fpool = faulty_interp_pool(&manifest, devices,
                                   RuntimeOptions::default(), &fplan);
    let t0 = Instant::now();
    let faulted = refine_block(&fpool, &refiner, &make_works(), &plan)
        .unwrap_or_else(|e| {
            eprintln!("[ablation_engine] RECOVERY FAILURE: faulted \
                       block refinement did not complete: {e}");
            std::process::exit(1);
        });
    let fault_secs = t0.elapsed().as_secs_f64().max(1e-9);
    for (li, (a, b)) in clean.iter().zip(&faulted).enumerate() {
        if a.mask.data != b.mask.data {
            eprintln!("[ablation_engine] PARITY FAILURE: faulted \
                       layer {li} mask diverged from the fault-free \
                       run");
            std::process::exit(1);
        }
    }
    let retries = fpool.shard_retries();
    let quarantined = fpool.workers_quarantined();
    if retries == 0 || quarantined == 0 {
        eprintln!("[ablation_engine] RECOVERY FAILURE: the fault plan \
                   injected nothing (retries {retries}, quarantined \
                   {quarantined})");
        std::process::exit(1);
    }
    let total_rows = (layers * rows) as f64;
    let clean_rps = total_rows / clean_secs;
    let fault_rps = total_rows / fault_secs;
    let overhead_pct = 100.0 * (fault_secs / clean_secs - 1.0);
    let mut table = Table::new(
        format!("Fault recovery — offload[interp], 1 worker killed + \
                 transient storm ({layers} layers x {rows}x{d}, \
                 T_max={t_max})"),
        &["run", "seconds", "rows/s", "shard retries", "quarantined"]);
    table.row(vec!["clean".into(), format!("{clean_secs:.3}"),
                   format!("{clean_rps:.0}"), "0".into(), "0".into()]);
    table.row(vec!["faulted".into(), format!("{fault_secs:.3}"),
                   format!("{fault_rps:.0}"), retries.to_string(),
                   quarantined.to_string()]);
    table.print();
    let section = Json::obj(vec![
        ("d", Json::num(d as f64)),
        ("rows", Json::num(rows as f64)),
        ("layers", Json::num(layers as f64)),
        ("devices", Json::num(devices as f64)),
        ("t_max", Json::num(t_max as f64)),
        ("rows_per_s_clean", Json::num(clean_rps)),
        ("rows_per_s_faulted", Json::num(fault_rps)),
        ("recovery_overhead_pct", Json::num(overhead_pct)),
        ("shard_retries", Json::num(retries as f64)),
        ("workers_quarantined", Json::num(quarantined as f64)),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "faults", section) {
        eprintln!("[ablation_engine] FAILED writing bench_kernels: {e}");
        std::process::exit(1);
    }
    println!("[ablation_engine] faults section written to \
              reports/bench_kernels.json (recovery parity OK)");
}

/// Artifact-free sparsity-sweep gate: the warm-started curve through
/// one session vs a cold fresh-session prune per grid point.  The
/// warm sweep pays one calibration pass for the whole curve while
/// cold-per-point pays one per level, so at calibration-dominated
/// sizes the sweep must come in at least 2x faster — and every warm
/// point's refined loss must stay within 5% of the cold run's, with
/// the chain head (no inherited mask on either arm) exactly equal.
/// Exits non-zero on any violation (the CI bench smoke job gates on
/// this) and leaves `reports/sweep.json` behind as the CI curve
/// artifact.
fn sweep_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
    let (t_max, calib_batches) =
        if quick { (4usize, 8usize) } else { (8, 8) };
    let pool = interp_pool(&tiny_manifest(), 1,
                           RuntimeOptions::default());
    let meta = pool.manifest().config("tiny").unwrap().clone();
    let ds = Dataset::build(&meta, 42);
    let store = ParamStore::init(&meta, meta.init_seed);

    let cfg = SweepConfig {
        levels: vec![
            PatternKind::Unstructured { sparsity: 0.4 },
            PatternKind::Unstructured { sparsity: 0.55 },
            PatternKind::Unstructured { sparsity: 0.7 },
        ],
        criteria: vec![Criterion::Wanda],
        refiners: vec![Refiner::SparseSwapsNative],
        t_max,
        calib_batches,
        warm_start: true,
        cold_compare: false,
        eval_ppl: true,
        val_batches: 2,
        out: Some("reports/sweep.json".into()),
    };
    let mut session = PruneSession::new(&pool, &store, &ds,
                                        RunOptions::default());
    let warm = sweep::sweep(&mut session, &cfg)
        .expect("warm sweep over interp");
    if warm.calibrations != 1 {
        eprintln!("[ablation_engine] PERF GATE FAILURE: warm sweep \
                   paid {} calibration passes, expected 1",
                  warm.calibrations);
        std::process::exit(1);
    }
    let warm_secs = warm.prune_seconds().max(1e-9);

    // Cold baseline: a fresh session per grid point, timed including
    // its own calibration pass — what running each point standalone
    // costs.  Specs are built from the same grid walk the sweep uses.
    let mut cold: Vec<(f64, f64)> = Vec::new();
    for (criterion, refiner, level) in sweep::points(&cfg) {
        let spec = MaskSpec {
            criterion,
            pattern_kind: level,
            refiner,
            t_max,
            calib_batches,
            sequential: false,
            checkpoints: Vec::new(),
        };
        let t0 = Instant::now();
        let (_, rep) = PruneSession::new(&pool, &store, &ds,
                                         RunOptions::default())
            .prune(&spec)
            .expect("cold per-point prune");
        cold.push((t0.elapsed().as_secs_f64(),
                   rep.total_refined_loss()));
    }
    let cold_total: f64 = cold.iter().map(|(s, _)| s).sum();
    let speedup = cold_total / warm_secs;

    let mut table = Table::new(
        format!("Sparsity sweep — warm chain vs cold per point \
                 (tiny, wanda+native, T_max={t_max}, \
                 {calib_batches} calib batches)"),
        &["point", "warm s", "cold s", "warm loss", "cold loss",
          "swaps", "warm from"]);
    let mut points_json: Vec<Json> = Vec::new();
    for (w, (cold_secs, cold_loss)) in warm.points.iter().zip(&cold) {
        if w.refined_loss > cold_loss * 1.05 {
            eprintln!("[ablation_engine] PERF GATE FAILURE: sweep \
                       point {} warm refined loss {} exceeds the \
                       cold run's {} by more than 5%",
                      w.key, w.refined_loss, cold_loss);
            std::process::exit(1);
        }
        table.row(vec![
            w.key.clone(),
            format!("{:.3}", w.seconds),
            format!("{cold_secs:.3}"),
            format!("{:.1}", w.refined_loss),
            format!("{cold_loss:.1}"),
            w.swaps.to_string(),
            w.warm_from.clone().unwrap_or_else(|| "-".into()),
        ]);
        points_json.push(Json::obj(vec![
            ("key", Json::str(w.key.as_str())),
            ("target_sparsity", Json::num(w.target_sparsity)),
            ("warm_seconds", Json::num(w.seconds)),
            ("cold_seconds", Json::num(*cold_secs)),
            ("warm_refined_loss", Json::num(w.refined_loss)),
            ("cold_refined_loss", Json::num(*cold_loss)),
            ("swaps", Json::num(w.swaps as f64)),
        ]));
    }
    // Both arms start the first level from a cold warmstart, and the
    // pipeline is deterministic — any drift there is a real bug, not
    // a tolerance question.
    if warm.points[0].refined_loss != cold[0].1 {
        eprintln!("[ablation_engine] PARITY FAILURE: chain-head \
                   refined loss {} diverged from the cold run's {}",
                  warm.points[0].refined_loss, cold[0].1);
        std::process::exit(1);
    }
    if speedup < 2.0 {
        eprintln!("[ablation_engine] PERF GATE FAILURE: warm sweep \
                   {warm_secs:.3}s vs cold-per-point \
                   {cold_total:.3}s is only {speedup:.2}x, below the \
                   2x gate");
        std::process::exit(1);
    }
    table.print();
    println!("sweep: 1 calibration for {} points, {speedup:.2}x vs \
              cold-per-point",
             warm.points.len());

    let section = Json::obj(vec![
        ("t_max", Json::num(t_max as f64)),
        ("calib_batches", Json::num(calib_batches as f64)),
        ("points", Json::Arr(points_json)),
        ("calibrations_warm", Json::num(warm.calibrations as f64)),
        ("warm_prune_seconds", Json::num(warm_secs)),
        ("cold_total_seconds", Json::num(cold_total)),
        ("speedup_warm_vs_cold", Json::num(speedup)),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "sweep", section) {
        eprintln!("[ablation_engine] FAILED writing bench_kernels: {e}");
        std::process::exit(1);
    }
    println!("[ablation_engine] sweep section written to \
              reports/bench_kernels.json (warm-vs-cold gates OK; \
              curve at reports/sweep.json)");
}

/// Artifact-free out-of-core streaming gate: the staged streamed
/// pipeline (prefetch block b+1's weights and Gram accumulation while
/// block b refines) vs the fully-resident baseline on a deep skewed
/// model (d_ff = 4x d_model, so the MLP layers dominate each block).
/// Exits non-zero if any streamed mask diverges bitwise from the
/// resident run, if the store's accounted peak exceeds the 2-block
/// staging bound (globals + 2x the largest block), or if the streamed
/// wall clock lands at or past 1.15x the resident run (the prefetch
/// stage must hide the disk + Gram latency).  Writes the "stream"
/// section of `reports/bench_kernels.json`.
fn stream_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
    let (d_model, d_ff, n_blocks, t_max) =
        if quick { (32usize, 128usize, 4usize, 4usize) }
        else { (48, 192, 6, 8) };
    let meta = meta_for(96, d_model, 2, d_ff, n_blocks, 16, 2);
    let manifest = model_manifest(&meta);
    let pool = interp_pool(&manifest, 1, RuntimeOptions::default());
    let ds = Dataset::build(&meta, 42);
    let store = ParamStore::init(&meta, 5);
    let spec = MaskSpec {
        criterion: Criterion::Wanda,
        pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
        refiner: Refiner::SparseSwapsNative,
        t_max,
        calib_batches: 2,
        sequential: false,
        checkpoints: Vec::new(),
    };

    let t0 = Instant::now();
    let (resident_masks, resident_rep) =
        PruneSession::new(&pool, &store, &ds, RunOptions::default())
            .prune(&spec)
            .expect("resident prune");
    let resident_secs = t0.elapsed().as_secs_f64().max(1e-9);

    let path = std::env::temp_dir().join(format!(
        "sparseswaps_stream_bench_{}.ssck", std::process::id()));
    checkpoint::save(&path, &store, None)
        .expect("write streaming checkpoint");
    let sstore = StreamingStore::open(&path, &meta, 0)
        .expect("open streaming store");
    let t0 = Instant::now();
    let (stream_masks, stream_rep) =
        PruneSession::new(&pool, &sstore, &ds, RunOptions::default())
            .prune(&spec)
            .expect("streamed prune");
    let stream_secs = t0.elapsed().as_secs_f64().max(1e-9);
    let stats = sstore.stats();
    std::fs::remove_file(&path).ok();

    for (li, (a, b)) in resident_masks.masks.iter()
        .zip(&stream_masks.masks).enumerate()
    {
        if a.data != b.data {
            eprintln!("[ablation_engine] PARITY FAILURE: streamed \
                       layer {li} mask diverged from the resident \
                       store");
            std::process::exit(1);
        }
    }
    let bytes_of = |i: usize| -> usize {
        meta.params[i].1.iter().product::<usize>() * 4
    };
    let globals_bytes: usize =
        [0usize, 1 + n_blocks * 9, 2 + n_blocks * 9].iter()
            .map(|&i| bytes_of(i)).sum();
    let max_block_bytes = (0..n_blocks)
        .map(|b| (1 + b * 9..1 + (b + 1) * 9)
            .map(bytes_of).sum::<usize>())
        .max()
        .unwrap_or(0);
    let total_bytes: usize =
        (0..meta.params.len()).map(bytes_of).sum();
    let bound = globals_bytes + 2 * max_block_bytes;
    if stats.peak_bytes > bound {
        eprintln!("[ablation_engine] PERF GATE FAILURE: streamed peak \
                   residency {} B exceeds the 2-block staging bound \
                   {} B (globals {globals_bytes} + 2 x \
                   {max_block_bytes})", stats.peak_bytes, bound);
        std::process::exit(1);
    }
    let overhead = stream_secs / resident_secs;
    if overhead >= 1.15 {
        eprintln!("[ablation_engine] PERF GATE FAILURE: streamed wall \
                   {stream_secs:.3}s is {overhead:.2}x the resident \
                   run's {resident_secs:.3}s, at or past the 1.15x \
                   gate");
        std::process::exit(1);
    }

    let mib = |b: usize| b as f64 / (1u64 << 20) as f64;
    let mut table = Table::new(
        format!("Out-of-core streaming — staged vs resident \
                 ({n_blocks} blocks, d_model={d_model}, d_ff={d_ff}, \
                 T_max={t_max})"),
        &["store", "seconds", "calib s", "refine s", "peak MiB",
          "tensor loads"]);
    table.row(vec![
        "resident".into(),
        format!("{resident_secs:.3}"),
        format!("{:.3}", resident_rep.calib_seconds),
        format!("{:.3}", resident_rep.refine_seconds),
        format!("{:.2}", mib(total_bytes)),
        "0".into(),
    ]);
    table.row(vec![
        "streamed".into(),
        format!("{stream_secs:.3}"),
        format!("{:.3}", stream_rep.calib_seconds),
        format!("{:.3}", stream_rep.refine_seconds),
        format!("{:.2}", mib(stats.peak_bytes)),
        stats.loads.to_string(),
    ]);
    table.print();
    println!("stream: peak {:.2} MiB of a {:.2} MiB model \
              ({:.0}% saved), {overhead:.2}x resident wall",
             mib(stats.peak_bytes), mib(total_bytes),
             100.0 * (1.0 - stats.peak_bytes as f64
                      / total_bytes.max(1) as f64));

    let section = Json::obj(vec![
        ("d_model", Json::num(d_model as f64)),
        ("d_ff", Json::num(d_ff as f64)),
        ("blocks", Json::num(n_blocks as f64)),
        ("t_max", Json::num(t_max as f64)),
        ("resident_seconds", Json::num(resident_secs)),
        ("stream_seconds", Json::num(stream_secs)),
        ("stream_overhead", Json::num(overhead)),
        ("model_bytes", Json::num(total_bytes as f64)),
        ("peak_bytes", Json::num(stats.peak_bytes as f64)),
        ("bound_bytes", Json::num(bound as f64)),
        ("loads", Json::num(stats.loads as f64)),
        ("loaded_bytes", Json::num(stats.loaded_bytes as f64)),
        ("releases", Json::num(stats.releases as f64)),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "stream", section) {
        eprintln!("[ablation_engine] FAILED writing bench_kernels: {e}");
        std::process::exit(1);
    }
    println!("[ablation_engine] stream section written to \
              reports/bench_kernels.json (staged-vs-resident parity \
              and 2-block residency OK)");
}

/// Pooled calibration & eval vs the serial baseline.  Exits non-zero
/// on any Gram/mask/ppl divergence across device counts, on upload
/// bytes past the tokens-only steady-state model, or on the 4-device
/// calibration wall at or past 0.9x serial.
fn calib_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
    let n_blocks = if quick { 4usize } else { 6 };
    let meta = meta_for(96, 48, 2, 192, n_blocks, 16, 2);
    let manifest = model_manifest(&meta);
    let ds = Dataset::build(&meta, 42);
    let store = ParamStore::init(&meta, 5);
    let n_batches = 12usize;
    let calib = ds.batches(&meta, Split::Calibration, n_batches);

    // Min-of-two walls: the first pass per pool also pays artifact
    // compilation, the second is the steady state we gate on.
    let serial_pool = interp_pool(&manifest, 1, RuntimeOptions::default());
    let mut serial_secs = f64::INFINITY;
    let mut baseline = None;
    for _ in 0..2 {
        let t0 = Instant::now();
        let s = accumulate(serial_pool.primary(), &store, &calib)
            .expect("serial calibration");
        serial_secs = serial_secs.min(t0.elapsed().as_secs_f64());
        baseline.get_or_insert(s);
    }
    let serial_secs = serial_secs.max(1e-9);
    let baseline = baseline.unwrap();

    let mut table = Table::new(
        format!("Pooled calibration — striped fan-out vs serial \
                 ({n_blocks} blocks, d_model=48, d_ff=192, \
                 {n_batches} batches)"),
        &["devices", "seconds", "speedup", "MiB up", "MiB down",
          "probes resident"]);
    table.row(vec![
        "serial".into(), format!("{serial_secs:.3}"), "1.00x".into(),
        "-".into(), "-".into(), "-".into(),
    ]);
    let mut pooled_json: Vec<Json> = Vec::new();
    let mut wall4 = f64::INFINITY;
    for devices in [1usize, 2, 4] {
        let pool = interp_pool(&manifest, devices,
                               RuntimeOptions::default());
        let mut secs = f64::INFINITY;
        let mut stats = None;
        for _ in 0..2 {
            let t0 = Instant::now();
            let s = accumulate_pool(&pool, &store, &calib)
                .expect("pooled calibration");
            secs = secs.min(t0.elapsed().as_secs_f64());
            stats.get_or_insert(s);
        }
        let secs = secs.max(1e-9);
        let stats = stats.unwrap();
        if devices == 4 {
            wall4 = secs;
        }
        for block in 0..n_blocks {
            for si in 0..STREAMS.len() {
                if baseline.stream_gram(block, si)
                       != stats.stream_gram(block, si)
                   || baseline.stream_sum(block, si)
                       != stats.stream_sum(block, si) {
                    eprintln!("[ablation_engine] PARITY FAILURE: \
                               {devices}-device Gram stats diverged \
                               from serial (block {block}, stream \
                               {})", STREAMS[si]);
                    std::process::exit(1);
                }
            }
        }
        let t = &stats.traffic;
        let expected = expected_upload_bytes(&store, devices, &calib);
        if t.upload_bytes > expected {
            eprintln!("[ablation_engine] PERF GATE FAILURE: \
                       {devices}-device calibration uploaded {} B, \
                       past the tokens-only steady-state model's \
                       {expected} B — resident accumulators are \
                       re-uploading", t.upload_bytes);
            std::process::exit(1);
        }
        let mib = |b: u64| b as f64 / (1u64 << 20) as f64;
        table.row(vec![
            format!("{devices}"),
            format!("{secs:.3}"),
            format!("{:.2}x", serial_secs / secs),
            format!("{:.2}", mib(t.upload_bytes)),
            format!("{:.2}", mib(t.download_bytes)),
            format!("{}/{}", t.probe_hits,
                    t.probe_hits + t.probe_misses),
        ]);
        pooled_json.push(Json::obj(vec![
            ("devices", Json::num(devices as f64)),
            ("seconds", Json::num(secs)),
            ("speedup", Json::num(serial_secs / secs)),
            ("upload_bytes", Json::num(t.upload_bytes as f64)),
            ("expected_upload_bytes", Json::num(expected as f64)),
            ("download_bytes", Json::num(t.download_bytes as f64)),
            ("probe_hit_rate", Json::num(t.probe_hit_rate())),
        ]));
    }
    table.print();
    if wall4 >= 0.9 * serial_secs {
        eprintln!("[ablation_engine] PERF GATE FAILURE: 4-device \
                   calibration wall {wall4:.3}s is not under 0.9x \
                   the serial {serial_secs:.3}s");
        std::process::exit(1);
    }

    // Refined masks must ride the same decomposition: a pooled prune
    // must reproduce the serial masks bit-for-bit.
    let spec = MaskSpec {
        criterion: Criterion::Wanda,
        pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
        refiner: Refiner::SparseSwapsNative,
        t_max: 4,
        calib_batches: 3,
        sequential: false,
        checkpoints: Vec::new(),
    };
    let (serial_masks, _) =
        PruneSession::new(&serial_pool, &store, &ds,
                          RunOptions::default())
            .prune(&spec).expect("serial prune");
    let pool4 = interp_pool(&manifest, 4, RuntimeOptions::default());
    let (pooled_masks, _) =
        PruneSession::new(&pool4, &store, &ds, RunOptions::default())
            .prune(&spec).expect("pooled prune");
    for (li, (a, b)) in serial_masks.masks.iter()
        .zip(&pooled_masks.masks).enumerate()
    {
        if a.data != b.data {
            eprintln!("[ablation_engine] PARITY FAILURE: 4-device \
                       layer {li} mask diverged from the serial \
                       prune");
            std::process::exit(1);
        }
    }

    // Fanned eval must reduce to the serial ppl bit-for-bit.
    let val = ds.batches(&meta, Split::Validation, 5);
    let serial_ppl = perplexity(serial_pool.primary(), &store, &val)
        .expect("serial ppl");
    let pooled_ppl = perplexity_pool(&pool4, &store, &val)
        .expect("pooled ppl");
    if serial_ppl.to_bits() != pooled_ppl.to_bits() {
        eprintln!("[ablation_engine] PARITY FAILURE: 4-device ppl \
                   {pooled_ppl} diverged from serial {serial_ppl}");
        std::process::exit(1);
    }

    let section = Json::obj(vec![
        ("d_model", Json::num(48.0)),
        ("d_ff", Json::num(192.0)),
        ("blocks", Json::num(n_blocks as f64)),
        ("batches", Json::num(n_batches as f64)),
        ("serial_seconds", Json::num(serial_secs)),
        ("pooled", Json::Arr(pooled_json)),
        ("ppl", Json::num(serial_ppl)),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "calib", section) {
        eprintln!("[ablation_engine] FAILED writing bench_kernels: {e}");
        std::process::exit(1);
    }
    println!("[ablation_engine] calib section written to \
              reports/bench_kernels.json (pooled Gram/mask/ppl \
              parity and resident-upload accounting OK)");
}

fn main() {
    native_section();
    pool_section();
    shards_section();
    wave2_section();
    faults_section();
    sweep_section();
    stream_section();
    calib_section();

    // Offload engines (need AOT artifacts; their own layer at an
    // artifact width).
    common::run_bench("ablation_engine", |ctx| {
        let d = 128usize;
        let rows = 128usize;
        let t_max = if ctx.quick { 10 } else { 25 };
        let mut rng = Rng::new(7);
        let x = Matrix::from_fn(4 * d, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
        let pattern = Pattern::PerRow { keep: d * 2 / 5 };
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);

        let mut table = Table::new(
            format!("Ablation B — offload engines ({rows}x{d}, 60%, \
                     T_max={t_max})"),
            &["Engine", "seconds", "total swaps", "µs/swap", "rows/s",
              "swaps/s", "rel. reduction"]);
        let mut engines_json: Vec<Json> = Vec::new();
        let mut ran = 0;
        for impl_name in ["xla", "pallas"] {
            if sparseswaps::runtime::Manifest::load("artifacts").ok()
                .and_then(|m| m.find_swap_artifact(
                    d, "row", impl_name, 1).ok().map(|_| ()))
                .is_none() {
                continue;
            }
            let mut mask = warm.clone();
            let cfg = OffloadConfig { impl_name: impl_name.into(), t_max };
            let t0 = Instant::now();
            let (outcome, _) = refine_layer_offload(
                &ctx.rt, &w, &mut mask, &g, pattern, &cfg, &[])
                .map_err(|e| e.to_string())?;
            let secs = t0.elapsed().as_secs_f64();
            record(&mut table, &mut engines_json,
                   &format!("offload[{impl_name}]"), rows, secs,
                   &outcome);
            ran += 1;
        }
        if ran == 0 {
            return Ok(vec!["\n(no swap artifacts at this width)\n"
                .to_string()]);
        }
        table.print();
        // Append the offload rows to the report native_section() wrote,
        // so the perf trajectory keeps tracking every engine.
        let path = "reports/ablation_engine.json";
        if let Some(mut root) = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(text.trim()).ok())
        {
            if let Json::Obj(map) = &mut root {
                if let Some(Json::Arr(engines)) = map.get_mut("engines") {
                    engines.extend(engines_json);
                }
                map.insert("offload_d".into(), Json::num(d as f64));
            }
            std::fs::write(path, format!("{root}\n"))
                .map_err(|e| e.to_string())?;
        }
        Ok(vec![table.to_markdown()])
    });
}
