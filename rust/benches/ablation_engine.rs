//! Ablation B (DESIGN.md): swap-engine comparison on one realistic layer
//! — fused-XLA offload (k=1 vs k=8 per call), Pallas-kernel offload, and
//! the native Rust engine.  Measures wall-clock per accepted swap and
//! verifies all engines land on comparable losses.
mod common;

use std::time::Instant;

use sparseswaps::coordinator::{refine_layer_offload, OffloadConfig};
use sparseswaps::pruning::mask::{mask_from_scores, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::pruning::sparseswaps::{refine_layer, SwapConfig};
use sparseswaps::util::benchlib::Table;
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;

fn main() {
    common::run_bench("ablation_engine", |ctx| {
        let d = 128usize;
        let rows = 128usize;
        let t_max = if ctx.quick { 10 } else { 25 };
        let mut rng = Rng::new(7);
        let x = Matrix::from_fn(4 * d, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
        let pattern = Pattern::PerRow { keep: d * 2 / 5 };
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);

        let mut table = Table::new(
            format!("Ablation B — engines on one layer ({rows}x{d}, 60%, \
                     T_max={t_max})"),
            &["Engine", "seconds", "total swaps", "µs/swap",
              "rel. reduction"]);

        // Offload engines (require artifacts at this width).
        for impl_name in ["xla", "pallas"] {
            if sparseswaps::runtime::Manifest::load("artifacts").ok()
                .and_then(|m| m.find_swap_artifact(
                    d, "row", impl_name, 1).ok().map(|_| ()))
                .is_none() {
                continue;
            }
            let mut mask = warm.clone();
            let cfg = OffloadConfig { impl_name: impl_name.into(), t_max };
            let t0 = Instant::now();
            let (outcome, _) = refine_layer_offload(
                &ctx.rt, &w, &mut mask, &g, pattern, &cfg, &[])
                .map_err(|e| e.to_string())?;
            let secs = t0.elapsed().as_secs_f64();
            let swaps = outcome.total_swaps().max(1);
            table.row(vec![
                format!("offload[{impl_name}]"),
                format!("{secs:.3}"),
                swaps.to_string(),
                format!("{:.1}", 1e6 * secs / swaps as f64),
                format!("{:.2}%", 100.0 * outcome.relative_reduction()),
            ]);
        }
        // Native engine, 1 and N threads.
        for threads in [1usize, 4] {
            let mut mask = warm.clone();
            let cfg = SwapConfig { t_max, eps: 0.0 };
            let t0 = Instant::now();
            let outcome = refine_layer(&w, &mut mask, &g, pattern, &cfg,
                                       threads);
            let secs = t0.elapsed().as_secs_f64();
            let swaps = outcome.total_swaps().max(1);
            table.row(vec![
                format!("native[{threads}t]"),
                format!("{secs:.3}"),
                swaps.to_string(),
                format!("{:.1}", 1e6 * secs / swaps as f64),
                format!("{:.2}%", 100.0 * outcome.relative_reduction()),
            ]);
        }
        table.print();
        Ok(vec![table.to_markdown()])
    });
}
