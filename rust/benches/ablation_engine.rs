//! Ablation B (DESIGN.md): swap-engine comparison on one realistic layer
//! — fused-XLA offload (k=1 vs k=8 per call), Pallas-kernel offload, the
//! legacy full-rescan native loop, and the incremental active-set native
//! engine.  Measures wall-clock per accepted swap plus rows/s and
//! swaps/s throughput, verifies all engines land on comparable losses
//! (the two native loops must produce *identical* masks), and emits the
//! numbers to `reports/ablation_engine.json` so the incremental-engine
//! speedup is tracked in the perf trajectory.
mod common;

use std::time::Instant;

use sparseswaps::coordinator::{refine_layer_offload, OffloadConfig};
use sparseswaps::pruning::mask::{mask_from_scores, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::pruning::sparseswaps::{
    refine_layer, refine_layer_rescan, LayerOutcome, SwapConfig,
};
use sparseswaps::util::benchlib::Table;
use sparseswaps::util::jsonlite::Json;
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;

fn main() {
    common::run_bench("ablation_engine", |ctx| {
        let d = 128usize;
        let rows = 128usize;
        let t_max = if ctx.quick { 10 } else { 25 };
        let mut rng = Rng::new(7);
        let x = Matrix::from_fn(4 * d, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
        let pattern = Pattern::PerRow { keep: d * 2 / 5 };
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);

        let mut table = Table::new(
            format!("Ablation B — engines on one layer ({rows}x{d}, 60%, \
                     T_max={t_max})"),
            &["Engine", "seconds", "total swaps", "µs/swap", "rows/s",
              "swaps/s", "rel. reduction"]);
        let mut engines_json: Vec<Json> = Vec::new();
        let mut record = |table: &mut Table, label: &str, secs: f64,
                          outcome: &LayerOutcome| {
            let secs_safe = secs.max(1e-9);
            let swaps = outcome.total_swaps().max(1);
            let rows_per_s = rows as f64 / secs_safe;
            let swaps_per_s = swaps as f64 / secs_safe;
            table.row(vec![
                label.to_string(),
                format!("{secs:.3}"),
                swaps.to_string(),
                format!("{:.1}", 1e6 * secs / swaps as f64),
                format!("{rows_per_s:.0}"),
                format!("{swaps_per_s:.0}"),
                format!("{:.2}%", 100.0 * outcome.relative_reduction()),
            ]);
            engines_json.push(Json::obj(vec![
                ("engine", Json::str(label)),
                ("seconds", Json::num(secs)),
                ("swaps", Json::num(outcome.total_swaps() as f64)),
                ("rows_per_s", Json::num(rows_per_s)),
                ("swaps_per_s", Json::num(swaps_per_s)),
                ("rel_reduction",
                 Json::num(outcome.relative_reduction())),
            ]));
        };

        // Offload engines (require artifacts at this width).
        for impl_name in ["xla", "pallas"] {
            if sparseswaps::runtime::Manifest::load("artifacts").ok()
                .and_then(|m| m.find_swap_artifact(
                    d, "row", impl_name, 1).ok().map(|_| ()))
                .is_none() {
                continue;
            }
            let mut mask = warm.clone();
            let cfg = OffloadConfig { impl_name: impl_name.into(), t_max };
            let t0 = Instant::now();
            let (outcome, _) = refine_layer_offload(
                &ctx.rt, &w, &mut mask, &g, pattern, &cfg, &[])
                .map_err(|e| e.to_string())?;
            let secs = t0.elapsed().as_secs_f64();
            record(&mut table, &format!("offload[{impl_name}]"), secs,
                   &outcome);
        }

        // Native loops: legacy full-rescan vs incremental active-set,
        // at 1 and 4 row-parallel threads.  Masks must agree bitwise.
        let cfg = SwapConfig { t_max, eps: 0.0 };
        let mut rescan_1t = f64::NAN;
        let mut incremental_1t = f64::NAN;
        let mut mask_rescan: Option<Matrix> = None;
        for threads in [1usize, 4] {
            let mut mask = warm.clone();
            let t0 = Instant::now();
            let outcome = refine_layer_rescan(&w, &mut mask, &g, pattern,
                                              &cfg, threads);
            let secs = t0.elapsed().as_secs_f64();
            if threads == 1 {
                rescan_1t = secs;
                mask_rescan = Some(mask.clone());
            }
            record(&mut table, &format!("rescan[{threads}t]"), secs,
                   &outcome);
        }
        for threads in [1usize, 4] {
            let mut mask = warm.clone();
            let t0 = Instant::now();
            let outcome = refine_layer(&w, &mut mask, &g, pattern, &cfg,
                                       threads);
            let secs = t0.elapsed().as_secs_f64();
            if threads == 1 {
                incremental_1t = secs;
            }
            if mask.data != mask_rescan.as_ref().unwrap().data {
                return Err(format!(
                    "incremental mask diverged from rescan reference \
                     at {threads} threads"));
            }
            record(&mut table, &format!("incremental[{threads}t]"), secs,
                   &outcome);
        }
        let speedup = rescan_1t / incremental_1t.max(1e-9);
        println!("incremental active-set speedup vs rescan (1t): \
                  {speedup:.2}x");
        table.print();

        let json = Json::obj(vec![
            ("bench", Json::str("ablation_engine")),
            ("rows", Json::num(rows as f64)),
            ("d", Json::num(d as f64)),
            ("t_max", Json::num(t_max as f64)),
            ("engines", Json::Arr(engines_json)),
            ("incremental_speedup_1t", Json::num(speedup)),
        ]);
        std::fs::create_dir_all("reports").ok();
        std::fs::write("reports/ablation_engine.json",
                       format!("{json}\n"))
            .map_err(|e| e.to_string())?;

        Ok(vec![table.to_markdown(),
                format!("\nincremental active-set speedup vs rescan \
                         (1t): **{speedup:.2}x**\n")])
    });
}
