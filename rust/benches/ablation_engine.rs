//! Ablation B (DESIGN.md): swap-engine comparison.
//!
//! Part 1 (artifact-free, always runs): the legacy full-rescan native
//! loop vs the incremental active-set engine on each kernel dispatch
//! arm, on a realistic layer (d=1024 outside quick mode).  Verifies
//! every arm's masks are bit-identical to the rescan oracle, measures
//! wall-clock per accepted swap plus rows/s and swaps/s, and emits the
//! numbers to `reports/ablation_engine.json` and the "engine" section
//! of `reports/bench_kernels.json` so the speedup trajectory
//! (incremental-vs-rescan and SIMD-vs-scalar) is tracked per PR.
//!
//! Part 2 (artifact-free, always runs): the runtime-pool sweep — the
//! offload engine over the interp backend, fanning a block of layers
//! across 1/2/4 device workers.  Gates on pooled masks being
//! bit-identical to the serial schedule and reports rows/s, buffer-
//! cache hit rate and steal counts to the "pool" section of
//! `reports/bench_kernels.json`.
//!
//! Part 3 (artifact-free, always runs): the shard-granularity sweep —
//! the native engine through the shared shard dispatch on a skewed
//! synthetic block (one layer 4x the rows of the rest), comparing
//! layer-granular scheduling against row shards.  Gates on bit-
//! identical masks across granularities and reports rows/s plus the
//! worker load-imbalance (max/mean busy time) to the "shards" section
//! of `reports/bench_kernels.json`.
//!
//! Part 4 (needs artifacts): the fused-XLA and Pallas offload engines
//! on their own artifact-width layer.
mod common;

use std::sync::Mutex;
use std::time::Instant;

use sparseswaps::coordinator::scheduler::{
    refine_block, BlockSchedule, LayerWork,
};
use sparseswaps::coordinator::{
    refine_layer_offload, OffloadConfig, OffloadEngine, Refiner,
};
use sparseswaps::pruning::engine::{LayerContext, RefineEngine};
use sparseswaps::pruning::mask::{mask_from_scores, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::pruning::sparseswaps::{
    refine_layer_rescan, LayerOutcome, NativeEngine, SwapConfig,
};
use sparseswaps::runtime::testutil::{interp_pool, swap_manifest};
use sparseswaps::runtime::{Runtime, RuntimeOptions};
use sparseswaps::util::benchlib::{merge_json_section, Table};
use sparseswaps::util::jsonlite::Json;
use sparseswaps::util::kernels;
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;
use sparseswaps::util::threadpool::ThreadPool;

fn record(table: &mut Table, engines_json: &mut Vec<Json>, label: &str,
          rows: usize, secs: f64, outcome: &LayerOutcome) -> f64 {
    let secs_safe = secs.max(1e-9);
    let swaps = outcome.total_swaps().max(1);
    let rows_per_s = rows as f64 / secs_safe;
    let swaps_per_s = swaps as f64 / secs_safe;
    table.row(vec![
        label.to_string(),
        format!("{secs:.3}"),
        swaps.to_string(),
        format!("{:.1}", 1e6 * secs / swaps as f64),
        format!("{rows_per_s:.0}"),
        format!("{swaps_per_s:.0}"),
        format!("{:.2}%", 100.0 * outcome.relative_reduction()),
    ]);
    engines_json.push(Json::obj(vec![
        ("engine", Json::str(label)),
        ("seconds", Json::num(secs)),
        ("swaps", Json::num(outcome.total_swaps() as f64)),
        ("rows_per_s", Json::num(rows_per_s)),
        ("swaps_per_s", Json::num(swaps_per_s)),
        ("rel_reduction", Json::num(outcome.relative_reduction())),
    ]));
    rows_per_s
}

/// Artifact-free engine comparison; exits non-zero if any arm's mask
/// diverges from the rescan oracle.
fn native_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
    let (d, rows, t_max) =
        if quick { (128usize, 64usize, 10usize) }
        else { (1024, 256, 25) };
    let mut rng = Rng::new(7);
    let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
    let mut g = Matrix::zeros(d, d);
    g.gram_accumulate_par(&x, 4);
    let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
    let pattern = Pattern::PerRow { keep: d * 2 / 5 };
    let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()), pattern);
    let cfg = SwapConfig { t_max, eps: 0.0 };

    let mut table = Table::new(
        format!("Ablation B — native engines on one layer ({rows}x{d}, \
                 60%, T_max={t_max})"),
        &["Engine", "seconds", "total swaps", "µs/swap", "rows/s",
          "swaps/s", "rel. reduction"]);
    let mut engines_json: Vec<Json> = Vec::new();

    // Baseline: the legacy full-rescan loop (bit-exact oracle).
    let mut rescan_1t = f64::NAN;
    let mut mask_rescan: Option<Matrix> = None;
    for threads in [1usize, 4] {
        let mut mask = warm.clone();
        let t0 = Instant::now();
        let outcome = refine_layer_rescan(&w, &mut mask, &g, pattern,
                                          &cfg, threads);
        let secs = t0.elapsed().as_secs_f64();
        if threads == 1 {
            rescan_1t = secs;
            mask_rescan = Some(mask.clone());
        }
        record(&mut table, &mut engines_json,
               &format!("rescan[{threads}t]"), rows, secs, &outcome);
    }
    let mask_rescan = mask_rescan.expect("rescan ran at 1 thread");

    // Incremental active-set engine, per kernel arm x thread count.
    let mut rows_per_s_1t: Vec<(String, f64)> = Vec::new();
    let mut secs_1t: Vec<(String, f64)> = Vec::new();
    for arm in kernels::arms() {
        for threads in [1usize, 4] {
            let engine = NativeEngine { eps: 0.0, arm: Some(arm) };
            let ctx = LayerContext {
                w: &w, g: g.as_gram(), stats: None, pattern, t_max,
                threads,
            };
            let mut mask = warm.clone();
            let t0 = Instant::now();
            let outcome = engine.refine(&ctx, &mut mask, &[])
                .expect("native engine is infallible");
            let secs = t0.elapsed().as_secs_f64();
            if mask.data != mask_rescan.data {
                eprintln!("[ablation_engine] PARITY FAILURE: \
                           incremental[{}][{threads}t] mask diverged \
                           from the rescan oracle", arm.name());
                std::process::exit(1);
            }
            let label = format!("incremental[{}][{threads}t]",
                                arm.name());
            let rps = record(&mut table, &mut engines_json, &label,
                             rows, secs, &outcome.layer);
            if threads == 1 {
                rows_per_s_1t.push((arm.name().to_string(), rps));
                secs_1t.push((arm.name().to_string(), secs));
            }
        }
    }

    let secs_of = |name: &str| {
        secs_1t.iter().find(|(n, _)| n == name).map(|(_, s)| *s)
    };
    let scalar_1t = secs_of("scalar").unwrap_or(f64::NAN);
    let incremental_speedup = rescan_1t / scalar_1t.max(1e-9);
    println!("incremental active-set speedup vs rescan (scalar, 1t): \
              {incremental_speedup:.2}x");
    let simd_speedup = match secs_of("simd") {
        Some(simd_1t) => {
            let s = scalar_1t / simd_1t.max(1e-9);
            println!("SIMD arm speedup vs scalar (1t): {s:.2}x");
            Some(s)
        }
        None => {
            println!("SIMD arm unavailable on this host");
            None
        }
    };
    table.print();

    let mut fields = vec![
        ("bench", Json::str("ablation_engine")),
        ("rows", Json::num(rows as f64)),
        ("d", Json::num(d as f64)),
        ("t_max", Json::num(t_max as f64)),
        ("engines", Json::Arr(engines_json.clone())),
        ("incremental_speedup_1t", Json::num(incremental_speedup)),
    ];
    if let Some(s) = simd_speedup {
        fields.push(("simd_speedup_1t", Json::num(s)));
    }
    let json = Json::obj(fields);
    std::fs::create_dir_all("reports").ok();
    if let Err(e) = std::fs::write("reports/ablation_engine.json",
                                   format!("{json}\n")) {
        eprintln!("[ablation_engine] FAILED writing report: {e}");
        std::process::exit(1);
    }

    let engine_section = Json::obj(vec![
        ("d", Json::num(d as f64)),
        ("rows", Json::num(rows as f64)),
        ("t_max", Json::num(t_max as f64)),
        ("rescan_rows_per_s_1t",
         Json::num(rows as f64 / rescan_1t.max(1e-9))),
        ("rows_per_s_1t", Json::Obj(
            rows_per_s_1t.iter()
                .map(|(n, v)| (n.clone(), Json::num(*v)))
                .collect())),
        ("incremental_speedup_vs_rescan_1t",
         Json::num(incremental_speedup)),
        ("simd_speedup_vs_scalar_1t",
         simd_speedup.map(Json::num).unwrap_or(Json::Null)),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "engine", engine_section) {
        eprintln!("[ablation_engine] FAILED writing bench_kernels: {e}");
        std::process::exit(1);
    }
    println!("[ablation_engine] engine section written to \
              reports/bench_kernels.json");
}

/// Artifact-free runtime-pool sweep: the offload engine over the
/// interp backend, one block of layers fanned across 1/2/4 device
/// workers.  Exits non-zero if any pooled mask diverges from the
/// serial schedule (the CI bench smoke job gates on this).
fn pool_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
    let (d, chunk, rows, layers, t_max) =
        if quick { (64usize, 32usize, 64usize, 4usize, 10usize) }
        else { (256, 64, 192, 8, 25) };
    let manifest = swap_manifest(d, chunk);
    let pattern = Pattern::PerRow { keep: d * 2 / 5 };
    let mut rng = Rng::new(11);
    let work: Vec<(Matrix, Matrix, Matrix)> = (0..layers).map(|_| {
        let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate_par(&x, 4);
        let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);
        (w, g, warm)
    }).collect();

    let mut table = Table::new(
        format!("Runtime pool — offload[interp] layer fan-out \
                 ({layers} layers x {rows}x{d}, T_max={t_max})"),
        &["devices", "seconds", "rows/s", "cache hit rate", "steals",
          "speedup vs 1"]);
    let mut sweeps: Vec<Json> = Vec::new();
    let mut reference: Option<Vec<Matrix>> = None;
    let mut serial_secs = f64::NAN;
    for devices in [1usize, 2, 4] {
        let pool = interp_pool(&manifest, devices,
                               RuntimeOptions::default());
        let slots: Vec<Mutex<Option<Matrix>>> =
            (0..layers).map(|_| Mutex::new(None)).collect();
        let t0 = Instant::now();
        let jobs: Vec<Box<dyn FnOnce(&Runtime) + Send + '_>> = work
            .iter()
            .zip(&slots)
            .map(|((w, g, warm), slot)| {
                Box::new(move |rt: &Runtime| {
                    let ctx = LayerContext {
                        w, g: g.as_gram(), stats: None, pattern,
                        t_max, threads: 1,
                    };
                    let mut mask = warm.clone();
                    OffloadEngine::new(rt, "interp")
                        .refine(&ctx, &mut mask, &[])
                        .expect("interp offload refine");
                    *slot.lock().unwrap() = Some(mask);
                }) as Box<dyn FnOnce(&Runtime) + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let masks: Vec<Matrix> = slots.into_iter()
            .map(|s| s.into_inner().unwrap().expect("job completed"))
            .collect();
        match &reference {
            None => {
                serial_secs = secs;
                reference = Some(masks);
            }
            Some(want) => {
                for (li, (a, b)) in want.iter().zip(&masks).enumerate() {
                    if a.data != b.data {
                        eprintln!("[ablation_engine] PARITY FAILURE: \
                                   pool[{devices}] layer {li} mask \
                                   diverged from the serial schedule");
                        std::process::exit(1);
                    }
                }
            }
        }
        let stats = pool.stats_total();
        let rows_per_s = (layers * rows) as f64 / secs;
        let speedup = serial_secs / secs;
        table.row(vec![
            devices.to_string(),
            format!("{secs:.3}"),
            format!("{rows_per_s:.0}"),
            format!("{:.0}%", 100.0 * stats.cache_hit_rate()),
            pool.steals().to_string(),
            format!("{speedup:.2}x"),
        ]);
        sweeps.push(Json::obj(vec![
            ("devices", Json::num(devices as f64)),
            ("seconds", Json::num(secs)),
            ("rows_per_s", Json::num(rows_per_s)),
            ("cache_hit_rate", Json::num(stats.cache_hit_rate())),
            ("cache_evictions", Json::num(stats.cache_evictions as f64)),
            ("steals", Json::num(pool.steals() as f64)),
            ("speedup_vs_serial", Json::num(speedup)),
        ]));
    }
    table.print();
    let section = Json::obj(vec![
        ("d", Json::num(d as f64)),
        ("rows", Json::num(rows as f64)),
        ("layers", Json::num(layers as f64)),
        ("t_max", Json::num(t_max as f64)),
        ("sweeps", Json::Arr(sweeps)),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "pool", section) {
        eprintln!("[ablation_engine] FAILED writing bench_kernels: {e}");
        std::process::exit(1);
    }
    println!("[ablation_engine] pool section written to \
              reports/bench_kernels.json (serial parity OK)");
}

/// Artifact-free shard-granularity sweep on a skewed synthetic block:
/// one layer with 4x the rows of the rest pins a whole-layer worker
/// while the others idle; row shards split it.  Exits non-zero if any
/// granularity's masks diverge from the layer-granular schedule (the
/// CI bench smoke job gates on this).
fn shards_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
    let (d, base_rows, t_max) =
        if quick { (64usize, 24usize, 8usize) } else { (256, 96, 20) };
    let wide_rows = 4 * base_rows;
    let n_small = 7usize;
    let workers = 4usize;
    let pattern = Pattern::PerRow { keep: d * 2 / 5 };
    let mut rng = Rng::new(21);
    let mut row_counts = vec![wide_rows];
    row_counts.extend(vec![base_rows; n_small]);
    let layers: Vec<(Matrix, Matrix, Matrix)> = row_counts.iter()
        .map(|&rows| {
            let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
            let mut g = Matrix::zeros(d, d);
            g.gram_accumulate_par(&x, 4);
            let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
            let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                        pattern);
            (w, g, warm)
        })
        .collect();
    let total_rows: usize = row_counts.iter().sum();

    let mut table = Table::new(
        format!("Shard granularity — native engine, skewed block \
                 (1x{wide_rows} + {n_small}x{base_rows} rows, d={d}, \
                  {workers} workers, T_max={t_max})"),
        &["granularity", "seconds", "rows/s", "imbalance (max/mean)",
          "speedup vs layer"]);
    let mut sweeps: Vec<Json> = Vec::new();
    let mut reference: Option<Vec<Matrix>> = None;
    let mut layer_secs = f64::NAN;
    for (label, shard_rows) in
        [("layer", usize::MAX), ("shard-adaptive", 0usize),
         ("shard-16", 16)]
    {
        // Fresh pool per config so busy-time counters start at zero.
        let tp = ThreadPool::new(workers);
        let works: Vec<LayerWork> = layers.iter().enumerate()
            .map(|(li, (w, g, warm))| LayerWork {
                li,
                label: format!("layer{li}"),
                w: w.clone(),
                g: g.as_gram(),
                stats: None,
                pattern,
                warm: warm.clone(),
                shard_align: 1,
                gram_key: sparseswaps::coordinator::swaploop::
                    next_refinement_id(),
            })
            .collect();
        let plan = BlockSchedule {
            t_max,
            threads_per_shard: 1,
            checkpoints: Vec::new(),
            shard_rows,
            serial: false,
        };
        let t0 = Instant::now();
        let res = refine_block(&tp, &Refiner::SparseSwapsNative,
                               &works, &plan)
            .expect("native shard refinement");
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let busy = tp.busy_nanos();
        let mean = busy.iter().sum::<u64>() as f64
            / busy.len().max(1) as f64;
        let imbalance = busy.iter().copied().max().unwrap_or(0) as f64
            / mean.max(1.0);
        let masks: Vec<Matrix> =
            res.into_iter().map(|r| r.mask).collect();
        match &reference {
            None => {
                layer_secs = secs;
                reference = Some(masks);
            }
            Some(want) => {
                for (li, (a, b)) in
                    want.iter().zip(&masks).enumerate() {
                    if a.data != b.data {
                        eprintln!("[ablation_engine] PARITY FAILURE: \
                                   {label} layer {li} mask diverged \
                                   from the layer-granular schedule");
                        std::process::exit(1);
                    }
                }
            }
        }
        let rows_per_s = total_rows as f64 / secs;
        let speedup = layer_secs / secs;
        table.row(vec![
            label.to_string(),
            format!("{secs:.3}"),
            format!("{rows_per_s:.0}"),
            format!("{imbalance:.2}"),
            format!("{speedup:.2}x"),
        ]);
        sweeps.push(Json::obj(vec![
            ("granularity", Json::str(label)),
            ("seconds", Json::num(secs)),
            ("rows_per_s", Json::num(rows_per_s)),
            ("imbalance_max_over_mean", Json::num(imbalance)),
            ("speedup_vs_layer", Json::num(speedup)),
        ]));
    }
    table.print();
    let section = Json::obj(vec![
        ("d", Json::num(d as f64)),
        ("rows_wide", Json::num(wide_rows as f64)),
        ("rows_small", Json::num(base_rows as f64)),
        ("layers", Json::num((1 + n_small) as f64)),
        ("workers", Json::num(workers as f64)),
        ("t_max", Json::num(t_max as f64)),
        ("sweeps", Json::Arr(sweeps)),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "shards", section) {
        eprintln!("[ablation_engine] FAILED writing bench_kernels: {e}");
        std::process::exit(1);
    }
    println!("[ablation_engine] shards section written to \
              reports/bench_kernels.json (granularity parity OK)");
}

fn main() {
    native_section();
    pool_section();
    shards_section();

    // Offload engines (need AOT artifacts; their own layer at an
    // artifact width).
    common::run_bench("ablation_engine", |ctx| {
        let d = 128usize;
        let rows = 128usize;
        let t_max = if ctx.quick { 10 } else { 25 };
        let mut rng = Rng::new(7);
        let x = Matrix::from_fn(4 * d, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
        let pattern = Pattern::PerRow { keep: d * 2 / 5 };
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);

        let mut table = Table::new(
            format!("Ablation B — offload engines ({rows}x{d}, 60%, \
                     T_max={t_max})"),
            &["Engine", "seconds", "total swaps", "µs/swap", "rows/s",
              "swaps/s", "rel. reduction"]);
        let mut engines_json: Vec<Json> = Vec::new();
        let mut ran = 0;
        for impl_name in ["xla", "pallas"] {
            if sparseswaps::runtime::Manifest::load("artifacts").ok()
                .and_then(|m| m.find_swap_artifact(
                    d, "row", impl_name, 1).ok().map(|_| ()))
                .is_none() {
                continue;
            }
            let mut mask = warm.clone();
            let cfg = OffloadConfig { impl_name: impl_name.into(), t_max };
            let t0 = Instant::now();
            let (outcome, _) = refine_layer_offload(
                &ctx.rt, &w, &mut mask, &g, pattern, &cfg, &[])
                .map_err(|e| e.to_string())?;
            let secs = t0.elapsed().as_secs_f64();
            record(&mut table, &mut engines_json,
                   &format!("offload[{impl_name}]"), rows, secs,
                   &outcome);
            ran += 1;
        }
        if ran == 0 {
            return Ok(vec!["\n(no swap artifacts at this width)\n"
                .to_string()]);
        }
        table.print();
        // Append the offload rows to the report native_section() wrote,
        // so the perf trajectory keeps tracking every engine.
        let path = "reports/ablation_engine.json";
        if let Some(mut root) = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(text.trim()).ok())
        {
            if let Json::Obj(map) = &mut root {
                if let Some(Json::Arr(engines)) = map.get_mut("engines") {
                    engines.extend(engines_json);
                }
                map.insert("offload_d".into(), Json::num(d as f64));
            }
            std::fs::write(path, format!("{root}\n"))
                .map_err(|e| e.to_string())?;
        }
        Ok(vec![table.to_markdown()])
    });
}
