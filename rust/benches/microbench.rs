//! Hot-path micro-benchmarks for the perf log (EXPERIMENTS.md §Perf):
//! kernel-layer GFLOP/s per dispatch arm (dot/axpy/axpy_dot/matmul/
//! syrk — runs without artifacts and feeds the "kernels" section of
//! `reports/bench_kernels.json`), swap-step artifact latency per
//! width/k, runtime pack/exec/unpack split, and the native engine's
//! per-swap cost.
mod common;

use sparseswaps::pruning::mask::{mask_from_scores, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::runtime::TensorData;
use sparseswaps::util::benchlib::{
    bench, fmt_duration_ns, gflops, merge_json_section, Table,
};
use sparseswaps::util::jsonlite::Json;
use sparseswaps::util::kernels::{self, Arm};
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;

fn main() {
    // Artifact-free kernel section first: always runs (CI bench smoke
    // relies on it), asserts scalar/SIMD parity, and emits GFLOP/s.
    kernel_section();
    // Artifact-dependent sections (skip gracefully on fresh checkouts).
    common::run_bench("microbench", |ctx| {
        let mut table = Table::new(
            "Microbench — swap-step artifact latency",
            &["artifact", "chunk", "mean", "p95", "ms/row-iter x1e3"]);
        let widths = [64usize, 128, 256, 512];
        for d in widths {
            for k in [1usize, 8] {
                let name = format!("swap_step_d{d}_row_xla_k{k}");
                let Ok(entry) = ctx.rt.manifest().artifact(&name)
                    else { continue };
                let entry = entry.clone();
                let rows = entry.chunk_rows;
                let mut rng = Rng::new(3);
                let x = Matrix::from_fn(2 * d, d,
                                        |_, _| rng.gaussian_f32());
                let mut g = Matrix::zeros(d, d);
                g.gram_accumulate(&x);
                let w = Matrix::from_fn(rows, d,
                                        |_, _| rng.gaussian_f32());
                let mask = mask_from_scores(
                    &saliency::wanda(&w, &g.diag()),
                    Pattern::PerRow { keep: d * 2 / 5 });
                let inputs = vec![
                    TensorData::from_matrix(&w),
                    TensorData::from_matrix(&mask),
                    TensorData::from_matrix(&g),
                ];
                let samples = if ctx.quick { 3 } else { 8 };
                let stats = bench(1, samples, || {
                    ctx.rt.execute(&name, inputs.clone()).unwrap();
                });
                table.row(vec![
                    name.clone(),
                    rows.to_string(),
                    fmt_duration_ns(stats.mean_ns),
                    fmt_duration_ns(stats.p95_ns),
                    format!("{:.3}",
                            stats.mean_ns / 1e6
                            / (rows * k) as f64 * 1e3),
                ]);
            }
        }
        table.print();

        let stats = ctx.rt.stats();
        // Since the backend refactor, output download/decompose time
        // is part of the backend's execute call, so it folds into
        // "exec" (ServiceStats::unpack_nanos stays 0).
        let mut split = Table::new(
            "Microbench — runtime time split (cumulative)",
            &["executions", "exec (incl. unpack)", "pack", "compile"]);
        split.row(vec![
            stats.executions.to_string(),
            format!("{:.2}s", stats.exec_nanos as f64 / 1e9),
            format!("{:.2}s", stats.pack_nanos as f64 / 1e9),
            format!("{:.2}s", stats.compile_nanos as f64 / 1e9),
        ]);
        split.print();
        Ok(vec![table.to_markdown(), split.to_markdown()])
    });
}

/// Benchmark every kernel on every available dispatch arm and merge
/// the numbers into `reports/bench_kernels.json` (section "kernels").
/// Exits non-zero if the arms disagree beyond tolerance, so the CI
/// bench smoke job doubles as a parity gate.
fn kernel_section() {
    let quick = std::env::var("SPARSESWAPS_QUICK").is_ok();
    let arms = kernels::arms();
    println!("[microbench] kernel section: arms {:?} (active {})",
             arms.iter().map(|a| a.name()).collect::<Vec<_>>(),
             kernels::active().name());
    verify_arm_parity(&arms);

    let mut table = Table::new(
        "Microbench — kernel layer throughput per dispatch arm",
        &["op", "arm", "shape", "mean", "GFLOP/s"]);
    let mut results: Vec<Json> = Vec::new();
    let sizes: &[usize] = if quick { &[96] } else { &[256, 1024] };
    let samples = if quick { 3 } else { 5 };
    let mut rng = Rng::new(11);
    let mut sink = 0.0f32;
    for &d in sizes {
        let n = d * d;
        let a: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let am = Matrix::from_fn(d, d, |_, _| rng.gaussian_f32());
        let bm = Matrix::from_fn(d, d, |_, _| rng.gaussian_f32());
        let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
        for &arm in &arms {
            let mut record = |op: &str, shape: String, flops: f64,
                              mean_ns: f64| {
                let gf = gflops(flops, mean_ns);
                table.row(vec![
                    op.to_string(),
                    arm.name().to_string(),
                    shape.clone(),
                    fmt_duration_ns(mean_ns),
                    format!("{gf:.2}"),
                ]);
                results.push(Json::obj(vec![
                    ("op", Json::str(op)),
                    ("arm", Json::str(arm.name())),
                    ("shape", Json::str(shape)),
                    ("mean_ns", Json::num(mean_ns)),
                    ("gflops", Json::num(gf)),
                ]));
            };

            let st = bench(1, samples, || {
                sink += kernels::dot_arm(arm, &a, &b);
            });
            record("dot", format!("n={n}"), 2.0 * n as f64, st.mean_ns);

            let mut y = b.clone();
            let st = bench(1, samples, || {
                kernels::axpy_arm(arm, 0.5, &a, &mut y);
            });
            sink += y[0];
            record("axpy", format!("n={n}"), 2.0 * n as f64, st.mean_ns);

            let mut y = b.clone();
            let st = bench(1, samples, || {
                sink += kernels::axpy_dot_arm(arm, 0.5, &a, &mut y);
            });
            record("axpy_dot", format!("n={n}"), 4.0 * n as f64,
                   st.mean_ns);

            let st = bench(1, samples, || {
                let c = kernels::matmul_arm(arm, &am, &bm);
                sink += c.data[0];
            });
            record("matmul", format!("{d}x{d}x{d}"),
                   2.0 * (d as f64).powi(3), st.mean_ns);

            for threads in [1usize, 4] {
                let mut g = Matrix::zeros(d, d);
                let st = bench(1, samples, || {
                    kernels::syrk_arm(arm, &mut g, &x, threads);
                });
                sink += g.data[0];
                // Upper triangle + mirror ~= t*d*d effective flops.
                record(&format!("syrk[{threads}t]"),
                       format!("t={} d={d}", 2 * d),
                       2.0 * (2 * d) as f64 * (d as f64) * (d as f64)
                           / 2.0,
                       st.mean_ns);
            }
        }
    }
    std::hint::black_box(sink);
    table.print();
    let section = Json::obj(vec![
        ("arms", Json::Arr(
            arms.iter().map(|a| Json::str(a.name())).collect())),
        ("active", Json::str(kernels::active().name())),
        ("results", Json::Arr(results)),
    ]);
    if let Err(e) = merge_json_section("reports/bench_kernels.json",
                                       "kernels", section) {
        eprintln!("[microbench] FAILED writing bench_kernels.json: {e}");
        std::process::exit(1);
    }
    println!("[microbench] kernel section written to \
              reports/bench_kernels.json");
}

/// Cross-arm correctness gate on ragged shapes (exits non-zero on
/// mismatch; the full randomized coverage lives in tests/properties.rs).
fn verify_arm_parity(arms: &[Arm]) {
    if arms.len() < 2 {
        println!("[microbench] single-arm host: parity check skipped");
        return;
    }
    let mut rng = Rng::new(29);
    let mut fail = |msg: String| {
        eprintln!("[microbench] KERNEL PARITY FAILURE: {msg}");
        std::process::exit(1);
    };
    for n in [3usize, 33, 257] {
        let a: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let ds = kernels::dot_arm(Arm::Scalar, &a, &b);
        let dv = kernels::dot_arm(Arm::Simd, &a, &b);
        if (ds - dv).abs() > 1e-4 * ds.abs().max(1.0) {
            fail(format!("dot n={n}: {ds} vs {dv}"));
        }
        let mut ys = b.clone();
        let mut yv = b.clone();
        kernels::axpy_arm(Arm::Scalar, 0.7, &a, &mut ys);
        kernels::axpy_arm(Arm::Simd, 0.7, &a, &mut yv);
        if ys.iter().zip(&yv).any(|(s, v)| s.to_bits() != v.to_bits()) {
            fail(format!("axpy not bit-identical at n={n}"));
        }
    }
    for d in [5usize, 21] {
        let x = Matrix::from_fn(2 * d + 1, d, |_, _| rng.gaussian_f32());
        let mut gs = Matrix::zeros(d, d);
        kernels::syrk_arm(Arm::Scalar, &mut gs, &x, 1);
        let mut gv = Matrix::zeros(d, d);
        kernels::syrk_arm(Arm::Simd, &mut gv, &x, 1);
        let scale = gs.data.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
        if gs.max_abs_diff(&gv) > 1e-4 * scale {
            fail(format!("syrk d={d} diverged across arms"));
        }
        let a = Matrix::from_fn(d, d + 3, |_, _| rng.gaussian_f32());
        let b = Matrix::from_fn(d + 3, d, |_, _| rng.gaussian_f32());
        let ms = kernels::matmul_arm(Arm::Scalar, &a, &b);
        let mv = kernels::matmul_arm(Arm::Simd, &a, &b);
        let scale = ms.data.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
        if ms.max_abs_diff(&mv) > 1e-4 * scale {
            fail(format!("matmul d={d} diverged across arms"));
        }
    }
    println!("[microbench] scalar/simd parity OK");
}
