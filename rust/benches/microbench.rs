//! Hot-path micro-benchmarks for the perf log (EXPERIMENTS.md §Perf):
//! swap-step artifact latency per width/k, runtime pack/exec/unpack
//! split, and the native engine's per-swap cost.
mod common;

use sparseswaps::pruning::mask::{mask_from_scores, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::runtime::TensorData;
use sparseswaps::util::benchlib::{bench, fmt_duration_ns, Table};
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;

fn main() {
    common::run_bench("microbench", |ctx| {
        let mut table = Table::new(
            "Microbench — swap-step artifact latency",
            &["artifact", "chunk", "mean", "p95", "ms/row-iter x1e3"]);
        let widths = [64usize, 128, 256, 512];
        for d in widths {
            for k in [1usize, 8] {
                let name = format!("swap_step_d{d}_row_xla_k{k}");
                let Ok(entry) = ctx.rt.manifest().artifact(&name)
                    else { continue };
                let entry = entry.clone();
                let rows = entry.chunk_rows;
                let mut rng = Rng::new(3);
                let x = Matrix::from_fn(2 * d, d,
                                        |_, _| rng.gaussian_f32());
                let mut g = Matrix::zeros(d, d);
                g.gram_accumulate(&x);
                let w = Matrix::from_fn(rows, d,
                                        |_, _| rng.gaussian_f32());
                let mask = mask_from_scores(
                    &saliency::wanda(&w, &g.diag()),
                    Pattern::PerRow { keep: d * 2 / 5 });
                let inputs = vec![
                    TensorData::from_matrix(&w),
                    TensorData::from_matrix(&mask),
                    TensorData::from_matrix(&g),
                ];
                let samples = if ctx.quick { 3 } else { 8 };
                let stats = bench(1, samples, || {
                    ctx.rt.execute(&name, inputs.clone()).unwrap();
                });
                table.row(vec![
                    name.clone(),
                    rows.to_string(),
                    fmt_duration_ns(stats.mean_ns),
                    fmt_duration_ns(stats.p95_ns),
                    format!("{:.3}",
                            stats.mean_ns / 1e6
                            / (rows * k) as f64 * 1e3),
                ]);
            }
        }
        table.print();

        let stats = ctx.rt.stats();
        let mut split = Table::new(
            "Microbench — runtime time split (cumulative)",
            &["executions", "exec", "pack", "unpack", "compile"]);
        split.row(vec![
            stats.executions.to_string(),
            format!("{:.2}s", stats.exec_nanos as f64 / 1e9),
            format!("{:.2}s", stats.pack_nanos as f64 / 1e9),
            format!("{:.2}s", stats.unpack_nanos as f64 / 1e9),
            format!("{:.2}s", stats.compile_nanos as f64 / 1e9),
        ]);
        split.print();
        Ok(vec![table.to_markdown(), split.to_markdown()])
    });
}
