//! Regenerates paper Table 2: perplexity with magnitude warmstart at
//! 50% / 60% sparsity, with and without SparseSwaps refinement.
mod common;

fn main() {
    common::run_bench("table2", |ctx| {
        let t = sparseswaps::report::table2(ctx)
            .map_err(|e| e.to_string())?;
        t.print();
        Ok(vec![t.to_markdown()])
    });
}
