//! Ablation A (DESIGN.md): how far are SparseSwaps' 1-swap local optima
//! from the *exact* optimum?  Brute-force subset enumeration is feasible
//! at d <= 20; the paper only notes IP solvers are infeasible at scale —
//! this measures the gap the local search actually leaves.
use std::time::Instant;

use sparseswaps::pruning::error::row_loss;
use sparseswaps::pruning::exact::optimal_row_mask;
use sparseswaps::pruning::mask::{mask_from_scores, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::pruning::sparseswaps::{refine_row, SwapConfig};
use sparseswaps::util::benchlib::Table;
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;

fn main() {
    let t0 = Instant::now();
    let mut table = Table::new(
        "Ablation A — 1-swap local optimum vs exact optimum (d=16, \
         keep=8, 40 instances)",
        &["Warmstart", "mean warmstart/opt", "mean SS/opt",
          "worst SS/opt", "% instances at optimum"]);
    let d = 16;
    let keep = 8;
    for crit in [saliency::Criterion::Magnitude,
                 saliency::Criterion::Wanda] {
        let mut ratios_warm = Vec::new();
        let mut ratios_ss = Vec::new();
        let mut at_opt = 0;
        let n = 40;
        for seed in 0..n {
            let mut rng = Rng::new(1000 + seed);
            let x = Matrix::from_fn(48, d, |_, _| rng.gaussian_f32());
            let mut g = Matrix::zeros(d, d);
            g.gram_accumulate(&x);
            let w: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
            let wm = Matrix::from_vec(1, d, w.clone());
            let scores = saliency::scores(crit, &wm, &g.diag());
            let mask = mask_from_scores(&scores,
                                        Pattern::PerRow { keep });
            let warm = row_loss(&w, mask.row(0), &g);
            let mut mrow = mask.row(0).to_vec();
            let out = refine_row(&w, &mut mrow, &g, 0,
                                 &SwapConfig { t_max: 10_000, eps: 0.0 });
            let (_, opt) = optimal_row_mask(&w, &g, keep);
            let denom = opt.max(1e-9);
            ratios_warm.push(warm / denom);
            ratios_ss.push(out.loss_after / denom);
            if out.loss_after <= opt * 1.001 + 1e-9 {
                at_opt += 1;
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let worst = ratios_ss.iter().cloned().fold(0.0, f64::max);
        table.row(vec![
            crit.name().to_string(),
            format!("{:.2}x", mean(&ratios_warm)),
            format!("{:.3}x", mean(&ratios_ss)),
            format!("{worst:.3}x"),
            format!("{:.0}%", 100.0 * at_opt as f64 / n as f64),
        ]);
    }
    table.print();
    table.append_to("reports/benchmarks.md").ok();
    println!("[ablation_exact] done in {:.1}s",
             t0.elapsed().as_secs_f64());
}
