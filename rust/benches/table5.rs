//! Regenerates paper Table 5: pipeline wall-clock vs T_max — the
//! "overhead grows linearly in the number of swap iterations" claim.
mod common;

fn main() {
    common::run_bench("table5", |ctx| {
        let model = if ctx.quick { "tiny" } else { "gpt-a" };
        let t = sparseswaps::report::table5(ctx, model)
            .map_err(|e| e.to_string())?;
        t.print();
        Ok(vec![t.to_markdown()])
    });
}
