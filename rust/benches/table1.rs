//! Regenerates paper Table 1: perplexity + zero-shot accuracy for
//! {Wanda, RIA} x {-, DSnoT, SparseSwaps} at 60% row-wise and 2:4
//! sparsity across the model zoo.
mod common;

fn main() {
    common::run_bench("table1", |ctx| {
        let (a, b) = sparseswaps::report::table1(ctx)
            .map_err(|e| e.to_string())?;
        a.print();
        b.print();
        Ok(vec![a.to_markdown(), b.to_markdown()])
    });
}
