//! Regenerates paper Table 4: average relative error reduction per
//! warmstart criterion (weaker warmstarts leave more room).
mod common;

fn main() {
    common::run_bench("table4", |ctx| {
        let t = sparseswaps::report::table4(ctx)
            .map_err(|e| e.to_string())?;
        t.print();
        Ok(vec![t.to_markdown()])
    });
}
