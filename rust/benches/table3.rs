//! Regenerates paper Table 3: mean relative error reduction and
//! perplexity vs the number of 1-swap iterations (Wanda warmstart).
mod common;

fn main() {
    common::run_bench("table3", |ctx| {
        let model = if ctx.quick { "tiny" } else { "gpt-a" };
        let t = sparseswaps::report::table3(ctx, model)
            .map_err(|e| e.to_string())?;
        t.print();
        Ok(vec![t.to_markdown()])
    });
}
