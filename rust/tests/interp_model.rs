//! Interp-vs-analytic checks for the model-execution artifact kinds:
//! a finite-difference gradient check of `train_step`'s backward pass
//! on a 2-block config, `eval_step` NLL against a hand-rolled softmax
//! on a 3-token vocab, and `seq_nll` mask windowing at the
//! seq_len + 1 truncation boundary used by `zeroshot::accuracy`.

use sparseswaps::eval::zeroshot::{self, Task};
use sparseswaps::model::testutil::{meta_for, tiny_meta};
use sparseswaps::model::ParamStore;
use sparseswaps::runtime::interp_model;
use sparseswaps::runtime::testutil::{interp_runtime, model_manifest};
use sparseswaps::runtime::{RuntimeOptions, TensorData};
use sparseswaps::util::prng::Rng;

fn token_batch(meta: &sparseswaps::runtime::ModelMeta, seed: u64)
    -> (TensorData, TensorData) {
    let mut rng = Rng::new(seed);
    let n = meta.batch * meta.seq_len;
    let dims = vec![meta.batch, meta.seq_len];
    let toks: Vec<i32> = (0..n)
        .map(|_| rng.usize_below(meta.vocab) as i32)
        .collect();
    let tgts: Vec<i32> = (0..n)
        .map(|_| rng.usize_below(meta.vocab) as i32)
        .collect();
    (TensorData::I32 { dims: dims.clone(), data: toks },
     TensorData::I32 { dims, data: tgts })
}

#[test]
fn batch_row_parallelism_is_bit_identical() {
    // The interp forward/backward fan batch rows across the global
    // thread pool; every output row is computed by the same scalar
    // code on exactly one worker, so losses AND gradients must be
    // bit-identical to the serial path.
    let meta = meta_for(32, 16, 2, 32, 2, 8, 4);
    let store = ParamStore::init(&meta, 9);
    let (toks, tgts) = token_batch(&meta, 17);
    let refs: Vec<&TensorData> =
        store.tensors.iter().map(|t| t.as_ref()).collect();
    let (l1, g1) = interp_model::loss_and_grads_threads(
        &meta, &refs, &toks, &tgts, 1).unwrap();
    for threads in [2usize, 4, 7] {
        let (lt, gt) = interp_model::loss_and_grads_threads(
            &meta, &refs, &toks, &tgts, threads).unwrap();
        assert_eq!(l1.to_bits(), lt.to_bits(),
                   "loss diverged at {threads} threads");
        assert_eq!(g1.len(), gt.len());
        for (pi, (a, b)) in g1.iter().zip(&gt).enumerate() {
            assert_eq!(a.len(), b.len());
            for (j, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(),
                           "grad[{pi}][{j}] diverged at {threads} \
                            threads");
            }
        }
    }
}

#[test]
fn train_step_gradients_match_finite_differences() {
    // 2-block config, small enough that 2 forwards per checked
    // coordinate stay cheap: vocab 32, dm 16 (head dim 8), dff 32,
    // seq 8, batch 2.
    let meta = meta_for(32, 16, 2, 32, 2, 8, 2);
    let store = ParamStore::init(&meta, 3);
    let (toks, tgts) = token_batch(&meta, 5);
    let refs: Vec<&TensorData> =
        store.tensors.iter().map(|t| t.as_ref()).collect();
    let (loss, grads) =
        interp_model::loss_and_grads(&meta, &refs, &toks, &tgts)
            .unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");

    let h = 2e-2f32;
    let mut sq_err = 0.0f64;
    let mut sq_ref = 0.0f64;
    for (pi, g) in grads.iter().enumerate() {
        // Check the highest-magnitude coordinate of every parameter
        // tensor (embeddings, norms, every projection of both blocks,
        // the LM head).
        let (j, &gj) = g.iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .unwrap();
        let fd = {
            let probe = |delta: f32| -> f64 {
                let mut tensors = store.tensors.clone();
                std::sync::Arc::make_mut(&mut tensors[pi])
                    .as_f32_mut().unwrap()[j] += delta;
                let refs: Vec<&TensorData> =
                    tensors.iter().map(|t| t.as_ref()).collect();
                interp_model::mean_nll(&meta, &refs, &toks, &tgts)
                    .unwrap()
            };
            (probe(h) - probe(-h)) / (2.0 * h as f64)
        };
        let g64 = gj as f64;
        sq_err += (fd - g64) * (fd - g64);
        sq_ref += g64 * g64;
        let (name, _) = &meta.params[pi];
        assert!((fd - g64).abs() <= 0.1 * g64.abs().max(0.02),
                "{name}[{j}]: analytic {g64} vs central-difference {fd}");
    }
    // Aggregate agreement across all checked coordinates.
    assert!(sq_err < 1e-2 * sq_ref,
            "relative L2 gradient error {} too large",
            (sq_err / sq_ref).sqrt());
}

#[test]
fn eval_step_nll_matches_hand_rolled_softmax() {
    // 3-token vocab: small enough to hand-roll the cross-entropy.
    let meta = meta_for(3, 4, 2, 8, 1, 4, 1);
    let store = ParamStore::init(&meta, 9);
    let (toks, tgts) = token_batch(&meta, 2);
    let refs: Vec<&TensorData> =
        store.tensors.iter().map(|t| t.as_ref()).collect();
    let logits =
        interp_model::forward_logits(&meta, &refs, &toks).unwrap();
    assert_eq!((logits.rows, logits.cols),
               (meta.batch * meta.seq_len, meta.vocab));

    // Hand-rolled: nll_t = ln(sum_j e^{l_j}) - l_y, in f64.
    let tgt_ids = tgts.as_i32().unwrap();
    let mut want = 0.0f64;
    for t in 0..logits.rows {
        let row = logits.row(t);
        let z: f64 = row.iter().map(|&v| (v as f64).exp()).sum();
        want += z.ln() - row[tgt_ids[t] as usize] as f64;
    }

    // Through the full service path (manifest entry -> backend).
    let rt = interp_runtime(&model_manifest(&meta),
                            RuntimeOptions::default());
    let mut inputs = store.tensor_args();
    inputs.push(toks.clone());
    inputs.push(tgts.clone());
    let out = rt.execute("eval_step_tiny", inputs).unwrap();
    let got = out[0].scalar_value().unwrap();
    let count = out[1].scalar_value().unwrap();
    assert_eq!(count, (meta.batch * meta.seq_len) as f64);
    assert!((got - want).abs() / want.abs().max(1.0) < 1e-4,
            "eval_step {got} vs hand-rolled {want}");
}

/// Hand-derive the (tokens, targets, mask) row `accuracy` must build
/// for one scored sequence, straight from the spec: sequences longer
/// than seq_len + 1 keep their tail (the choice span must survive),
/// targets are tokens shifted by one, and the mask covers the choice
/// span clipped to the window.
fn expected_row(ids: &[i32], span_start: usize, l: usize)
    -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    let n = ids.len();
    let shift = n.saturating_sub(l + 1);
    let window = &ids[shift..];
    let mut tokens = vec![0i32; l];
    let mut targets = vec![0i32; l];
    for t in 0..window.len().min(l + 1).saturating_sub(1) {
        tokens[t] = window[t];
        targets[t] = window[t + 1];
    }
    let mut mask = vec![0.0f32; l];
    let start = span_start.saturating_sub(shift);
    let end = (n - 1 - shift).min(l);
    for m in &mut mask[start..end] {
        *m = 1.0;
    }
    (tokens, targets, mask)
}

#[test]
fn seq_nll_mask_windowing_at_truncation_boundary() {
    let meta = tiny_meta();
    let (b, l) = (meta.batch, meta.seq_len);
    assert_eq!(b, zeroshot::N_CHOICES,
               "test packs one task into one batch");
    let store = ParamStore::init(&meta, 7);
    let rt = interp_runtime(&model_manifest(&meta),
                            RuntimeOptions::default());

    // Four choices straddling the l + 1 truncation boundary:
    // exactly l + 1 (no shift), l + 2 and l + 3 (tail-kept, shifted
    // windows), and one short sequence (zero padding at the end).
    let lens = [l + 1, l + 3, l + 2, l / 2];
    let mut rng = Rng::new(13);
    let mut choice_ids = Vec::new();
    let mut span_start = Vec::new();
    for &n in &lens {
        let ids: Vec<i32> = (0..n)
            .map(|_| rng.usize_below(meta.vocab) as i32)
            .collect();
        choice_ids.push(ids);
        span_start.push(n - 4); // spans the last three transitions
    }
    let task = Task { choice_ids: choice_ids.clone(),
                      span_start: span_start.clone(), gold: 0 };

    let nlls = zeroshot::score_tasks(&rt, &store, &[task]).unwrap();
    assert_eq!(nlls.len(), 1);

    // Independently windowed batch: row c = choice c.
    let mut tokens = Vec::with_capacity(b * l);
    let mut targets = Vec::with_capacity(b * l);
    let mut mask = Vec::with_capacity(b * l);
    for c in 0..zeroshot::N_CHOICES {
        let (tk, tg, mk) = expected_row(&choice_ids[c], span_start[c], l);
        tokens.extend(tk);
        targets.extend(tg);
        mask.extend(mk);
    }
    let mut inputs = store.tensor_args();
    let dims = vec![b, l];
    inputs.push(TensorData::I32 { dims: dims.clone(), data: tokens });
    inputs.push(TensorData::I32 { dims: dims.clone(), data: targets });
    inputs.push(TensorData::F32 { dims, data: mask });
    let out = rt.execute("seq_nll_tiny", inputs).unwrap();
    let want = out[0].as_f32().unwrap();

    for c in 0..zeroshot::N_CHOICES {
        let got = nlls[0][c];
        assert!(got.is_finite() && got > 0.0, "choice {c}: {got}");
        // Same artifact over identical hand-windowed inputs ->
        // bit-identical scores.
        assert_eq!(got, want[c] as f64,
                   "choice {c} (len {}): windowing mismatch", lens[c]);
    }
}
