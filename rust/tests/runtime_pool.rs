//! Runtime pool + device-buffer cache integration: concurrent
//! submit/steal over real service workers, cache
//! hit/evict/invalidate-on-generation-bump semantics, and the
//! pooled-vs-serial offload mask parity property.
//!
//! Everything here runs artifact-free: `runtime::testutil` fabricates
//! in-memory manifests and the interp backend executes them natively.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;

use sparseswaps::coordinator::OffloadEngine;
use sparseswaps::pruning::engine::{LayerContext, RefineEngine};
use sparseswaps::pruning::mask::{mask_from_scores, validate, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::runtime::testutil::{
    interp_pool, interp_runtime, swap_manifest,
};
use sparseswaps::runtime::{
    BufferKey, ExecInput, Runtime, RuntimeError, RuntimeOptions,
    TensorData,
};
use sparseswaps::util::proptest::{check, ensure};
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;

fn layer(rng: &mut Rng, rows: usize, d: usize, pattern: Pattern)
    -> (Matrix, Matrix, Matrix) {
    let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
    let mut g = Matrix::zeros(d, d);
    g.gram_accumulate(&x);
    let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
    let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()), pattern);
    (w, g, warm)
}

#[test]
fn concurrent_submit_with_stealing_drains_a_pinned_queue() {
    let manifest = swap_manifest(16, 8);
    let pool = interp_pool(&manifest, 4, RuntimeOptions::default());
    let counter = Arc::new(AtomicU64::new(0));
    for _ in 0..32 {
        let c = Arc::clone(&counter);
        pool.submit_to(0, move |_rt| {
            std::thread::sleep(Duration::from_millis(2));
            c.fetch_add(1, Ordering::Relaxed);
        });
    }
    pool.wait();
    assert_eq!(counter.load(Ordering::Relaxed), 32);
    assert!(pool.steals() > 0,
            "all jobs pinned to worker 0: idle workers must steal");
    assert_eq!(pool.jobs_run().iter().sum::<u64>(), 32);
}

#[test]
fn pool_runs_jobs_concurrently_on_distinct_workers() {
    let manifest = swap_manifest(16, 8);
    let pool = interp_pool(&manifest, 4, RuntimeOptions::default());
    // The barrier releases only when four jobs are *simultaneously*
    // inside four dispatcher threads; each worker blocks in its first
    // job, so completion proves genuine 4-way concurrency.
    let barrier = Arc::new(Barrier::new(4));
    let devices = Arc::new(Mutex::new(std::collections::BTreeSet::new()));
    for i in 0..4 {
        let barrier = Arc::clone(&barrier);
        let devices = Arc::clone(&devices);
        pool.submit_to(i, move |rt: &Runtime| {
            barrier.wait();
            devices.lock().unwrap().insert(rt.device());
        });
    }
    pool.wait();
    assert_eq!(devices.lock().unwrap().len(), 4,
               "each concurrent job must run on its own device worker");
}

#[test]
fn cache_hits_generation_bumps_and_explicit_invalidation() {
    let (d, chunk) = (8usize, 4usize);
    let manifest = swap_manifest(d, chunk);
    let rt = interp_runtime(&manifest, RuntimeOptions {
        device_mem_budget: 0, // unlimited
        ..RuntimeOptions::default()
    });
    let name = format!("layer_loss_d{d}");
    let w = TensorData::from_matrix(
        &Matrix::from_fn(chunk, d, |i, j| (i + j) as f32 * 0.1));
    let ones = TensorData::from_matrix(
        &Matrix::from_fn(chunk, d, |_, _| 1.0));
    let g = Arc::new(TensorData::from_matrix(&Matrix::eye(d)));
    let exec = |generation: u64| {
        rt.execute_cached(&name, vec![
            ExecInput::Inline(w.clone()),
            ExecInput::Inline(ones.clone()),
            ExecInput::Cached {
                key: BufferKey { layer: 7, tensor: "gram".into(),
                                 generation },
                data: Arc::clone(&g),
            },
        ]).unwrap()
    };
    let out = exec(0);
    // All-kept mask: exact zero loss per row.
    assert!(out[0].as_f32().unwrap().iter().all(|&l| l == 0.0));
    exec(0);
    let s = rt.stats();
    assert_eq!((s.cache_hits, s.cache_misses, s.cache_invalidations),
               (1, 1, 0));
    assert_eq!(s.cache_bytes, (d * d * 4) as u64);

    // Generation bump: stale buffer dropped, fresh upload.
    exec(1);
    let s = rt.stats();
    assert_eq!((s.cache_hits, s.cache_misses, s.cache_invalidations),
               (1, 2, 1));

    // Explicit layer invalidation releases the buffer; next use
    // re-uploads.
    rt.invalidate(7);
    exec(1);
    let s = rt.stats();
    assert_eq!((s.cache_hits, s.cache_misses, s.cache_invalidations),
               (1, 3, 2));
    assert_eq!(s.cache_peak_bytes, (d * d * 4) as u64);
}

#[test]
fn key_only_probes_hit_miss_and_stay_bit_identical() {
    let (d, chunk) = (8usize, 4usize);
    let manifest = swap_manifest(d, chunk);
    let rt = interp_runtime(&manifest, RuntimeOptions::default());
    let name = format!("layer_loss_d{d}");
    let mut rng = Rng::new(31);
    let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
    let mut gm = Matrix::zeros(d, d);
    gm.gram_accumulate(&x);
    let w = TensorData::from_matrix(
        &Matrix::from_fn(chunk, d, |_, _| rng.gaussian_f32()));
    let mask = TensorData::from_matrix(&Matrix::from_fn(
        chunk, d, |i, j| if (i + j) % 3 == 0 { 0.0 } else { 1.0 }));
    let g = Arc::new(TensorData::from_matrix(&gm));
    let key = |generation: u64| BufferKey {
        layer: 3, tensor: "gram".into(), generation,
    };
    let exec = |g_input: ExecInput| {
        rt.execute_cached(&name, vec![
            ExecInput::Inline(w.clone()),
            ExecInput::Inline(mask.clone()),
            g_input,
        ])
    };

    // Probe before anything is resident: structured NotResident, no
    // upload, no execution — and NOT counted as a data-path miss.
    let err = exec(ExecInput::CachedRef { key: key(0) }).unwrap_err();
    assert!(matches!(err, RuntimeError::NotResident(_)), "{err}");
    let s = rt.stats();
    assert_eq!((s.probe_hits, s.probe_misses), (0, 1));
    assert_eq!((s.cache_hits, s.cache_misses), (0, 0));
    assert_eq!(s.executions, 0);

    // Data-attached upload, then a key-only probe, then a plain
    // Cached re-execution: all three must produce bit-identical
    // outputs (the probe path feeds the very same device buffer).
    let out_up = exec(ExecInput::Cached {
        key: key(0), data: Arc::clone(&g),
    }).unwrap();
    let out_probe = exec(ExecInput::CachedRef { key: key(0) }).unwrap();
    let out_cached = exec(ExecInput::Cached {
        key: key(0), data: Arc::clone(&g),
    }).unwrap();
    let out_inline = rt.execute(&name, vec![
        w.clone(), mask.clone(), (*g).clone(),
    ]).unwrap();
    let bits = |outs: &[TensorData]| -> Vec<Vec<u32>> {
        outs.iter()
            .map(|t| t.as_f32().unwrap().iter()
                 .map(|v| v.to_bits()).collect())
            .collect()
    };
    let want = bits(&out_up);
    assert_eq!(bits(&out_probe), want, "probe-hit output diverged");
    assert_eq!(bits(&out_cached), want, "cached-hit output diverged");
    assert_eq!(bits(&out_inline), want, "inline output diverged");
    let s = rt.stats();
    assert_eq!((s.probe_hits, s.probe_misses), (1, 1));
    // The probe hit must not inflate the data-path hit counters: one
    // upload miss + exactly one Cached hit.
    assert_eq!((s.cache_hits, s.cache_misses), (1, 1));

    // A generation bump makes the resident buffer stale for probes
    // too: key-only addressing of the new generation misses until the
    // caller retries with data attached.
    let err = exec(ExecInput::CachedRef { key: key(1) }).unwrap_err();
    assert!(matches!(err, RuntimeError::NotResident(_)), "{err}");
    let out_bumped = exec(ExecInput::Cached {
        key: key(1), data: Arc::clone(&g),
    }).unwrap();
    assert_eq!(bits(&out_bumped), want);
    let s = rt.stats();
    assert_eq!((s.probe_hits, s.probe_misses), (1, 2));
    assert_eq!((s.cache_hits, s.cache_misses, s.cache_invalidations),
               (1, 2, 1));
    // Upload accounting: the inline W/mask pairs travel every call
    // (5 executions), G only on its two generation uploads plus the
    // one all-inline call.
    let wm_bytes = (2 * chunk * d * 4) as u64;
    let g_bytes = (d * d * 4) as u64;
    assert_eq!(s.upload_bytes, 5 * wm_bytes + 3 * g_bytes);
}

#[test]
fn cache_lru_eviction_respects_device_mem_budget() {
    let (d, chunk) = (8usize, 4usize);
    let gram_bytes = (d * d * 4) as u64;
    let manifest = swap_manifest(d, chunk);
    // Budget fits one gram buffer but not two.
    let rt = interp_runtime(&manifest, RuntimeOptions {
        device_mem_budget: gram_bytes + gram_bytes / 2,
        ..RuntimeOptions::default()
    });
    let name = format!("layer_loss_d{d}");
    let w = TensorData::from_matrix(
        &Matrix::from_fn(chunk, d, |i, j| (i * d + j) as f32 * 0.01));
    let ones = TensorData::from_matrix(
        &Matrix::from_fn(chunk, d, |_, _| 1.0));
    let g = Arc::new(TensorData::from_matrix(&Matrix::eye(d)));
    let exec = |layer: u64| {
        rt.execute_cached(&name, vec![
            ExecInput::Inline(w.clone()),
            ExecInput::Inline(ones.clone()),
            ExecInput::Cached {
                key: BufferKey { layer, tensor: "gram".into(),
                                 generation: 0 },
                data: Arc::clone(&g),
            },
        ]).unwrap()
    };
    exec(1);
    exec(2); // exceeds the budget -> LRU evicts layer 1's buffer
    let s = rt.stats();
    assert_eq!(s.cache_evictions, 1);
    assert!(s.cache_bytes <= gram_bytes + gram_bytes / 2);
    exec(1); // must re-upload (was evicted)
    let s = rt.stats();
    assert_eq!(s.cache_hits, 0);
    assert_eq!(s.cache_misses, 3);
}

#[test]
fn execute_cached_validates_signatures() {
    let manifest = swap_manifest(8, 4);
    let rt = interp_runtime(&manifest, RuntimeOptions::default());
    // Wrong input count.
    assert!(rt.execute("layer_loss_d8",
                       vec![TensorData::scalar_f32(1.0)]).is_err());
    // Wrong gram dims.
    let bad = rt.execute("layer_loss_d8", vec![
        TensorData::F32 { dims: vec![4, 8], data: vec![0.0; 32] },
        TensorData::F32 { dims: vec![4, 8], data: vec![1.0; 32] },
        TensorData::F32 { dims: vec![7, 8], data: vec![0.0; 56] },
    ]);
    assert!(bad.is_err());
    // Duplicate cache keys in one call: both positions would resolve
    // to the single surviving buffer — rejected up front.
    let mat = Arc::new(TensorData::F32 { dims: vec![4, 8],
                                         data: vec![1.0; 32] });
    let key = BufferKey { layer: 1, tensor: "w".into(), generation: 0 };
    let dup = rt.execute_cached("layer_loss_d8", vec![
        ExecInput::Cached { key: key.clone(), data: Arc::clone(&mat) },
        ExecInput::Cached { key, data: mat },
        ExecInput::Inline(TensorData::F32 { dims: vec![8, 8],
                                            data: vec![0.0; 64] }),
    ]);
    assert!(dup.is_err());
}

#[test]
fn pool_workers_share_one_compile_cache() {
    let manifest = swap_manifest(8, 4);
    let pool = interp_pool(&manifest, 3, RuntimeOptions::default());
    for i in 0..3 {
        pool.runtime(i).preload("layer_loss_d8").unwrap();
    }
    let total = pool.stats_total();
    assert_eq!(total.compiles, 1,
               "each artifact must compile once per pool");
    assert_eq!(total.compiles_shared, 2,
               "late workers must import the shared executable");
    // Re-preloading on any worker is a local no-op (neither a compile
    // nor another shared import).
    pool.runtime(1).preload("layer_loss_d8").unwrap();
    let total = pool.stats_total();
    assert_eq!((total.compiles, total.compiles_shared), (1, 2));
    // A standalone runtime (no shared cache) keeps compiling locally.
    let rt = interp_runtime(&manifest, RuntimeOptions::default());
    rt.preload("layer_loss_d8").unwrap();
    let s = rt.stats();
    assert_eq!((s.compiles, s.compiles_shared), (1, 0));
}

#[test]
fn pooled_offload_masks_bit_identical_to_serial() {
    let (rows, d, chunk) = (24usize, 32usize, 8usize);
    let manifest = swap_manifest(d, chunk);
    let serial = interp_pool(&manifest, 1, RuntimeOptions::default());
    let pooled = interp_pool(&manifest, 4, RuntimeOptions::default());
    check("pooled offload == serial offload", 8, |gen| {
        let pattern = *gen.choose(&[Pattern::PerRow { keep: 13 },
                                    Pattern::Nm { n: 2, m: 4 }]);
        let t_max = gen.usize_in(3, 20);
        let n_layers = gen.usize_in(2, 5);
        let layers: Vec<(Matrix, Matrix, Matrix)> = (0..n_layers)
            .map(|_| layer(&mut gen.rng, rows, d, pattern))
            .collect();

        // Serial reference: every layer through the single worker.
        let mut serial_masks = Vec::with_capacity(n_layers);
        for (w, g, warm) in &layers {
            let ctx = LayerContext {
                w: w.view(), g: g.as_gram(), stats: None, pattern,
                t_max, threads: 1,
                gmax: None,
            };
            let mut mask = warm.clone();
            OffloadEngine::new(serial.primary(), "interp")
                .refine(&ctx, &mut mask, &[])
                .map_err(|e| e.to_string())?;
            serial_masks.push(mask);
        }

        // Pooled: the same layers fanned out over 4 workers.
        let slots: Vec<Mutex<Option<Matrix>>> =
            (0..n_layers).map(|_| Mutex::new(None)).collect();
        let jobs: Vec<Box<dyn FnOnce(&Runtime) + Send + '_>> = layers
            .iter()
            .zip(&slots)
            .map(|((w, g, warm), slot)| {
                Box::new(move |rt: &Runtime| {
                    let ctx = LayerContext {
                        w: w.view(), g: g.as_gram(), stats: None,
                        pattern, t_max, threads: 1,
                        gmax: None,
                    };
                    let mut mask = warm.clone();
                    OffloadEngine::new(rt, "interp")
                        .refine(&ctx, &mut mask, &[])
                        .expect("interp offload refine");
                    *slot.lock().unwrap() = Some(mask);
                }) as Box<dyn FnOnce(&Runtime) + Send + '_>
            })
            .collect();
        pooled.run_scoped(jobs);

        for (li, (want, slot)) in
            serial_masks.iter().zip(&slots).enumerate() {
            let got = slot.lock().unwrap().take()
                .ok_or_else(|| format!("layer {li}: job lost"))?;
            validate(&got, pattern)?;
            ensure(got.data == want.data, || format!(
                "layer {li}: pooled mask diverged from serial \
                 (pattern {pattern:?}, t_max {t_max})"))?;
        }
        Ok(())
    });
    // The pooled runs must actually have reused resident buffers.
    let total = pooled.stats_total();
    assert!(total.cache_hits > 0,
            "expected device-buffer cache hits across segment calls");
}

#[test]
fn offload_engine_snapshots_match_across_schedules() {
    // Checkpoint snapshots are part of the refinement contract; they
    // must also be schedule-invariant.
    let (rows, d, chunk) = (16usize, 32usize, 8usize);
    let manifest = swap_manifest(d, chunk);
    let serial = interp_pool(&manifest, 1, RuntimeOptions::default());
    let pooled = interp_pool(&manifest, 3, RuntimeOptions::default());
    let mut rng = Rng::new(77);
    let pattern = Pattern::PerRow { keep: 13 };
    let (w, g, warm) = layer(&mut rng, rows, d, pattern);
    let checkpoints = [2usize, 9, 16];
    let run = |rt: &Runtime| {
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: None, pattern,
            t_max: 16,
            threads: 1,
            gmax: None,
        };
        let mut mask = warm.clone();
        let out = OffloadEngine::new(rt, "interp")
            .refine(&ctx, &mut mask, &checkpoints)
            .unwrap();
        (mask, out)
    };
    let (m1, o1) = run(serial.primary());
    let (m2, o2) = run(pooled.runtime(2));
    assert_eq!(m1.data, m2.data);
    assert_eq!(o1.layer.total_swaps(), o2.layer.total_swaps());
    assert_eq!(o1.snapshots.len(), o2.snapshots.len());
    for (cp, snap) in &o1.snapshots {
        assert_eq!(snap.data, o2.snapshots[cp].data, "checkpoint {cp}");
    }
}
