//! Out-of-core streaming integration tests: the staged streamed
//! pipeline (weights leased per block from a `.ssck` checkpoint via
//! `StreamingStore`, Gram statistics from the incremental per-block
//! stream) must be bit-invisible next to the fully-resident store —
//! identical masks and snapshots for every engine, shard size and
//! calibration mode, including journal-resumed runs — while holding
//! at most two blocks of weights resident.
//!
//! Everything runs on an interp-backed pool over the in-memory tiny
//! manifest, so the whole streamed path is tier-1 coverage.

use std::path::PathBuf;

use sparseswaps::coordinator::{
    MaskSpec, PatternKind, PruneReport, PruneSession, Refiner,
    RunOptions,
};
use sparseswaps::data::Dataset;
use sparseswaps::model::testutil::tiny_manifest;
use sparseswaps::model::{
    checkpoint, MaskSet, ParamStore, StreamingStore, WeightStore,
};
use sparseswaps::runtime::testutil::interp_pool;
use sparseswaps::runtime::{RuntimeError, RuntimeOptions, RuntimePool};

/// Untrained tiny model + dataset (pruning is deterministic in the
/// weights) and its checkpoint on disk for the streaming store.
fn setup(tag: &str) -> (RuntimePool, ParamStore, Dataset, PathBuf) {
    let pool = interp_pool(&tiny_manifest(), 1,
                           RuntimeOptions::default());
    let meta = pool.manifest().config("tiny").unwrap().clone();
    let ds = Dataset::build(&meta, 42);
    let store = ParamStore::init(&meta, meta.init_seed);
    let path = std::env::temp_dir().join(format!(
        "ssstream_test_{tag}_{}.ssck", std::process::id()));
    checkpoint::save(&path, &store, None).unwrap();
    (pool, store, ds, path)
}

fn prune_with(pool: &RuntimePool, store: &dyn WeightStore,
              ds: &Dataset, spec: &MaskSpec, run: RunOptions)
    -> Result<(MaskSet, PruneReport), RuntimeError> {
    PruneSession::new(pool, store, ds, run).prune(spec)
}

fn assert_masks_eq(a: &MaskSet, b: &MaskSet, what: &str) {
    for (li, (x, y)) in a.masks.iter().zip(&b.masks).enumerate() {
        assert_eq!(x.data, y.data, "{what}: layer {li} mask diverged");
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ssstream_test_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn streamed_masks_match_resident_across_engines_and_shards() {
    let (pool, store, ds, path) = setup("parity");
    let meta = store.meta.clone();
    // (refiner, sequential, shard_rows): every engine in both
    // calibration modes, plus an awkward shard size on the native
    // engine (shard scheduling is orthogonal to the weight store).
    let offload = || Refiner::SparseSwapsOffload {
        impl_name: "interp".into(),
    };
    let combos: Vec<(Refiner, bool, usize)> = vec![
        (Refiner::SparseSwapsNative, false, 0),
        (Refiner::SparseSwapsNative, true, 0),
        (Refiner::SparseSwapsNative, false, 3),
        (offload(), false, 0),
        (offload(), true, 0),
        (Refiner::Dsnot, false, 0),
        (Refiner::Dsnot, true, 0),
    ];
    for (refiner, sequential, shard_rows) in combos {
        let what = format!("{}/{}/shard{shard_rows}", refiner.label(),
                           if sequential { "seq" } else { "oneshot" });
        let spec = MaskSpec {
            pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
            refiner,
            t_max: 6,
            calib_batches: 2,
            sequential,
            checkpoints: vec![2, 6],
            ..Default::default()
        };
        let run = RunOptions { shard_rows, ..Default::default() };
        let (m_res, r_res) =
            prune_with(&pool, &store, &ds, &spec, run.clone())
                .unwrap();
        let sstore = StreamingStore::open(&path, &meta, 0).unwrap();
        let (m_str, r_str) =
            prune_with(&pool, &sstore, &ds, &spec, run).unwrap();
        assert_masks_eq(&m_res, &m_str, &what);
        assert_eq!(r_res.snapshots.len(), r_str.snapshots.len(),
                   "{what}: snapshot count diverged");
        for (cp, snap) in &r_res.snapshots {
            assert_masks_eq(snap, &r_str.snapshots[cp],
                            &format!("{what}: checkpoint {cp}"));
        }
        // The streamed layer reports carry the same refinement
        // trajectory, not just the same end state.
        for (a, b) in r_res.layers.iter().zip(&r_str.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.swaps, b.swaps,
                       "{what}: {} swap count diverged", a.name);
            assert_eq!(a.loss_refined, b.loss_refined,
                       "{what}: {} refined loss diverged", a.name);
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn streamed_resume_reproduces_uninterrupted_masks() {
    // Sequential is the interesting mode: block 1's statistics pass
    // through block 0's restored masks, so resume must push the
    // journaled masks through the residual stream exactly.  The
    // one-shot staged stream resumes too (restored blocks advance the
    // stream densely without re-accumulating).
    let (pool, store, ds, path) = setup("resume");
    let meta = store.meta.clone();
    for sequential in [true, false] {
        let spec = MaskSpec {
            pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
            refiner: Refiner::SparseSwapsNative,
            t_max: 6,
            calib_batches: 2,
            sequential,
            ..Default::default()
        };
        let (m_full, _) = prune_with(&pool, &store, &ds, &spec,
                                     RunOptions::default())
            .unwrap();

        let tag = if sequential { "seq" } else { "oneshot" };
        let dir = tmp_dir(&format!("resume_{tag}"));
        let sstore = StreamingStore::open(&path, &meta, 0).unwrap();
        let run_halt = RunOptions {
            journal: Some(dir.clone()),
            halt_after_block: Some(0),
            ..Default::default()
        };
        let (_, r_halt) =
            prune_with(&pool, &sstore, &ds, &spec, run_halt).unwrap();
        assert!(r_halt.layers.iter().all(|l| l.block == 0),
                "{tag}: halted run must stop after block 0");

        let sstore = StreamingStore::open(&path, &meta, 0).unwrap();
        let run_resume = RunOptions {
            journal: Some(dir.clone()),
            resume: true,
            ..Default::default()
        };
        let (m_res, r_res) =
            prune_with(&pool, &sstore, &ds, &spec, run_resume)
                .unwrap();
        assert!(r_res.layers.iter().all(|l| l.block == 1),
                "{tag}: resume must skip the journaled block");
        assert_masks_eq(&m_full, &m_res,
                        &format!("streamed {tag} resume"));
        std::fs::remove_dir_all(&dir).ok();
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn store_stats_account_bytes_exactly_through_a_prune() {
    let (pool, store, ds, path) = setup("stats");
    let meta = store.meta.clone();
    let bytes_of = |i: usize| -> usize {
        meta.params[i].1.iter().product::<usize>() * 4
    };
    let n = meta.n_blocks;
    let globals_bytes: usize = [0usize, 1 + n * 9, 2 + n * 9].iter()
        .map(|&i| bytes_of(i)).sum();
    let max_block_bytes = (0..n)
        .map(|b| (1 + b * 9..1 + (b + 1) * 9)
            .map(bytes_of).sum::<usize>())
        .max().unwrap();
    let total_bytes: usize =
        (0..meta.params.len()).map(bytes_of).sum();

    let spec = MaskSpec {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
        refiner: Refiner::SparseSwapsNative,
        t_max: 4,
        calib_batches: 2,
        sequential: false,
        ..Default::default()
    };
    let sstore = StreamingStore::open(&path, &meta, 0).unwrap();
    let (masks, _) = prune_with(&pool, &sstore, &ds, &spec,
                                RunOptions::default()).unwrap();
    let stats = sstore.stats();
    // One-shot streams lease every tensor exactly once: the 3 globals
    // plus 9 params per block, totalling the whole model's bytes.
    assert_eq!(stats.loads, 3 + 9 * n, "tensor load count");
    assert_eq!(stats.loaded_bytes, total_bytes, "bytes read from disk");
    // Peak residency stays within the staged 2-block bound (globals
    // are released before the first block leases, so the high-water
    // mark is whichever is larger), and everything is released once
    // the stream passes it.
    assert!(stats.peak_bytes >= max_block_bytes);
    assert!(stats.peak_bytes <= globals_bytes.max(2 * max_block_bytes),
            "peak {} above the 2-block bound (globals {}, 2-block {})",
            stats.peak_bytes, globals_bytes, 2 * max_block_bytes);
    assert!(stats.peak_bytes < total_bytes,
            "streaming never holds the whole model");
    assert_eq!(stats.resident_bytes, 0,
               "all leases released after the prune");
    assert_eq!(stats.releases, n + 1, "per-block releases + globals");

    // The streamed output checkpoint round-trips: re-leased weights
    // and the refined masks land byte-identical to the resident save.
    let out = std::env::temp_dir().join(format!(
        "ssstream_test_stats_out_{}.ssck", std::process::id()));
    checkpoint::save_streaming(&out, &sstore, Some(&masks)).unwrap();
    let (loaded, loaded_masks) = checkpoint::load(&out, &meta).unwrap();
    for (i, (a, b)) in store.tensors.iter().zip(&loaded.tensors)
        .enumerate()
    {
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap(),
                   "tensor {i} diverged through save_streaming");
    }
    assert_masks_eq(&masks, &loaded_masks.unwrap(),
                    "save_streaming masks");
    std::fs::remove_file(&out).ok();
    std::fs::remove_file(&path).ok();
}

#[test]
fn over_budget_streamed_prune_is_rejected() {
    let (pool, store, ds, path) = setup("budget");
    let meta = store.meta.clone();
    let bytes_of = |i: usize| -> usize {
        meta.params[i].1.iter().product::<usize>() * 4
    };
    let n = meta.n_blocks;
    let globals_bytes: usize = [0usize, 1 + n * 9, 2 + n * 9].iter()
        .map(|&i| bytes_of(i)).sum();
    let max_block_bytes = (0..n)
        .map(|b| (1 + b * 9..1 + (b + 1) * 9)
            .map(bytes_of).sum::<usize>())
        .max().unwrap();
    let spec = MaskSpec {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
        refiner: Refiner::SparseSwapsNative,
        t_max: 4,
        calib_batches: 2,
        sequential: false,
        ..Default::default()
    };
    // Enough for the globals and one block, not for the two-block
    // staging overlap: the prefetch lease must be refused and the
    // prune must surface the budget error instead of thrashing.
    let budget = globals_bytes.max(max_block_bytes)
        + max_block_bytes / 2;
    let sstore = StreamingStore::open(&path, &meta, budget).unwrap();
    let err = prune_with(&pool, &sstore, &ds, &spec,
                         RunOptions::default()).unwrap_err();
    assert!(err.to_string().contains("budget"),
            "unexpected error: {err}");

    // A budget that fits the staged overlap succeeds outright.
    let budget = globals_bytes.max(2 * max_block_bytes);
    let sstore = StreamingStore::open(&path, &meta, budget).unwrap();
    prune_with(&pool, &sstore, &ds, &spec, RunOptions::default())
        .unwrap();
    std::fs::remove_file(&path).ok();
}
