//! Integration: execute real AOT artifacts through the PJRT runtime and
//! check them against the native Rust implementations.
//!
//! Requires `make artifacts` (skipped otherwise, so `cargo test` stays
//! green on a fresh checkout).

use sparseswaps::pruning::error::layer_row_losses;
use sparseswaps::pruning::mask::{mask_from_scores, validate, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::pruning::sparseswaps::{refine_layer, SwapConfig};
use sparseswaps::runtime::{Runtime, TensorData};
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(
        std::env::var("SPARSESWAPS_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".into()));
    dir.join("manifest.json").exists().then_some(dir)
}

fn instance(seed: u64, rows: usize, d: usize) -> (Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let x = Matrix::from_fn(4 * d, d, |_, _| rng.gaussian_f32());
    let mut g = Matrix::zeros(d, d);
    g.gram_accumulate(&x);
    let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
    (w, g)
}

/// Pad a (rows x d) matrix into the artifact's fixed chunk height.
fn pad_chunk(m: &Matrix, chunk_rows: usize) -> Matrix {
    assert!(m.rows <= chunk_rows);
    let mut out = Matrix::zeros(chunk_rows, m.cols);
    out.data[..m.data.len()].copy_from_slice(&m.data);
    out
}

#[test]
fn layer_loss_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::start(&dir).unwrap();
    let entry = rt.manifest().artifact("layer_loss_d64").unwrap().clone();
    let rows = entry.chunk_rows;

    let (w, g) = instance(0, 16, 64);
    let scores = saliency::wanda(&w, &g.diag());
    let mask = mask_from_scores(&scores, Pattern::PerRow { keep: 26 });

    // Pad rows 16..rows with kept-everything masks (zero loss).
    let wp = pad_chunk(&w, rows);
    let mut mp = pad_chunk(&mask, rows);
    for r in 16..rows {
        mp.row_mut(r).fill(1.0);
    }
    let out = rt.execute("layer_loss_d64", vec![
        TensorData::from_matrix(&wp),
        TensorData::from_matrix(&mp),
        TensorData::from_matrix(&g),
    ]).unwrap();
    let losses = out[0].as_f32().unwrap();
    let native = layer_row_losses(&w, &mask, &g);
    for r in 0..16 {
        let rel = (losses[r] as f64 - native[r]).abs()
            / native[r].abs().max(1.0);
        assert!(rel < 1e-3, "row {r}: {} vs {}", losses[r], native[r]);
    }
    for r in 16..rows {
        assert!(losses[r].abs() < 1e-3);
    }
}

#[test]
fn swap_step_artifact_matches_native_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::start(&dir).unwrap();
    let name = "swap_step_d64_row_xla_k8";
    let entry = rt.manifest().artifact(name).unwrap().clone();
    let rows = entry.chunk_rows;

    let (w, g) = instance(1, rows, 64);
    let scores = saliency::wanda(&w, &g.diag());
    let pattern = Pattern::PerRow { keep: 26 };
    let mask = mask_from_scores(&scores, pattern);

    let out = rt.execute(name, vec![
        TensorData::from_matrix(&w),
        TensorData::from_matrix(&mask),
        TensorData::from_matrix(&g),
    ]).unwrap();
    let m_out = out[0].clone().into_matrix().unwrap();
    let l_before = out[1].as_f32().unwrap().to_vec();
    let l_after = out[2].as_f32().unwrap().to_vec();
    let swaps = out[3].as_f32().unwrap().to_vec();

    validate(&m_out, pattern).unwrap();
    // Offload losses must match native evaluation of its own masks.
    let native_before = layer_row_losses(&w, &mask, &g);
    let native_after = layer_row_losses(&w, &m_out, &g);
    for r in 0..rows {
        assert!((l_before[r] as f64 - native_before[r]).abs()
                / native_before[r].max(1.0) < 1e-3);
        assert!((l_after[r] as f64 - native_after[r]).abs()
                / native_after[r].max(1.0) < 1e-3);
        assert!(l_after[r] <= l_before[r] * 1.0001 + 1e-3);
        assert!(swaps[r] <= 8.0);
    }

    // And the native engine with the same budget reaches the same losses
    // (tie-breaking may differ; the objective may not).
    let mut native_mask = mask.clone();
    let cfg = SwapConfig { t_max: 8, eps: 0.0 };
    let out_native = refine_layer(&w, &mut native_mask, &g, pattern, &cfg,
                                  2);
    for r in 0..rows {
        let a = l_after[r] as f64;
        let b = out_native.rows[r].loss_after;
        assert!((a - b).abs() / b.abs().max(1.0) < 5e-3,
                "row {r}: offload {a} vs native {b}");
        assert_eq!(swaps[r] as usize, out_native.rows[r].swaps,
                   "row {r} swap count");
    }
}

#[test]
fn swap_step_nm_artifact_preserves_blocks() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::start(&dir).unwrap();
    let name = "swap_step_d64_nm2_4_xla_k8";
    let entry = rt.manifest().artifact(name).unwrap().clone();
    let rows = entry.chunk_rows;

    let (w, g) = instance(2, rows, 64);
    let pattern = Pattern::Nm { n: 2, m: 4 };
    let mask = mask_from_scores(&saliency::wanda(&w, &g.diag()), pattern);
    let out = rt.execute(name, vec![
        TensorData::from_matrix(&w),
        TensorData::from_matrix(&mask),
        TensorData::from_matrix(&g),
    ]).unwrap();
    let m_out = out[0].clone().into_matrix().unwrap();
    validate(&m_out, pattern).unwrap();
    let l_before = out[1].as_f32().unwrap();
    let l_after = out[2].as_f32().unwrap();
    let total_b: f32 = l_before.iter().sum();
    let total_a: f32 = l_after.iter().sum();
    assert!(total_a < total_b, "{total_a} !< {total_b}");
}

#[test]
fn pallas_swap_artifact_agrees_with_xla_variant() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::start(&dir).unwrap();
    // Pallas variants exist only for the designated width (manifest
    // `pallas_widths`); 128 in the default build.
    let pallas = "swap_step_d128_row_pallas_k1";
    let xla_ = "swap_step_d128_row_xla_k1";
    if rt.manifest().artifact(pallas).is_err() {
        return;
    }
    let rows = rt.manifest().artifact(pallas).unwrap().chunk_rows;
    let (w, g) = instance(3, rows, 128);
    let mask = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                Pattern::PerRow { keep: 51 });
    let inputs = |m: &Matrix| vec![
        TensorData::from_matrix(&w),
        TensorData::from_matrix(m),
        TensorData::from_matrix(&g),
    ];
    let out_p = rt.execute(pallas, inputs(&mask)).unwrap();
    let out_x = rt.execute(xla_, inputs(&mask)).unwrap();
    let la_p = out_p[2].as_f32().unwrap();
    let la_x = out_x[2].as_f32().unwrap();
    for r in 0..rows {
        assert!((la_p[r] - la_x[r]).abs() / la_x[r].abs().max(1.0) < 5e-3,
                "row {r}: pallas {} vs xla {}", la_p[r], la_x[r]);
    }
}

#[test]
fn runtime_rejects_bad_signatures() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::start(&dir).unwrap();
    let err = rt.execute("layer_loss_d64", vec![
        TensorData::scalar_f32(1.0),
    ]);
    assert!(err.is_err());
    let entry = rt.manifest().artifact("layer_loss_d64").unwrap().clone();
    let rows = entry.chunk_rows;
    // Wrong dims on the gram input.
    let err2 = rt.execute("layer_loss_d64", vec![
        TensorData::F32 { dims: vec![rows, 64],
                          data: vec![0.0; rows * 64] },
        TensorData::F32 { dims: vec![rows, 64],
                          data: vec![1.0; rows * 64] },
        TensorData::F32 { dims: vec![63, 64], data: vec![0.0; 63 * 64] },
    ]);
    assert!(err2.is_err());
}

#[test]
fn service_stats_accumulate() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::start(&dir).unwrap();
    let before = rt.stats();
    let (w, g) = instance(4, 8, 64);
    let entry = rt.manifest().artifact("layer_loss_d64").unwrap().clone();
    let wp = pad_chunk(&w, entry.chunk_rows);
    let mp = {
        let mut m = Matrix::zeros(entry.chunk_rows, 64);
        m.data.fill(1.0);
        m
    };
    rt.execute("layer_loss_d64", vec![
        TensorData::from_matrix(&wp),
        TensorData::from_matrix(&mp),
        TensorData::from_matrix(&g),
    ]).unwrap();
    let after = rt.stats();
    assert_eq!(after.executions, before.executions + 1);
    assert!(after.compiles >= 1);
    assert!(after.exec_nanos > 0);
}
