//! Pooled calibration & eval integration tests: the striped Gram
//! accumulation and the fanned eval drivers must be *bit-identical*
//! for any device count — the stripe decomposition is fixed
//! ([`CALIB_STRIPES`]) and the host reduces stripe/batch partials in
//! ascending order, so 1-, 2- and 4-worker pools all see the same f32
//! add sequence.  The resident-accumulator protocol is pinned by
//! exact byte accounting: steady-state calibration batches upload
//! only their token tensors.
//!
//! Everything runs on interp-backed pools over the in-memory tiny
//! manifest (tier-1, artifact-free).

use std::path::PathBuf;

use sparseswaps::coordinator::{
    MaskSpec, PatternKind, PruneSession, Refiner, RunOptions,
};
use sparseswaps::data::{Dataset, Split};
use sparseswaps::eval::{perplexity, perplexity_pool, zeroshot};
use sparseswaps::gram::{
    accumulate, accumulate_pool, expected_upload_bytes, GramStats,
    CALIB_STRIPES, STREAMS,
};
use sparseswaps::model::testutil::tiny_manifest;
use sparseswaps::model::{
    checkpoint, MaskSet, ParamStore, StreamingStore,
};
use sparseswaps::runtime::testutil::interp_pool;
use sparseswaps::runtime::{RuntimeOptions, RuntimePool};

fn setup() -> (ParamStore, Dataset) {
    let manifest = tiny_manifest();
    let meta = manifest.config("tiny").unwrap().clone();
    let ds = Dataset::build(&meta, 42);
    let store = ParamStore::init(&meta, meta.init_seed);
    (store, ds)
}

fn pool(devices: usize) -> RuntimePool {
    interp_pool(&tiny_manifest(), devices, RuntimeOptions::default())
}

/// Bitwise equality of two stat sets over every (block, stream) pair.
fn assert_stats_eq(a: &GramStats, b: &GramStats, what: &str) {
    assert_eq!(a.tokens, b.tokens, "{what}: token count diverged");
    assert_eq!(a.batches, b.batches, "{what}: batch count diverged");
    for block in 0..a.meta.n_blocks {
        for si in 0..STREAMS.len() {
            let (ga, gb) = (a.stream_gram(block, si),
                            b.stream_gram(block, si));
            assert!(ga.iter().map(|v| v.to_bits())
                        .eq(gb.iter().map(|v| v.to_bits())),
                    "{what}: gram diverged (block {block}, \
                     stream {})", STREAMS[si]);
            let (sa, sb) = (a.stream_sum(block, si),
                            b.stream_sum(block, si));
            assert!(sa.iter().map(|v| v.to_bits())
                        .eq(sb.iter().map(|v| v.to_bits())),
                    "{what}: sums diverged (block {block}, \
                     stream {})", STREAMS[si]);
        }
    }
}

fn assert_masks_eq(a: &MaskSet, b: &MaskSet, what: &str) {
    for (li, (x, y)) in a.masks.iter().zip(&b.masks).enumerate() {
        assert_eq!(x.data, y.data, "{what}: layer {li} mask diverged");
    }
}

#[test]
fn gram_stats_bit_identical_across_device_counts() {
    let (store, ds) = setup();
    let meta = store.meta.clone();
    // Ragged counts on purpose: fewer batches than stripes (1, 3),
    // batches % devices != 0 (3, 5), and a full multiple (8).
    for n_batches in [1usize, 3, 5, 8] {
        let calib = ds.batches(&meta, Split::Calibration, n_batches);
        let serial = pool(1);
        let baseline =
            accumulate(serial.primary(), &store, &calib).unwrap();
        for devices in [1usize, 2, 4] {
            let p = pool(devices);
            let stats = accumulate_pool(&p, &store, &calib).unwrap();
            assert_stats_eq(&baseline, &stats,
                            &format!("{n_batches} batches on \
                                      {devices} device(s)"));
        }
    }
}

#[test]
fn resident_accumulators_upload_only_tokens_steady_state() {
    let (store, ds) = setup();
    let meta = store.meta.clone();
    // 6 batches over 4 stripes: stripes 0 and 1 run a second,
    // steady-state batch whose only upload may be its token tensor.
    let calib = ds.batches(&meta, Split::Calibration, 6);
    for devices in [1usize, 4] {
        let p = pool(devices);
        let stats = accumulate_pool(&p, &store, &calib).unwrap();
        let t = stats.traffic;
        assert_eq!(t.upload_bytes,
                   expected_upload_bytes(&store, devices, &calib),
                   "{devices} device(s): upload bytes off the \
                    weights-once + zeros-per-stripe + tokens model");
        assert_eq!(t.executions, calib.len() as u64,
                   "one calib_step execution per batch");
        assert_eq!(t.probe_misses, 0,
                   "no key-only probe may miss on a healthy pool");
        assert!(t.probe_hits > 0,
                "steady-state batches probe weights + accumulators \
                 key-only");
        // The stripe chains stay device-resident: only each
        // non-empty stripe's final outputs travel back.
        let stripes = calib.len().min(CALIB_STRIPES) as u64;
        assert_eq!(t.download_bytes % stripes, 0);
        assert!(t.download_bytes > 0);
    }
}

#[test]
fn pooled_prune_masks_match_serial_across_modes() {
    let (store, ds) = setup();
    let meta = store.meta.clone();
    let path: PathBuf = std::env::temp_dir().join(format!(
        "sscalib_test_{}.ssck", std::process::id()));
    checkpoint::save(&path, &store, None).unwrap();
    let offload = || Refiner::SparseSwapsOffload {
        impl_name: "interp".into(),
    };
    for (refiner, sequential) in [
        (Refiner::SparseSwapsNative, false),
        (Refiner::SparseSwapsNative, true),
        (offload(), false),
        (offload(), true),
    ] {
        let what = format!("{}/{}", refiner.label(),
                           if sequential { "seq" } else { "oneshot" });
        let spec = MaskSpec {
            pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
            refiner,
            t_max: 6,
            calib_batches: 3,
            sequential,
            ..Default::default()
        };
        let serial = pool(1);
        let (m1, r1) =
            PruneSession::new(&serial, &store, &ds,
                              RunOptions::default())
                .prune(&spec).unwrap();
        assert!(r1.calib_traffic.executions > 0,
                "{what}: prune report must carry calibration traffic");
        for devices in [2usize, 4] {
            let p = pool(devices);
            let (m, _) = PruneSession::new(&p, &store, &ds,
                                           RunOptions::default())
                .prune(&spec).unwrap();
            assert_masks_eq(&m1, &m,
                            &format!("{what} on {devices} device(s)"));
            if devices == 2 {
                // The streamed store rides the same striped workers.
                let sstore =
                    StreamingStore::open(&path, &meta, 0).unwrap();
                let (ms, _) = PruneSession::new(&p, &sstore, &ds,
                                                RunOptions::default())
                    .prune(&spec).unwrap();
                assert_masks_eq(&m1, &ms,
                                &format!("{what} streamed on 2 \
                                          device(s)"));
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn eval_bit_identical_across_device_counts() {
    let (store, ds) = setup();
    let meta = store.meta.clone();
    // 5 batches: ragged against both 2 and 4 workers.
    let val = ds.batches(&meta, Split::Validation, 5);
    let serial = pool(1);
    let base_ppl = perplexity(serial.primary(), &store, &val).unwrap();
    let tasks = zeroshot::build_tasks(&ds, meta.vocab, 12, 7);
    let base_scores =
        zeroshot::score_tasks(serial.primary(), &store, &tasks)
            .unwrap();
    for devices in [1usize, 2, 4] {
        let p = pool(devices);
        let ppl = perplexity_pool(&p, &store, &val).unwrap();
        assert_eq!(ppl.to_bits(), base_ppl.to_bits(),
                   "{devices} device(s): perplexity diverged");
        let scores =
            zeroshot::score_tasks_pool(&p, &store, &tasks).unwrap();
        for (t, (a, b)) in
            base_scores.iter().zip(&scores).enumerate() {
            for (c, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(),
                           "{devices} device(s): task {t} choice {c} \
                            NLL diverged");
            }
        }
    }
}
