//! Shard-boundary semantics: masks AND checkpoint snapshots must be
//! bit-identical to the whole-layer schedule for every shard size,
//! engine, and worker/device count — the scheduling counterpart of
//! the paper's row-decoupling assumption, and the invariant that lets
//! `coordinator::scheduler::refine_block` split a wide layer across
//! workers.
//!
//! Shard sizes swept: 1 (every row its own unit), a prime (7, so the
//! tail is ragged almost everywhere), 0 (adaptive), and whole-layer;
//! schedulers: host `ThreadPool`s at 1/3 workers for the native
//! engine, interp `RuntimePool`s at 1/2/4 devices for the offload
//! engine.

use std::collections::BTreeMap;

use sparseswaps::coordinator::scheduler::{
    refine_block, BlockSchedule, LayerWork,
};
use sparseswaps::coordinator::Refiner;
use sparseswaps::pruning::dsnot::FeatureStats;
use sparseswaps::pruning::engine::{LayerContext, RefineEngine};
use sparseswaps::pruning::mask::{mask_from_scores, validate, Pattern};
use sparseswaps::pruning::saliency;
use sparseswaps::pruning::sparseswaps::{gmax_table, NativeEngine};
use sparseswaps::runtime::testutil::{interp_pool, swap_manifest};
use sparseswaps::runtime::RuntimeOptions;
use sparseswaps::util::proptest::{check, ensure};
use sparseswaps::util::prng::Rng;
use sparseswaps::util::tensor::Matrix;
use sparseswaps::util::threadpool::ThreadPool;

fn layer(rng: &mut Rng, rows: usize, d: usize, pattern: Pattern)
    -> (Matrix, Matrix, Matrix) {
    let x = Matrix::from_fn(2 * d, d, |_, _| rng.gaussian_f32());
    let mut g = Matrix::zeros(d, d);
    g.gram_accumulate(&x);
    let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
    let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()), pattern);
    (w, g, warm)
}

fn plan(t_max: usize, checkpoints: &[usize], shard_rows: usize)
    -> BlockSchedule {
    BlockSchedule {
        t_max,
        threads_per_shard: 1,
        checkpoints: checkpoints.to_vec(),
        shard_rows,
        serial: false,
        max_retries: 2,
    }
}

fn work<'a>(li: usize, w: &'a Matrix, g: &'a Matrix, warm: &Matrix,
            pattern: Pattern, stats: Option<FeatureStats>,
            align: usize) -> LayerWork<'a> {
    LayerWork {
        li,
        label: format!("layer{li}"),
        w: w.view(),
        g: g.as_gram(),
        stats,
        pattern,
        warm: warm.clone(),
        shard_align: align,
        gram_key: sparseswaps::coordinator::swaploop::
            next_refinement_id(),
    }
}

fn assert_snapshots_equal(
    want: &BTreeMap<usize, Matrix>, got: &BTreeMap<usize, Matrix>,
    what: &str,
) -> Result<(), String> {
    ensure(want.len() == got.len(),
           || format!("{what}: {} vs {} snapshots", got.len(),
                      want.len()))?;
    for (cp, snap) in want {
        let g = got.get(cp)
            .ok_or_else(|| format!("{what}: checkpoint {cp} missing"))?;
        ensure(g.data == snap.data,
               || format!("{what}: checkpoint {cp} snapshot diverged"))?;
    }
    Ok(())
}

#[test]
fn native_shard_sweep_masks_and_snapshots_bit_identical() {
    check("native shard sweep", 20, |gen| {
        let d = *gen.choose(&[16usize, 24, 32]);
        let rows = gen.usize_in(4, 30);
        let pattern = if d % 4 == 0 && gen.rng.bool(0.4) {
            Pattern::Nm { n: 2, m: 4 }
        } else {
            Pattern::PerRow { keep: gen.usize_in(1, d - 1) }
        };
        let t_max = gen.usize_in(2, 20);
        let cps =
            vec![1, gen.usize_in(1, t_max), t_max, t_max + 5];
        let (w, g, warm) = layer(&mut gen.rng, rows, d, pattern);

        // Whole-layer reference straight through the engine.
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: None, pattern, t_max,
            threads: 1,
            gmax: None,
        };
        let mut ref_mask = warm.clone();
        let ref_out = NativeEngine::default()
            .refine(&ctx, &mut ref_mask, &cps)
            .map_err(|e| e.to_string())?;

        for shard_rows in [1usize, 7, 0, rows] {
            for workers in [1usize, 3] {
                let tp = ThreadPool::new(workers);
                let works = vec![work(0, &w, &g, &warm, pattern, None,
                                      1)];
                let res = refine_block(
                    &tp, &Refiner::SparseSwapsNative, &works,
                    &plan(t_max, &cps, shard_rows))
                    .map_err(|e| e.to_string())?;
                let tag = format!(
                    "shard_rows={shard_rows} workers={workers} \
                     pattern={pattern:?} t_max={t_max}");
                ensure(res.len() == 1, || format!("{tag}: results"))?;
                validate(&res[0].mask, pattern)?;
                ensure(res[0].mask.data == ref_mask.data,
                       || format!("{tag}: mask diverged"))?;
                ensure(res[0].outcome.layer.total_swaps()
                       == ref_out.layer.total_swaps(),
                       || format!("{tag}: swap counts diverged"))?;
                assert_snapshots_equal(&ref_out.snapshots,
                                       &res[0].outcome.snapshots,
                                       &tag)?;
            }
        }
        Ok(())
    });
}

#[test]
fn offload_shard_sweep_masks_and_snapshots_bit_identical() {
    let (rows, d, chunk) = (19usize, 32usize, 8usize);
    let manifest = swap_manifest(d, chunk);
    let refiner = Refiner::SparseSwapsOffload {
        impl_name: "interp".into(),
    };
    let mut rng = Rng::new(31);
    for pattern in [Pattern::PerRow { keep: 13 },
                    Pattern::Nm { n: 2, m: 4 }] {
        let (w, g, warm) = layer(&mut rng, rows, d, pattern);
        let t_max = 14;
        let cps = [2usize, 9, 14];

        // Whole-layer reference on a single-device pool.
        let serial = interp_pool(&manifest, 1, RuntimeOptions::default());
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: None, pattern, t_max,
            threads: 1,
            gmax: None,
        };
        let mut ref_mask = warm.clone();
        let ref_out = sparseswaps::coordinator::OffloadEngine::new(
            serial.primary(), "interp")
            .refine(&ctx, &mut ref_mask, &cps)
            .unwrap();

        for devices in [1usize, 2, 4] {
            let pool = interp_pool(&manifest, devices,
                                   RuntimeOptions::default());
            for shard_rows in [1usize, 7, 0, rows] {
                let works = vec![work(0, &w, &g, &warm, pattern, None,
                                      chunk)];
                let res = refine_block(&pool, &refiner, &works,
                                       &plan(t_max, &cps, shard_rows))
                    .unwrap();
                let tag = format!(
                    "devices={devices} shard_rows={shard_rows} \
                     pattern={pattern:?}");
                validate(&res[0].mask, pattern).unwrap();
                assert_eq!(res[0].mask.data, ref_mask.data,
                           "{tag}: mask diverged");
                assert_eq!(res[0].outcome.layer.total_swaps(),
                           ref_out.layer.total_swaps(), "{tag}");
                assert_snapshots_equal(&ref_out.snapshots,
                                       &res[0].outcome.snapshots, &tag)
                    .unwrap();
            }
        }
    }
}

#[test]
fn shared_gmax_table_matches_per_shard_recompute() {
    // The per-layer skip-bound table is a pure function of
    // (G, nm_block): handing every shard one borrowed table must land
    // on the same masks as each shard recomputing its own — for
    // unstructured scans (whole-row maxima) and N:M (per-block
    // maxima) alike, at every shard size.
    let (rows, d, t_max) = (13usize, 16usize, 12usize);
    let mut rng = Rng::new(17);
    for pattern in [Pattern::PerRow { keep: 7 },
                    Pattern::Nm { n: 2, m: 4 }] {
        let (w, g, warm) = layer(&mut rng, rows, d, pattern);
        let table = gmax_table(g.as_gram(), pattern.nm_block(), 3);
        assert_eq!(table.len(), d);

        // Whole-layer reference (computes its own local table).
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: None, pattern, t_max,
            threads: 1,
            gmax: None,
        };
        let mut ref_mask = warm.clone();
        NativeEngine::default()
            .refine(&ctx, &mut ref_mask, &[])
            .unwrap();

        // Manual shard loop through the row-range contract, with and
        // without the shared table.
        let refine_sharded = |gmax: Option<&[f64]>, shard_rows: usize| {
            let mut mask = warm.clone();
            let mut r0 = 0usize;
            while r0 < rows {
                let r1 = (r0 + shard_rows).min(rows);
                let ctx = LayerContext {
                    w: w.view(), g: g.as_gram(), stats: None, pattern,
                    t_max, threads: 1,
                    gmax,
                };
                let mut shard = Matrix::zeros(r1 - r0, d);
                for r in r0..r1 {
                    shard.row_mut(r - r0).copy_from_slice(mask.row(r));
                }
                NativeEngine::default()
                    .refine_rows(&ctx, r0..r1, &mut shard, &[])
                    .unwrap();
                for r in r0..r1 {
                    mask.row_mut(r).copy_from_slice(shard.row(r - r0));
                }
                r0 = r1;
            }
            mask
        };
        for shard_rows in [1usize, 7, rows] {
            let local = refine_sharded(None, shard_rows);
            let shared = refine_sharded(Some(&table), shard_rows);
            assert_eq!(local.data, shared.data,
                       "{pattern:?} shard_rows={shard_rows}: shared \
                        table changed a mask");
            assert_eq!(shared.data, ref_mask.data,
                       "{pattern:?} shard_rows={shard_rows}: sharded \
                        diverged from whole-layer");
            validate(&shared, pattern).unwrap();
        }

        // The scheduler path computes the table once per layer and
        // lends it to every shard; it must land on the identical
        // masks at every plan, adaptive included.
        let tp = ThreadPool::new(3);
        for shard_rows in [1usize, 7, 0, rows] {
            let works = vec![work(0, &w, &g, &warm, pattern, None, 1)];
            let res = refine_block(
                &tp, &Refiner::SparseSwapsNative, &works,
                &plan(t_max, &[], shard_rows))
                .unwrap();
            assert_eq!(res[0].mask.data, ref_mask.data,
                       "{pattern:?} shard_rows={shard_rows}: scheduler \
                        shared-gmax mask diverged");
        }
    }
}

#[test]
fn ragged_tail_shard_plan_covers_every_row() {
    // rows % shard_size != 0: the tail shard is short, coverage must
    // still be exact and results identical.
    let (rows, d) = (13usize, 16usize);
    let pattern = Pattern::PerRow { keep: 7 };
    let mut rng = Rng::new(7);
    let (w, g, warm) = layer(&mut rng, rows, d, pattern);
    let ctx = LayerContext {
        w: w.view(), g: g.as_gram(), stats: None, pattern, t_max: 10,
        threads: 1,
        gmax: None,
    };
    let mut ref_mask = warm.clone();
    NativeEngine::default().refine(&ctx, &mut ref_mask, &[]).unwrap();

    let tp = ThreadPool::new(2);
    let works = vec![work(0, &w, &g, &warm, pattern, None, 1)];
    let res = refine_block(&tp, &Refiner::SparseSwapsNative, &works,
                           &plan(10, &[], 5))
        .unwrap();
    // 13 rows at 5 per shard: 5 + 5 + 3.
    assert_eq!(res[0].shards, 3);
    assert_eq!(res[0].outcome.layer.rows.len(), rows);
    assert_eq!(res[0].mask.data, ref_mask.data);
}

#[test]
fn skewed_block_adaptive_sharding_matches_per_layer_reference() {
    // One 4x-wide layer among narrow ones (the MLP down-projection
    // shape): adaptive sharding must split it without changing any
    // layer's mask.
    let d = 16usize;
    let pattern = Pattern::PerRow { keep: 6 };
    let mut rng = Rng::new(11);
    let row_counts = [24usize, 6, 6, 6];
    let layers: Vec<(Matrix, Matrix, Matrix)> = row_counts.iter()
        .map(|&rows| layer(&mut rng, rows, d, pattern))
        .collect();
    let mut refs = Vec::new();
    for (w, g, warm) in &layers {
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: None, pattern,
            t_max: 12,
            threads: 1,
            gmax: None,
        };
        let mut m = warm.clone();
        NativeEngine::default().refine(&ctx, &mut m, &[]).unwrap();
        refs.push(m);
    }
    let tp = ThreadPool::new(4);
    let works: Vec<LayerWork> = layers.iter().enumerate()
        .map(|(li, (w, g, warm))| work(li, w, g, warm, pattern, None,
                                       1))
        .collect();
    let res = refine_block(&tp, &Refiner::SparseSwapsNative, &works,
                           &plan(12, &[], 0))
        .unwrap();
    // Adaptive target = 42 / (4 x 4) -> 3 rows: the wide layer splits.
    assert!(res[0].shards >= 4,
            "wide layer must split under adaptive sizing (got {})",
            res[0].shards);
    for (li, r) in res.iter().enumerate() {
        assert_eq!(r.li, li);
        assert_eq!(r.mask.data, refs[li].data, "layer {li}");
    }
}

#[test]
fn dsnot_and_noop_ride_the_same_dispatch_path() {
    // Engines without iteration checkpoints go through the identical
    // shard plan; sharding must not change their masks either.
    let (rows, d) = (11usize, 24usize);
    let pattern = Pattern::PerRow { keep: 10 };
    let mut rng = Rng::new(3);
    let x = Matrix::from_fn(64, d,
                            |_, j| (j as f32 * 0.1 - 1.0)
                                + 0.3 * rng.gaussian_f32());
    let mut g = Matrix::zeros(d, d);
    g.gram_accumulate(&x);
    let mut sums = vec![0.0f32; d];
    for t in 0..x.rows {
        for j in 0..d {
            sums[j] += x.at(t, j);
        }
    }
    let stats = FeatureStats::from_gram(&g.diag(), &sums, x.rows);
    let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
    let warm = mask_from_scores(&saliency::magnitude(&w), pattern);

    for refiner in [Refiner::Dsnot, Refiner::None] {
        let stats_for = |r: &Refiner| match r {
            Refiner::Dsnot => Some(stats.clone()),
            _ => None,
        };
        let tp = ThreadPool::new(3);
        let whole = refine_block(
            &tp, &refiner,
            &[work(0, &w, &g, &warm, pattern, stats_for(&refiner),
                   1)],
            &plan(10, &[], rows))
            .unwrap();
        let sharded = refine_block(
            &tp, &refiner,
            &[work(0, &w, &g, &warm, pattern, stats_for(&refiner),
                   1)],
            &plan(10, &[], 4))
            .unwrap();
        assert_eq!(whole[0].mask.data, sharded[0].mask.data,
                   "{refiner:?}");
        validate(&sharded[0].mask, pattern).unwrap();
    }
}
