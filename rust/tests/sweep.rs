//! Sweep-harness integration tests over interp-backed pools: warm
//! continuation quality vs cold refinement, deterministic grid order,
//! session calibration sharing, and the journal/warm-start exclusion.
//!
//! Tolerances are deliberately loose where trajectories may differ
//! (warm vs cold explore different 1-swap basins); exact equality is
//! asserted only where the pipeline guarantees it (session reuse,
//! grid order).

use std::path::PathBuf;

use sparseswaps::coordinator::sweep::{point_key, points, sweep};
use sparseswaps::coordinator::{
    MaskSpec, PatternKind, PruneSession, Refiner, RunOptions,
    SweepConfig,
};
use sparseswaps::data::Dataset;
use sparseswaps::model::testutil::tiny_manifest;
use sparseswaps::model::{MaskSet, ParamStore};
use sparseswaps::pruning::Criterion;
use sparseswaps::runtime::testutil::interp_pool;
use sparseswaps::runtime::{RuntimeOptions, RuntimePool};
use sparseswaps::util::jsonlite::Json;

fn tiny_setup(pool: &RuntimePool) -> (ParamStore, Dataset) {
    let meta = pool.manifest().config("tiny").unwrap().clone();
    let ds = Dataset::build(&meta, 42);
    let store = ParamStore::init(&meta, meta.init_seed);
    (store, ds)
}

fn base_cfg() -> SweepConfig {
    SweepConfig {
        levels: vec![
            PatternKind::Unstructured { sparsity: 0.4 },
            PatternKind::Unstructured { sparsity: 0.5 },
            PatternKind::Unstructured { sparsity: 0.6 },
        ],
        criteria: vec![Criterion::Wanda],
        refiners: vec![Refiner::SparseSwapsNative],
        t_max: 8,
        calib_batches: 2,
        warm_start: true,
        cold_compare: false,
        eval_ppl: false,
        val_batches: 2,
        out: None,
    }
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("ss_sweep_test_{tag}_{}", std::process::id()))
}

#[test]
fn warm_sweep_matches_cold_error_and_calibrates_once() {
    let pool = interp_pool(&tiny_manifest(), 1,
                           RuntimeOptions::default());
    let (store, ds) = tiny_setup(&pool);

    let out = tmp_path("curve.json");
    let warm_cfg = SweepConfig { out: Some(out.clone()), ..base_cfg() };
    let mut warm_session =
        PruneSession::new(&pool, &store, &ds, RunOptions::default());
    let warm = sweep(&mut warm_session, &warm_cfg).unwrap();

    let cold_cfg = SweepConfig { warm_start: false, ..base_cfg() };
    let mut cold_session =
        PruneSession::new(&pool, &store, &ds, RunOptions::default());
    let cold = sweep(&mut cold_session, &cold_cfg).unwrap();

    // One-shot grids pay for exactly one calibration pass, however
    // many points they have.
    assert_eq!(warm.calibrations, 1);
    assert_eq!(cold.calibrations, 1);
    assert_eq!(warm.points.len(), 3);
    assert_eq!(cold.points.len(), 3);

    // Chain heads start cold; every later level continues warm.
    assert!(warm.points[0].warm_from.is_none());
    assert!(warm.points[1..].iter().all(|p| p.warm_from.is_some()));
    assert!(cold.points.iter().all(|p| p.warm_from.is_none()));

    // The chain head has no inherited mask in either arm, so the
    // deterministic pipeline must agree exactly there.
    assert_eq!(warm.points[0].refined_loss, cold.points[0].refined_loss);

    for (w, c) in warm.points.iter().zip(&cold.points) {
        assert_eq!(w.key, c.key);
        assert!((w.achieved_sparsity - w.target_sparsity).abs() < 0.02,
                "{}: achieved {} vs target {}", w.key,
                w.achieved_sparsity, w.target_sparsity);
        // Warm continuation must land within a small band of the
        // cold refinement's error (usually at or below it: the warm
        // mask already descended at the previous level).
        assert!(w.refined_loss <= c.refined_loss * 1.05,
                "{}: warm loss {} vs cold {}", w.key, w.refined_loss,
                c.refined_loss);
        // Monotone 1-swap descent holds regardless of the start.
        assert!(w.refined_loss
                <= w.warmstart_loss * 1.0001 + 1e-9);
    }

    // The curve artifact is valid JSON carrying the whole grid.
    let text = std::fs::read_to_string(&out).unwrap();
    let json = Json::parse(&text).unwrap();
    assert_eq!(json.get("model").and_then(|m| m.as_str()),
               Some("tiny"));
    assert_eq!(json.get("calibrations").and_then(|c| c.as_f64()),
               Some(1.0));
    match json.get("points") {
        Some(Json::Arr(pts)) => {
            assert_eq!(pts.len(), 3);
            for (p, rep) in pts.iter().zip(&warm.points) {
                assert_eq!(p.get("key").and_then(|k| k.as_str()),
                           Some(rep.key.as_str()));
            }
        }
        other => panic!("points missing from sweep.json: {other:?}"),
    }
    std::fs::remove_file(&out).ok();
}

#[test]
fn grid_walk_matches_points_order_and_keys_are_unique() {
    // Equal-sparsity levels (2:4 vs unstructured 50%) must neither
    // collide in keys nor reorder between runs; the report's point
    // sequence is exactly `points(&cfg)`.
    let pool = interp_pool(&tiny_manifest(), 1,
                           RuntimeOptions::default());
    let (store, ds) = tiny_setup(&pool);
    let cfg = SweepConfig {
        levels: vec![
            PatternKind::Nm { n: 2, m: 4 },
            PatternKind::Unstructured { sparsity: 0.5 },
            PatternKind::Unstructured { sparsity: 0.6 },
        ],
        criteria: vec![Criterion::Wanda, Criterion::Magnitude],
        refiners: vec![Refiner::None],
        ..base_cfg()
    };
    let mut session =
        PruneSession::new(&pool, &store, &ds, RunOptions::default());
    let rep = sweep(&mut session, &cfg).unwrap();
    let expected: Vec<String> = points(&cfg).iter()
        .map(|(c, r, p)| point_key(*c, r, *p))
        .collect();
    let got: Vec<String> =
        rep.points.iter().map(|p| p.key.clone()).collect();
    assert_eq!(got, expected);
    let unique: std::collections::BTreeSet<&String> = got.iter()
        .collect();
    assert_eq!(unique.len(), got.len(),
               "2:4 and 50% unstructured must not collide");
    // 2:4 sits at the same target sparsity as unstructured 50% but
    // keeps its own kinded key.
    assert!(got.iter().any(|k| k.ends_with("nm:2:4")));
    assert!(got.iter().any(|k| k.ends_with("unstructured:50%")));
}

#[test]
fn session_reuse_is_bit_identical_and_calibrates_once() {
    // The cold arm of a sweep reuses one session across specs; masks
    // must be bit-identical to fresh-session runs (the cached Gram
    // statistics are the same accumulation, reused not recomputed).
    let pool = interp_pool(&tiny_manifest(), 1,
                           RuntimeOptions::default());
    let (store, ds) = tiny_setup(&pool);
    let specs: Vec<MaskSpec> = [0.5, 0.6].iter().map(|&s| MaskSpec {
        pattern_kind: PatternKind::Unstructured { sparsity: s },
        refiner: Refiner::SparseSwapsNative,
        t_max: 6,
        calib_batches: 2,
        sequential: false,
        ..Default::default()
    }).collect();

    let mut shared =
        PruneSession::new(&pool, &store, &ds, RunOptions::default());
    let shared_masks: Vec<MaskSet> = specs.iter()
        .map(|spec| shared.prune(spec).unwrap().0)
        .collect();
    assert_eq!(shared.calibrations(), 1,
               "the second spec must reuse the cached Gram stats");

    for (spec, masks) in specs.iter().zip(&shared_masks) {
        let (fresh, _) =
            PruneSession::new(&pool, &store, &ds,
                              RunOptions::default())
                .prune(spec).unwrap();
        for (li, (a, b)) in
            masks.masks.iter().zip(&fresh.masks).enumerate() {
            assert_eq!(a.data, b.data,
                       "layer {li}: shared-session mask diverged \
                        from the fresh-session run");
        }
    }
}

#[test]
fn warm_continuations_and_sweeps_reject_journaling() {
    let pool = interp_pool(&tiny_manifest(), 1,
                           RuntimeOptions::default());
    let (store, ds) = tiny_setup(&pool);
    let meta = store.meta.clone();
    let run = RunOptions {
        journal: Some(tmp_path("journal")),
        ..Default::default()
    };

    // Direct warm continuation under a journal.
    let spec = MaskSpec {
        refiner: Refiner::SparseSwapsNative,
        t_max: 2,
        calib_batches: 2,
        sequential: false,
        ..Default::default()
    };
    let warm = MaskSet::all_ones(&meta);
    let err = PruneSession::new(&pool, &store, &ds, run.clone())
        .prune_from(&spec, Some(&warm))
        .unwrap_err();
    assert!(err.to_string().contains("journal"),
            "unexpected error: {err}");

    // Whole sweep on a journaled session.
    let mut session = PruneSession::new(&pool, &store, &ds, run);
    let err = sweep(&mut session, &base_cfg()).unwrap_err();
    assert!(err.to_string().contains("journaled"),
            "unexpected error: {err}");
}
