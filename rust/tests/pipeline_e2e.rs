//! End-to-end integration on the `tiny` config: train through the
//! train-step artifact, calibrate, prune with Wanda, refine with
//! SparseSwaps (offload), evaluate perplexity and zero-shot accuracy.
//!
//! Runs **by default** on an interp-backed pool over an in-memory
//! manifest (`model::testutil::tiny_manifest`) — no `make artifacts`
//! needed, so the whole paper pipeline is tier-1 coverage.  When an
//! artifact directory exists (or `SPARSESWAPS_ARTIFACTS` points at
//! one), the same tests drive the real AOT artifacts through PJRT
//! instead.

use std::sync::OnceLock;

use sparseswaps::coordinator::{
    train, MaskSpec, PatternKind, PruneReport, PruneSession, Refiner,
    RunOptions, TrainConfig,
};
use sparseswaps::data::{Dataset, Split};
use sparseswaps::eval::{perplexity, zeroshot};
use sparseswaps::model::testutil::tiny_manifest;
use sparseswaps::model::{checkpoint, MaskSet, ParamStore};
use sparseswaps::runtime::testutil::interp_pool;
use sparseswaps::runtime::{
    Runtime, RuntimeError, RuntimeOptions, RuntimePool,
};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(
        std::env::var("SPARSESWAPS_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".into()));
    dir.join("manifest.json").exists().then_some(dir)
}

/// A pool plus the swap-artifact impl tag its manifest carries ("xla"
/// for real AOT artifacts, "interp" for the in-memory manifest).
struct Harness {
    pool: RuntimePool,
    impl_name: &'static str,
}

impl Harness {
    fn refiner(&self) -> Refiner {
        Refiner::SparseSwapsOffload { impl_name: self.impl_name.into() }
    }
}

fn harness_with(devices: usize) -> Harness {
    match artifacts_dir() {
        Some(dir) => Harness {
            pool: RuntimePool::start(&dir, devices,
                                     RuntimeOptions::default())
                .unwrap(),
            impl_name: "xla",
        },
        None => Harness {
            pool: interp_pool(&tiny_manifest(), devices,
                              RuntimeOptions::default()),
            impl_name: "interp",
        },
    }
}

/// Two-device pool: serial stages use the primary worker (the handle
/// derefs to it), offload refinement fans out across both.
fn harness() -> Harness {
    harness_with(2)
}

/// One-off prune through a fresh session with default run options —
/// the common case here; tests that tweak `RunOptions` (shard sizes)
/// build their own `PruneSession`.
fn prune(pool: &RuntimePool, store: &ParamStore, ds: &Dataset,
         spec: &MaskSpec)
    -> Result<(MaskSet, PruneReport), RuntimeError> {
    PruneSession::new(pool, store, ds, RunOptions::default())
        .prune(spec)
}

/// Train the tiny model once per process (training is deterministic,
/// so every test sees the same weights) and assert the loss went
/// down.  The dataset is rebuilt per call — it is cheap relative to
/// training and not `Clone`.
fn trained_tiny(rt: &Runtime) -> (ParamStore, Dataset) {
    static TRAINED: OnceLock<ParamStore> = OnceLock::new();
    let meta = rt.manifest().config("tiny").unwrap().clone();
    let ds = Dataset::build(&meta, 42);
    let store = TRAINED.get_or_init(|| {
        let mut store = ParamStore::init(&meta, meta.init_seed);
        let cfg = TrainConfig { steps: 60, lr: 2e-3, n_batches: 12,
                                log_every: 50 };
        let report = train(rt, &mut store, &ds, &cfg).unwrap();
        assert!(report.final_loss < report.initial_loss,
                "training must reduce loss: {} -> {}",
                report.initial_loss, report.final_loss);
        store
    }).clone();
    (store, ds)
}

#[test]
fn train_prune_eval_full_cycle() {
    let h = harness();
    let rt = &h.pool;
    let (store, ds) = trained_tiny(rt);
    let meta = store.meta.clone();

    // Dense perplexity.
    let val = ds.batches(&meta, Split::Validation, 4);
    let ppl_dense = perplexity(rt, &store, &val).unwrap();
    assert!(ppl_dense.is_finite() && ppl_dense > 1.0);

    // Wanda warmstart at 50%, no refinement.
    let cfg_wanda = MaskSpec {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
        refiner: Refiner::None,
        calib_batches: 4,
        sequential: true,
        ..Default::default()
    };
    let (masks_w, report_w) = prune(rt, &store, &ds, &cfg_wanda).unwrap();
    let ppl_wanda = perplexity(rt, &store.masked(&masks_w), &val).unwrap();

    // Same warmstart + SparseSwaps refinement.
    let cfg_ss = MaskSpec {
        refiner: h.refiner(),
        t_max: 25,
        ..cfg_wanda.clone()
    };
    let (masks_s, report_s) = prune(rt, &store, &ds, &cfg_ss).unwrap();
    let ppl_ss = perplexity(rt, &store.masked(&masks_s), &val).unwrap();

    // Refined local error never exceeds the Wanda warmstart,
    // layer-by-layer (the paper's monotone 1-swap descent).
    assert_eq!(report_s.layers.len(), meta.prunable.len());
    for l in &report_s.layers {
        assert!(l.loss_refined <= l.loss_warmstart * 1.0001 + 1e-6,
                "{}: {} -> {}", l.name, l.loss_warmstart, l.loss_refined);
    }
    let red = report_s.mean_relative_reduction();
    assert!(red > 0.05, "mean relative reduction {red}");

    // Masks achieve the requested sparsity.
    let sp = masks_s.overall_sparsity();
    assert!((sp - 0.5).abs() < 0.02, "sparsity {sp}");

    // Pruning hurts vs dense; refinement must not catastrophically
    // degrade vs warmstart (Table 3 shows parity at 50%; we allow a
    // generous band rather than asserting strict improvement).
    assert!(ppl_wanda > ppl_dense * 0.99);
    assert!(ppl_ss < ppl_wanda * 1.25,
            "refined ppl {ppl_ss} way above warmstart {ppl_wanda}");

    // Sanity on the unrefined report: warmstart == refined loss.
    for l in &report_w.layers {
        assert_eq!(l.loss_warmstart, l.loss_refined);
    }

    // Machine-readable summary for the CI artifact (next to the
    // kernel bench report).
    let summary = format!(
        "{{\n  \"backend\": \"{}\",\n  \"ppl_dense\": {ppl_dense},\n  \
         \"ppl_wanda\": {ppl_wanda},\n  \"ppl_sparseswaps\": {ppl_ss},\n  \
         \"mean_relative_reduction\": {red},\n  \"sparsity\": {sp}\n}}\n",
        h.impl_name);
    if std::fs::create_dir_all("reports").is_ok() {
        let _ = std::fs::write("reports/e2e_summary.json", summary);
    }
}

#[test]
fn magnitude_warmstart_benefits_more() {
    // Table 2 / Table 4 shape: weaker warmstarts see larger relative
    // error reductions from SparseSwaps.
    let h = harness();
    let (store, ds) = trained_tiny(&h.pool);
    let base = MaskSpec {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
        refiner: h.refiner(),
        t_max: 25,
        calib_batches: 4,
        ..Default::default()
    };
    let cfg_mag = MaskSpec {
        criterion: sparseswaps::pruning::Criterion::Magnitude,
        ..base.clone()
    };
    let cfg_wanda = MaskSpec {
        criterion: sparseswaps::pruning::Criterion::Wanda,
        ..base
    };
    let (_, rep_mag) = prune(&h.pool, &store, &ds, &cfg_mag).unwrap();
    let (_, rep_wanda) = prune(&h.pool, &store, &ds, &cfg_wanda).unwrap();
    let red_mag = rep_mag.mean_relative_reduction();
    let red_wanda = rep_wanda.mean_relative_reduction();
    assert!(red_mag > red_wanda * 0.8,
            "magnitude reduction {red_mag} should be >= wanda-ish \
             {red_wanda}");
    // And magnitude's absolute warmstart loss is worse than Wanda's.
    assert!(rep_mag.total_warmstart_loss()
            > rep_wanda.total_warmstart_loss());
}

#[test]
fn nm_pattern_end_to_end() {
    let h = harness();
    let (store, ds) = trained_tiny(&h.pool);
    let cfg = MaskSpec {
        pattern_kind: PatternKind::Nm { n: 2, m: 4 },
        refiner: h.refiner(),
        t_max: 10,
        calib_batches: 3,
        ..Default::default()
    };
    let (masks, report) = prune(&h.pool, &store, &ds, &cfg).unwrap();
    let sp = masks.overall_sparsity();
    assert!((sp - 0.5).abs() < 1e-6, "2:4 must be exactly 50%: {sp}");
    assert!(report.mean_relative_reduction() > 0.0);
}

#[test]
fn dsnot_baseline_runs_and_preserves_pattern() {
    let h = harness();
    let (store, ds) = trained_tiny(&h.pool);
    let cfg = MaskSpec {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
        refiner: Refiner::Dsnot,
        calib_batches: 3,
        ..Default::default()
    };
    let (masks, report) = prune(&h.pool, &store, &ds, &cfg).unwrap();
    assert!((masks.overall_sparsity() - 0.6).abs() < 0.02);
    assert_eq!(report.layers.len(), store.meta.prunable.len());
}

#[test]
fn native_and_offload_engines_agree() {
    let h = harness();
    let (store, ds) = trained_tiny(&h.pool);
    let base = MaskSpec {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
        t_max: 10,
        calib_batches: 3,
        sequential: false, // same grams for both runs
        ..Default::default()
    };
    let cfg_off = MaskSpec {
        refiner: h.refiner(),
        ..base.clone()
    };
    let cfg_nat = MaskSpec {
        refiner: Refiner::SparseSwapsNative,
        ..base
    };
    let (_, rep_off) = prune(&h.pool, &store, &ds, &cfg_off).unwrap();
    let (_, rep_nat) = prune(&h.pool, &store, &ds, &cfg_nat).unwrap();
    for (a, b) in rep_off.layers.iter().zip(&rep_nat.layers) {
        assert_eq!(a.name, b.name);
        // The engines evaluate the identical objective but in different
        // precisions (f32 offload reporting vs f64 native), so
        // near-zero dL values can cross the strict-decrease threshold
        // differently; allow a small relative loss band and a small
        // swap-count slack per layer.
        let rel = (a.loss_refined - b.loss_refined).abs()
            / b.loss_refined.abs().max(1e-6);
        assert!(rel < 2e-2, "{}: offload {} vs native {}", a.name,
                a.loss_refined, b.loss_refined);
        // Swap *counts* are trajectory-dependent (different tie-breaking
        // explores different local optima basins), so only require the
        // same order of magnitude of work.
        let (lo, hi) = (b.swaps.min(a.swaps), b.swaps.max(a.swaps));
        assert!(hi as f64 <= lo as f64 * 1.5 + 8.0,
                "{}: swap counts differ too much: {} vs {}",
                a.name, a.swaps, b.swaps);
    }
}

#[test]
fn pooled_offload_masks_match_single_device() {
    // The runtime-pool acceptance property: layer fan-out across
    // devices must be bit-invisible in the masks (interp or PJRT).
    let h1 = harness_with(1);
    let h4 = harness_with(4);
    let (store, ds) = trained_tiny(&h1.pool);
    let cfg = MaskSpec {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
        refiner: h1.refiner(),
        t_max: 10,
        calib_batches: 3,
        sequential: false,
        ..Default::default()
    };
    let (m1, _) = prune(&h1.pool, &store, &ds, &cfg).unwrap();
    let (m4, _) = prune(&h4.pool, &store, &ds, &cfg).unwrap();
    for (a, b) in m1.masks.iter().zip(&m4.masks) {
        assert_eq!(a.data, b.data,
                   "pooled offload masks must be bit-identical to the \
                    single-device schedule");
    }
}

#[test]
fn sharded_prune_matches_whole_layer_schedule() {
    // The shard-dispatch acceptance property at pipeline level:
    // masks AND checkpoint snapshots must be bit-identical between
    // whole-layer shards and a deliberately awkward shard size, on
    // both the offload and native engines.
    let h = harness();
    let (store, ds) = trained_tiny(&h.pool);
    for refiner in [h.refiner(), Refiner::SparseSwapsNative] {
        let spec = MaskSpec {
            pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
            refiner,
            t_max: 8,
            calib_batches: 2,
            sequential: false,
            checkpoints: vec![2, 8],
            ..Default::default()
        };
        // Shard size is a run option, not part of the mask spec:
        // same spec, two schedules.
        let whole = RunOptions { shard_rows: usize::MAX,
                                 ..Default::default() };
        let sharded = RunOptions { shard_rows: 3, ..Default::default() };
        let (m1, r1) = PruneSession::new(&h.pool, &store, &ds, whole)
            .prune(&spec).unwrap();
        let (m2, r2) = PruneSession::new(&h.pool, &store, &ds, sharded)
            .prune(&spec).unwrap();
        for (li, (a, b)) in m1.masks.iter().zip(&m2.masks).enumerate()
        {
            assert_eq!(a.data, b.data,
                       "layer {li}: sharded mask diverged from the \
                        whole-layer schedule");
        }
        assert_eq!(r1.snapshots.len(), r2.snapshots.len());
        for (cp, snap) in &r1.snapshots {
            let other = &r2.snapshots[cp];
            for (a, b) in snap.masks.iter().zip(&other.masks) {
                assert_eq!(a.data, b.data, "checkpoint {cp} snapshot \
                                            diverged");
            }
        }
    }
}

#[test]
fn zero_shot_scoring_runs() {
    let h = harness();
    let (store, ds) = trained_tiny(&h.pool);
    let tasks = zeroshot::build_tasks(&ds, store.meta.vocab, 24, 7);
    let acc = zeroshot::accuracy(&h.pool, &store, &tasks).unwrap();
    assert!((0.0..=1.0).contains(&acc));
    // A trained model should beat uniform chance on chain continuations
    // most of the time; keep a loose bound to avoid flakiness.
    assert!(acc >= 0.20, "accuracy {acc} below sanity floor");
}

#[test]
fn checkpoint_round_trip_through_pipeline() {
    let h = harness();
    let rt = &h.pool;
    let (store, ds) = trained_tiny(rt);
    let cfg = MaskSpec {
        refiner: h.refiner(),
        t_max: 5,
        calib_batches: 2,
        ..Default::default()
    };
    let (masks, _) = prune(rt, &store, &ds, &cfg).unwrap();
    let path = std::env::temp_dir().join("e2e_ckpt.ssck");
    checkpoint::save(&path, &store, Some(&masks)).unwrap();
    let (loaded, loaded_masks) =
        checkpoint::load(&path, &store.meta).unwrap();
    let loaded_masks = loaded_masks.unwrap();
    // Same ppl from the reloaded masked model.
    let val = ds.batches(&store.meta, Split::Validation, 2);
    let p1 = perplexity(rt, &store.masked(&masks), &val).unwrap();
    let p2 = perplexity(rt, &loaded.masked(&loaded_masks), &val).unwrap();
    assert!((p1 - p2).abs() < 1e-6);
    std::fs::remove_file(path).ok();
}

#[test]
fn table3_checkpoints_snapshot_masks() {
    let h = harness();
    let (store, ds) = trained_tiny(&h.pool);
    let cfg = MaskSpec {
        refiner: h.refiner(),
        t_max: 10,
        calib_batches: 2,
        checkpoints: vec![1, 5, 10],
        sequential: false,
        ..Default::default()
    };
    let (final_masks, report) = prune(&h.pool, &store, &ds, &cfg).unwrap();
    assert_eq!(report.snapshots.len(), 3);
    // Snapshot losses must be monotone non-increasing in iterations.
    let loss_of = |ms: &sparseswaps::model::MaskSet| -> f64 {
        ms.overall_sparsity()
    };
    for ms in report.snapshots.values() {
        assert!((loss_of(ms) - 0.6).abs() < 0.02);
    }
    // The t_max snapshot equals the final mask.
    let last = &report.snapshots[&10];
    for (a, b) in last.masks.iter().zip(&final_masks.masks) {
        assert_eq!(a.data, b.data);
    }
}
