//! End-to-end integration on the `tiny` config: train through the AOT
//! train-step artifact, calibrate, prune with Wanda, refine with
//! SparseSwaps (offload), evaluate perplexity and zero-shot accuracy.
//!
//! Requires `make artifacts`; each test no-ops otherwise.

use sparseswaps::coordinator::{
    prune, train, PatternKind, PruneConfig, Refiner, TrainConfig,
};
use sparseswaps::data::{Dataset, Split};
use sparseswaps::eval::{perplexity, zeroshot};
use sparseswaps::model::{checkpoint, ParamStore};
use sparseswaps::runtime::{Runtime, RuntimeOptions, RuntimePool};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(
        std::env::var("SPARSESWAPS_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".into()));
    dir.join("manifest.json").exists().then_some(dir)
}

/// Two-device pool: serial stages use the primary worker (the handle
/// derefs to it), offload refinement fans out across both.
fn runtime() -> Option<RuntimePool> {
    artifacts_dir().map(|dir| {
        RuntimePool::start(&dir, 2, RuntimeOptions::default()).unwrap()
    })
}

fn trained_tiny(rt: &Runtime) -> (ParamStore, Dataset) {
    let meta = rt.manifest().config("tiny").unwrap().clone();
    let ds = Dataset::build(&meta, 42);
    let mut store = ParamStore::init(&meta, meta.init_seed);
    let cfg = TrainConfig { steps: 60, lr: 2e-3, n_batches: 12,
                            log_every: 50 };
    let report = train(rt, &mut store, &ds, &cfg).unwrap();
    assert!(report.final_loss < report.initial_loss,
            "training must reduce loss: {} -> {}",
            report.initial_loss, report.final_loss);
    (store, ds)
}

#[test]
fn train_prune_eval_full_cycle() {
    let Some(rt) = runtime() else { return };
    let (store, ds) = trained_tiny(&rt);
    let meta = store.meta.clone();

    // Dense perplexity.
    let val = ds.batches(&meta, Split::Validation, 4);
    let ppl_dense = perplexity(&rt, &store, &val).unwrap();
    assert!(ppl_dense.is_finite() && ppl_dense > 1.0);

    // Wanda warmstart at 50%, no refinement.
    let cfg_wanda = PruneConfig {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
        refiner: Refiner::None,
        calib_batches: 4,
        sequential: true,
        ..Default::default()
    };
    let (masks_w, report_w) = prune(&rt, &store, &ds, &cfg_wanda).unwrap();
    let ppl_wanda = perplexity(&rt, &store.masked(&masks_w), &val).unwrap();

    // Same warmstart + SparseSwaps refinement.
    let cfg_ss = PruneConfig {
        refiner: Refiner::SparseSwapsOffload { impl_name: "xla".into() },
        t_max: 25,
        ..cfg_wanda.clone()
    };
    let (masks_s, report_s) = prune(&rt, &store, &ds, &cfg_ss).unwrap();
    let ppl_ss = perplexity(&rt, &store.masked(&masks_s), &val).unwrap();

    // Local error strictly improves layer-by-layer.
    assert_eq!(report_s.layers.len(), meta.prunable.len());
    for l in &report_s.layers {
        assert!(l.loss_refined <= l.loss_warmstart * 1.0001 + 1e-6,
                "{}: {} -> {}", l.name, l.loss_warmstart, l.loss_refined);
    }
    let red = report_s.mean_relative_reduction();
    assert!(red > 0.05, "mean relative reduction {red}");

    // Masks achieve the requested sparsity.
    let sp = masks_s.overall_sparsity();
    assert!((sp - 0.5).abs() < 0.02, "sparsity {sp}");

    // Pruning hurts vs dense; refinement must not catastrophically
    // degrade vs warmstart (Table 3 shows parity at 50%; we allow a
    // generous band rather than asserting strict improvement).
    assert!(ppl_wanda > ppl_dense * 0.99);
    assert!(ppl_ss < ppl_wanda * 1.25,
            "refined ppl {ppl_ss} way above warmstart {ppl_wanda}");

    // Sanity on the unrefined report: warmstart == refined loss.
    for l in &report_w.layers {
        assert_eq!(l.loss_warmstart, l.loss_refined);
    }
}

#[test]
fn magnitude_warmstart_benefits_more() {
    // Table 2 / Table 4 shape: weaker warmstarts see larger relative
    // error reductions from SparseSwaps.
    let Some(rt) = runtime() else { return };
    let (store, ds) = trained_tiny(&rt);
    let base = PruneConfig {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
        refiner: Refiner::SparseSwapsOffload { impl_name: "xla".into() },
        t_max: 25,
        calib_batches: 4,
        ..Default::default()
    };
    let cfg_mag = PruneConfig {
        criterion: sparseswaps::pruning::Criterion::Magnitude,
        ..base.clone()
    };
    let cfg_wanda = PruneConfig {
        criterion: sparseswaps::pruning::Criterion::Wanda,
        ..base
    };
    let (_, rep_mag) = prune(&rt, &store, &ds, &cfg_mag).unwrap();
    let (_, rep_wanda) = prune(&rt, &store, &ds, &cfg_wanda).unwrap();
    let red_mag = rep_mag.mean_relative_reduction();
    let red_wanda = rep_wanda.mean_relative_reduction();
    assert!(red_mag > red_wanda * 0.8,
            "magnitude reduction {red_mag} should be >= wanda-ish \
             {red_wanda}");
    // And magnitude's absolute warmstart loss is worse than Wanda's.
    assert!(rep_mag.total_warmstart_loss()
            > rep_wanda.total_warmstart_loss());
}

#[test]
fn nm_pattern_end_to_end() {
    let Some(rt) = runtime() else { return };
    let (store, ds) = trained_tiny(&rt);
    let cfg = PruneConfig {
        pattern_kind: PatternKind::Nm { n: 2, m: 4 },
        refiner: Refiner::SparseSwapsOffload { impl_name: "xla".into() },
        t_max: 10,
        calib_batches: 3,
        ..Default::default()
    };
    let (masks, report) = prune(&rt, &store, &ds, &cfg).unwrap();
    let sp = masks.overall_sparsity();
    assert!((sp - 0.5).abs() < 1e-6, "2:4 must be exactly 50%: {sp}");
    assert!(report.mean_relative_reduction() > 0.0);
}

#[test]
fn dsnot_baseline_runs_and_preserves_pattern() {
    let Some(rt) = runtime() else { return };
    let (store, ds) = trained_tiny(&rt);
    let cfg = PruneConfig {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
        refiner: Refiner::Dsnot,
        calib_batches: 3,
        ..Default::default()
    };
    let (masks, report) = prune(&rt, &store, &ds, &cfg).unwrap();
    assert!((masks.overall_sparsity() - 0.6).abs() < 0.02);
    assert_eq!(report.layers.len(), store.meta.prunable.len());
}

#[test]
fn native_and_offload_engines_agree() {
    let Some(rt) = runtime() else { return };
    let (store, ds) = trained_tiny(&rt);
    let base = PruneConfig {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
        t_max: 10,
        calib_batches: 3,
        sequential: false, // same grams for both runs
        ..Default::default()
    };
    let cfg_off = PruneConfig {
        refiner: Refiner::SparseSwapsOffload { impl_name: "xla".into() },
        ..base.clone()
    };
    let cfg_nat = PruneConfig {
        refiner: Refiner::SparseSwapsNative,
        ..base
    };
    let (_, rep_off) = prune(&rt, &store, &ds, &cfg_off).unwrap();
    let (_, rep_nat) = prune(&rt, &store, &ds, &cfg_nat).unwrap();
    for (a, b) in rep_off.layers.iter().zip(&rep_nat.layers) {
        assert_eq!(a.name, b.name);
        // The engines evaluate the identical objective but in different
        // precisions (f32 XLA vs f64 native), so near-zero dL values can
        // cross the strict-decrease threshold differently; allow a small
        // relative loss band and a small swap-count slack per layer.
        let rel = (a.loss_refined - b.loss_refined).abs()
            / b.loss_refined.abs().max(1e-6);
        assert!(rel < 2e-2, "{}: offload {} vs native {}", a.name,
                a.loss_refined, b.loss_refined);
        // Swap *counts* are trajectory-dependent (different tie-breaking
        // explores different local optima basins), so only require the
        // same order of magnitude of work.
        let (lo, hi) = (b.swaps.min(a.swaps), b.swaps.max(a.swaps));
        assert!(hi as f64 <= lo as f64 * 1.5 + 8.0,
                "{}: swap counts differ too much: {} vs {}",
                a.name, a.swaps, b.swaps);
    }
}

#[test]
fn pooled_offload_masks_match_single_device() {
    // The runtime-pool acceptance property on real artifacts: layer
    // fan-out across devices must be bit-invisible in the masks.
    let Some(dir) = artifacts_dir() else { return };
    let rt1 = RuntimePool::start(&dir, 1, RuntimeOptions::default())
        .unwrap();
    let rt4 = RuntimePool::start(&dir, 4, RuntimeOptions::default())
        .unwrap();
    let (store, ds) = trained_tiny(&rt1);
    let cfg = PruneConfig {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
        refiner: Refiner::SparseSwapsOffload { impl_name: "xla".into() },
        t_max: 10,
        calib_batches: 3,
        sequential: false,
        ..Default::default()
    };
    let (m1, _) = prune(&rt1, &store, &ds, &cfg).unwrap();
    let (m4, _) = prune(&rt4, &store, &ds, &cfg).unwrap();
    for (a, b) in m1.masks.iter().zip(&m4.masks) {
        assert_eq!(a.data, b.data,
                   "pooled offload masks must be bit-identical to the \
                    single-device schedule");
    }
}

#[test]
fn zero_shot_scoring_runs() {
    let Some(rt) = runtime() else { return };
    let (store, ds) = trained_tiny(&rt);
    let tasks = zeroshot::build_tasks(&ds, store.meta.vocab, 24, 7);
    let acc = zeroshot::accuracy(&rt, &store, &tasks).unwrap();
    assert!((0.0..=1.0).contains(&acc));
    // A trained model should beat uniform chance on chain continuations
    // most of the time; keep a loose bound to avoid flakiness.
    assert!(acc >= 0.20, "accuracy {acc} below sanity floor");
}

#[test]
fn checkpoint_round_trip_through_pipeline() {
    let Some(rt) = runtime() else { return };
    let (store, ds) = trained_tiny(&rt);
    let cfg = PruneConfig {
        refiner: Refiner::SparseSwapsOffload { impl_name: "xla".into() },
        t_max: 5,
        calib_batches: 2,
        ..Default::default()
    };
    let (masks, _) = prune(&rt, &store, &ds, &cfg).unwrap();
    let path = std::env::temp_dir().join("e2e_ckpt.ssck");
    checkpoint::save(&path, &store, Some(&masks)).unwrap();
    let (loaded, loaded_masks) =
        checkpoint::load(&path, &store.meta).unwrap();
    let loaded_masks = loaded_masks.unwrap();
    // Same ppl from the reloaded masked model.
    let val = ds.batches(&store.meta, Split::Validation, 2);
    let p1 = perplexity(&rt, &store.masked(&masks), &val).unwrap();
    let p2 = perplexity(&rt, &loaded.masked(&loaded_masks), &val).unwrap();
    assert!((p1 - p2).abs() < 1e-6);
    std::fs::remove_file(path).ok();
}

#[test]
fn table3_checkpoints_snapshot_masks() {
    let Some(rt) = runtime() else { return };
    let (store, ds) = trained_tiny(&rt);
    let cfg = PruneConfig {
        refiner: Refiner::SparseSwapsOffload { impl_name: "xla".into() },
        t_max: 10,
        calib_batches: 2,
        checkpoints: vec![1, 5, 10],
        sequential: false,
        ..Default::default()
    };
    let (final_masks, report) = prune(&rt, &store, &ds, &cfg).unwrap();
    assert_eq!(report.snapshots.len(), 3);
    // Snapshot losses must be monotone non-increasing in iterations.
    let loss_of = |ms: &sparseswaps::model::MaskSet| -> f64 {
        ms.overall_sparsity()
    };
    for ms in report.snapshots.values() {
        assert!((loss_of(ms) - 0.6).abs() < 0.02);
    }
    // The t_max snapshot equals the final mask.
    let last = &report.snapshots[&10];
    for (a, b) in last.masks.iter().zip(&final_masks.masks) {
        assert_eq!(a.data, b.data);
    }
}
