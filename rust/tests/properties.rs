//! Property tests for the Algorithm-1 invariants (DESIGN.md §8), run on
//! the in-repo property harness (`util::proptest`) over randomized
//! instances.  No artifacts required — these exercise the native engine
//! and the shared math.

use sparseswaps::pruning::engine::{LayerContext, RefineEngine};
use sparseswaps::pruning::error::{corr_vector, layer_loss, row_loss};
use sparseswaps::pruning::exact::optimal_row_mask;
use sparseswaps::pruning::mask::{
    achieved_sparsity, apply_mask, mask_from_scores, validate, Pattern,
};
use sparseswaps::pruning::saliency;
use sparseswaps::pruning::sparseswaps::{
    best_swap, refine_layer, refine_layer_rescan, refine_row,
    NativeEngine, SwapConfig,
};
use sparseswaps::util::kernels::{self, Arm};
use sparseswaps::util::proptest::{check, ensure, Gen};
use sparseswaps::util::tensor::Matrix;

struct Instance {
    w: Matrix,
    g: Matrix,
    pattern: Pattern,
}

fn random_instance(gen: &mut Gen, nm_allowed: bool) -> Instance {
    let d = *gen.choose(&[8usize, 12, 16, 24, 32]);
    let rows = gen.usize_in(1, 6);
    let t = gen.usize_in(d, 4 * d);
    let x = Matrix::from_fn(t, d, |_, _| gen.rng.gaussian_f32());
    let mut g = Matrix::zeros(d, d);
    g.gram_accumulate(&x);
    let w = Matrix::from_fn(rows, d, |_, _| gen.rng.gaussian_f32());
    let pattern = if nm_allowed && d % 4 == 0 && gen.rng.bool(0.4) {
        Pattern::Nm { n: 2, m: 4 }
    } else {
        let keep = gen.usize_in(1, d - 1);
        Pattern::PerRow { keep }
    };
    Instance { w, g, pattern }
}

fn warmstart(gen: &mut Gen, inst: &Instance) -> Matrix {
    let crit = *gen.choose(&[saliency::Criterion::Magnitude,
                             saliency::Criterion::Wanda,
                             saliency::Criterion::Ria]);
    let scores = saliency::scores(crit, &inst.w, &inst.g.diag());
    mask_from_scores(&scores, inst.pattern)
}

#[test]
fn prop_loss_never_increases() {
    // (i) every accepted swap strictly decreases the per-row loss.
    check("loss monotone", 120, |gen| {
        let inst = random_instance(gen, true);
        let mut mask = warmstart(gen, &inst);
        let before = layer_loss(&inst.w, &mask, &inst.g);
        let t_max = gen.usize_in(1, 50);
        refine_layer(&inst.w, &mut mask, &inst.g, inst.pattern,
                     &SwapConfig { t_max, eps: 0.0 }, 1);
        let after = layer_loss(&inst.w, &mask, &inst.g);
        ensure(after <= before * (1.0 + 1e-5) + 1e-4,
               || format!("{before} -> {after}"))
    });
}

#[test]
fn prop_sparsity_pattern_preserved() {
    // (ii) per-row counts / N:M block counts survive any refinement.
    check("pattern preserved", 120, |gen| {
        let inst = random_instance(gen, true);
        let mut mask = warmstart(gen, &inst);
        refine_layer(&inst.w, &mut mask, &inst.g, inst.pattern,
                     &SwapConfig { t_max: 30, eps: 0.0 }, 1);
        validate(&mask, inst.pattern).map_err(|e| e)
    });
}

#[test]
fn prop_corr_vector_consistent_after_swaps() {
    // (iii) the Eq.-6 incremental update of c equals recomputation.
    check("corr consistency", 80, |gen| {
        let inst = random_instance(gen, false);
        let w = inst.w.row(0);
        let mut m: Vec<f32> = warmstart(gen, &inst).row(0).to_vec();
        let mut c = corr_vector(w, &m, &inst.g);
        for _ in 0..10 {
            let Some((dl, u, p)) = best_swap(w, &m, &c, &inst.g, 0)
                else { break };
            if dl >= 0.0 {
                break;
            }
            m[u] = 0.0;
            m[p] = 1.0;
            // Incremental Eq. 6 update...
            for i in 0..w.len() {
                c[i] += w[u] * inst.g.at(i, u) - w[p] * inst.g.at(i, p);
            }
            // ...must match recomputation from scratch.
            let fresh = corr_vector(w, &m, &inst.g);
            for i in 0..c.len() {
                let scale = fresh[i].abs().max(1.0);
                if (c[i] - fresh[i]).abs() / scale > 1e-3 {
                    return Err(format!(
                        "c[{i}] drifted: {} vs {}", c[i], fresh[i]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_termination_bound() {
    // (iv) Prop A.2: at most ceil(L0/eps) swaps with tolerance eps.
    check("termination bound", 60, |gen| {
        let inst = random_instance(gen, false);
        let mut mask = warmstart(gen, &inst);
        for r in 0..inst.w.rows {
            let l0 = row_loss(inst.w.row(r), mask.row(r), &inst.g);
            if l0 <= 0.0 {
                continue;
            }
            let eps = l0 / (gen.usize_in(2, 40) as f64);
            let mut mrow = mask.row_mut(r).to_vec();
            let out = refine_row(inst.w.row(r), &mut mrow, &inst.g, 0,
                                 &SwapConfig { t_max: 100_000, eps });
            let bound = (l0 / eps).ceil() as usize;
            if out.swaps > bound {
                return Err(format!("{} swaps > bound {}", out.swaps,
                                   bound));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_terminal_is_local_optimum() {
    // (v) at convergence no single feasible swap improves the loss.
    check("local optimum", 40, |gen| {
        let inst = random_instance(gen, true);
        let mut mask = warmstart(gen, &inst);
        let nm = inst.pattern.nm_block();
        let out = refine_layer(&inst.w, &mut mask, &inst.g, inst.pattern,
                               &SwapConfig { t_max: 100_000, eps: 0.0 },
                               1);
        for (r, row_out) in out.rows.iter().enumerate() {
            ensure(row_out.converged, || format!("row {r} not converged"))?;
            let w = inst.w.row(r);
            let base = row_loss(w, mask.row(r), &inst.g);
            let d = w.len();
            for u in 0..d {
                for p in 0..d {
                    let feasible = mask.at(r, u) == 1.0
                        && mask.at(r, p) == 0.0
                        && (nm == 0 || u / nm == p / nm);
                    if feasible {
                        let mut m2 = mask.row(r).to_vec();
                        m2[u] = 0.0;
                        m2[p] = 1.0;
                        let l2 = row_loss(w, &m2, &inst.g);
                        if l2 < base - 1e-2 - 1e-5 * base.abs() {
                            return Err(format!(
                                "row {r} swap ({u},{p}) improves \
                                 {base} -> {l2}"));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_exact_optimum_sandwich() {
    // (vii) brute-force optimum <= SparseSwaps result <= warmstart.
    check("optimum sandwich", 30, |gen| {
        let d = *gen.choose(&[8usize, 10, 12, 14]);
        let t = gen.usize_in(d, 3 * d);
        let x = Matrix::from_fn(t, d, |_, _| gen.rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let w: Vec<f32> = (0..d).map(|_| gen.rng.gaussian_f32()).collect();
        let keep = gen.usize_in(1, d - 1);
        let wm = Matrix::from_vec(1, d, w.clone());
        let scores = saliency::wanda(&wm, &g.diag());
        let mask = mask_from_scores(&scores, Pattern::PerRow { keep });
        let warm = row_loss(&w, mask.row(0), &g);
        let mut mrow = mask.row(0).to_vec();
        let out = refine_row(&w, &mut mrow, &g, 0,
                             &SwapConfig { t_max: 100_000, eps: 0.0 });
        let (_, opt) = optimal_row_mask(&w, &g, keep);
        ensure(out.loss_after <= warm * (1.0 + 1e-5) + 1e-4,
               || format!("refined {} > warmstart {warm}",
                          out.loss_after))?;
        ensure(opt <= out.loss_after * (1.0 + 1e-4) + 1e-3,
               || format!("optimum {opt} > refined {}", out.loss_after))
    });
}

#[test]
fn prop_incremental_engine_matches_rescan_reference() {
    // (viii) the incremental active-set native engine is bit-identical
    // to the from-scratch rescan loop: same masks, same swap counts,
    // for both PerRow and Nm patterns, across 1/4 thread counts.
    check("incremental active-set parity", 80, |gen| {
        let inst = random_instance(gen, true);
        let warm = warmstart(gen, &inst);
        let t_max = gen.usize_in(1, 40);
        let cfg = SwapConfig { t_max, eps: 0.0 };
        let mut m_ref = warm.clone();
        let out_ref = refine_layer_rescan(&inst.w, &mut m_ref, &inst.g,
                                          inst.pattern, &cfg, 1);
        for threads in [1usize, 4] {
            let mut m = warm.clone();
            let out = refine_layer(&inst.w, &mut m, &inst.g,
                                   inst.pattern, &cfg, threads);
            ensure(m.data == m_ref.data,
                   || format!("mask mismatch vs rescan at {threads} \
                               threads (t_max {t_max}, pattern \
                               {:?})", inst.pattern))?;
            ensure(out.total_swaps() == out_ref.total_swaps(),
                   || format!("swap count {} vs reference {}",
                              out.total_swaps(), out_ref.total_swaps()))?;
            let rel = (out.total_after() - out_ref.total_after()).abs()
                / out_ref.total_after().abs().max(1e-9);
            ensure(rel < 1e-9,
                   || format!("loss {} vs reference {}",
                              out.total_after(), out_ref.total_after()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_checkpoint_segmentation_is_exact() {
    // (ix) the shared checkpoint driver cannot change the result: the
    // native engine's row state persists across segment boundaries, so
    // a checkpointed run lands on the same final mask as a plain run,
    // and every in-range checkpoint snapshot is a valid mask.
    check("checkpoint segmentation exact", 40, |gen| {
        let inst = random_instance(gen, true);
        let warm = warmstart(gen, &inst);
        let t_max = gen.usize_in(2, 30);
        let cps = vec![gen.usize_in(1, t_max), gen.usize_in(1, t_max),
                       t_max + gen.usize_in(1, 10)];
        let ctx = LayerContext {
            w: inst.w.view(), g: inst.g.as_gram(), stats: None,
            pattern: inst.pattern, t_max, threads: 1,
            gmax: None,
        };
        let mut plain = warm.clone();
        NativeEngine::default().refine(&ctx, &mut plain, &[])
            .map_err(|e| e.to_string())?;
        let mut segmented = warm.clone();
        let out = NativeEngine::default()
            .refine(&ctx, &mut segmented, &cps)
            .map_err(|e| e.to_string())?;
        ensure(plain.data == segmented.data,
               || format!("segmented mask diverged (t_max {t_max}, \
                           checkpoints {cps:?})"))?;
        for &cp in &cps {
            if cp <= t_max {
                let snap = out.snapshots.get(&cp).ok_or_else(
                    || format!("checkpoint {cp} missing"))?;
                validate(snap, inst.pattern)?;
            } else {
                ensure(!out.snapshots.contains_key(&cp),
                       || format!("out-of-range checkpoint {cp} \
                                   captured"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_syrk_exactly_symmetric() {
    // (x) the kernel-layer rank-k update computes the upper triangle
    // and mirrors it: results are bit-exactly symmetric on every arm,
    // thread count, and ragged (non-lane-multiple) dimension, and
    // match the explicit X^T X product.
    check("syrk symmetry", 40, |gen| {
        let d = gen.usize_in(1, 41);
        let t = gen.usize_in(1, 3 * d);
        let x = Matrix::from_fn(t, d, |_, _| gen.rng.gaussian_f32());
        let want = x.transpose().matmul(&x);
        for arm in kernels::arms() {
            for threads in [1usize, 3] {
                let mut g = Matrix::zeros(d, d);
                kernels::syrk_arm(arm, &mut g, &x, threads);
                for i in 0..d {
                    for j in 0..i {
                        if g.at(i, j).to_bits() != g.at(j, i).to_bits() {
                            return Err(format!(
                                "asymmetric at ({i},{j}), arm {arm:?}, \
                                 {threads} threads"));
                        }
                    }
                }
                let scale = want.data.iter()
                    .map(|v| v.abs())
                    .fold(1.0f32, f32::max);
                ensure(g.max_abs_diff(&want) <= 1e-3 * scale,
                       || format!("syrk diverged from X^T X (d={d}, \
                                   t={t}, arm {arm:?})"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kernels_scalar_simd_parity() {
    // (xi) scalar-vs-SIMD parity for dot/axpy/axpy_dot/matmul/gram on
    // ragged sizes: axpy (and axpy_dot's update half) bit-identical,
    // reductions within relative 1e-4.
    if !kernels::simd_available() {
        return;
    }
    check("kernel arm parity", 60, |gen| {
        let n = gen.usize_in(1, 300);
        let a = gen.vec_gaussian(n, 1.0);
        let b = gen.vec_gaussian(n, 1.0);
        let ds = kernels::dot_arm(Arm::Scalar, &a, &b) as f64;
        let dv = kernels::dot_arm(Arm::Simd, &a, &b) as f64;
        ensure((ds - dv).abs() <= 1e-4 * ds.abs().max(1.0),
               || format!("dot parity n={n}: {ds} vs {dv}"))?;

        let alpha = gen.f32_in(-2.0, 2.0);
        let mut ys = b.clone();
        let mut yv = b.clone();
        kernels::axpy_arm(Arm::Scalar, alpha, &a, &mut ys);
        kernels::axpy_arm(Arm::Simd, alpha, &a, &mut yv);
        for i in 0..n {
            if ys[i].to_bits() != yv[i].to_bits() {
                return Err(format!("axpy not bit-identical at {i}"));
            }
        }

        let mut zs = b.clone();
        let mut zv = b.clone();
        let rs = kernels::axpy_dot_arm(Arm::Scalar, alpha, &a, &mut zs)
            as f64;
        let rv = kernels::axpy_dot_arm(Arm::Simd, alpha, &a, &mut zv)
            as f64;
        for i in 0..n {
            if zs[i].to_bits() != zv[i].to_bits() {
                return Err(format!("axpy_dot update not bit-identical \
                                    at {i}"));
            }
        }
        ensure((rs - rv).abs() <= 1e-4 * rs.abs().max(1.0),
               || format!("axpy_dot readback parity: {rs} vs {rv}"))?;

        let (rows, inner, cols) =
            (gen.usize_in(1, 12), gen.usize_in(1, 40), gen.usize_in(1, 12));
        let am = Matrix::from_fn(rows, inner, |_, _| gen.rng.gaussian_f32());
        let bm = Matrix::from_fn(inner, cols, |_, _| gen.rng.gaussian_f32());
        let ms = kernels::matmul_arm(Arm::Scalar, &am, &bm);
        let mv = kernels::matmul_arm(Arm::Simd, &am, &bm);
        let scale = ms.data.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
        ensure(ms.max_abs_diff(&mv) <= 1e-4 * scale.max(1.0),
               || format!("matmul parity ({rows}x{inner}x{cols})"))?;

        let d = gen.usize_in(1, 30);
        let t = gen.usize_in(1, 2 * d);
        let x = Matrix::from_fn(t, d, |_, _| gen.rng.gaussian_f32());
        let mut gs = Matrix::zeros(d, d);
        kernels::syrk_arm(Arm::Scalar, &mut gs, &x, 1);
        let mut gv = Matrix::zeros(d, d);
        kernels::syrk_arm(Arm::Simd, &mut gv, &x, 1);
        let gscale = gs.data.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
        ensure(gs.max_abs_diff(&gv) <= 1e-4 * gscale.max(1.0),
               || format!("gram parity (t={t}, d={d})"))
    });
}

#[test]
fn prop_engine_masks_identical_across_arms() {
    // (xii) the property-test oracle of the kernel layer: refining the
    // same instance on the scalar and SIMD arms produces *identical*
    // masks and swap counts (the Eq.-6 state is elementwise, the pair
    // scan evaluates identical f64 values), and losses agree within
    // relative 1e-4.
    if !kernels::simd_available() {
        return;
    }
    check("engine arm parity", 40, |gen| {
        let inst = random_instance(gen, true);
        let warm = warmstart(gen, &inst);
        let t_max = gen.usize_in(1, 30);
        let mut results: Vec<(Vec<f32>, usize, f64)> = Vec::new();
        for arm in [Arm::Scalar, Arm::Simd] {
            let engine = NativeEngine { eps: 0.0, arm: Some(arm) };
            let ctx = LayerContext {
                w: inst.w.view(), g: inst.g.as_gram(), stats: None,
                pattern: inst.pattern, t_max, threads: 1,
                gmax: None,
            };
            let mut mask = warm.clone();
            let out = engine.refine(&ctx, &mut mask, &[])
                .map_err(|e| e.to_string())?;
            results.push((mask.data, out.layer.total_swaps(),
                          out.layer.total_after()));
        }
        ensure(results[0].0 == results[1].0,
               || format!("masks diverged across arms (t_max {t_max}, \
                           pattern {:?})", inst.pattern))?;
        ensure(results[0].1 == results[1].1,
               || format!("swap counts diverged: {} vs {}",
                          results[0].1, results[1].1))?;
        let (l0, l1) = (results[0].2, results[1].2);
        ensure((l0 - l1).abs() <= 1e-4 * l0.abs().max(1.0),
               || format!("losses diverged: {l0} vs {l1}"))
    });
}

#[test]
fn prop_block_skip_bound_never_skips_argmin() {
    // (xiii) the per-block active-set skip bound is conservative: on
    // N:M patterns (where it newly applies) the incremental engine
    // still lands on the rescan loop's exact masks and swap counts —
    // i.e. no true argmin pair was ever skipped.
    check("per-block skip bound", 60, |gen| {
        let m = *gen.choose(&[4usize, 8]);
        let blocks = gen.usize_in(2, 6);
        let d = m * blocks;
        let keep_n = gen.usize_in(1, m - 1);
        let pattern = Pattern::Nm { n: keep_n, m };
        let t = gen.usize_in(d, 2 * d);
        let x = Matrix::from_fn(t, d, |_, _| gen.rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let rows = gen.usize_in(1, 4);
        let w = Matrix::from_fn(rows, d, |_, _| gen.rng.gaussian_f32());
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);
        let cfg = SwapConfig { t_max: gen.usize_in(1, 30), eps: 0.0 };
        let mut m_ref = warm.clone();
        let out_ref = refine_layer_rescan(&w, &mut m_ref, &g, pattern,
                                          &cfg, 1);
        for arm in kernels::arms() {
            let engine = NativeEngine { eps: 0.0, arm: Some(arm) };
            let ctx = LayerContext {
                w: w.view(), g: g.as_gram(), stats: None, pattern,
                t_max: cfg.t_max, threads: 1,
                gmax: None,
            };
            let mut mask = warm.clone();
            let out = engine.refine(&ctx, &mut mask, &[])
                .map_err(|e| e.to_string())?;
            ensure(mask.data == m_ref.data,
                   || format!("N:M mask diverged from rescan \
                               ({keep_n}:{m}, d={d}, arm {arm:?})"))?;
            ensure(out.layer.total_swaps() == out_ref.total_swaps(),
                   || format!("swap count {} vs rescan {}",
                              out.layer.total_swaps(),
                              out_ref.total_swaps()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_masking_matches_loss_semantics() {
    // Masked-weight semantics: pruning error of (W, M) equals the
    // distance between dense and masked layer outputs.
    check("masking semantics", 60, |gen| {
        let inst = random_instance(gen, false);
        let mask = warmstart(gen, &inst);
        let mut wm = inst.w.clone();
        apply_mask(&mut wm, &mask);
        // (W - M.W) == W - masked(W) elementwise.
        for i in 0..inst.w.rows {
            for j in 0..inst.w.cols {
                let lhs = (1.0 - mask.at(i, j)) * inst.w.at(i, j);
                let rhs = inst.w.at(i, j) - wm.at(i, j);
                if (lhs - rhs).abs() > 1e-6 {
                    return Err(format!("mismatch at ({i},{j})"));
                }
            }
        }
        ensure((0.0..=1.0).contains(&achieved_sparsity(&mask)),
               || "sparsity out of range".into())
    });
}

#[test]
fn prop_best_swap_matches_bruteforce_delta() {
    // Eq. 5 lookup == brute-force evaluation of L(m') - L(m) over all
    // feasible pairs, and best_swap returns the minimum.
    check("eq5 vs bruteforce", 60, |gen| {
        let inst = random_instance(gen, false);
        let w = inst.w.row(0);
        let m: Vec<f32> = warmstart(gen, &inst).row(0).to_vec();
        let c = corr_vector(w, &m, &inst.g);
        let base = row_loss(w, &m, &inst.g);
        let d = w.len();
        let mut best_direct: Option<f64> = None;
        for u in 0..d {
            for p in 0..d {
                if m[u] == 1.0 && m[p] == 0.0 {
                    let mut m2 = m.clone();
                    m2[u] = 0.0;
                    m2[p] = 1.0;
                    let dl = row_loss(w, &m2, &inst.g) - base;
                    if best_direct.map_or(true, |b| dl < b) {
                        best_direct = Some(dl);
                    }
                }
            }
        }
        match (best_swap(w, &m, &c, &inst.g, 0), best_direct) {
            (None, None) => Ok(()),
            (Some((dl, _, _)), Some(direct)) => {
                let scale = direct.abs().max(1.0);
                ensure((dl - direct).abs() / scale < 1e-3,
                       || format!("eq5 {dl} vs direct {direct}"))
            }
            (a, b) => Err(format!("feasibility mismatch: {a:?} vs \
                                   {}", b.is_some())),
        }
    });
}
