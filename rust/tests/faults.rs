//! Fault-tolerance integration tests: shard retry, worker quarantine,
//! offload→native degradation and journal-based resume, all driven by
//! the deterministic fault-injection harness
//! (`runtime::faults::FaultyBackend`) over interp-backed pools — no
//! real hardware faults, no flaky timing.
//!
//! The recovery invariant under test everywhere: per-row refinement
//! results are independent of *where* they ran, so any run that
//! completes — through retries, around quarantined workers, resumed
//! from a journal — must produce masks and snapshots bit-identical to
//! an undisturbed run.

use std::path::PathBuf;

use sparseswaps::coordinator::{
    MaskSpec, PatternKind, PruneReport, PruneSession, Refiner,
    RunOptions,
};
use sparseswaps::data::Dataset;
use sparseswaps::model::testutil::tiny_manifest;
use sparseswaps::model::{MaskSet, ParamStore};
use sparseswaps::pruning::RefineError;
use sparseswaps::runtime::testutil::{faulty_interp_pool, interp_pool};
use sparseswaps::runtime::{
    BufferKey, FaultPlan, RuntimeError, RuntimeOptions, RuntimePool,
};

/// Untrained tiny model + dataset (pruning is deterministic in the
/// weights; the recovery invariants do not need a trained model).
fn tiny_setup(pool: &RuntimePool) -> (ParamStore, Dataset) {
    let meta = pool.manifest().config("tiny").unwrap().clone();
    let ds = Dataset::build(&meta, 42);
    let store = ParamStore::init(&meta, meta.init_seed);
    (store, ds)
}

fn base_spec() -> MaskSpec {
    MaskSpec {
        pattern_kind: PatternKind::Unstructured { sparsity: 0.5 },
        refiner: Refiner::SparseSwapsOffload {
            impl_name: "interp".into(),
        },
        t_max: 8,
        calib_batches: 2,
        sequential: false,
        ..Default::default()
    }
}

/// One prune through a fresh session — fault runs each get their own
/// session so retry/quarantine state never leaks between arms.
fn prune(pool: &RuntimePool, store: &ParamStore, ds: &Dataset,
         spec: &MaskSpec, run: RunOptions)
    -> Result<(MaskSet, PruneReport), RuntimeError> {
    PruneSession::new(pool, store, ds, run).prune(spec)
}

fn assert_masks_eq(a: &MaskSet, b: &MaskSet, what: &str) {
    for (li, (x, y)) in a.masks.iter().zip(&b.masks).enumerate() {
        assert_eq!(x.data, y.data, "{what}: layer {li} mask diverged");
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ssfault_test_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn transiency_classification_is_exact() {
    // Only worker-tied failures may be redispatched; result-shape or
    // input errors would fail identically anywhere and must abort.
    let nr = RuntimeError::NotResident(BufferKey {
        layer: 7,
        tensor: "gram".into(),
        generation: 0,
    });
    assert!(nr.is_transient());
    assert!(RuntimeError::Transient("worker died".into())
        .is_transient());
    assert!(!RuntimeError::Msg("bad shape".into()).is_transient());
    assert!(!RuntimeError::Xla("compile failed".into()).is_transient());

    assert!(RefineError::Transient("lost reply".into()).is_transient());
    assert!(!RefineError::Msg("bad input".into()).is_transient());
    assert!(!RefineError::MissingInput("gram").is_transient());
}

#[test]
fn transient_faults_leave_masks_bit_identical() {
    // The first eligible call on each device fails (`nth=1`), plus a
    // bounded storm of random transient + NotResident faults.  Every
    // failed shard redispatches; the completed run must be
    // indistinguishable from the fault-free one in masks *and*
    // checkpoint snapshots.
    let manifest = tiny_manifest();
    let clean = interp_pool(&manifest, 2, RuntimeOptions::default());
    let plan = FaultPlan::parse(
        "seed=11;nth=1;rate=0.05;storm=0.05;max_faults=2")
        .unwrap();
    let faulty = faulty_interp_pool(&manifest, 2,
                                    RuntimeOptions::default(), &plan);
    // Keep this test about the retry path alone; quarantine has its
    // own tests below.
    faulty.set_quarantine_after(100);
    let (store, ds) = tiny_setup(&clean);
    let spec = MaskSpec {
        checkpoints: vec![2, 8],
        ..base_spec()
    };
    // Above devices x max_faults, so completion is guaranteed.
    let run = RunOptions { max_shard_retries: 8, ..Default::default() };
    let (m_clean, r_clean) =
        prune(&clean, &store, &ds, &spec, run.clone()).unwrap();
    let (m_faulty, r_faulty) =
        prune(&faulty, &store, &ds, &spec, run).unwrap();
    assert_masks_eq(&m_clean, &m_faulty, "transient-fault run");
    assert_eq!(r_clean.snapshots.len(), r_faulty.snapshots.len());
    for (cp, snap) in &r_clean.snapshots {
        assert_masks_eq(snap, &r_faulty.snapshots[cp],
                        &format!("checkpoint {cp} snapshot"));
    }
    assert!(faulty.shard_retries() >= 1,
            "fail-nth must force at least one shard retry");
    assert_eq!(faulty.workers_quarantined(), 0);
}

#[test]
fn killed_worker_is_quarantined_and_the_run_completes() {
    // Device 1's service thread panics mid-run (total worker death);
    // random transient faults ride along on the survivor.
    // `max_faults=1` keeps the survivor's failure streak below the
    // quarantine threshold, so exactly the dead worker quarantines
    // and the run finishes on device 0 with bit-identical masks.
    let manifest = tiny_manifest();
    let clean = interp_pool(&manifest, 2, RuntimeOptions::default());
    let plan = FaultPlan::parse(
        "seed=5;rate=0.05;max_faults=1;kill=1;kill_after=2")
        .unwrap();
    let faulty = faulty_interp_pool(&manifest, 2,
                                    RuntimeOptions::default(), &plan);
    let (store, ds) = tiny_setup(&clean);
    let spec = base_spec();
    let run = RunOptions { max_shard_retries: 8, ..Default::default() };
    let (m_clean, _) =
        prune(&clean, &store, &ds, &spec, run.clone()).unwrap();
    let (m_faulty, _) = prune(&faulty, &store, &ds, &spec, run).unwrap();
    assert_masks_eq(&m_clean, &m_faulty, "killed-worker run");
    assert_eq!(faulty.quarantined_workers(), vec![1]);
    assert!(faulty.shard_retries() >= 1,
            "the dying worker's shards must have been redispatched");
}

#[test]
fn all_workers_quarantined_degrades_to_native() {
    // Both device workers die on their first swap call; calibration
    // (never faulted by the default swap-kinds plan) still succeeds,
    // so the pipeline reaches refinement, quarantines everything and
    // falls back to the native host engine instead of aborting.  The
    // degraded run must equal a straight native-refiner run.
    let manifest = tiny_manifest();
    let plan = FaultPlan::parse("kill=0,1;kill_after=0").unwrap();
    let faulty = faulty_interp_pool(&manifest, 2,
                                    RuntimeOptions::default(), &plan);
    let clean = interp_pool(&manifest, 2, RuntimeOptions::default());
    let (store, ds) = tiny_setup(&clean);
    let spec = base_spec();
    let run = RunOptions { max_shard_retries: 6, ..Default::default() };
    let (m_degraded, _) =
        prune(&faulty, &store, &ds, &spec, run.clone()).unwrap();
    assert_eq!(faulty.workers_quarantined(), 2);

    let spec_native = MaskSpec {
        refiner: Refiner::SparseSwapsNative,
        ..spec
    };
    let (m_native, _) =
        prune(&clean, &store, &ds, &spec_native, run).unwrap();
    assert_masks_eq(&m_degraded, &m_native, "degraded run");
}

#[test]
fn resumed_run_reproduces_uninterrupted_masks() {
    // Sequential mode is the interesting case: block 1's
    // recalibration depends on block 0's masks, so resume must
    // restore them exactly for the remaining blocks to match.
    let manifest = tiny_manifest();
    let pool = interp_pool(&manifest, 1, RuntimeOptions::default());
    let (store, ds) = tiny_setup(&pool);
    let spec = MaskSpec {
        refiner: Refiner::SparseSwapsNative,
        sequential: true,
        t_max: 6,
        ..base_spec()
    };
    // The full run journals into the repo-relative reports dir (same
    // idiom as the e2e summary): CI uploads it as the prune-journal
    // artifact, so a real journal is inspectable per PR.
    let dir_full = PathBuf::from("reports/prune_journal");
    let run_full = RunOptions {
        journal: Some(dir_full.clone()),
        ..Default::default()
    };
    let (m_full, _) = prune(&pool, &store, &ds, &spec, run_full).unwrap();

    // "Crash" between blocks via the halt hook, then resume.  The
    // spec is untouched — interrupting and resuming are run options.
    let dir = tmp_dir("resume");
    let run_halt = RunOptions {
        journal: Some(dir.clone()),
        halt_after_block: Some(0),
        ..Default::default()
    };
    let (_, r_halt) = prune(&pool, &store, &ds, &spec, run_halt).unwrap();
    assert!(r_halt.layers.iter().all(|l| l.block == 0));

    let run_resume = RunOptions {
        journal: Some(dir.clone()),
        resume: true,
        ..Default::default()
    };
    let (m_res, r_res) =
        prune(&pool, &store, &ds, &spec, run_resume).unwrap();
    assert!(!r_res.layers.is_empty());
    assert!(r_res.layers.iter().all(|l| l.block == 1),
            "resume must skip the journaled block");
    assert_masks_eq(&m_full, &m_res, "resumed run");
    // Leave `dir_full` in place for the CI artifact upload.
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_rejects_bad_journals() {
    let manifest = tiny_manifest();
    let pool = interp_pool(&manifest, 1, RuntimeOptions::default());
    let (store, ds) = tiny_setup(&pool);
    let dir = tmp_dir("fpr");
    let spec = MaskSpec {
        refiner: Refiner::SparseSwapsNative,
        t_max: 6,
        ..base_spec()
    };
    let run_first = RunOptions {
        journal: Some(dir.clone()),
        halt_after_block: Some(0),
        ..Default::default()
    };
    prune(&pool, &store, &ds, &spec, run_first).unwrap();

    // Any mask-affecting knob changes the fingerprint; resuming under
    // it must be refused, not silently mixed.
    let spec_other = MaskSpec { t_max: 7, ..spec.clone() };
    let run_resume = RunOptions {
        journal: Some(dir.clone()),
        resume: true,
        ..Default::default()
    };
    let err = prune(&pool, &store, &ds, &spec_other,
                    run_resume.clone()).unwrap_err();
    assert!(err.to_string().contains("fingerprint mismatch"),
            "unexpected error: {err}");

    // Resume without any journal on disk.
    let run_empty = RunOptions {
        journal: Some(tmp_dir("missing")),
        ..run_resume
    };
    let err = prune(&pool, &store, &ds, &spec, run_empty).unwrap_err();
    assert!(err.to_string().contains("no journal to resume"),
            "unexpected error: {err}");

    // Resume without a journal directory configured at all.
    let run_nodir = RunOptions { journal: None, resume: true,
                                 ..Default::default() };
    let err = prune(&pool, &store, &ds, &spec, run_nodir).unwrap_err();
    assert!(err.to_string().contains("resume requires"),
            "unexpected error: {err}");
    std::fs::remove_dir_all(&dir).ok();
}
