//! The sparsity-sweep harness: ppl-vs-sparsity curves as warm-started
//! mask continuations.
//!
//! The paper's central operational property — 1-swap refinement
//! warmstarts from *any* valid mask — makes a sparsity curve a chain
//! of short continuations rather than independent solves: the level-s
//! refined mask, tightened to s+δ by pruning its lowest-saliency kept
//! weights per row ([`crate::pruning::mask::tighten_mask`]), is a
//! near-converged warmstart for the next level.  Reference sweep
//! scripts instead rerun model load + calibration per point in shell
//! loops; here one [`PruneSession`] is built once, the one-shot Gram
//! statistics are accumulated once, and every grid point is one
//! `prune_from` call.
//!
//! The grid is `(criterion × refiner × levels)` with levels sorted
//! ascending by sparsity ([`points`]; deterministic, stable for
//! equal-sparsity entries such as unstructured-50% vs 2:4).  Each
//! `(criterion, refiner)` pair forms one warm chain; a level whose
//! sparsity is below its predecessor's (possible when an N:M entry
//! interleaves) restarts the chain cold rather than "tightening"
//! upward.
//!
//! Per point the report records ppl, per-layer error, swaps, rows/s
//! and — with `cold_compare` — the same spec refined from a cold
//! warmstart mask, so the curve artifact (`reports/sweep.json`)
//! carries the warm-vs-cold timing and loss deltas the bench gate
//! asserts on.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::coordinator::pipeline::{
    MaskSpec, PatternKind, PruneSession, Refiner,
};
use crate::data::Split;
use crate::eval::perplexity_pool;
use crate::model::store::MaskSet;
use crate::model::weight_store::WeightStore;
use crate::pruning::saliency::Criterion;
use crate::runtime::service::RuntimeError;
use crate::util::jsonlite::Json;

#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Grid levels: sparsity fractions and/or N:M patterns.
    pub levels: Vec<PatternKind>,
    pub criteria: Vec<Criterion>,
    pub refiners: Vec<Refiner>,
    pub t_max: usize,
    pub calib_batches: usize,
    /// Warm-start each level from the previous refined mask
    /// (tightened); disable to refine every point cold.
    pub warm_start: bool,
    /// Additionally refine every warm-started point from a cold
    /// warmstart mask (same session, so calibration is still shared)
    /// and record the timing/loss delta per point.
    pub cold_compare: bool,
    /// Evaluate masked-model perplexity per point.
    pub eval_ppl: bool,
    pub val_batches: usize,
    /// Curve artifact path (`reports/sweep.json`); `None` skips the
    /// write.
    pub out: Option<PathBuf>,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            levels: vec![
                PatternKind::Unstructured { sparsity: 0.4 },
                PatternKind::Unstructured { sparsity: 0.5 },
                PatternKind::Unstructured { sparsity: 0.6 },
            ],
            criteria: vec![Criterion::Wanda],
            refiners: vec![Refiner::SparseSwapsNative],
            t_max: 10,
            calib_batches: 4,
            warm_start: true,
            cold_compare: false,
            eval_ppl: false,
            val_batches: 4,
            out: None,
        }
    }
}

/// Collision-proof point key for merged JSON: criterion, refiner and
/// the *kinded* pattern key, so unstructured-50% and 2:4 stay
/// distinct.
pub fn point_key(criterion: Criterion, refiner: &Refiner,
                 pattern: PatternKind) -> String {
    format!("{}|{}|{}", criterion.name(), refiner.label(),
            pattern.key())
}

/// The grid in iteration order: criterion-major, then refiner, then
/// levels stable-sorted ascending by target sparsity (equal-sparsity
/// levels keep their configured order).  Deterministic: two calls on
/// the same config yield the same sequence, so merged sweep JSON and
/// warm chains are reproducible.
pub fn points(cfg: &SweepConfig)
    -> Vec<(Criterion, Refiner, PatternKind)> {
    let mut levels = cfg.levels.clone();
    levels.sort_by(|a, b| {
        a.sparsity().partial_cmp(&b.sparsity())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut out = Vec::with_capacity(
        cfg.criteria.len() * cfg.refiners.len() * levels.len());
    for &criterion in &cfg.criteria {
        for refiner in &cfg.refiners {
            for &level in &levels {
                out.push((criterion, refiner.clone(), level));
            }
        }
    }
    out
}

/// One grid point's results.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub key: String,
    pub criterion: &'static str,
    pub refiner: String,
    pub pattern: String,
    pub pattern_key: String,
    pub target_sparsity: f64,
    pub achieved_sparsity: f64,
    pub ppl: Option<f64>,
    pub warmstart_loss: f64,
    pub refined_loss: f64,
    pub mean_relative_reduction: f64,
    pub swaps: usize,
    pub rows: usize,
    /// Prune wall seconds for this point (includes the one shared
    /// calibration pass on the first point that needs it; excludes
    /// ppl eval).
    pub seconds: f64,
    pub rows_per_s: f64,
    /// Key of the point whose refined mask warm-started this one
    /// (`None` for cold chain heads).
    pub warm_from: Option<String>,
    /// `cold_compare` arm: same spec refined from a cold warmstart.
    pub cold_seconds: Option<f64>,
    pub cold_refined_loss: Option<f64>,
    /// Per-layer `(name, warmstart_loss, refined_loss)`.
    pub layers: Vec<(String, f64, f64)>,
}

#[derive(Clone, Debug)]
pub struct SweepReport {
    pub model: String,
    pub points: Vec<SweepPoint>,
    /// Calibration passes the whole sweep paid for (the headline
    /// number: 1 for a one-shot grid, however many points it has).
    pub calibrations: usize,
    pub seconds: f64,
}

impl SweepReport {
    pub fn to_json(&self) -> Json {
        let points = self.points.iter().map(|p| {
            let opt = |v: Option<f64>| match v {
                Some(x) => Json::num(x),
                None => Json::Null,
            };
            let layers = p.layers.iter().map(|(name, w, r)| {
                Json::obj(vec![
                    ("name", Json::str(name.as_str())),
                    ("warmstart_loss", Json::num(*w)),
                    ("refined_loss", Json::num(*r)),
                ])
            }).collect();
            Json::obj(vec![
                ("key", Json::str(p.key.as_str())),
                ("criterion", Json::str(p.criterion)),
                ("refiner", Json::str(p.refiner.as_str())),
                ("pattern", Json::str(p.pattern.as_str())),
                ("pattern_key", Json::str(p.pattern_key.as_str())),
                ("target_sparsity", Json::num(p.target_sparsity)),
                ("achieved_sparsity", Json::num(p.achieved_sparsity)),
                ("ppl", opt(p.ppl)),
                ("warmstart_loss", Json::num(p.warmstart_loss)),
                ("refined_loss", Json::num(p.refined_loss)),
                ("mean_relative_reduction",
                 Json::num(p.mean_relative_reduction)),
                ("swaps", Json::num(p.swaps as f64)),
                ("rows", Json::num(p.rows as f64)),
                ("seconds", Json::num(p.seconds)),
                ("rows_per_s", Json::num(p.rows_per_s)),
                ("warm_from", match &p.warm_from {
                    Some(k) => Json::str(k.as_str()),
                    None => Json::Null,
                }),
                ("cold_seconds", opt(p.cold_seconds)),
                ("cold_refined_loss", opt(p.cold_refined_loss)),
                ("layers", Json::Arr(layers)),
            ])
        }).collect();
        Json::obj(vec![
            ("model", Json::str(self.model.as_str())),
            ("calibrations", Json::num(self.calibrations as f64)),
            ("seconds", Json::num(self.seconds)),
            ("points", Json::Arr(points)),
        ])
    }

    pub fn write(&self, path: &Path) -> Result<(), RuntimeError> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).map_err(|e| {
                RuntimeError::Msg(format!("sweep report: {e}"))
            })?;
        }
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| RuntimeError::Msg(format!(
                "sweep report {}: {e}", path.display())))
    }

    /// Sum of per-point prune seconds (the warm arm's wall-clock,
    /// excluding ppl eval).
    pub fn prune_seconds(&self) -> f64 {
        self.points.iter().map(|p| p.seconds).sum()
    }
}

/// Walk the sweep grid over one session.  Every point dispatches
/// through [`PruneSession::prune_from`]; warm chains run per
/// `(criterion, refiner)` pair.  Sweeps are never journaled (warm
/// continuations have no stable fingerprint), so the session must
/// not carry journal/resume options.
pub fn sweep(session: &mut PruneSession, cfg: &SweepConfig)
    -> Result<SweepReport, RuntimeError> {
    if session.run.journal.is_some() || session.run.resume {
        return Err(RuntimeError::Msg(
            "sweep runs cannot be journaled or resumed: warm-started \
             continuations are not covered by the journal \
             fingerprint".into()));
    }
    if cfg.levels.is_empty() || cfg.criteria.is_empty()
        || cfg.refiners.is_empty() {
        return Err(RuntimeError::Msg(
            "sweep grid is empty (need >=1 level, criterion and \
             refiner)".into()));
    }
    if cfg.eval_ppl && session.store().as_resident().is_none() {
        return Err(RuntimeError::Msg(
            "sweep ppl evaluation needs the full model resident; \
             drop the eval or --stream-weights".into()));
    }
    let meta = session.store().meta().clone();
    let val = cfg.eval_ppl.then(|| {
        session.dataset().batches(&meta, Split::Validation,
                                  cfg.val_batches)
    });
    let t_all = Instant::now();
    let grid = points(cfg);
    let mut out: Vec<SweepPoint> = Vec::with_capacity(grid.len());
    // One warm chain per (criterion, refiner): the previous level's
    // refined masks plus enough context to label and gate the
    // continuation.
    let mut chain: Option<(Criterion, Refiner, f64, String,
                           MaskSet)> = None;
    for (criterion, refiner, level) in grid {
        let same_chain = matches!(&chain, Some((c, r, ..))
                                  if *c == criterion && *r == refiner);
        if !same_chain {
            chain = None;
        }
        let spec = MaskSpec {
            criterion,
            pattern_kind: level,
            refiner: refiner.clone(),
            t_max: cfg.t_max,
            calib_batches: cfg.calib_batches,
            sequential: false,
            checkpoints: Vec::new(),
        };
        // Warm-start only when continuing to equal-or-higher
        // sparsity; a chain can only tighten.
        let warm_from = match &chain {
            Some((_, _, s, key, masks))
                if cfg.warm_start
                    && *s <= level.sparsity() + 1e-9 =>
                Some((key.clone(), masks)),
            _ => None,
        };
        let key = point_key(criterion, &refiner, level);
        crate::log_debug!("sweep[{}] {} (warm from {:?})", meta.name,
                          key, warm_from.as_ref().map(|(k, _)| k));
        let t0 = Instant::now();
        let (masks, rep) = session.prune_from(
            &spec, warm_from.as_ref().map(|(_, m)| *m))?;
        let seconds = t0.elapsed().as_secs_f64();
        let (cold_seconds, cold_refined_loss) =
            if cfg.cold_compare && warm_from.is_some() {
                let tc = Instant::now();
                let (_, cold) = session.prune(&spec)?;
                (Some(tc.elapsed().as_secs_f64()),
                 Some(cold.total_refined_loss()))
            } else {
                (None, None)
            };
        let ppl = match &val {
            Some(batches) => Some(perplexity_pool(
                session.pool(),
                &session.resident_store()?.masked(&masks), batches)?),
            None => None,
        };
        let rows: usize = rep.layers.iter().map(|l| l.rows).sum();
        out.push(SweepPoint {
            key: key.clone(),
            criterion: criterion.name(),
            refiner: refiner.label(),
            pattern: level.label(),
            pattern_key: level.key(),
            target_sparsity: level.sparsity(),
            achieved_sparsity: masks.overall_sparsity(),
            ppl,
            warmstart_loss: rep.total_warmstart_loss(),
            refined_loss: rep.total_refined_loss(),
            mean_relative_reduction: rep.mean_relative_reduction(),
            swaps: rep.layers.iter().map(|l| l.swaps).sum(),
            rows,
            seconds,
            rows_per_s: if seconds > 0.0 {
                rows as f64 / seconds
            } else {
                0.0
            },
            warm_from: warm_from.map(|(k, _)| k),
            cold_seconds,
            cold_refined_loss,
            layers: rep.layers.iter()
                .map(|l| (l.name.clone(), l.loss_warmstart,
                          l.loss_refined))
                .collect(),
        });
        chain = Some((criterion, refiner, level.sparsity(), key,
                      masks));
    }
    let report = SweepReport {
        model: meta.name.clone(),
        points: out,
        calibrations: session.calibrations(),
        seconds: t_all.elapsed().as_secs_f64(),
    };
    if let Some(path) = &cfg.out {
        report.write(path)?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_criterion_major_and_sparsity_sorted() {
        let cfg = SweepConfig {
            levels: vec![
                PatternKind::Unstructured { sparsity: 0.6 },
                PatternKind::Nm { n: 2, m: 4 },
                PatternKind::Unstructured { sparsity: 0.5 },
                PatternKind::Unstructured { sparsity: 0.3 },
            ],
            criteria: vec![Criterion::Wanda, Criterion::Magnitude],
            refiners: vec![Refiner::None,
                           Refiner::SparseSwapsNative],
            ..SweepConfig::default()
        };
        let grid = points(&cfg);
        assert_eq!(grid, points(&cfg), "grid order must be \
                                        deterministic");
        assert_eq!(grid.len(), 2 * 2 * 4);
        // Levels ascend by sparsity within each chain; the stable
        // sort keeps the configured order for the equal-sparsity
        // pair (2:4 listed before unstructured 50%).
        let chain: Vec<String> = grid[..4].iter()
            .map(|(_, _, p)| p.key())
            .collect();
        assert_eq!(chain, vec!["unstructured:30%", "nm:2:4",
                               "unstructured:50%",
                               "unstructured:60%"]);
        // Criterion-major: the first half is all-Wanda.
        assert!(grid[..8].iter()
                .all(|(c, ..)| *c == Criterion::Wanda));
        // Point keys are unique across the grid (the kinded pattern
        // key disambiguates 2:4 from unstructured 50%).
        let keys: std::collections::BTreeSet<String> = grid.iter()
            .map(|(c, r, p)| point_key(*c, r, *p))
            .collect();
        assert_eq!(keys.len(), grid.len());
    }
}
