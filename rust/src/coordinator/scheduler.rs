//! Shard-granular scheduling: the row-range work unit behind every
//! refinement dispatch.
//!
//! The paper's tractability move — equal per-row sparsity decouples
//! rows, so every row's 1-swap refinement is independent — means the
//! scheduling grain does not have to be the layer.  Before this
//! module the pipeline scheduled whole layers, so one wide layer (an
//! MLP down-projection has ~4x the rows of an attention projection)
//! pinned one worker while the rest drained and idled.  Now the work
//! unit is a [`Shard`] — a contiguous row range of one layer — and a
//! single [`refine_block`] dispatch path drives every engine on every
//! substrate through the [`Scheduler`] trait: host
//! [`ThreadPool`] workers for the runtime-free engines, and the
//! [`RuntimePool`]'s device workers for the offload engine.
//!
//! Shard sizing is adaptive: the target is
//! `total_rows / (SHARD_OVERSUB x workers)`, so the long-tail layer
//! splits across otherwise-idle workers instead of serializing the
//! block.  Row sharding cannot split an N:M block (blocks span
//! *columns* within one row), so the only boundary that matters is
//! the offload artifact's chunk shape, which adaptive sizing aligns
//! to per layer.
//!
//! Because rows are independent, masks and checkpoint snapshots are
//! bit-identical to the whole-layer schedule for every shard size and
//! worker count — property-tested in `tests/shards.rs` and gated in
//! the `ablation_engine` bench's "shards" sweep.
//!
//! The shard is also the *recovery* grain: [`refine_block`] collects
//! per-shard outcomes instead of aborting on the first loss, and
//! redispatches transiently failed shards (dead worker, evicted
//! buffers — `RefineError::is_transient`) up to
//! [`BlockSchedule::max_retries`] times, hinting the pool away from
//! the worker that just failed.  Outcomes feed the [`RuntimePool`]
//! quarantine ledger through [`Scheduler::report_outcome`]; once
//! every worker is quarantined the block aborts with a recognizable
//! error and the pipeline degrades to the native host path.  Retried
//! runs stay bit-identical (each attempt re-copies its warmstart rows
//! — property-tested in `tests/faults.rs`).

use std::ops::Range;
use std::sync::mpsc;
use std::time::Instant;

use crate::coordinator::pipeline::Refiner;
use crate::pruning::dsnot::FeatureStats;
use crate::pruning::engine::{
    LayerContext, RefineEngine, RefineError, RefineOutcome,
    SnapshotAssembler,
};
use crate::pruning::mask::Pattern;
use crate::pruning::sparseswaps::{gmax_table, LayerOutcome};
use crate::runtime::pool::RuntimePool;
use crate::runtime::service::{Runtime, RuntimeError};
use crate::util::tensor::{GramView, Matrix, MatrixView};
use crate::util::threadpool::ThreadPool;

/// One schedulable work unit: a contiguous row range of one layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shard {
    /// Index into the scheduled block's layer list.
    pub layer: usize,
    /// Row range of that layer this unit refines.
    pub rows: Range<usize>,
}

/// The worker a shard job landed on: a plain host thread (runtime-free
/// engines), or a runtime-pool device worker whose service the
/// offload engine executes against.
#[derive(Clone, Copy)]
pub enum WorkerCtx<'a> {
    Host,
    Device(&'a Runtime),
}

/// A queued shard job.  Boxed so both pool types move the same
/// object; the [`WorkerCtx`] argument is how the dispatching pool
/// tells the job what it may execute against.
pub type ShardJob<'env> = Box<dyn FnOnce(WorkerCtx<'_>) + Send + 'env>;

/// Anything that can run a batch of shard jobs to completion — the
/// scheduling half of the one refinement dispatch path.  Both
/// implementations run the batch *scoped* (the call returns only when
/// every job finished), so jobs may borrow block-local state
/// (zero-copy Gram views into the calibration stream stacks).
pub trait Scheduler {
    /// Worker count (adaptive shard sizing divides work by this).
    fn workers(&self) -> usize;

    /// Run every job to completion (scoped fork/join).
    fn run_shards<'env>(&self, jobs: Vec<ShardJob<'env>>);

    /// [`run_shards`] with a best-effort placement hint: spread the
    /// jobs over workers *not* listed in `avoid` — the retry path's
    /// "redispatch on a different worker".  The default (host pool)
    /// ignores the hint: host threads do not fail independently.
    ///
    /// [`run_shards`]: Scheduler::run_shards
    fn run_shards_avoiding<'env>(&self, jobs: Vec<ShardJob<'env>>,
                                 _avoid: &[usize]) {
        self.run_shards(jobs);
    }

    /// Record one shard outcome for the worker health ledger.  The
    /// default is a no-op; [`RuntimePool`] feeds its quarantine
    /// streaks from this.
    fn report_outcome(&self, _worker: usize, _ok: bool) {}

    /// Currently quarantined worker indices (always empty for the
    /// host pool).
    fn quarantined(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Count one shard redispatch (surfaced through pool stats).
    fn note_shard_retry(&self) {}

    /// Cumulative nanoseconds each worker spent executing jobs —
    /// max/mean across workers is the bench load-imbalance metric.
    fn busy_nanos(&self) -> Vec<u64>;
}

impl Scheduler for ThreadPool {
    fn workers(&self) -> usize {
        self.size()
    }

    fn run_shards<'env>(&self, jobs: Vec<ShardJob<'env>>) {
        let wrapped: Vec<Box<dyn FnOnce() + Send + 'env>> = jobs
            .into_iter()
            .map(|job| {
                Box::new(move || job(WorkerCtx::Host))
                    as Box<dyn FnOnce() + Send + 'env>
            })
            .collect();
        self.run_scoped(wrapped);
    }

    fn busy_nanos(&self) -> Vec<u64> {
        ThreadPool::busy_nanos(self)
    }
}

impl Scheduler for RuntimePool {
    fn workers(&self) -> usize {
        self.devices()
    }

    fn run_shards<'env>(&self, jobs: Vec<ShardJob<'env>>) {
        Scheduler::run_shards_avoiding(self, jobs, &[]);
    }

    fn run_shards_avoiding<'env>(&self, jobs: Vec<ShardJob<'env>>,
                                 avoid: &[usize]) {
        let wrapped: Vec<Box<dyn FnOnce(&Runtime) + Send + 'env>> = jobs
            .into_iter()
            .map(|job| {
                Box::new(move |rt: &Runtime| {
                    job(WorkerCtx::Device(rt))
                })
                    as Box<dyn FnOnce(&Runtime) + Send + 'env>
            })
            .collect();
        self.run_scoped_avoiding(wrapped, avoid);
    }

    fn report_outcome(&self, worker: usize, ok: bool) {
        self.report_worker_outcome(worker, ok);
    }

    fn quarantined(&self) -> Vec<usize> {
        self.quarantined_workers()
    }

    fn note_shard_retry(&self) {
        RuntimePool::note_shard_retry(self);
    }

    fn busy_nanos(&self) -> Vec<u64> {
        RuntimePool::busy_nanos(self)
    }
}

/// Shards targeted per worker by adaptive sizing: enough slack that a
/// 4x-wide long-tail layer splits across idle workers, few enough
/// that per-shard setup (engine row state, the skip-bound table)
/// stays noise next to the scan work.
pub const SHARD_OVERSUB: usize = 4;

/// Adaptive shard size over a block:
/// `total_rows / (SHARD_OVERSUB x workers)`, at least 1.  Callers
/// align the result up to a per-layer multiple (the offload chunk
/// shape) before splitting.
pub fn adaptive_shard_rows(total_rows: usize, workers: usize) -> usize {
    total_rows
        .div_ceil(SHARD_OVERSUB.max(1) * workers.max(1))
        .max(1)
}

/// Split one layer's `rows` into [`Shard`]s of `size` rows, last one
/// ragged.  `size` is clamped into `[1, rows]`; a zero-row layer
/// still yields one empty shard so it produces a (trivial) result.
pub fn split_rows(layer: usize, rows: usize, size: usize) -> Vec<Shard> {
    if rows == 0 {
        return vec![Shard { layer, rows: 0..0 }];
    }
    let size = size.clamp(1, rows);
    let mut out = Vec::with_capacity(rows.div_ceil(size));
    let mut lo = 0usize;
    while lo < rows {
        let hi = (lo + size).min(rows);
        out.push(Shard { layer, rows: lo..hi });
        lo = hi;
    }
    out
}

/// One layer's refinement inputs, shared by all of its shards.
/// The warmstart mask is owned; weights and the Gram matrix are
/// zero-copy views — into the weight store (or a block lease) and the
/// block's calibration stats respectively (shard jobs carry the
/// borrows through the scoped submission APIs).
pub struct LayerWork<'a> {
    /// Caller's layer index (results are keyed by it).
    pub li: usize,
    /// Layer name for error messages.
    pub label: String,
    pub w: MatrixView<'a>,
    pub g: GramView<'a>,
    pub stats: Option<FeatureStats>,
    pub pattern: Pattern,
    /// Warmstart mask; every shard copies its row range out of it.
    pub warm: Matrix,
    /// Preferred shard-size multiple (the offload artifact's
    /// chunk_rows; 1 for host engines).  Only adaptive sizing
    /// respects it — an explicit `BlockSchedule::shard_rows` is taken
    /// literally (the shard-sweep tests rely on that).
    pub shard_align: usize,
    /// Shared device-buffer key for this layer's Gram tensor
    /// (`coordinator::swaploop::next_refinement_id`, one per layer):
    /// every shard of the layer reuses the same resident G on its
    /// worker.  Ignored by host engines.  The caller releases the
    /// buffer (`Runtime::invalidate`) once the layer is done.
    pub gram_key: u64,
}

/// How [`refine_block`] drives one block.
#[derive(Clone, Debug)]
pub struct BlockSchedule {
    /// Iteration budget per row (the paper's T_max).
    pub t_max: usize,
    /// Engine-internal row threads per shard job (1 under a
    /// multi-worker scheduler — parallelism comes from shards).
    pub threads_per_shard: usize,
    /// Cumulative-iteration snapshot checkpoints (Table 3).
    pub checkpoints: Vec<usize>,
    /// Rows per shard; 0 = adaptive ([`adaptive_shard_rows`], aligned
    /// per layer to `LayerWork::shard_align`).
    pub shard_rows: usize,
    /// Dispatch shards one at a time (per-layer wall-clock timings;
    /// `--layer-parallel=false`).  Masks are identical either way.
    pub serial: bool,
    /// Redispatch budget per shard for *transient* failures (dead
    /// worker, evicted buffers): a shard may run `1 + max_retries`
    /// times before the block aborts.  Deterministic failures
    /// (`RefineError::is_transient` == false) never retry.
    pub max_retries: usize,
}

/// One layer's merged refinement result.
pub struct ShardedLayer {
    pub li: usize,
    /// Final whole-layer mask.
    pub mask: Matrix,
    /// Per-row outcomes in row order plus whole-layer checkpoint
    /// snapshots (merged by [`SnapshotAssembler`]).
    pub outcome: RefineOutcome,
    /// Summed shard refinement seconds (CPU seconds under a parallel
    /// schedule, wall seconds under `serial`).
    pub seconds: f64,
    /// How many shards the layer was split into.
    pub shards: usize,
}

struct ShardDone {
    layer: usize,
    rows: Range<usize>,
    mask: Matrix,
    outcome: RefineOutcome,
    seconds: f64,
}

/// One shard attempt's fan-in record: which shard, which worker ran
/// it (`usize::MAX` = host/unknown), and how it went.  The worker id
/// feeds the quarantine ledger and the redispatch-elsewhere hint.
struct ShardReport {
    idx: usize,
    worker: usize,
    res: Result<ShardDone, RefineError>,
}

fn run_shard(refiner: &Refiner, wc: WorkerCtx<'_>, work: &LayerWork<'_>,
             gmax: Option<&[f64]>, shard: &Shard, plan: &BlockSchedule)
    -> Result<ShardDone, RefineError> {
    let engine = refiner.shard_engine(&wc, work.gram_key)
        .map_err(RefineError::Msg)?;
    let ctx = LayerContext {
        w: work.w,
        g: work.g,
        stats: work.stats.as_ref(),
        pattern: work.pattern,
        t_max: plan.t_max,
        threads: plan.threads_per_shard,
        gmax,
    };
    let range = shard.rows.clone();
    let mut mask = Matrix::zeros(range.len(), work.w.cols);
    for (k, r) in range.clone().enumerate() {
        mask.row_mut(k).copy_from_slice(work.warm.row(r));
    }
    let t0 = Instant::now();
    // Propagate the engine error as-is: the retry loop classifies by
    // variant (`is_transient`), and the report site adds the
    // layer/rows context without erasing it.
    let outcome = engine
        .refine_rows(&ctx, range.clone(), &mut mask,
                     &plan.checkpoints)?;
    Ok(ShardDone {
        layer: shard.layer,
        rows: range,
        mask,
        outcome,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

/// THE refinement dispatch: shard every layer of a block, fan the
/// shards across the scheduler's workers, and merge per-shard masks,
/// outcomes and snapshots back per layer.  The `PruneSession`
/// pipeline routes every refiner through here (no native/offload
/// split); the shard tests and the `ablation_engine` "shards" sweep
/// call it directly.
///
/// Results come back in `works` order.
pub fn refine_block(
    sched: &dyn Scheduler,
    refiner: &Refiner,
    works: &[LayerWork<'_>],
    plan: &BlockSchedule,
) -> Result<Vec<ShardedLayer>, RuntimeError> {
    let total_rows: usize = works.iter().map(|w| w.w.rows).sum();
    let mut shards: Vec<Shard> = Vec::new();
    for (wi, work) in works.iter().enumerate() {
        let size = if plan.shard_rows != 0 {
            plan.shard_rows
        } else {
            let t = adaptive_shard_rows(total_rows, sched.workers());
            let a = work.shard_align.max(1);
            t.div_ceil(a) * a
        };
        shards.extend(split_rows(wi, work.w.rows, size));
    }
    let n_shards = shards.len();
    // Shared skip-bound tables, one per layer: `gmax` depends only on
    // (G, pattern), so computing it here and handing every shard a
    // borrowed slice turns the native engine's O(d²) per-shard scan
    // into a per-layer one (the jobs borrow the tables for 'env just
    // like `works`).  Only the native engine consumes it; other
    // refiners skip the cost entirely.
    let gmax_tables: Vec<Option<Vec<f64>>> = works.iter()
        .map(|work| {
            matches!(refiner, Refiner::SparseSwapsNative).then(|| {
                gmax_table(work.g, work.pattern.nm_block(),
                           sched.workers())
            })
        })
        .collect();
    // Retry state, indexed by shard: resolved results, failed-attempt
    // counts, and the worker each shard last failed on (the
    // redispatch-elsewhere hint; `usize::MAX` = unknown/host).
    let mut done: Vec<Option<ShardDone>> =
        (0..n_shards).map(|_| None).collect();
    let mut attempts = vec![0usize; n_shards];
    let mut avoid_worker = vec![usize::MAX; n_shards];
    let mut pending: Vec<usize> = (0..n_shards).collect();
    // Each round dispatches the pending shards, classifies every
    // outcome, and requeues the transient failures (quarantine and
    // retry budget permitting).  Rows are independent and warmstart
    // state is copied per attempt, so a redispatched shard recomputes
    // exactly what the clean run would — retried runs stay
    // bit-identical (property-tested in `tests/faults.rs`).
    while !pending.is_empty() {
        let round = std::mem::take(&mut pending);
        let (tx, rx) = mpsc::channel::<ShardReport>();
        let mut jobs: Vec<ShardJob<'_>> =
            Vec::with_capacity(round.len());
        for &idx in &round {
            let tx = tx.clone();
            // Shared borrows for 'env (like `works`): no per-shard
            // clone of the refiner or the checkpoint list.
            let shard = &shards[idx];
            let work = &works[shard.layer];
            let gmax = gmax_tables[shard.layer].as_deref();
            jobs.push(Box::new(move |wc| {
                let worker = match wc {
                    WorkerCtx::Device(rt) => rt.device(),
                    WorkerCtx::Host => usize::MAX,
                };
                let res =
                    run_shard(refiner, wc, work, gmax, shard, plan);
                let _ = tx.send(ShardReport { idx, worker, res });
            }));
        }
        drop(tx);
        let avoid: Vec<usize> = round.iter()
            .map(|&idx| avoid_worker[idx])
            .filter(|&w| w != usize::MAX)
            .collect();
        if plan.serial {
            for job in jobs {
                sched.run_shards(vec![job]);
            }
        } else if avoid.is_empty() {
            sched.run_shards(jobs);
        } else {
            sched.run_shards_avoiding(jobs, &avoid);
        }
        // Classify the round.  A shard lost to a worker panic is
        // contained by its pool but sends no report — it is retried
        // like a transient failure (better than a silently incomplete
        // mask, and the pool already counted the panic against the
        // worker's quarantine streak).
        let mut seen = vec![false; n_shards];
        let mut retryable: Vec<(usize, String)> = Vec::new();
        for report in rx {
            seen[report.idx] = true;
            let shard = &shards[report.idx];
            let label = &works[shard.layer].label;
            match report.res {
                Ok(d) => {
                    sched.report_outcome(report.worker, true);
                    done[report.idx] = Some(d);
                }
                Err(e) if e.is_transient() => {
                    sched.report_outcome(report.worker, false);
                    avoid_worker[report.idx] = report.worker;
                    retryable.push((report.idx, format!(
                        "{} rows {:?}: {e}", label, shard.rows)));
                }
                // Deterministic failure: a retry would recompute the
                // same error, so abort the block immediately.
                Err(e) => {
                    return Err(RuntimeError::Msg(format!(
                        "{} rows {:?}: {e}", label, shard.rows)));
                }
            }
        }
        for &idx in &round {
            if !seen[idx] {
                let shard = &shards[idx];
                retryable.push((idx, format!(
                    "{} rows {:?}: shard lost (worker panic)",
                    works[shard.layer].label, shard.rows)));
            }
        }
        if retryable.is_empty() {
            continue;
        }
        // With every worker quarantined no retry can land on healthy
        // hardware — surface that state (the pipeline reads the pool's
        // quarantine counters to decide on native degradation) before
        // burning the retry budget on a doomed redispatch.
        let q = sched.quarantined().len();
        if q > 0 && q >= sched.workers() {
            let (_, why) = &retryable[0];
            return Err(RuntimeError::Msg(format!(
                "all {q} workers quarantined; last failure: {why}")));
        }
        for (idx, why) in retryable {
            attempts[idx] += 1;
            if attempts[idx] > plan.max_retries {
                return Err(RuntimeError::Msg(format!(
                    "shard retry budget exhausted after {} attempts: \
                     {why}", attempts[idx])));
            }
            sched.note_shard_retry();
            pending.push(idx);
        }
    }
    let mut per_layer: Vec<Vec<ShardDone>> =
        (0..works.len()).map(|_| Vec::new()).collect();
    for d in done {
        let s = d.expect("every shard resolved or the block aborted");
        per_layer[s.layer].push(s);
    }
    let mut merged = Vec::with_capacity(works.len());
    for (work, mut mine) in works.iter().zip(per_layer) {
        mine.sort_by_key(|s| s.rows.start);
        let n = mine.len();
        let mut asm = SnapshotAssembler::new(work.w.rows, work.w.cols);
        let mut rows_out = Vec::with_capacity(work.w.rows);
        let mut seconds = 0.0;
        for s in mine {
            seconds += s.seconds;
            rows_out.extend(s.outcome.layer.rows);
            asm.add(s.rows, s.mask, s.outcome.snapshots);
        }
        let (mask, snapshots) = asm.finish().map_err(|e| {
            RuntimeError::Msg(format!("{}: {e}", work.label))
        })?;
        merged.push(ShardedLayer {
            li: work.li,
            mask,
            outcome: RefineOutcome {
                layer: LayerOutcome { rows: rows_out },
                snapshots,
            },
            seconds,
            shards: n,
        });
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ranges(shards: &[Shard]) -> Vec<(usize, usize)> {
        shards.iter().map(|s| (s.rows.start, s.rows.end)).collect()
    }

    #[test]
    fn split_rows_tiles_exactly_with_ragged_tail() {
        let s = split_rows(2, 13, 5);
        assert_eq!(ranges(&s), vec![(0, 5), (5, 10), (10, 13)]);
        assert!(s.iter().all(|sh| sh.layer == 2));
        // Oversized and zero sizes clamp.
        assert_eq!(ranges(&split_rows(0, 7, usize::MAX)),
                   vec![(0, 7)]);
        assert_eq!(ranges(&split_rows(0, 3, 0)),
                   vec![(0, 1), (1, 2), (2, 3)]);
        // A zero-row layer still yields one (empty) shard.
        assert_eq!(ranges(&split_rows(0, 0, 4)), vec![(0, 0)]);
    }

    #[test]
    fn adaptive_size_targets_oversubscription() {
        // 1024 rows over 4 workers: 4x oversubscription -> 64 rows.
        assert_eq!(adaptive_shard_rows(1024, 4), 64);
        assert_eq!(adaptive_shard_rows(0, 4), 1);
        assert_eq!(adaptive_shard_rows(5, 100), 1);
        // The widest layer of a skewed block splits: one 512-row
        // layer among 7 x 128 ends up in multiple shards.
        let total = 512 + 7 * 128;
        let size = adaptive_shard_rows(total, 4);
        assert!(512 / size >= 4,
                "wide layer must split across workers (size {size})");
    }
}
