//! Layer-3 coordinator: the pruning pipeline (shard-granular
//! scheduling + calibration + warmstart + refinement through
//! `RefineEngine`s, behind the `PruneSession` job-spec API), the
//! sparsity-sweep harness (warm-started mask continuation over a
//! level × criterion × refiner grid), the shard scheduler itself, the
//! per-block mask journal behind `prune --resume`, the offload swap
//! engine, and the trainer that drives the AOT train-step artifact.

pub mod journal;
pub mod pipeline;
pub mod scheduler;
pub mod swaploop;
pub mod sweep;
pub mod trainer;

pub use journal::{config_fingerprint, fingerprint_key, Journal};
pub use pipeline::{
    LayerReport, MaskSpec, PatternKind, PruneReport, PruneSession,
    Refiner, RunOptions,
};
pub use scheduler::{refine_block, BlockSchedule, Scheduler, Shard};
pub use swaploop::{refine_layer_offload, OffloadConfig, OffloadEngine};
pub use sweep::{SweepConfig, SweepPoint, SweepReport};
pub use trainer::{train, TrainConfig, TrainReport};
