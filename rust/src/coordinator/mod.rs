//! Layer-3 coordinator: the pruning pipeline (shard-granular
//! scheduling + calibration + warmstart + refinement through
//! `RefineEngine`s), the shard scheduler itself, the per-block mask
//! journal behind `prune --resume`, the offload swap engine, and the
//! trainer that drives the AOT train-step artifact.

pub mod journal;
pub mod pipeline;
pub mod scheduler;
pub mod swaploop;
pub mod trainer;

pub use journal::{config_fingerprint, Journal};
pub use pipeline::{prune, PatternKind, PruneConfig, PruneReport, Refiner};
pub use scheduler::{refine_block, BlockSchedule, Scheduler, Shard};
pub use swaploop::{refine_layer_offload, OffloadConfig, OffloadEngine};
pub use trainer::{train, TrainConfig, TrainReport};
