//! Offload swap engine: drives the AOT `swap_step_*` artifacts over row
//! chunks with exact T_max bookkeeping and convergence compaction.
//!
//! This is the production path for Algorithm 1 (the paper's
//! "GPU-accelerated, fully parallelizable across rows" claim — here the
//! accelerator is the CPU PJRT client, on TPU it would be the same HLO):
//!
//!   * rows are packed into fixed-size chunks (the artifact's static
//!     leading dimension), padded with all-kept rows (no feasible swap,
//!     provably a no-op);
//!   * each call performs up to k swaps per row inside one executable
//!     (k = 8 artifacts amortise per-call overhead; k = 1 artifacts
//!     finish residual budgets so T_max semantics stay exact);
//!   * rows that converge (fewer than k swaps accepted in a call) are
//!     compacted out of the active set, so late iterations run on
//!     ever-smaller chunks;
//!   * optional mask snapshots at given cumulative-iteration checkpoints
//!     (Table 3's "perplexity vs number of 1-swap iterations" needs the
//!     mask after 1, 2, 5, ... swaps without re-running the pipeline).

use std::collections::BTreeMap;

use crate::pruning::mask::Pattern;
use crate::pruning::sparseswaps::{LayerOutcome, RowOutcome};
use crate::runtime::service::{Runtime, RuntimeError};
use crate::runtime::tensor_data::TensorData;
use crate::util::tensor::Matrix;

#[derive(Clone, Debug)]
pub struct OffloadConfig {
    /// "xla" (fused, CPU fast path) or "pallas" (L1 kernel variant).
    pub impl_name: String,
    pub t_max: usize,
}

impl Default for OffloadConfig {
    fn default() -> Self {
        Self { impl_name: "xla".into(), t_max: 100 }
    }
}

/// Refine every row of (w, mask) against Gram matrix g.  Returns the
/// outcome plus mask snapshots at the requested iteration checkpoints.
pub fn refine_layer_offload(
    rt: &Runtime, w: &Matrix, mask: &mut Matrix, g: &Matrix,
    pattern: Pattern, cfg: &OffloadConfig, checkpoints: &[usize],
) -> Result<(LayerOutcome, BTreeMap<usize, Matrix>), RuntimeError> {
    let d = w.cols;
    let tag = pattern.artifact_tag();
    let k8 = rt.manifest()
        .find_swap_artifact(d, &tag, &cfg.impl_name, 8)?.clone();
    let k1 = rt.manifest()
        .find_swap_artifact(d, &tag, &cfg.impl_name, 1)?.clone();
    assert_eq!(k8.chunk_rows, k1.chunk_rows);
    let chunk = k8.chunk_rows;
    let g_tensor = TensorData::from_matrix(g);

    #[derive(Clone)]
    struct RowState {
        used: usize,
        converged: bool,
        loss_before: f64,
        loss_after: f64,
    }
    let mut rows: Vec<RowState> = (0..w.rows).map(|_| RowState {
        used: 0,
        converged: false,
        loss_before: f64::NAN,
        loss_after: f64::NAN,
    }).collect();

    let mut snapshots: BTreeMap<usize, Matrix> = BTreeMap::new();
    let mut sorted_cp: Vec<usize> = checkpoints.to_vec();
    sorted_cp.sort_unstable();
    sorted_cp.dedup();

    // Iterations completed so far across the whole layer (uniform per
    // row by construction: we advance all active rows in lockstep).
    let mut done_iters = 0usize;

    while done_iters < cfg.t_max {
        // Next stop: a checkpoint boundary or t_max.
        let next_stop = sorted_cp.iter().copied()
            .find(|&c| c > done_iters && c <= cfg.t_max)
            .unwrap_or(cfg.t_max);
        let budget = next_stop - done_iters;
        // Use the k8 artifact while >= 8 iterations remain, else k1
        // (keeps T_max bookkeeping exact for arbitrary budgets).
        let (entry, k) = if budget >= k8.k_iters && k8.k_iters > 1 {
            (&k8, k8.k_iters)
        } else {
            (&k1, k1.k_iters)
        };

        let active: Vec<usize> = rows.iter().enumerate()
            .filter(|(_, r)| !r.converged)
            .map(|(i, _)| i)
            .collect();
        if active.is_empty() {
            // Stationary from here on; jump to the next stop so any
            // remaining checkpoints still get recorded.
            done_iters = next_stop;
            if sorted_cp.contains(&done_iters) {
                snapshots.insert(done_iters, mask.clone());
            }
            continue;
        }

        for group in active.chunks(chunk) {
            // Pack the chunk (pad with all-kept rows = guaranteed no-op).
            let mut wc = Matrix::zeros(chunk, d);
            let mut mc = Matrix::from_fn(chunk, d, |_, _| 1.0);
            for (slot, &ri) in group.iter().enumerate() {
                wc.row_mut(slot).copy_from_slice(w.row(ri));
                mc.row_mut(slot).copy_from_slice(mask.row(ri));
            }
            let out = rt.execute(&entry.name, vec![
                TensorData::from_matrix(&wc),
                TensorData::from_matrix(&mc),
                g_tensor.clone(),
            ])?;
            let m_out = out[0].as_f32()?;
            let l_before = out[1].as_f32()?;
            let l_after = out[2].as_f32()?;
            let swaps = out[3].as_f32()?;
            for (slot, &ri) in group.iter().enumerate() {
                mask.row_mut(ri)
                    .copy_from_slice(&m_out[slot * d..(slot + 1) * d]);
                let r = &mut rows[ri];
                if r.loss_before.is_nan() {
                    r.loss_before = l_before[slot] as f64;
                }
                r.loss_after = l_after[slot] as f64;
                let s = swaps[slot] as usize;
                r.used += s;
                if s < k {
                    // Fewer accepted swaps than iterations executed:
                    // the row hit a 1-swap local optimum inside the call.
                    r.converged = true;
                }
            }
        }
        // Each call executes exactly `k` iterations per active row.
        done_iters += k;
        if sorted_cp.contains(&done_iters) {
            snapshots.insert(done_iters, mask.clone());
        }
    }
    // If every row converged before later checkpoints, the mask is
    // stationary from here on — record it for the remaining checkpoints
    // so Table-3 style sweeps always see a complete series.
    for &cp in &sorted_cp {
        if cp <= cfg.t_max {
            snapshots.entry(cp).or_insert_with(|| mask.clone());
        }
    }

    let outcome = LayerOutcome {
        rows: rows.into_iter().map(|r| RowOutcome {
            loss_before: if r.loss_before.is_nan() { 0.0 }
                         else { r.loss_before },
            loss_after: if r.loss_after.is_nan() { r.loss_before.max(0.0) }
                        else { r.loss_after },
            swaps: r.used,
            converged: r.converged,
        }).collect(),
    };
    Ok((outcome, snapshots))
}

#[cfg(test)]
mod tests {
    #[test]
    fn config_default() {
        let c = super::OffloadConfig::default();
        assert_eq!(c.impl_name, "xla");
        assert_eq!(c.t_max, 100);
    }
}
