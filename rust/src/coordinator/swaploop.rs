//! Offload swap engine: drives the AOT `swap_step_*` artifacts over row
//! chunks with exact T_max bookkeeping and convergence compaction.
//!
//! This is the production path for Algorithm 1 (the paper's
//! "GPU-accelerated, fully parallelizable across rows" claim — here the
//! accelerator is the CPU PJRT client, on TPU it would be the same HLO):
//!
//!   * rows are packed into fixed-size chunks (the artifact's static
//!     leading dimension), padded with all-kept rows (no feasible swap,
//!     provably a no-op);
//!   * each call performs up to k swaps per row inside one executable
//!     (k = 8 artifacts amortise per-call overhead; k = 1 artifacts
//!     finish residual budgets so T_max semantics stay exact);
//!   * rows that converge (fewer than k swaps accepted in a call) are
//!     compacted out of the active set, so late iterations run on
//!     ever-smaller chunks;
//!   * the Gram tensor and the packed W chunks go through the
//!     service's persistent device-buffer cache: G is addressed by a
//!     key-only probe first (`ExecInput::CachedRef` — the d² host
//!     copy is not even *built* while the buffer is resident) and
//!     uploaded at most once per (layer, device) via the
//!     `NotResident` retry; W chunks upload once per active-set
//!     generation, and only the mask chunks — which change every
//!     call — travel per call.  This is the transport analogue of
//!     the host-side `GramView`;
//!   * checkpoint segmentation (Table 3's "perplexity vs number of
//!     1-swap iterations") is delegated to the shared
//!     [`drive_segments`] driver, the same one the native engine uses —
//!     this module only decides how far one artifact call advances.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::pruning::engine::{
    drive_segments, LayerContext, RefineEngine, RefineError, RefineOutcome,
};
use crate::pruning::error::row_loss;
use crate::pruning::mask::Pattern;
use crate::pruning::sparseswaps::{LayerOutcome, RowOutcome};
use crate::runtime::service::{
    BufferKey, ExecInput, Runtime, RuntimeError,
};
use crate::runtime::tensor_data::TensorData;
use crate::util::tensor::Matrix;

/// Monotone id distinguishing cached device buffers (the
/// [`BufferKey`] "layer" coordinate).  Process-wide, so concurrent
/// refinements on different pool workers never collide even within
/// one worker's cache.  The scheduler draws one per *layer* (shared
/// Gram key across that layer's shards); each `refine_rows` call
/// additionally draws its own for the shard-local W chunks.
///
/// Delegates to the runtime-layer allocator so calibration and eval
/// drivers (which key weights and resident accumulators the same way)
/// share the one id space.
pub fn next_refinement_id() -> u64 {
    crate::runtime::service::next_buffer_layer_id()
}

/// Lower a runtime failure into the engine error space, preserving
/// the transient/deterministic classification the shard retry loop
/// keys on (a plain `e.to_string()` into `Msg` would erase it).
fn refine_err(e: RuntimeError) -> RefineError {
    if e.is_transient() {
        RefineError::Transient(e.to_string())
    } else {
        RefineError::Msg(e.to_string())
    }
}

#[derive(Clone, Debug)]
pub struct OffloadConfig {
    /// "xla" (fused, CPU fast path) or "pallas" (L1 kernel variant).
    pub impl_name: String,
    pub t_max: usize,
}

impl Default for OffloadConfig {
    fn default() -> Self {
        Self { impl_name: "xla".into(), t_max: 100 }
    }
}

#[derive(Clone)]
struct RowState {
    used: usize,
    converged: bool,
    loss_before: f64,
    loss_after: f64,
}

/// SparseSwaps through the HLO swap artifacts, as a [`RefineEngine`].
///
/// Holds the runtime handle; `ctx.threads` is ignored because the PJRT
/// service serialises artifact execution anyway (row parallelism lives
/// *inside* the artifact).  Implements the row-range contract: a
/// shard packs only its own rows into chunks, and because per-row
/// results are independent of chunk grouping (pad rows are provable
/// no-ops), any shard plan lands on the whole-layer masks bit for
/// bit.
pub struct OffloadEngine<'rt> {
    rt: &'rt Runtime,
    impl_name: String,
    /// Shared Gram buffer key for every shard of one layer (see
    /// [`Self::with_gram_key`]); `None` = key G under the call's own
    /// id and release it eagerly (standalone whole-layer callers).
    gram_key: Option<u64>,
}

impl<'rt> OffloadEngine<'rt> {
    pub fn new(rt: &'rt Runtime, impl_name: impl Into<String>) -> Self {
        Self { rt, impl_name: impl_name.into(), gram_key: None }
    }

    /// [`Self::new`] with a caller-assigned Gram buffer key
    /// ([`next_refinement_id`], one per layer).  Shards of the same
    /// layer then share the resident G on their worker — uploaded
    /// once per (layer, device) instead of once per shard — while W
    /// chunks stay under each call's own id (their rows differ per
    /// shard, so sharing those keys would alias wrong data).  The
    /// shared G is *not* eagerly invalidated (sibling shards may
    /// still need it); the caller releases it when the layer is done,
    /// or the LRU reclaims it.
    pub fn with_gram_key(rt: &'rt Runtime,
                         impl_name: impl Into<String>, key: u64)
        -> Self {
        Self { rt, impl_name: impl_name.into(), gram_key: Some(key) }
    }
}

impl RefineEngine for OffloadEngine<'_> {
    fn name(&self) -> String {
        format!("sparseswaps[{}]", self.impl_name)
    }

    fn refine_rows(&self, ctx: &LayerContext,
                   row_range: std::ops::Range<usize>, mask: &mut Matrix,
                   checkpoints: &[usize])
        -> Result<RefineOutcome, RefineError> {
        let (w, g) = (ctx.w, ctx.g);
        assert!(row_range.end <= w.rows);
        let n_rows = row_range.len();
        let r0 = row_range.start;
        assert_eq!((mask.rows, mask.cols), (n_rows, w.cols));
        let d = w.cols;
        let tag = ctx.pattern.artifact_tag();
        let manifest = self.rt.manifest();
        let k8 = manifest
            .find_swap_artifact(d, &tag, &self.impl_name, 8)
            .map_err(|e| RefineError::Msg(e.to_string()))?
            .clone();
        let k1 = manifest
            .find_swap_artifact(d, &tag, &self.impl_name, 1)
            .map_err(|e| RefineError::Msg(e.to_string()))?
            .clone();
        assert_eq!(k8.chunk_rows, k1.chunk_rows);
        let chunk = k8.chunk_rows;
        // G goes through the service's device-buffer cache under the
        // scheduler-shared `gram_key`, so it uploads once per
        // (layer, device) no matter how the layer is sharded.  The
        // host copy is *lazy*: every call first sends a key-only
        // probe (`ExecInput::CachedRef` — no d² host copy built, no
        // data shipped), and only a `NotResident` miss (first shard
        // on a device, or post-eviction) packs the d*d tensor and
        // retries with the data attached.  Steady-state shards
        // therefore pay zero G-copy bytes; W chunks stay under this
        // call's own id (their rows differ per shard).
        let layer_id = next_refinement_id();
        let g_layer = self.gram_key.unwrap_or(layer_id);
        let mut g_host: Option<Arc<TensorData>> = None;
        let g_key = BufferKey {
            layer: g_layer,
            tensor: "gram".into(),
            generation: 0,
        };
        // W chunks are constant while the active row set is;
        // convergence compaction bumps the generation, invalidating
        // the per-chunk uploads (and the host-side packed copies).
        // Row indices here are shard-local (0..n_rows); only the
        // weight reads offset by `r0` into the layer.
        let mut generation: u64 = 0;
        let mut last_active: Vec<usize> = (0..n_rows).collect();
        let mut w_chunks: Vec<Option<Arc<TensorData>>> = Vec::new();

        let mut rows: Vec<RowState> = (0..n_rows).map(|_| RowState {
            used: 0,
            converged: false,
            loss_before: f64::NAN,
            loss_after: f64::NAN,
        }).collect();

        let driven = drive_segments(ctx.t_max, checkpoints, mask,
                                    |mask, budget| {
            // Use the k8 artifact while >= 8 iterations remain, else k1
            // (keeps T_max bookkeeping exact for arbitrary budgets).
            let (entry, k) = if budget >= k8.k_iters && k8.k_iters > 1 {
                (&k8, k8.k_iters)
            } else {
                (&k1, k1.k_iters)
            };
            let active: Vec<usize> = rows.iter().enumerate()
                .filter(|(_, r)| !r.converged)
                .map(|(i, _)| i)
                .collect();
            if active.is_empty() {
                // Stationary: the driver jumps to the next boundary so
                // remaining checkpoints still get recorded.
                return Ok(0);
            }
            if active != last_active {
                generation += 1;
                last_active.clone_from(&active);
                w_chunks.clear();
            }
            w_chunks.resize(active.len().div_ceil(chunk), None);
            for (gi, group) in active.chunks(chunk).enumerate() {
                // W chunk: packed once per generation (pad rows are
                // zero weights = no-op) and served from the resident
                // device buffer on later calls.
                let wc = match &w_chunks[gi] {
                    Some(t) => Arc::clone(t),
                    None => {
                        let mut m = Matrix::zeros(chunk, d);
                        for (slot, &ri) in group.iter().enumerate() {
                            m.row_mut(slot)
                                .copy_from_slice(w.row(r0 + ri));
                        }
                        let t = Arc::new(TensorData::from_matrix(&m));
                        w_chunks[gi] = Some(Arc::clone(&t));
                        t
                    }
                };
                // Mask chunk: changes every call, so packed inline
                // (pad with all-kept rows = no feasible swap, provably
                // a no-op).
                let mut mc = Matrix::from_fn(chunk, d, |_, _| 1.0);
                for (slot, &ri) in group.iter().enumerate() {
                    mc.row_mut(slot).copy_from_slice(mask.row(ri));
                }
                // Probe-then-upload: while `g_host` is unbuilt the G
                // input is a key-only probe; the one failure mode
                // (`NotResident`) packs the host copy and retries the
                // same call with the data attached.  At most one
                // retry per call — once built, `Cached` cannot miss
                // that way again.
                let out = loop {
                    let g_input = match &g_host {
                        Some(data) => ExecInput::Cached {
                            key: g_key.clone(),
                            data: Arc::clone(data),
                        },
                        None => ExecInput::CachedRef {
                            key: g_key.clone(),
                        },
                    };
                    let res = self.rt.execute_cached(&entry.name, vec![
                        ExecInput::Cached {
                            key: BufferKey {
                                layer: layer_id,
                                tensor: format!("w{gi}"),
                                generation,
                            },
                            data: Arc::clone(&wc),
                        },
                        ExecInput::Inline(TensorData::from_matrix(&mc)),
                        g_input,
                    ]);
                    match res {
                        Err(RuntimeError::NotResident(_))
                            if g_host.is_none() =>
                        {
                            g_host = Some(Arc::new(TensorData::F32 {
                                dims: vec![g.d, g.d],
                                data: g.as_slice().to_vec(),
                            }));
                        }
                        other => break other.map_err(refine_err)?,
                    }
                };
                let m_out = out[0].as_f32()
                    .map_err(|e| RefineError::Msg(e.to_string()))?;
                let l_before = out[1].as_f32()
                    .map_err(|e| RefineError::Msg(e.to_string()))?;
                let l_after = out[2].as_f32()
                    .map_err(|e| RefineError::Msg(e.to_string()))?;
                let swaps = out[3].as_f32()
                    .map_err(|e| RefineError::Msg(e.to_string()))?;
                for (slot, &ri) in group.iter().enumerate() {
                    mask.row_mut(ri)
                        .copy_from_slice(&m_out[slot * d..(slot + 1) * d]);
                    let r = &mut rows[ri];
                    if r.loss_before.is_nan() {
                        r.loss_before = l_before[slot] as f64;
                    }
                    r.loss_after = l_after[slot] as f64;
                    let s = swaps[slot] as usize;
                    r.used += s;
                    if s < k {
                        // Fewer accepted swaps than iterations executed:
                        // the row hit a 1-swap local optimum inside the
                        // call.
                        r.converged = true;
                    }
                }
            }
            // Each call executes exactly `k` iterations per active row.
            Ok(k)
        });
        // Release this call's resident W chunks whether or not the
        // drive succeeded; the LRU would reclaim them eventually,
        // releasing now keeps the budget for live work.  A shared G
        // stays resident for sibling shards (the scheduler's caller
        // releases it when the layer is done); a call-local G shares
        // `layer_id` and is released here with the chunks.
        self.rt.invalidate(layer_id);
        let snapshots = driven?;

        // Rows the loop never touched (t_max == 0, or a row that was
        // never packed into a chunk) still carry NaN sentinels.  Compute
        // their true loss explicitly — the old code collapsed these to
        // 0.0 via NaN.max(0.0), reporting zero loss where the native
        // engine reports the real one.
        for (ri, r) in rows.iter_mut().enumerate() {
            if r.loss_before.is_nan() {
                // Both sentinels are always set together by the chunk
                // loop, so this is the only recoverable state.
                let l = row_loss(w.row(r0 + ri), mask.row(ri), g);
                r.loss_before = l;
                r.loss_after = l;
            }
        }

        let layer = LayerOutcome {
            rows: rows.into_iter().map(|r| RowOutcome {
                loss_before: r.loss_before,
                loss_after: r.loss_after,
                swaps: r.used,
                converged: r.converged,
            }).collect(),
        };
        Ok(RefineOutcome { layer, snapshots })
    }
}

/// Refine every row of (w, mask) against Gram matrix g.  Returns the
/// outcome plus mask snapshots at the requested iteration checkpoints.
/// Thin wrapper over [`OffloadEngine`] kept for benches and direct
/// callers; the pipeline goes through the trait.
pub fn refine_layer_offload(
    rt: &Runtime, w: &Matrix, mask: &mut Matrix, g: &Matrix,
    pattern: Pattern, cfg: &OffloadConfig, checkpoints: &[usize],
) -> Result<(LayerOutcome, BTreeMap<usize, Matrix>), RuntimeError> {
    let ctx = LayerContext {
        w: w.view(),
        g: g.as_gram(),
        stats: None,
        pattern,
        t_max: cfg.t_max,
        threads: 1,
        gmax: None,
    };
    let out = OffloadEngine::new(rt, cfg.impl_name.clone())
        .refine(&ctx, mask, checkpoints)
        .map_err(|e| if e.is_transient() {
            RuntimeError::Transient(e.to_string())
        } else {
            RuntimeError::Msg(e.to_string())
        })?;
    Ok((out.layer, out.snapshots))
}

#[cfg(test)]
mod tests {
    #[test]
    fn config_default() {
        let c = super::OffloadConfig::default();
        assert_eq!(c.impl_name, "xla");
        assert_eq!(c.t_max, 100);
    }
}
