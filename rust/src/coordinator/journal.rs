//! Resumable prune runs: a per-block mask journal.
//!
//! The paper's central property — 1-swap refinement warmstarts from
//! *any* valid mask — makes crash recovery structurally cheap: a
//! partially refined model is itself a valid warmstart, so a prune
//! run that died between blocks can resume from its last journaled
//! block instead of starting over.  After each block the pipeline
//! appends that block's refined layer masks here; `prune --resume`
//! reloads them, skips the completed blocks (including their
//! sequential recalibration passes), and continues.  Sequential
//! recalibration is a deterministic function of (weights, masks,
//! calibration seed), so a resumed run's remaining blocks are
//! bit-identical to an uninterrupted run's — property-tested in
//! `tests/faults.rs`.
//!
//! Layout under the journal directory:
//!
//!   meta.json            {"version", "fingerprint", "model",
//!                         "n_blocks"}
//!   block_<b>.ssjb       magic "SSJB" | u32 version | u32 fingerprint
//!                        | u32 block | u32 n_layers | per layer:
//!                        u32 layer_index | u32 rows | u32 cols |
//!                        f32 LE payload | u32 crc32 trailer
//!
//! The fingerprint is a CRC32 over every config knob that changes the
//! refined masks ([`config_fingerprint`]); resuming under a different
//! config is rejected rather than silently mixing two runs' masks.
//! Mask snapshots (`--checkpoints`) are *not* journaled: a resumed
//! run restores final masks for completed blocks but re-records
//! snapshots only for the blocks it refines itself.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::coordinator::pipeline::MaskSpec;
use crate::model::checkpoint::crc32;
use crate::runtime::service::RuntimeError;
use crate::util::jsonlite::Json;
use crate::util::tensor::Matrix;

const MAGIC: &[u8; 4] = b"SSJB";
const VERSION: u32 = 1;

fn err(e: impl std::fmt::Display) -> RuntimeError {
    RuntimeError::Msg(format!("journal: {e}"))
}

/// The fingerprint's preimage: one field per [`MaskSpec`] knob.  The
/// mask-affecting knobs *are* the `MaskSpec` fields now, so the
/// struct is the single source of truth instead of a hand-maintained
/// knob list — but the serialized key (field order, names, label
/// formats) is a compatibility surface: journals written by earlier
/// versions resume only if this string is byte-identical for the same
/// knobs.  `fingerprint_domain_is_pinned` locks it.
pub fn fingerprint_key(model: &str, spec: &MaskSpec) -> String {
    format!(
        "model={};criterion={};pattern={};refiner={};t_max={};\
         calib={};sequential={};checkpoints={:?}",
        model, spec.criterion.name(), spec.pattern_kind.label(),
        spec.refiner.label(), spec.t_max, spec.calib_batches,
        spec.sequential, spec.checkpoints)
}

/// CRC32 over every config knob that changes the refined masks —
/// exactly the [`MaskSpec`] fields.  A resume under a different
/// fingerprint is rejected: the journaled masks would be a different
/// run's.  Wall-clock knobs ([`crate::coordinator::RunOptions`]:
/// threads, shard size, retry budget) are structurally excluded —
/// masks are bit-identical across them.
pub fn config_fingerprint(model: &str, spec: &MaskSpec) -> u32 {
    crc32(fingerprint_key(model, spec).as_bytes())
}

/// One prune run's journal directory handle.
pub struct Journal {
    dir: PathBuf,
    fingerprint: u32,
}

impl Journal {
    /// Start a fresh journal: wipes stale block files from any prior
    /// run in `dir` and writes `meta.json`.
    pub fn create(dir: impl AsRef<Path>, model: &str, n_blocks: usize,
                  fingerprint: u32) -> Result<Journal, RuntimeError> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).map_err(err)?;
        for entry in std::fs::read_dir(&dir).map_err(err)? {
            let path = entry.map_err(err)?.path();
            let name = path.file_name()
                .and_then(|n| n.to_str())
                .unwrap_or("");
            if name.starts_with("block_") && name.ends_with(".ssjb") {
                std::fs::remove_file(&path).map_err(err)?;
            }
        }
        let meta = Json::obj(vec![
            ("version", Json::num(VERSION as f64)),
            ("fingerprint", Json::num(fingerprint as f64)),
            ("model", Json::str(model)),
            ("n_blocks", Json::num(n_blocks as f64)),
        ]);
        std::fs::write(dir.join("meta.json"), format!("{meta}\n"))
            .map_err(err)?;
        Ok(Journal { dir, fingerprint })
    }

    /// Open an existing journal for `--resume`, validating that it
    /// was written under the same config fingerprint.
    pub fn open_resume(dir: impl AsRef<Path>, fingerprint: u32)
        -> Result<Journal, RuntimeError> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path).map_err(|e| {
            err(format!(
                "no journal to resume at {}: {e}", meta_path.display()))
        })?;
        let meta = Json::parse(&text).map_err(err)?;
        let stored = meta.get("fingerprint")
            .and_then(Json::as_f64)
            .ok_or_else(|| err("meta.json lacks a fingerprint"))?
            as u32;
        if stored != fingerprint {
            return Err(err(format!(
                "journal fingerprint mismatch (journal {stored:#x}, \
                 config {fingerprint:#x}): the journaled masks were \
                 produced under a different prune config")));
        }
        Ok(Journal { dir, fingerprint })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn block_path(&self, b: usize) -> PathBuf {
        self.dir.join(format!("block_{b}.ssjb"))
    }

    /// Journal one completed block's refined masks, keyed by the
    /// model-wide prunable-layer index.  Written via a temp file +
    /// rename so a crash mid-write never leaves a truncated block
    /// file behind (the CRC trailer catches torn writes that slip
    /// through anyway).
    pub fn record_block(&self, b: usize,
                        layer_masks: &[(usize, &Matrix)])
        -> Result<(), RuntimeError> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.fingerprint.to_le_bytes());
        buf.extend_from_slice(&(b as u32).to_le_bytes());
        buf.extend_from_slice(
            &(layer_masks.len() as u32).to_le_bytes());
        for (li, m) in layer_masks {
            buf.extend_from_slice(&(*li as u32).to_le_bytes());
            buf.extend_from_slice(&(m.rows as u32).to_le_bytes());
            buf.extend_from_slice(&(m.cols as u32).to_le_bytes());
            for &x in &m.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        let tmp = self.dir.join(format!("block_{b}.ssjb.tmp"));
        let mut f = std::fs::File::create(&tmp).map_err(err)?;
        f.write_all(&buf).map_err(err)?;
        drop(f);
        std::fs::rename(&tmp, self.block_path(b)).map_err(err)?;
        Ok(())
    }

    /// Block indices with a journaled block file, sorted.  Validity
    /// (CRC, fingerprint) is checked by [`Journal::load_block`].
    pub fn completed_blocks(&self) -> Vec<usize> {
        let mut out: Vec<usize> = Vec::new();
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return out;
        };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(b) = name.strip_prefix("block_")
                .and_then(|s| s.strip_suffix(".ssjb"))
                .and_then(|s| s.parse::<usize>().ok()) {
                out.push(b);
            }
        }
        out.sort_unstable();
        out
    }

    /// Load one journaled block's `(layer_index, mask)` list.
    pub fn load_block(&self, b: usize)
        -> Result<Vec<(usize, Matrix)>, RuntimeError> {
        let path = self.block_path(b);
        let mut buf = Vec::new();
        std::fs::File::open(&path)
            .and_then(|mut f| f.read_to_end(&mut buf))
            .map_err(|e| err(format!("{}: {e}", path.display())))?;
        if buf.len() < 24 || &buf[..4] != MAGIC {
            return Err(err(format!("{}: bad magic", path.display())));
        }
        let stored_crc = u32::from_le_bytes(
            buf[buf.len() - 4..].try_into().unwrap());
        let actual = crc32(&buf[..buf.len() - 4]);
        if stored_crc != actual {
            return Err(err(format!(
                "{}: crc mismatch (stored {stored_crc:#x}, computed \
                 {actual:#x})", path.display())));
        }
        let body = &buf[..buf.len() - 4];
        let mut pos = 4usize;
        let mut u32_at = |p: &mut usize| -> Result<u32, RuntimeError> {
            if *p + 4 > body.len() {
                return Err(err(format!(
                    "{}: truncated", path.display())));
            }
            let v = u32::from_le_bytes(
                body[*p..*p + 4].try_into().unwrap());
            *p += 4;
            Ok(v)
        };
        let version = u32_at(&mut pos)?;
        if version != VERSION {
            return Err(err(format!(
                "{}: unsupported version {version}", path.display())));
        }
        let fp = u32_at(&mut pos)?;
        if fp != self.fingerprint {
            return Err(err(format!(
                "{}: fingerprint mismatch", path.display())));
        }
        let block = u32_at(&mut pos)? as usize;
        if block != b {
            return Err(err(format!(
                "{}: holds block {block}, expected {b}",
                path.display())));
        }
        let n_layers = u32_at(&mut pos)? as usize;
        let mut out = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let li = u32_at(&mut pos)? as usize;
            let rows = u32_at(&mut pos)? as usize;
            let cols = u32_at(&mut pos)? as usize;
            let n = rows * cols;
            if pos + n * 4 > body.len() {
                return Err(err(format!(
                    "{}: truncated payload", path.display())));
            }
            let data: Vec<f32> = body[pos..pos + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += n * 4;
            out.push((li, Matrix::from_vec(rows, cols, data)));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ssjb_test_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn mask(rows: usize, cols: usize, bias: f32) -> Matrix {
        Matrix::from_fn(rows, cols, |r, c| {
            if (r + c) % 2 == 0 { 1.0 } else { bias }
        })
    }

    #[test]
    fn round_trip_blocks() {
        let dir = tmp_dir("roundtrip");
        let j = Journal::create(&dir, "tiny", 2, 0xABCD).unwrap();
        assert!(j.completed_blocks().is_empty());
        let m0 = mask(8, 6, 0.0);
        let m1 = mask(4, 6, 0.0);
        j.record_block(0, &[(0, &m0), (3, &m1)]).unwrap();
        assert_eq!(j.completed_blocks(), vec![0]);
        let got = j.load_block(0).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 0);
        assert_eq!(got[0].1.data, m0.data);
        assert_eq!(got[1].0, 3);
        assert_eq!(got[1].1.data, m1.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_validates_fingerprint() {
        let dir = tmp_dir("fingerprint");
        Journal::create(&dir, "tiny", 2, 7).unwrap();
        assert!(Journal::open_resume(&dir, 7).is_ok());
        let e = Journal::open_resume(&dir, 8).unwrap_err();
        assert!(e.to_string().contains("fingerprint mismatch"),
                "unexpected error: {e}");
        let missing = tmp_dir("fingerprint_missing");
        let e = Journal::open_resume(&missing, 7).unwrap_err();
        assert!(e.to_string().contains("no journal to resume"),
                "unexpected error: {e}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn create_wipes_stale_blocks() {
        let dir = tmp_dir("wipe");
        let j = Journal::create(&dir, "tiny", 2, 1).unwrap();
        j.record_block(1, &[(0, &mask(4, 4, 0.0))]).unwrap();
        let j2 = Journal::create(&dir, "tiny", 2, 1).unwrap();
        assert!(j2.completed_blocks().is_empty(),
                "create must wipe stale block files");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_detected() {
        let dir = tmp_dir("corrupt");
        let j = Journal::create(&dir, "tiny", 1, 9).unwrap();
        j.record_block(0, &[(0, &mask(6, 4, 0.0))]).unwrap();
        let path = dir.join("block_0.ssjb");
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let e = j.load_block(0).unwrap_err();
        assert!(e.to_string().contains("crc mismatch"),
                "unexpected error: {e}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_tracks_mask_changing_knobs() {
        let spec = MaskSpec::default();
        let a = config_fingerprint("tiny", &spec);
        assert_eq!(a, config_fingerprint("tiny", &spec));
        let mut other = spec.clone();
        other.t_max = spec.t_max + 1;
        assert_ne!(a, config_fingerprint("tiny", &other));
        assert_ne!(a, config_fingerprint("tiny2", &spec));
        let mut seq = spec.clone();
        seq.sequential = !spec.sequential;
        assert_ne!(a, config_fingerprint("tiny", &seq));
    }

    #[test]
    fn fingerprint_domain_is_pinned() {
        // Compatibility pin: journals written before the
        // MaskSpec/RunOptions split hashed this exact string, so the
        // key serialization must never drift — existing journals keep
        // resuming only while it is byte-identical.  Wall-clock knobs
        // (threads, shard size, retries) live in `RunOptions` and are
        // structurally absent.
        let spec = MaskSpec::default();
        assert_eq!(
            fingerprint_key("tiny", &spec),
            "model=tiny;criterion=wanda;pattern=60%;\
             refiner=sparseswaps[xla];t_max=100;calib=8;\
             sequential=true;checkpoints=[]");
        let spec = MaskSpec {
            criterion: crate::pruning::saliency::Criterion::Magnitude,
            pattern_kind:
                crate::coordinator::pipeline::PatternKind::Nm {
                    n: 2, m: 4,
                },
            refiner:
                crate::coordinator::pipeline::Refiner::SparseSwapsNative,
            t_max: 25,
            calib_batches: 4,
            sequential: false,
            checkpoints: vec![2, 8],
        };
        assert_eq!(
            fingerprint_key("gpt-a", &spec),
            "model=gpt-a;criterion=magnitude;pattern=2:4;\
             refiner=sparseswaps[native];t_max=25;calib=4;\
             sequential=false;checkpoints=[2, 8]");
    }
}
