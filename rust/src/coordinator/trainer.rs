//! Training loop: drives the `train_step_{cfg}` artifact (Adam + clip,
//! built by jax.grad at AOT time) from Rust.  Python never runs here —
//! optimizer state lives in host tensors threaded through executions.
//!
//! Every input rides the service's device-buffer cache
//! (`ExecInput::Cached` under one per-`train()` key space): the batch
//! tensors and the learning rate upload once and stay resident for
//! the whole run (generation 0 — batches recur every `n_batches`
//! steps), while params/m/v/step — the tensors the step actually
//! returns — bump their generation each step, so exactly the
//! returned-tensor set re-uploads per step and nothing else.  Before
//! this the loop shipped *every* input inline every step
//! (`ServiceStats::upload_bytes` is the wave-2 bench number that
//! dropped).

use std::sync::Arc;
use std::time::Instant;

use crate::data::{Dataset, Split};
use crate::model::store::ParamStore;
use crate::runtime::service::{
    BufferKey, ExecInput, Runtime, RuntimeError,
};
use crate::runtime::tensor_data::TensorData;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub n_batches: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { steps: 300, lr: 1e-3, n_batches: 32, log_every: 25 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// (step, loss) samples at `log_every` cadence plus the final step.
    pub loss_curve: Vec<(usize, f64)>,
    pub final_loss: f64,
    pub initial_loss: f64,
    pub seconds: f64,
}

pub fn train(rt: &Runtime, store: &mut ParamStore, ds: &Dataset,
             cfg: &TrainConfig) -> Result<TrainReport, RuntimeError> {
    let meta = store.meta.clone();
    let artifact = format!("train_step_{}", meta.name);
    let n_params = meta.params.len();

    // One cache key space per train() call (unique process-wide, so
    // concurrent trainers on one pool never collide); released at the
    // end.  Batches and lr live at generation 0 forever — resident
    // after their first use.  Params/m/v/step carry the step index as
    // their generation: the step returns fresh host tensors, so the
    // bump re-uploads exactly those and invalidates the stale
    // buffers.
    let train_id = crate::coordinator::swaploop::next_refinement_id();
    let key = |tensor: String, generation: u64| BufferKey {
        layer: train_id,
        tensor,
        generation,
    };
    let batches: Vec<(Arc<TensorData>, Arc<TensorData>)> =
        ds.batches(&meta, Split::Train, cfg.n_batches)
        .into_iter()
        .map(|(t, g)| (Arc::new(t), Arc::new(g)))
        .collect();
    let arcs = |ts: Vec<TensorData>| -> Vec<Arc<TensorData>> {
        ts.into_iter().map(Arc::new).collect()
    };
    // The store's tensors are already Arc-shared; the store is written
    // back on success only, so an error mid-run leaves it untouched.
    let mut params = store.tensors.clone();
    let mut m = ParamStore::zeros_like(&meta).tensors;
    let mut v = ParamStore::zeros_like(&meta).tensors;
    let mut step = Arc::new(TensorData::scalar_i32(0));
    let lr = Arc::new(TensorData::scalar_f32(cfg.lr));

    let t0 = Instant::now();
    let mut report = TrainReport::default();
    for s in 0..cfg.steps {
        let gen = s as u64;
        let bi = s % batches.len();
        let (tokens, targets) = &batches[bi];
        let mut inputs = Vec::with_capacity(3 * n_params + 4);
        let cached = |tensor: String, gen: u64, t: &Arc<TensorData>| {
            ExecInput::Cached {
                key: key(tensor, gen),
                data: Arc::clone(t),
            }
        };
        for (i, p) in params.iter().enumerate() {
            inputs.push(cached(format!("p{i}"), gen, p));
        }
        for (i, t) in m.iter().enumerate() {
            inputs.push(cached(format!("m{i}"), gen, t));
        }
        for (i, t) in v.iter().enumerate() {
            inputs.push(cached(format!("v{i}"), gen, t));
        }
        inputs.push(cached("step".into(), gen, &step));
        inputs.push(cached(format!("tok{bi}"), 0, tokens));
        inputs.push(cached(format!("tgt{bi}"), 0, targets));
        inputs.push(cached("lr".into(), 0, &lr));
        let mut out = rt.execute_cached(&artifact, inputs)?;
        // outputs: params.., m.., v.., step, loss
        let loss = out.pop().unwrap().scalar_value()?;
        step = Arc::new(out.pop().unwrap());
        let vs = out.split_off(2 * n_params);
        let ms = out.split_off(n_params);
        params = arcs(out);
        m = arcs(ms);
        v = arcs(vs);
        if s == 0 {
            report.initial_loss = loss;
        }
        if s % cfg.log_every == 0 || s + 1 == cfg.steps {
            report.loss_curve.push((s, loss));
            crate::log_info!("train[{}] step {s}/{} loss {loss:.4}",
                             meta.name, cfg.steps);
        }
        report.final_loss = loss;
    }
    store.tensors = params;
    rt.invalidate(train_id);
    report.seconds = t0.elapsed().as_secs_f64();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = TrainConfig::default();
        assert!(c.steps > 0 && c.lr > 0.0 && c.n_batches > 0);
    }
}
