//! Training loop: drives the `train_step_{cfg}` artifact (Adam + clip,
//! built by jax.grad at AOT time) from Rust.  Python never runs here —
//! optimizer state lives in host tensors threaded through executions.

use std::time::Instant;

use crate::data::{Dataset, Split};
use crate::model::store::ParamStore;
use crate::runtime::service::{Runtime, RuntimeError};
use crate::runtime::tensor_data::TensorData;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub n_batches: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { steps: 300, lr: 1e-3, n_batches: 32, log_every: 25 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// (step, loss) samples at `log_every` cadence plus the final step.
    pub loss_curve: Vec<(usize, f64)>,
    pub final_loss: f64,
    pub initial_loss: f64,
    pub seconds: f64,
}

pub fn train(rt: &Runtime, store: &mut ParamStore, ds: &Dataset,
             cfg: &TrainConfig) -> Result<TrainReport, RuntimeError> {
    let meta = store.meta.clone();
    let artifact = format!("train_step_{}", meta.name);
    let n_params = meta.params.len();
    let batches = ds.batches(&meta, Split::Train, cfg.n_batches);

    let mut m = ParamStore::zeros_like(&meta).tensors;
    let mut v = ParamStore::zeros_like(&meta).tensors;
    let mut step = TensorData::scalar_i32(0);
    let lr = TensorData::scalar_f32(cfg.lr);

    let t0 = Instant::now();
    let mut report = TrainReport::default();
    for s in 0..cfg.steps {
        let (tokens, targets) = &batches[s % batches.len()];
        let mut inputs = Vec::with_capacity(3 * n_params + 4);
        inputs.extend(store.tensors.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(step.clone());
        inputs.push(tokens.clone());
        inputs.push(targets.clone());
        inputs.push(lr.clone());
        let mut out = rt.execute(&artifact, inputs)?;
        // outputs: params.., m.., v.., step, loss
        let loss = out.pop().unwrap().scalar_value()?;
        step = out.pop().unwrap();
        let vs = out.split_off(2 * n_params);
        let ms = out.split_off(n_params);
        store.tensors = out;
        m = ms;
        v = vs;
        if s == 0 {
            report.initial_loss = loss;
        }
        if s % cfg.log_every == 0 || s + 1 == cfg.steps {
            report.loss_curve.push((s, loss));
            crate::log_info!("train[{}] step {s}/{} loss {loss:.4}",
                             meta.name, cfg.steps);
        }
        report.final_loss = loss;
    }
    report.seconds = t0.elapsed().as_secs_f64();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = TrainConfig::default();
        assert!(c.steps > 0 && c.lr > 0.0 && c.n_batches > 0);
    }
}
