//! The pruning pipeline: the paper's full procedure over a transformer.
//!
//!   for each block (sequential mode — earlier blocks already masked):
//!     run calibration through the masked model, accumulating the four
//!       Gram streams for the block's layers;
//!     for each prunable layer:
//!       warmstart mask (magnitude / Wanda / RIA — computed natively
//!         from W and diag(G));
//!       refinement: SparseSwaps (offload via HLO swap artifacts, or the
//!         native Rust engine), DSnoT, or none;
//!       record exact per-layer loss before/after and apply the mask.
//!
//! One-shot mode instead calibrates once on the dense model and prunes
//! every block from those statistics (Wanda-style; cheaper, slightly
//! worse).  Both modes exist because the paper's baselines differ in
//! this respect and the ablation benches compare them.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::coordinator::swaploop::{refine_layer_offload, OffloadConfig};
use crate::data::{Dataset, Split};
use crate::gram::{accumulate, GramStats};
use crate::model::store::{MaskSet, ParamStore};
use crate::pruning::dsnot::{self, DsnotConfig};
use crate::pruning::error::relative_reduction;
use crate::pruning::mask::{mask_from_scores, validate, Pattern};
use crate::pruning::saliency::{self, Criterion};
use crate::pruning::sparseswaps::{self, SwapConfig};
use crate::runtime::service::{Runtime, RuntimeError};
use crate::util::threadpool::default_threads;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Refiner {
    /// Warmstart only.
    None,
    /// SparseSwaps through the HLO artifacts (production path).
    SparseSwapsOffload { impl_name: String },
    /// SparseSwaps through the pure-Rust engine (reference path).
    SparseSwapsNative,
    /// The DSnoT baseline.
    Dsnot,
}

impl Refiner {
    pub fn label(&self) -> String {
        match self {
            Refiner::None => "none".into(),
            Refiner::SparseSwapsOffload { impl_name } =>
                format!("sparseswaps[{impl_name}]"),
            Refiner::SparseSwapsNative => "sparseswaps[native]".into(),
            Refiner::Dsnot => "dsnot".into(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct PruneConfig {
    pub criterion: Criterion,
    pub pattern_kind: PatternKind,
    pub refiner: Refiner,
    pub t_max: usize,
    pub calib_batches: usize,
    /// Sequential (per-block re-calibration on the masked model) vs
    /// one-shot (single dense calibration pass).
    pub sequential: bool,
    /// Mask snapshots at these cumulative iteration counts (Table 3).
    pub checkpoints: Vec<usize>,
    pub threads: usize,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PatternKind {
    Unstructured { sparsity: f64 },
    Nm { n: usize, m: usize },
}

impl PatternKind {
    pub fn pattern_for(&self, d_in: usize) -> Pattern {
        match *self {
            PatternKind::Unstructured { sparsity } =>
                Pattern::per_row_sparsity(d_in, sparsity),
            PatternKind::Nm { n, m } => Pattern::Nm { n, m },
        }
    }

    pub fn label(&self) -> String {
        match *self {
            PatternKind::Unstructured { sparsity } =>
                format!("{:.0}%", sparsity * 100.0),
            PatternKind::Nm { n, m } => format!("{n}:{m}"),
        }
    }
}

impl Default for PruneConfig {
    fn default() -> Self {
        Self {
            criterion: Criterion::Wanda,
            pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
            refiner: Refiner::SparseSwapsOffload {
                impl_name: "xla".into(),
            },
            t_max: 100,
            calib_batches: 8,
            sequential: true,
            checkpoints: Vec::new(),
            threads: default_threads(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub layer_type: String,
    pub block: usize,
    pub loss_warmstart: f64,
    pub loss_refined: f64,
    pub swaps: usize,
    pub rows_converged: usize,
    pub rows: usize,
    pub seconds: f64,
}

impl LayerReport {
    pub fn relative_reduction(&self) -> f64 {
        relative_reduction(self.loss_warmstart, self.loss_refined)
    }
}

#[derive(Clone, Debug, Default)]
pub struct PruneReport {
    pub layers: Vec<LayerReport>,
    pub calib_seconds: f64,
    pub refine_seconds: f64,
    pub warmstart_seconds: f64,
    /// Mask snapshots per checkpoint (whole-model MaskSets).
    pub snapshots: BTreeMap<usize, MaskSet>,
}

impl PruneReport {
    pub fn total_warmstart_loss(&self) -> f64 {
        self.layers.iter().map(|l| l.loss_warmstart).sum()
    }

    pub fn total_refined_loss(&self) -> f64 {
        self.layers.iter().map(|l| l.loss_refined).sum()
    }

    /// Mean over layers of the per-layer relative reduction (the paper's
    /// Table 3/4 "average relative error reduction").
    pub fn mean_relative_reduction(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.relative_reduction()).sum::<f64>()
            / self.layers.len() as f64
    }
}

/// Run the pruning pipeline.  `store` keeps its dense weights; the
/// resulting masks are returned (apply with `store.masked(&masks)`).
pub fn prune(rt: &Runtime, store: &ParamStore, ds: &Dataset,
             cfg: &PruneConfig) -> Result<(MaskSet, PruneReport),
                                          RuntimeError> {
    let meta = store.meta.clone();
    let calib = ds.batches(&meta, Split::Calibration, cfg.calib_batches);
    let mut masks = MaskSet::all_ones(&meta);
    let mut report = PruneReport::default();
    for &cp in &cfg.checkpoints {
        report.snapshots.insert(cp, MaskSet::all_ones(&meta));
    }

    let blocks: Vec<usize> = (0..meta.n_blocks).collect();
    let mut stats_oneshot: Option<GramStats> = None;
    if !cfg.sequential {
        let t0 = Instant::now();
        stats_oneshot = Some(accumulate(rt, store, &calib)?);
        report.calib_seconds += t0.elapsed().as_secs_f64();
    }

    for &b in &blocks {
        let stats = if cfg.sequential {
            // Recalibrate with everything pruned so far applied.
            let t0 = Instant::now();
            let masked = store.masked(&masks);
            let s = accumulate(rt, &masked, &calib)?;
            report.calib_seconds += t0.elapsed().as_secs_f64();
            s
        } else {
            stats_oneshot.clone().unwrap()
        };

        let layers: Vec<_> = meta.prunable.iter().enumerate()
            .filter(|(_, l)| l.block == b)
            .map(|(i, l)| (i, l.clone()))
            .collect();
        for (li, layer) in layers {
            let w = store.weight(&layer);
            let g = stats.gram_for(&layer);
            let pattern = cfg.pattern_kind.pattern_for(layer.d_in);

            let t0 = Instant::now();
            let scores = saliency::scores(cfg.criterion, &w, &g.diag());
            let mut mask = mask_from_scores(&scores, pattern);
            report.warmstart_seconds += t0.elapsed().as_secs_f64();

            let t1 = Instant::now();
            let mut layer_report = LayerReport {
                name: layer.name.clone(),
                layer_type: layer.layer_type.clone(),
                block: layer.block,
                loss_warmstart: 0.0,
                loss_refined: 0.0,
                swaps: 0,
                rows_converged: 0,
                rows: layer.d_out,
                seconds: 0.0,
            };
            match &cfg.refiner {
                Refiner::None => {
                    let loss = crate::pruning::error::layer_loss(
                        &w, &mask, &g);
                    layer_report.loss_warmstart = loss;
                    layer_report.loss_refined = loss;
                }
                Refiner::SparseSwapsOffload { impl_name } => {
                    let ocfg = OffloadConfig {
                        impl_name: impl_name.clone(),
                        t_max: cfg.t_max,
                    };
                    let (outcome, snaps) = refine_layer_offload(
                        rt, &w, &mut mask, &g, pattern, &ocfg,
                        &cfg.checkpoints)?;
                    layer_report.loss_warmstart = outcome.total_before();
                    layer_report.loss_refined = outcome.total_after();
                    layer_report.swaps = outcome.total_swaps();
                    layer_report.rows_converged = outcome.rows.iter()
                        .filter(|r| r.converged).count();
                    for (cp, snap) in snaps {
                        if let Some(ms) = report.snapshots.get_mut(&cp) {
                            ms.masks[li] = snap;
                        }
                    }
                }
                Refiner::SparseSwapsNative => {
                    // Segment the budget at checkpoint boundaries so the
                    // native engine supports Table-3 style snapshots too
                    // (restarting refine_layer is exact: c is recomputed
                    // from the current mask each call).
                    let mut stops: Vec<usize> = cfg.checkpoints.iter()
                        .copied().filter(|&c| c <= cfg.t_max).collect();
                    stops.push(cfg.t_max);
                    stops.sort_unstable();
                    stops.dedup();
                    let mut done = 0usize;
                    let mut first: Option<Vec<f64>> = None;
                    let mut total_swaps = 0usize;
                    let mut last_outcome = None;
                    for &stop in &stops {
                        if stop > done {
                            let scfg = SwapConfig { t_max: stop - done,
                                                    eps: 0.0 };
                            let outcome = sparseswaps::refine_layer(
                                &w, &mut mask, &g, pattern, &scfg,
                                cfg.threads);
                            if first.is_none() {
                                first = Some(outcome.rows.iter()
                                    .map(|r| r.loss_before).collect());
                            }
                            total_swaps += outcome.total_swaps();
                            last_outcome = Some(outcome);
                            done = stop;
                        }
                        if cfg.checkpoints.contains(&stop) {
                            if let Some(ms) =
                                report.snapshots.get_mut(&stop) {
                                ms.masks[li] = mask.clone();
                            }
                        }
                    }
                    let outcome = last_outcome.expect("t_max > 0");
                    layer_report.loss_warmstart = first
                        .map(|f| f.iter().sum())
                        .unwrap_or_default();
                    layer_report.loss_refined = outcome.total_after();
                    layer_report.swaps = total_swaps;
                    layer_report.rows_converged = outcome.rows.iter()
                        .filter(|r| r.converged).count();
                }
                Refiner::Dsnot => {
                    let before = crate::pruning::error::layer_loss(
                        &w, &mask, &g);
                    let fstats = stats.feature_stats_for(&layer);
                    dsnot::refine_layer(&w, &mut mask, &fstats, pattern,
                                        &DsnotConfig::default());
                    layer_report.loss_warmstart = before;
                    layer_report.loss_refined =
                        crate::pruning::error::layer_loss(&w, &mask, &g);
                }
            }
            layer_report.seconds = t1.elapsed().as_secs_f64();
            report.refine_seconds += layer_report.seconds;

            validate(&mask, pattern)
                .map_err(|e| RuntimeError::Msg(format!(
                    "{}: {e}", layer.name)))?;
            crate::log_debug!(
                "prune[{}] {} loss {:.4} -> {:.4} ({:+.1}%)",
                meta.name, layer.name, layer_report.loss_warmstart,
                layer_report.loss_refined,
                -100.0 * layer_report.relative_reduction());
            masks.masks[li] = mask;
            report.layers.push(layer_report);
        }
    }
    // Checkpoint snapshots cover layers only up to their capture point;
    // fill the remainder with the final masks so each snapshot is a
    // complete, valid model mask.
    let final_masks = masks.clone();
    for (_, snap) in report.snapshots.iter_mut() {
        for (i, m) in snap.masks.iter_mut().enumerate() {
            if m.data.iter().all(|&v| v == 1.0) {
                *m = final_masks.masks[i].clone();
            }
        }
    }
    Ok((masks, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(Refiner::None.label(), "none");
        assert_eq!(Refiner::SparseSwapsOffload { impl_name: "xla".into() }
                   .label(), "sparseswaps[xla]");
        assert_eq!(PatternKind::Unstructured { sparsity: 0.6 }.label(),
                   "60%");
        assert_eq!(PatternKind::Nm { n: 2, m: 4 }.label(), "2:4");
    }

    #[test]
    fn pattern_for_width() {
        let pk = PatternKind::Unstructured { sparsity: 0.5 };
        assert_eq!(pk.pattern_for(64), Pattern::PerRow { keep: 32 });
        let nm = PatternKind::Nm { n: 2, m: 4 };
        assert_eq!(nm.pattern_for(64), Pattern::Nm { n: 2, m: 4 });
    }
}
