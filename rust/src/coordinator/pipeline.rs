//! The pruning pipeline: the paper's full procedure over a transformer.
//!
//!   for each block (sequential mode — earlier blocks already masked):
//!     run calibration through the masked model, accumulating the four
//!       Gram streams for the block's layers;
//!     for each prunable layer:
//!       warmstart mask (magnitude / Wanda / RIA — computed natively
//!         from W and diag(G));
//!       refinement through the layer's [`RefineEngine`] (SparseSwaps
//!         offload or native, DSnoT, or none);
//!       record exact per-layer loss before/after and apply the mask.
//!
//! Refinement is embarrassingly parallel across rows *and* layers
//! (the paper's row decoupling, once the block's Gram statistics are
//! fixed), so the scheduling grain is the row *shard*
//! ([`crate::coordinator::scheduler::Shard`]), not the layer: a block
//! becomes one list of shards fanned across workers through the one
//! [`refine_block`] dispatch path — host [`ThreadPool`] workers for
//! the runtime-free engines, the [`RuntimePool`]'s device workers for
//! the offload engine.  Adaptive sharding splits the long-tail layer
//! (an MLP down-projection has ~4x the rows of an attention
//! projection) across otherwise-idle workers.  Per-row results are
//! independent of scheduling, so masks and snapshots are
//! bit-identical to the whole-layer serial schedule for every shard
//! size and worker count.
//!
//! One-shot mode instead calibrates once on the dense model and prunes
//! every block from those statistics (Wanda-style; cheaper, slightly
//! worse).  Both modes exist because the paper's baselines differ in
//! this respect and the ablation benches compare them.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::coordinator::journal::{config_fingerprint, Journal};
use crate::coordinator::scheduler::{
    refine_block, BlockSchedule, LayerWork, Scheduler, ShardedLayer,
    WorkerCtx,
};
use crate::coordinator::swaploop::OffloadEngine;
use crate::data::{Dataset, Split};
use crate::gram::{accumulate, GramStats};
use crate::model::store::{MaskSet, ParamStore};
use crate::pruning::dsnot::DsnotEngine;
use crate::pruning::engine::{NoopEngine, RefineEngine};
use crate::pruning::error::relative_reduction;
use crate::pruning::mask::{mask_from_scores, validate, Pattern};
use crate::pruning::saliency::{self, Criterion};
use crate::pruning::sparseswaps::NativeEngine;
use crate::runtime::pool::RuntimePool;
use crate::runtime::service::{Runtime, RuntimeError};
use crate::util::threadpool::{default_threads, ThreadPool};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Refiner {
    /// Warmstart only.
    None,
    /// SparseSwaps through the HLO artifacts (production path).
    SparseSwapsOffload { impl_name: String },
    /// SparseSwaps through the pure-Rust engine (reference path).
    SparseSwapsNative,
    /// The DSnoT baseline.
    Dsnot,
}

impl Refiner {
    pub fn label(&self) -> String {
        match self {
            Refiner::None => "none".into(),
            Refiner::SparseSwapsOffload { impl_name } =>
                format!("sparseswaps[{impl_name}]"),
            Refiner::SparseSwapsNative => "sparseswaps[native]".into(),
            Refiner::Dsnot => "dsnot".into(),
        }
    }

    /// Engine construction for one shard job, bound to the worker the
    /// scheduler placed it on.  Runtime-free engines delegate to the
    /// single [`Self::local_engine`] registry (adding one means one
    /// constructor line there); the offload engine binds to the
    /// worker's runtime and the layer's shared Gram buffer key.
    pub fn shard_engine<'a>(&self, worker: &WorkerCtx<'a>,
                            gram_key: u64)
        -> Result<Box<dyn RefineEngine + 'a>, String> {
        match self {
            Refiner::SparseSwapsOffload { impl_name } => match worker {
                WorkerCtx::Device(rt) => Ok(Box::new(
                    OffloadEngine::with_gram_key(*rt,
                                                 impl_name.clone(),
                                                 gram_key))),
                WorkerCtx::Host => Err(
                    "offload refiner scheduled on a host worker \
                     (needs a runtime-pool scheduler)".into()),
            },
            local => {
                let engine: Box<dyn RefineEngine> = local
                    .local_engine()
                    .expect("non-offload refiners are runtime-free");
                Ok(engine)
            }
        }
    }

    /// Runtime-free engine construction; `None` for engines that need
    /// a device worker (offload holds the runtime handle).
    fn local_engine(&self) -> Option<Box<dyn RefineEngine + Send>> {
        match self {
            Refiner::None => Some(Box::new(NoopEngine)),
            Refiner::SparseSwapsNative =>
                Some(Box::new(NativeEngine::default())),
            Refiner::Dsnot => Some(Box::new(DsnotEngine::default())),
            Refiner::SparseSwapsOffload { .. } => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct PruneConfig {
    pub criterion: Criterion,
    pub pattern_kind: PatternKind,
    pub refiner: Refiner,
    pub t_max: usize,
    pub calib_batches: usize,
    /// Sequential (per-block re-calibration on the masked model) vs
    /// one-shot (single dense calibration pass).
    pub sequential: bool,
    /// Mask snapshots at these cumulative iteration counts (Table 3).
    pub checkpoints: Vec<usize>,
    pub threads: usize,
    /// Schedule independent row shards of a block concurrently:
    /// runtime-free engines on the thread pool, the offload engine
    /// across the runtime pool's device workers.  Masks are identical
    /// either way; disable to get per-layer wall-clock timings
    /// (shards then cover whole layers and dispatch one at a time).
    pub layer_parallel: bool,
    /// Rows per refinement shard work unit; 0 = adaptive
    /// (≈ block rows / (4 x workers), aligned per layer to the
    /// offload chunk shape).  Masks and snapshots are bit-identical
    /// for every value.
    pub shard_rows: usize,
    /// Per-shard redispatch budget for transient worker failures
    /// ([`BlockSchedule::max_retries`]; deterministic failures never
    /// retry).
    pub max_shard_retries: usize,
    /// Journal directory for resumable runs: after each block the
    /// refined masks land in `<dir>/block_<b>.ssjb`
    /// ([`crate::coordinator::journal`]).  `None` disables
    /// journaling (and resume).
    pub journal: Option<PathBuf>,
    /// Resume from the journal instead of starting fresh: completed
    /// blocks' masks are restored and skipped (including their
    /// sequential recalibration); refinement continues at the first
    /// unjournaled block.  Rejected if the journal was written under
    /// a different config fingerprint.
    pub resume: bool,
    /// Test hook: stop cleanly after journaling this block,
    /// simulating a crash between blocks (the resume tests drive the
    /// kill-then-`--resume` path through this under plain
    /// `cargo test`).
    pub halt_after_block: Option<usize>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PatternKind {
    Unstructured { sparsity: f64 },
    Nm { n: usize, m: usize },
}

impl PatternKind {
    pub fn pattern_for(&self, d_in: usize) -> Pattern {
        match *self {
            PatternKind::Unstructured { sparsity } =>
                Pattern::per_row_sparsity(d_in, sparsity),
            PatternKind::Nm { n, m } => Pattern::Nm { n, m },
        }
    }

    pub fn label(&self) -> String {
        match *self {
            PatternKind::Unstructured { sparsity } =>
                format!("{:.0}%", sparsity * 100.0),
            PatternKind::Nm { n, m } => format!("{n}:{m}"),
        }
    }
}

impl Default for PruneConfig {
    fn default() -> Self {
        Self {
            criterion: Criterion::Wanda,
            pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
            refiner: Refiner::SparseSwapsOffload {
                impl_name: "xla".into(),
            },
            t_max: 100,
            calib_batches: 8,
            sequential: true,
            checkpoints: Vec::new(),
            threads: default_threads(),
            layer_parallel: true,
            shard_rows: 0,
            max_shard_retries: 2,
            journal: None,
            resume: false,
            halt_after_block: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub layer_type: String,
    pub block: usize,
    pub loss_warmstart: f64,
    pub loss_refined: f64,
    pub swaps: usize,
    pub rows_converged: usize,
    pub rows: usize,
    pub seconds: f64,
}

impl LayerReport {
    pub fn relative_reduction(&self) -> f64 {
        relative_reduction(self.loss_warmstart, self.loss_refined)
    }
}

#[derive(Clone, Debug, Default)]
pub struct PruneReport {
    pub layers: Vec<LayerReport>,
    pub calib_seconds: f64,
    /// Summed per-layer refinement time (CPU seconds under the
    /// layer-parallel schedule, wall seconds under the serial one).
    pub refine_seconds: f64,
    pub warmstart_seconds: f64,
    /// Mask snapshots per checkpoint (whole-model MaskSets).
    pub snapshots: BTreeMap<usize, MaskSet>,
}

impl PruneReport {
    pub fn total_warmstart_loss(&self) -> f64 {
        self.layers.iter().map(|l| l.loss_warmstart).sum()
    }

    pub fn total_refined_loss(&self) -> f64 {
        self.layers.iter().map(|l| l.loss_refined).sum()
    }

    /// Mean over layers of the per-layer relative reduction (the paper's
    /// Table 3/4 "average relative error reduction").
    pub fn mean_relative_reduction(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.relative_reduction()).sum::<f64>()
            / self.layers.len() as f64
    }
}

/// Run the pruning pipeline.  `store` keeps its dense weights; the
/// resulting masks are returned (apply with `store.masked(&masks)`).
///
/// Serial stages (calibration, warmstarts) run on the pool's primary
/// runtime; refinement goes through the one shard dispatch path
/// ([`refine_block`]): row shards fan across the host thread pool
/// (runtime-free engines) or the runtime pool's device workers
/// (offload).  Masks and snapshots are bit-identical for every shard
/// size and worker count (disable `layer_parallel` for per-layer
/// wall-clock timings).
///
/// Fault tolerance: transiently failed shards are redispatched (up to
/// `PruneConfig::max_shard_retries` per shard, on a different worker
/// where possible); if every device worker ends up quarantined the
/// run degrades to the native host refiner instead of aborting.  With
/// `PruneConfig::journal` set, each block's refined masks are
/// journaled so an interrupted run can resume
/// (`PruneConfig::resume`) with bit-identical results.  A resumed
/// run's report covers only the blocks it refined itself, and
/// snapshots are re-recorded only for those blocks (restored blocks
/// contribute their *final* masks to the backfill).
pub fn prune(pool: &RuntimePool, store: &ParamStore, ds: &Dataset,
             cfg: &PruneConfig) -> Result<(MaskSet, PruneReport),
                                          RuntimeError> {
    let rt: &Runtime = pool.primary();
    let meta = store.meta.clone();
    let calib = ds.batches(&meta, Split::Calibration, cfg.calib_batches);
    let mut masks = MaskSet::all_ones(&meta);
    let mut report = PruneReport::default();
    // Snapshot capture is tracked explicitly per (checkpoint, layer):
    // `None` means "not captured yet" and is backfilled with the final
    // layer mask at the end.  (The old implementation used "mask is
    // all-ones" as the not-captured sentinel, which clobbered
    // legitimately dense snapshots.)
    let n_layers = meta.prunable.len();
    let mut captured: BTreeMap<usize,
                               Vec<Option<crate::util::tensor::Matrix>>> =
        cfg.checkpoints.iter()
            .map(|&cp| (cp, (0..n_layers).map(|_| None).collect()))
            .collect();

    // One shard dispatch path for every refiner: the scheduler is the
    // device pool for the offload engine and a host thread pool for
    // the runtime-free engines; the shard plan does the rest.
    let offload =
        matches!(cfg.refiner, Refiner::SparseSwapsOffload { .. });
    let host_workers = if cfg.layer_parallel {
        cfg.threads.max(1)
    } else {
        1
    };
    let thread_pool = (!offload).then(|| ThreadPool::new(host_workers));
    let sched: &dyn Scheduler = match &thread_pool {
        Some(tp) => tp,
        None => pool,
    };
    let plan = BlockSchedule {
        t_max: cfg.t_max,
        // Under a multi-worker scheduler parallelism comes from the
        // shards themselves; the serial schedule keeps the engines'
        // internal row threads instead.
        threads_per_shard: if cfg.layer_parallel {
            1
        } else {
            cfg.threads.max(1)
        },
        checkpoints: cfg.checkpoints.clone(),
        shard_rows: if cfg.layer_parallel {
            cfg.shard_rows
        } else {
            // Whole-layer shards keep per-layer timings meaningful.
            usize::MAX
        },
        serial: !cfg.layer_parallel,
        max_retries: cfg.max_shard_retries,
    };

    // Resumable runs: journal each block's refined masks, and on
    // `--resume` restore the completed blocks instead of recomputing
    // them.  The restored masks reproduce the exact model state the
    // interrupted run had, so the remaining blocks' sequential
    // recalibration — and therefore their masks — are bit-identical
    // to an uninterrupted run's.
    let fingerprint = config_fingerprint(&meta.name, cfg);
    let journal = match &cfg.journal {
        Some(dir) if cfg.resume =>
            Some(Journal::open_resume(dir, fingerprint)?),
        Some(dir) => Some(Journal::create(dir, &meta.name,
                                          meta.n_blocks, fingerprint)?),
        None if cfg.resume => {
            return Err(RuntimeError::Msg(
                "resume requires a journal directory".into()));
        }
        None => None,
    };
    let mut completed: Vec<usize> = Vec::new();
    if cfg.resume {
        let j = journal.as_ref().expect("resume checked above");
        for b in j.completed_blocks() {
            for (li, mask) in j.load_block(b)? {
                masks.masks[li] = mask;
            }
            completed.push(b);
        }
        crate::log_debug!(
            "prune[{}] resume: restored {} journaled block(s)",
            meta.name, completed.len());
    }

    // Graceful degradation: when every device worker has been
    // quarantined the offload path cannot make progress, so the rest
    // of the run falls back to the native host engine (bit-identical
    // masks for the interp backend; gated in the wave-2 bench for the
    // offload parity in general).
    let native = Refiner::SparseSwapsNative;
    let mut degraded = false;
    let mut fallback_pool: Option<ThreadPool> = None;

    let blocks: Vec<usize> = (0..meta.n_blocks).collect();
    let mut stats_oneshot: Option<GramStats> = None;
    if !cfg.sequential {
        let t0 = Instant::now();
        stats_oneshot = Some(accumulate(rt, store, &calib)?);
        report.calib_seconds += t0.elapsed().as_secs_f64();
    }

    for &b in &blocks {
        if completed.contains(&b) {
            continue;
        }
        // Borrow (never clone) the Gram statistics: layer jobs hold
        // zero-copy views into this block's stream stacks.
        let stats_block;
        let stats: &GramStats = if cfg.sequential {
            // Recalibrate with everything pruned so far applied.
            let t0 = Instant::now();
            let masked = store.masked(&masks);
            stats_block = accumulate(rt, &masked, &calib)?;
            report.calib_seconds += t0.elapsed().as_secs_f64();
            &stats_block
        } else {
            stats_oneshot.as_ref().expect("one-shot stats computed")
        };

        let layers: Vec<_> = meta.prunable.iter().enumerate()
            .filter(|(_, l)| l.block == b)
            .map(|(i, l)| (i, l.clone()))
            .collect();

        // Warmstart every layer first (cheap, serial), then refine
        // the whole block through the shard dispatch.
        let mut works = Vec::with_capacity(layers.len());
        for (li, layer) in layers {
            let w = store.weight(&layer);
            let g = stats.gram_for(&layer);
            let pattern = cfg.pattern_kind.pattern_for(layer.d_in);
            let t0 = Instant::now();
            let scores = saliency::scores(cfg.criterion, &w, &g.diag());
            let warm = mask_from_scores(&scores, pattern);
            report.warmstart_seconds += t0.elapsed().as_secs_f64();
            let fstats = if cfg.refiner == Refiner::Dsnot {
                Some(stats.feature_stats_for(&layer))
            } else {
                None
            };
            // Adaptive shard sizes align to the offload chunk shape
            // so no shard pays a padded half-chunk.
            let shard_align = match &cfg.refiner {
                Refiner::SparseSwapsOffload { impl_name }
                    if !degraded => rt
                    .manifest()
                    .find_swap_artifact(layer.d_in,
                                        &pattern.artifact_tag(),
                                        impl_name, 8)
                    .map(|e| e.chunk_rows)
                    .unwrap_or(1),
                _ => 1,
            };
            works.push(LayerWork {
                li,
                label: layer.name.clone(),
                w,
                g,
                stats: fstats,
                pattern,
                warm,
                shard_align,
                gram_key: crate::coordinator::swaploop::
                    next_refinement_id(),
            });
        }

        let (refiner_b, sched_b): (&Refiner, &dyn Scheduler) =
            if degraded {
                (&native,
                 fallback_pool.as_ref().expect("degraded pool built"))
            } else {
                (&cfg.refiner, sched)
            };
        let results = refine_block(sched_b, refiner_b, &works, &plan);

        // Release the block's shared Gram buffers on every device
        // before propagating any error (shards leave them resident
        // for their siblings; the block is done — or dead — now, so
        // the budget goes back to live layers either way).
        if offload && !degraded {
            for work in &works {
                for d in 0..pool.devices() {
                    pool.runtime(d).invalidate(work.gram_key);
                }
            }
        }
        let results = match results {
            Ok(r) => r,
            Err(e) if offload && !degraded
                && pool.workers_quarantined()
                    >= pool.devices() as u64 => {
                eprintln!(
                    "prune: all {} device worker(s) quarantined \
                     ({e}); degrading to the native host refiner",
                    pool.devices());
                degraded = true;
                fallback_pool = Some(ThreadPool::new(host_workers));
                refine_block(
                    fallback_pool.as_ref().expect("just built"),
                    &native, &works, &plan)?
            }
            Err(e) => return Err(e),
        };

        for res in results {
            let ShardedLayer { li, mask, outcome, seconds, .. } = res;
            let layer = &meta.prunable[li];
            let pattern = cfg.pattern_kind.pattern_for(layer.d_in);
            report.refine_seconds += seconds;
            validate(&mask, pattern)
                .map_err(|e| RuntimeError::Msg(format!(
                    "{}: {e}", layer.name)))?;
            let lr = LayerReport {
                name: layer.name.clone(),
                layer_type: layer.layer_type.clone(),
                block: layer.block,
                loss_warmstart: outcome.layer.total_before(),
                loss_refined: outcome.layer.total_after(),
                swaps: outcome.layer.total_swaps(),
                rows_converged: outcome.layer.rows_converged(),
                rows: layer.d_out,
                seconds,
            };
            crate::log_debug!(
                "prune[{}] {} loss {:.4} -> {:.4} ({:+.1}%)",
                meta.name, lr.name, lr.loss_warmstart, lr.loss_refined,
                -100.0 * lr.relative_reduction());
            for (cp, snap) in outcome.snapshots {
                if let Some(slots) = captured.get_mut(&cp) {
                    slots[li] = Some(snap);
                }
            }
            masks.masks[li] = mask;
            report.layers.push(lr);
        }

        if let Some(j) = &journal {
            let layer_masks: Vec<_> = works.iter()
                .map(|w| (w.li, &masks.masks[w.li]))
                .collect();
            j.record_block(b, &layer_masks)?;
        }
        if cfg.halt_after_block == Some(b) {
            crate::log_debug!(
                "prune[{}] halting after block {b} (test hook)",
                meta.name);
            break;
        }
    }

    // Each snapshot covers layers only up to its capture point; fill the
    // never-captured slots with the final masks so every snapshot is a
    // complete, valid model mask.
    let final_masks = masks.clone();
    for (cp, slots) in captured {
        let snapshot = MaskSet {
            masks: slots.into_iter().enumerate()
                .map(|(i, m)| m.unwrap_or_else(
                    || final_masks.masks[i].clone()))
                .collect(),
        };
        report.snapshots.insert(cp, snapshot);
    }
    Ok((masks, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(Refiner::None.label(), "none");
        assert_eq!(Refiner::SparseSwapsOffload { impl_name: "xla".into() }
                   .label(), "sparseswaps[xla]");
        assert_eq!(PatternKind::Unstructured { sparsity: 0.6 }.label(),
                   "60%");
        assert_eq!(PatternKind::Nm { n: 2, m: 4 }.label(), "2:4");
    }

    #[test]
    fn pattern_for_width() {
        let pk = PatternKind::Unstructured { sparsity: 0.5 };
        assert_eq!(pk.pattern_for(64), Pattern::PerRow { keep: 32 });
        let nm = PatternKind::Nm { n: 2, m: 4 };
        assert_eq!(nm.pattern_for(64), Pattern::Nm { n: 2, m: 4 });
    }

    #[test]
    fn local_engines_cover_runtime_free_refiners() {
        assert!(Refiner::None.local_engine().is_some());
        assert!(Refiner::SparseSwapsNative.local_engine().is_some());
        assert!(Refiner::Dsnot.local_engine().is_some());
        assert!(Refiner::SparseSwapsOffload { impl_name: "xla".into() }
                .local_engine().is_none());
    }

    #[test]
    fn engine_labels_match_refiner_labels() {
        for r in [Refiner::None, Refiner::SparseSwapsNative,
                  Refiner::Dsnot] {
            assert_eq!(r.local_engine().unwrap().name(), r.label());
        }
    }
}
