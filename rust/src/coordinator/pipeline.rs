//! The pruning pipeline: the paper's full procedure over a transformer.
//!
//!   for each block (sequential mode — earlier blocks already masked):
//!     run calibration through the masked model, accumulating the four
//!       Gram streams for the block's layers;
//!     for each prunable layer:
//!       warmstart mask (magnitude / Wanda / RIA — computed natively
//!         from W and diag(G), or tightened from an inherited mask);
//!       refinement through the layer's [`RefineEngine`] (SparseSwaps
//!         offload or native, DSnoT, or none);
//!       record exact per-layer loss before/after and apply the mask.
//!
//! Refinement is embarrassingly parallel across rows *and* layers
//! (the paper's row decoupling, once the block's Gram statistics are
//! fixed), so the scheduling grain is the row *shard*
//! ([`crate::coordinator::scheduler::Shard`]), not the layer: a block
//! becomes one list of shards fanned across workers through the one
//! [`refine_block`] dispatch path — host [`ThreadPool`] workers for
//! the runtime-free engines, the [`RuntimePool`]'s device workers for
//! the offload engine.  Adaptive sharding splits the long-tail layer
//! (an MLP down-projection has ~4x the rows of an attention
//! projection) across otherwise-idle workers.  Per-row results are
//! independent of scheduling, so masks and snapshots are
//! bit-identical to the whole-layer serial schedule for every shard
//! size and worker count.
//!
//! One-shot mode instead calibrates once on the dense model and prunes
//! every block from those statistics (Wanda-style; cheaper, slightly
//! worse).  Both modes exist because the paper's baselines differ in
//! this respect and the ablation benches compare them.
//!
//! The pipeline talks to parameters through the block-granular
//! [`WeightStore`] trait rather than the flat in-memory tensor list.
//! With a [`crate::model::weight_store::ResidentStore`] nothing
//! changes; with a [`crate::model::weight_store::StreamingStore`]
//! (`--stream-weights`) the run becomes a **staged stream**: the
//! calibration residual streams are embedded once
//! ([`GramStream::start`]), and while block `b` refines on the
//! schedulers, a prefetch stage leases block `b+1` from disk — and in
//! one-shot mode also accumulates its Gram statistics — so peak host
//! memory is O(2 blocks) plus the residual streams, never the
//! checkpoint size.  Refined (and journal-restored) blocks are
//! released as the stream passes them.  Per-row refinement depends
//! only on (W, G, spec), and the `embed`+`calib_block` artifacts are
//! bit-identical to the stacked `calib_step`, so streamed masks and
//! snapshots match the resident store bit-for-bit for every engine,
//! backend and shard size.
//!
//! The job-spec API splits what used to be one 14-field config in two:
//! [`MaskSpec`] holds exactly the knobs that determine the resulting
//! masks (and therefore the journal fingerprint domain —
//! [`crate::coordinator::journal::config_fingerprint`] hashes a
//! `MaskSpec` directly), while [`RunOptions`] holds the wall-clock
//! knobs (threads, shards, retries, journaling) that never change a
//! mask bit.  [`PruneSession`] owns the long-lived half of a run —
//! pool, store, dataset and cached one-shot calibration statistics —
//! so callers that walk many specs over one model (the sparsity-sweep
//! harness, the report tables) calibrate once and prune per spec.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::coordinator::journal::{config_fingerprint, Journal};
use crate::coordinator::scheduler::{
    refine_block, BlockSchedule, LayerWork, Scheduler, ShardedLayer,
    WorkerCtx,
};
use crate::coordinator::swaploop::OffloadEngine;
use crate::data::{Dataset, Split};
use crate::gram::{accumulate_pool, BlockStats, GramStats, GramStream};
use crate::model::store::{MaskSet, ParamStore};
use crate::model::weight_store::{BlockLease, StoreError, WeightStore};
use crate::pruning::dsnot::DsnotEngine;
use crate::pruning::engine::{NoopEngine, RefineEngine};
use crate::pruning::error::relative_reduction;
use crate::pruning::mask::{
    mask_from_scores, tighten_mask, validate, Pattern,
};
use crate::pruning::saliency::{self, Criterion};
use crate::pruning::sparseswaps::NativeEngine;
use crate::runtime::manifest::{ModelMeta, PrunableLayer};
use crate::runtime::pool::RuntimePool;
use crate::runtime::service::{PhaseTraffic, RuntimeError};
use crate::runtime::tensor_data::TensorData;
use crate::util::cli::{JournalFlags, PoolFlags};
use crate::util::tensor::{Matrix, MatrixView};
use crate::util::threadpool::{default_threads, ThreadPool};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Refiner {
    /// Warmstart only.
    None,
    /// SparseSwaps through the HLO artifacts (production path).
    SparseSwapsOffload { impl_name: String },
    /// SparseSwaps through the pure-Rust engine (reference path).
    SparseSwapsNative,
    /// The DSnoT baseline.
    Dsnot,
}

impl Refiner {
    pub fn label(&self) -> String {
        match self {
            Refiner::None => "none".into(),
            Refiner::SparseSwapsOffload { impl_name } =>
                format!("sparseswaps[{impl_name}]"),
            Refiner::SparseSwapsNative => "sparseswaps[native]".into(),
            Refiner::Dsnot => "dsnot".into(),
        }
    }

    /// Engine construction for one shard job, bound to the worker the
    /// scheduler placed it on.  Runtime-free engines delegate to the
    /// single [`Self::local_engine`] registry (adding one means one
    /// constructor line there); the offload engine binds to the
    /// worker's runtime and the layer's shared Gram buffer key.
    pub fn shard_engine<'a>(&self, worker: &WorkerCtx<'a>,
                            gram_key: u64)
        -> Result<Box<dyn RefineEngine + 'a>, String> {
        match self {
            Refiner::SparseSwapsOffload { impl_name } => match worker {
                WorkerCtx::Device(rt) => Ok(Box::new(
                    OffloadEngine::with_gram_key(*rt,
                                                 impl_name.clone(),
                                                 gram_key))),
                WorkerCtx::Host => Err(
                    "offload refiner scheduled on a host worker \
                     (needs a runtime-pool scheduler)".into()),
            },
            local => {
                let engine: Box<dyn RefineEngine> = local
                    .local_engine()
                    .expect("non-offload refiners are runtime-free");
                Ok(engine)
            }
        }
    }

    /// Runtime-free engine construction; `None` for engines that need
    /// a device worker (offload holds the runtime handle).
    fn local_engine(&self) -> Option<Box<dyn RefineEngine + Send>> {
        match self {
            Refiner::None => Some(Box::new(NoopEngine)),
            Refiner::SparseSwapsNative =>
                Some(Box::new(NativeEngine::default())),
            Refiner::Dsnot => Some(Box::new(DsnotEngine::default())),
            Refiner::SparseSwapsOffload { .. } => None,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PatternKind {
    Unstructured { sparsity: f64 },
    Nm { n: usize, m: usize },
}

impl PatternKind {
    pub fn pattern_for(&self, d_in: usize) -> Pattern {
        match *self {
            PatternKind::Unstructured { sparsity } =>
                Pattern::per_row_sparsity(d_in, sparsity),
            PatternKind::Nm { n, m } => Pattern::Nm { n, m },
        }
    }

    pub fn label(&self) -> String {
        match *self {
            PatternKind::Unstructured { sparsity } =>
                format!("{:.0}%", sparsity * 100.0),
            PatternKind::Nm { n, m } => format!("{n}:{m}"),
        }
    }

    /// Collision-proof key for merged JSON sections.  `label()` alone
    /// prints `"50%"` for unstructured and `"2:4"` for N:M — two
    /// different masks at the same sparsity — so point keys carry the
    /// kind too.
    pub fn key(&self) -> String {
        match *self {
            PatternKind::Unstructured { .. } =>
                format!("unstructured:{}", self.label()),
            PatternKind::Nm { .. } => format!("nm:{}", self.label()),
        }
    }

    /// Target sparsity as a fraction (an N:M pattern keeps n of every
    /// m weights).  Grid ordering and warm-chain eligibility key off
    /// this.
    pub fn sparsity(&self) -> f64 {
        match *self {
            PatternKind::Unstructured { sparsity } => sparsity,
            PatternKind::Nm { n, m } => 1.0 - n as f64 / m as f64,
        }
    }

    /// Parse a CLI pattern token: a sparsity (`0.6`, `60%`) or an
    /// N:M spec (`2:4`).
    pub fn parse(s: &str) -> Result<PatternKind, String> {
        if let Some(Pattern::Nm { n, m }) = Pattern::parse(s) {
            return Ok(PatternKind::Nm { n, m });
        }
        let v: f64 = s.trim_end_matches('%').parse().map_err(|_| {
            format!("bad pattern {s:?}: want e.g. 0.6 or 2:4")
        })?;
        let sparsity = if v > 1.0 { v / 100.0 } else { v };
        if !(0.0..1.0).contains(&sparsity) {
            return Err(format!("sparsity {sparsity} out of range"));
        }
        Ok(PatternKind::Unstructured { sparsity })
    }
}

/// The mask-affecting half of a pruning job: two runs over the same
/// model with equal `MaskSpec`s produce bit-identical masks, whatever
/// their [`RunOptions`].  This is exactly the journal fingerprint
/// domain ([`config_fingerprint`] hashes these fields and nothing
/// else).
#[derive(Clone, Debug, PartialEq)]
pub struct MaskSpec {
    pub criterion: Criterion,
    pub pattern_kind: PatternKind,
    pub refiner: Refiner,
    pub t_max: usize,
    pub calib_batches: usize,
    /// Sequential (per-block re-calibration on the masked model) vs
    /// one-shot (single dense calibration pass).
    pub sequential: bool,
    /// Mask snapshots at these cumulative iteration counts (Table 3).
    pub checkpoints: Vec<usize>,
}

impl Default for MaskSpec {
    fn default() -> Self {
        Self {
            criterion: Criterion::Wanda,
            pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
            refiner: Refiner::SparseSwapsOffload {
                impl_name: "xla".into(),
            },
            t_max: 100,
            calib_batches: 8,
            sequential: true,
            checkpoints: Vec::new(),
        }
    }
}

/// The wall-clock half of a pruning job: scheduling, retry and
/// journaling knobs.  None of these change a single mask bit — the
/// shard-parity and fault-recovery tests pin that invariant.
#[derive(Clone, Debug)]
pub struct RunOptions {
    pub threads: usize,
    /// Schedule independent row shards of a block concurrently:
    /// runtime-free engines on the thread pool, the offload engine
    /// across the runtime pool's device workers.  Masks are identical
    /// either way; disable to get per-layer wall-clock timings
    /// (shards then cover whole layers and dispatch one at a time).
    pub layer_parallel: bool,
    /// Rows per refinement shard work unit; 0 = adaptive
    /// (≈ block rows / (4 x workers), aligned per layer to the
    /// offload chunk shape).  Masks and snapshots are bit-identical
    /// for every value.
    pub shard_rows: usize,
    /// Per-shard redispatch budget for transient worker failures
    /// ([`BlockSchedule::max_retries`]; deterministic failures never
    /// retry).
    pub max_shard_retries: usize,
    /// Journal directory for resumable runs: after each block the
    /// refined masks land in `<dir>/block_<b>.ssjb`
    /// ([`crate::coordinator::journal`]).  `None` disables
    /// journaling (and resume).
    pub journal: Option<PathBuf>,
    /// Resume from the journal instead of starting fresh: completed
    /// blocks' masks are restored and skipped (including their
    /// sequential recalibration); refinement continues at the first
    /// unjournaled block.  Rejected if the journal was written under
    /// a different config fingerprint.
    pub resume: bool,
    /// Test hook: stop cleanly after journaling this block,
    /// simulating a crash between blocks (the resume tests drive the
    /// kill-then-`--resume` path through this under plain
    /// `cargo test`).
    pub halt_after_block: Option<usize>,
}

impl Default for RunOptions {
    fn default() -> Self {
        Self {
            threads: default_threads(),
            layer_parallel: true,
            shard_rows: 0,
            max_shard_retries: 2,
            journal: None,
            resume: false,
            halt_after_block: None,
        }
    }
}

impl RunOptions {
    /// Build from the shared CLI flag blocks
    /// ([`crate::util::cli::PoolFlags`] /
    /// [`crate::util::cli::JournalFlags`]); per-command knobs
    /// (`layer_parallel`, `shard_rows`, `halt_after_block`) keep
    /// their defaults and are overridden by the caller.
    pub fn from_flags(pool: &PoolFlags, journal: &JournalFlags)
        -> RunOptions {
        RunOptions {
            threads: match pool.threads {
                0 => default_threads(),
                t => t,
            },
            max_shard_retries: journal.max_shard_retries,
            journal: journal.journal.clone(),
            resume: journal.resume,
            ..RunOptions::default()
        }
    }
}

#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub layer_type: String,
    pub block: usize,
    pub loss_warmstart: f64,
    pub loss_refined: f64,
    pub swaps: usize,
    pub rows_converged: usize,
    pub rows: usize,
    pub seconds: f64,
}

impl LayerReport {
    pub fn relative_reduction(&self) -> f64 {
        relative_reduction(self.loss_warmstart, self.loss_refined)
    }
}

#[derive(Clone, Debug, Default)]
pub struct PruneReport {
    pub layers: Vec<LayerReport>,
    /// Calibration seconds actually spent by this run: 0 when the
    /// session served the one-shot Gram statistics from its cache.
    pub calib_seconds: f64,
    /// Summed per-layer refinement time (CPU seconds under the
    /// layer-parallel schedule, wall seconds under the serial one).
    pub refine_seconds: f64,
    pub warmstart_seconds: f64,
    /// Runtime traffic attributable to this run's calibration passes
    /// (uploads, downloads, cache probes), merged across the pool's
    /// workers.  Zero when the session served cached one-shot
    /// statistics.  Under the streamed one-shot driver the prefetch
    /// stage overlaps refinement on the same device workers, so this
    /// can include concurrently scheduled refinement traffic there.
    pub calib_traffic: PhaseTraffic,
    /// Mask snapshots per checkpoint (whole-model MaskSets).
    pub snapshots: BTreeMap<usize, MaskSet>,
}

impl PruneReport {
    pub fn total_warmstart_loss(&self) -> f64 {
        self.layers.iter().map(|l| l.loss_warmstart).sum()
    }

    pub fn total_refined_loss(&self) -> f64 {
        self.layers.iter().map(|l| l.loss_refined).sum()
    }

    /// Mean over layers of the per-layer relative reduction (the paper's
    /// Table 3/4 "average relative error reduction").
    pub fn mean_relative_reduction(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.relative_reduction()).sum::<f64>()
            / self.layers.len() as f64
    }
}

/// A pruning session: the one entry point to the pipeline, shared by
/// `sparseswaps prune`, `sparseswaps sweep` and the e2e harness.  It
/// borrows the long-lived run state — runtime pool, dense weights,
/// dataset — and owns the calibration cache, so walking many
/// [`MaskSpec`]s over one model (a sparsity sweep, a report table)
/// builds the stack once and calibrates once per distinct one-shot
/// budget instead of once per grid point.
///
/// `store` keeps its dense weights; each [`Self::prune`] returns the
/// masks (apply with `store.masked(&masks)`).
///
/// Serial stages (calibration, warmstarts) run on the pool's primary
/// runtime; refinement goes through the one shard dispatch path
/// ([`refine_block`]): row shards fan across the host thread pool
/// (runtime-free engines) or the runtime pool's device workers
/// (offload).  Masks and snapshots are bit-identical for every shard
/// size and worker count (disable `RunOptions::layer_parallel` for
/// per-layer wall-clock timings).
///
/// Fault tolerance: transiently failed shards are redispatched (up to
/// `RunOptions::max_shard_retries` per shard, on a different worker
/// where possible); if every device worker ends up quarantined the
/// run degrades to the native host refiner instead of aborting.  With
/// `RunOptions::journal` set, each block's refined masks are
/// journaled so an interrupted run can resume (`RunOptions::resume`)
/// with bit-identical results.  A resumed run's report covers only
/// the blocks it refined itself, and snapshots are re-recorded only
/// for those blocks (restored blocks contribute their *final* masks
/// to the backfill).
pub struct PruneSession<'a> {
    pool: &'a RuntimePool,
    store: &'a dyn WeightStore,
    ds: &'a Dataset,
    /// Wall-clock knobs; a pub field so callers (the fault tests, the
    /// sweep driver) can adjust scheduling between `prune` calls
    /// without rebuilding the session.
    pub run: RunOptions,
    /// Cached one-shot Gram statistics, keyed by the calibration
    /// budget they were accumulated under.  `accumulate` is
    /// deterministic, so serving a spec from this cache is
    /// bit-identical to recomputing.
    dense_stats: Option<(usize, GramStats)>,
    calibrations: usize,
}

impl<'a> PruneSession<'a> {
    pub fn new(pool: &'a RuntimePool, store: &'a dyn WeightStore,
               ds: &'a Dataset, run: RunOptions) -> Self {
        Self { pool, store, ds, run, dense_stats: None,
               calibrations: 0 }
    }

    pub fn pool(&self) -> &'a RuntimePool {
        self.pool
    }

    pub fn store(&self) -> &'a dyn WeightStore {
        self.store
    }

    /// The full in-memory store, for stages that need whole-model
    /// access (perplexity evaluation, `store.masked` materialisation).
    /// Errors when the weights live out of core.
    pub fn resident_store(&self) -> Result<&'a ParamStore, RuntimeError> {
        self.store.as_resident().ok_or_else(|| RuntimeError::Msg(
            "this stage needs the full model resident; it is not \
             available with --stream-weights".into()))
    }

    pub fn dataset(&self) -> &'a Dataset {
        self.ds
    }

    /// Calibration passes this session has paid for (dense one-shot
    /// accumulations plus sequential per-block recalibrations).  The
    /// sweep harness asserts this stays at 1 across a one-shot grid.
    pub fn calibrations(&self) -> usize {
        self.calibrations
    }

    /// Run the pruning pipeline for one job spec, warmstarting from
    /// saliency scores alone.
    pub fn prune(&mut self, spec: &MaskSpec)
        -> Result<(MaskSet, PruneReport), RuntimeError> {
        self.prune_from(spec, None)
    }

    /// Run the pipeline warm-started from an inherited mask set
    /// (typically the previous sweep level's refined masks): each
    /// layer's starting mask is `tighten_mask(prev, scores, pattern)`
    /// — the lowest-saliency kept weights are pruned down to the new
    /// pattern's budget — instead of a fresh `mask_from_scores`.  The
    /// journal restore path already proves arbitrary partial masks
    /// are valid refinement warmstarts; this is the same contract.
    ///
    /// Warm continuations cannot be journaled or resumed: the journal
    /// fingerprint covers the [`MaskSpec`] but not the inherited
    /// mask, so a resumed continuation could silently mix chains.
    pub fn prune_from(&mut self, spec: &MaskSpec,
                      warm: Option<&MaskSet>)
        -> Result<(MaskSet, PruneReport), RuntimeError> {
        if warm.is_some()
            && (self.run.journal.is_some() || self.run.resume) {
            return Err(RuntimeError::Msg(
                "warm-started continuation runs cannot be journaled \
                 or resumed (the journal fingerprint does not cover \
                 the inherited mask)".into()));
        }
        if let Some(prev) = warm {
            let want = self.store.meta().prunable.len();
            if prev.masks.len() != want {
                return Err(RuntimeError::Msg(format!(
                    "warm mask set has {} layer masks, model has \
                     {want}", prev.masks.len())));
            }
        }
        // One-shot Gram statistics are a pure function of
        // (store, calib_batches): cache them across specs.
        // Sequential mode recalibrates per block inside `prune_impl`
        // by design and bypasses the cache; a streaming store cannot
        // hold whole-model statistics resident, so its one-shot runs
        // accumulate per block inside the staged stream instead.
        let mut calib_pre = 0.0;
        let mut traffic_pre = PhaseTraffic::default();
        if !spec.sequential {
            if let Some(resident) = self.store.as_resident() {
                let cached = matches!(&self.dense_stats,
                                      Some((n, _)) if *n
                                          == spec.calib_batches);
                if !cached {
                    let calib = self.ds.batches(self.store.meta(),
                                                Split::Calibration,
                                                spec.calib_batches);
                    let t0 = Instant::now();
                    let stats = accumulate_pool(self.pool, resident,
                                                &calib)?;
                    calib_pre = t0.elapsed().as_secs_f64();
                    traffic_pre = stats.traffic;
                    self.calibrations += 1;
                    self.dense_stats =
                        Some((spec.calib_batches, stats));
                }
            }
        }
        let dense = self.dense_stats.as_ref()
            .filter(|_| !spec.sequential)
            .map(|(_, s)| s);
        let mut seq_calibs = 0;
        let out = prune_impl(self.pool, self.store, self.ds, spec,
                             &self.run, warm, dense, calib_pre,
                             traffic_pre, &mut seq_calibs);
        self.calibrations += seq_calibs;
        out
    }
}

/// Where one block's weights live for its refinement stage: the whole
/// resident store, or the block's lease from a streaming store.  Per-
/// row refinement sees identical bytes either way.
enum BlockWeights<'w> {
    Resident(&'w ParamStore),
    Lease(&'w BlockLease),
}

impl<'w> BlockWeights<'w> {
    fn weight(&self, layer: &PrunableLayer) -> MatrixView<'w> {
        match *self {
            BlockWeights::Resident(s) => s.weight(layer),
            BlockWeights::Lease(l) => l.weight(layer),
        }
    }
}

fn store_err(e: StoreError) -> RuntimeError {
    RuntimeError::Msg(format!("weight store: {e}"))
}

/// The per-block refine stage shared by the resident and the streamed
/// drivers: warmstart, shard dispatch (with quarantine degradation),
/// result folding and journaling for one block.  The drivers differ
/// only in where weights and Gram statistics come from and what
/// happens to them afterwards.
struct BlockStage<'s> {
    pool: &'s RuntimePool,
    meta: &'s ModelMeta,
    spec: &'s MaskSpec,
    plan: BlockSchedule,
    offload: bool,
    host_workers: usize,
    thread_pool: Option<ThreadPool>,
    native: Refiner,
    degraded: bool,
    fallback_pool: Option<ThreadPool>,
    journal: Option<Journal>,
    warm_from: Option<&'s MaskSet>,
    masks: MaskSet,
    report: PruneReport,
    captured: BTreeMap<usize, Vec<Option<Matrix>>>,
}

impl BlockStage<'_> {
    /// Warmstart and refine block `b` from the given weights and Gram
    /// statistics, fold the results into masks/report/snapshots, and
    /// journal the block.  Per-row results depend only on (W, G,
    /// spec), so both drivers produce bit-identical masks.
    fn refine_one(&mut self, b: usize, weights: BlockWeights<'_>,
                  stats: &GramStats) -> Result<(), RuntimeError> {
        let rt = self.pool.primary();
        let spec = self.spec;
        let layers: Vec<_> = self.meta.prunable.iter().enumerate()
            .filter(|(_, l)| l.block == b)
            .map(|(i, l)| (i, l.clone()))
            .collect();

        // Warmstart every layer first (cheap, serial), then refine
        // the whole block through the shard dispatch.
        let mut works = Vec::with_capacity(layers.len());
        for (li, layer) in layers {
            let w = weights.weight(&layer);
            let g = stats.gram_for(&layer);
            let pattern = spec.pattern_kind.pattern_for(layer.d_in);
            let t0 = Instant::now();
            let scores = saliency::scores(spec.criterion, w,
                                          &g.diag());
            // A warm continuation inherits the previous level's
            // refined mask, tightened to the new pattern's budget;
            // a cold run warmstarts from the scores alone.
            let warm = match self.warm_from {
                Some(prev) =>
                    tighten_mask(&prev.masks[li], &scores, pattern),
                None => mask_from_scores(&scores, pattern),
            };
            self.report.warmstart_seconds +=
                t0.elapsed().as_secs_f64();
            let fstats = if spec.refiner == Refiner::Dsnot {
                Some(stats.feature_stats_for(&layer))
            } else {
                None
            };
            // Adaptive shard sizes align to the offload chunk shape
            // so no shard pays a padded half-chunk.
            let shard_align = match &spec.refiner {
                Refiner::SparseSwapsOffload { impl_name }
                    if !self.degraded => rt
                    .manifest()
                    .find_swap_artifact(layer.d_in,
                                        &pattern.artifact_tag(),
                                        impl_name, 8)
                    .map(|e| e.chunk_rows)
                    .unwrap_or(1),
                _ => 1,
            };
            works.push(LayerWork {
                li,
                label: layer.name.clone(),
                w,
                g,
                stats: fstats,
                pattern,
                warm,
                shard_align,
                gram_key: crate::coordinator::swaploop::
                    next_refinement_id(),
            });
        }

        let (refiner_b, sched_b): (&Refiner, &dyn Scheduler) =
            if self.degraded {
                (&self.native,
                 self.fallback_pool.as_ref()
                     .expect("degraded pool built"))
            } else if let Some(tp) = &self.thread_pool {
                (&spec.refiner, tp)
            } else {
                (&spec.refiner, self.pool)
            };
        let results = refine_block(sched_b, refiner_b, &works,
                                   &self.plan);

        // Release the block's shared Gram buffers on every device
        // before propagating any error (shards leave them resident
        // for their siblings; the block is done — or dead — now, so
        // the budget goes back to live layers either way).
        if self.offload && !self.degraded {
            for work in &works {
                for d in 0..self.pool.devices() {
                    self.pool.runtime(d).invalidate(work.gram_key);
                }
            }
        }
        let results = match results {
            Ok(r) => r,
            Err(e) if self.offload && !self.degraded
                && self.pool.workers_quarantined()
                    >= self.pool.devices() as u64 => {
                eprintln!(
                    "prune: all {} device worker(s) quarantined \
                     ({e}); degrading to the native host refiner",
                    self.pool.devices());
                self.degraded = true;
                self.fallback_pool =
                    Some(ThreadPool::new(self.host_workers));
                refine_block(
                    self.fallback_pool.as_ref().expect("just built"),
                    &self.native, &works, &self.plan)?
            }
            Err(e) => return Err(e),
        };

        for res in results {
            let ShardedLayer { li, mask, outcome, seconds, .. } = res;
            let layer = &self.meta.prunable[li];
            let pattern = spec.pattern_kind.pattern_for(layer.d_in);
            self.report.refine_seconds += seconds;
            validate(&mask, pattern)
                .map_err(|e| RuntimeError::Msg(format!(
                    "{}: {e}", layer.name)))?;
            let lr = LayerReport {
                name: layer.name.clone(),
                layer_type: layer.layer_type.clone(),
                block: layer.block,
                loss_warmstart: outcome.layer.total_before(),
                loss_refined: outcome.layer.total_after(),
                swaps: outcome.layer.total_swaps(),
                rows_converged: outcome.layer.rows_converged(),
                rows: layer.d_out,
                seconds,
            };
            crate::log_debug!(
                "prune[{}] {} loss {:.4} -> {:.4} ({:+.1}%)",
                self.meta.name, lr.name, lr.loss_warmstart,
                lr.loss_refined, -100.0 * lr.relative_reduction());
            for (cp, snap) in outcome.snapshots {
                if let Some(slots) = self.captured.get_mut(&cp) {
                    slots[li] = Some(snap);
                }
            }
            self.masks.masks[li] = mask;
            self.report.layers.push(lr);
        }

        if let Some(j) = &self.journal {
            let layer_masks: Vec<_> = works.iter()
                .map(|w| (w.li, &self.masks.masks[w.li]))
                .collect();
            j.record_block(b, &layer_masks)?;
        }
        Ok(())
    }
}

/// The pipeline body.  Private: every caller goes through
/// [`PruneSession`], so there is exactly one prune entry path.
#[allow(clippy::too_many_arguments)]
fn prune_impl(pool: &RuntimePool, store: &dyn WeightStore,
              ds: &Dataset, spec: &MaskSpec, run: &RunOptions,
              warm_from: Option<&MaskSet>, dense: Option<&GramStats>,
              calib_pre: f64, traffic_pre: PhaseTraffic,
              calibrations: &mut usize)
    -> Result<(MaskSet, PruneReport), RuntimeError> {
    let meta = store.meta().clone();
    // Sequential mode rebuilds its calibration batches here; resident
    // one-shot mode received the session's cached dense statistics; a
    // streaming store accumulates per block inside the staged stream,
    // so it needs the batches in one-shot mode too.
    let streaming = store.as_resident().is_none();
    let calib = (spec.sequential || streaming).then(|| {
        ds.batches(&meta, Split::Calibration, spec.calib_batches)
    });
    let mut masks = MaskSet::all_ones(&meta);
    let report = PruneReport {
        calib_seconds: calib_pre,
        calib_traffic: traffic_pre,
        ..PruneReport::default()
    };
    // Snapshot capture is tracked explicitly per (checkpoint, layer):
    // `None` means "not captured yet" and is backfilled with the final
    // layer mask at the end.  (The old implementation used "mask is
    // all-ones" as the not-captured sentinel, which clobbered
    // legitimately dense snapshots.)
    let n_layers = meta.prunable.len();
    let captured: BTreeMap<usize, Vec<Option<Matrix>>> =
        spec.checkpoints.iter()
            .map(|&cp| (cp, (0..n_layers).map(|_| None).collect()))
            .collect();

    // One shard dispatch path for every refiner: the scheduler is the
    // device pool for the offload engine and a host thread pool for
    // the runtime-free engines; the shard plan does the rest.
    let offload =
        matches!(spec.refiner, Refiner::SparseSwapsOffload { .. });
    let host_workers = if run.layer_parallel {
        run.threads.max(1)
    } else {
        1
    };
    let thread_pool = (!offload).then(|| ThreadPool::new(host_workers));
    let plan = BlockSchedule {
        t_max: spec.t_max,
        // Under a multi-worker scheduler parallelism comes from the
        // shards themselves; the serial schedule keeps the engines'
        // internal row threads instead.
        threads_per_shard: if run.layer_parallel {
            1
        } else {
            run.threads.max(1)
        },
        checkpoints: spec.checkpoints.clone(),
        shard_rows: if run.layer_parallel {
            run.shard_rows
        } else {
            // Whole-layer shards keep per-layer timings meaningful.
            usize::MAX
        },
        serial: !run.layer_parallel,
        max_retries: run.max_shard_retries,
    };

    // Resumable runs: journal each block's refined masks, and on
    // `--resume` restore the completed blocks instead of recomputing
    // them.  The restored masks reproduce the exact model state the
    // interrupted run had, so the remaining blocks' sequential
    // recalibration — and therefore their masks — are bit-identical
    // to an uninterrupted run's.
    let fingerprint = config_fingerprint(&meta.name, spec);
    let journal = match &run.journal {
        Some(dir) if run.resume =>
            Some(Journal::open_resume(dir, fingerprint)?),
        Some(dir) => Some(Journal::create(dir, &meta.name,
                                          meta.n_blocks, fingerprint)?),
        None if run.resume => {
            return Err(RuntimeError::Msg(
                "resume requires a journal directory".into()));
        }
        None => None,
    };
    let mut completed: Vec<usize> = Vec::new();
    if run.resume {
        let j = journal.as_ref().expect("resume checked above");
        for b in j.completed_blocks() {
            for (li, mask) in j.load_block(b)? {
                masks.masks[li] = mask;
            }
            completed.push(b);
        }
        crate::log_debug!(
            "prune[{}] resume: restored {} journaled block(s)",
            meta.name, completed.len());
    }

    // Graceful degradation state lives in the stage: when every
    // device worker has been quarantined the offload path cannot make
    // progress, so the rest of the run falls back to the native host
    // engine (bit-identical masks for the interp backend; gated in
    // the wave-2 bench for the offload parity in general).
    let mut stage = BlockStage {
        pool,
        meta: &meta,
        spec,
        plan,
        offload,
        host_workers,
        thread_pool,
        native: Refiner::SparseSwapsNative,
        degraded: false,
        fallback_pool: None,
        journal,
        warm_from,
        masks,
        report,
        captured,
    };

    match store.as_resident() {
        Some(resident) => {
            for b in 0..meta.n_blocks {
                if completed.contains(&b) {
                    continue;
                }
                // Borrow (never clone) the Gram statistics: layer
                // jobs hold zero-copy views into this block's stream
                // stacks.
                let stats_block;
                let stats: &GramStats = if spec.sequential {
                    // Recalibrate with everything pruned so far
                    // applied.
                    let t0 = Instant::now();
                    let masked = resident.masked(&stage.masks);
                    let batches =
                        calib.as_ref().expect("sequential batches");
                    stats_block = accumulate_pool(pool, &masked,
                                                  batches)?;
                    stage.report.calib_seconds +=
                        t0.elapsed().as_secs_f64();
                    stage.report.calib_traffic
                        .merge(&stats_block.traffic);
                    *calibrations += 1;
                    &stats_block
                } else {
                    dense.expect(
                        "one-shot stats provided by the session")
                };
                stage.refine_one(b, BlockWeights::Resident(resident),
                                 stats)?;
                if run.halt_after_block == Some(b) {
                    crate::log_debug!(
                        "prune[{}] halting after block {b} \
                         (test hook)",
                        meta.name);
                    break;
                }
            }
        }
        None => {
            let batches =
                calib.as_ref().expect("streaming batches built");
            run_streamed(store, &meta, spec, run, batches,
                         &completed, &mut stage, calibrations)?;
        }
    }
    let BlockStage { masks, mut report, captured, .. } = stage;

    // Each snapshot covers layers only up to its capture point; fill the
    // never-captured slots with the final masks so every snapshot is a
    // complete, valid model mask.
    let final_masks = masks.clone();
    for (cp, slots) in captured {
        let snapshot = MaskSet {
            masks: slots.into_iter().enumerate()
                .map(|(i, m)| m.unwrap_or_else(
                    || final_masks.masks[i].clone()))
                .collect(),
        };
        report.snapshots.insert(cp, snapshot);
    }
    Ok((masks, report))
}

/// One prefetch step of the one-shot staged stream: lease block `b`
/// and run its calibration forward — accumulating Gram statistics
/// unless the block was journal-restored (`skip`), in which case the
/// residual streams just advance through it.
fn fetch_oneshot(store: &dyn WeightStore, stream: &mut GramStream,
                 meta: &ModelMeta, b: usize, skip: bool)
    -> Result<(BlockLease, Option<BlockStats>, f64), RuntimeError> {
    let lease = store.lease_block(b).map_err(store_err)?;
    let t0 = Instant::now();
    let params = lease.block_params(meta, b, None);
    let stats = if skip {
        stream.push_block(&params)?;
        None
    } else {
        Some(stream.accumulate_and_push(&params)?)
    };
    Ok((lease, stats, t0.elapsed().as_secs_f64()))
}

/// The staged streaming driver: weights are leased per block from the
/// out-of-core store, Gram statistics come from the incremental
/// [`GramStream`], and while block `b` refines a scoped prefetch
/// thread readies block `b+1` — its disk lease, and in one-shot mode
/// its Gram accumulation too (sequential statistics depend on block
/// `b`'s refined mask, so only the lease overlaps there).  Every
/// block — refined or journal-restored — is released once the stream
/// passes it, so peak weight residency is two blocks (plus the
/// globals, released right after the embed stage).
#[allow(clippy::too_many_arguments)]
fn run_streamed(store: &dyn WeightStore, meta: &ModelMeta,
                spec: &MaskSpec, run: &RunOptions,
                calib: &[(TensorData, TensorData)],
                completed: &[usize], stage: &mut BlockStage<'_>,
                calibrations: &mut usize)
    -> Result<(), RuntimeError> {
    // Embed the calibration batches from the leased globals, then
    // release them: from here on only the residual streams plus at
    // most two leased blocks are resident.  The stream fans its batch
    // stripes over the pool's healthy workers; the decomposition is
    // device-count independent, so streamed masks keep matching the
    // resident store bit-for-bit at any pool size.
    let t0 = Instant::now();
    let globals = store.lease_globals().map_err(store_err)?;
    let workers = stage.pool.healthy_runtimes();
    let mut stream = GramStream::start(&workers, meta,
                                       globals.tensor(0), calib)?;
    drop(globals);
    store.release_globals();
    stage.report.calib_seconds += t0.elapsed().as_secs_f64();
    if !spec.sequential {
        // The whole one-shot stream is one dense calibration pass.
        *calibrations += 1;
    }

    if spec.sequential {
        let mut next_lease: Option<BlockLease> = None;
        for b in 0..meta.n_blocks {
            let lease = match next_lease.take() {
                Some(l) => l,
                None => store.lease_block(b).map_err(store_err)?,
            };
            if completed.contains(&b) {
                // Journal-restored block: advance the residual
                // streams through its restored masks, then release it
                // like a refined block.
                let t0 = Instant::now();
                stream.push_block(&lease.block_params(
                    meta, b, Some(&stage.masks)))?;
                stage.report.calib_seconds +=
                    t0.elapsed().as_secs_f64();
                store.release_block(b);
                continue;
            }
            // Peek the block's statistics against its *dense* weights
            // without advancing — exactly what the resident driver's
            // whole-model recalibration sees at this block's input.
            let t0 = Instant::now();
            let bs = stream.accumulate_block(
                &lease.block_params(meta, b, None))?;
            stage.report.calib_seconds += t0.elapsed().as_secs_f64();
            *calibrations += 1;
            let mut stats = GramStats::hollow(meta);
            stats.tokens = stream.tokens;
            stats.batches = stream.batches;
            stats.set_block(b, bs);
            // Refine block b while a prefetch thread leases block
            // b+1's weights from disk.
            next_lease = std::thread::scope(
                |s| -> Result<Option<BlockLease>, RuntimeError> {
                let handle = (b + 1 < meta.n_blocks).then(|| {
                    s.spawn(move || store.lease_block(b + 1))
                });
                stage.refine_one(b, BlockWeights::Lease(&lease),
                                 &stats)?;
                match handle {
                    Some(h) => h.join()
                        .map_err(|_| RuntimeError::Msg(
                            "prefetch stage panicked".into()))?
                        .map(Some).map_err(store_err),
                    None => Ok(None),
                }
            })?;
            // Advance the residual streams through the block with its
            // refined mask applied, then drop it from host memory.
            let t0 = Instant::now();
            stream.push_block(&lease.block_params(
                meta, b, Some(&stage.masks)))?;
            stage.report.calib_seconds += t0.elapsed().as_secs_f64();
            store.release_block(b);
            if run.halt_after_block == Some(b) {
                crate::log_debug!(
                    "prune[{}] halting after block {b} (test hook)",
                    meta.name);
                break;
            }
        }
    } else {
        let mut next: Option<(BlockLease, Option<BlockStats>, f64)> =
            None;
        for b in 0..meta.n_blocks {
            let skip = completed.contains(&b);
            let (lease, bstats, secs) = match next.take() {
                Some(pre) => pre,
                None => fetch_oneshot(store, &mut stream, meta, b,
                                      skip)?,
            };
            stage.report.calib_seconds += secs;
            if let Some(bs) = bstats {
                let mut stats = GramStats::hollow(meta);
                stats.tokens = stream.tokens;
                stats.batches = stream.batches;
                stats.set_block(b, bs);
                // Refine block b while the prefetch thread leases
                // block b+1 *and* runs its Gram accumulation (one-
                // shot statistics never depend on refined masks).
                next = std::thread::scope(
                    |s| -> Result<Option<(BlockLease,
                                          Option<BlockStats>, f64)>,
                                  RuntimeError> {
                    let handle = (b + 1 < meta.n_blocks).then(|| {
                        let stream = &mut stream;
                        let skip_next =
                            completed.contains(&(b + 1));
                        s.spawn(move || fetch_oneshot(
                            store, stream, meta, b + 1, skip_next))
                    });
                    stage.refine_one(b, BlockWeights::Lease(&lease),
                                     &stats)?;
                    match handle {
                        Some(h) => h.join()
                            .map_err(|_| RuntimeError::Msg(
                                "prefetch stage panicked".into()))?
                            .map(Some),
                        None => Ok(None),
                    }
                })?;
            }
            store.release_block(b);
            if !skip && run.halt_after_block == Some(b) {
                crate::log_debug!(
                    "prune[{}] halting after block {b} (test hook)",
                    meta.name);
                break;
            }
        }
    }
    stage.report.calib_traffic.merge(&stream.traffic());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(Refiner::None.label(), "none");
        assert_eq!(Refiner::SparseSwapsOffload { impl_name: "xla".into() }
                   .label(), "sparseswaps[xla]");
        assert_eq!(PatternKind::Unstructured { sparsity: 0.6 }.label(),
                   "60%");
        assert_eq!(PatternKind::Nm { n: 2, m: 4 }.label(), "2:4");
    }

    #[test]
    fn pattern_for_width() {
        let pk = PatternKind::Unstructured { sparsity: 0.5 };
        assert_eq!(pk.pattern_for(64), Pattern::PerRow { keep: 32 });
        let nm = PatternKind::Nm { n: 2, m: 4 };
        assert_eq!(nm.pattern_for(64), Pattern::Nm { n: 2, m: 4 });
    }

    #[test]
    fn pattern_keys_disambiguate_equal_sparsity() {
        // label() alone collides: both masks are 50% sparse.
        let un = PatternKind::Unstructured { sparsity: 0.5 };
        let nm = PatternKind::Nm { n: 2, m: 4 };
        assert_eq!(un.sparsity(), nm.sparsity());
        assert_eq!(un.key(), "unstructured:50%");
        assert_eq!(nm.key(), "nm:2:4");
        assert_ne!(un.key(), nm.key());
    }

    #[test]
    fn pattern_parse_round_trips() {
        assert_eq!(PatternKind::parse("0.6").unwrap(),
                   PatternKind::Unstructured { sparsity: 0.6 });
        assert_eq!(PatternKind::parse("60%").unwrap(),
                   PatternKind::Unstructured { sparsity: 0.6 });
        assert_eq!(PatternKind::parse("2:4").unwrap(),
                   PatternKind::Nm { n: 2, m: 4 });
        assert!(PatternKind::parse("junk").is_err());
        assert!(PatternKind::parse("1.0").is_err());
    }

    #[test]
    fn local_engines_cover_runtime_free_refiners() {
        assert!(Refiner::None.local_engine().is_some());
        assert!(Refiner::SparseSwapsNative.local_engine().is_some());
        assert!(Refiner::Dsnot.local_engine().is_some());
        assert!(Refiner::SparseSwapsOffload { impl_name: "xla".into() }
                .local_engine().is_none());
    }

    #[test]
    fn engine_labels_match_refiner_labels() {
        for r in [Refiner::None, Refiner::SparseSwapsNative,
                  Refiner::Dsnot] {
            assert_eq!(r.local_engine().unwrap().name(), r.label());
        }
    }
}
