//! The pruning pipeline: the paper's full procedure over a transformer.
//!
//!   for each block (sequential mode — earlier blocks already masked):
//!     run calibration through the masked model, accumulating the four
//!       Gram streams for the block's layers;
//!     for each prunable layer:
//!       warmstart mask (magnitude / Wanda / RIA — computed natively
//!         from W and diag(G));
//!       refinement through the layer's [`RefineEngine`] (SparseSwaps
//!         offload or native, DSnoT, or none);
//!       record exact per-layer loss before/after and apply the mask.
//!
//! Refinement is per-layer embarrassingly parallel (the paper's row
//! decoupling extends across layers once the block's Gram statistics
//! are fixed), so layers within a block are scheduled concurrently:
//! runtime-free engines on the shared [`ThreadPool`] (row-thread
//! budget split across the concurrent jobs), and the offload engine
//! across the workers of the [`RuntimePool`] when it has more than
//! one device — each layer job runs against its worker's own service
//! thread and device-buffer cache.  Per-row results are independent
//! of scheduling, so masks are bit-identical to the serial schedule
//! either way.
//!
//! One-shot mode instead calibrates once on the dense model and prunes
//! every block from those statistics (Wanda-style; cheaper, slightly
//! worse).  Both modes exist because the paper's baselines differ in
//! this respect and the ablation benches compare them.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::coordinator::swaploop::OffloadEngine;
use crate::data::{Dataset, Split};
use crate::gram::{accumulate, GramStats};
use crate::model::store::{MaskSet, ParamStore};
use crate::pruning::dsnot::{DsnotEngine, FeatureStats};
use crate::pruning::engine::{
    LayerContext, NoopEngine, RefineEngine, RefineOutcome,
};
use crate::pruning::error::relative_reduction;
use crate::pruning::mask::{mask_from_scores, validate, Pattern};
use crate::pruning::saliency::{self, Criterion};
use crate::pruning::sparseswaps::NativeEngine;
use crate::runtime::manifest::PrunableLayer;
use crate::runtime::pool::RuntimePool;
use crate::runtime::service::{Runtime, RuntimeError};
use crate::util::threadpool::{default_threads, ThreadPool};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Refiner {
    /// Warmstart only.
    None,
    /// SparseSwaps through the HLO artifacts (production path).
    SparseSwapsOffload { impl_name: String },
    /// SparseSwaps through the pure-Rust engine (reference path).
    SparseSwapsNative,
    /// The DSnoT baseline.
    Dsnot,
}

impl Refiner {
    pub fn label(&self) -> String {
        match self {
            Refiner::None => "none".into(),
            Refiner::SparseSwapsOffload { impl_name } =>
                format!("sparseswaps[{impl_name}]"),
            Refiner::SparseSwapsNative => "sparseswaps[native]".into(),
            Refiner::Dsnot => "dsnot".into(),
        }
    }

    /// Engine construction — the pipeline's entire refiner dispatch.
    /// Non-offload engines come from the single [`Self::local_engine`]
    /// registry, so adding a refiner means one constructor line there.
    pub fn engine<'a>(&self, rt: &'a Runtime)
        -> Box<dyn RefineEngine + 'a> {
        match self {
            Refiner::SparseSwapsOffload { impl_name } =>
                Box::new(OffloadEngine::new(rt, impl_name.clone())),
            local => local.local_engine()
                .expect("non-offload refiners are runtime-free"),
        }
    }

    /// Runtime-free engine construction for pool workers; `None` for
    /// engines that must stay on the scheduling thread (offload holds
    /// the PJRT handle, which serialises execution anyway).
    fn local_engine(&self) -> Option<Box<dyn RefineEngine + Send>> {
        match self {
            Refiner::None => Some(Box::new(NoopEngine)),
            Refiner::SparseSwapsNative =>
                Some(Box::new(NativeEngine::default())),
            Refiner::Dsnot => Some(Box::new(DsnotEngine::default())),
            Refiner::SparseSwapsOffload { .. } => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct PruneConfig {
    pub criterion: Criterion,
    pub pattern_kind: PatternKind,
    pub refiner: Refiner,
    pub t_max: usize,
    pub calib_batches: usize,
    /// Sequential (per-block re-calibration on the masked model) vs
    /// one-shot (single dense calibration pass).
    pub sequential: bool,
    /// Mask snapshots at these cumulative iteration counts (Table 3).
    pub checkpoints: Vec<usize>,
    pub threads: usize,
    /// Schedule independent layers of a block concurrently:
    /// runtime-free engines on the thread pool, the offload engine
    /// across the runtime pool's device workers.  Masks are identical
    /// either way; disable to get per-layer wall-clock timings.
    pub layer_parallel: bool,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PatternKind {
    Unstructured { sparsity: f64 },
    Nm { n: usize, m: usize },
}

impl PatternKind {
    pub fn pattern_for(&self, d_in: usize) -> Pattern {
        match *self {
            PatternKind::Unstructured { sparsity } =>
                Pattern::per_row_sparsity(d_in, sparsity),
            PatternKind::Nm { n, m } => Pattern::Nm { n, m },
        }
    }

    pub fn label(&self) -> String {
        match *self {
            PatternKind::Unstructured { sparsity } =>
                format!("{:.0}%", sparsity * 100.0),
            PatternKind::Nm { n, m } => format!("{n}:{m}"),
        }
    }
}

impl Default for PruneConfig {
    fn default() -> Self {
        Self {
            criterion: Criterion::Wanda,
            pattern_kind: PatternKind::Unstructured { sparsity: 0.6 },
            refiner: Refiner::SparseSwapsOffload {
                impl_name: "xla".into(),
            },
            t_max: 100,
            calib_batches: 8,
            sequential: true,
            checkpoints: Vec::new(),
            threads: default_threads(),
            layer_parallel: true,
        }
    }
}

#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub layer_type: String,
    pub block: usize,
    pub loss_warmstart: f64,
    pub loss_refined: f64,
    pub swaps: usize,
    pub rows_converged: usize,
    pub rows: usize,
    pub seconds: f64,
}

impl LayerReport {
    pub fn relative_reduction(&self) -> f64 {
        relative_reduction(self.loss_warmstart, self.loss_refined)
    }
}

#[derive(Clone, Debug, Default)]
pub struct PruneReport {
    pub layers: Vec<LayerReport>,
    pub calib_seconds: f64,
    /// Summed per-layer refinement time (CPU seconds under the
    /// layer-parallel schedule, wall seconds under the serial one).
    pub refine_seconds: f64,
    pub warmstart_seconds: f64,
    /// Mask snapshots per checkpoint (whole-model MaskSets).
    pub snapshots: BTreeMap<usize, MaskSet>,
}

impl PruneReport {
    pub fn total_warmstart_loss(&self) -> f64 {
        self.layers.iter().map(|l| l.loss_warmstart).sum()
    }

    pub fn total_refined_loss(&self) -> f64 {
        self.layers.iter().map(|l| l.loss_refined).sum()
    }

    /// Mean over layers of the per-layer relative reduction (the paper's
    /// Table 3/4 "average relative error reduction").
    pub fn mean_relative_reduction(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.relative_reduction()).sum::<f64>()
            / self.layers.len() as f64
    }
}

/// One layer's inputs.  Weights and mask are owned; the Gram matrix is
/// a zero-copy [`GramView`] into the block's calibration stream stack,
/// so scheduling a layer never materialises a d*d copy.  Jobs move to
/// pool workers through the scoped submission API
/// ([`ThreadPool::run_scoped`]), which is what lets them carry the
/// borrow.
struct LayerJob<'a> {
    li: usize,
    layer: PrunableLayer,
    w: crate::util::tensor::Matrix,
    g: crate::util::tensor::GramView<'a>,
    stats: Option<FeatureStats>,
    pattern: Pattern,
    mask: crate::util::tensor::Matrix,
}

struct LayerResult {
    li: usize,
    pattern: Pattern,
    mask: crate::util::tensor::Matrix,
    outcome: RefineOutcome,
    report: LayerReport,
}

/// Refine one prepared layer through an engine and assemble its report.
fn refine_job(engine: &dyn RefineEngine, job: LayerJob<'_>, t_max: usize,
              threads: usize, checkpoints: &[usize])
    -> Result<LayerResult, String> {
    let LayerJob { li, layer, w, g, stats, pattern, mut mask } = job;
    let ctx = LayerContext {
        w: &w,
        g,
        stats: stats.as_ref(),
        pattern,
        t_max,
        threads,
    };
    let t0 = Instant::now();
    let outcome = engine.refine(&ctx, &mut mask, checkpoints)
        .map_err(|e| format!("{}: {e}", layer.name))?;
    let seconds = t0.elapsed().as_secs_f64();
    let report = LayerReport {
        name: layer.name.clone(),
        layer_type: layer.layer_type.clone(),
        block: layer.block,
        loss_warmstart: outcome.layer.total_before(),
        loss_refined: outcome.layer.total_after(),
        swaps: outcome.layer.total_swaps(),
        rows_converged: outcome.layer.rows_converged(),
        rows: layer.d_out,
        seconds,
    };
    Ok(LayerResult { li, pattern, mask, outcome, report })
}

/// Refine a block's layers concurrently on the pool.  Each job builds
/// its runtime-free engine; the row-thread budget is split across the
/// concurrent jobs so a narrow block (fewer layers than cores) keeps
/// the same total parallelism as the serial schedule.  Row results are
/// independent of thread counts, so masks are identical either way.
fn refine_block_parallel<'a>(pool: &ThreadPool, jobs: Vec<LayerJob<'a>>,
                             refiner: &Refiner, t_max: usize,
                             threads: usize, checkpoints: &[usize])
    -> Result<Vec<LayerResult>, RuntimeError> {
    let n_jobs = jobs.len();
    let row_threads = (threads / n_jobs.max(1)).max(1);
    let (tx, rx) = std::sync::mpsc::channel();
    // Scoped submission: jobs borrow the block's Gram stream stack
    // (zero-copy views), so they go through `run_scoped`, which blocks
    // until every job has finished.
    let mut scoped: Vec<Box<dyn FnOnce() + Send + 'a>> =
        Vec::with_capacity(n_jobs);
    for job in jobs {
        let tx = tx.clone();
        let refiner = refiner.clone();
        let checkpoints = checkpoints.to_vec();
        scoped.push(Box::new(move || {
            let engine = refiner.local_engine()
                .expect("offload engines are scheduled serially");
            let res = refine_job(engine.as_ref(), job, t_max,
                                 row_threads, &checkpoints);
            let _ = tx.send(res);
        }));
    }
    drop(tx);
    pool.run_scoped(scoped);
    collect_block_results(rx, n_jobs)
}

/// Drain a block's fan-in channel: surface the first failed job,
/// detect jobs lost to worker panics (a panicked job is contained by
/// its pool but sends no result — better an error than a silently
/// incomplete mask set), and restore submission order.
fn collect_block_results(
    rx: std::sync::mpsc::Receiver<Result<LayerResult, String>>,
    n_jobs: usize,
) -> Result<Vec<LayerResult>, RuntimeError> {
    let mut results = Vec::new();
    for res in rx {
        results.push(res.map_err(RuntimeError::Msg)?);
    }
    if results.len() != n_jobs {
        return Err(RuntimeError::Msg(format!(
            "layer refinement lost {} of {} jobs (worker panic)",
            n_jobs - results.len(), n_jobs)));
    }
    results.sort_by_key(|r| r.li);
    Ok(results)
}

/// Refine a block's layers concurrently across the runtime pool's
/// workers (offload engine).  Each job builds an [`OffloadEngine`]
/// bound to *its* worker's runtime, so artifact executions fan out
/// over the devices while per-layer refinement — and therefore every
/// mask — stays identical to the serial single-service schedule.
fn refine_block_offload<'a>(pool: &RuntimePool, jobs: Vec<LayerJob<'a>>,
                            impl_name: &str, t_max: usize,
                            checkpoints: &[usize])
    -> Result<Vec<LayerResult>, RuntimeError> {
    let n_jobs = jobs.len();
    let (tx, rx) = std::sync::mpsc::channel();
    let mut scoped: Vec<Box<dyn FnOnce(&Runtime) + Send + 'a>> =
        Vec::with_capacity(n_jobs);
    for job in jobs {
        let tx = tx.clone();
        let impl_name = impl_name.to_string();
        let checkpoints = checkpoints.to_vec();
        scoped.push(Box::new(move |rt: &Runtime| {
            let engine = OffloadEngine::new(rt, impl_name);
            // Row parallelism lives inside the artifact; one host
            // thread per layer job is the whole story.
            let res = refine_job(&engine, job, t_max, 1, &checkpoints);
            let _ = tx.send(res);
        }));
    }
    drop(tx);
    pool.run_scoped(scoped);
    collect_block_results(rx, n_jobs)
}

/// Run the pruning pipeline.  `store` keeps its dense weights; the
/// resulting masks are returned (apply with `store.masked(&masks)`).
///
/// Serial stages (calibration, warmstarts) run on the pool's primary
/// runtime; offload refinement fans layers out across all pool
/// workers when `pool.devices() > 1` (disable with
/// `layer_parallel: false` — masks are bit-identical either way).
pub fn prune(pool: &RuntimePool, store: &ParamStore, ds: &Dataset,
             cfg: &PruneConfig) -> Result<(MaskSet, PruneReport),
                                          RuntimeError> {
    let rt: &Runtime = pool.primary();
    let meta = store.meta.clone();
    let calib = ds.batches(&meta, Split::Calibration, cfg.calib_batches);
    let mut masks = MaskSet::all_ones(&meta);
    let mut report = PruneReport::default();
    // Snapshot capture is tracked explicitly per (checkpoint, layer):
    // `None` means "not captured yet" and is backfilled with the final
    // layer mask at the end.  (The old implementation used "mask is
    // all-ones" as the not-captured sentinel, which clobbered
    // legitimately dense snapshots.)
    let n_layers = meta.prunable.len();
    let mut captured: BTreeMap<usize,
                               Vec<Option<crate::util::tensor::Matrix>>> =
        cfg.checkpoints.iter()
            .map(|&cp| (cp, (0..n_layers).map(|_| None).collect()))
            .collect();

    let use_thread_pool = cfg.layer_parallel && cfg.threads > 1
        && cfg.refiner.local_engine().is_some();
    let thread_pool = if use_thread_pool {
        Some(ThreadPool::new(cfg.threads))
    } else {
        None
    };
    let offload_impl = match &cfg.refiner {
        Refiner::SparseSwapsOffload { impl_name }
            if cfg.layer_parallel && pool.devices() > 1 =>
            Some(impl_name.clone()),
        _ => None,
    };

    let blocks: Vec<usize> = (0..meta.n_blocks).collect();
    let mut stats_oneshot: Option<GramStats> = None;
    if !cfg.sequential {
        let t0 = Instant::now();
        stats_oneshot = Some(accumulate(rt, store, &calib)?);
        report.calib_seconds += t0.elapsed().as_secs_f64();
    }

    for &b in &blocks {
        // Borrow (never clone) the Gram statistics: layer jobs hold
        // zero-copy views into this block's stream stacks.
        let stats_block;
        let stats: &GramStats = if cfg.sequential {
            // Recalibrate with everything pruned so far applied.
            let t0 = Instant::now();
            let masked = store.masked(&masks);
            stats_block = accumulate(rt, &masked, &calib)?;
            report.calib_seconds += t0.elapsed().as_secs_f64();
            &stats_block
        } else {
            stats_oneshot.as_ref().expect("one-shot stats computed")
        };

        let layers: Vec<_> = meta.prunable.iter().enumerate()
            .filter(|(_, l)| l.block == b)
            .map(|(i, l)| (i, l.clone()))
            .collect();

        // Warmstart every layer first (cheap, serial), then refine.
        let mut jobs = Vec::with_capacity(layers.len());
        for (li, layer) in layers {
            let w = store.weight(&layer);
            let g = stats.gram_for(&layer);
            let pattern = cfg.pattern_kind.pattern_for(layer.d_in);
            let t0 = Instant::now();
            let scores = saliency::scores(cfg.criterion, &w, &g.diag());
            let mask = mask_from_scores(&scores, pattern);
            report.warmstart_seconds += t0.elapsed().as_secs_f64();
            let fstats = if cfg.refiner == Refiner::Dsnot {
                Some(stats.feature_stats_for(&layer))
            } else {
                None
            };
            jobs.push(LayerJob {
                li, layer, w, g, stats: fstats, pattern, mask,
            });
        }

        let results = if let Some(tp) = &thread_pool {
            refine_block_parallel(tp, jobs, &cfg.refiner, cfg.t_max,
                                  cfg.threads, &cfg.checkpoints)?
        } else if let Some(impl_name) = &offload_impl {
            refine_block_offload(pool, jobs, impl_name, cfg.t_max,
                                 &cfg.checkpoints)?
        } else {
            let engine = cfg.refiner.engine(rt);
            let mut out = Vec::with_capacity(jobs.len());
            for job in jobs {
                out.push(refine_job(engine.as_ref(), job, cfg.t_max,
                                    cfg.threads, &cfg.checkpoints)
                         .map_err(RuntimeError::Msg)?);
            }
            out
        };

        for res in results {
            let LayerResult { li, pattern, mask, outcome, report: lr } =
                res;
            report.refine_seconds += lr.seconds;
            validate(&mask, pattern)
                .map_err(|e| RuntimeError::Msg(format!(
                    "{}: {e}", lr.name)))?;
            crate::log_debug!(
                "prune[{}] {} loss {:.4} -> {:.4} ({:+.1}%)",
                meta.name, lr.name, lr.loss_warmstart, lr.loss_refined,
                -100.0 * lr.relative_reduction());
            for (cp, snap) in outcome.snapshots {
                if let Some(slots) = captured.get_mut(&cp) {
                    slots[li] = Some(snap);
                }
            }
            masks.masks[li] = mask;
            report.layers.push(lr);
        }
    }

    // Each snapshot covers layers only up to its capture point; fill the
    // never-captured slots with the final masks so every snapshot is a
    // complete, valid model mask.
    let final_masks = masks.clone();
    for (cp, slots) in captured {
        let snapshot = MaskSet {
            masks: slots.into_iter().enumerate()
                .map(|(i, m)| m.unwrap_or_else(
                    || final_masks.masks[i].clone()))
                .collect(),
        };
        report.snapshots.insert(cp, snapshot);
    }
    Ok((masks, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(Refiner::None.label(), "none");
        assert_eq!(Refiner::SparseSwapsOffload { impl_name: "xla".into() }
                   .label(), "sparseswaps[xla]");
        assert_eq!(PatternKind::Unstructured { sparsity: 0.6 }.label(),
                   "60%");
        assert_eq!(PatternKind::Nm { n: 2, m: 4 }.label(), "2:4");
    }

    #[test]
    fn pattern_for_width() {
        let pk = PatternKind::Unstructured { sparsity: 0.5 };
        assert_eq!(pk.pattern_for(64), Pattern::PerRow { keep: 32 });
        let nm = PatternKind::Nm { n: 2, m: 4 };
        assert_eq!(nm.pattern_for(64), Pattern::Nm { n: 2, m: 4 });
    }

    #[test]
    fn local_engines_cover_runtime_free_refiners() {
        assert!(Refiner::None.local_engine().is_some());
        assert!(Refiner::SparseSwapsNative.local_engine().is_some());
        assert!(Refiner::Dsnot.local_engine().is_some());
        assert!(Refiner::SparseSwapsOffload { impl_name: "xla".into() }
                .local_engine().is_none());
    }

    #[test]
    fn engine_labels_match_refiner_labels() {
        for r in [Refiner::None, Refiner::SparseSwapsNative,
                  Refiner::Dsnot] {
            assert_eq!(r.local_engine().unwrap().name(), r.label());
        }
    }
}
