//! The `RefineEngine` trait: one uniform contract for every mask
//! refiner, plus the crate's single checkpoint-segmentation driver.
//!
//! Before this module existed every refiner was an arm of a large
//! `match` inside the coordinator prune pipeline, and the Table-3
//! checkpoint/snapshot bookkeeping was implemented twice (once in the
//! native path, once — differently — in the offload swap loop).  Now:
//!
//!   * every refiner implements [`RefineEngine::refine_rows`] over a
//!     borrowed [`LayerContext`] and a *row range* — the shard work
//!     unit — so the pipeline schedules row shards without knowing
//!     which algorithm runs inside ([`RefineEngine::refine`] is the
//!     whole-layer convenience form: one shard covering every row);
//!   * segmented engines (native and offload SparseSwaps) drive their
//!     iteration budget through [`drive_segments`], the one place that
//!     knows how to split `t_max` at checkpoint boundaries and capture
//!     mask snapshots; under sharding the driver runs once per shard
//!     and [`SnapshotAssembler`] merges the per-shard snapshots back
//!     into whole-layer masks;
//!   * adding a refiner from related work (Frank-Wolfe relaxation,
//!     learnable masks, ...) is a one-file change: implement the trait
//!     and register a constructor in `Refiner::shard_engine`
//!     (`coordinator::pipeline`).  See `examples/custom_engine.rs`.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::ops::Range;

use crate::pruning::dsnot::FeatureStats;
use crate::pruning::mask::Pattern;
use crate::pruning::sparseswaps::{LayerOutcome, RowOutcome};
use crate::util::tensor::{GramView, Matrix, MatrixView};

/// Everything a refiner may consume for one layer.  Borrowed, so the
/// pipeline stays free to schedule layers concurrently.
pub struct LayerContext<'a> {
    /// Dense weights, [d_out, d_in] (the paper's row-major layout): a
    /// zero-copy view into the parameter store or a weight-block
    /// lease, so refinement never duplicates the weight payload.
    pub w: MatrixView<'a>,
    /// Gram matrix of the layer's input stream, [d_in, d_in]: a
    /// zero-copy view into the calibration stream stack (or into a
    /// square `Matrix` via [`Matrix::as_gram`]).
    pub g: GramView<'a>,
    /// Per-feature calibration statistics for surrogate-objective
    /// refiners (DSnoT); exact-objective engines ignore it.
    pub stats: Option<&'a FeatureStats>,
    pub pattern: Pattern,
    /// Iteration budget per row (the paper's T_max).
    pub t_max: usize,
    /// Worker threads the engine may use internally.
    pub threads: usize,
    /// Shared per-layer skip-bound table: `gmax[u]` = max |G_uj| over
    /// column `u`'s scan scope (whole row unstructured, its N:M block
    /// for [`Pattern::Nm`] — see `sparseswaps::gmax_table`).  The
    /// table depends only on `g` and `pattern`, so the scheduler
    /// computes it once per layer and every row shard borrows it;
    /// `None` makes engines that want it compute their own (the
    /// whole-layer convenience path).  Must have length `g.d` and
    /// match `pattern`'s block size when present.
    pub gmax: Option<&'a [f64]>,
}

/// Why a refinement call failed.
#[derive(Debug)]
pub enum RefineError {
    /// Engine-internal failure (artifact lookup, runtime execution, ...).
    Msg(String),
    /// The [`LayerContext`] lacks an input this engine requires.
    MissingInput(&'static str),
    /// Worker-tied failure (dead runtime worker, evicted buffers):
    /// the same rows can succeed on another worker, so the shard
    /// scheduler redispatches these and only these.
    Transient(String),
}

impl fmt::Display for RefineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RefineError::Msg(s) => write!(f, "refine: {s}"),
            RefineError::MissingInput(what) =>
                write!(f, "refine: missing input: {what}"),
            RefineError::Transient(s) =>
                write!(f, "refine (transient): {s}"),
        }
    }
}

impl RefineError {
    /// True when a retry on a different worker can fix this failure
    /// (see `RuntimeError::is_transient`, which this mirrors at the
    /// engine layer).
    pub fn is_transient(&self) -> bool {
        matches!(self, RefineError::Transient(_))
    }
}

impl std::error::Error for RefineError {}

impl From<String> for RefineError {
    fn from(s: String) -> Self {
        RefineError::Msg(s)
    }
}

/// What a refinement call produced: per-row outcomes plus the mask
/// snapshots captured at the requested iteration checkpoints.
#[derive(Clone, Debug, Default)]
pub struct RefineOutcome {
    /// Per-row losses, swap counts and convergence flags.
    pub layer: LayerOutcome,
    /// Mask snapshot per requested checkpoint; every requested
    /// checkpoint in (0, t_max] is present (engines that do not iterate
    /// — warmstart-only, DSnoT — return an empty map and the pipeline
    /// backfills with the final mask).
    pub snapshots: BTreeMap<usize, Matrix>,
}

/// The uniform refiner contract.
///
/// The work unit is a *row shard*: a contiguous row range of one
/// layer.  Because the paper enforces equal per-row sparsity, every
/// row's refinement is independent, so implementations must produce
/// identical per-row results for any row partition — that invariant
/// is what lets the scheduler split a wide layer across workers with
/// bit-identical masks (property-tested in `tests/shards.rs`).
pub trait RefineEngine {
    /// Stable engine label for logs and reports.
    fn name(&self) -> String;

    /// Refine rows `rows` of the layer under `ctx`, capturing
    /// snapshots at the requested cumulative-iteration checkpoints.
    /// `mask` is *shard-local*: `rows.len()` x `ctx.w.cols`, its row
    /// `k` corresponding to layer row `rows.start + k`; the outcome's
    /// per-row results and snapshots are shard-local too.
    /// Implementations must keep every mask row valid for
    /// `ctx.pattern` at every step (row sharding cannot split an N:M
    /// block — blocks span columns within one row).
    fn refine_rows(&self, ctx: &LayerContext, rows: Range<usize>,
                   mask: &mut Matrix, checkpoints: &[usize])
        -> Result<RefineOutcome, RefineError>;

    /// Whole-layer refinement: one shard covering every row.
    fn refine(&self, ctx: &LayerContext, mask: &mut Matrix,
              checkpoints: &[usize])
        -> Result<RefineOutcome, RefineError> {
        self.refine_rows(ctx, 0..ctx.w.rows, mask, checkpoints)
    }
}

/// The checkpoint-segmentation driver — the only implementation of
/// Table-3 snapshot bookkeeping in the crate, shared by the native and
/// offload engines.
///
/// `advance` moves every unconverged row forward by at most `budget`
/// iterations and returns the number it actually executed (uniform
/// across active rows by construction: engines advance rows in
/// lockstep).  Returning 0 signals a stationary mask; the driver then
/// jumps to the next boundary so later checkpoints still get recorded.
/// Checkpoints outside (0, t_max] are ignored here and backfilled by
/// the caller.
pub fn drive_segments<F>(t_max: usize, checkpoints: &[usize],
                         mask: &mut Matrix, mut advance: F)
    -> Result<BTreeMap<usize, Matrix>, RefineError>
where
    F: FnMut(&mut Matrix, usize) -> Result<usize, RefineError>,
{
    let mut stops: Vec<usize> = checkpoints.iter().copied()
        .filter(|&c| c > 0 && c <= t_max)
        .collect();
    stops.sort_unstable();
    stops.dedup();
    let mut snapshots: BTreeMap<usize, Matrix> = BTreeMap::new();
    let mut done = 0usize;
    while done < t_max {
        let next_stop = stops.iter().copied().find(|&c| c > done)
            .unwrap_or(t_max);
        let budget = next_stop - done;
        let stepped = advance(mask, budget)?;
        done = if stepped == 0 {
            next_stop
        } else {
            done + stepped.min(budget)
        };
        if stops.binary_search(&done).is_ok() {
            snapshots.insert(done, mask.clone());
        }
    }
    // Every row may converge before later checkpoints; the mask is
    // stationary from there, so the remaining snapshots are the final
    // mask (Table-3 sweeps always see a complete series).
    for &cp in &stops {
        snapshots.entry(cp).or_insert_with(|| mask.clone());
    }
    Ok(snapshots)
}

/// Merges per-shard refinement results back into whole-layer state:
/// the final layer mask plus one whole-layer `Matrix` snapshot per
/// checkpoint.  The per-layer `mask.clone()` bookkeeping the driver
/// does cannot survive sharding as-is — each shard only ever saw its
/// own rows — so this is the one place shard-local snapshots become
/// model-shaped ones again.
///
/// A shard missing a checkpoint contributes its *final* mask there:
/// either its engine never iterates (warmstart-only, DSnoT — empty
/// snapshot maps, later backfilled by the pipeline), or every one of
/// its rows converged before the checkpoint, in which case the rows
/// were stationary from convergence on and the final mask is exactly
/// what the whole-layer schedule would have recorded.
pub struct SnapshotAssembler {
    rows: usize,
    cols: usize,
    shards: Vec<(Range<usize>, Matrix, BTreeMap<usize, Matrix>)>,
}

impl SnapshotAssembler {
    /// Assembler for one `rows` x `cols` layer.
    pub fn new(rows: usize, cols: usize) -> SnapshotAssembler {
        SnapshotAssembler { rows, cols, shards: Vec::new() }
    }

    /// Record one shard's final mask and checkpoint snapshots (`mask`
    /// holds layer rows `rows`, shard-local shape).
    pub fn add(&mut self, rows: Range<usize>, mask: Matrix,
               snapshots: BTreeMap<usize, Matrix>) {
        assert_eq!((mask.rows, mask.cols), (rows.len(), self.cols),
                   "shard mask shape does not match its row range");
        for snap in snapshots.values() {
            assert_eq!((snap.rows, snap.cols), (rows.len(), self.cols),
                       "shard snapshot shape does not match its range");
        }
        self.shards.push((rows, mask, snapshots));
    }

    /// Assemble, checking the shards tile `0..rows` exactly once.
    /// Returns the final whole-layer mask and a whole-layer snapshot
    /// per checkpoint seen by any shard.
    pub fn finish(mut self)
        -> Result<(Matrix, BTreeMap<usize, Matrix>), String> {
        self.shards.sort_by_key(|(r, _, _)| r.start);
        let mut next = 0usize;
        for (r, _, _) in &self.shards {
            if r.start != next {
                return Err(format!(
                    "shards do not tile the layer: expected row {next}, \
                     got {}", r.start));
            }
            next = r.end;
        }
        if next != self.rows {
            return Err(format!(
                "shards cover {next} of {} layer rows", self.rows));
        }
        let copy_into = |dst: &mut Matrix, r: &Range<usize>,
                         src: &Matrix| {
            for (k, row) in r.clone().enumerate() {
                dst.row_mut(row).copy_from_slice(src.row(k));
            }
        };
        let mut mask = Matrix::zeros(self.rows, self.cols);
        for (r, m, _) in &self.shards {
            copy_into(&mut mask, r, m);
        }
        let cps: BTreeSet<usize> = self.shards.iter()
            .flat_map(|(_, _, s)| s.keys().copied())
            .collect();
        let mut snapshots = BTreeMap::new();
        for cp in cps {
            let mut snap = Matrix::zeros(self.rows, self.cols);
            for (r, m, s) in &self.shards {
                copy_into(&mut snap, r, s.get(&cp).unwrap_or(m));
            }
            snapshots.insert(cp, snap);
        }
        Ok((mask, snapshots))
    }
}

/// Warmstart-only "refiner": records the exact per-row loss and leaves
/// the mask untouched.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopEngine;

impl RefineEngine for NoopEngine {
    fn name(&self) -> String {
        "none".into()
    }

    fn refine_rows(&self, ctx: &LayerContext, rows: Range<usize>,
                   mask: &mut Matrix, _checkpoints: &[usize])
        -> Result<RefineOutcome, RefineError> {
        assert!(rows.end <= ctx.w.rows);
        assert_eq!((mask.rows, mask.cols), (rows.len(), ctx.w.cols));
        let out = (0..rows.len())
            .map(|k| {
                let l = crate::pruning::error::row_loss(
                    ctx.w.row(rows.start + k), mask.row(k), ctx.g);
                RowOutcome {
                    loss_before: l,
                    loss_after: l,
                    swaps: 0,
                    converged: false,
                }
            })
            .collect();
        Ok(RefineOutcome {
            layer: LayerOutcome { rows: out },
            snapshots: BTreeMap::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask::mask_from_scores;
    use crate::pruning::saliency;
    use crate::util::prng::Rng;

    fn instance() -> (Matrix, Matrix, Matrix, Pattern) {
        let mut rng = Rng::new(11);
        let d = 16;
        let x = Matrix::from_fn(48, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let w = Matrix::from_fn(4, d, |_, _| rng.gaussian_f32());
        let pattern = Pattern::PerRow { keep: 6 };
        let mask = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);
        (w, g, mask, pattern)
    }

    #[test]
    fn noop_preserves_mask_and_reports_loss() {
        let (w, g, mut mask, pattern) = instance();
        let before = mask.clone();
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: None, pattern, t_max: 10,
            threads: 1, gmax: None,
        };
        let out = NoopEngine.refine(&ctx, &mut mask, &[2, 5]).unwrap();
        assert_eq!(mask.data, before.data);
        assert!(out.snapshots.is_empty());
        assert_eq!(out.layer.rows.len(), w.rows);
        assert!((out.layer.total_before() - out.layer.total_after()).abs()
                < 1e-12);
        assert!(out.layer.total_before() > 0.0);
    }

    #[test]
    fn driver_segments_at_checkpoints() {
        let mut mask = Matrix::zeros(1, 4);
        let mut budgets: Vec<usize> = Vec::new();
        let snaps = drive_segments(10, &[3, 7, 12, 0], &mut mask,
                                   |m, budget| {
            budgets.push(budget);
            // Mutate so snapshots are distinguishable.
            m.data[0] += budget as f32;
            Ok(budget)
        }).unwrap();
        // Segments split exactly at in-range checkpoints.
        assert_eq!(budgets, vec![3, 4, 3]);
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[&3].data[0], 3.0);
        assert_eq!(snaps[&7].data[0], 7.0);
    }

    #[test]
    fn driver_backfills_after_stationary() {
        let mut mask = Matrix::zeros(1, 2);
        let mut calls = 0;
        let snaps = drive_segments(20, &[2, 15], &mut mask, |m, budget| {
            calls += 1;
            if calls == 1 {
                m.data[0] = 1.0;
                Ok(budget)
            } else {
                Ok(0) // stationary: all rows converged
            }
        }).unwrap();
        // Checkpoint 2 captured live; 15 backfilled with the final mask.
        assert_eq!(snaps[&2].data[0], 1.0);
        assert_eq!(snaps[&15].data[0], 1.0);
    }

    fn fill(rows: usize, cols: usize, v: f32) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| v)
    }

    #[test]
    fn assembler_merges_shards_and_backfills_missing_checkpoints() {
        let mut asm = SnapshotAssembler::new(5, 3);
        // Shard 0..2 captured checkpoint 4; shard 2..5 converged early
        // and returns no snapshot there — its final mask fills in.
        let mut s0 = BTreeMap::new();
        s0.insert(4usize, fill(2, 3, 1.0));
        asm.add(0..2, fill(2, 3, 2.0), s0);
        asm.add(2..5, fill(3, 3, 7.0), BTreeMap::new());
        let (mask, snaps) = asm.finish().unwrap();
        assert_eq!(mask.row(0), &[2.0; 3]);
        assert_eq!(mask.row(4), &[7.0; 3]);
        assert_eq!(snaps.len(), 1);
        let snap = &snaps[&4];
        assert_eq!(snap.row(1), &[1.0; 3]);
        assert_eq!(snap.row(2), &[7.0; 3]);
    }

    #[test]
    fn assembler_rejects_gaps_and_short_coverage() {
        let mut asm = SnapshotAssembler::new(4, 2);
        asm.add(0..1, fill(1, 2, 0.0), BTreeMap::new());
        asm.add(2..4, fill(2, 2, 0.0), BTreeMap::new());
        assert!(asm.finish().is_err());
        let mut asm = SnapshotAssembler::new(4, 2);
        asm.add(0..3, fill(3, 2, 0.0), BTreeMap::new());
        assert!(asm.finish().is_err());
    }

    #[test]
    fn noop_refines_rows_against_layer_offsets() {
        let (w, g, mask, pattern) = instance();
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: None, pattern, t_max: 5,
            threads: 1, gmax: None,
        };
        // Shard rows 1..3: losses must match the whole-layer call.
        let full = NoopEngine.refine(&ctx, &mut mask.clone(), &[])
            .unwrap();
        let mut shard = Matrix::zeros(2, w.cols);
        shard.row_mut(0).copy_from_slice(mask.row(1));
        shard.row_mut(1).copy_from_slice(mask.row(2));
        let out = NoopEngine.refine_rows(&ctx, 1..3, &mut shard, &[])
            .unwrap();
        assert_eq!(out.layer.rows.len(), 2);
        for k in 0..2 {
            assert_eq!(out.layer.rows[k].loss_before,
                       full.layer.rows[k + 1].loss_before);
        }
    }

    #[test]
    fn driver_partial_steps_accumulate() {
        // An engine stepping k=2 at a time still lands on even
        // checkpoints and t_max exactly.
        let mut mask = Matrix::zeros(1, 1);
        let snaps = drive_segments(8, &[4], &mut mask, |m, budget| {
            let k = budget.min(2);
            m.data[0] += k as f32;
            Ok(k)
        }).unwrap();
        assert_eq!(snaps[&4].data[0], 4.0);
        assert_eq!(mask.data[0], 8.0);
    }
}
