//! Cross-row sparsity reallocation — the paper's explicitly-named future
//! work ("the algorithm … cannot reallocate sparsity levels across rows.
//! A reallocation of sparsity between individual rows might pose an
//! interesting direction").
//!
//! We implement a marginal-cost reallocator on top of the Gram-form
//! loss: starting from the uniform per-row budget, repeatedly move one
//! unit of *keep* budget from the row that loses least by pruning one
//! more weight to the row that gains most by keeping one more, as
//! long as the exchange strictly decreases the summed layer loss.
//!
//! Marginal costs are exact and cheap in the Gram form:
//!   * giving row i one more keep = the best single *unprune* move:
//!     min_p  -2 w_p c_p + w_p^2 G_pp   (dL of reviving p; <= 0 gain)
//!   * taking one keep from row i = the best single *prune* move:
//!     min_u   2 w_u c_u + w_u^2 G_uu   (dL of pruning u; >= 0 cost)
//!
//! After reallocation each row is refined by ordinary SparseSwaps under
//! its new budget, so the result remains a per-row-constrained mask —
//! just with a non-uniform, loss-aware budget split (total keeps
//! unchanged, so the *layer* sparsity still matches the target exactly).

use crate::pruning::error::corr_vector;
use crate::pruning::sparseswaps::{refine_row, SwapConfig};
use crate::util::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct ReallocConfig {
    /// Maximum budget moves (keep-unit exchanges between rows).
    pub max_moves: usize,
    /// Keep at least this many weights in every row.
    pub min_keep: usize,
    /// SparseSwaps budget for the post-reallocation refinement.
    pub t_max: usize,
}

impl Default for ReallocConfig {
    fn default() -> Self {
        Self { max_moves: 256, min_keep: 1, t_max: 50 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct ReallocOutcome {
    pub moves: usize,
    pub loss_uniform: f64,
    pub loss_realloc: f64,
    /// Final keep budget per row.
    pub budgets: Vec<usize>,
}

/// Best single unprune gain (dL <= 0) for a row: (dl, index).
fn best_unprune(w: &[f32], m: &[f32], c: &[f32], g: &Matrix)
    -> Option<(f64, usize)> {
    let mut best: Option<(f64, usize)> = None;
    for p in 0..w.len() {
        if m[p] < 0.5 {
            let dl = -2.0 * w[p] as f64 * c[p] as f64
                + (w[p] as f64).powi(2) * g.at(p, p) as f64;
            if best.map_or(true, |(b, _)| dl < b) {
                best = Some((dl, p));
            }
        }
    }
    best
}

/// Cheapest single prune cost (dL >= 0 usually) for a row: (dl, index).
fn best_prune(w: &[f32], m: &[f32], c: &[f32], g: &Matrix)
    -> Option<(f64, usize)> {
    let mut best: Option<(f64, usize)> = None;
    for u in 0..w.len() {
        if m[u] > 0.5 {
            let dl = 2.0 * w[u] as f64 * c[u] as f64
                + (w[u] as f64).powi(2) * g.at(u, u) as f64;
            if best.map_or(true, |(b, _)| dl < b) {
                best = Some((dl, u));
            }
        }
    }
    best
}

/// Reallocate keep budgets across rows of one layer, then refine each
/// row with SparseSwaps under its final budget.  `mask` must satisfy a
/// uniform per-row pattern on entry; on exit it satisfies per-row
/// budgets summing to the same total (layer sparsity preserved).
pub fn reallocate_layer(w: &Matrix, mask: &mut Matrix, g: &Matrix,
                        cfg: &ReallocConfig) -> ReallocOutcome {
    let rows = w.rows;
    let d = w.cols;
    // Per-row working state.
    let mut ms: Vec<Vec<f32>> =
        (0..rows).map(|r| mask.row(r).to_vec()).collect();
    let mut cs: Vec<Vec<f32>> = (0..rows)
        .map(|r| corr_vector(w.row(r), &ms[r], g))
        .collect();
    let loss_of = |r: usize, m: &[f32], c: &[f32]| {
        crate::pruning::error::row_loss_with_corr(w.row(r), m, c)
    };
    let loss_uniform: f64 =
        (0..rows).map(|r| loss_of(r, &ms[r], &cs[r])).sum();

    let mut moves = 0;
    for _ in 0..cfg.max_moves {
        // Receiver: the row with the largest gain from +1 keep.
        // Donor: the row with the smallest cost of -1 keep.
        let mut recv: Option<(f64, usize, usize)> = None; // (dl, row, p)
        let mut donor: Option<(f64, usize, usize)> = None; // (dl, row, u)
        for r in 0..rows {
            let keeps = ms[r].iter().filter(|&&v| v > 0.5).count();
            if keeps < d {
                if let Some((dl, p)) = best_unprune(w.row(r), &ms[r],
                                                    &cs[r], g) {
                    if recv.map_or(true, |(b, _, _)| dl < b) {
                        recv = Some((dl, r, p));
                    }
                }
            }
            if keeps > cfg.min_keep {
                if let Some((dl, u)) = best_prune(w.row(r), &ms[r],
                                                  &cs[r], g) {
                    if donor.map_or(true, |(b, _, _)| dl < b) {
                        donor = Some((dl, r, u));
                    }
                }
            }
        }
        let (Some((gain, rr, p)), Some((cost, dr, u))) = (recv, donor)
            else { break };
        if rr == dr || gain + cost >= 0.0 {
            // Same row (ordinary swap territory) or no net win: stop.
            break;
        }
        // Apply: row rr keeps p; row dr prunes u.  Update c per Eq. 6
        // (one-sided variants: only one index flips per row; G is
        // symmetric, so column p is row p — one kernel axpy each).
        ms[rr][p] = 1.0;
        crate::util::tensor::axpy(-w.row(rr)[p], g.row(p), &mut cs[rr]);
        ms[dr][u] = 0.0;
        crate::util::tensor::axpy(w.row(dr)[u], g.row(u), &mut cs[dr]);
        moves += 1;
    }

    // Refine every row under its final budget.
    let scfg = SwapConfig { t_max: cfg.t_max, eps: 0.0 };
    let mut budgets = Vec::with_capacity(rows);
    let mut loss_realloc = 0.0;
    for r in 0..rows {
        let out = refine_row(w.row(r), &mut ms[r], g, 0, &scfg);
        loss_realloc += out.loss_after;
        budgets.push(ms[r].iter().filter(|&&v| v > 0.5).count());
        mask.row_mut(r).copy_from_slice(&ms[r]);
    }
    ReallocOutcome { moves, loss_uniform, loss_realloc, budgets }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::error::layer_loss;
    use crate::pruning::mask::{mask_from_scores, Pattern};
    use crate::pruning::saliency;
    use crate::pruning::sparseswaps::refine_layer;
    use crate::util::prng::Rng;

    fn instance(seed: u64, rows: usize, d: usize) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(3 * d, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        // Heterogeneous row scales so reallocation has something to do.
        let w = Matrix::from_fn(rows, d, |r, _| {
            rng.gaussian_f32() * (1.0 + r as f32)
        });
        (w, g)
    }

    #[test]
    fn total_keeps_preserved() {
        let (w, g) = instance(0, 6, 24);
        let pattern = Pattern::PerRow { keep: 10 };
        let mut mask = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                        pattern);
        let before: f32 = mask.data.iter().sum();
        reallocate_layer(&w, &mut mask, &g, &ReallocConfig::default());
        let after: f32 = mask.data.iter().sum();
        assert_eq!(before, after, "layer sparsity must be unchanged");
    }

    #[test]
    fn beats_or_matches_uniform_sparseswaps() {
        for seed in 0..5 {
            let (w, g) = instance(seed, 6, 24);
            let pattern = Pattern::PerRow { keep: 9 };
            let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                        pattern);
            // Uniform budgets + SparseSwaps.
            let mut uni = warm.clone();
            refine_layer(&w, &mut uni, &g, pattern,
                         &SwapConfig { t_max: 50, eps: 0.0 }, 1);
            let loss_uni = layer_loss(&w, &uni, &g);
            // Reallocated budgets + SparseSwaps.
            let mut re = warm.clone();
            let out = reallocate_layer(&w, &mut re, &g, &ReallocConfig {
                t_max: 50, ..Default::default()
            });
            let loss_re = layer_loss(&w, &re, &g);
            assert!(loss_re <= loss_uni * 1.001 + 1e-6,
                    "seed {seed}: realloc {loss_re} > uniform {loss_uni}");
            assert!((out.loss_realloc - loss_re).abs()
                    / loss_re.max(1.0) < 1e-3);
        }
    }

    #[test]
    fn respects_min_keep() {
        let (w, g) = instance(3, 4, 16);
        let pattern = Pattern::PerRow { keep: 4 };
        let mut mask = mask_from_scores(&saliency::magnitude(&w), pattern);
        let out = reallocate_layer(&w, &mut mask, &g, &ReallocConfig {
            max_moves: 1000, min_keep: 2, t_max: 20,
        });
        assert!(out.budgets.iter().all(|&b| b >= 2), "{:?}", out.budgets);
    }

    #[test]
    fn heterogeneous_rows_attract_budget() {
        // With row scales growing in r, later (high-energy) rows should
        // end up with at least as much budget on average.
        let (w, g) = instance(7, 8, 32);
        let pattern = Pattern::PerRow { keep: 12 };
        let mut mask = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                        pattern);
        let out = reallocate_layer(&w, &mut mask, &g, &ReallocConfig {
            max_moves: 500, min_keep: 1, t_max: 20,
        });
        if out.moves > 0 {
            let lo: usize = out.budgets[..4].iter().sum();
            let hi: usize = out.budgets[4..].iter().sum();
            assert!(hi >= lo, "budgets {:?}", out.budgets);
        }
    }

    #[test]
    fn no_moves_on_homogeneous_rows_is_fine() {
        // Identical rows: reallocation may find nothing; must still be
        // a valid refinement.
        let mut rng = Rng::new(9);
        let x = Matrix::from_fn(64, 16, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(16, 16);
        g.gram_accumulate(&x);
        let row: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
        let w = Matrix::from_fn(4, 16, |_, j| row[j]);
        let pattern = Pattern::PerRow { keep: 8 };
        let mut mask = mask_from_scores(&saliency::magnitude(&w), pattern);
        let before = layer_loss(&w, &mask, &g);
        reallocate_layer(&w, &mut mask, &g, &ReallocConfig::default());
        let after = layer_loss(&w, &mask, &g);
        assert!(after <= before + 1e-6);
    }
}
