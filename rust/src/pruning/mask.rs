//! Pruning masks and sparsity patterns.
//!
//! Masks are stored as f32 {0,1} matrices ([`Matrix`]) so they can be fed
//! to the HLO swap artifacts without conversion; 1 keeps a weight, 0
//! prunes it.  Patterns follow the paper: per-row (equal sparsity per
//! row — the row-decoupling assumption of Sec 2.1.1) and semi-structured
//! N:M (keep N per block of M).

use crate::util::tensor::Matrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    /// Keep exactly `keep` weights in every row.
    PerRow { keep: usize },
    /// Keep exactly `n` weights in every consecutive block of `m`.
    Nm { n: usize, m: usize },
}

impl Pattern {
    /// Per-row pattern from a target sparsity fraction (pruned share).
    pub fn per_row_sparsity(d_in: usize, sparsity: f64) -> Pattern {
        assert!((0.0..1.0).contains(&sparsity));
        let prune = ((d_in as f64) * sparsity).round() as usize;
        Pattern::PerRow { keep: d_in - prune.min(d_in) }
    }

    pub fn sparsity(&self, d_in: usize) -> f64 {
        match self {
            Pattern::PerRow { keep } => 1.0 - *keep as f64 / d_in as f64,
            Pattern::Nm { n, m } => 1.0 - *n as f64 / *m as f64,
        }
    }

    /// The block width constraining swaps (0 = whole row).
    pub fn nm_block(&self) -> usize {
        match self {
            Pattern::PerRow { .. } => 0,
            Pattern::Nm { m, .. } => *m,
        }
    }

    pub fn keep_per_row(&self, d_in: usize) -> usize {
        match self {
            Pattern::PerRow { keep } => (*keep).min(d_in),
            Pattern::Nm { n, m } => d_in / m * n,
        }
    }

    /// Artifact-name suffix ("row", "nm2_4", "nm4_8").
    pub fn artifact_tag(&self) -> String {
        match self {
            Pattern::PerRow { .. } => "row".to_string(),
            Pattern::Nm { n, m } => format!("nm{n}_{m}"),
        }
    }

    pub fn parse(s: &str) -> Option<Pattern> {
        if let Some((n, m)) = s.split_once(':') {
            let n = n.parse().ok()?;
            let m = m.parse().ok()?;
            if n == 0 || m == 0 || n > m {
                return None;
            }
            Some(Pattern::Nm { n, m })
        } else {
            None
        }
    }
}

/// Build a mask keeping the highest-score entries under `pattern`.
/// Deterministic: ties break toward the lower index.
pub fn mask_from_scores(scores: &Matrix, pattern: Pattern) -> Matrix {
    let (rows, cols) = (scores.rows, scores.cols);
    let mut mask = Matrix::zeros(rows, cols);
    match pattern {
        Pattern::PerRow { keep } => {
            let keep = keep.min(cols);
            let mut idx: Vec<usize> = Vec::with_capacity(cols);
            for r in 0..rows {
                idx.clear();
                idx.extend(0..cols);
                let srow = scores.row(r);
                idx.sort_by(|&a, &b| srow[b]
                    .partial_cmp(&srow[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b)));
                let mrow = mask.row_mut(r);
                for &j in idx.iter().take(keep) {
                    mrow[j] = 1.0;
                }
            }
        }
        Pattern::Nm { n, m } => {
            assert!(cols % m == 0,
                    "d_in {cols} not divisible by N:M block {m}");
            for r in 0..rows {
                let srow = scores.row(r);
                let mrow = mask.row_mut(r);
                for b in 0..cols / m {
                    let lo = b * m;
                    let mut idx: Vec<usize> = (lo..lo + m).collect();
                    idx.sort_by(|&a, &c| srow[c]
                        .partial_cmp(&srow[a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&c)));
                    for &j in idx.iter().take(n) {
                        mrow[j] = 1.0;
                    }
                }
            }
        }
    }
    mask
}

/// Rank candidate indices for one row/block: previously-kept entries
/// first, then by descending score, then by index.  Filling a keep
/// budget from this order prunes the lowest-score *kept* weights when
/// tightening and backfills the highest-score *pruned* weights when
/// loosening.
fn rank_kept_then_score(idx: &mut [usize], srow: &[f32],
                        prow: &[f32]) {
    idx.sort_by(|&a, &b| {
        (prow[b] != 0.0).cmp(&(prow[a] != 0.0))
            .then(srow[b].partial_cmp(&srow[a])
                .unwrap_or(std::cmp::Ordering::Equal))
            .then(a.cmp(&b))
    });
}

/// Derive a mask satisfying `pattern` from a previous (typically
/// looser) mask: per row (or per N:M block), candidates are ranked
/// kept-first, then by score, then by index, and the pattern's budget
/// is filled from the top.  Tightening from sparsity s to s' > s thus
/// prunes exactly the lowest-saliency kept weights — the sweep
/// harness's warm-started mask continuation.  The result is always an
/// exact `pattern` mask, even across kinds (per-row -> N:M), it is
/// deterministic, and it reproduces `prev` whenever `prev` already
/// satisfies `pattern`.
pub fn tighten_mask(prev: &Matrix, scores: &Matrix, pattern: Pattern)
    -> Matrix {
    assert_eq!((prev.rows, prev.cols), (scores.rows, scores.cols),
               "tighten_mask: mask/score shape mismatch");
    let (rows, cols) = (scores.rows, scores.cols);
    let mut mask = Matrix::zeros(rows, cols);
    match pattern {
        Pattern::PerRow { keep } => {
            let keep = keep.min(cols);
            let mut idx: Vec<usize> = Vec::with_capacity(cols);
            for r in 0..rows {
                idx.clear();
                idx.extend(0..cols);
                rank_kept_then_score(&mut idx, scores.row(r),
                                     prev.row(r));
                let mrow = mask.row_mut(r);
                for &j in idx.iter().take(keep) {
                    mrow[j] = 1.0;
                }
            }
        }
        Pattern::Nm { n, m } => {
            assert!(cols % m == 0,
                    "d_in {cols} not divisible by N:M block {m}");
            for r in 0..rows {
                let srow = scores.row(r);
                let prow = prev.row(r);
                let mrow = mask.row_mut(r);
                for b in 0..cols / m {
                    let lo = b * m;
                    let mut idx: Vec<usize> = (lo..lo + m).collect();
                    rank_kept_then_score(&mut idx, srow, prow);
                    for &j in idx.iter().take(n) {
                        mrow[j] = 1.0;
                    }
                }
            }
        }
    }
    mask
}

/// Check that `mask` is binary and satisfies `pattern` exactly.
pub fn validate(mask: &Matrix, pattern: Pattern) -> Result<(), String> {
    for (i, &v) in mask.data.iter().enumerate() {
        if v != 0.0 && v != 1.0 {
            return Err(format!("mask entry {i} = {v} is not binary"));
        }
    }
    match pattern {
        Pattern::PerRow { keep } => {
            let keep = keep.min(mask.cols);
            for r in 0..mask.rows {
                let k: f32 = mask.row(r).iter().sum();
                if k as usize != keep {
                    return Err(format!(
                        "row {r} keeps {k} weights, expected {keep}"));
                }
            }
        }
        Pattern::Nm { n, m } => {
            if mask.cols % m != 0 {
                return Err(format!("cols {} not divisible by {m}",
                                   mask.cols));
            }
            for r in 0..mask.rows {
                let row = mask.row(r);
                for b in 0..mask.cols / m {
                    let k: f32 = row[b * m..(b + 1) * m].iter().sum();
                    if k as usize != n {
                        return Err(format!(
                            "row {r} block {b} keeps {k}, expected {n}"));
                    }
                }
            }
        }
    }
    Ok(())
}

/// Apply a mask in place: W <- M ⊙ W.
pub fn apply_mask(w: &mut Matrix, mask: &Matrix) {
    assert_eq!((w.rows, w.cols), (mask.rows, mask.cols));
    for (wv, &mv) in w.data.iter_mut().zip(&mask.data) {
        *wv *= mv;
    }
}

/// Achieved overall sparsity of a mask (fraction of zeros).
pub fn achieved_sparsity(mask: &Matrix) -> f64 {
    let kept: f64 = mask.data.iter().map(|&v| v as f64).sum();
    1.0 - kept / mask.data.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scores() -> Matrix {
        Matrix::from_fn(2, 8, |i, j| ((i * 8 + j) % 5) as f32
                        + 0.1 * j as f32)
    }

    #[test]
    fn per_row_keeps_topk() {
        let m = mask_from_scores(&scores(), Pattern::PerRow { keep: 3 });
        validate(&m, Pattern::PerRow { keep: 3 }).unwrap();
        // Highest-scoring indices in row 0: scores 0.. = [0,1.1,2.2,3.3,
        // 4.4,0.5,1.6,2.7] -> top3 = {4, 3, 7}
        assert_eq!(m.row(0), &[0., 0., 0., 1., 1., 0., 0., 1.]);
    }

    #[test]
    fn nm_mask_per_block() {
        let m = mask_from_scores(&scores(), Pattern::Nm { n: 2, m: 4 });
        validate(&m, Pattern::Nm { n: 2, m: 4 }).unwrap();
        for r in 0..2 {
            for b in 0..2 {
                let k: f32 = m.row(r)[b * 4..(b + 1) * 4].iter().sum();
                assert_eq!(k, 2.0);
            }
        }
    }

    #[test]
    fn validate_catches_violations() {
        let mut m = mask_from_scores(&scores(), Pattern::PerRow { keep: 3 });
        m.set(0, 0, 1.0); // extra kept weight
        assert!(validate(&m, Pattern::PerRow { keep: 3 }).is_err());
        m.set(0, 0, 0.5);
        assert!(validate(&m, Pattern::PerRow { keep: 3 }).is_err());
    }

    #[test]
    fn pattern_helpers() {
        let p = Pattern::per_row_sparsity(100, 0.6);
        assert_eq!(p, Pattern::PerRow { keep: 40 });
        assert!((p.sparsity(100) - 0.6).abs() < 1e-9);
        assert_eq!(Pattern::parse("2:4"), Some(Pattern::Nm { n: 2, m: 4 }));
        assert_eq!(Pattern::parse("4:2"), None);
        assert_eq!(Pattern::Nm { n: 2, m: 4 }.keep_per_row(16), 8);
        assert_eq!(Pattern::Nm { n: 2, m: 4 }.artifact_tag(), "nm2_4");
    }

    #[test]
    fn apply_and_sparsity() {
        let mut w = Matrix::from_fn(2, 4, |_, _| 1.0);
        let mask = mask_from_scores(&Matrix::from_fn(2, 4, |_, j| j as f32),
                                    Pattern::PerRow { keep: 1 });
        apply_mask(&mut w, &mask);
        assert_eq!(w.data.iter().sum::<f32>(), 2.0);
        assert!((achieved_sparsity(&mask) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn tie_break_is_deterministic() {
        let s = Matrix::zeros(1, 6);
        let m = mask_from_scores(&s, Pattern::PerRow { keep: 2 });
        assert_eq!(m.row(0), &[1., 1., 0., 0., 0., 0.]);
    }

    #[test]
    fn tighten_prunes_lowest_score_kept_weights() {
        let s = scores();
        let loose = mask_from_scores(&s, Pattern::PerRow { keep: 5 });
        let tight = tighten_mask(&loose, &s,
                                 Pattern::PerRow { keep: 3 });
        validate(&tight, Pattern::PerRow { keep: 3 }).unwrap();
        // Tightening keeps a subset of the previously-kept weights...
        for (t, l) in tight.data.iter().zip(&loose.data) {
            assert!(*t <= *l, "tightening resurrected a pruned weight");
        }
        // ...and exactly the top-score subset: equal to a cold mask
        // at the tighter budget when the loose mask was score-built.
        let cold = mask_from_scores(&s, Pattern::PerRow { keep: 3 });
        assert_eq!(tight.data, cold.data);
    }

    #[test]
    fn tighten_is_identity_on_a_conforming_mask() {
        let s = scores();
        // An arbitrary (non-top-score) conforming mask must survive
        // unchanged: kept entries outrank all pruned entries.
        let mut prev = Matrix::zeros(2, 8);
        for r in 0..2 {
            for j in [0, 2, 5] {
                prev.row_mut(r)[j] = 1.0;
            }
        }
        let again = tighten_mask(&prev, &s, Pattern::PerRow { keep: 3 });
        assert_eq!(again.data, prev.data);
    }

    #[test]
    fn loosening_backfills_highest_score_pruned_weights() {
        let s = scores();
        let tight = mask_from_scores(&s, Pattern::PerRow { keep: 2 });
        let loose = tighten_mask(&tight, &s,
                                 Pattern::PerRow { keep: 4 });
        validate(&loose, Pattern::PerRow { keep: 4 }).unwrap();
        for (l, t) in loose.data.iter().zip(&tight.data) {
            assert!(*l >= *t, "loosening dropped a kept weight");
        }
    }

    #[test]
    fn tighten_crosses_pattern_kinds() {
        // Unstructured 50% -> 2:4: the result must be an exact N:M
        // mask, preferring previously-kept weights inside each block.
        let s = scores();
        let row = mask_from_scores(&s, Pattern::PerRow { keep: 4 });
        let nm = tighten_mask(&row, &s, Pattern::Nm { n: 2, m: 4 });
        validate(&nm, Pattern::Nm { n: 2, m: 4 }).unwrap();
        let again = tighten_mask(&row, &s, Pattern::Nm { n: 2, m: 4 });
        assert_eq!(nm.data, again.data, "tighten_mask must be \
                                         deterministic");
    }
}
