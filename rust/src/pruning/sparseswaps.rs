//! Native (pure-Rust) SparseSwaps engine: exact Algorithm 1.
//!
//! This is the reference implementation the HLO offload engine is tested
//! against, and the fallback when artifacts are unavailable.  Per row:
//!
//!   1. c = G((1-m) ⊙ w), L = q.c
//!   2. repeat up to t_max times:
//!        evaluate dL(u,p) (Eq. 5) over all feasible pairs via O(1)
//!        lookups into (G, c); take the argmin;
//!        if dL < -eps: flip the pair, update c (Eq. 6), else stop.
//!
//! The pair scan precomputes the separable terms
//!   a_u = 2 w_u c_u + w_u^2 G_uu   (cost of pruning kept u)
//!   b_p = -2 w_p c_p + w_p^2 G_pp  (gain of reviving pruned p)
//! so the inner loop is one multiply-add per pair — the same O(|U||P|)
//! complexity the paper reports.

use crate::pruning::error::{corr_vector, row_loss_with_corr};
use crate::pruning::mask::Pattern;
use crate::util::tensor::Matrix;
use crate::util::threadpool::parallel_map;

#[derive(Clone, Copy, Debug)]
pub struct SwapConfig {
    /// Maximum accepted swaps per row (the paper's T_max).
    pub t_max: usize,
    /// Minimum improvement to accept a swap (paper uses 0 = strict).
    pub eps: f64,
}

impl Default for SwapConfig {
    fn default() -> Self {
        Self { t_max: 100, eps: 0.0 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct RowOutcome {
    pub loss_before: f64,
    pub loss_after: f64,
    pub swaps: usize,
    /// True if the row reached a 1-swap local optimum before t_max.
    pub converged: bool,
}

#[derive(Clone, Debug, Default)]
pub struct LayerOutcome {
    pub rows: Vec<RowOutcome>,
}

impl LayerOutcome {
    pub fn total_before(&self) -> f64 {
        self.rows.iter().map(|r| r.loss_before).sum()
    }

    pub fn total_after(&self) -> f64 {
        self.rows.iter().map(|r| r.loss_after).sum()
    }

    pub fn total_swaps(&self) -> usize {
        self.rows.iter().map(|r| r.swaps).sum()
    }

    pub fn relative_reduction(&self) -> f64 {
        crate::pruning::error::relative_reduction(self.total_before(),
                                                  self.total_after())
    }
}

/// Best feasible 1-swap for one row given precomputed c.
/// Returns (dl, u, p) or None when no feasible pair exists.
pub fn best_swap(w: &[f32], m: &[f32], c: &[f32], g: &Matrix,
                 nm_block: usize) -> Option<(f64, usize, usize)> {
    let d = w.len();
    let diag = |i: usize| g.at(i, i);

    // Separable Eq.-5 terms.
    let mut kept: Vec<usize> = Vec::new();
    let mut pruned: Vec<usize> = Vec::new();
    for i in 0..d {
        if m[i] > 0.5 {
            kept.push(i);
        } else {
            pruned.push(i);
        }
    }
    if kept.is_empty() || pruned.is_empty() {
        return None;
    }
    let a_u: Vec<f64> = kept.iter()
        .map(|&u| 2.0 * w[u] as f64 * c[u] as f64
             + (w[u] as f64).powi(2) * diag(u) as f64)
        .collect();
    let b_p: Vec<f64> = pruned.iter()
        .map(|&p| -2.0 * w[p] as f64 * c[p] as f64
             + (w[p] as f64).powi(2) * diag(p) as f64)
        .collect();

    let mut best: Option<(f64, usize, usize)> = None;
    let mut consider = |dl: f64, u: usize, p: usize| {
        if best.map_or(true, |(b, _, _)| dl < b) {
            best = Some((dl, u, p));
        }
    };

    if nm_block == 0 {
        for (ku, &u) in kept.iter().enumerate() {
            let wu = w[u] as f64;
            let au = a_u[ku];
            let grow = g.row(u);
            for (kp, &p) in pruned.iter().enumerate() {
                let dl = au + b_p[kp]
                    - 2.0 * wu * w[p] as f64 * grow[p] as f64;
                consider(dl, u, p);
            }
        }
    } else {
        // N:M: only same-block pairs are feasible.
        for (ku, &u) in kept.iter().enumerate() {
            let blk = u / nm_block;
            let wu = w[u] as f64;
            let au = a_u[ku];
            let grow = g.row(u);
            // pruned is sorted ascending; binary search the block range.
            let lo = pruned.partition_point(|&p| p < blk * nm_block);
            let hi = pruned.partition_point(|&p| p < (blk + 1) * nm_block);
            for kp in lo..hi {
                let p = pruned[kp];
                let dl = au + b_p[kp]
                    - 2.0 * wu * w[p] as f64 * grow[p] as f64;
                consider(dl, u, p);
            }
        }
    }
    best
}

/// Run Algorithm 1 on a single row, mutating the mask row in place.
pub fn refine_row(w: &[f32], m: &mut [f32], g: &Matrix, nm_block: usize,
                  cfg: &SwapConfig) -> RowOutcome {
    let mut c = corr_vector(w, m, g);
    let loss_before = row_loss_with_corr(w, m, &c);
    let mut swaps = 0;
    let mut converged = false;
    for _ in 0..cfg.t_max {
        match best_swap(w, m, &c, g, nm_block) {
            Some((dl, u, p)) if dl < -cfg.eps => {
                m[u] = 0.0;
                m[p] = 1.0;
                // Eq. 6: c += w_u G[:,u] - w_p G[:,p]  (G symmetric, so
                // columns are rows).
                crate::util::tensor::axpy(w[u], g.row(u), &mut c);
                crate::util::tensor::axpy(-w[p], g.row(p), &mut c);
                swaps += 1;
            }
            _ => {
                converged = true;
                break;
            }
        }
    }
    // Recompute the final loss from scratch (no accumulated drift).
    let c_final = corr_vector(w, m, g);
    let loss_after = row_loss_with_corr(w, m, &c_final);
    RowOutcome { loss_before, loss_after, swaps, converged }
}

/// Refine every row of a layer, parallelised across rows (the paper's
/// "fully parallelizable across rows" claim).
pub fn refine_layer(w: &Matrix, mask: &mut Matrix, g: &Matrix,
                    pattern: Pattern, cfg: &SwapConfig, threads: usize)
    -> LayerOutcome {
    assert_eq!((w.rows, w.cols), (mask.rows, mask.cols));
    assert_eq!(g.rows, w.cols);
    let nm_block = pattern.nm_block();
    let rows: Vec<(Vec<f32>, RowOutcome)> =
        parallel_map(w.rows, threads, |r| {
            let mut mrow = mask.row(r).to_vec();
            let outcome = refine_row(w.row(r), &mut mrow, g, nm_block, cfg);
            (mrow, outcome)
        });
    let mut outcome = LayerOutcome::default();
    for (r, (mrow, row_out)) in rows.into_iter().enumerate() {
        mask.row_mut(r).copy_from_slice(&mrow);
        outcome.rows.push(row_out);
    }
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::error::{layer_loss, row_loss};
    use crate::pruning::mask::{mask_from_scores, validate};
    use crate::pruning::saliency;
    use crate::util::prng::Rng;

    pub(crate) fn instance(seed: u64, t: usize, rows: usize, d: usize)
        -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(t, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
        (w, g, x)
    }

    #[test]
    fn refinement_reduces_wanda_loss() {
        let (w, g, _) = instance(0, 64, 8, 32);
        let pattern = Pattern::PerRow { keep: 13 };
        let scores = saliency::wanda(&w, &g.diag());
        let mut mask = mask_from_scores(&scores, pattern);
        let before = layer_loss(&w, &mask, &g);
        let out = refine_layer(&w, &mut mask, &g, pattern,
                               &SwapConfig::default(), 2);
        let after = layer_loss(&w, &mask, &g);
        assert!(after < before * 0.95, "{before} -> {after}");
        assert!((out.total_after() - after).abs() / after.max(1.0) < 1e-3);
        validate(&mask, pattern).unwrap();
    }

    #[test]
    fn nm_pattern_preserved_and_improved() {
        let (w, g, _) = instance(1, 64, 6, 32);
        let pattern = Pattern::Nm { n: 2, m: 4 };
        let scores = saliency::wanda(&w, &g.diag());
        let mut mask = mask_from_scores(&scores, pattern);
        let before = layer_loss(&w, &mask, &g);
        refine_layer(&w, &mut mask, &g, pattern, &SwapConfig::default(), 1);
        let after = layer_loss(&w, &mask, &g);
        assert!(after <= before + 1e-9);
        validate(&mask, pattern).unwrap();
    }

    #[test]
    fn terminal_mask_is_local_optimum() {
        let (w, g, _) = instance(2, 48, 3, 20);
        let pattern = Pattern::PerRow { keep: 8 };
        let scores = saliency::magnitude(&w);
        let mut mask = mask_from_scores(&scores, pattern);
        let out = refine_layer(&w, &mut mask, &g, pattern,
                               &SwapConfig { t_max: 1000, eps: 0.0 }, 1);
        assert!(out.rows.iter().all(|r| r.converged));
        // Exhaustive: no single swap may improve.
        for r in 0..w.rows {
            let base = row_loss(w.row(r), mask.row(r), &g);
            for u in 0..20 {
                for p in 0..20 {
                    if mask.at(r, u) == 1.0 && mask.at(r, p) == 0.0 {
                        let mut m2: Vec<f32> = mask.row(r).to_vec();
                        m2[u] = 0.0;
                        m2[p] = 1.0;
                        let l2 = row_loss(w.row(r), &m2, &g);
                        assert!(l2 >= base - 1e-2,
                                "row {r}: swap ({u},{p}) improves \
                                 {base} -> {l2}");
                    }
                }
            }
        }
    }

    #[test]
    fn swap_count_within_tmax() {
        let (w, g, _) = instance(3, 32, 4, 24);
        let pattern = Pattern::PerRow { keep: 10 };
        let mut mask = mask_from_scores(&saliency::magnitude(&w), pattern);
        let cfg = SwapConfig { t_max: 3, eps: 0.0 };
        let out = refine_layer(&w, &mut mask, &g, pattern, &cfg, 1);
        assert!(out.rows.iter().all(|r| r.swaps <= 3));
    }

    #[test]
    fn paper_counterexample() {
        // Sec 2.1.3 worked example: B=1, d=4, X=1, w=[10,-1,9,-9],
        // pruned={0,1}: L=81.  The best joint swap reaches L=1.
        let g = {
            let x = Matrix::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0]);
            let mut g = Matrix::zeros(4, 4);
            g.gram_accumulate(&x);
            g
        };
        let w = vec![10.0f32, -1.0, 9.0, -9.0];
        let mut m = vec![0.0f32, 0.0, 1.0, 1.0];
        let out = refine_row(&w, &mut m, &g, 0,
                             &SwapConfig { t_max: 1, eps: 0.0 });
        assert!((out.loss_before - 81.0).abs() < 1e-3);
        assert!((out.loss_after - 1.0).abs() < 1e-3, "{}", out.loss_after);
        assert_eq!(m, vec![0.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let (w, g, _) = instance(4, 40, 8, 24);
        let pattern = Pattern::PerRow { keep: 9 };
        let scores = saliency::wanda(&w, &g.diag());
        let mut m1 = mask_from_scores(&scores, pattern);
        let mut m4 = m1.clone();
        refine_layer(&w, &mut m1, &g, pattern, &SwapConfig::default(), 1);
        refine_layer(&w, &mut m4, &g, pattern, &SwapConfig::default(), 4);
        assert_eq!(m1.data, m4.data);
    }

    #[test]
    fn eps_bounds_swap_count() {
        // Prop A.2: with eps > 0 the algorithm performs at most
        // ceil(L0 / eps) swaps.
        let (w, g, _) = instance(5, 32, 4, 24);
        let pattern = Pattern::PerRow { keep: 8 };
        let mut mask = mask_from_scores(&saliency::magnitude(&w), pattern);
        for r in 0..w.rows {
            let l0 = row_loss(w.row(r), mask.row(r), &g);
            let eps = (l0 / 10.0).max(1e-6);
            let mut mrow = mask.row_mut(r).to_vec();
            let out = refine_row(w.row(r), &mut mrow, &g, 0,
                                 &SwapConfig { t_max: 10_000, eps });
            let bound = (l0 / eps).ceil() as usize;
            assert!(out.swaps <= bound, "{} > {}", out.swaps, bound);
        }
    }
}
