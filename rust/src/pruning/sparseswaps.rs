//! Native (pure-Rust) SparseSwaps engine: exact Algorithm 1.
//!
//! This is the reference implementation the HLO offload engine is tested
//! against, and the fallback when artifacts are unavailable.  Per row:
//!
//!   1. c = G((1-m) ⊙ w), L = q.c
//!   2. repeat up to t_max times:
//!        evaluate dL(u,p) (Eq. 5) over all feasible pairs via O(1)
//!        lookups into (G, c); take the argmin;
//!        if dL < -eps: flip the pair, update c (Eq. 6), else stop.
//!
//! The pair scan precomputes the separable terms
//!   a_u = 2 w_u c_u + w_u^2 G_uu   (cost of pruning kept u)
//!   b_p = -2 w_p c_p + w_p^2 G_pp  (gain of reviving pruned p)
//! so the inner loop is one multiply-add per pair — the same O(|U||P|)
//! complexity the paper reports.  The inner loop itself runs through
//! the runtime-dispatched kernel layer
//! (`util::kernels::pair_scan_gather_arm`: scalar, or AVX2 f64 lanes
//! gathering `G_up` straight from the f32 Gram row via `vgatherqps`,
//! with exact first-wins argmin semantics either way).
//!
//! Two loop implementations share those semantics:
//!
//!   * [`refine_layer`] / [`NativeEngine`] — the production *incremental
//!     active-set* loop: the kept/pruned partition, the correlation
//!     vector c, and slab-per-worker scratch for the separable terms
//!     persist across swaps *and* checkpoint segments (row states are
//!     advanced in place — never cloned per segment), and kept indices
//!     whose conservative Eq.-5 lower bound cannot beat the current
//!     best pair skip their inner scan entirely.  The bound is
//!     per-N:M-block (falling back to the whole row for unstructured
//!     patterns), so N:M scans benefit too;
//!   * [`refine_layer_rescan`] — the pre-refactor loop that rebuilds
//!     the partition and both term vectors from scratch on every
//!     accepted swap.  Retained as the bit-exact oracle for the parity
//!     property tests and as the baseline arm of the `ablation_engine`
//!     bench.
//!
//! Both produce bit-identical masks on every dispatch arm: the
//! incremental loop evaluates the same f64 expressions in the same
//! order, only skips pairs that provably cannot win the argmin, and
//! the Eq.-6 update (`axpy`) is elementwise mul+add in both kernel
//! arms, so even the scalar-vs-SIMD masks agree bit-for-bit.

use crate::pruning::engine::{
    drive_segments, LayerContext, RefineEngine, RefineError, RefineOutcome,
};
use crate::pruning::error::{corr_vector, row_loss, row_loss_with_corr};
use crate::pruning::mask::Pattern;
use crate::util::kernels::{self, Arm};
use crate::util::tensor::{axpy, GramView, Matrix, MatrixView};
use crate::util::threadpool::parallel_map;

#[derive(Clone, Copy, Debug)]
pub struct SwapConfig {
    /// Maximum accepted swaps per row (the paper's T_max).
    pub t_max: usize,
    /// Minimum improvement to accept a swap (paper uses 0 = strict).
    pub eps: f64,
}

impl Default for SwapConfig {
    fn default() -> Self {
        Self { t_max: 100, eps: 0.0 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct RowOutcome {
    pub loss_before: f64,
    pub loss_after: f64,
    pub swaps: usize,
    /// True if the row reached a 1-swap local optimum before t_max.
    pub converged: bool,
}

#[derive(Clone, Debug, Default)]
pub struct LayerOutcome {
    pub rows: Vec<RowOutcome>,
}

impl LayerOutcome {
    pub fn total_before(&self) -> f64 {
        self.rows.iter().map(|r| r.loss_before).sum()
    }

    pub fn total_after(&self) -> f64 {
        self.rows.iter().map(|r| r.loss_after).sum()
    }

    pub fn total_swaps(&self) -> usize {
        self.rows.iter().map(|r| r.swaps).sum()
    }

    pub fn rows_converged(&self) -> usize {
        self.rows.iter().filter(|r| r.converged).count()
    }

    pub fn relative_reduction(&self) -> f64 {
        crate::pruning::error::relative_reduction(self.total_before(),
                                                  self.total_after())
    }
}

/// Best feasible 1-swap for one row given precomputed c.
/// Returns (dl, u, p) or None when no feasible pair exists.
pub fn best_swap<'a>(w: &[f32], m: &[f32], c: &[f32],
                     g: impl Into<GramView<'a>>, nm_block: usize)
    -> Option<(f64, usize, usize)> {
    let g = g.into();
    let d = w.len();
    let diag = |i: usize| g.at(i, i);

    // Separable Eq.-5 terms.
    let mut kept: Vec<usize> = Vec::new();
    let mut pruned: Vec<usize> = Vec::new();
    for i in 0..d {
        if m[i] > 0.5 {
            kept.push(i);
        } else {
            pruned.push(i);
        }
    }
    if kept.is_empty() || pruned.is_empty() {
        return None;
    }
    let a_u: Vec<f64> = kept.iter()
        .map(|&u| 2.0 * w[u] as f64 * c[u] as f64
             + (w[u] as f64).powi(2) * diag(u) as f64)
        .collect();
    let b_p: Vec<f64> = pruned.iter()
        .map(|&p| -2.0 * w[p] as f64 * c[p] as f64
             + (w[p] as f64).powi(2) * diag(p) as f64)
        .collect();

    let mut best: Option<(f64, usize, usize)> = None;
    let mut consider = |dl: f64, u: usize, p: usize| {
        if best.map_or(true, |(b, _, _)| dl < b) {
            best = Some((dl, u, p));
        }
    };

    if nm_block == 0 {
        for (ku, &u) in kept.iter().enumerate() {
            let wu = w[u] as f64;
            let au = a_u[ku];
            let grow = g.row(u);
            for (kp, &p) in pruned.iter().enumerate() {
                let dl = au + b_p[kp]
                    - 2.0 * wu * w[p] as f64 * grow[p] as f64;
                consider(dl, u, p);
            }
        }
    } else {
        // N:M: only same-block pairs are feasible.
        for (ku, &u) in kept.iter().enumerate() {
            let blk = u / nm_block;
            let wu = w[u] as f64;
            let au = a_u[ku];
            let grow = g.row(u);
            // pruned is sorted ascending; binary search the block range.
            let lo = pruned.partition_point(|&p| p < blk * nm_block);
            let hi = pruned.partition_point(|&p| p < (blk + 1) * nm_block);
            for kp in lo..hi {
                let p = pruned[kp];
                let dl = au + b_p[kp]
                    - 2.0 * wu * w[p] as f64 * grow[p] as f64;
                consider(dl, u, p);
            }
        }
    }
    best
}

/// Run Algorithm 1 on a single row, mutating the mask row in place.
/// Full-rescan reference loop: every accepted swap rebuilds the
/// partition and both Eq.-5 term vectors via [`best_swap`].
pub fn refine_row<'a>(w: &[f32], m: &mut [f32],
                      g: impl Into<GramView<'a>>, nm_block: usize,
                      cfg: &SwapConfig) -> RowOutcome {
    let g = g.into();
    let mut c = corr_vector(w, m, g);
    let loss_before = row_loss_with_corr(w, m, &c);
    let mut swaps = 0;
    let mut converged = false;
    for _ in 0..cfg.t_max {
        match best_swap(w, m, &c, g, nm_block) {
            Some((dl, u, p)) if dl < -cfg.eps => {
                m[u] = 0.0;
                m[p] = 1.0;
                // Eq. 6: c += w_u G[:,u] - w_p G[:,p]  (G symmetric, so
                // columns are rows).
                axpy(w[u], g.row(u), &mut c);
                axpy(-w[p], g.row(p), &mut c);
                swaps += 1;
            }
            _ => {
                converged = true;
                break;
            }
        }
    }
    // Recompute the final loss from scratch (no accumulated drift).
    let c_final = corr_vector(w, m, g);
    let loss_after = row_loss_with_corr(w, m, &c_final);
    RowOutcome { loss_before, loss_after, swaps, converged }
}

/// The pre-refactor layer loop: [`refine_row`] per row, rebuilding all
/// per-row state on every swap.  Kept as the bit-exact reference for
/// [`refine_layer`] (see the parity properties in `tests/properties.rs`)
/// and as the baseline arm of the `ablation_engine` bench.
pub fn refine_layer_rescan<'a>(w: &Matrix, mask: &mut Matrix,
                               g: impl Into<GramView<'a>>,
                               pattern: Pattern, cfg: &SwapConfig,
                               threads: usize) -> LayerOutcome {
    let g = g.into();
    assert_eq!((w.rows, w.cols), (mask.rows, mask.cols));
    assert_eq!(g.d, w.cols);
    let nm_block = pattern.nm_block();
    let rows: Vec<(Vec<f32>, RowOutcome)> =
        parallel_map(w.rows, threads, |r| {
            let mut mrow = mask.row(r).to_vec();
            let outcome = refine_row(w.row(r), &mut mrow, g, nm_block, cfg);
            (mrow, outcome)
        });
    let mut outcome = LayerOutcome::default();
    for (r, (mrow, row_out)) in rows.into_iter().enumerate() {
        mask.row_mut(r).copy_from_slice(&mrow);
        outcome.rows.push(row_out);
    }
    outcome
}

/// The Eq.-5 skip-bound table: `gmax[u]` = max |G_uj| over the
/// columns u's scan can reach — its N:M block for block patterns, or
/// the whole row when unstructured.  Indexed by *column*, so the
/// table is identical for every row shard of a layer; the scheduler
/// (`coordinator::scheduler::refine_block`) computes it once per
/// layer and hands shards a borrowed slice through
/// [`LayerContext::gmax`], turning the O(d²) scan from a per-shard
/// cost into a per-layer one.  Standalone callers (whole-layer
/// `refine`, tests) may leave `gmax: None` and the engine computes
/// its own — bit-identical either way, since the table is a pure
/// function of `(g, nm_block)`.
pub fn gmax_table(g: GramView<'_>, nm_block: usize, threads: usize)
    -> Vec<f64> {
    let d = g.d;
    parallel_map(d, threads.max(1), |u| {
        let (lo, hi) = if nm_block == 0 {
            (0, d)
        } else {
            let blk = u / nm_block;
            (blk * nm_block, ((blk + 1) * nm_block).min(d))
        };
        g.row(u)[lo..hi].iter()
            .map(|&v| (v as f64).abs())
            .fold(0.0, f64::max)
    })
}

// --- incremental active-set engine ------------------------------------------

/// Persistent per-row state of the incremental engine: the mask row,
/// the Eq.-6-maintained correlation vector, and the kept/pruned index
/// partition (each ascending).  Survives accepted swaps *and*
/// checkpoint segment boundaries — rows are advanced *in place*
/// (chunked across workers), so nothing is cloned or rebuilt
/// mid-refinement.
struct RowState {
    mask: Vec<f32>,
    c: Vec<f32>,
    kept: Vec<usize>,
    pruned: Vec<usize>,
    swaps: usize,
    converged: bool,
    loss_before: f64,
}

impl RowState {
    fn init(w: &[f32], m: &[f32], g: GramView<'_>) -> RowState {
        let c = corr_vector(w, m, g);
        let loss_before = row_loss_with_corr(w, m, &c);
        let mut kept = Vec::with_capacity(m.len());
        let mut pruned = Vec::with_capacity(m.len());
        for (i, &mv) in m.iter().enumerate() {
            if mv > 0.5 {
                kept.push(i);
            } else {
                pruned.push(i);
            }
        }
        RowState {
            mask: m.to_vec(),
            c,
            kept,
            pruned,
            swaps: 0,
            converged: false,
            loss_before,
        }
    }

    /// Apply an accepted swap (prune u, revive p): Eq.-6 update of c
    /// plus an O(log d) sorted-partition exchange.
    fn apply_swap(&mut self, arm: Arm, w: &[f32], g: GramView<'_>,
                  u: usize, p: usize) {
        self.mask[u] = 0.0;
        self.mask[p] = 1.0;
        kernels::axpy_arm(arm, w[u], g.row(u), &mut self.c);
        kernels::axpy_arm(arm, -w[p], g.row(p), &mut self.c);
        let ku = self.kept.binary_search(&u).expect("u was kept");
        self.kept.remove(ku);
        let ki = self.kept.binary_search(&p).unwrap_err();
        self.kept.insert(ki, p);
        let pp = self.pruned.binary_search(&p).expect("p was pruned");
        self.pruned.remove(pp);
        let pi = self.pruned.binary_search(&u).unwrap_err();
        self.pruned.insert(pi, u);
        self.swaps += 1;
    }
}

/// Slab-per-worker scratch for the pair scan: allocated once per
/// worker when refinement starts and reused across every row *and*
/// every checkpoint segment that worker processes (the old design
/// reallocated per row per segment).  `G_up` is no longer packed at
/// all — the inner scan gathers it straight from the f32 Gram row
/// (`kernels::pair_scan_gather_arm`), dropping the per-kept-index
/// f64 packing pass the old loop paid.
struct Scratch {
    /// Separable Eq.-5 gain of reviving each pruned index.
    b: Vec<f64>,
    /// w_p as f64, packed over the pruned partition.
    wp: Vec<f64>,
    /// Per-N:M-block minimum of `b` (skip bound); empty when
    /// unstructured.
    blk_min_b: Vec<f64>,
    /// Per-N:M-block max |w_p| (skip bound); empty when unstructured.
    blk_wmax: Vec<f64>,
}

impl Scratch {
    fn new(d: usize, nm_block: usize) -> Scratch {
        let nblocks = if nm_block == 0 { 0 } else { d.div_ceil(nm_block) };
        Scratch {
            b: Vec::with_capacity(d),
            wp: Vec::with_capacity(d),
            blk_min_b: vec![0.0; nblocks],
            blk_wmax: vec![0.0; nblocks],
        }
    }
}

/// Identical selection to [`best_swap`] — same argmin, same first-wins
/// tie-breaking, bit-identical f64 arithmetic — but reading the
/// maintained partition, reusing the worker slab, running the inner
/// loop through the kernel layer, and skipping kept indices whose
/// conservative lower bound on any reachable dL cannot beat the
/// current best pair.  `gmax[u]` is max |G_uj| over the columns u's
/// scan can touch (its N:M block, or the whole row when
/// unstructured), so the bound is tight per block and N:M scans
/// benefit too.
fn best_swap_active(arm: Arm, w: &[f32], st: &RowState, g: GramView<'_>,
                    nm_block: usize, gmax: &[f64], ws: &mut Scratch)
    -> Option<(f64, usize, usize)> {
    let (kept, pruned) = (&st.kept, &st.pruned);
    if kept.is_empty() || pruned.is_empty() {
        return None;
    }
    let c = &st.c;

    // Pack the separable pruned-side terms once per call, tracking the
    // skip-bound statistics per scan scope (row, or N:M block).
    ws.b.clear();
    ws.wp.clear();
    let mut min_b = f64::INFINITY;
    let mut wmax = 0.0f64;
    if nm_block > 0 {
        for v in ws.blk_min_b.iter_mut() {
            *v = f64::INFINITY;
        }
        for v in ws.blk_wmax.iter_mut() {
            *v = 0.0;
        }
    }
    for &p in pruned {
        let bp = -2.0 * w[p] as f64 * c[p] as f64
            + (w[p] as f64).powi(2) * g.at(p, p) as f64;
        let wpf = w[p] as f64;
        if nm_block == 0 {
            if bp < min_b {
                min_b = bp;
            }
            if wpf.abs() > wmax {
                wmax = wpf.abs();
            }
        } else {
            let blk = p / nm_block;
            if bp < ws.blk_min_b[blk] {
                ws.blk_min_b[blk] = bp;
            }
            if wpf.abs() > ws.blk_wmax[blk] {
                ws.blk_wmax[blk] = wpf.abs();
            }
        }
        ws.b.push(bp);
        ws.wp.push(wpf);
    }

    let mut best_dl = f64::INFINITY;
    let mut best: Option<(usize, usize)> = None;
    if nm_block == 0 {
        for &u in kept.iter() {
            let wu = w[u] as f64;
            // 2.0 * x is exact in f64, so (2*w_u)*w_p*G_up below rounds
            // identically to best_swap's 2.0*w_u*w_p*G_up.
            let au = 2.0 * wu * c[u] as f64
                + wu.powi(2) * g.at(u, u) as f64;
            let wu2 = 2.0 * wu;
            // Active-set skip: dL(u, .) >= a_u + min_p b_p
            // - |2 w_u| max_p|w_p| max_j|G_uj| in exact arithmetic; the
            // relative slack dwarfs f64 rounding, so a skipped u can
            // never have held the strictly-smaller argmin.
            let cap = wu2.abs() * wmax * gmax[u];
            let slack = 1e-9 * (au.abs() + min_b.abs() + cap + 1.0);
            if best.is_some() && au + min_b - cap - slack >= best_dl {
                continue;
            }
            if let Some((dl, kp)) = kernels::pair_scan_gather_arm(
                arm, au, wu2, &ws.b, &ws.wp, g.row(u), pruned, best_dl)
            {
                best_dl = dl;
                best = Some((u, pruned[kp]));
            }
        }
    } else {
        // N:M: only same-block pairs are feasible; the per-block bound
        // (min_b, wmax and gmax restricted to u's block) lets whole
        // blocks skip their scan.
        for &u in kept.iter() {
            let blk = u / nm_block;
            let lo = pruned.partition_point(|&p| p < blk * nm_block);
            let hi = pruned.partition_point(|&p| p < (blk + 1) * nm_block);
            if lo == hi {
                continue;
            }
            let wu = w[u] as f64;
            let au = 2.0 * wu * c[u] as f64
                + wu.powi(2) * g.at(u, u) as f64;
            let wu2 = 2.0 * wu;
            let min_b_blk = ws.blk_min_b[blk];
            let cap = wu2.abs() * ws.blk_wmax[blk] * gmax[u];
            let slack = 1e-9 * (au.abs() + min_b_blk.abs() + cap + 1.0);
            if best.is_some() && au + min_b_blk - cap - slack >= best_dl {
                continue;
            }
            if let Some((dl, kp)) = kernels::pair_scan_gather_arm(
                arm, au, wu2, &ws.b[lo..hi], &ws.wp[lo..hi], g.row(u),
                &pruned[lo..hi], best_dl)
            {
                best_dl = dl;
                best = Some((u, pruned[lo + kp]));
            }
        }
    }
    best.map(|(u, p)| (best_dl, u, p))
}

/// Advance one row by up to `budget` accepted swaps, reusing the
/// worker's slab.
#[allow(clippy::too_many_arguments)]
fn advance_row(arm: Arm, w: &[f32], g: GramView<'_>, nm_block: usize,
               eps: f64, gmax: &[f64], budget: usize, st: &mut RowState,
               ws: &mut Scratch) {
    for _ in 0..budget {
        match best_swap_active(arm, w, st, g, nm_block, gmax, ws) {
            Some((dl, u, p)) if dl < -eps => {
                st.apply_swap(arm, w, g, u, p)
            }
            _ => {
                st.converged = true;
                break;
            }
        }
    }
}

/// The incremental active-set SparseSwaps engine (pure Rust).
///
/// Row state persists across swaps and checkpoint segments (advanced
/// in place — no per-segment clones), so driving Table-3 snapshots
/// costs nothing beyond the mask copies, and the final losses are
/// still recomputed from scratch (no drift).  Implements the
/// row-range contract: rows are independent, so any shard of rows
/// produces exactly the per-row results of the whole-layer run
/// (`tests/shards.rs` sweeps this against the scheduler).
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeEngine {
    /// Minimum improvement to accept a swap (paper uses 0 = strict).
    pub eps: f64,
    /// Kernel dispatch arm override (parity tests and benches);
    /// `None` uses the process-wide arm (`--kernels`).
    pub arm: Option<Arm>,
}

impl RefineEngine for NativeEngine {
    fn name(&self) -> String {
        "sparseswaps[native]".into()
    }

    fn refine_rows(&self, ctx: &LayerContext,
                   rows: std::ops::Range<usize>, mask: &mut Matrix,
                   checkpoints: &[usize])
        -> Result<RefineOutcome, RefineError> {
        let (w, g) = (ctx.w, ctx.g);
        assert!(rows.end <= w.rows);
        let n_rows = rows.len();
        let r0 = rows.start;
        assert_eq!((mask.rows, mask.cols), (n_rows, w.cols));
        assert_eq!(g.d, w.cols);
        let d = w.cols;
        let nm_block = ctx.pattern.nm_block();
        let threads = ctx.threads.max(1);
        let eps = self.eps;
        let arm = self.arm.unwrap_or_else(kernels::active);
        // Skip-bound table (see `gmax_table`): borrowed from the
        // context when the scheduler computed it once for the whole
        // layer, else computed here — the one O(d^2) cost of this
        // call either way, and a pure function of (g, nm_block), so
        // the borrowed and local paths are bit-identical.
        let gmax_local: Vec<f64>;
        let gmax: &[f64] = match ctx.gmax {
            Some(t) => {
                assert_eq!(t.len(), d,
                           "shared gmax table length != layer width");
                t
            }
            None => {
                gmax_local = gmax_table(g, nm_block, threads);
                &gmax_local
            }
        };
        let mut states: Vec<RowState> = parallel_map(n_rows, threads,
                                                     |k| {
            RowState::init(w.row(r0 + k), mask.row(k), g)
        });
        // Slab-per-worker scratch, reused across checkpoint segments.
        let n_workers = threads.min(n_rows.max(1));
        let mut slabs: Vec<Scratch> = (0..n_workers)
            .map(|_| Scratch::new(d, nm_block))
            .collect();
        let snapshots = drive_segments(ctx.t_max, checkpoints, mask,
                                       |mask, budget| {
            if states.iter().all(|s| s.converged) {
                return Ok(0);
            }
            if n_workers == 1 {
                // Shard-sized work unit under an external scheduler:
                // no per-segment thread spawn, just the loop.
                let slab = &mut slabs[0];
                for (k, st) in states.iter_mut().enumerate() {
                    if !st.converged {
                        advance_row(arm, w.row(r0 + k), g, nm_block,
                                    eps, gmax, budget, st, slab);
                    }
                }
            } else {
                let chunk = n_rows.div_ceil(n_workers).max(1);
                std::thread::scope(|scope| {
                    for (ci, (sts, slab)) in states
                        .chunks_mut(chunk)
                        .zip(slabs.iter_mut())
                        .enumerate()
                    {
                        scope.spawn(move || {
                            for (k, st) in sts.iter_mut().enumerate() {
                                let r = r0 + ci * chunk + k;
                                if !st.converged {
                                    advance_row(arm, w.row(r), g,
                                                nm_block, eps, gmax,
                                                budget, st, slab);
                                }
                            }
                        });
                    }
                });
            }
            for (k, st) in states.iter().enumerate() {
                mask.row_mut(k).copy_from_slice(&st.mask);
            }
            Ok(budget)
        })?;
        // Final losses recomputed from scratch (no accumulated drift),
        // exactly like the rescan loop.
        let loss_after: Vec<f64> = parallel_map(n_rows, threads, |k| {
            row_loss(w.row(r0 + k), mask.row(k), g)
        });
        let rows = states.iter().zip(&loss_after)
            .map(|(st, &la)| RowOutcome {
                loss_before: st.loss_before,
                loss_after: la,
                swaps: st.swaps,
                converged: st.converged,
            })
            .collect();
        Ok(RefineOutcome {
            layer: LayerOutcome { rows },
            snapshots,
        })
    }
}

/// Refine every row of a layer, parallelised across rows (the paper's
/// "fully parallelizable across rows" claim).  Delegates to the
/// incremental [`NativeEngine`]; bit-identical to
/// [`refine_layer_rescan`].
pub fn refine_layer<'a>(w: impl Into<MatrixView<'a>>, mask: &mut Matrix,
                        g: impl Into<GramView<'a>>, pattern: Pattern,
                        cfg: &SwapConfig, threads: usize)
    -> LayerOutcome {
    let ctx = LayerContext {
        w: w.into(),
        g: g.into(),
        stats: None,
        pattern,
        t_max: cfg.t_max,
        threads,
        gmax: None,
    };
    NativeEngine { eps: cfg.eps, arm: None }
        .refine(&ctx, mask, &[])
        .expect("native engine is infallible")
        .layer
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::error::{layer_loss, row_loss};
    use crate::pruning::mask::{mask_from_scores, validate};
    use crate::pruning::saliency;
    use crate::util::prng::Rng;

    pub(crate) fn instance(seed: u64, t: usize, rows: usize, d: usize)
        -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(t, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
        (w, g, x)
    }

    #[test]
    fn refinement_reduces_wanda_loss() {
        let (w, g, _) = instance(0, 64, 8, 32);
        let pattern = Pattern::PerRow { keep: 13 };
        let scores = saliency::wanda(&w, &g.diag());
        let mut mask = mask_from_scores(&scores, pattern);
        let before = layer_loss(&w, &mask, &g);
        let out = refine_layer(&w, &mut mask, &g, pattern,
                               &SwapConfig::default(), 2);
        let after = layer_loss(&w, &mask, &g);
        assert!(after < before * 0.95, "{before} -> {after}");
        assert!((out.total_after() - after).abs() / after.max(1.0) < 1e-3);
        validate(&mask, pattern).unwrap();
    }

    #[test]
    fn nm_pattern_preserved_and_improved() {
        let (w, g, _) = instance(1, 64, 6, 32);
        let pattern = Pattern::Nm { n: 2, m: 4 };
        let scores = saliency::wanda(&w, &g.diag());
        let mut mask = mask_from_scores(&scores, pattern);
        let before = layer_loss(&w, &mask, &g);
        refine_layer(&w, &mut mask, &g, pattern, &SwapConfig::default(), 1);
        let after = layer_loss(&w, &mask, &g);
        assert!(after <= before + 1e-9);
        validate(&mask, pattern).unwrap();
    }

    #[test]
    fn terminal_mask_is_local_optimum() {
        let (w, g, _) = instance(2, 48, 3, 20);
        let pattern = Pattern::PerRow { keep: 8 };
        let scores = saliency::magnitude(&w);
        let mut mask = mask_from_scores(&scores, pattern);
        let out = refine_layer(&w, &mut mask, &g, pattern,
                               &SwapConfig { t_max: 1000, eps: 0.0 }, 1);
        assert!(out.rows.iter().all(|r| r.converged));
        // Exhaustive: no single swap may improve.
        for r in 0..w.rows {
            let base = row_loss(w.row(r), mask.row(r), &g);
            for u in 0..20 {
                for p in 0..20 {
                    if mask.at(r, u) == 1.0 && mask.at(r, p) == 0.0 {
                        let mut m2: Vec<f32> = mask.row(r).to_vec();
                        m2[u] = 0.0;
                        m2[p] = 1.0;
                        let l2 = row_loss(w.row(r), &m2, &g);
                        assert!(l2 >= base - 1e-2,
                                "row {r}: swap ({u},{p}) improves \
                                 {base} -> {l2}");
                    }
                }
            }
        }
    }

    #[test]
    fn swap_count_within_tmax() {
        let (w, g, _) = instance(3, 32, 4, 24);
        let pattern = Pattern::PerRow { keep: 10 };
        let mut mask = mask_from_scores(&saliency::magnitude(&w), pattern);
        let cfg = SwapConfig { t_max: 3, eps: 0.0 };
        let out = refine_layer(&w, &mut mask, &g, pattern, &cfg, 1);
        assert!(out.rows.iter().all(|r| r.swaps <= 3));
    }

    #[test]
    fn paper_counterexample() {
        // Sec 2.1.3 worked example: B=1, d=4, X=1, w=[10,-1,9,-9],
        // pruned={0,1}: L=81.  The best joint swap reaches L=1.
        let g = {
            let x = Matrix::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0]);
            let mut g = Matrix::zeros(4, 4);
            g.gram_accumulate(&x);
            g
        };
        let w = vec![10.0f32, -1.0, 9.0, -9.0];
        let mut m = vec![0.0f32, 0.0, 1.0, 1.0];
        let out = refine_row(&w, &mut m, &g, 0,
                             &SwapConfig { t_max: 1, eps: 0.0 });
        assert!((out.loss_before - 81.0).abs() < 1e-3);
        assert!((out.loss_after - 1.0).abs() < 1e-3, "{}", out.loss_after);
        assert_eq!(m, vec![0.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let (w, g, _) = instance(4, 40, 8, 24);
        let pattern = Pattern::PerRow { keep: 9 };
        let scores = saliency::wanda(&w, &g.diag());
        let mut m1 = mask_from_scores(&scores, pattern);
        let mut m4 = m1.clone();
        refine_layer(&w, &mut m1, &g, pattern, &SwapConfig::default(), 1);
        refine_layer(&w, &mut m4, &g, pattern, &SwapConfig::default(), 4);
        assert_eq!(m1.data, m4.data);
    }

    #[test]
    fn eps_bounds_swap_count() {
        // Prop A.2: with eps > 0 the algorithm performs at most
        // ceil(L0 / eps) swaps.
        let (w, g, _) = instance(5, 32, 4, 24);
        let pattern = Pattern::PerRow { keep: 8 };
        let mut mask = mask_from_scores(&saliency::magnitude(&w), pattern);
        for r in 0..w.rows {
            let l0 = row_loss(w.row(r), mask.row(r), &g);
            let eps = (l0 / 10.0).max(1e-6);
            let mut mrow = mask.row_mut(r).to_vec();
            let out = refine_row(w.row(r), &mut mrow, &g, 0,
                                 &SwapConfig { t_max: 10_000, eps });
            let bound = (l0 / eps).ceil() as usize;
            assert!(out.swaps <= bound, "{} > {}", out.swaps, bound);
        }
    }

    #[test]
    fn incremental_matches_rescan_smoke() {
        // Full parity coverage lives in tests/properties.rs; this is
        // the fast in-module check.
        for seed in 0..6 {
            let (w, g, _) = instance(100 + seed, 48, 5, 24);
            for pattern in [Pattern::PerRow { keep: 9 },
                            Pattern::Nm { n: 2, m: 4 }] {
                let warm = mask_from_scores(
                    &saliency::wanda(&w, &g.diag()), pattern);
                let cfg = SwapConfig { t_max: 30, eps: 0.0 };
                let mut m_ref = warm.clone();
                let out_ref = refine_layer_rescan(&w, &mut m_ref, &g,
                                                  pattern, &cfg, 1);
                let mut m_inc = warm.clone();
                let out_inc = refine_layer(&w, &mut m_inc, &g, pattern,
                                           &cfg, 1);
                assert_eq!(m_ref.data, m_inc.data, "seed {seed}");
                assert_eq!(out_ref.total_swaps(), out_inc.total_swaps());
            }
        }
    }

    #[test]
    fn kernel_arms_produce_identical_masks() {
        // The Eq.-6 axpy is elementwise in both arms and the pair scan
        // evaluates identical f64 values, so scalar and SIMD runs land
        // on bit-identical masks (and swap counts).
        for pattern in [Pattern::PerRow { keep: 10 },
                        Pattern::Nm { n: 2, m: 4 }] {
            let (w, g, _) = instance(42, 64, 6, 32);
            let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                        pattern);
            let ctx = LayerContext {
                w: w.view(), g: g.as_gram(), stats: None, pattern,
                t_max: 25, threads: 2, gmax: None,
            };
            let mut reference: Option<(Vec<f32>, usize)> = None;
            for arm in kernels::arms() {
                let engine = NativeEngine { eps: 0.0, arm: Some(arm) };
                let mut mask = warm.clone();
                let out = engine.refine(&ctx, &mut mask, &[]).unwrap();
                match &reference {
                    None => {
                        reference =
                            Some((mask.data.clone(),
                                  out.layer.total_swaps()));
                    }
                    Some((m0, s0)) => {
                        assert_eq!(&mask.data, m0, "arm {arm:?}");
                        assert_eq!(out.layer.total_swaps(), *s0);
                    }
                }
            }
        }
    }

    #[test]
    fn engine_checkpoints_match_plain_run() {
        let (w, g, _) = instance(9, 48, 4, 24);
        let pattern = Pattern::PerRow { keep: 9 };
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: None, pattern, t_max: 20,
            threads: 1, gmax: None,
        };
        let mut plain = warm.clone();
        NativeEngine::default().refine(&ctx, &mut plain, &[]).unwrap();
        let mut segmented = warm.clone();
        let out = NativeEngine::default()
            .refine(&ctx, &mut segmented, &[1, 3, 7, 20, 99])
            .unwrap();
        // Continuous row state: segmentation cannot change the result.
        assert_eq!(plain.data, segmented.data);
        // Requested in-range checkpoints all captured; 99 > t_max left
        // to the pipeline backfill.
        for cp in [1usize, 3, 7, 20] {
            let snap = &out.snapshots[&cp];
            validate(snap, pattern).unwrap();
        }
        assert!(!out.snapshots.contains_key(&99));
        assert_eq!(out.snapshots[&20].data, segmented.data);
    }

    #[test]
    fn refine_rows_matches_whole_layer_per_row() {
        let (w, g, _) = instance(21, 48, 6, 24);
        let pattern = Pattern::PerRow { keep: 9 };
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: None, pattern, t_max: 15,
            threads: 1, gmax: None,
        };
        let mut full = warm.clone();
        NativeEngine::default().refine(&ctx, &mut full, &[]).unwrap();
        // Rows 2..5 as one shard: bit-identical to the same rows of
        // the whole-layer run (the row-decoupling invariant).
        let mut shard = Matrix::zeros(3, w.cols);
        for k in 0..3 {
            shard.row_mut(k).copy_from_slice(warm.row(2 + k));
        }
        let out = NativeEngine::default()
            .refine_rows(&ctx, 2..5, &mut shard, &[])
            .unwrap();
        assert_eq!(out.layer.rows.len(), 3);
        for k in 0..3 {
            assert_eq!(shard.row(k), full.row(2 + k), "row {k}");
        }
    }

    #[test]
    fn engine_handles_t_max_zero() {
        let (w, g, _) = instance(10, 32, 3, 16);
        let pattern = Pattern::PerRow { keep: 6 };
        let warm = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: None, pattern, t_max: 0,
            threads: 1, gmax: None,
        };
        let mut mask = warm.clone();
        let out = NativeEngine::default()
            .refine(&ctx, &mut mask, &[]).unwrap();
        assert_eq!(mask.data, warm.data);
        assert_eq!(out.layer.total_swaps(), 0);
        assert!((out.layer.total_before() - out.layer.total_after()).abs()
                < 1e-9);
    }
}
