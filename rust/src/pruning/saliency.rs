//! Warmstart saliency criteria: magnitude, Wanda, RIA.
//!
//! All three need only the weights and the Gram diagonal (the feature
//! norms are ||X_j||_2 = sqrt(G_jj) — a consequence of the paper's Gram
//! formulation, Sec 2.1.2), so warmstarts are computed natively without
//! touching PJRT.

use crate::util::tensor::{Matrix, MatrixView};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Criterion {
    Magnitude,
    Wanda,
    /// RIA (Zhang et al., 2024a): relative importance * activation norms.
    Ria,
}

impl Criterion {
    pub fn parse(s: &str) -> Option<Criterion> {
        match s {
            "magnitude" => Some(Criterion::Magnitude),
            "wanda" => Some(Criterion::Wanda),
            "ria" => Some(Criterion::Ria),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Criterion::Magnitude => "magnitude",
            Criterion::Wanda => "wanda",
            Criterion::Ria => "ria",
        }
    }
}

/// |W_ij| — the data-free baseline the paper shows degrades badly on
/// transformers (Table 2).
pub fn magnitude<'a>(w: impl Into<MatrixView<'a>>) -> Matrix {
    let w = w.into();
    Matrix::from_vec(w.rows, w.cols,
                     w.as_slice().iter().map(|v| v.abs()).collect())
}

/// Wanda: |W_ij| * ||X_j||_2 = |W_ij| * sqrt(G_jj)  (Sun et al., 2024).
pub fn wanda<'a>(w: impl Into<MatrixView<'a>>, gram_diag: &[f32])
    -> Matrix {
    let w = w.into();
    assert_eq!(w.cols, gram_diag.len());
    let norms: Vec<f32> =
        gram_diag.iter().map(|&g| g.max(0.0).sqrt()).collect();
    Matrix::from_fn(w.rows, w.cols, |i, j| w.at(i, j).abs() * norms[j])
}

/// RIA with the paper's default a = 0.5:
///   RIA_ij = (|W_ij| / sum_k |W_ik|  +  |W_ij| / sum_k |W_kj|)
///            * (||X_j||_2)^a
pub fn ria<'a>(w: impl Into<MatrixView<'a>>, gram_diag: &[f32], a: f32)
    -> Matrix {
    let w = w.into();
    assert_eq!(w.cols, gram_diag.len());
    let mut row_sums = vec![0.0f32; w.rows];
    let mut col_sums = vec![0.0f32; w.cols];
    for i in 0..w.rows {
        for j in 0..w.cols {
            let v = w.at(i, j).abs();
            row_sums[i] += v;
            col_sums[j] += v;
        }
    }
    let norms: Vec<f32> = gram_diag
        .iter()
        .map(|&g| g.max(0.0).sqrt().powf(a))
        .collect();
    Matrix::from_fn(w.rows, w.cols, |i, j| {
        let v = w.at(i, j).abs();
        let rel = v / row_sums[i].max(1e-12) + v / col_sums[j].max(1e-12);
        rel * norms[j]
    })
}

/// Dispatch on criterion.
pub fn scores<'a>(criterion: Criterion, w: impl Into<MatrixView<'a>>,
                  gram_diag: &[f32]) -> Matrix {
    let w = w.into();
    match criterion {
        Criterion::Magnitude => magnitude(w),
        Criterion::Wanda => wanda(w, gram_diag),
        Criterion::Ria => ria(w, gram_diag, 0.5),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn magnitude_is_abs() {
        let w = Matrix::from_vec(1, 3, vec![-2.0, 0.5, -0.1]);
        assert_eq!(magnitude(&w).data, vec![2.0, 0.5, 0.1]);
    }

    #[test]
    fn wanda_weights_by_feature_norm() {
        let w = Matrix::from_vec(1, 2, vec![1.0, 1.0]);
        // G_00 = 4 (norm 2), G_11 = 9 (norm 3).
        let s = wanda(&w, &[4.0, 9.0]);
        assert_eq!(s.data, vec![2.0, 3.0]);
    }

    #[test]
    fn wanda_clamps_negative_diag() {
        let w = Matrix::from_vec(1, 2, vec![1.0, 1.0]);
        let s = wanda(&w, &[-1e-6, 1.0]);
        assert_eq!(s.data[0], 0.0);
    }

    #[test]
    fn ria_prefers_relatively_large_entries() {
        // Row 0 is uniformly large; row 1 has one dominant entry.  RIA's
        // relative term must boost the dominant entry of row 1 above the
        // (absolutely larger) entries of row 0's column shares.
        let w = Matrix::from_vec(2, 2, vec![4.0, 4.0, 0.1, 2.0]);
        let s = ria(&w, &[1.0, 1.0], 0.5);
        // Within row 1, entry 1 dominates entry 0 by a large margin.
        assert!(s.at(1, 1) > 10.0 * s.at(1, 0));
    }

    #[test]
    fn criterion_parse() {
        assert_eq!(Criterion::parse("wanda"), Some(Criterion::Wanda));
        assert_eq!(Criterion::parse("ria"), Some(Criterion::Ria));
        assert_eq!(Criterion::parse("x"), None);
        assert_eq!(Criterion::Magnitude.name(), "magnitude");
    }

    #[test]
    fn dispatch_matches_direct() {
        let w = Matrix::from_fn(3, 4, |i, j| (i as f32 - j as f32) * 0.7);
        let gd = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(scores(Criterion::Wanda, &w, &gd).data,
                   wanda(&w, &gd).data);
        assert_eq!(scores(Criterion::Magnitude, &w, &gd).data,
                   magnitude(&w).data);
    }
}
