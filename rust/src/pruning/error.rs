//! Exact per-row / per-layer pruning error via the Gram formulation:
//!   L_i(m) = (w_i - m_i ⊙ w_i)^T G (w_i - m_i ⊙ w_i)        (Sec 2.1.2)
//! and the correlation vector c = G((1-m) ⊙ w)                (Sec 2.1.3).

use crate::util::tensor::{dot, GramView, Matrix, MatrixView};

/// Correlation vector c for one row: c = G q with q = (1-m) ⊙ w.
/// `g` may be a borrowed [`GramView`] (zero-copy stream-stack slice)
/// or a `&Matrix`.
pub fn corr_vector<'a>(w: &[f32], m: &[f32],
                       g: impl Into<GramView<'a>>) -> Vec<f32> {
    let g = g.into();
    let d = w.len();
    assert_eq!(g.d, d);
    let q: Vec<f32> = w.iter().zip(m).map(|(&wv, &mv)| (1.0 - mv) * wv)
        .collect();
    // c_i = sum_j G_ij q_j; exploit q's sparsity (only pruned j non-zero).
    let mut c = vec![0.0f32; d];
    for (j, &qj) in q.iter().enumerate() {
        if qj != 0.0 {
            crate::util::tensor::axpy(qj, g.row(j), &mut c);
        }
    }
    c
}

/// Exact per-row loss given a precomputed correlation vector:
/// L = q^T G q = q . c.
pub fn row_loss_with_corr(w: &[f32], m: &[f32], c: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for i in 0..w.len() {
        let q = (1.0 - m[i]) * w[i];
        if q != 0.0 {
            s += (q as f64) * (c[i] as f64);
        }
    }
    s
}

/// Exact per-row loss from scratch.
pub fn row_loss<'a>(w: &[f32], m: &[f32],
                    g: impl Into<GramView<'a>>) -> f64 {
    let c = corr_vector(w, m, g);
    row_loss_with_corr(w, m, &c)
}

/// Per-row losses for a full layer. Returns one loss per row of `w`.
/// `w` may be a borrowed [`MatrixView`] (a weight leased from a
/// `WeightStore`) or a `&Matrix`.
pub fn layer_row_losses<'a, 'b>(w: impl Into<MatrixView<'b>>,
                                mask: &Matrix,
                                g: impl Into<GramView<'a>>) -> Vec<f64> {
    let w = w.into();
    assert_eq!((w.rows, w.cols), (mask.rows, mask.cols));
    let g = g.into();
    (0..w.rows).map(|r| row_loss(w.row(r), mask.row(r), g)).collect()
}

/// Total layer loss  ||W X - (M ⊙ W) X||_F^2  (Eq. 1).
pub fn layer_loss<'a, 'b>(w: impl Into<MatrixView<'b>>, mask: &Matrix,
                          g: impl Into<GramView<'a>>) -> f64 {
    layer_row_losses(w, mask, g).iter().sum()
}

/// Direct (activation-space) loss for testing the Gram identity:
/// computes ||(W - M⊙W) X^T||_F^2 from raw activations x [t, d].
pub fn layer_loss_direct(w: &Matrix, mask: &Matrix, x: &Matrix) -> f64 {
    assert_eq!(w.cols, x.cols);
    let mut total = 0.0f64;
    for r in 0..w.rows {
        let wrow = w.row(r);
        let mrow = mask.row(r);
        let q: Vec<f32> = wrow.iter().zip(mrow)
            .map(|(&wv, &mv)| (1.0 - mv) * wv)
            .collect();
        for t in 0..x.rows {
            let v = dot(&q, x.row(t)) as f64;
            total += v * v;
        }
    }
    total
}

/// Relative error reduction (paper's Fig. 1 / Tables 3-4 metric):
/// 1 - after/before, guarded for before == 0.
pub fn relative_reduction(before: f64, after: f64) -> f64 {
    if before <= 0.0 {
        0.0
    } else {
        1.0 - after / before
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_instance(seed: u64, t: usize, d: usize)
        -> (Matrix, Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(t, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let w = Matrix::from_fn(4, d, |_, _| rng.gaussian_f32());
        let mask = Matrix::from_fn(4, d, |_, _| {
            if rng.bool(0.5) { 1.0 } else { 0.0 }
        });
        (x, g, w, mask)
    }

    #[test]
    fn gram_loss_equals_direct_loss() {
        let (x, g, w, mask) = random_instance(3, 40, 16);
        let via_gram = layer_loss(&w, &mask, &g);
        let direct = layer_loss_direct(&w, &mask, &x);
        assert!((via_gram - direct).abs() / direct.max(1.0) < 1e-4,
                "{via_gram} vs {direct}");
    }

    #[test]
    fn full_mask_has_zero_loss() {
        let (_, g, w, _) = random_instance(5, 30, 12);
        let ones = Matrix::from_fn(4, 12, |_, _| 1.0);
        assert!(layer_loss(&w, &ones, &g).abs() < 1e-6);
    }

    #[test]
    fn empty_mask_loss_is_full_norm() {
        let (x, g, w, _) = random_instance(7, 25, 10);
        let zeros = Matrix::zeros(4, 10);
        let loss = layer_loss(&w, &zeros, &g);
        // ||W X^T||_F^2 computed directly.
        let mut want = 0.0f64;
        for r in 0..4 {
            for t in 0..x.rows {
                let v = dot(w.row(r), x.row(t)) as f64;
                want += v * v;
            }
        }
        assert!((loss - want).abs() / want < 1e-4);
    }

    #[test]
    fn corr_vector_matches_dense_matvec() {
        let (_, g, w, mask) = random_instance(9, 30, 14);
        let c = corr_vector(w.row(0), mask.row(0), &g);
        let q: Vec<f32> = w.row(0).iter().zip(mask.row(0))
            .map(|(&wv, &mv)| (1.0 - mv) * wv)
            .collect();
        let want = g.matvec(&q);
        for i in 0..14 {
            assert!((c[i] - want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn losses_are_nonnegative() {
        for seed in 0..5 {
            let (_, g, w, mask) = random_instance(seed, 20, 8);
            for l in layer_row_losses(&w, &mask, &g) {
                assert!(l >= -1e-6, "{l}");
            }
        }
    }

    #[test]
    fn relative_reduction_basics() {
        assert!((relative_reduction(10.0, 4.0) - 0.6).abs() < 1e-12);
        assert_eq!(relative_reduction(0.0, 0.0), 0.0);
    }
}
