//! Exact brute-force mask selection for tiny instances.
//!
//! The paper notes the mask-selection problem is NP-hard and that IP
//! solvers are infeasible at LLM scale; at toy scale (d_in <= ~22) we
//! can enumerate every per-row mask and measure how far SparseSwaps'
//! 1-swap local optima are from the true optimum (the "Abl. A" study in
//! DESIGN.md).  Enumeration uses Gosper's hack over k-subsets, and the
//! loss L = sum_{i,j in P} w_i w_j G_ij is evaluated over the pruned
//! set only, so each candidate costs O(|P|^2).

use crate::util::tensor::{GramView, Matrix};

/// Max dimension we allow (C(24,12) ~ 2.7M subsets keeps this fast).
pub const MAX_EXACT_DIM: usize = 24;

/// Loss of pruning exactly the set bits of `pruned` (bitmask over d).
fn loss_of_pruned_set(w: &[f32], g: GramView<'_>, pruned: u64) -> f64 {
    let mut idx = [0usize; MAX_EXACT_DIM];
    let mut n = 0;
    let mut bits = pruned;
    while bits != 0 {
        idx[n] = bits.trailing_zeros() as usize;
        n += 1;
        bits &= bits - 1;
    }
    let mut loss = 0.0f64;
    for a in 0..n {
        let i = idx[a];
        let wi = w[i] as f64;
        loss += wi * wi * g.at(i, i) as f64;
        for b in a + 1..n {
            let j = idx[b];
            loss += 2.0 * wi * w[j] as f64 * g.at(i, j) as f64;
        }
    }
    loss
}

/// Next k-subset bitmask in lexicographic order (Gosper's hack):
///   u = lowest set bit; w = v + u ripples the lowest block up one;
///   (v ^ w) / u >> 2 re-packs the remaining block bits at the bottom.
fn next_subset(v: u64) -> u64 {
    debug_assert!(v != 0);
    let u = v & v.wrapping_neg();
    let w = v.wrapping_add(u);
    w | (((v ^ w) / u) >> 2)
}

/// Optimal per-row mask: keep `keep` of `d` weights minimising the exact
/// loss.  Returns (mask_row, optimal_loss).
pub fn optimal_row_mask<'a>(w: &[f32], g: impl Into<GramView<'a>>,
                            keep: usize) -> (Vec<f32>, f64) {
    let g = g.into();
    let d = w.len();
    assert!(d <= MAX_EXACT_DIM, "exact solver capped at {MAX_EXACT_DIM}");
    assert!(keep <= d);
    let prune = d - keep;
    if prune == 0 {
        return (vec![1.0; d], 0.0);
    }
    let mut best_loss = f64::INFINITY;
    let mut best_set = 0u64;
    let mut subset: u64 = (1u64 << prune) - 1;
    let limit: u64 = 1u64 << d;
    while subset < limit {
        let loss = loss_of_pruned_set(w, g, subset);
        if loss < best_loss {
            best_loss = loss;
            best_set = subset;
        }
        if subset == 0 {
            break;
        }
        subset = next_subset(subset);
    }
    let mut mask = vec![1.0f32; d];
    for i in 0..d {
        if best_set >> i & 1 == 1 {
            mask[i] = 0.0;
        }
    }
    (mask, best_loss)
}

/// Exact optimum for every row of a small layer.
pub fn optimal_layer_mask<'a>(w: &Matrix, g: impl Into<GramView<'a>>,
                              keep: usize) -> (Matrix, f64) {
    let g = g.into();
    let mut mask = Matrix::zeros(w.rows, w.cols);
    let mut total = 0.0;
    for r in 0..w.rows {
        let (row, loss) = optimal_row_mask(w.row(r), g, keep);
        mask.row_mut(r).copy_from_slice(&row);
        total += loss;
    }
    (mask, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::error::row_loss;
    use crate::pruning::mask::{mask_from_scores, Pattern};
    use crate::pruning::saliency;
    use crate::pruning::sparseswaps::{refine_row, SwapConfig};
    use crate::util::prng::Rng;

    fn instance(seed: u64, d: usize) -> (Vec<f32>, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(32, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let w: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
        (w, g)
    }

    #[test]
    fn matches_exhaustive_loss_evaluation() {
        let (w, g) = instance(0, 10);
        let (mask, loss) = optimal_row_mask(&w, &g, 5);
        assert!((row_loss(&w, &mask, &g) - loss).abs() < 1e-3);
        assert_eq!(mask.iter().filter(|&&v| v == 1.0).count(), 5);
    }

    #[test]
    fn optimum_beats_or_matches_all_heuristics() {
        for seed in 0..5 {
            let (w, g) = instance(seed, 12);
            let keep = 6;
            let (_, opt) = optimal_row_mask(&w, &g, keep);
            let wm = Matrix::from_vec(1, 12, w.clone());
            for crit in [saliency::Criterion::Magnitude,
                         saliency::Criterion::Wanda,
                         saliency::Criterion::Ria] {
                let scores = saliency::scores(crit, &wm, &g.diag());
                let mask = mask_from_scores(&scores,
                                            Pattern::PerRow { keep });
                let loss = row_loss(&w, mask.row(0), &g);
                assert!(opt <= loss + 1e-4,
                        "{:?}: optimum {} > heuristic {}", crit, opt, loss);
            }
        }
    }

    #[test]
    fn sparseswaps_local_optimum_sandwiched() {
        // optimum <= SparseSwaps result <= warmstart (per row).
        for seed in 0..5 {
            let (w, g) = instance(100 + seed, 14);
            let keep = 7;
            let wm = Matrix::from_vec(1, 14, w.clone());
            let scores = saliency::wanda(&wm, &g.diag());
            let mask = mask_from_scores(&scores, Pattern::PerRow { keep });
            let warm = row_loss(&w, mask.row(0), &g);
            let mut mrow = mask.row(0).to_vec();
            let out = refine_row(&w, &mut mrow, &g, 0,
                                 &SwapConfig { t_max: 1000, eps: 0.0 });
            let (_, opt) = optimal_row_mask(&w, &g, keep);
            assert!(out.loss_after <= warm + 1e-6);
            assert!(opt <= out.loss_after + 1e-3,
                    "optimum {} > sparseswaps {}", opt, out.loss_after);
        }
    }

    #[test]
    fn keep_all_is_zero_loss() {
        let (w, g) = instance(7, 8);
        let (mask, loss) = optimal_row_mask(&w, &g, 8);
        assert_eq!(mask, vec![1.0; 8]);
        assert_eq!(loss, 0.0);
    }

    #[test]
    fn gospers_hack_visits_all_subsets() {
        // Count 3-subsets of 6 elements: C(6,3) = 20.
        let mut count = 0;
        let mut s: u64 = 0b111;
        while s < 1 << 6 {
            count += 1;
            s = next_subset(s);
        }
        assert_eq!(count, 20);
    }
}
