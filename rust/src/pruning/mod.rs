//! The pruning algorithm library: masks and patterns, warmstart
//! saliencies, exact per-row error (Gram form), the [`RefineEngine`]
//! contract every refiner implements, the native SparseSwaps engine,
//! the DSnoT baseline, and a brute-force exact solver for tiny
//! instances.  The HLO *offload* engine lives in `coordinator::swaploop`
//! (it needs the PJRT runtime) but implements the same trait and is
//! property-tested against `sparseswaps` here.

pub mod dsnot;
pub mod engine;
pub mod error;
pub mod exact;
pub mod mask;
pub mod realloc;
pub mod saliency;
pub mod sparseswaps;

pub use engine::{
    LayerContext, NoopEngine, RefineEngine, RefineError, RefineOutcome,
};
pub use mask::Pattern;
pub use saliency::Criterion;
pub use sparseswaps::NativeEngine;
