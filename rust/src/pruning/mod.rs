//! The pruning algorithm library: masks and patterns, warmstart
//! saliencies, exact per-row error (Gram form), the native SparseSwaps
//! engine, the DSnoT baseline, and a brute-force exact solver for tiny
//! instances.  The HLO *offload* engine lives in `coordinator::swaploop`
//! and is property-tested against `sparseswaps` here.

pub mod dsnot;
pub mod error;
pub mod exact;
pub mod mask;
pub mod realloc;
pub mod saliency;
pub mod sparseswaps;

pub use mask::Pattern;
pub use saliency::Criterion;
