//! DSnoT baseline (Zhang et al., 2024b): "Dynamic Sparse no Training".
//!
//! Reimplemented from the paper's description for comparison (the
//! official code is unavailable offline).  DSnoT iteratively *grows* a
//! pruned weight and *prunes* a kept weight per row, choosing both from
//! cheap surrogate statistics of the reconstruction error rather than
//! the exact quadratic objective:
//!
//!   * expected residual  E[r] = sum_{j pruned} w_j mu_j, where mu_j is
//!     the mean of feature j over the calibration set;
//!   * grow the pruned index whose expected contribution w_p mu_p
//!     opposes E[r] with the largest magnitude (moves E[r] toward 0);
//!   * prune the kept index with the smallest Wanda-style influence
//!     |w_u| * sqrt(mu_u^2 + var_u)  (second moment = E[x_u^2]).
//!
//! Because both choices ignore the interaction term -2 w_u w_p G_up, a
//! DSnoT cycle can *increase* the true loss (exactly the failure mode
//! the paper's Sec 2.1.3 counterexample illustrates); SparseSwaps is
//! monotone by construction.  Our tests assert the behaviour class, and
//! the benches reproduce the Table 1 ordering (DSnoT helps, SparseSwaps
//! helps more).

use std::collections::BTreeMap;

use crate::pruning::engine::{
    LayerContext, RefineEngine, RefineError, RefineOutcome,
};
use crate::pruning::error::row_loss;
use crate::pruning::mask::Pattern;
use crate::pruning::sparseswaps::{LayerOutcome, RowOutcome};
use crate::util::tensor::Matrix;
use crate::util::threadpool::parallel_map;

/// Per-feature calibration statistics (accumulated alongside the Gram
/// matrix during the calibration pass).
#[derive(Clone, Debug)]
pub struct FeatureStats {
    /// Mean of each feature over calibration tokens.
    pub mean: Vec<f32>,
    /// Second moment E[x_j^2] (= G_jj / tokens).
    pub second_moment: Vec<f32>,
}

impl FeatureStats {
    pub fn from_gram(gram_diag: &[f32], feature_sums: &[f32],
                     tokens: usize) -> Self {
        assert_eq!(gram_diag.len(), feature_sums.len());
        let n = tokens.max(1) as f32;
        let mean: Vec<f32> = feature_sums.iter().map(|s| s / n).collect();
        let second_moment: Vec<f32> =
            gram_diag.iter().map(|g| (g / n).max(0.0)).collect();
        Self { mean, second_moment }
    }

    pub fn variance(&self, j: usize) -> f32 {
        (self.second_moment[j] - self.mean[j] * self.mean[j]).max(0.0)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct DsnotConfig {
    /// Maximum prune/regrow cycles per row.
    pub max_cycles: usize,
    /// Stop when |E[r]| drops below this threshold.
    pub residual_tol: f32,
}

impl Default for DsnotConfig {
    fn default() -> Self {
        Self { max_cycles: 50, residual_tol: 1e-6 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct DsnotOutcome {
    pub cycles: usize,
}

/// One row of DSnoT.  `m` is mutated in place; the sparsity level (and
/// N:M block structure, if any) is preserved by swapping in pairs.
pub fn refine_row(w: &[f32], m: &mut [f32], stats: &FeatureStats,
                  nm_block: usize, cfg: &DsnotConfig) -> DsnotOutcome {
    let d = w.len();
    let mut cycles = 0;
    for _ in 0..cfg.max_cycles {
        // Expected residual of the pruned set.
        let mut er = 0.0f32;
        for j in 0..d {
            if m[j] < 0.5 {
                er += w[j] * stats.mean[j];
            }
        }
        if er.abs() <= cfg.residual_tol {
            break;
        }
        // Grow: pruned index whose contribution most opposes E[r].
        let mut grow: Option<(f32, usize)> = None;
        for p in 0..d {
            if m[p] < 0.5 {
                let contrib = w[p] * stats.mean[p];
                // Removing p from the pruned set changes E[r] by -contrib;
                // we want the largest decrease of |E[r]|.
                let newmag = (er - contrib).abs();
                let gain = er.abs() - newmag;
                if gain > 0.0
                    && grow.map_or(true, |(bg, _)| gain > bg) {
                    grow = Some((gain, p));
                }
            }
        }
        let Some((_, p_star)) = grow else { break };
        // Prune: kept index with the smallest influence, restricted to
        // the same N:M block when applicable.
        let (blk_lo, blk_hi) = if nm_block > 0 {
            let b = p_star / nm_block;
            (b * nm_block, (b + 1) * nm_block)
        } else {
            (0, d)
        };
        let mut prune: Option<(f32, usize)> = None;
        for u in blk_lo..blk_hi {
            if m[u] > 0.5 && u != p_star {
                let infl = w[u].abs() * stats.second_moment[u].sqrt();
                if prune.map_or(true, |(bi, _)| infl < bi) {
                    prune = Some((infl, u));
                }
            }
        }
        let Some((_, u_star)) = prune else { break };
        m[p_star] = 1.0;
        m[u_star] = 0.0;
        cycles += 1;
    }
    DsnotOutcome { cycles }
}

/// Refine a whole layer with DSnoT.
pub fn refine_layer(w: &Matrix, mask: &mut Matrix, stats: &FeatureStats,
                    pattern: Pattern, cfg: &DsnotConfig) -> usize {
    let nm_block = pattern.nm_block();
    let mut total = 0;
    for r in 0..w.rows {
        let mut row = mask.row(r).to_vec();
        total += refine_row(w.row(r), &mut row, stats, nm_block, cfg).cycles;
        mask.row_mut(r).copy_from_slice(&row);
    }
    total
}

/// DSnoT behind the [`RefineEngine`] contract.  Rows are independent,
/// so they are refined in parallel; the exact Gram-form loss is
/// recorded before/after each row so reports are directly comparable
/// with the SparseSwaps engines (the legacy pipeline only recorded the
/// layer total).
///
/// DSnoT cycles are grow/prune moves against a surrogate objective —
/// they are not 1-swap iterations — so iteration checkpoints do not
/// apply: `refine` returns no snapshots and the pipeline backfills
/// every checkpoint with the final mask.  The cycle budget comes from
/// [`DsnotConfig`] (its own hyperparameter), not `ctx.t_max`, matching
/// the baseline's published setup.
#[derive(Clone, Copy, Debug, Default)]
pub struct DsnotEngine {
    pub cfg: DsnotConfig,
}

impl RefineEngine for DsnotEngine {
    fn name(&self) -> String {
        "dsnot".into()
    }

    fn refine_rows(&self, ctx: &LayerContext,
                   rows: std::ops::Range<usize>, mask: &mut Matrix,
                   _checkpoints: &[usize])
        -> Result<RefineOutcome, RefineError> {
        let stats = ctx.stats.ok_or(RefineError::MissingInput(
            "per-feature calibration statistics (DSnoT)"))?;
        let (w, g) = (ctx.w, ctx.g);
        assert!(rows.end <= w.rows);
        let n_rows = rows.len();
        let r0 = rows.start;
        assert_eq!((mask.rows, mask.cols), (n_rows, w.cols));
        let nm_block = ctx.pattern.nm_block();
        let cfg = self.cfg;
        let refined: Vec<(Vec<f32>, RowOutcome)> =
            parallel_map(n_rows, ctx.threads.max(1), |k| {
                let mut m = mask.row(k).to_vec();
                let before = row_loss(w.row(r0 + k), &m, g);
                let out = refine_row(w.row(r0 + k), &mut m, stats,
                                     nm_block, &cfg);
                let after = row_loss(w.row(r0 + k), &m, g);
                (m, RowOutcome {
                    loss_before: before,
                    loss_after: after,
                    swaps: out.cycles,
                    converged: out.cycles < cfg.max_cycles,
                })
            });
        let mut out_rows = Vec::with_capacity(n_rows);
        for (k, (m, ro)) in refined.into_iter().enumerate() {
            mask.row_mut(k).copy_from_slice(&m);
            out_rows.push(ro);
        }
        Ok(RefineOutcome {
            layer: LayerOutcome { rows: out_rows },
            snapshots: BTreeMap::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask::{mask_from_scores, validate, Pattern};
    use crate::pruning::saliency;
    use crate::util::prng::Rng;

    fn stats_from_x(x: &Matrix) -> FeatureStats {
        let d = x.cols;
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(x);
        let mut sums = vec![0.0f32; d];
        for t in 0..x.rows {
            for j in 0..d {
                sums[j] += x.at(t, j);
            }
        }
        FeatureStats::from_gram(&g.diag(), &sums, x.rows)
    }

    fn biased_instance(seed: u64) -> (Matrix, Matrix, FeatureStats) {
        // Features with non-zero means so E[r] is informative.
        let mut rng = Rng::new(seed);
        let d = 24;
        let means: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
        let x = Matrix::from_fn(64, d,
                                |_, j| means[j] + 0.3 * rng.gaussian_f32());
        let w = Matrix::from_fn(6, d, |_, _| rng.gaussian_f32());
        let stats = stats_from_x(&x);
        (w, x, stats)
    }

    #[test]
    fn preserves_per_row_sparsity() {
        let (w, _, stats) = biased_instance(0);
        let pattern = Pattern::PerRow { keep: 10 };
        let mut mask = mask_from_scores(&saliency::magnitude(&w), pattern);
        refine_layer(&w, &mut mask, &stats, pattern,
                     &DsnotConfig::default());
        validate(&mask, pattern).unwrap();
    }

    #[test]
    fn preserves_nm_structure() {
        let (w, _, stats) = biased_instance(1);
        let pattern = Pattern::Nm { n: 2, m: 4 };
        let mut mask = mask_from_scores(&saliency::magnitude(&w), pattern);
        refine_layer(&w, &mut mask, &stats, pattern,
                     &DsnotConfig::default());
        validate(&mask, pattern).unwrap();
    }

    #[test]
    fn reduces_expected_residual() {
        let (w, _, stats) = biased_instance(2);
        let pattern = Pattern::PerRow { keep: 10 };
        let mut mask = mask_from_scores(&saliency::magnitude(&w), pattern);
        let er = |m: &Matrix, r: usize| -> f32 {
            (0..w.cols)
                .filter(|&j| m.at(r, j) < 0.5)
                .map(|j| w.at(r, j) * stats.mean[j])
                .sum()
        };
        let before: f32 = (0..w.rows).map(|r| er(&mask, r).abs()).sum();
        refine_layer(&w, &mut mask, &stats, pattern,
                     &DsnotConfig::default());
        let after: f32 = (0..w.rows).map(|r| er(&mask, r).abs()).sum();
        assert!(after <= before + 1e-4, "{before} -> {after}");
    }

    #[test]
    fn stats_variance_consistent() {
        let (_, x, stats) = biased_instance(3);
        // variance = E[x^2] - mean^2 must be >= 0 and roughly match a
        // direct computation.
        for j in 0..x.cols {
            let mean = (0..x.rows).map(|t| x.at(t, j)).sum::<f32>()
                / x.rows as f32;
            let var = (0..x.rows)
                .map(|t| (x.at(t, j) - mean).powi(2))
                .sum::<f32>() / x.rows as f32;
            assert!((stats.variance(j) - var).abs() < 1e-2,
                    "{} vs {}", stats.variance(j), var);
        }
    }

    #[test]
    fn engine_matches_legacy_layer_loop() {
        let (w, x, stats) = biased_instance(5);
        let d = x.cols;
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let pattern = Pattern::PerRow { keep: 10 };
        let warm = mask_from_scores(&saliency::magnitude(&w), pattern);
        let mut m_legacy = warm.clone();
        refine_layer(&w, &mut m_legacy, &stats, pattern,
                     &DsnotConfig::default());
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: Some(&stats), pattern,
            t_max: 0, threads: 2,
            gmax: None,
        };
        let mut m_engine = warm.clone();
        let out = DsnotEngine::default()
            .refine(&ctx, &mut m_engine, &[]).unwrap();
        assert_eq!(m_legacy.data, m_engine.data);
        validate(&m_engine, pattern).unwrap();
        assert_eq!(out.layer.rows.len(), w.rows);
    }

    #[test]
    fn engine_requires_feature_stats() {
        let (w, x, _) = biased_instance(6);
        let d = x.cols;
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let pattern = Pattern::PerRow { keep: 10 };
        let mut mask = mask_from_scores(&saliency::magnitude(&w), pattern);
        let ctx = LayerContext {
            w: w.view(), g: g.as_gram(), stats: None, pattern, t_max: 0,
            threads: 1,
            gmax: None,
        };
        assert!(DsnotEngine::default()
                .refine(&ctx, &mut mask, &[]).is_err());
    }

    #[test]
    fn can_increase_true_loss_unlike_sparseswaps() {
        // Behaviour-class check: across random instances DSnoT sometimes
        // increases the exact quadratic loss (it optimises a surrogate);
        // SparseSwaps never does.  We only assert "sometimes" over a
        // seed sweep to keep the test robust.
        use crate::pruning::error::layer_loss;
        let mut dsnot_row_increased = 0;
        for seed in 0..40 {
            let (w, x, stats) = biased_instance(100 + seed);
            let d = x.cols;
            let mut g = Matrix::zeros(d, d);
            g.gram_accumulate(&x);
            let pattern = Pattern::PerRow { keep: 10 };
            // Wanda warmstart: already strong, so the surrogate's blind
            // spots (ignored interactions) show up more readily.
            let scores = saliency::wanda(&w, &g.diag());
            let mut mask = mask_from_scores(&scores, pattern);
            let mut dmask = mask.clone();
            refine_layer(&w, &mut dmask, &stats, pattern,
                         &DsnotConfig::default());
            for r in 0..w.rows {
                let b = crate::pruning::error::row_loss(
                    w.row(r), mask.row(r), &g);
                let a = crate::pruning::error::row_loss(
                    w.row(r), dmask.row(r), &g);
                if a > b * (1.0 + 1e-6) {
                    dsnot_row_increased += 1;
                }
            }
            // SparseSwaps on the same warmstart is always monotone.
            let out = crate::pruning::sparseswaps::refine_layer(
                &w, &mut mask, &g, pattern,
                &crate::pruning::sparseswaps::SwapConfig::default(), 1);
            assert!(out.total_after() <= out.total_before() + 1e-6);
            let _ = layer_loss(&w, &mask, &g);
        }
        assert!(dsnot_row_increased > 0,
                "expected DSnoT to be non-monotone on some row");
    }
}
