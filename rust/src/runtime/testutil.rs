//! In-memory manifests and interp-backed runtimes/pools, shared by
//! the runtime tests, the pooled-offload parity properties, and the
//! artifact-free pool sweep in `benches/ablation_engine.rs`.
//!
//! Nothing here touches the filesystem: `swap_manifest` fabricates
//! the swap-step/layer-loss artifact entries directly and
//! `InterpBackend` executes them natively, so the whole offload stack
//! (engine → pool → service → cache) runs without `make artifacts`.

use std::path::PathBuf;
use std::sync::Arc;

use crate::runtime::backend::InterpBackend;
use crate::runtime::faults::{FaultPlan, FaultyBackend};
use crate::runtime::manifest::{ArtifactEntry, Manifest, ModelMeta};
use crate::runtime::pool::RuntimePool;
use crate::runtime::service::{Runtime, RuntimeOptions};

/// Row-chunk size of the swap artifacts fabricated by
/// [`model_manifest`] (small enough that tiny-config layers exercise
/// the multi-chunk path).
pub const MODEL_SWAP_CHUNK_ROWS: usize = 64;

/// Manifest holding interp-executable swap-step artifacts (k=1 and
/// k=8, per-row + 2:4 patterns, impl "interp") and a layer-loss
/// artifact, all at one width/chunk shape.
pub fn swap_manifest(d: usize, chunk_rows: usize) -> Manifest {
    let mut artifacts = std::collections::BTreeMap::new();
    for (tag, nm) in [("row", 0usize), ("nm2_4", 4)] {
        for k in [1usize, 8] {
            let e = ArtifactEntry::swap_step(d, chunk_rows, tag, nm,
                                             "interp", k);
            artifacts.insert(e.name.clone(), e);
        }
    }
    let ll = ArtifactEntry::layer_loss(d, chunk_rows);
    artifacts.insert(ll.name.clone(), ll);
    Manifest {
        dir: PathBuf::from("."),
        configs: Default::default(),
        artifacts,
    }
}

/// Manifest exposing the full artifact surface for one model config:
/// the model-execution kinds for `meta` (including the streamed
/// `embed`/`calib_block` pair) plus swap-step (k=1 and
/// k=8, per-row + 2:4 patterns, impl "interp") and layer-loss
/// artifacts for every prunable width — all interp-executable, so the
/// whole train → calibrate → prune → refine → evaluate cycle runs
/// without `make artifacts`.
pub fn model_manifest(meta: &ModelMeta) -> Manifest {
    let mut artifacts = std::collections::BTreeMap::new();
    let mut widths: Vec<usize> =
        meta.prunable.iter().map(|p| p.d_in).collect();
    widths.sort_unstable();
    widths.dedup();
    for &d in &widths {
        for (tag, nm) in [("row", 0usize), ("nm2_4", 4)] {
            for k in [1usize, 8] {
                let e = ArtifactEntry::swap_step(
                    d, MODEL_SWAP_CHUNK_ROWS, tag, nm, "interp", k);
                artifacts.insert(e.name.clone(), e);
            }
        }
        let ll = ArtifactEntry::layer_loss(d, MODEL_SWAP_CHUNK_ROWS);
        artifacts.insert(ll.name.clone(), ll);
    }
    for e in [
        ArtifactEntry::calib_step(meta),
        ArtifactEntry::calib_block(meta),
        ArtifactEntry::embed(meta),
        ArtifactEntry::eval_step(meta),
        ArtifactEntry::seq_nll(meta),
        ArtifactEntry::train_step(meta),
    ] {
        artifacts.insert(e.name.clone(), e);
    }
    let mut configs = std::collections::BTreeMap::new();
    configs.insert(meta.name.clone(), meta.clone());
    Manifest { dir: PathBuf::from("."), configs, artifacts }
}

/// One service worker over [`InterpBackend`].
pub fn interp_runtime(manifest: &Manifest, opts: RuntimeOptions)
    -> Runtime {
    Runtime::start_with_backend(Arc::new(manifest.clone()),
                                InterpBackend::new_default, opts)
        .expect("start interp runtime")
}

/// A pool of `devices` interp workers over one manifest.  Mirrors
/// `RuntimePool::start`: all workers share one compile cache, so
/// each artifact compiles once per pool.
pub fn interp_pool(manifest: &Manifest, devices: usize,
                   opts: RuntimeOptions) -> RuntimePool {
    let opts = opts.with_shared_compile_cache();
    RuntimePool::from_runtimes(
        (0..devices.max(1))
            .map(|device| interp_runtime(
                manifest, RuntimeOptions { device, ..opts.clone() }))
            .collect())
}

/// [`interp_pool`] with every worker's backend wrapped in a
/// [`FaultyBackend`] driving `plan` — the test/bench surface for the
/// recovery paths (mirrors `RuntimePool::start_with_faults`).
pub fn faulty_interp_pool(manifest: &Manifest, devices: usize,
                          opts: RuntimeOptions, plan: &FaultPlan)
    -> RuntimePool {
    let opts = opts.with_shared_compile_cache();
    RuntimePool::from_runtimes(
        (0..devices.max(1))
            .map(|device| {
                let plan = plan.clone();
                Runtime::start_with_backend(
                    Arc::new(manifest.clone()),
                    move || Ok(FaultyBackend::new(
                        InterpBackend::new(), plan, device)),
                    RuntimeOptions { device, ..opts.clone() })
                    .expect("start faulty interp runtime")
            })
            .collect())
}
