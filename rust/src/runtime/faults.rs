//! Deterministic fault injection for the runtime layer.
//!
//! [`FaultyBackend`] wraps any [`Backend`] and injects failures into
//! `execute` on a seeded per-device schedule, so every recovery path —
//! shard retry, worker quarantine, offload→native degradation — runs
//! under plain `cargo test -q` with no real hardware faults.  Fault
//! modes:
//!
//! - **transient** (`rate=`): the call fails with
//!   [`RuntimeError::Transient`]; the shard retries on another worker.
//! - **storm** (`storm=`): the call fails with
//!   [`RuntimeError::NotResident`], exercising the engine's
//!   probe-miss retry *and* the shard-retry path once host data is
//!   already attached.
//! - **panic** (`panic=`, `kill=`): the call panics, unwinding the
//!   service thread — total worker death.  Every later call on that
//!   device observes a channel-closed [`RuntimeError::Transient`].
//! - **fail-nth** (`nth=`): the nth eligible call on each device
//!   fails transiently, exactly once — a deterministic smoke fault.
//!
//! Schedules are deterministic per device: each wrapper forks its own
//! [`Rng`] from `(seed, device)`, so the fault sequence depends only
//! on the call index on that device, never on cross-device
//! interleaving.  Faults apply to the swap artifact kinds only by
//! default (`kinds=all` widens them), keeping calibration and
//! training clean so tests can target the refinement recovery paths.

use crate::runtime::backend::Backend;
use crate::runtime::manifest::ArtifactEntry;
use crate::runtime::service::{BufferKey, RuntimeError};
use crate::runtime::tensor_data::TensorData;
use crate::util::prng::Rng;

/// Parsed fault schedule.  Built from a spec string
/// (`seed=42;rate=0.05;kill=1;kill_after=2`) via [`FaultPlan::parse`]
/// or the `SPARSESWAPS_FAULTS` environment variable via
/// [`FaultPlan::from_env`].
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// PRNG seed; each device forks its own stream from it.
    pub seed: u64,
    /// Per-call probability of a transient execute failure.
    pub exec_fail_rate: f64,
    /// Per-call probability of a `NotResident` storm failure.
    pub storm_rate: f64,
    /// Per-call probability of a panic (kills the service thread).
    pub panic_rate: f64,
    /// Fail the nth eligible call (1-based) on every device, once.
    pub fail_nth: Option<u64>,
    /// Devices whose service thread is killed by a panic...
    pub kill_workers: Vec<usize>,
    /// ...after this many eligible calls have succeeded there.
    pub kill_after: u64,
    /// Cap on randomly injected (rate/storm/panic) faults per device.
    /// Bounds the worst case so a retry storm cannot starve a run:
    /// with `max_retries` above `devices * max_faults`, completion is
    /// guaranteed.  `None` = unbounded.
    pub max_faults: Option<u64>,
    /// Fault every artifact kind, not just `swap_step`/`layer_loss`.
    pub all_kinds: bool,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 42,
            exec_fail_rate: 0.0,
            storm_rate: 0.0,
            panic_rate: 0.0,
            fail_nth: None,
            kill_workers: Vec::new(),
            kill_after: 0,
            max_faults: None,
            all_kinds: false,
        }
    }
}

impl FaultPlan {
    /// Parse a `key=value;key=value` spec.  Keys: `seed`, `rate`,
    /// `storm`, `panic` (probabilities in [0, 1]), `nth`,
    /// `kill` (comma-separated device list), `kill_after`,
    /// `max_faults`, `kinds` (`swap` | `all`).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        fn num<T: std::str::FromStr>(k: &str, v: &str)
            -> Result<T, String> {
            v.trim().parse().map_err(
                |_| format!("fault plan: bad value for {k}: {v:?}"))
        }
        let mut plan = FaultPlan::default();
        for part in spec.split(';').map(str::trim)
            .filter(|s| !s.is_empty())
        {
            let (k, v) = part.split_once('=').ok_or_else(
                || format!("fault plan: expected key=value, \
                            got {part:?}"))?;
            match k.trim() {
                "seed" => plan.seed = num(k, v)?,
                "rate" => plan.exec_fail_rate = num(k, v)?,
                "storm" => plan.storm_rate = num(k, v)?,
                "panic" => plan.panic_rate = num(k, v)?,
                "nth" => plan.fail_nth = Some(num(k, v)?),
                "kill_after" => plan.kill_after = num(k, v)?,
                "max_faults" => plan.max_faults = Some(num(k, v)?),
                "kill" => {
                    plan.kill_workers = v.split(',')
                        .map(|w| num("kill", w))
                        .collect::<Result<_, _>>()?;
                }
                "kinds" => match v.trim() {
                    "all" => plan.all_kinds = true,
                    "swap" => plan.all_kinds = false,
                    other => return Err(format!(
                        "fault plan: kinds must be swap|all, \
                         got {other:?}")),
                },
                other => return Err(format!(
                    "fault plan: unknown key {other:?}")),
            }
        }
        for (k, p) in [("rate", plan.exec_fail_rate),
                       ("storm", plan.storm_rate),
                       ("panic", plan.panic_rate)] {
            if !(0.0..=1.0).contains(&p) {
                return Err(format!(
                    "fault plan: {k} must be in [0, 1], got {p}"));
            }
        }
        Ok(plan)
    }

    /// Read `SPARSESWAPS_FAULTS`; `Ok(None)` when unset or empty.
    pub fn from_env() -> Result<Option<FaultPlan>, String> {
        match std::env::var("SPARSESWAPS_FAULTS") {
            Ok(s) if !s.trim().is_empty() => Self::parse(&s).map(Some),
            _ => Ok(None),
        }
    }

    /// True when any fault mode is configured.
    pub fn is_active(&self) -> bool {
        self.exec_fail_rate > 0.0
            || self.storm_rate > 0.0
            || self.panic_rate > 0.0
            || self.fail_nth.is_some()
            || !self.kill_workers.is_empty()
    }
}

enum Fault {
    Transient,
    Storm,
    Panic,
}

/// [`Backend`] wrapper injecting the plan's faults into `execute`.
/// Everything else delegates untouched.
pub struct FaultyBackend<B: Backend> {
    inner: B,
    plan: FaultPlan,
    device: usize,
    rng: Rng,
    /// Eligible execute calls observed on this device (drives
    /// `fail_nth` / `kill_after`).
    calls: u64,
    /// Randomly injected faults so far (capped by `max_faults`).
    injected: u64,
}

impl<B: Backend> FaultyBackend<B> {
    pub fn new(inner: B, plan: FaultPlan, device: usize) -> Self {
        let rng = Rng::new(plan.seed).fork(device as u64 + 1);
        FaultyBackend { inner, plan, device, rng, calls: 0, injected: 0 }
    }

    fn eligible(&self, entry: &ArtifactEntry) -> bool {
        self.plan.all_kinds
            || matches!(entry.kind.as_str(), "swap_step" | "layer_loss")
    }

    fn fault_for(&mut self, entry: &ArtifactEntry) -> Option<Fault> {
        if !self.eligible(entry) {
            return None;
        }
        self.calls += 1;
        if self.plan.kill_workers.contains(&self.device)
            && self.calls > self.plan.kill_after
        {
            return Some(Fault::Panic);
        }
        if Some(self.calls) == self.plan.fail_nth {
            return Some(Fault::Transient);
        }
        if self.plan.max_faults.is_some_and(|m| self.injected >= m) {
            return None;
        }
        let fault = if self.plan.panic_rate > 0.0
            && self.rng.bool(self.plan.panic_rate)
        {
            Fault::Panic
        } else if self.plan.storm_rate > 0.0
            && self.rng.bool(self.plan.storm_rate)
        {
            Fault::Storm
        } else if self.plan.exec_fail_rate > 0.0
            && self.rng.bool(self.plan.exec_fail_rate)
        {
            Fault::Transient
        } else {
            return None;
        };
        self.injected += 1;
        Some(fault)
    }
}

impl<B: Backend> Backend for FaultyBackend<B> {
    type Buf = B::Buf;

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn compile(&mut self, entry: &ArtifactEntry)
        -> Result<bool, RuntimeError> {
        self.inner.compile(entry)
    }

    fn export_compiled(&mut self, entry: &ArtifactEntry)
        -> Option<Vec<u8>> {
        self.inner.export_compiled(entry)
    }

    fn import_compiled(&mut self, entry: &ArtifactEntry, bytes: &[u8])
        -> Result<bool, RuntimeError> {
        self.inner.import_compiled(entry, bytes)
    }

    fn upload(&mut self, t: &TensorData) -> Result<Self::Buf, RuntimeError> {
        self.inner.upload(t)
    }

    fn execute(&mut self, entry: &ArtifactEntry, inputs: &[&Self::Buf])
        -> Result<Vec<TensorData>, RuntimeError> {
        match self.fault_for(entry) {
            Some(Fault::Panic) => panic!(
                "fault injection: killing device {} in {}",
                self.device, entry.name),
            Some(Fault::Storm) => {
                return Err(RuntimeError::NotResident(BufferKey {
                    layer: 0,
                    tensor: "fault-storm".into(),
                    generation: 0,
                }));
            }
            Some(Fault::Transient) => {
                return Err(RuntimeError::Transient(format!(
                    "fault injection: device {} call {} ({})",
                    self.device, self.calls, entry.name)));
            }
            None => {}
        }
        self.inner.execute(entry, inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::InterpBackend;

    fn swap_entry() -> ArtifactEntry {
        ArtifactEntry::layer_loss(8, 4)
    }

    #[test]
    fn parse_full_spec() {
        let plan = FaultPlan::parse(
            "seed=7; rate=0.05; storm=0.1; panic=0.01; nth=3; \
             kill=1,2; kill_after=4; max_faults=5; kinds=all")
            .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.exec_fail_rate, 0.05);
        assert_eq!(plan.storm_rate, 0.1);
        assert_eq!(plan.panic_rate, 0.01);
        assert_eq!(plan.fail_nth, Some(3));
        assert_eq!(plan.kill_workers, vec![1, 2]);
        assert_eq!(plan.kill_after, 4);
        assert_eq!(plan.max_faults, Some(5));
        assert!(plan.all_kinds);
        assert!(plan.is_active());
    }

    #[test]
    fn parse_rejects_junk() {
        assert!(FaultPlan::parse("rate").is_err());
        assert!(FaultPlan::parse("rate=lots").is_err());
        assert!(FaultPlan::parse("rate=1.5").is_err());
        assert!(FaultPlan::parse("frobnicate=1").is_err());
        assert!(FaultPlan::parse("kinds=some").is_err());
    }

    #[test]
    fn empty_plan_is_inactive_and_injects_nothing() {
        let plan = FaultPlan::parse("").unwrap();
        assert_eq!(plan, FaultPlan::default());
        assert!(!plan.is_active());
        let mut fb = FaultyBackend::new(InterpBackend::new(), plan, 0);
        let e = swap_entry();
        for _ in 0..64 {
            assert!(fb.fault_for(&e).is_none());
        }
    }

    #[test]
    fn schedule_is_deterministic_per_device() {
        let plan =
            FaultPlan::parse("seed=9;rate=0.3;storm=0.2").unwrap();
        let e = swap_entry();
        let draw = |device: usize| -> Vec<u8> {
            let mut fb = FaultyBackend::new(
                InterpBackend::new(), plan.clone(), device);
            (0..200).map(|_| match fb.fault_for(&e) {
                None => 0,
                Some(Fault::Transient) => 1,
                Some(Fault::Storm) => 2,
                Some(Fault::Panic) => 3,
            }).collect()
        };
        // Same (seed, device) → same schedule; sibling devices differ.
        assert_eq!(draw(0), draw(0));
        assert_eq!(draw(1), draw(1));
        assert_ne!(draw(0), draw(1));
        assert!(draw(0).iter().any(|&f| f != 0));
    }

    #[test]
    fn swap_kinds_only_by_default() {
        let plan = FaultPlan::parse("rate=1.0").unwrap();
        let mut fb =
            FaultyBackend::new(InterpBackend::new(), plan, 0);
        let mut calib = swap_entry();
        calib.kind = "calib_step".into();
        assert!(fb.fault_for(&calib).is_none());
        assert!(fb.fault_for(&swap_entry()).is_some());

        let plan = FaultPlan::parse("rate=1.0;kinds=all").unwrap();
        let mut fb = FaultyBackend::new(InterpBackend::new(), plan, 0);
        assert!(fb.fault_for(&calib).is_some());
    }

    #[test]
    fn kill_fires_only_on_listed_device_after_budget() {
        let plan =
            FaultPlan::parse("kill=1;kill_after=2").unwrap();
        let e = swap_entry();
        let mut survivor =
            FaultyBackend::new(InterpBackend::new(), plan.clone(), 0);
        for _ in 0..8 {
            assert!(survivor.fault_for(&e).is_none());
        }
        let mut victim = FaultyBackend::new(InterpBackend::new(), plan, 1);
        assert!(victim.fault_for(&e).is_none());
        assert!(victim.fault_for(&e).is_none());
        assert!(matches!(victim.fault_for(&e), Some(Fault::Panic)));
        assert!(matches!(victim.fault_for(&e), Some(Fault::Panic)));
    }

    #[test]
    fn max_faults_caps_random_injection() {
        let plan =
            FaultPlan::parse("rate=1.0;max_faults=3").unwrap();
        let e = swap_entry();
        let mut fb = FaultyBackend::new(InterpBackend::new(), plan, 0);
        let injected = (0..32)
            .filter(|_| fb.fault_for(&e).is_some())
            .count();
        assert_eq!(injected, 3);
    }
}
