//! Pure-Rust interpreter of the model-execution artifact kinds
//! (`calib_step`, `eval_step`, `seq_nll`, `train_step`): the tiny-GPT
//! forward/backward (token embeddings, pre-RMSNorm, multi-head RoPE
//! attention, SwiGLU MLP, untied LM head, cross-entropy) plus the Adam
//! update, driven entirely by [`ModelMeta`].  This is the Rust mirror
//! of `python/compile/model.py`, which remains the AOT ground truth
//! for the PJRT path — the formulas, epsilons and parameter layout
//! here follow it line by line.
//!
//! Numerics are f32 like the lowered HLO, with f64 accumulation for
//! the scalar loss reductions.  The hot loops route through the
//! runtime-dispatched kernel layer: every inner product is a
//! `util::kernels::dot` over contiguous rows (weights stay in the
//! paper's [d_out, d_in] layout, so `x @ W^T` never transposes), rank-1
//! updates are `axpy`, and calibration Gram updates go through the
//! row-panel `syrk` behind [`Matrix::gram_accumulate`] — the interp
//! path picks up the SIMD arms from PR 2 for free.
//!
//! Forward and backward are independent per batch row, so the hot
//! loops fan out across the global thread pool: the projection/LM-head
//! matmuls and `matmul_nn`/`accum_tn` adjoints split into contiguous
//! row panels, and the O(l^2) attention stages run one job per
//! sequence.  Every output row is written by exactly one worker with
//! the same scalar code as the serial path, so losses and gradients
//! are **bit-identical** for every thread count (asserted in
//! `tests/interp_model.rs`).
//!
//! Entry points mirror the artifact signatures exactly (inputs in
//! manifest order, outputs in declared order), so
//! `runtime::backend::InterpBackend` can dispatch on
//! `ArtifactEntry::kind` with no adaptation layer.

use crate::runtime::manifest::ModelMeta;
use crate::runtime::tensor_data::TensorData;
use crate::util::tensor::{axpy, dot, Matrix};
use crate::util::threadpool::{self, default_threads};

const RMS_EPS: f32 = 1e-5;
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
const GRAD_CLIP: f32 = 1.0;

// --- parameter unpacking ---------------------------------------------------

/// Borrowed views of one block's nine parameter tensors, in the flat
/// manifest order (`configs.ModelConfig.layer_shapes`).
struct BlockParams<'a> {
    attn_norm: &'a [f32],
    wq: &'a [f32],
    wk: &'a [f32],
    wv: &'a [f32],
    wo: &'a [f32],
    mlp_norm: &'a [f32],
    wg: &'a [f32],
    wu: &'a [f32],
    wd: &'a [f32],
}

struct Params<'a> {
    tok_emb: &'a [f32],
    blocks: Vec<BlockParams<'a>>,
    final_norm: &'a [f32],
    lm_head: &'a [f32],
}

fn unpack<'a>(meta: &ModelMeta, params: &[&'a TensorData])
    -> Result<Params<'a>, String> {
    let want = 1 + meta.n_blocks * 9 + 2;
    if params.len() != want {
        return Err(format!(
            "{}: expected {want} parameter tensors, got {}",
            meta.name, params.len()));
    }
    let f = |i: usize| -> Result<&'a [f32], String> {
        params[i].as_f32()
            .map_err(|e| format!("{} param {i}: {e}", meta.name))
    };
    let mut blocks = Vec::with_capacity(meta.n_blocks);
    for b in 0..meta.n_blocks {
        let base = 1 + b * 9;
        blocks.push(BlockParams {
            attn_norm: f(base)?,
            wq: f(base + 1)?,
            wk: f(base + 2)?,
            wv: f(base + 3)?,
            wo: f(base + 4)?,
            mlp_norm: f(base + 5)?,
            wg: f(base + 6)?,
            wu: f(base + 7)?,
            wd: f(base + 8)?,
        });
    }
    Ok(Params {
        tok_emb: f(0)?,
        blocks,
        final_norm: f(1 + meta.n_blocks * 9)?,
        lm_head: f(1 + meta.n_blocks * 9 + 1)?,
    })
}

// --- kernel-backed matmul helpers ------------------------------------------

/// Run `body(panel, lo, hi)` over contiguous row panels of `data`
/// ([rows, width] row-major) on the global thread pool.  Every row is
/// written by exactly one worker with the same scalar code as the
/// serial path, so results are bit-identical for any `threads`.
fn par_row_panels<F>(threads: usize, rows: usize, width: usize,
                     data: &mut [f32], body: F)
where
    F: Fn(&mut [f32], usize, usize) + Sync,
{
    debug_assert_eq!(data.len(), rows * width);
    let threads = threads.max(1).min(rows.max(1));
    if threads <= 1 {
        body(data, 0, rows);
        return;
    }
    let chunk = rows.div_ceil(threads);
    let body = &body;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
        Vec::with_capacity(threads);
    let mut rest = data;
    let mut lo = 0usize;
    while lo < rows {
        let here = chunk.min(rows - lo);
        let (panel, tail) = rest.split_at_mut(here * width);
        rest = tail;
        let start = lo;
        jobs.push(Box::new(move || body(panel, start, start + here)));
        lo += here;
    }
    threadpool::global().run_scoped(jobs);
}

/// y = x @ w^T for a paper-layout weight w [d_out, d_in] given as a
/// flat slice.  Rows of both operands are contiguous, so every entry
/// is one kernel `dot`; output rows split across the pool.
fn matmul_nt(x: &Matrix, w: &[f32], d_out: usize, threads: usize)
    -> Matrix {
    let d_in = x.cols;
    assert_eq!(w.len(), d_out * d_in);
    let mut y = Matrix::zeros(x.rows, d_out);
    par_row_panels(threads, x.rows, d_out, &mut y.data,
                   |panel, lo, hi| {
        for t in lo..hi {
            let xr = x.row(t);
            let yr =
                &mut panel[(t - lo) * d_out..(t - lo + 1) * d_out];
            for (o, yo) in yr.iter_mut().enumerate() {
                *yo = dot(xr, &w[o * d_in..(o + 1) * d_in]);
            }
        }
    });
    y
}

/// dx = dy @ w for w [d_out, d_in]: `axpy` accumulation over the
/// contiguous weight rows (the adjoint of [`matmul_nt`] wrt x),
/// output rows split across the pool.
fn matmul_nn(dy: &Matrix, w: &[f32], d_in: usize, threads: usize)
    -> Matrix {
    let d_out = dy.cols;
    assert_eq!(w.len(), d_out * d_in);
    let mut dx = Matrix::zeros(dy.rows, d_in);
    par_row_panels(threads, dy.rows, d_in, &mut dx.data,
                   |panel, lo, hi| {
        for t in lo..hi {
            let dyr = dy.row(t);
            let dxr =
                &mut panel[(t - lo) * d_in..(t - lo + 1) * d_in];
            for (o, &a) in dyr.iter().enumerate() {
                if a != 0.0 {
                    axpy(a, &w[o * d_in..(o + 1) * d_in], dxr);
                }
            }
        }
    });
    dx
}

/// dw += dy^T @ x into a flat [d_out, d_in] gradient slice (the
/// adjoint of [`matmul_nt`] wrt w), gradient rows split across the
/// pool.  `t` stays the outer loop inside each panel, so every dw
/// element accumulates its contributions in ascending-t order exactly
/// like the serial pass — bit-identical for any split.
fn accum_tn(dw: &mut [f32], dy: &Matrix, x: &Matrix, threads: usize) {
    assert_eq!(dw.len(), dy.cols * x.cols);
    assert_eq!(dy.rows, x.rows);
    let d_in = x.cols;
    par_row_panels(threads, dy.cols, d_in, dw, |panel, o0, o1| {
        for t in 0..x.rows {
            let xr = x.row(t);
            let dyr = dy.row(t);
            for o in o0..o1 {
                let a = dyr[o];
                if a != 0.0 {
                    axpy(a, xr,
                         &mut panel[(o - o0) * d_in
                                    ..(o - o0 + 1) * d_in]);
                }
            }
        }
    });
}

fn add_assign(a: &mut Matrix, b: &Matrix) {
    debug_assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

// --- building blocks -------------------------------------------------------

/// y[t] = x[t] * rsqrt(mean(x[t]^2) + eps) * w.  Returns (y, inv_rms
/// per row) — the backward pass needs only x, w and inv_rms.
fn rmsnorm(x: &Matrix, w: &[f32]) -> (Matrix, Vec<f32>) {
    let d = x.cols;
    assert_eq!(w.len(), d);
    let mut y = Matrix::zeros(x.rows, d);
    let mut inv = Vec::with_capacity(x.rows);
    for t in 0..x.rows {
        let xr = x.row(t);
        let ms = dot(xr, xr) / d as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        inv.push(r);
        let yr = y.row_mut(t);
        for j in 0..d {
            yr[j] = xr[j] * r * w[j];
        }
    }
    (y, inv)
}

/// Backward of [`rmsnorm`]: with s = x * r, y = s ⊙ w and
/// r = (mean(x²)+eps)^(-1/2), we get ds = dy ⊙ w,
/// dx = r·ds − (r³/d)·(ds·x)·x and dw += dy ⊙ x · r.
fn rmsnorm_backward(x: &Matrix, w: &[f32], inv: &[f32], dy: &Matrix,
                    dw: &mut [f32]) -> Matrix {
    let d = x.cols;
    let mut dx = Matrix::zeros(x.rows, d);
    for t in 0..x.rows {
        let (xr, dyr) = (x.row(t), dy.row(t));
        let r = inv[t];
        let mut ds_dot_x = 0.0f32;
        for j in 0..d {
            ds_dot_x += dyr[j] * w[j] * xr[j];
            dw[j] += dyr[j] * xr[j] * r;
        }
        let c = r * r * r * ds_dot_x / d as f32;
        let dxr = dx.row_mut(t);
        for j in 0..d {
            dxr[j] = r * dyr[j] * w[j] - c * xr[j];
        }
    }
    dx
}

/// cos/sin tables for RoPE: entry (p, i) holds the angle p * theta^(-i
/// / half), matching `model.rope`.
fn rope_tables(l: usize, half: usize, theta: f32) -> (Vec<f32>, Vec<f32>) {
    let mut cos = Vec::with_capacity(l * half);
    let mut sin = Vec::with_capacity(l * half);
    for p in 0..l {
        for i in 0..half {
            let freq = theta.powf(-(i as f32) / half as f32);
            let ang = p as f32 * freq;
            cos.push(ang.cos());
            sin.push(ang.sin());
        }
    }
    (cos, sin)
}

/// Apply RoPE in place over a [b*l, n_heads*hd] activation.  `tables`
/// is the (cos, sin) pair from [`rope_tables`]; `sign` is +1.0 for the
/// forward rotation and -1.0 for the adjoint (the rotation is
/// orthogonal, so backward = rotate by the negative angle).
fn rope_in_place(x: &mut Matrix, b: usize, l: usize, n_heads: usize,
                 hd: usize, tables: (&[f32], &[f32]), sign: f32) {
    let (cos, sin) = tables;
    let half = hd / 2;
    for bi in 0..b {
        for p in 0..l {
            let row = x.row_mut(bi * l + p);
            for h in 0..n_heads {
                let c0 = h * hd;
                for i in 0..half {
                    let c = cos[p * half + i];
                    let s = sign * sin[p * half + i];
                    let x1 = row[c0 + i];
                    let x2 = row[c0 + half + i];
                    row[c0 + i] = x1 * c - x2 * s;
                    row[c0 + half + i] = x1 * s + x2 * c;
                }
            }
        }
    }
}

// --- forward ---------------------------------------------------------------

/// Per-block activation cache.  The four calibration streams are
/// exactly `h` (qkv), `attn_out` (o), `h2` (gu) and `dmlp` (down).
struct BlockCache {
    x_in: Matrix,
    h: Matrix,
    r_attn: Vec<f32>,
    /// Post-RoPE projections [b*l, dm] (backward uses the rotated
    /// values and un-rotates the gradients).
    q: Matrix,
    k: Matrix,
    v: Matrix,
    /// Softmax attention weights, one [l, l] matrix per (batch row,
    /// head) in row-major (bi * n_heads + h) order; entries above the
    /// diagonal are exactly zero (causal).
    probs: Vec<Matrix>,
    attn_out: Matrix,
    x_mid: Matrix,
    h2: Matrix,
    r_mlp: Vec<f32>,
    gate: Matrix,
    up: Matrix,
    dmlp: Matrix,
}

struct Forward {
    blocks: Vec<BlockCache>,
    /// Final residual-stream activation (pre final norm).
    x_out: Matrix,
    xf: Matrix,
    r_final: Vec<f32>,
    logits: Matrix,
}

fn check_dims(meta: &ModelMeta) -> Result<(usize, usize), String> {
    let (dm, nh) = (meta.d_model, meta.n_heads);
    if nh == 0 || dm % nh != 0 {
        return Err(format!(
            "{}: d_model {dm} not divisible by n_heads {nh}", meta.name));
    }
    let hd = dm / nh;
    if hd % 2 != 0 {
        return Err(format!(
            "{}: head dim {hd} must be even for RoPE", meta.name));
    }
    Ok((dm, hd))
}

/// Causal softmax attention for one sequence (batch row `bi`):
/// scores -> softmax -> weighted V sum, writing this sequence's rows
/// of `attn_out` (`attn_rows`, l x dm) and its `probs` matrices (one
/// [l, l] per head).  One job per sequence on the pool; sequences are
/// independent, so the parallel schedule is bit-identical to serial.
#[allow(clippy::too_many_arguments)]
fn attn_forward_seq(bi: usize, l: usize, hd: usize, scale: f32,
                    q: &Matrix, k: &Matrix, v: &Matrix,
                    probs_seq: &mut [Matrix], attn_rows: &mut [f32]) {
    let dm = probs_seq.len() * hd;
    let mut acc = vec![0.0f32; hd];
    for (hh, pm) in probs_seq.iter_mut().enumerate() {
        let c0 = hh * hd;
        let c1 = c0 + hd;
        for i in 0..l {
            let qi = &q.row(bi * l + i)[c0..c1];
            let pr = pm.row_mut(i);
            let mut m = f32::NEG_INFINITY;
            for (j, pj) in pr.iter_mut().enumerate().take(i + 1) {
                let s = dot(qi, &k.row(bi * l + j)[c0..c1]) * scale;
                *pj = s;
                m = m.max(s);
            }
            let mut z = 0.0f32;
            for pj in pr.iter_mut().take(i + 1) {
                let e = (*pj - m).exp();
                *pj = e;
                z += e;
            }
            for pj in pr.iter_mut().take(i + 1) {
                *pj /= z;
            }
        }
        for i in 0..l {
            let pr = pm.row(i);
            acc.fill(0.0);
            for (j, &pj) in pr.iter().enumerate().take(i + 1) {
                axpy(pj, &v.row(bi * l + j)[c0..c1], &mut acc);
            }
            attn_rows[i * dm + c0..i * dm + c1].copy_from_slice(&acc);
        }
    }
}

/// Token-embedding lookup: the [b*l, d_model] residual-stream seed.
/// Shared by [`forward`] and `exec_embed`, so the streamed
/// calibration path starts from bit-identical activations.
fn embed_tokens(meta: &ModelMeta, tok_emb: &[f32], tokens: &[i32],
                b: usize, l: usize) -> Result<Matrix, String> {
    let (dm, vocab) = (meta.d_model, meta.vocab);
    let t_n = b * l;
    if tokens.len() != t_n {
        return Err(format!("{}: expected {t_n} tokens, got {}",
                           meta.name, tokens.len()));
    }
    let mut x = Matrix::zeros(t_n, dm);
    for (t, &id) in tokens.iter().enumerate() {
        let id = id as usize;
        if id >= vocab {
            return Err(format!("{}: token id {id} >= vocab {vocab}",
                               meta.name));
        }
        x.row_mut(t).copy_from_slice(&tok_emb[id * dm..(id + 1) * dm]);
    }
    Ok(x)
}

/// One transformer block's forward pass, consuming the residual
/// stream `x_in` and returning the full activation cache plus the
/// next residual stream.  Shared by [`forward`] and
/// `exec_calib_block`, so per-block streamed execution propagates
/// activations bit-identically to the whole-model pass.
#[allow(clippy::too_many_arguments)]
fn block_forward(meta: &ModelMeta, bp: &BlockParams<'_>, x_in: Matrix,
                 b: usize, l: usize, tables: (&[f32], &[f32]),
                 threads: usize) -> (BlockCache, Matrix) {
    let (dm, nh, dff) = (meta.d_model, meta.n_heads, meta.d_ff);
    let hd = dm / nh;
    let scale = 1.0 / (hd as f32).sqrt();
    let t_n = b * l;
    let (h, r_attn) = rmsnorm(&x_in, bp.attn_norm);

    let mut q = matmul_nt(&h, bp.wq, dm, threads);
    let mut k = matmul_nt(&h, bp.wk, dm, threads);
    let v = matmul_nt(&h, bp.wv, dm, threads);
    rope_in_place(&mut q, b, l, nh, hd, tables, 1.0);
    rope_in_place(&mut k, b, l, nh, hd, tables, 1.0);

    let mut probs: Vec<Matrix> =
        (0..b * nh).map(|_| Matrix::zeros(l, l)).collect();
    let mut attn_out = Matrix::zeros(t_n, dm);
    // Degenerate shapes (l == 0): attention is a no-op, and
    // chunks_mut(0) would panic — skip the fan-out entirely.
    if l * dm > 0 {
        // One job per sequence: row block bi*l..(bi+1)*l of
        // attn_out and probs[bi*nh..(bi+1)*nh] are each written
        // by exactly one worker.
        let (q, k, v) = (&q, &k, &v);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(b);
        for (bi, (probs_seq, attn_rows)) in probs
            .chunks_mut(nh)
            .zip(attn_out.data.chunks_mut(l * dm))
            .enumerate()
        {
            let job = move || attn_forward_seq(bi, l, hd, scale, q,
                                               k, v, probs_seq,
                                               attn_rows);
            if threads <= 1 || b <= 1 {
                job();
            } else {
                jobs.push(Box::new(job));
            }
        }
        if !jobs.is_empty() {
            threadpool::global().run_scoped(jobs);
        }
    }

    let proj = matmul_nt(&attn_out, bp.wo, dm, threads);
    let mut x_mid = x_in.clone();
    add_assign(&mut x_mid, &proj);

    let (h2, r_mlp) = rmsnorm(&x_mid, bp.mlp_norm);
    let gate = matmul_nt(&h2, bp.wg, dff, threads);
    let up = matmul_nt(&h2, bp.wu, dff, threads);
    let mut dmlp = Matrix::zeros(t_n, dff);
    for idx in 0..t_n * dff {
        let g = gate.data[idx];
        let sg = 1.0 / (1.0 + (-g).exp());
        dmlp.data[idx] = g * sg * up.data[idx];
    }
    let down = matmul_nt(&dmlp, bp.wd, dm, threads);
    let mut x_out = x_mid.clone();
    add_assign(&mut x_out, &down);

    (BlockCache {
        x_in, h, r_attn, q, k, v, probs, attn_out, x_mid, h2,
        r_mlp, gate, up, dmlp,
    }, x_out)
}

fn forward(meta: &ModelMeta, p: &Params, tokens: &[i32], b: usize,
           l: usize, threads: usize) -> Result<Forward, String> {
    let (_, hd) = check_dims(meta)?;
    let mut x = embed_tokens(meta, p.tok_emb, tokens, b, l)?;
    let (cos, sin) = rope_tables(l, hd / 2, meta.rope_theta as f32);
    let mut blocks = Vec::with_capacity(meta.n_blocks);
    for bp in &p.blocks {
        let (cache, x_out) =
            block_forward(meta, bp, x, b, l, (&cos, &sin), threads);
        blocks.push(cache);
        x = x_out;
    }

    let (xf, r_final) = rmsnorm(&x, p.final_norm);
    let logits = matmul_nt(&xf, p.lm_head, vocab, threads);
    Ok(Forward { blocks, x_out: x, xf, r_final, logits })
}

/// Per-token NLL and the softmax probabilities (cached for the
/// cross-entropy backward).
fn token_nll(logits: &Matrix, targets: &[i32])
    -> Result<(Vec<f32>, Matrix), String> {
    let v = logits.cols;
    if targets.len() != logits.rows {
        return Err(format!("expected {} targets, got {}", logits.rows,
                           targets.len()));
    }
    let mut probs = Matrix::zeros(logits.rows, v);
    let mut nll = Vec::with_capacity(logits.rows);
    for t in 0..logits.rows {
        let lr = logits.row(t);
        let y = targets[t] as usize;
        if y >= v {
            return Err(format!("target id {y} >= vocab {v}"));
        }
        let m = lr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let pr = probs.row_mut(t);
        let mut z = 0.0f32;
        for j in 0..v {
            let e = (lr[j] - m).exp();
            pr[j] = e;
            z += e;
        }
        for pj in pr.iter_mut() {
            *pj /= z;
        }
        nll.push(z.ln() - (lr[y] - m));
    }
    Ok((nll, probs))
}

// --- backward --------------------------------------------------------------

/// Attention backward for one sequence (batch row `bi`): writes this
/// sequence's rows of dq/dk/dv from its cached probs and the rotated
/// q/k/v.  One job per sequence on the pool, bit-identical to serial.
#[allow(clippy::too_many_arguments)]
fn attn_backward_seq(bi: usize, l: usize, nh: usize, hd: usize,
                     scale: f32, q: &Matrix, k: &Matrix, v: &Matrix,
                     probs_seq: &[Matrix], d_attn_out: &Matrix,
                     dq_rows: &mut [f32], dk_rows: &mut [f32],
                     dv_rows: &mut [f32]) {
    let dm = nh * hd;
    let mut dp_row = vec![0.0f32; l];
    for (hh, pm) in probs_seq.iter().enumerate() {
        let c0 = hh * hd;
        let c1 = c0 + hd;
        for i in 0..l {
            let dout_i = &d_attn_out.row(bi * l + i)[c0..c1];
            let pr = pm.row(i);
            // dP and the softmax-jacobian inner product.
            let mut dot_pp = 0.0f32;
            for j in 0..=i {
                let dp = dot(dout_i, &v.row(bi * l + j)[c0..c1]);
                dp_row[j] = dp;
                dot_pp += dp * pr[j];
            }
            for j in 0..=i {
                axpy(pr[j], dout_i,
                     &mut dv_rows[j * dm + c0..j * dm + c1]);
                let ds = pr[j] * (dp_row[j] - dot_pp) * scale;
                if ds != 0.0 {
                    axpy(ds, &k.row(bi * l + j)[c0..c1],
                         &mut dq_rows[i * dm + c0..i * dm + c1]);
                    axpy(ds, &q.row(bi * l + i)[c0..c1],
                         &mut dk_rows[j * dm + c0..j * dm + c1]);
                }
            }
        }
    }
}

/// Gradients of a scalar loss wrt every parameter tensor (manifest
/// order), given dL/dlogits.  Mirrors `jax.grad` through the exact
/// forward recomputed by [`forward`].
fn backward(meta: &ModelMeta, p: &Params, fwd: &Forward,
            dlogits: &Matrix, tokens: &[i32], b: usize, l: usize,
            threads: usize) -> Vec<Vec<f32>> {
    let (dm, hd) = (meta.d_model, meta.d_model / meta.n_heads);
    let (nh, dff, nb) = (meta.n_heads, meta.d_ff, meta.n_blocks);
    let scale = 1.0 / (hd as f32).sqrt();
    let (cos, sin) = rope_tables(l, hd / 2, meta.rope_theta as f32);
    let mut grads: Vec<Vec<f32>> = meta.params.iter()
        .map(|(_, dims)| vec![0.0f32; dims.iter().product()])
        .collect();
    let i_final_norm = 1 + nb * 9;
    let i_lm_head = i_final_norm + 1;

    accum_tn(&mut grads[i_lm_head], dlogits, &fwd.xf, threads);
    let dxf = matmul_nn(dlogits, p.lm_head, dm, threads);
    let mut dx = rmsnorm_backward(&fwd.x_out, p.final_norm,
                                  &fwd.r_final, &dxf,
                                  &mut grads[i_final_norm]);

    for bi_rev in (0..nb).rev() {
        let cache = &fwd.blocks[bi_rev];
        let bp = &p.blocks[bi_rev];
        let base = 1 + bi_rev * 9;

        // MLP: x_out = x_mid + (silu(gate) ⊙ up) @ wd^T.
        let d_dmlp = matmul_nn(&dx, bp.wd, dff, threads);
        accum_tn(&mut grads[base + 8], &dx, &cache.dmlp, threads);
        let mut dgate = Matrix::zeros(b * l, dff);
        let mut dup = Matrix::zeros(b * l, dff);
        for idx in 0..b * l * dff {
            let g = cache.gate.data[idx];
            let sg = 1.0 / (1.0 + (-g).exp());
            let silu = g * sg;
            let dsilu = sg * (1.0 + g * (1.0 - sg));
            let dd = d_dmlp.data[idx];
            dgate.data[idx] = dd * cache.up.data[idx] * dsilu;
            dup.data[idx] = dd * silu;
        }
        accum_tn(&mut grads[base + 6], &dgate, &cache.h2, threads);
        accum_tn(&mut grads[base + 7], &dup, &cache.h2, threads);
        let mut dh2 = matmul_nn(&dgate, bp.wg, dm, threads);
        add_assign(&mut dh2, &matmul_nn(&dup, bp.wu, dm, threads));
        let dx_mid_norm = rmsnorm_backward(&cache.x_mid, bp.mlp_norm,
                                           &cache.r_mlp, &dh2,
                                           &mut grads[base + 5]);
        let mut dx_mid = dx;
        add_assign(&mut dx_mid, &dx_mid_norm);

        // Attention: x_mid = x_in + attn_out @ wo^T.
        accum_tn(&mut grads[base + 4], &dx_mid, &cache.attn_out,
                 threads);
        let d_attn_out = matmul_nn(&dx_mid, bp.wo, dm, threads);
        let mut dq = Matrix::zeros(b * l, dm);
        let mut dk = Matrix::zeros(b * l, dm);
        let mut dv = Matrix::zeros(b * l, dm);
        // Same degenerate-shape guard as the forward pass.
        if l * dm > 0 {
            let (q, k, v) = (&cache.q, &cache.k, &cache.v);
            let d_attn_out = &d_attn_out;
            let probs_all = &cache.probs;
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(b);
            for (bi, ((dq_rows, dk_rows), dv_rows)) in dq.data
                .chunks_mut(l * dm)
                .zip(dk.data.chunks_mut(l * dm))
                .zip(dv.data.chunks_mut(l * dm))
                .enumerate()
            {
                let probs_seq = &probs_all[bi * nh..(bi + 1) * nh];
                let job = move || attn_backward_seq(
                    bi, l, nh, hd, scale, q, k, v, probs_seq,
                    d_attn_out, dq_rows, dk_rows, dv_rows);
                if threads <= 1 || b <= 1 {
                    job();
                } else {
                    jobs.push(Box::new(job));
                }
            }
            if !jobs.is_empty() {
                threadpool::global().run_scoped(jobs);
            }
        }
        rope_in_place(&mut dq, b, l, nh, hd, (&cos, &sin), -1.0);
        rope_in_place(&mut dk, b, l, nh, hd, (&cos, &sin), -1.0);
        accum_tn(&mut grads[base + 1], &dq, &cache.h, threads);
        accum_tn(&mut grads[base + 2], &dk, &cache.h, threads);
        accum_tn(&mut grads[base + 3], &dv, &cache.h, threads);
        let mut dh = matmul_nn(&dq, bp.wq, dm, threads);
        add_assign(&mut dh, &matmul_nn(&dk, bp.wk, dm, threads));
        add_assign(&mut dh, &matmul_nn(&dv, bp.wv, dm, threads));
        let dx_in_norm = rmsnorm_backward(&cache.x_in, bp.attn_norm,
                                          &cache.r_attn, &dh,
                                          &mut grads[base]);
        dx = dx_mid;
        add_assign(&mut dx, &dx_in_norm);
    }

    let demb = &mut grads[0];
    for (t, &id) in tokens.iter().enumerate() {
        let id = id as usize;
        axpy(1.0, dx.row(t), &mut demb[id * dm..(id + 1) * dm]);
    }
    grads
}

// --- public analytic API (tests, finite-difference checks) -----------------

fn batch_dims(t: &TensorData, what: &str)
    -> Result<(usize, usize), String> {
    match t.dims() {
        [b, l] => Ok((*b, *l)),
        other => Err(format!("{what}: expected a [b, l] tensor, got \
                              dims {other:?}")),
    }
}

/// Logits [b*l, vocab] of one forward pass (row t = position t of the
/// flattened batch).
pub fn forward_logits(meta: &ModelMeta, params: &[&TensorData],
                      tokens: &TensorData) -> Result<Matrix, String> {
    let (b, l) = batch_dims(tokens, "tokens")?;
    let p = unpack(meta, params)?;
    Ok(forward(meta, &p, tokens.as_i32()?, b, l, default_threads())?
        .logits)
}

/// Mean token NLL over the batch (the training objective), f64.
pub fn mean_nll(meta: &ModelMeta, params: &[&TensorData],
                tokens: &TensorData, targets: &TensorData)
    -> Result<f64, String> {
    let (b, l) = batch_dims(tokens, "tokens")?;
    let p = unpack(meta, params)?;
    let fwd = forward(meta, &p, tokens.as_i32()?, b, l,
                      default_threads())?;
    let (nll, _) = token_nll(&fwd.logits, targets.as_i32()?)?;
    Ok(nll.iter().map(|&x| x as f64).sum::<f64>() / (b * l) as f64)
}

/// Mean token NLL and its (pre-clip) gradient wrt every parameter
/// tensor, in manifest order — the analytic side of the
/// finite-difference checks in `tests/interp_model.rs`.
pub fn loss_and_grads(meta: &ModelMeta, params: &[&TensorData],
                      tokens: &TensorData, targets: &TensorData)
    -> Result<(f64, Vec<Vec<f32>>), String> {
    loss_and_grads_threads(meta, params, tokens, targets,
                           default_threads())
}

/// [`loss_and_grads`] with an explicit worker count.  Results are
/// bit-identical for every value — the hook the thread-invariance
/// parity test drives.
pub fn loss_and_grads_threads(meta: &ModelMeta, params: &[&TensorData],
                              tokens: &TensorData,
                              targets: &TensorData, threads: usize)
    -> Result<(f64, Vec<Vec<f32>>), String> {
    let (b, l) = batch_dims(tokens, "tokens")?;
    let toks = tokens.as_i32()?;
    let tgts = targets.as_i32()?;
    let p = unpack(meta, params)?;
    let fwd = forward(meta, &p, toks, b, l, threads)?;
    let (nll, probs) = token_nll(&fwd.logits, tgts)?;
    let loss = nll.iter().map(|&x| x as f64).sum::<f64>()
        / (b * l) as f64;
    let t_n = (b * l) as f32;
    let mut dlogits = probs;
    for t in 0..b * l {
        let y = tgts[t] as usize;
        let r = dlogits.row_mut(t);
        r[y] -= 1.0;
        for val in r.iter_mut() {
            *val /= t_n;
        }
    }
    let grads = backward(meta, &p, &fwd, &dlogits, toks, b, l, threads);
    Ok((loss, grads))
}

// --- artifact entry points -------------------------------------------------

/// `train_step_{cfg}`: one Adam step with global-norm gradient
/// clipping.  Inputs (params.., m.., v.., step, tokens, targets, lr);
/// outputs (params.., m.., v.., step, loss) — the exact contract
/// `coordinator::trainer::train` threads through executions.
pub fn exec_train_step(meta: &ModelMeta, inputs: &[&TensorData])
    -> Result<Vec<TensorData>, String> {
    let np = meta.param_count();
    if inputs.len() != 3 * np + 4 {
        return Err(format!("train_step_{}: expected {} inputs, got {}",
                           meta.name, 3 * np + 4, inputs.len()));
    }
    let (params, rest) = inputs.split_at(np);
    let (m_in, rest) = rest.split_at(np);
    let (v_in, rest) = rest.split_at(np);
    let step0 = rest[0].as_i32()?.first().copied()
        .ok_or("train_step: empty step tensor")?;
    let tokens_t = rest[1];
    let targets_t = rest[2];
    let lr = rest[3].as_f32()?.first().copied()
        .ok_or("train_step: empty lr tensor")?;
    let (loss, grads) = loss_and_grads(meta, params, tokens_t,
                                       targets_t)?;

    // Global-norm clip, then Adam with bias correction (model.py
    // `train_step`: b1=0.9, b2=0.999, eps=1e-8, clip=1.0).
    let gnorm = (grads.iter()
        .flat_map(|g| g.iter())
        .map(|&x| (x as f64) * (x as f64))
        .sum::<f64>() + 1e-12)
        .sqrt();
    let scale = (GRAD_CLIP as f64 / gnorm).min(1.0) as f32;
    let step = step0 + 1;
    let stepf = step as f32;
    let bc1 = 1.0 - ADAM_B1.powf(stepf);
    let bc2 = 1.0 - ADAM_B2.powf(stepf);

    let mut out_p = Vec::with_capacity(np);
    let mut out_m = Vec::with_capacity(np);
    let mut out_v = Vec::with_capacity(np);
    for i in 0..np {
        let pw = params[i].as_f32()?;
        let mw = m_in[i].as_f32()?;
        let vw = v_in[i].as_f32()?;
        if mw.len() != pw.len() || vw.len() != pw.len()
            || grads[i].len() != pw.len() {
            return Err(format!("train_step_{}: param {i} state size \
                                mismatch", meta.name));
        }
        let dims = params[i].dims().to_vec();
        let mut p_new = Vec::with_capacity(pw.len());
        let mut m_new = Vec::with_capacity(pw.len());
        let mut v_new = Vec::with_capacity(pw.len());
        for j in 0..pw.len() {
            let g = grads[i][j] * scale;
            let mj = ADAM_B1 * mw[j] + (1.0 - ADAM_B1) * g;
            let vj = ADAM_B2 * vw[j] + (1.0 - ADAM_B2) * g * g;
            let upd = (mj / bc1) / ((vj / bc2).sqrt() + ADAM_EPS);
            p_new.push(pw[j] - lr * upd);
            m_new.push(mj);
            v_new.push(vj);
        }
        out_p.push(TensorData::F32 { dims: dims.clone(), data: p_new });
        out_m.push(TensorData::F32 { dims: dims.clone(), data: m_new });
        out_v.push(TensorData::F32 { dims, data: v_new });
    }
    let mut out = out_p;
    out.extend(out_m);
    out.extend(out_v);
    out.push(TensorData::scalar_i32(step));
    out.push(TensorData::scalar_f32(loss as f32));
    Ok(out)
}

/// `eval_step_{cfg}`: summed token NLL + token count (the perplexity
/// building block).
pub fn exec_eval_step(meta: &ModelMeta, inputs: &[&TensorData])
    -> Result<Vec<TensorData>, String> {
    let np = meta.param_count();
    if inputs.len() != np + 2 {
        return Err(format!("eval_step_{}: expected {} inputs, got {}",
                           meta.name, np + 2, inputs.len()));
    }
    let (params, rest) = inputs.split_at(np);
    let (b, l) = batch_dims(rest[0], "eval_step tokens")?;
    let p = unpack(meta, params)?;
    let fwd = forward(meta, &p, rest[0].as_i32()?, b, l,
                      default_threads())?;
    let (nll, _) = token_nll(&fwd.logits, rest[1].as_i32()?)?;
    let sum = nll.iter().map(|&x| x as f64).sum::<f64>();
    Ok(vec![
        TensorData::scalar_f32(sum as f32),
        TensorData::scalar_f32((b * l) as f32),
    ])
}

/// `seq_nll_{cfg}`: masked per-row summed NLL [b] (lm-eval-style
/// choice scoring for `eval::zeroshot`).
pub fn exec_seq_nll(meta: &ModelMeta, inputs: &[&TensorData])
    -> Result<Vec<TensorData>, String> {
    let np = meta.param_count();
    if inputs.len() != np + 3 {
        return Err(format!("seq_nll_{}: expected {} inputs, got {}",
                           meta.name, np + 3, inputs.len()));
    }
    let (params, rest) = inputs.split_at(np);
    let (b, l) = batch_dims(rest[0], "seq_nll tokens")?;
    let mask = rest[2].as_f32()?;
    if mask.len() != b * l {
        return Err(format!("seq_nll_{}: mask has {} elements, want {}",
                           meta.name, mask.len(), b * l));
    }
    let p = unpack(meta, params)?;
    let fwd = forward(meta, &p, rest[0].as_i32()?, b, l,
                      default_threads())?;
    let (nll, _) = token_nll(&fwd.logits, rest[1].as_i32()?)?;
    let rows: Vec<f32> = (0..b)
        .map(|bi| (0..l)
            .map(|t| nll[bi * l + t] * mask[bi * l + t])
            .sum())
        .collect();
    Ok(vec![TensorData::F32 { dims: vec![b], data: rows }])
}

/// `calib_step_{cfg}`: forward pass accumulating the four Gram streams
/// and feature sums per block (Sec 2.1.2 on-the-fly accumulation).
/// The X^T X updates go through the kernel layer's `syrk`.
pub fn exec_calib_step(meta: &ModelMeta, inputs: &[&TensorData])
    -> Result<Vec<TensorData>, String> {
    let np = meta.param_count();
    if inputs.len() != np + 9 {
        return Err(format!("calib_step_{}: expected {} inputs, got {}",
                           meta.name, np + 9, inputs.len()));
    }
    let (params, rest) = inputs.split_at(np);
    let tokens_t = rest[0];
    let (b, l) = batch_dims(tokens_t, "calib_step tokens")?;
    let p = unpack(meta, params)?;
    let fwd = forward(meta, &p, tokens_t.as_i32()?, b, l,
                      default_threads())?;

    let mut grams: Vec<TensorData> =
        rest[1..5].iter().map(|t| (*t).clone()).collect();
    let mut sums: Vec<TensorData> =
        rest[5..9].iter().map(|t| (*t).clone()).collect();
    for (bi, cache) in fwd.blocks.iter().enumerate() {
        accumulate_block_stats(meta, cache, &mut grams, &mut sums, bi,
                               "calib_step")?;
    }
    let mut out = grams;
    out.extend(sums);
    Ok(out)
}

/// Fold one block's four capture streams into Gram / feature-sum
/// tensors at stack offset `bi` (0 for the per-block `calib_block`
/// tensors).  Shared by `exec_calib_step` and `exec_calib_block` so
/// the stacked and streamed accumulation orders are bit-identical.
fn accumulate_block_stats(meta: &ModelMeta, cache: &BlockCache,
                          grams: &mut [TensorData],
                          sums: &mut [TensorData], bi: usize,
                          what: &str) -> Result<(), String> {
    // gram::STREAMS order: qkv, o, gu, down.
    let streams: [(&Matrix, usize); 4] = [
        (&cache.h, meta.d_model),
        (&cache.attn_out, meta.d_model),
        (&cache.h2, meta.d_model),
        (&cache.dmlp, meta.d_ff),
    ];
    for (si, (x, d)) in streams.iter().enumerate() {
        let d = *d;
        let gd = grams[si].as_f32_mut()?;
        let off = bi * d * d;
        if gd.len() < off + d * d {
            return Err(format!(
                "{what}_{}: gram stack {si} too small for \
                 block {bi} width {d}", meta.name));
        }
        let mut g_mat =
            Matrix::from_vec(d, d, gd[off..off + d * d].to_vec());
        g_mat.gram_accumulate(x);
        gd[off..off + d * d].copy_from_slice(&g_mat.data);

        let sd = sums[si].as_f32_mut()?;
        let soff = bi * d;
        if sd.len() < soff + d {
            return Err(format!(
                "{what}_{}: sum stack {si} too small for \
                 block {bi} width {d}", meta.name));
        }
        for t in 0..x.rows {
            axpy(1.0, x.row(t), &mut sd[soff..soff + d]);
        }
    }
    Ok(())
}

/// `embed_{cfg}`: token-embedding lookup — stage 0 of the streamed
/// calibration pipeline.  Inputs (tok_emb, tokens); one output, the
/// residual stream h [b*l, d_model].
pub fn exec_embed(meta: &ModelMeta, inputs: &[&TensorData])
    -> Result<Vec<TensorData>, String> {
    if inputs.len() != 2 {
        return Err(format!("embed_{}: expected 2 inputs, got {}",
                           meta.name, inputs.len()));
    }
    check_dims(meta)?;
    let tok_emb = inputs[0].as_f32()?;
    let (b, l) = batch_dims(inputs[1], "embed tokens")?;
    let x = embed_tokens(meta, tok_emb, inputs[1].as_i32()?, b, l)?;
    Ok(vec![TensorData::F32 {
        dims: vec![b * l, meta.d_model],
        data: x.data,
    }])
}

/// `calib_block_{cfg}`: one block's forward pass over a resident
/// residual stream, optionally folding the block's four capture
/// streams into per-block Gram / feature-sum tensors.  Inputs (the
/// block's nine params, h_in, accum i32 — 0 propagates only — four
/// Grams, four sums); outputs (four Grams, four sums, h_out).  The
/// streamed-calibration workhorse: running it per block over the
/// `exec_embed` output reproduces `exec_calib_step` bit-for-bit.
pub fn exec_calib_block(meta: &ModelMeta, inputs: &[&TensorData])
    -> Result<Vec<TensorData>, String> {
    if inputs.len() != 19 {
        return Err(format!("calib_block_{}: expected 19 inputs, \
                            got {}", meta.name, inputs.len()));
    }
    let (dm, hd) = check_dims(meta)?;
    let (b, l) = (meta.batch, meta.seq_len);
    let f = |i: usize| -> Result<&[f32], String> {
        inputs[i].as_f32()
            .map_err(|e| format!("calib_block_{} input {i}: {e}",
                                 meta.name))
    };
    let bp = BlockParams {
        attn_norm: f(0)?,
        wq: f(1)?,
        wk: f(2)?,
        wv: f(3)?,
        wo: f(4)?,
        mlp_norm: f(5)?,
        wg: f(6)?,
        wu: f(7)?,
        wd: f(8)?,
    };
    let h_in = inputs[9].as_f32()?;
    if h_in.len() != b * l * dm {
        return Err(format!(
            "calib_block_{}: h_in has {} elements, want {}",
            meta.name, h_in.len(), b * l * dm));
    }
    let accum = inputs[10].as_i32()?.first().copied()
        .ok_or("calib_block: empty accum tensor")? != 0;
    let x_in = Matrix::from_vec(b * l, dm, h_in.to_vec());
    let (cos, sin) = rope_tables(l, hd / 2, meta.rope_theta as f32);
    let (cache, x_out) = block_forward(meta, &bp, x_in, b, l,
                                       (&cos, &sin),
                                       default_threads());
    let mut grams: Vec<TensorData> =
        inputs[11..15].iter().map(|t| (*t).clone()).collect();
    let mut sums: Vec<TensorData> =
        inputs[15..19].iter().map(|t| (*t).clone()).collect();
    if accum {
        accumulate_block_stats(meta, &cache, &mut grams, &mut sums, 0,
                               "calib_block")?;
    }
    let mut out = grams;
    out.extend(sums);
    out.push(TensorData::F32 {
        dims: vec![b * l, dm],
        data: x_out.data,
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::store::ParamStore;
    use crate::model::testutil::meta_for;
    use crate::util::prng::Rng;

    fn toy() -> (crate::runtime::manifest::ModelMeta, ParamStore,
                 TensorData, TensorData) {
        let meta = meta_for(16, 8, 2, 16, 2, 4, 2);
        let store = ParamStore::init(&meta, 11);
        let mut rng = Rng::new(5);
        let n = meta.batch * meta.seq_len;
        let toks: Vec<i32> = (0..n)
            .map(|_| rng.usize_below(meta.vocab) as i32)
            .collect();
        let tgts: Vec<i32> = (0..n)
            .map(|_| rng.usize_below(meta.vocab) as i32)
            .collect();
        let dims = vec![meta.batch, meta.seq_len];
        (meta, store,
         TensorData::I32 { dims: dims.clone(), data: toks },
         TensorData::I32 { dims, data: tgts })
    }

    #[test]
    fn forward_shapes_and_finite() {
        let (meta, store, toks, _) = toy();
        let refs: Vec<&TensorData> =
            store.tensors.iter().map(|t| t.as_ref()).collect();
        let logits = forward_logits(&meta, &refs, &toks).unwrap();
        assert_eq!((logits.rows, logits.cols),
                   (meta.batch * meta.seq_len, meta.vocab));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn untrained_loss_near_uniform() {
        // Random init at fan-in scale produces near-uniform logits, so
        // the mean NLL starts close to ln(vocab).
        let (meta, store, toks, tgts) = toy();
        let refs: Vec<&TensorData> =
            store.tensors.iter().map(|t| t.as_ref()).collect();
        let loss = mean_nll(&meta, &refs, &toks, &tgts).unwrap();
        let uniform = (meta.vocab as f64).ln();
        assert!((loss - uniform).abs() < 1.0,
                "loss {loss} vs ln(V) {uniform}");
    }

    #[test]
    fn rope_rotation_is_orthogonal() {
        // forward(sign=+1) then adjoint(sign=-1) round-trips exactly
        // (up to f32 rounding).
        let (b, l, nh, hd) = (2usize, 3usize, 2usize, 4usize);
        let mut rng = Rng::new(1);
        let x0 = Matrix::from_fn(b * l, nh * hd, |_, _| rng.gaussian_f32());
        let (cos, sin) = rope_tables(l, hd / 2, 10000.0);
        let mut x = x0.clone();
        rope_in_place(&mut x, b, l, nh, hd, (&cos, &sin), 1.0);
        rope_in_place(&mut x, b, l, nh, hd, (&cos, &sin), -1.0);
        assert!(x.max_abs_diff(&x0) < 1e-5);
    }

    #[test]
    fn rmsnorm_backward_matches_fd() {
        let mut rng = Rng::new(2);
        let x = Matrix::from_fn(3, 5, |_, _| rng.gaussian_f32());
        let w: Vec<f32> = (0..5).map(|_| rng.gaussian_f32()).collect();
        // Scalar objective: sum of outputs weighted by fixed c.
        let c = Matrix::from_fn(3, 5, |_, _| rng.gaussian_f32());
        let f = |x: &Matrix, w: &[f32]| -> f64 {
            let (y, _) = rmsnorm(x, w);
            y.data.iter().zip(&c.data)
                .map(|(&a, &b)| (a * b) as f64)
                .sum()
        };
        let (_, inv) = rmsnorm(&x, &w);
        let mut dw = vec![0.0f32; 5];
        let dx = rmsnorm_backward(&x, &w, &inv, &c, &mut dw);
        let h = 1e-3f32;
        for (i, j) in [(0usize, 0usize), (1, 3), (2, 4)] {
            let mut xp = x.clone();
            xp.set(i, j, x.at(i, j) + h);
            let mut xm = x.clone();
            xm.set(i, j, x.at(i, j) - h);
            let fd = (f(&xp, &w) - f(&xm, &w)) / (2.0 * h as f64);
            let g = dx.at(i, j) as f64;
            assert!((fd - g).abs() < 1e-2 * g.abs().max(0.1),
                    "dx[{i}][{j}]: fd {fd} vs {g}");
        }
        for j in 0..5 {
            let mut wp = w.clone();
            wp[j] += h;
            let mut wm = w.clone();
            wm[j] -= h;
            let fd = (f(&x, &wp) - f(&x, &wm)) / (2.0 * h as f64);
            let g = dw[j] as f64;
            assert!((fd - g).abs() < 1e-2 * g.abs().max(0.1),
                    "dw[{j}]: fd {fd} vs {g}");
        }
    }

    #[test]
    fn train_step_round_trip_shapes() {
        let (meta, store, toks, tgts) = toy();
        let np = meta.param_count();
        let zeros = ParamStore::zeros_like(&meta);
        let mut inputs: Vec<TensorData> = store.tensor_args();
        inputs.extend(zeros.tensor_args());
        inputs.extend(zeros.tensor_args());
        inputs.push(TensorData::scalar_i32(0));
        inputs.push(toks);
        inputs.push(tgts);
        inputs.push(TensorData::scalar_f32(1e-3));
        let refs: Vec<&TensorData> = inputs.iter().collect();
        let out = exec_train_step(&meta, &refs).unwrap();
        assert_eq!(out.len(), 3 * np + 2);
        assert_eq!(out[3 * np].as_i32().unwrap(), &[1]);
        let loss = out[3 * np + 1].scalar_value().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        for i in 0..np {
            assert_eq!(out[i].dims(), store.tensors[i].dims());
            // Adam moved every parameter tensor (grads are dense).
            assert_ne!(out[i].as_f32().unwrap(),
                       store.tensors[i].as_f32().unwrap(),
                       "param {i} unchanged");
        }
    }

    #[test]
    fn repeated_train_steps_reduce_loss() {
        let (meta, store, toks, tgts) = toy();
        let np = meta.param_count();
        let zeros = ParamStore::zeros_like(&meta);
        let mut params = store.tensor_args();
        let mut m = zeros.tensor_args();
        let mut v = zeros.tensor_args();
        let mut step = TensorData::scalar_i32(0);
        let lr = TensorData::scalar_f32(5e-3);
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for s in 0..30 {
            let mut inputs: Vec<&TensorData> = Vec::new();
            inputs.extend(params.iter());
            inputs.extend(m.iter());
            inputs.extend(v.iter());
            inputs.push(&step);
            inputs.push(&toks);
            inputs.push(&tgts);
            inputs.push(&lr);
            let mut out = exec_train_step(&meta, &inputs).unwrap();
            let loss = out.pop().unwrap().scalar_value().unwrap();
            step = out.pop().unwrap();
            v = out.split_off(2 * np);
            m = out.split_off(np);
            params = out;
            if s == 0 {
                first = loss;
            }
            last = loss;
        }
        // Memorising one fixed batch must drive the loss down fast.
        assert!(last < first * 0.9, "loss {first} -> {last}");
    }

    #[test]
    fn calib_step_accumulates_psd_grams() {
        let (meta, store, toks, _) = toy();
        let entry = crate::runtime::manifest::ArtifactEntry::calib_step(
            &meta);
        let mut stats: Vec<TensorData> = entry.inputs
            [meta.param_count() + 1..]
            .iter()
            .map(TensorData::zeros)
            .collect();
        let mut inputs: Vec<&TensorData> =
            store.tensors.iter().map(|t| t.as_ref()).collect();
        inputs.push(&toks);
        inputs.extend(stats.iter());
        let out = exec_calib_step(&meta, &inputs).unwrap();
        assert_eq!(out.len(), 8);
        // Diagonals of every Gram stack are non-negative and not all
        // zero; accumulating a second batch doubles nothing but grows
        // every diagonal monotonically.
        let diag_sum = |t: &TensorData, nb: usize, d: usize| -> f64 {
            let v = t.as_f32().unwrap();
            (0..nb).flat_map(|b| (0..d).map(move |i| (b, i)))
                .map(|(b, i)| v[b * d * d + i * d + i] as f64)
                .sum()
        };
        let s1 = diag_sum(&out[0], meta.n_blocks, meta.d_model);
        assert!(s1 > 0.0);
        stats = out;
        let mut inputs: Vec<&TensorData> =
            store.tensors.iter().map(|t| t.as_ref()).collect();
        inputs.push(&toks);
        inputs.extend(stats.iter());
        let out2 = exec_calib_step(&meta, &inputs).unwrap();
        let s2 = diag_sum(&out2[0], meta.n_blocks, meta.d_model);
        assert!(s2 > s1 * 1.5, "gram diagonal must keep accumulating");
        // Feature sums track the capture streams too.
        assert!(out2[4].as_f32().unwrap().iter()
                .any(|&v| v != 0.0));
    }

    #[test]
    fn embed_plus_calib_blocks_match_calib_step_bitwise() {
        let (meta, store, toks, _) = toy();
        let np = meta.param_count();

        // Resident reference: one whole-model calib_step.
        let entry = crate::runtime::manifest::ArtifactEntry::calib_step(
            &meta);
        let stats: Vec<TensorData> = entry.inputs[np + 1..]
            .iter()
            .map(TensorData::zeros)
            .collect();
        let mut inputs: Vec<&TensorData> =
            store.tensors.iter().map(|t| t.as_ref()).collect();
        inputs.push(&toks);
        inputs.extend(stats.iter());
        let reference = exec_calib_step(&meta, &inputs).unwrap();

        // Streamed path: embed, then one calib_block per block with
        // per-block zero stats, threading h through.
        let emb_in = vec![store.tensors[0].as_ref(), &toks];
        let mut h = exec_embed(&meta, &emb_in).unwrap()
            .pop().unwrap();
        let cb = crate::runtime::manifest::ArtifactEntry::calib_block(
            &meta);
        let widths = [meta.d_model, meta.d_model, meta.d_model,
                      meta.d_ff];
        let one = TensorData::scalar_i32(1);
        for b in 0..meta.n_blocks {
            let zeros: Vec<TensorData> = cb.inputs[11..19].iter()
                .map(TensorData::zeros)
                .collect();
            let mut cb_in: Vec<&TensorData> =
                store.tensors[1 + b * 9..1 + (b + 1) * 9]
                    .iter().map(|t| t.as_ref()).collect();
            cb_in.push(&h);
            cb_in.push(&one);
            cb_in.extend(zeros.iter());
            let mut out = exec_calib_block(&meta, &cb_in).unwrap();
            let h_out = out.pop().unwrap();
            // Per-block grams/sums equal the matching slab of the
            // stacked reference, bit for bit.
            for (si, d) in widths.iter().enumerate() {
                let g_ref = reference[si].as_f32().unwrap();
                let g_blk = out[si].as_f32().unwrap();
                assert_eq!(g_blk, &g_ref[b * d * d..(b + 1) * d * d],
                           "gram stream {si} block {b}");
                let s_ref = reference[4 + si].as_f32().unwrap();
                let s_blk = out[4 + si].as_f32().unwrap();
                assert_eq!(s_blk, &s_ref[b * d..(b + 1) * d],
                           "sum stream {si} block {b}");
            }
            h = h_out;
        }

        // accum = 0 propagates h without touching the stats.
        let zero = TensorData::scalar_i32(0);
        let zeros: Vec<TensorData> = cb.inputs[11..19].iter()
            .map(TensorData::zeros)
            .collect();
        let emb_in = vec![store.tensors[0].as_ref(), &toks];
        let h0 = exec_embed(&meta, &emb_in).unwrap().pop().unwrap();
        let mut cb_in: Vec<&TensorData> = store.tensors[1..10]
            .iter().map(|t| t.as_ref()).collect();
        cb_in.push(&h0);
        cb_in.push(&zero);
        cb_in.extend(zeros.iter());
        let out = exec_calib_block(&meta, &cb_in).unwrap();
        for t in &out[..8] {
            assert!(t.as_f32().unwrap().iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn seq_nll_masks_rows_independently() {
        let (meta, store, toks, tgts) = toy();
        let (b, l) = (meta.batch, meta.seq_len);
        let mut inputs: Vec<&TensorData> =
            store.tensors.iter().map(|t| t.as_ref()).collect();
        inputs.push(&toks);
        inputs.push(&tgts);
        let full = TensorData::F32 { dims: vec![b, l],
                                     data: vec![1.0; b * l] };
        let mut half_data = vec![0.0f32; b * l];
        for bi in 0..b {
            for t in 0..l / 2 {
                half_data[bi * l + t] = 1.0;
            }
        }
        let half = TensorData::F32 { dims: vec![b, l], data: half_data };
        let mut in_full = inputs.clone();
        in_full.push(&full);
        let mut in_half = inputs.clone();
        in_half.push(&half);
        let out_full = exec_seq_nll(&meta, &in_full).unwrap();
        let out_half = exec_seq_nll(&meta, &in_half).unwrap();
        let vf = out_full[0].as_f32().unwrap();
        let vh = out_half[0].as_f32().unwrap();
        assert_eq!(vf.len(), b);
        for bi in 0..b {
            assert!(vh[bi] < vf[bi],
                    "masked row {bi} must drop NLL: {} vs {}",
                    vh[bi], vf[bi]);
            assert!(vf[bi] > 0.0);
        }
        // eval_step agrees with the fully-masked seq_nll total.
        let out_eval = exec_eval_step(&meta, &inputs).unwrap();
        let total: f64 = vf.iter().map(|&x| x as f64).sum();
        let eval_sum = out_eval[0].scalar_value().unwrap();
        assert!((total - eval_sum).abs() / eval_sum.abs().max(1.0)
                < 1e-4);
    }
}
