//! Pluggable execution backends for the runtime service.
//!
//! The service thread owns exactly one [`Backend`]: the XLA PJRT
//! client when the `xla` feature is enabled (requires the vendored
//! `xla` crate), or [`InterpBackend`] — a pure-Rust interpreter of
//! every artifact kind, refinement and model-execution alike — in the
//! default std-only build.  The backend is always constructed *on*
//! the service thread (factory pattern, see
//! `Runtime::start_with_backend`), so non-`Send` device handles never
//! cross threads; only the factory has to be `Send`.
//!
//! The split is what makes the runtime layer testable: the pool and
//! the device-buffer cache are exercised against [`InterpBackend`]
//! (or a test-local mock) without any PJRT toolchain, while the
//! production path keeps the exact artifact contract.

use std::collections::HashSet;

use crate::runtime::interp_model;
use crate::runtime::manifest::{ArtifactEntry, ModelMeta};
use crate::runtime::service::RuntimeError;
use crate::runtime::tensor_data::TensorData;

/// One device's execution substrate, driven by the service thread.
pub trait Backend {
    /// Device-resident buffer handle (may wrap raw pointers; the
    /// service never moves it off its thread).
    type Buf;

    /// Stable backend label for logs and errors.
    fn name(&self) -> &'static str;

    /// Compile an artifact ahead of execution (idempotent).  Returns
    /// `true` when a compile actually happened, `false` when the
    /// executable was already cached.
    fn compile(&mut self, entry: &ArtifactEntry)
        -> Result<bool, RuntimeError>;

    /// Serialize a compiled executable for cross-worker handoff
    /// through the pool's shared compile cache.  Backends that cannot
    /// serialize return `None` (the default) and every worker
    /// compiles locally, exactly as before the cache existed.
    fn export_compiled(&mut self, _entry: &ArtifactEntry)
        -> Option<Vec<u8>> {
        None
    }

    /// Install an executable a sibling worker exported.  Returns
    /// `true` when the handoff was accepted (the entry now counts as
    /// compiled on this worker), `false` to fall back to a local
    /// compile.
    fn import_compiled(&mut self, _entry: &ArtifactEntry,
                       _bytes: &[u8]) -> Result<bool, RuntimeError> {
        Ok(false)
    }

    /// Upload one host tensor into a device buffer.
    fn upload(&mut self, t: &TensorData)
        -> Result<Self::Buf, RuntimeError>;

    /// Execute a compiled artifact over device buffers, returning
    /// host tensors in the artifact's declared output order.
    fn execute(&mut self, entry: &ArtifactEntry, inputs: &[&Self::Buf])
        -> Result<Vec<TensorData>, RuntimeError>;
}

/// Backend the default (std-only) build starts services with.
#[cfg(feature = "xla")]
pub type DefaultBackend = XlaBackend;
/// Backend the default (std-only) build starts services with.
#[cfg(not(feature = "xla"))]
pub type DefaultBackend = InterpBackend;

fn unknown_kind(kind: &str) -> RuntimeError {
    RuntimeError::Msg(format!(
        "unknown artifact kind {kind:?} (expected one of {:?})",
        crate::runtime::manifest::ARTIFACT_KINDS))
}

/// Resolved model config of a model-execution artifact entry.
/// `Manifest::load` attaches it at parse time; hand-built entries
/// must use the typed `ArtifactEntry` constructors.
fn model_meta(entry: &ArtifactEntry) -> Result<&ModelMeta, RuntimeError> {
    entry.model.as_ref().ok_or_else(|| RuntimeError::Msg(format!(
        "{}: model artifact carries no resolved config metadata \
         (manifest entry missing its `config`)", entry.name)))
}

/// Pure-Rust interpreter of every artifact kind: the refinement kinds
/// (`swap_step`, `layer_loss`) via the same reference ops as the
/// native engine (`pruning::sparseswaps::refine_row`), and the
/// model-execution kinds (`calib_step`, `calib_block`, `embed`,
/// `eval_step`, `seq_nll`, `train_step`) via `runtime::interp_model`'s tiny-GPT
/// forward/backward — so the whole pipeline (train → calibrate →
/// prune → refine → evaluate) runs, and is testable and benchable,
/// without a PJRT toolchain or `make artifacts`.
///
/// "Device" buffers are host copies: [`Backend::upload`] clones the
/// tensor, standing in for the host→device transfer, so a cache hit
/// skips exactly the work a real device would skip.
#[derive(Default)]
pub struct InterpBackend {
    compiled: HashSet<String>,
}

impl InterpBackend {
    pub fn new() -> InterpBackend {
        InterpBackend::default()
    }

    /// Factory for `Runtime::start_with_backend`.
    pub fn new_default() -> Result<InterpBackend, RuntimeError> {
        Ok(InterpBackend::new())
    }
}

impl Backend for InterpBackend {
    type Buf = TensorData;

    fn name(&self) -> &'static str {
        "interp"
    }

    fn compile(&mut self, entry: &ArtifactEntry)
        -> Result<bool, RuntimeError> {
        match entry.kind.as_str() {
            "swap_step" | "layer_loss" =>
                Ok(self.compiled.insert(entry.name.clone())),
            "calib_step" | "calib_block" | "embed" | "eval_step"
            | "seq_nll" | "train_step" => {
                model_meta(entry)?;
                Ok(self.compiled.insert(entry.name.clone()))
            }
            other => Err(unknown_kind(other)),
        }
    }

    fn export_compiled(&mut self, entry: &ArtifactEntry)
        -> Option<Vec<u8>> {
        // The interp "executable" is just the validated entry, so the
        // serialized form is an empty marker; import re-validates,
        // standing in for deserialization.
        self.compiled.contains(&entry.name).then(Vec::new)
    }

    fn import_compiled(&mut self, entry: &ArtifactEntry,
                       _bytes: &[u8]) -> Result<bool, RuntimeError> {
        // The marker carries no state, so installing == validating ==
        // compiling.  Delegate to `compile` so a kind added there can
        // never drift out of the import path (`Ok(false)` = already
        // present = still accepted).
        self.compile(entry)?;
        Ok(true)
    }

    fn upload(&mut self, t: &TensorData)
        -> Result<TensorData, RuntimeError> {
        Ok(t.clone())
    }

    fn execute(&mut self, entry: &ArtifactEntry, inputs: &[&TensorData])
        -> Result<Vec<TensorData>, RuntimeError> {
        match entry.kind.as_str() {
            "swap_step" => exec_swap_step(entry, inputs),
            "layer_loss" => exec_layer_loss(entry, inputs),
            "calib_step" => interp_model::exec_calib_step(
                model_meta(entry)?, inputs).map_err(RuntimeError::Msg),
            "calib_block" => interp_model::exec_calib_block(
                model_meta(entry)?, inputs).map_err(RuntimeError::Msg),
            "embed" => interp_model::exec_embed(
                model_meta(entry)?, inputs).map_err(RuntimeError::Msg),
            "eval_step" => interp_model::exec_eval_step(
                model_meta(entry)?, inputs).map_err(RuntimeError::Msg),
            "seq_nll" => interp_model::exec_seq_nll(
                model_meta(entry)?, inputs).map_err(RuntimeError::Msg),
            "train_step" => interp_model::exec_train_step(
                model_meta(entry)?, inputs).map_err(RuntimeError::Msg),
            other => Err(unknown_kind(other)),
        }
    }
}

/// Unpack the shared (w, mask, gram) chunk layout of the refinement
/// artifacts.
fn chunk_inputs<'a>(entry: &ArtifactEntry, inputs: &[&'a TensorData])
    -> Result<(&'a [f32], &'a [f32], crate::util::tensor::GramView<'a>,
               usize, usize),
              RuntimeError> {
    if inputs.len() != 3 {
        return Err(RuntimeError::Msg(format!(
            "{}: expected 3 inputs (w, mask, gram), got {}",
            entry.name, inputs.len())));
    }
    let (d, chunk) = (entry.width, entry.chunk_rows);
    let w = inputs[0].as_f32().map_err(RuntimeError::Msg)?;
    let m = inputs[1].as_f32().map_err(RuntimeError::Msg)?;
    let g = inputs[2].as_f32().map_err(RuntimeError::Msg)?;
    if w.len() != chunk * d || m.len() != chunk * d || g.len() != d * d {
        return Err(RuntimeError::Msg(format!(
            "{}: input element counts do not match chunk {chunk} x \
             width {d}", entry.name)));
    }
    Ok((w, m, crate::util::tensor::GramView::new(g, d), chunk, d))
}

/// Up to `k_iters` exact 1-swaps per row — the reference semantics of
/// the `swap_step_*` artifacts (bit-for-bit `refine_row`).
fn exec_swap_step(entry: &ArtifactEntry, inputs: &[&TensorData])
    -> Result<Vec<TensorData>, RuntimeError> {
    use crate::pruning::sparseswaps::{refine_row, SwapConfig};
    let (w, m, g, chunk, d) = chunk_inputs(entry, inputs)?;
    let cfg = SwapConfig { t_max: entry.k_iters.max(1), eps: 0.0 };
    let mut m_out = m.to_vec();
    let mut l_before = vec![0.0f32; chunk];
    let mut l_after = vec![0.0f32; chunk];
    let mut swaps = vec![0.0f32; chunk];
    for r in 0..chunk {
        let row_w = &w[r * d..(r + 1) * d];
        let row_m = &mut m_out[r * d..(r + 1) * d];
        let out = refine_row(row_w, row_m, g, entry.nm_block, &cfg);
        l_before[r] = out.loss_before as f32;
        l_after[r] = out.loss_after as f32;
        swaps[r] = out.swaps as f32;
    }
    Ok(vec![
        TensorData::F32 { dims: vec![chunk, d], data: m_out },
        TensorData::F32 { dims: vec![chunk], data: l_before },
        TensorData::F32 { dims: vec![chunk], data: l_after },
        TensorData::F32 { dims: vec![chunk], data: swaps },
    ])
}

/// Exact per-row loss of a masked chunk (the `layer_loss_*` kind).
fn exec_layer_loss(entry: &ArtifactEntry, inputs: &[&TensorData])
    -> Result<Vec<TensorData>, RuntimeError> {
    let (w, m, g, chunk, d) = chunk_inputs(entry, inputs)?;
    let losses: Vec<f32> = (0..chunk)
        .map(|r| crate::pruning::error::row_loss(
            &w[r * d..(r + 1) * d], &m[r * d..(r + 1) * d], g) as f32)
        .collect();
    Ok(vec![TensorData::F32 { dims: vec![chunk], data: losses }])
}

#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

/// PJRT-backed execution.  Compiled only with `--features xla`, which
/// requires adding the vendored `xla` crate as a path dependency (the
/// offline build has no crates.io access); see DESIGN.md runtime
/// notes and the load_hlo example for the artifact flow:
///   HLO text -> HloModuleProto -> XlaComputation -> compile (cached).
#[cfg(feature = "xla")]
mod xla_backend {
    use std::collections::HashMap;

    use super::Backend;
    use crate::runtime::manifest::{ArtifactEntry, DType};
    use crate::runtime::service::RuntimeError;
    use crate::runtime::tensor_data::TensorData;

    pub struct XlaBackend {
        client: xla::PjRtClient,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl XlaBackend {
        /// Factory for `Runtime::start_with_backend`.
        pub fn new_default() -> Result<XlaBackend, RuntimeError> {
            let client = xla::PjRtClient::cpu().map_err(|e| {
                RuntimeError::Xla(format!("client init failed: {e:?}"))
            })?;
            Ok(XlaBackend { client, executables: HashMap::new() })
        }
    }

    impl Backend for XlaBackend {
        type Buf = xla::PjRtBuffer;

        fn name(&self) -> &'static str {
            "xla-pjrt"
        }

        fn compile(&mut self, entry: &ArtifactEntry)
            -> Result<bool, RuntimeError> {
            if self.executables.contains_key(&entry.name) {
                return Ok(false);
            }
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .map_err(|e| RuntimeError::Xla(format!(
                    "parse {}: {e:?}", entry.file.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)
                .map_err(|e| RuntimeError::Xla(format!(
                    "compile {}: {e:?}", entry.name)))?;
            self.executables.insert(entry.name.clone(), exe);
            Ok(true)
        }

        fn upload(&mut self, t: &TensorData)
            -> Result<xla::PjRtBuffer, RuntimeError> {
            // Typed upload: `buffer_from_host_raw_bytes` passes an
            // `ElementType` discriminant where the C side expects a
            // `PrimitiveType`, silently creating a buffer of the wrong
            // dtype (F32 -> F16).  The typed variant converts
            // correctly.
            match t {
                TensorData::F32 { dims, data } => self
                    .client
                    .buffer_from_host_buffer::<f32>(data, dims, None),
                TensorData::I32 { dims, data } => self
                    .client
                    .buffer_from_host_buffer::<i32>(data, dims, None),
            }
            .map_err(|e| RuntimeError::Xla(format!("pack buffer: {e:?}")))
        }

        fn execute(&mut self, entry: &ArtifactEntry,
                   inputs: &[&xla::PjRtBuffer])
            -> Result<Vec<TensorData>, RuntimeError> {
            let exe = self.executables.get(&entry.name)
                .ok_or_else(|| RuntimeError::Msg(format!(
                    "{}: executed before compile", entry.name)))?;
            // Buffers stay owned by the service (persistently cached
            // ones survive the call); `execute_b` borrows them.  The
            // crate's literal-based `execute` leaks every input device
            // buffer — see EXPERIMENTS.md §Perf iteration 4.
            let result = exe.execute_b::<&xla::PjRtBuffer>(inputs)
                .map_err(|e| RuntimeError::Xla(format!(
                    "execute {}: {e:?}", entry.name)))?;
            let mut tuple = result[0][0].to_literal_sync()
                .map_err(|e| RuntimeError::Xla(format!("{e:?}")))?;
            let parts = tuple.decompose_tuple()
                .map_err(|e| RuntimeError::Xla(format!("{e:?}")))?;
            if parts.len() != entry.outputs.len() {
                return Err(RuntimeError::Msg(format!(
                    "{}: manifest declares {} outputs, PJRT returned {}",
                    entry.name, entry.outputs.len(), parts.len())));
            }
            parts.iter().zip(&entry.outputs)
                .map(|(lit, sig)| unpack_literal(lit, sig.dtype,
                                                 &sig.dims))
                .collect()
        }
    }

    fn unpack_literal(lit: &xla::Literal, dtype: DType, dims: &[usize])
        -> Result<TensorData, RuntimeError> {
        match dtype {
            DType::F32 => {
                let data = lit.to_vec::<f32>()
                    .map_err(|e| RuntimeError::Xla(format!("{e:?}")))?;
                Ok(TensorData::F32 { dims: dims.to_vec(), data })
            }
            DType::I32 => {
                let data = lit.to_vec::<i32>()
                    .map_err(|e| RuntimeError::Xla(format!("{e:?}")))?;
                Ok(TensorData::I32 { dims: dims.to_vec(), data })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::mask::{mask_from_scores, validate, Pattern};
    use crate::pruning::saliency;
    use crate::pruning::sparseswaps::{refine_row, SwapConfig};
    use crate::runtime::manifest::Manifest;
    use crate::util::prng::Rng;
    use crate::util::tensor::Matrix;

    fn instance(seed: u64, rows: usize, d: usize) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(3 * d, d, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let w = Matrix::from_fn(rows, d, |_, _| rng.gaussian_f32());
        (w, g)
    }

    #[test]
    fn interp_swap_step_matches_refine_row_bitwise() {
        let (d, chunk) = (24usize, 6usize);
        let entry = crate::runtime::manifest::ArtifactEntry::swap_step(
            d, chunk, "row", 0, "interp", 8);
        let (w, g) = instance(3, chunk, d);
        let pattern = Pattern::PerRow { keep: 10 };
        let mask = mask_from_scores(&saliency::wanda(&w, &g.diag()),
                                    pattern);
        let mut be = InterpBackend::new();
        assert!(be.compile(&entry).unwrap());
        assert!(!be.compile(&entry).unwrap());
        let bufs = [
            be.upload(&crate::runtime::TensorData::from_matrix(&w))
                .unwrap(),
            be.upload(&crate::runtime::TensorData::from_matrix(&mask))
                .unwrap(),
            be.upload(&crate::runtime::TensorData::from_matrix(&g))
                .unwrap(),
        ];
        let refs: Vec<&TensorData> = bufs.iter().collect();
        let out = be.execute(&entry, &refs).unwrap();
        let m_out = out[0].as_f32().unwrap();
        let swaps = out[3].as_f32().unwrap();
        let cfg = SwapConfig { t_max: 8, eps: 0.0 };
        for r in 0..chunk {
            let mut want = mask.row(r).to_vec();
            let ro = refine_row(w.row(r), &mut want, &g, 0, &cfg);
            assert_eq!(&m_out[r * d..(r + 1) * d], &want[..], "row {r}");
            assert_eq!(swaps[r] as usize, ro.swaps, "row {r}");
        }
        let got = Matrix::from_vec(chunk, d, m_out.to_vec());
        validate(&got, pattern).unwrap();
    }

    #[test]
    fn interp_layer_loss_matches_native() {
        let (d, chunk) = (16usize, 4usize);
        let entry = crate::runtime::manifest::ArtifactEntry::layer_loss(
            d, chunk);
        let (w, g) = instance(4, chunk, d);
        let mask = mask_from_scores(&saliency::magnitude(&w),
                                    Pattern::PerRow { keep: 7 });
        let mut be = InterpBackend::new();
        be.compile(&entry).unwrap();
        let bufs = [
            be.upload(&TensorData::from_matrix(&w)).unwrap(),
            be.upload(&TensorData::from_matrix(&mask)).unwrap(),
            be.upload(&TensorData::from_matrix(&g)).unwrap(),
        ];
        let refs: Vec<&TensorData> = bufs.iter().collect();
        let out = be.execute(&entry, &refs).unwrap();
        let losses = out[0].as_f32().unwrap();
        let native =
            crate::pruning::error::layer_row_losses(&w, &mask, &g);
        for r in 0..chunk {
            assert!((losses[r] as f64 - native[r]).abs()
                    / native[r].abs().max(1.0) < 1e-5, "row {r}");
        }
    }

    #[test]
    fn interp_rejects_model_kind_without_meta() {
        // A model-execution entry that never resolved its config (the
        // typed constructors and `Manifest::load` always attach one)
        // must fail at compile, not mid-execution.
        let mut be = InterpBackend::new();
        let mut entry = crate::runtime::manifest::ArtifactEntry::layer_loss(
            8, 4);
        entry.kind = "calib_step".into();
        assert!(be.compile(&entry).is_err());
        entry.kind = "frobnicate".into();
        assert!(be.compile(&entry).is_err());
    }

    #[test]
    fn interp_eval_step_runs_through_backend() {
        let meta = crate::model::testutil::meta_for(8, 8, 2, 16, 1, 4, 2);
        let entry = crate::runtime::manifest::ArtifactEntry::eval_step(
            &meta);
        let store = crate::model::store::ParamStore::init(&meta, 3);
        let n = meta.batch * meta.seq_len;
        let toks = TensorData::I32 {
            dims: vec![meta.batch, meta.seq_len],
            data: (0..n).map(|i| (i % meta.vocab) as i32).collect(),
        };
        let mut be = InterpBackend::new();
        assert!(be.compile(&entry).unwrap());
        let mut bufs: Vec<TensorData> = store.tensors.iter()
            .map(|t| be.upload(t).unwrap())
            .collect();
        bufs.push(be.upload(&toks).unwrap());
        bufs.push(be.upload(&toks).unwrap());
        let refs: Vec<&TensorData> = bufs.iter().collect();
        let out = be.execute(&entry, &refs).unwrap();
        assert_eq!(out.len(), 2);
        let nll = out[0].scalar_value().unwrap();
        let count = out[1].scalar_value().unwrap();
        assert_eq!(count, n as f64);
        assert!(nll.is_finite() && nll > 0.0);
        // Mean NLL of a random-init model sits near ln(vocab).
        let mean = nll / count;
        assert!((mean - (meta.vocab as f64).ln()).abs() < 1.5,
                "mean nll {mean}");
    }

    #[test]
    fn swap_step_entry_naming_matches_manifest_scheme() {
        let e = crate::runtime::manifest::ArtifactEntry::swap_step(
            64, 128, "nm2_4", 4, "interp", 8);
        assert_eq!(e.name,
                   Manifest::swap_artifact_name(64, "nm2_4", "interp", 8));
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.outputs.len(), 4);
        assert_eq!(e.inputs[2].dims, vec![64, 64]);
        assert_eq!(e.outputs[0].dims, vec![128, 64]);
    }
}
