//! [`RuntimePool`]: N independent runtime service workers behind a
//! work-stealing dispatch queue.
//!
//! The paper's 1-swap refinement is embarrassingly parallel across
//! rows *and* layers; a single `runtime::Runtime` serialises the
//! offload path because one service thread owns the device.  The pool
//! starts `devices` workers (each its own service thread, compiled
//! executables, and device-buffer cache — no shared mutable state;
//! the parsed manifest is shared immutably) and dispatches per-layer
//! jobs across them:
//!
//!   * every worker has its own deque; [`RuntimePool::submit`]
//!     round-robins, [`RuntimePool::submit_to`] pins;
//!   * an idle worker first drains its own deque (FIFO), then steals
//!     from the other deques' tails, so an unbalanced block (one slow
//!     layer) never strands the remaining workers;
//!   * jobs receive `&Runtime` for *their* worker, so every artifact
//!     execution a job issues lands on that worker's device.
//!
//! Determinism: scheduling moves whole layers between identical
//! workers and per-layer refinement depends only on its inputs, so
//! pooled masks are bit-identical to the serial schedule (property-
//! tested in `tests/runtime_pool.rs`; gated in the bench smoke CI
//! job).
//!
//! Fault tolerance: the pool tracks per-worker consecutive-failure
//! streaks ([`RuntimePool::report_worker_outcome`], fed by the shard
//! scheduler) and **quarantines** a worker after
//! [`DEFAULT_QUARANTINE_AFTER`] failures in a row — it stops popping
//! or stealing work, placement redirects around it, and its deque
//! drains to the survivors through the normal steal path.  If *every*
//! worker ends up quarantined the dispatchers keep draining anyway
//! (jobs fail fast on the dead runtimes and report back through the
//! scheduler), so scoped batches always terminate and the caller gets
//! a clean all-quarantined error instead of a deadlock.  Recovery
//! counters surface through [`RuntimePool::stats_total`]
//! (`shard_retries`, `workers_quarantined`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::runtime::backend::DefaultBackend;
use crate::runtime::faults::{FaultPlan, FaultyBackend};
use crate::runtime::manifest::Manifest;
use crate::runtime::service::{
    Runtime, RuntimeError, RuntimeOptions, ServiceStats,
};

type Job = Box<dyn FnOnce(&Runtime) + Send + 'static>;

/// Consecutive shard failures on one worker before it is quarantined
/// (tunable via [`RuntimePool::set_quarantine_after`]; 0 disables).
pub const DEFAULT_QUARANTINE_AFTER: u64 = 2;

/// Lock recovering from poisoning.  Every critical section in this
/// module performs single-step mutations (push/pop/counter bump) that
/// leave the guarded state valid at every instant, and job panics are
/// contained by `catch_unwind` before they can unwind through one —
/// so a poisoned lock only means *some* thread panicked elsewhere,
/// never that the data is torn.  Propagating the poison would wedge
/// every surviving worker instead of just the thread that died.
fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct PoolState {
    /// One deque per worker: owner pops the front, thieves the back.
    queues: Vec<Mutex<VecDeque<Job>>>,
    /// Submission sequence number — the wakeup protocol.  Bumped
    /// under this mutex on every enqueue (and once at shutdown), with
    /// `work_cv` notified while it is held.  A dispatcher reads the
    /// counter *before* sweeping the queues and re-checks it under
    /// the same mutex before sleeping: if any submit landed during
    /// the sweep the counter moved, the wait is skipped, and the
    /// sweep re-runs — so a wakeup can never be lost and idle workers
    /// block indefinitely instead of polling on a timeout.
    work_seq: Mutex<u64>,
    work_cv: Condvar,
    pending: Mutex<usize>,
    done_cv: Condvar,
    shutdown: AtomicBool,
    steals: AtomicU64,
    ran: Vec<AtomicU64>,
    /// Nanoseconds each dispatcher spent executing jobs (load-balance
    /// diagnostic: max/mean across workers is the shard bench's
    /// imbalance metric).
    busy: Vec<AtomicU64>,
    /// Empty sweeps per dispatcher (each one leads to a blocking
    /// wait).  A parked pool accrues none — asserted by the
    /// no-busy-wakeup test; the old 5 ms timed wait woke ~200x/s.
    idle_sweeps: Vec<AtomicU64>,
    /// Quarantined workers take no new work (see module docs).
    quarantined: Vec<AtomicBool>,
    /// Consecutive shard failures per worker; success resets.
    fail_streak: Vec<AtomicU64>,
    /// Failure streak that trips quarantine (0 = never).
    quarantine_after: AtomicU64,
    /// Shard dispatches re-run after a transient failure (bumped by
    /// the scheduler via [`RuntimePool::note_shard_retry`]).
    shard_retries: AtomicU64,
}

impl PoolState {
    fn is_quarantined(&self, w: usize) -> bool {
        self.quarantined[w].load(Ordering::Relaxed)
    }

    fn all_quarantined(&self) -> bool {
        self.quarantined.iter().all(|q| q.load(Ordering::Relaxed))
    }

    /// Record one shard outcome on `worker`; out-of-range ids (the
    /// scheduler's unknown-worker sentinel) are ignored.
    fn report(&self, worker: usize, ok: bool) {
        let Some(streak) = self.fail_streak.get(worker) else {
            return;
        };
        if ok {
            streak.store(0, Ordering::Relaxed);
            return;
        }
        let failures = streak.fetch_add(1, Ordering::Relaxed) + 1;
        let k = self.quarantine_after.load(Ordering::Relaxed);
        if k > 0
            && failures >= k
            && !self.quarantined[worker].swap(true, Ordering::Relaxed)
        {
            eprintln!(
                "runtime-pool: quarantining worker {worker} after \
                 {failures} consecutive failures");
            // Wake every dispatcher: survivors drain the quarantined
            // deque through the steal path (or the all-quarantined
            // escape hatch engages — see `dispatch_main`).
            let mut seq = relock(&self.work_seq);
            *seq += 1;
            self.work_cv.notify_all();
        }
    }
}

pub struct RuntimePool {
    runtimes: Vec<Runtime>,
    state: Arc<PoolState>,
    dispatchers: Vec<JoinHandle<()>>,
    next: AtomicUsize,
}

impl RuntimePool {
    /// Start `devices` service workers (min 1) over the artifact
    /// directory.  The manifest is parsed once; every worker owns its
    /// own compiled executables and device-buffer cache.
    pub fn start(artifact_dir: impl AsRef<std::path::Path>,
                 devices: usize, opts: RuntimeOptions)
        -> Result<RuntimePool, RuntimeError> {
        let devices = devices.max(1);
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        // One shared compile cache per pool: the first worker to
        // compile an artifact exports the executable, later workers
        // import it instead of recompiling
        // (`ServiceStats::compiles_shared`).
        let opts = opts.with_shared_compile_cache();
        let mut runtimes = Vec::with_capacity(devices);
        for device in 0..devices {
            runtimes.push(Runtime::start_with_backend(
                Arc::clone(&manifest),
                DefaultBackend::new_default,
                RuntimeOptions { device, ..opts.clone() })?);
        }
        Ok(Self::from_runtimes(runtimes))
    }

    /// Like [`RuntimePool::start`], wrapping every worker's backend in
    /// a [`FaultyBackend`] driving the given deterministic fault plan
    /// (the `--fault-plan` / `SPARSESWAPS_FAULTS` surface).
    pub fn start_with_faults(
        artifact_dir: impl AsRef<std::path::Path>, devices: usize,
        opts: RuntimeOptions, plan: FaultPlan)
        -> Result<RuntimePool, RuntimeError> {
        let devices = devices.max(1);
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        let opts = opts.with_shared_compile_cache();
        let mut runtimes = Vec::with_capacity(devices);
        for device in 0..devices {
            let plan = plan.clone();
            runtimes.push(Runtime::start_with_backend(
                Arc::clone(&manifest),
                move || Ok(FaultyBackend::new(
                    DefaultBackend::new_default()?, plan, device)),
                RuntimeOptions { device, ..opts.clone() })?);
        }
        Ok(Self::from_runtimes(runtimes))
    }

    /// Wrap externally constructed runtime handles (tests and benches
    /// inject interp- or mock-backed workers here; see
    /// `runtime::testutil`).
    pub fn from_runtimes(runtimes: Vec<Runtime>) -> RuntimePool {
        assert!(!runtimes.is_empty(), "pool needs at least one runtime");
        let n = runtimes.len();
        let state = Arc::new(PoolState {
            queues: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            work_seq: Mutex::new(0),
            work_cv: Condvar::new(),
            pending: Mutex::new(0),
            done_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            steals: AtomicU64::new(0),
            ran: (0..n).map(|_| AtomicU64::new(0)).collect(),
            busy: (0..n).map(|_| AtomicU64::new(0)).collect(),
            idle_sweeps: (0..n).map(|_| AtomicU64::new(0)).collect(),
            quarantined: (0..n).map(|_| AtomicBool::new(false))
                .collect(),
            fail_streak: (0..n).map(|_| AtomicU64::new(0)).collect(),
            quarantine_after: AtomicU64::new(DEFAULT_QUARANTINE_AFTER),
            shard_retries: AtomicU64::new(0),
        });
        let dispatchers = runtimes.iter().enumerate()
            .map(|(i, rt)| {
                let rt = rt.clone();
                let state = Arc::clone(&state);
                std::thread::Builder::new()
                    .name(format!("runtime-pool-{i}"))
                    .spawn(move || dispatch_main(i, rt, state))
                    .expect("spawn pool dispatcher")
            })
            .collect();
        RuntimePool {
            runtimes,
            state,
            dispatchers,
            next: AtomicUsize::new(0),
        }
    }

    pub fn devices(&self) -> usize {
        self.runtimes.len()
    }

    /// Worker 0's handle — the designated runtime for inherently
    /// serial stages (calibration, training, evaluation).  Also
    /// reachable through `Deref`, so a `&RuntimePool` coerces wherever
    /// a `&Runtime` is expected.
    pub fn primary(&self) -> &Runtime {
        &self.runtimes[0]
    }

    pub fn runtime(&self, i: usize) -> &Runtime {
        &self.runtimes[i]
    }

    /// Jobs moved between workers so far.
    pub fn steals(&self) -> u64 {
        self.state.steals.load(Ordering::Relaxed)
    }

    /// Jobs completed per worker (dispatch fairness diagnostics).
    pub fn jobs_run(&self) -> Vec<u64> {
        self.state.ran.iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Cumulative nanoseconds each worker spent executing jobs — the
    /// load-balance diagnostic behind the shard bench's imbalance
    /// metric (max/mean busy time across workers).
    pub fn busy_nanos(&self) -> Vec<u64> {
        self.state.busy.iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Empty queue sweeps per worker — every entry is one dispatcher
    /// iteration that found no job and went on to block on the
    /// condvar.  A fully parked pool accrues none over time (the old
    /// timed-wait dispatcher accrued ~200 per second per worker).
    pub fn idle_sweeps(&self) -> Vec<u64> {
        self.state.idle_sweeps.iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Per-worker service stats (device i at index i).  Named so it
    /// does not shadow `Runtime::stats()` through `Deref` — `.stats()`
    /// on a pool still reads the primary worker.
    pub fn worker_stats(&self) -> Vec<ServiceStats> {
        self.runtimes.iter().map(|r| r.stats()).collect()
    }

    /// All workers' counters folded together, plus the pool-level
    /// recovery counters (per-service stats leave those at 0).
    pub fn stats_total(&self) -> ServiceStats {
        let mut total = ServiceStats::default();
        for s in self.worker_stats() {
            total.merge(&s);
        }
        total.shard_retries = self.shard_retries();
        total.workers_quarantined = self.workers_quarantined();
        total
    }

    /// Consecutive-failure streak that trips quarantine (default
    /// [`DEFAULT_QUARANTINE_AFTER`]; 0 disables quarantine).
    pub fn set_quarantine_after(&self, k: u64) {
        self.state.quarantine_after.store(k, Ordering::Relaxed);
    }

    /// Record one shard outcome on `worker` (out-of-range ids — the
    /// scheduler's host/unknown sentinel — are ignored).  A success
    /// resets the worker's consecutive-failure streak; enough
    /// failures in a row quarantine it: the worker stops taking or
    /// stealing work and placement redirects around it, so its deque
    /// drains to the survivors.
    pub fn report_worker_outcome(&self, worker: usize, ok: bool) {
        self.state.report(worker, ok);
    }

    /// Indices of currently quarantined workers.
    pub fn quarantined_workers(&self) -> Vec<usize> {
        (0..self.devices())
            .filter(|&w| self.state.is_quarantined(w))
            .collect()
    }

    /// Number of currently quarantined workers.
    pub fn workers_quarantined(&self) -> u64 {
        self.quarantined_workers().len() as u64
    }

    /// Handles of every non-quarantined worker, in ascending worker
    /// order — the worker set pool-parallel phases (striped
    /// calibration, batched eval) fan over.  Falls back to the full
    /// worker set when everything is quarantined, mirroring
    /// `eligible_worker`'s escape hatch: the phase keeps draining and
    /// fails fast instead of deadlocking.
    pub fn healthy_runtimes(&self) -> Vec<Runtime> {
        let healthy: Vec<Runtime> = (0..self.devices())
            .filter(|&w| !self.state.is_quarantined(w))
            .map(|w| self.runtimes[w].clone())
            .collect();
        if healthy.is_empty() {
            self.runtimes.clone()
        } else {
            healthy
        }
    }

    /// Count one shard redispatch (surfaced via [`stats_total`]).
    ///
    /// [`stats_total`]: RuntimePool::stats_total
    pub fn note_shard_retry(&self) {
        self.state.shard_retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn shard_retries(&self) -> u64 {
        self.state.shard_retries.load(Ordering::Relaxed)
    }

    /// Placement target honoring quarantine: the first healthy worker
    /// at or after `preferred`.  With every worker quarantined the
    /// preferred target is kept — the dispatchers' escape hatch keeps
    /// draining (jobs fail fast) so batches cannot deadlock while the
    /// scheduler aborts the run.
    fn eligible_worker(&self, preferred: usize) -> usize {
        let n = self.devices();
        let p = preferred % n;
        for k in 0..n {
            let c = (p + k) % n;
            if !self.state.is_quarantined(c) {
                return c;
            }
        }
        p
    }

    fn enqueue(&self, worker: usize, job: Job) {
        *relock(&self.state.pending) += 1;
        let w = self.eligible_worker(worker);
        relock(&self.state.queues[w]).push_back(job);
        // Advance the submission counter under the wakeup mutex so a
        // dispatcher mid-sweep re-checks instead of sleeping (see
        // `PoolState::work_seq`).
        let mut seq = relock(&self.state.work_seq);
        *seq += 1;
        self.state.work_cv.notify_all();
    }

    /// Submit one job to a specific worker's deque (still stealable
    /// by idle workers — that is the point of the test hook).
    pub fn submit_to<F>(&self, worker: usize, f: F)
    where
        F: FnOnce(&Runtime) + Send + 'static,
    {
        self.enqueue(worker, Box::new(f));
    }

    /// Round-robin submit.
    pub fn submit<F>(&self, f: F)
    where
        F: FnOnce(&Runtime) + Send + 'static,
    {
        let w = self.next.fetch_add(1, Ordering::Relaxed)
            % self.devices();
        self.enqueue(w, Box::new(f));
    }

    /// Block until every submitted job has finished.
    pub fn wait(&self) {
        let mut cnt = relock(&self.state.pending);
        while *cnt > 0 {
            cnt = self.state.done_cv.wait(cnt)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Run a batch of *borrowing* jobs to completion on the pool
    /// (scoped fork/join), the same contract as
    /// `ThreadPool::run_scoped`: submits every job round-robin, then
    /// blocks until all of *this batch* has finished, so jobs may
    /// capture non-`'static` references (zero-copy Gram views into
    /// block calibration state).  Completion is tracked per batch,
    /// not pool-wide, so concurrent scoped callers never convoy on
    /// each other's jobs.
    pub fn run_scoped<'env>(
        &self,
        jobs: Vec<Box<dyn FnOnce(&Runtime) + Send + 'env>>,
    ) {
        self.run_scoped_avoiding(jobs, &[]);
    }

    /// [`run_scoped`] with a placement hint: jobs are spread over the
    /// healthy workers *not* listed in `avoid` — the shard scheduler's
    /// retry-on-a-different-worker path.  Best effort on two counts:
    /// with no other healthy worker the hint is dropped rather than
    /// failing, and an idle avoided worker may still *steal* the job
    /// (benign: results are bit-identical on any worker; the hint
    /// only dodges likely-unhealthy ones).
    ///
    /// [`run_scoped`]: RuntimePool::run_scoped
    pub fn run_scoped_avoiding<'env>(
        &self,
        jobs: Vec<Box<dyn FnOnce(&Runtime) + Send + 'env>>,
        avoid: &[usize],
    ) {
        let n = self.devices();
        let healthy: Vec<usize> = (0..n)
            .filter(|&w| !self.state.is_quarantined(w))
            .collect();
        let preferred: Vec<usize> = healthy.iter().copied()
            .filter(|w| !avoid.contains(w))
            .collect();
        let targets: Vec<usize> = if !preferred.is_empty() {
            preferred
        } else if !healthy.is_empty() {
            healthy
        } else {
            (0..n).collect()
        };
        // Batch-local completion count, decremented by a drop guard
        // so a panicking job (contained by its dispatcher) still
        // counts down and the wait below cannot hang.
        struct BatchGuard(Arc<(Mutex<usize>, Condvar)>);
        impl Drop for BatchGuard {
            fn drop(&mut self) {
                let (lock, cv) = &*self.0;
                // Recover from poisoning: the count stays valid (the
                // only mutation is this decrement) and refusing would
                // hang the batch wait below forever.
                let mut cnt = relock(lock);
                *cnt -= 1;
                if *cnt == 0 {
                    cv.notify_all();
                }
            }
        }
        let batch = Arc::new((Mutex::new(jobs.len()), Condvar::new()));
        for job in jobs {
            // SAFETY: the batch wait below blocks until every job
            // submitted here has completed (dispatcher panics are
            // contained and the drop guard still counts down), so no
            // job — and therefore no borrow it captures — outlives
            // 'env.
            let job: Job = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce(&Runtime) + Send + 'env>, Job>(job)
            };
            let guard = BatchGuard(Arc::clone(&batch));
            let wrapped: Job = Box::new(move |rt: &Runtime| {
                let _guard = guard;
                job(rt);
            });
            let w = targets[self.next.fetch_add(1, Ordering::Relaxed)
                            % targets.len()];
            self.enqueue(w, wrapped);
        }
        let (lock, cv) = &*batch;
        let mut cnt = relock(lock);
        while *cnt > 0 {
            cnt = cv.wait(cnt).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// The pool dereferences to its primary worker, so serial call sites
/// (`train(&pool, ..)`, `perplexity(&pool, ..)`) keep compiling
/// unchanged while pooled scheduling stays explicit.
impl std::ops::Deref for RuntimePool {
    type Target = Runtime;

    fn deref(&self) -> &Runtime {
        self.primary()
    }
}

impl Drop for RuntimePool {
    fn drop(&mut self) {
        self.wait();
        self.state.shutdown.store(true, Ordering::Release);
        {
            // Bump the counter too: a dispatcher between its sweep
            // and its wait skips the sleep and re-checks `shutdown`.
            let mut seq = relock(&self.state.work_seq);
            *seq += 1;
            self.state.work_cv.notify_all();
        }
        for h in self.dispatchers.drain(..) {
            let _ = h.join();
        }
        // Worker runtimes shut down via their own guards.
    }
}

fn dispatch_main(me: usize, rt: Runtime, state: Arc<PoolState>) {
    let n = state.queues.len();
    loop {
        // Snapshot the submission counter *before* sweeping: any
        // submit that lands mid-sweep moves it, and the pre-sleep
        // re-check below turns the would-be lost wakeup into another
        // sweep.
        let seq_before = *relock(&state.work_seq);
        // A quarantined dispatcher takes no work — not even its own
        // deque, which drains to the survivors through their steal
        // path.  Escape hatch: with EVERY worker quarantined it keeps
        // draining anyway (jobs fail fast on the dead runtime and
        // report back), so scoped batches still terminate and the
        // scheduler aborts with a clean all-quarantined error instead
        // of deadlocking.
        let sidelined =
            state.is_quarantined(me) && !state.all_quarantined();
        // Own queue first (FIFO), then steal from the other deques'
        // tails.
        let mut job = if sidelined {
            None
        } else {
            relock(&state.queues[me]).pop_front()
        };
        if job.is_none() && !sidelined {
            for k in 1..n {
                let victim = (me + k) % n;
                job = relock(&state.queues[victim]).pop_back();
                if job.is_some() {
                    state.steals.fetch_add(1, Ordering::Relaxed);
                    break;
                }
            }
        }
        match job {
            Some(job) => {
                // Contain panics so a failing job can neither kill the
                // dispatcher nor leave the pending counter stuck.
                let t0 = std::time::Instant::now();
                let result = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| job(&rt)));
                if result.is_err() {
                    // A panicked job never reaches the scheduler's
                    // outcome report, so count the failure here for
                    // quarantine purposes.
                    state.report(me, false);
                }
                state.busy[me].fetch_add(
                    t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                state.ran[me].fetch_add(1, Ordering::Relaxed);
                let mut cnt = relock(&state.pending);
                *cnt -= 1;
                if *cnt == 0 {
                    state.done_cv.notify_all();
                }
            }
            None => {
                if state.shutdown.load(Ordering::Acquire) {
                    break;
                }
                state.idle_sweeps[me].fetch_add(1, Ordering::Relaxed);
                // Block until the next submit (or shutdown).  The
                // counter re-check under the mutex closes the race
                // with a submit that slipped in after the sweep; a
                // spurious wake just falls through to another sweep.
                let guard = relock(&state.work_seq);
                if *guard == seq_before
                    && !state.shutdown.load(Ordering::Acquire) {
                    drop(state.work_cv.wait(guard)
                        .unwrap_or_else(|e| e.into_inner()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::InterpBackend;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    fn empty_pool(n: usize) -> RuntimePool {
        let manifest = Arc::new(Manifest {
            dir: std::path::PathBuf::from("."),
            configs: Default::default(),
            artifacts: Default::default(),
        });
        let runtimes = (0..n)
            .map(|device| Runtime::start_with_backend(
                Arc::clone(&manifest),
                InterpBackend::new_default,
                RuntimeOptions { device,
                                 ..RuntimeOptions::default() })
                .unwrap())
            .collect();
        RuntimePool::from_runtimes(runtimes)
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = empty_pool(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.submit(move |_rt| {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 50);
        assert_eq!(pool.jobs_run().iter().sum::<u64>(), 50);
    }

    #[test]
    fn wait_is_reusable() {
        let pool = empty_pool(2);
        let counter = Arc::new(AtomicU64::new(0));
        for round in 0..3u64 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move |_rt| {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait();
            assert_eq!(counter.load(Ordering::Relaxed),
                       10 * (round + 1));
        }
    }

    #[test]
    fn idle_workers_steal_a_pinned_queue() {
        let pool = empty_pool(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..24 {
            let c = Arc::clone(&counter);
            pool.submit_to(0, move |_rt| {
                std::thread::sleep(Duration::from_millis(2));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 24);
        assert!(pool.steals() > 0,
                "idle workers must steal from the pinned queue");
    }

    #[test]
    fn jobs_see_their_workers_runtime() {
        let pool = empty_pool(3);
        let seen = Arc::new(Mutex::new(std::collections::BTreeSet::new()));
        for _ in 0..30 {
            let seen = Arc::clone(&seen);
            pool.submit(move |rt| {
                std::thread::sleep(Duration::from_millis(1));
                seen.lock().unwrap().insert(rt.device());
            });
        }
        pool.wait();
        let seen = seen.lock().unwrap();
        assert!(!seen.is_empty());
        assert!(seen.iter().all(|&d| d < 3));
    }

    #[test]
    fn run_scoped_allows_borrowed_jobs() {
        let pool = empty_pool(3);
        let data: Vec<u64> = (0..100).collect();
        let total = AtomicU64::new(0);
        {
            let data = &data;
            let total = &total;
            let jobs: Vec<Box<dyn FnOnce(&Runtime) + Send + '_>> =
                (0..4)
                    .map(|t| {
                        Box::new(move |_rt: &Runtime| {
                            let s: u64 = data.iter()
                                .skip(t)
                                .step_by(4)
                                .sum();
                            total.fetch_add(s, Ordering::Relaxed);
                        })
                            as Box<dyn FnOnce(&Runtime) + Send + '_>
                    })
                    .collect();
            pool.run_scoped(jobs);
        }
        assert_eq!(total.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = empty_pool(2);
        let counter = Arc::new(AtomicU64::new(0));
        pool.submit(|_rt| panic!("job failure"));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move |_rt| {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn idle_workers_block_instead_of_polling() {
        let pool = empty_pool(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..6 {
            let c = Arc::clone(&counter);
            pool.submit(move |_rt| {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 6);
        // Give every dispatcher time to finish its post-batch empty
        // sweep and park on the condvar.
        std::thread::sleep(Duration::from_millis(50));
        let before: u64 = pool.idle_sweeps().iter().sum();
        std::thread::sleep(Duration::from_millis(300));
        let after: u64 = pool.idle_sweeps().iter().sum();
        // A parked pool must not wake at all; the old 5 ms timed wait
        // accrued ~60 sweeps per worker over this window.  Allow a
        // tiny slack for stray spurious condvar wakeups.
        assert!(after - before <= 3,
                "dispatchers busy-woke {} times while parked",
                after - before);
        // And they must still wake correctly for new work afterwards.
        let c = Arc::clone(&counter);
        pool.submit(move |_rt| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn quarantined_worker_jobs_complete_on_survivors() {
        let pool = empty_pool(2);
        pool.set_quarantine_after(1);
        pool.report_worker_outcome(0, false);
        assert_eq!(pool.quarantined_workers(), vec![0]);
        assert_eq!(pool.workers_quarantined(), 1);
        let seen = Arc::new(Mutex::new(Vec::new()));
        for _ in 0..8 {
            let seen = Arc::clone(&seen);
            // Pin to the quarantined worker: placement must redirect
            // (and any job that still lands in deque 0 must drain via
            // the survivor's steal path).
            pool.submit_to(0, move |rt| {
                seen.lock().unwrap().push(rt.device());
            });
        }
        pool.wait();
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 8);
        assert!(seen.iter().all(|&d| d == 1),
                "jobs ran on quarantined worker: {:?}", *seen);
    }

    #[test]
    fn all_workers_quarantined_still_drains_scoped_batches() {
        let pool = empty_pool(2);
        pool.set_quarantine_after(1);
        pool.report_worker_outcome(0, false);
        pool.report_worker_outcome(1, false);
        assert_eq!(pool.workers_quarantined(), 2);
        // Escape hatch: with nobody healthy the dispatchers keep
        // draining so batches terminate instead of deadlocking.
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move |_rt| {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn success_resets_failure_streak() {
        let pool = empty_pool(2);
        pool.set_quarantine_after(2);
        pool.report_worker_outcome(0, false);
        pool.report_worker_outcome(0, true);
        pool.report_worker_outcome(0, false);
        assert!(pool.quarantined_workers().is_empty(),
                "interleaved success must reset the streak");
        pool.report_worker_outcome(0, false);
        assert_eq!(pool.quarantined_workers(), vec![0]);
        // The scheduler's unknown-worker sentinel is a no-op.
        pool.report_worker_outcome(usize::MAX, false);
        assert_eq!(pool.workers_quarantined(), 1);
    }

    #[test]
    fn stats_and_pool_survive_poisoned_locks() {
        let pool = empty_pool(2);
        pool.note_shard_retry();
        // Poison the two hottest locks by panicking while holding
        // their guards; `relock` recovery must keep the pool live.
        for _ in 0..2 {
            let state = Arc::clone(&pool.state);
            let _ = std::thread::spawn(move || {
                let _g1 = state.pending.lock().unwrap();
                let _g2 = state.work_seq.lock().unwrap();
                panic!("poison pool locks");
            })
            .join();
        }
        assert!(pool.state.pending.is_poisoned());
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.submit(move |_rt| {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 8);
        assert_eq!(pool.stats_total().shard_retries, 1);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = empty_pool(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..12 {
            let c = Arc::clone(&counter);
            pool.submit(move |_rt| {
                std::thread::sleep(Duration::from_millis(1));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 12);
    }
}
