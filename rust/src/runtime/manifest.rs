//! `artifacts/manifest.json` — the contract between the python AOT
//! compiler and this runtime.  Rust derives *no* shapes on its own: the
//! manifest carries every artifact's input/output signature and the full
//! model-config metadata (flat parameter order, prunable layers, Gram
//! stream mapping, swap chunk sizes).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::jsonlite::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType, String> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(format!("unsupported dtype {other:?}")),
        }
    }

    pub fn byte_size(&self) -> usize {
        4
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub dims: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSig, String> {
        let dims = v.get("dims").and_then(Json::as_arr)
            .ok_or("missing dims")?
            .iter()
            .map(|d| d.as_usize().ok_or("bad dim"))
            .collect::<Result<Vec<_>, _>>()?;
        let dtype = DType::parse(
            v.get("dtype").and_then(Json::as_str).ok_or("missing dtype")?)?;
        Ok(TensorSig { dims, dtype })
    }
}

/// Every artifact kind the runtime understands.  `Manifest::load`
/// rejects anything else at parse time — an unknown or missing kind
/// used to default to `""` and only surface later as an opaque
/// backend "unsupported kind" error.
pub const ARTIFACT_KINDS: [&str; 8] = [
    "swap_step", "layer_loss", "calib_step", "calib_block", "embed",
    "eval_step", "seq_nll", "train_step",
];

/// The subset of [`ARTIFACT_KINDS`] that executes the model itself
/// and therefore needs a resolvable `config` (a [`ModelMeta`]).
pub const MODEL_KINDS: [&str; 6] = [
    "calib_step", "calib_block", "embed", "eval_step", "seq_nll",
    "train_step",
];

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub kind: String,
    /// swap_step / layer_loss metadata (0 when absent).
    pub width: usize,
    pub chunk_rows: usize,
    pub nm_block: usize,
    pub k_iters: usize,
    pub impl_name: String,
    pub pattern: String,
    pub config: String,
    /// Resolved model config for the model-execution kinds
    /// ([`MODEL_KINDS`]); `None` for the refinement kinds.  Attached
    /// at parse time so backends can interpret the artifact without a
    /// manifest handle.
    pub model: Option<ModelMeta>,
}

impl ArtifactEntry {
    /// The swap-step signature contract, in one place: inputs
    /// (w [chunk, d], mask [chunk, d], gram [d, d]) and outputs
    /// (mask [chunk, d], loss_before [chunk], loss_after [chunk],
    /// swaps [chunk]), all f32.  Used by `runtime::testutil` to
    /// fabricate interp-executable manifests and by the integrity
    /// checks against the python AOT output.
    pub fn swap_step(width: usize, chunk_rows: usize, pattern_tag: &str,
                     nm_block: usize, impl_name: &str, k: usize)
        -> ArtifactEntry {
        let name = Manifest::swap_artifact_name(width, pattern_tag,
                                                impl_name, k);
        let mat = TensorSig { dims: vec![chunk_rows, width],
                              dtype: DType::F32 };
        let gram = TensorSig { dims: vec![width, width],
                               dtype: DType::F32 };
        let col = TensorSig { dims: vec![chunk_rows], dtype: DType::F32 };
        ArtifactEntry {
            file: PathBuf::from(format!("{name}.hlo.txt")),
            name,
            inputs: vec![mat.clone(), mat.clone(), gram],
            outputs: vec![mat, col.clone(), col.clone(), col],
            kind: "swap_step".into(),
            width,
            chunk_rows,
            nm_block,
            k_iters: k,
            impl_name: impl_name.into(),
            pattern: pattern_tag.into(),
            config: String::new(),
            model: None,
        }
    }

    /// The layer-loss signature contract: inputs (w, mask, gram) as in
    /// [`Self::swap_step`], one output (loss [chunk]).
    pub fn layer_loss(width: usize, chunk_rows: usize) -> ArtifactEntry {
        let name = Manifest::layer_loss_name(width);
        let mat = TensorSig { dims: vec![chunk_rows, width],
                              dtype: DType::F32 };
        let gram = TensorSig { dims: vec![width, width],
                               dtype: DType::F32 };
        let col = TensorSig { dims: vec![chunk_rows], dtype: DType::F32 };
        ArtifactEntry {
            file: PathBuf::from(format!("{name}.hlo.txt")),
            name,
            inputs: vec![mat.clone(), mat, gram],
            outputs: vec![col],
            kind: "layer_loss".into(),
            width,
            chunk_rows,
            nm_block: 0,
            k_iters: 0,
            impl_name: String::new(),
            pattern: String::new(),
            config: String::new(),
            model: None,
        }
    }

    /// Shared shell of the four model-execution artifact entries.
    fn model_entry(kind: &str, meta: &ModelMeta, inputs: Vec<TensorSig>,
                   outputs: Vec<TensorSig>) -> ArtifactEntry {
        let name = format!("{kind}_{}", meta.name);
        ArtifactEntry {
            file: PathBuf::from(format!("{name}.hlo.txt")),
            name,
            inputs,
            outputs,
            kind: kind.into(),
            width: 0,
            chunk_rows: 0,
            nm_block: 0,
            k_iters: 0,
            impl_name: String::new(),
            pattern: String::new(),
            config: meta.name.clone(),
            model: Some(meta.clone()),
        }
    }

    /// The train-step signature contract, mirroring
    /// `coordinator::trainer::train`: inputs (params.., m.., v..,
    /// step i32 [], tokens [b, l] i32, targets [b, l] i32, lr f32 [])
    /// and outputs (params.., m.., v.., step i32 [], loss f32 []).
    pub fn train_step(meta: &ModelMeta) -> ArtifactEntry {
        let p = param_sigs(meta);
        let mut inputs = Vec::with_capacity(3 * p.len() + 4);
        inputs.extend(p.iter().cloned());
        inputs.extend(p.iter().cloned());
        inputs.extend(p.iter().cloned());
        inputs.push(scalar_sig(DType::I32));
        inputs.push(tokens_sig(meta));
        inputs.push(tokens_sig(meta));
        inputs.push(scalar_sig(DType::F32));
        let mut outputs = Vec::with_capacity(3 * p.len() + 2);
        outputs.extend(p.iter().cloned());
        outputs.extend(p.iter().cloned());
        outputs.extend(p);
        outputs.push(scalar_sig(DType::I32));
        outputs.push(scalar_sig(DType::F32));
        Self::model_entry("train_step", meta, inputs, outputs)
    }

    /// The eval-step contract (`eval::perplexity`): inputs (params..,
    /// tokens, targets), outputs (summed NLL f32 [], token count
    /// f32 []).
    pub fn eval_step(meta: &ModelMeta) -> ArtifactEntry {
        let mut inputs = param_sigs(meta);
        inputs.push(tokens_sig(meta));
        inputs.push(tokens_sig(meta));
        let outputs = vec![scalar_sig(DType::F32),
                           scalar_sig(DType::F32)];
        Self::model_entry("eval_step", meta, inputs, outputs)
    }

    /// The seq-nll contract (`eval::zeroshot`): inputs (params..,
    /// tokens, targets, mask f32 [b, l]), one output (per-row masked
    /// NLL f32 [b]).
    pub fn seq_nll(meta: &ModelMeta) -> ArtifactEntry {
        let mut inputs = param_sigs(meta);
        inputs.push(tokens_sig(meta));
        inputs.push(tokens_sig(meta));
        inputs.push(TensorSig {
            dims: vec![meta.batch, meta.seq_len],
            dtype: DType::F32,
        });
        let outputs = vec![TensorSig { dims: vec![meta.batch],
                                       dtype: DType::F32 }];
        Self::model_entry("seq_nll", meta, inputs, outputs)
    }

    /// The calib-step contract (`gram::GramStats`): inputs (params..,
    /// tokens, four Gram stacks [n_blocks, d, d], four feature-sum
    /// stacks [n_blocks, d]) and the same eight stat tensors as
    /// outputs, in `gram::STREAMS` order (qkv, o, gu, down).
    pub fn calib_step(meta: &ModelMeta) -> ArtifactEntry {
        let widths = [meta.d_model, meta.d_model, meta.d_model,
                      meta.d_ff];
        let mut inputs = param_sigs(meta);
        inputs.push(tokens_sig(meta));
        let mut stats = Vec::with_capacity(8);
        for d in widths {
            stats.push(TensorSig { dims: vec![meta.n_blocks, d, d],
                                   dtype: DType::F32 });
        }
        for d in widths {
            stats.push(TensorSig { dims: vec![meta.n_blocks, d],
                                   dtype: DType::F32 });
        }
        inputs.extend(stats.iter().cloned());
        Self::model_entry("calib_step", meta, inputs, stats)
    }

    /// The embed contract (streamed calibration, stage 0): inputs
    /// (tok_emb [vocab, d_model], tokens [b, l] i32), one output — the
    /// flattened token embeddings h [b*l, d_model].
    pub fn embed(meta: &ModelMeta) -> ArtifactEntry {
        let inputs = vec![
            TensorSig { dims: meta.params[0].1.clone(),
                        dtype: DType::F32 },
            tokens_sig(meta),
        ];
        let outputs = vec![h_sig(meta)];
        Self::model_entry("embed", meta, inputs, outputs)
    }

    /// The per-block calib contract (streamed calibration): inputs
    /// (the block's nine param tensors in manifest order, h_in
    /// [b*l, d_model], accum i32 [] — 1 accumulates the Gram streams,
    /// 0 only propagates — four per-block Grams [d, d] and four
    /// feature sums [d] in `gram::STREAMS` order) and outputs (the
    /// four Grams, the four sums, h_out [b*l, d_model]).  One
    /// artifact serves every block: all blocks share shapes.
    pub fn calib_block(meta: &ModelMeta) -> ArtifactEntry {
        let widths = [meta.d_model, meta.d_model, meta.d_model,
                      meta.d_ff];
        let mut inputs: Vec<TensorSig> = meta.params[1..10].iter()
            .map(|(_, dims)| TensorSig { dims: dims.clone(),
                                         dtype: DType::F32 })
            .collect();
        inputs.push(h_sig(meta));
        inputs.push(scalar_sig(DType::I32));
        let mut stats = Vec::with_capacity(8);
        for d in widths {
            stats.push(TensorSig { dims: vec![d, d],
                                   dtype: DType::F32 });
        }
        for d in widths {
            stats.push(TensorSig { dims: vec![d], dtype: DType::F32 });
        }
        inputs.extend(stats.iter().cloned());
        let mut outputs = stats;
        outputs.push(h_sig(meta));
        Self::model_entry("calib_block", meta, inputs, outputs)
    }
}

/// Residual-stream activation signature [b*l, d_model] shared by the
/// streamed-calibration artifacts.
fn h_sig(meta: &ModelMeta) -> TensorSig {
    TensorSig {
        dims: vec![meta.batch * meta.seq_len, meta.d_model],
        dtype: DType::F32,
    }
}

/// One [`TensorSig`] per manifest parameter, in order — the
/// `ParamStore::tensor_args` prefix every model artifact consumes.
fn param_sigs(meta: &ModelMeta) -> Vec<TensorSig> {
    meta.params.iter()
        .map(|(_, dims)| TensorSig { dims: dims.clone(),
                                     dtype: DType::F32 })
        .collect()
}

fn tokens_sig(meta: &ModelMeta) -> TensorSig {
    TensorSig { dims: vec![meta.batch, meta.seq_len],
                dtype: DType::I32 }
}

fn scalar_sig(dtype: DType) -> TensorSig {
    TensorSig { dims: vec![], dtype }
}

#[derive(Clone, Debug)]
pub struct PrunableLayer {
    pub param_index: usize,
    pub name: String,
    pub layer_type: String,
    pub block: usize,
    pub d_out: usize,
    pub d_in: usize,
    pub stream: String,
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_blocks: usize,
    pub seq_len: usize,
    pub batch: usize,
    /// RoPE base frequency (python `ModelConfig.rope_theta`).
    pub rope_theta: f64,
    pub init_seed: u64,
    /// Flat parameter list: (name, dims) in artifact argument order.
    pub params: Vec<(String, Vec<usize>)>,
    pub prunable: Vec<PrunableLayer>,
}

impl ModelMeta {
    pub fn param_count(&self) -> usize {
        self.params.len()
    }

    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq_len
    }

    /// Total number of weights in prunable layers.
    pub fn prunable_weight_count(&self) -> usize {
        self.prunable.iter().map(|p| p.d_out * p.d_in).sum()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ModelMeta>,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

fn get_usize(v: &Json, key: &str) -> Result<usize, String> {
    v.get(key).and_then(Json::as_usize)
        .ok_or_else(|| format!("missing/invalid {key}"))
}

fn get_str(v: &Json, key: &str) -> Option<String> {
    v.get(key).and_then(Json::as_str).map(str::to_string)
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let root = Json::parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(&root, dir)
    }

    pub fn from_json(root: &Json, dir: PathBuf) -> Result<Manifest, String> {
        let mut configs = BTreeMap::new();
        for (name, cv) in root.get("configs").and_then(Json::as_obj)
            .ok_or("missing configs")? {
            let params = cv.get("params").and_then(Json::as_arr)
                .ok_or("missing params")?
                .iter()
                .map(|p| -> Result<_, String> {
                    let n = get_str(p, "name").ok_or("param name")?;
                    let dims = p.get("dims").and_then(Json::as_arr)
                        .ok_or("param dims")?
                        .iter()
                        .map(|d| d.as_usize().ok_or("bad dim".to_string()))
                        .collect::<Result<Vec<_>, _>>()?;
                    Ok((n, dims))
                })
                .collect::<Result<Vec<_>, String>>()?;
            let prunable = cv.get("prunable").and_then(Json::as_arr)
                .ok_or("missing prunable")?
                .iter()
                .map(|p| -> Result<_, String> {
                    Ok(PrunableLayer {
                        param_index: get_usize(p, "param_index")?,
                        name: get_str(p, "name").ok_or("name")?,
                        layer_type: get_str(p, "layer_type")
                            .ok_or("layer_type")?,
                        block: get_usize(p, "block")?,
                        d_out: get_usize(p, "d_out")?,
                        d_in: get_usize(p, "d_in")?,
                        stream: get_str(p, "stream").ok_or("stream")?,
                    })
                })
                .collect::<Result<Vec<_>, String>>()?;
            configs.insert(name.clone(), ModelMeta {
                name: name.clone(),
                vocab: get_usize(cv, "vocab")?,
                d_model: get_usize(cv, "d_model")?,
                n_heads: get_usize(cv, "n_heads")?,
                d_ff: get_usize(cv, "d_ff")?,
                n_blocks: get_usize(cv, "n_blocks")?,
                seq_len: get_usize(cv, "seq_len")?,
                batch: get_usize(cv, "batch")?,
                rope_theta: cv.get("rope_theta").and_then(Json::as_f64)
                    .unwrap_or(10000.0),
                init_seed: get_usize(cv, "init_seed")? as u64,
                params,
                prunable,
            });
        }

        let mut artifacts = BTreeMap::new();
        for (name, av) in root.get("artifacts").and_then(Json::as_obj)
            .ok_or("missing artifacts")? {
            let sigs = |key: &str| -> Result<Vec<TensorSig>, String> {
                av.get(key).and_then(Json::as_arr)
                    .ok_or_else(|| format!("missing {key}"))?
                    .iter()
                    .map(TensorSig::from_json)
                    .collect()
            };
            // A missing or typoed kind used to default to "" here and
            // only fail much later, inside a backend, as an opaque
            // "unsupported kind" execution error.  Catch it at parse
            // time, naming the artifact.
            let kind = get_str(av, "kind").ok_or_else(|| format!(
                "artifact {name:?}: missing kind (expected one of \
                 {ARTIFACT_KINDS:?})"))?;
            if !ARTIFACT_KINDS.contains(&kind.as_str()) {
                return Err(format!(
                    "artifact {name:?}: unknown kind {kind:?} (expected \
                     one of {ARTIFACT_KINDS:?})"));
            }
            let config = get_str(av, "config").unwrap_or_default();
            let model = if MODEL_KINDS.contains(&kind.as_str()) {
                if config.is_empty() {
                    return Err(format!(
                        "artifact {name:?}: kind {kind:?} requires a \
                         `config` naming its model"));
                }
                Some(configs.get(&config).cloned().ok_or_else(
                    || format!("artifact {name:?}: unknown model config \
                                {config:?}"))?)
            } else {
                None
            };
            artifacts.insert(name.clone(), ArtifactEntry {
                name: name.clone(),
                file: dir.join(get_str(av, "file").ok_or("file")?),
                inputs: sigs("inputs")?,
                outputs: sigs("outputs")?,
                kind,
                width: get_usize(av, "width").unwrap_or(0),
                chunk_rows: get_usize(av, "chunk_rows").unwrap_or(0),
                nm_block: get_usize(av, "nm_block").unwrap_or(0),
                k_iters: get_usize(av, "k_iters").unwrap_or(0),
                impl_name: get_str(av, "impl").unwrap_or_default(),
                pattern: get_str(av, "pattern").unwrap_or_default(),
                config,
                model,
            });
        }
        Ok(Manifest { dir, configs, artifacts })
    }

    pub fn config(&self, name: &str) -> Result<&ModelMeta, String> {
        self.configs.get(name)
            .ok_or_else(|| format!("unknown model config {name:?} \
                                    (have: {:?})",
                                   self.configs.keys().collect::<Vec<_>>()))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry, String> {
        self.artifacts.get(name)
            .ok_or_else(|| format!("unknown artifact {name:?}; run \
                                    `make artifacts`"))
    }

    /// Swap-step artifact name for (width, pattern tag, impl, k).
    pub fn swap_artifact_name(width: usize, pattern_tag: &str,
                              impl_name: &str, k: usize) -> String {
        format!("swap_step_d{width}_{pattern_tag}_{impl_name}_k{k}")
    }

    /// Layer-loss artifact name for a width.
    pub fn layer_loss_name(width: usize) -> String {
        format!("layer_loss_d{width}")
    }

    /// Pick the best available swap artifact: prefers the requested k,
    /// falls back to k=1.
    pub fn find_swap_artifact(&self, width: usize, pattern_tag: &str,
                              impl_name: &str, k: usize)
        -> Result<&ArtifactEntry, String> {
        let name = Self::swap_artifact_name(width, pattern_tag, impl_name,
                                            k);
        if let Some(a) = self.artifacts.get(&name) {
            return Ok(a);
        }
        self.artifact(&Self::swap_artifact_name(width, pattern_tag,
                                                impl_name, 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(r#"{
          "configs": {
            "tiny": {
              "vocab": 256, "d_model": 64, "n_heads": 2, "d_ff": 128,
              "n_blocks": 1, "seq_len": 32, "batch": 4, "rope_theta": 1e4,
              "init_seed": 7,
              "params": [
                {"name": "tok_emb", "dims": [256, 64]},
                {"name": "blocks.0.attn.q_proj", "dims": [64, 64]}
              ],
              "prunable": [
                {"param_index": 1, "name": "blocks.0.attn.q_proj",
                 "layer_type": "attn.q_proj", "block": 0,
                 "d_out": 64, "d_in": 64, "stream": "qkv"}
              ]
            }
          },
          "artifacts": {
            "swap_step_d64_row_xla_k1": {
              "file": "swap_step_d64_row_xla_k1.hlo.txt",
              "kind": "swap_step", "width": 64, "chunk_rows": 128,
              "pattern": "row", "nm_block": 0, "impl": "xla", "k_iters": 1,
              "inputs": [
                {"dims": [128, 64], "dtype": "float32"},
                {"dims": [128, 64], "dtype": "float32"},
                {"dims": [64, 64], "dtype": "float32"}
              ],
              "outputs": [
                {"dims": [128, 64], "dtype": "float32"},
                {"dims": [128], "dtype": "float32"},
                {"dims": [128], "dtype": "float32"},
                {"dims": [128], "dtype": "float32"}
              ]
            }
          }
        }"#).unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&sample_json(), PathBuf::from("/x"))
            .unwrap();
        let cfg = m.config("tiny").unwrap();
        assert_eq!(cfg.d_model, 64);
        assert_eq!(cfg.params.len(), 2);
        assert_eq!(cfg.prunable[0].stream, "qkv");
        let a = m.artifact("swap_step_d64_row_xla_k1").unwrap();
        assert_eq!(a.chunk_rows, 128);
        assert_eq!(a.inputs[2].dims, vec![64, 64]);
        assert_eq!(a.outputs.len(), 4);
        assert_eq!(a.inputs[0].dtype, DType::F32);
    }

    #[test]
    fn unknown_lookups_fail() {
        let m = Manifest::from_json(&sample_json(), PathBuf::from("/x"))
            .unwrap();
        assert!(m.config("nope").is_err());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn swap_fallback_to_k1() {
        let m = Manifest::from_json(&sample_json(), PathBuf::from("/x"))
            .unwrap();
        let a = m.find_swap_artifact(64, "row", "xla", 8).unwrap();
        assert_eq!(a.k_iters, 1);
    }

    fn artifact_json(kind_field: &str) -> Json {
        Json::parse(&format!(r#"{{
          "configs": {{}},
          "artifacts": {{
            "swap_step_d8_row_xla_k1": {{
              "file": "a.hlo.txt", {kind_field}
              "width": 8, "chunk_rows": 4,
              "inputs": [], "outputs": []
            }}
          }}
        }}"#)).unwrap()
    }

    #[test]
    fn missing_kind_is_a_parse_error() {
        let err = Manifest::from_json(&artifact_json(""),
                                      PathBuf::from("/x"))
            .unwrap_err();
        assert!(err.contains("swap_step_d8_row_xla_k1"), "{err}");
        assert!(err.contains("missing kind"), "{err}");
    }

    #[test]
    fn typoed_kind_is_a_parse_error() {
        let err = Manifest::from_json(
            &artifact_json(r#""kind": "swap_stpe","#),
            PathBuf::from("/x")).unwrap_err();
        assert!(err.contains("swap_step_d8_row_xla_k1"), "{err}");
        assert!(err.contains("swap_stpe"), "{err}");
    }

    #[test]
    fn model_kind_requires_known_config() {
        let json = Json::parse(r#"{
          "configs": {},
          "artifacts": {
            "eval_step_tiny": {
              "file": "e.hlo.txt", "kind": "eval_step",
              "config": "tiny", "inputs": [], "outputs": []
            }
          }
        }"#).unwrap();
        let err = Manifest::from_json(&json, PathBuf::from("/x"))
            .unwrap_err();
        assert!(err.contains("eval_step_tiny"), "{err}");
        assert!(err.contains("tiny"), "{err}");
    }

    #[test]
    fn model_entry_constructors_cover_contracts() {
        let meta = crate::model::testutil::tiny_meta();
        let np = meta.params.len();

        let t = ArtifactEntry::train_step(&meta);
        assert_eq!(t.name, "train_step_tiny");
        assert_eq!(t.inputs.len(), 3 * np + 4);
        assert_eq!(t.outputs.len(), 3 * np + 2);
        assert_eq!(t.inputs[3 * np].dtype, DType::I32); // step
        assert_eq!(t.inputs[3 * np + 1].dims,
                   vec![meta.batch, meta.seq_len]);
        assert!(t.model.is_some());

        let e = ArtifactEntry::eval_step(&meta);
        assert_eq!(e.inputs.len(), np + 2);
        assert_eq!(e.outputs.len(), 2);
        assert!(e.outputs.iter().all(|s| s.dims.is_empty()));

        let s = ArtifactEntry::seq_nll(&meta);
        assert_eq!(s.inputs.len(), np + 3);
        assert_eq!(s.inputs[np + 2].dtype, DType::F32); // mask
        assert_eq!(s.outputs[0].dims, vec![meta.batch]);

        let c = ArtifactEntry::calib_step(&meta);
        assert_eq!(c.inputs.len(), np + 9);
        assert_eq!(c.outputs.len(), 8);
        assert_eq!(c.outputs[3].dims,
                   vec![meta.n_blocks, meta.d_ff, meta.d_ff]);
        assert_eq!(c.outputs[4].dims,
                   vec![meta.n_blocks, meta.d_model]);

        let n = meta.batch * meta.seq_len;
        let em = ArtifactEntry::embed(&meta);
        assert_eq!(em.name, "embed_tiny");
        assert_eq!(em.inputs.len(), 2);
        assert_eq!(em.inputs[0].dims, meta.params[0].1);
        assert_eq!(em.inputs[1].dtype, DType::I32);
        assert_eq!(em.outputs[0].dims, vec![n, meta.d_model]);

        let cb = ArtifactEntry::calib_block(&meta);
        assert_eq!(cb.inputs.len(), 9 + 2 + 8);
        assert_eq!(cb.outputs.len(), 9);
        assert_eq!(cb.inputs[9].dims, vec![n, meta.d_model]); // h_in
        assert_eq!(cb.inputs[10].dtype, DType::I32); // accum
        assert_eq!(cb.outputs[3].dims, vec![meta.d_ff, meta.d_ff]);
        assert_eq!(cb.outputs[8].dims, vec![n, meta.d_model]); // h_out
        assert!(cb.model.is_some());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // Integration-ish: if `make artifacts` has run, the real manifest
        // must parse and agree with its own swap naming scheme.
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert!(!m.configs.is_empty());
        for (name, a) in &m.artifacts {
            if a.kind == "swap_step" {
                assert_eq!(name,
                           &Manifest::swap_artifact_name(
                               a.width, &a.pattern, &a.impl_name,
                               a.k_iters));
                assert_eq!(a.inputs.len(), 3);
                assert_eq!(a.outputs.len(), 4);
            }
        }
    }
}
