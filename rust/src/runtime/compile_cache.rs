//! Cross-worker compile cache: serialized-executable handoff between
//! the service workers of one [`crate::runtime::pool::RuntimePool`].
//!
//! Every pool worker owns its own backend and executables (non-`Send`
//! device handles never cross threads), which used to mean every
//! worker compiled every artifact from scratch — pool startup cost
//! scaled with N.  Now the pool hands each worker one shared
//! [`CompileCache`] through `RuntimeOptions::compile_cache`: the first
//! worker to compile an artifact exports its serialized form
//! (`Backend::export_compiled`), and later workers import it
//! (`Backend::import_compiled`) instead of recompiling — counted by
//! `ServiceStats::compiles_shared`.
//!
//! Backends that cannot serialize executables simply never export
//! (the trait's defaults), and every worker falls back to a local
//! compile exactly as before.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Shared store of serialized executables, keyed by artifact name.
/// One per pool; all methods are `&self` (internally locked) so the
/// handle clones freely across worker options.
#[derive(Debug, Default)]
pub struct CompileCache {
    entries: Mutex<HashMap<String, Arc<Vec<u8>>>>,
}

/// Lock recovering from poisoning.  Every critical section here is a
/// single map lookup or insert that leaves the map valid at every
/// instant, so a poisoned lock only means some *worker* thread
/// panicked while holding it — never that the map is torn.
/// Propagating the poison would make every surviving worker fall back
/// to a local compile (or die), turning one contained panic into a
/// pool-wide slowdown.
fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl CompileCache {
    /// A fresh cache behind an [`Arc`], ready to clone into every
    /// worker's `RuntimeOptions`.
    pub fn shared() -> Arc<CompileCache> {
        Arc::new(CompileCache::default())
    }

    /// Serialized executable for `artifact`, if any worker exported
    /// one.
    pub fn get(&self, artifact: &str) -> Option<Arc<Vec<u8>>> {
        relock(&self.entries).get(artifact).cloned()
    }

    /// Store a serialized executable.  First write wins: compiles are
    /// deterministic per manifest entry, so a racing second export is
    /// redundant, not conflicting.
    pub fn put(&self, artifact: &str, bytes: Vec<u8>) {
        relock(&self.entries)
            .entry(artifact.to_string())
            .or_insert_with(|| Arc::new(bytes));
    }

    /// Number of cached executables.
    pub fn len(&self) -> usize {
        relock(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_write_wins_and_lookup_roundtrips() {
        let cache = CompileCache::shared();
        assert!(cache.is_empty());
        assert!(cache.get("a").is_none());
        cache.put("a", vec![1, 2, 3]);
        cache.put("a", vec![9]);
        assert_eq!(cache.len(), 1);
        assert_eq!(*cache.get("a").unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn cache_survives_poisoned_lock() {
        let cache = CompileCache::shared();
        cache.put("a", vec![1]);
        // Poison the entries lock by panicking while holding it; the
        // cache must stay readable and writable for the surviving
        // workers.
        let c2 = Arc::clone(&cache);
        let _ = std::thread::spawn(move || {
            let _g = c2.entries.lock().unwrap();
            panic!("poison cache lock");
        })
        .join();
        assert!(cache.entries.is_poisoned());
        assert_eq!(*cache.get("a").unwrap(), vec![1]);
        cache.put("b", vec![2]);
        assert_eq!(cache.len(), 2);
    }
}
