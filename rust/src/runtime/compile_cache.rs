//! Cross-worker compile cache: serialized-executable handoff between
//! the service workers of one [`crate::runtime::pool::RuntimePool`].
//!
//! Every pool worker owns its own backend and executables (non-`Send`
//! device handles never cross threads), which used to mean every
//! worker compiled every artifact from scratch — pool startup cost
//! scaled with N.  Now the pool hands each worker one shared
//! [`CompileCache`] through `RuntimeOptions::compile_cache`: the first
//! worker to compile an artifact exports its serialized form
//! (`Backend::export_compiled`), and later workers import it
//! (`Backend::import_compiled`) instead of recompiling — counted by
//! `ServiceStats::compiles_shared`.
//!
//! Backends that cannot serialize executables simply never export
//! (the trait's defaults), and every worker falls back to a local
//! compile exactly as before.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Shared store of serialized executables, keyed by artifact name.
/// One per pool; all methods are `&self` (internally locked) so the
/// handle clones freely across worker options.
#[derive(Debug, Default)]
pub struct CompileCache {
    entries: Mutex<HashMap<String, Arc<Vec<u8>>>>,
}

impl CompileCache {
    /// A fresh cache behind an [`Arc`], ready to clone into every
    /// worker's `RuntimeOptions`.
    pub fn shared() -> Arc<CompileCache> {
        Arc::new(CompileCache::default())
    }

    /// Serialized executable for `artifact`, if any worker exported
    /// one.
    pub fn get(&self, artifact: &str) -> Option<Arc<Vec<u8>>> {
        self.entries.lock().unwrap().get(artifact).cloned()
    }

    /// Store a serialized executable.  First write wins: compiles are
    /// deterministic per manifest entry, so a racing second export is
    /// redundant, not conflicting.
    pub fn put(&self, artifact: &str, bytes: Vec<u8>) {
        self.entries.lock().unwrap()
            .entry(artifact.to_string())
            .or_insert_with(|| Arc::new(bytes));
    }

    /// Number of cached executables.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_write_wins_and_lookup_roundtrips() {
        let cache = CompileCache::shared();
        assert!(cache.is_empty());
        assert!(cache.get("a").is_none());
        cache.put("a", vec![1, 2, 3]);
        cache.put("a", vec![9]);
        assert_eq!(cache.len(), 1);
        assert_eq!(*cache.get("a").unwrap(), vec![1, 2, 3]);
    }
}
