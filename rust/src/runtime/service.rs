//! Runtime service: one dedicated thread per device worker owning a
//! (possibly non-`Send`) execution [`Backend`] plus its compiled
//! executables, serving execute requests over channels.
//!
//! Two things live on the service thread and nowhere else:
//!
//!   * the backend (PJRT client under `--features xla`, the pure-Rust
//!     artifact interpreter otherwise — see `runtime::backend`);
//!   * the **device-buffer cache**: host tensors uploaded through
//!     [`ExecInput::Cached`] stay resident on the device, keyed by
//!     `(layer, tensor, generation)`.  A repeat call with the same key
//!     reuses the buffer (no re-pack, no re-upload); a bumped
//!     generation invalidates the stale buffer; an LRU sweep bounded
//!     by [`RuntimeOptions::device_mem_budget`] reclaims memory after
//!     each call.  [`ExecInput::CachedRef`] is the key-only probe
//!     form: it names a resident buffer without shipping any host
//!     data, failing fast with [`RuntimeError::NotResident`] when the
//!     buffer is gone so the caller can re-send the data-attached
//!     form.  Hit/miss/eviction/probe counters surface through
//!     [`ServiceStats`].
//!
//! Executions exchange [`TensorData`] (plain `Vec`s + dims); the
//! service packs/unpacks at the boundary, so handles stay `Send` and
//! several workers can form a `runtime::pool::RuntimePool`.

use std::collections::{HashMap, HashSet};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::runtime::backend::{Backend, DefaultBackend};
use crate::runtime::compile_cache::CompileCache;
use crate::runtime::manifest::{ArtifactEntry, Manifest};
use crate::runtime::tensor_data::TensorData;

#[derive(Debug)]
pub enum RuntimeError {
    Msg(String),
    Xla(String),
    /// The backend returned a different number of outputs than the
    /// manifest declares for the artifact — a malformed or mismatched
    /// artifact, not a worker fault.  Structured (rather than a bare
    /// `Msg`) so calibration drivers can fail loudly with the artifact
    /// name instead of aborting on an `assert_eq!`.
    BadOutputArity { artifact: String, expected: usize, got: usize },
    /// A key-only probe ([`ExecInput::CachedRef`]) named a buffer that
    /// is not resident at the requested generation.  The call failed
    /// *before* any upload or execution; the caller retries with the
    /// full [`ExecInput::Cached`] form (data attached) — see
    /// `OffloadEngine` for the canonical probe-then-upload loop.
    NotResident(BufferKey),
    /// Failure tied to the worker, not the work: a dead service
    /// thread, a lost reply, a backend that failed to come up.  The
    /// same call can succeed on another worker, so the shard
    /// scheduler retries these (and only these — see
    /// [`RuntimeError::is_transient`]).
    Transient(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Msg(s) => write!(f, "runtime: {s}"),
            RuntimeError::Xla(s) => write!(f, "xla: {s}"),
            RuntimeError::BadOutputArity { artifact, expected, got } => {
                write!(f,
                       "runtime: {artifact}: manifest declares \
                        {expected} outputs, backend returned {got}")
            }
            RuntimeError::NotResident(k) => write!(
                f,
                "runtime: buffer ({}, {:?}, gen {}) not resident",
                k.layer, k.tensor, k.generation),
            RuntimeError::Transient(s) => {
                write!(f, "runtime (transient): {s}")
            }
        }
    }
}

impl RuntimeError {
    /// True for failures a retry on a different worker can fix:
    /// worker death (`Transient`) and evicted device buffers
    /// (`NotResident`).  Deterministic failures — manifest parse
    /// errors, shape mismatches, backend rejections — stay
    /// non-transient so the retry loop never spins on them.
    pub fn is_transient(&self) -> bool {
        matches!(self,
                 RuntimeError::Transient(_)
                 | RuntimeError::NotResident(_))
    }
}

impl std::error::Error for RuntimeError {}

impl From<String> for RuntimeError {
    fn from(s: String) -> Self {
        RuntimeError::Msg(s)
    }
}

type ExecResult = Result<Vec<TensorData>, RuntimeError>;

/// Monotone process-wide id for the [`BufferKey`] "layer" coordinate.
/// Every independent cached-buffer namespace — a refinement call's W
/// chunks, a calibration pass's weights, one stripe's resident
/// accumulators — draws a fresh id here, so concurrent users never
/// collide within one worker's cache.
pub fn next_buffer_layer_id() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Key of one persistently cached device buffer.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BufferKey {
    /// Caller-chosen layer/job id; unique per refinement call (see
    /// `OffloadEngine`), so concurrent layers never collide.
    pub layer: u64,
    /// Tensor role within the layer ("gram", "w0", "w1", ...).
    pub tensor: String,
    /// Content generation.  A bumped generation for the same
    /// (layer, tensor) drops the stale resident buffer on next use.
    pub generation: u64,
}

/// One input of a cached execution.
pub enum ExecInput {
    /// Uploaded for this call only, never cached (e.g. mask chunks,
    /// which change every call).
    Inline(TensorData),
    /// Uploaded once, then served from the resident device buffer
    /// while the generation matches.  `data` travels on every call so
    /// a miss (first use, bumped generation, post-eviction) re-uploads
    /// without a round-trip back to the caller; `Arc` keeps that
    /// cheap.
    Cached { key: BufferKey, data: Arc<TensorData> },
    /// Key-only probe: use the resident buffer under `key`, shipping
    /// *no host data at all*.  A hit counts as
    /// [`ServiceStats::probe_hits`] and behaves exactly like a
    /// `Cached` hit; a miss fails the whole call with
    /// [`RuntimeError::NotResident`] *before* anything is uploaded or
    /// executed ([`ServiceStats::probe_misses`]), and the caller
    /// retries with `Cached`.  This is what lets a steady-state shard
    /// skip even *building* the d² host copy of a layer's Gram matrix
    /// when the buffer is already on the device.
    CachedRef { key: BufferKey },
}

impl ExecInput {
    /// Host data carried by this input (`None` for key-only probes,
    /// which by construction ship nothing).
    fn data(&self) -> Option<&TensorData> {
        match self {
            ExecInput::Inline(t) => Some(t),
            ExecInput::Cached { data, .. } => Some(data),
            ExecInput::CachedRef { .. } => None,
        }
    }

    /// Cache key named by this input, if any.
    fn key(&self) -> Option<&BufferKey> {
        match self {
            ExecInput::Inline(_) => None,
            ExecInput::Cached { key, .. }
            | ExecInput::CachedRef { key } => Some(key),
        }
    }
}

enum Request {
    Exec {
        artifact: String,
        inputs: Vec<ExecInput>,
        /// Output retention plan: empty = return every output to the
        /// caller; otherwise one slot per artifact output, where
        /// `Some(key)` stores that output in the device-buffer cache
        /// under `key` instead of returning it (see
        /// [`Runtime::execute_retained`]).
        retain: Vec<Option<BufferKey>>,
        reply: mpsc::Sender<ExecResult>,
    },
    /// Compile without executing (warm the cache).
    Preload {
        artifact: String,
        reply: mpsc::Sender<Result<(), RuntimeError>>,
    },
    Stats {
        reply: mpsc::Sender<ServiceStats>,
    },
    /// Drop every cached buffer belonging to one layer id.
    Invalidate { layer: u64 },
    Shutdown,
}

#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub executions: u64,
    pub compiles: u64,
    /// Executables adopted from the pool's shared compile cache
    /// instead of compiled locally — the pool-startup diagnostic: a
    /// healthy N-worker pool compiles each artifact once and imports
    /// it N-1 times.
    pub compiles_shared: u64,
    /// Backend execute time; since the backend API returns host
    /// tensors, output download/decompose is included here.
    pub exec_nanos: u64,
    pub pack_nanos: u64,
    /// Retained for report compatibility; the backend API folds
    /// output unpacking into `exec_nanos`, so this stays 0.
    pub unpack_nanos: u64,
    pub compile_nanos: u64,
    /// Device-buffer cache: resident-buffer reuses (uploads skipped).
    pub cache_hits: u64,
    /// Uploads of cacheable inputs (first use, bumped generation, or
    /// re-upload after eviction).
    pub cache_misses: u64,
    /// LRU evictions forced by the device memory budget.
    pub cache_evictions: u64,
    /// Buffers dropped by generation bumps and explicit layer
    /// invalidation.
    pub cache_invalidations: u64,
    /// Bytes currently resident in the cache.
    pub cache_bytes: u64,
    /// High-water mark of `cache_bytes`.
    pub cache_peak_bytes: u64,
    /// Key-only probes ([`ExecInput::CachedRef`]) that found their
    /// buffer resident — each one is a d²-scale host copy the caller
    /// never had to build or ship.  Kept separate from `cache_hits`
    /// (which counts `Cached` lookups, data attached) so probe
    /// traffic never inflates [`Self::cache_hit_rate`].
    pub probe_hits: u64,
    /// Key-only probes that missed; the call failed with
    /// [`RuntimeError::NotResident`] and the caller re-sent the data.
    pub probe_misses: u64,
    /// Host bytes actually shipped to the backend: inline inputs
    /// every call plus cacheable uploads on `Cached` misses.  Probe
    /// and cache hits add nothing here — this is the number the
    /// wave-2 bench watches drop.
    pub upload_bytes: u64,
    /// Host bytes of outputs returned to callers.  Outputs retained
    /// on-device via [`Runtime::execute_retained`] add nothing here —
    /// this is the number the resident-accumulator calibration path
    /// watches drop (a steady-state calib batch downloads nothing).
    pub download_bytes: u64,
    /// Outputs stored in the device-buffer cache instead of being
    /// returned ([`Runtime::execute_retained`]).  Retention is
    /// device-side, so it is *not* counted in [`Self::upload_bytes`].
    pub outputs_retained: u64,
    /// Shard dispatches re-run after a transient failure.  Counted at
    /// the pool, not per service — per-worker stats report 0 and
    /// `RuntimePool::stats_total` injects the pool total.
    pub shard_retries: u64,
    /// Workers currently quarantined after consecutive failures
    /// (pool-level, like `shard_retries`).
    pub workers_quarantined: u64,
}

impl ServiceStats {
    pub fn exec_seconds(&self) -> f64 {
        self.exec_nanos as f64 / 1e9
    }

    /// Cache hit rate over all `Cached` (data-attached) lookups only
    /// (0 when none ran).  Key-only probes are deliberately excluded
    /// — counting a probe hit here too would double-count one
    /// resident-buffer reuse across two rates.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Probe hit rate over all key-only lookups (0 when none ran).
    pub fn probe_hit_rate(&self) -> f64 {
        let total = self.probe_hits + self.probe_misses;
        if total == 0 {
            0.0
        } else {
            self.probe_hits as f64 / total as f64
        }
    }

    /// Fold another worker's counters into this one (pool totals).
    /// Byte gauges sum across devices: `cache_bytes` is the fleet's
    /// current resident total; `cache_peak_bytes` becomes the *sum of
    /// per-device peaks* (an upper bound on any simultaneous fleet
    /// peak — the devices need not have peaked at the same instant).
    pub fn merge(&mut self, o: &ServiceStats) {
        self.executions += o.executions;
        self.compiles += o.compiles;
        self.compiles_shared += o.compiles_shared;
        self.exec_nanos += o.exec_nanos;
        self.pack_nanos += o.pack_nanos;
        self.unpack_nanos += o.unpack_nanos;
        self.compile_nanos += o.compile_nanos;
        self.cache_hits += o.cache_hits;
        self.cache_misses += o.cache_misses;
        self.cache_evictions += o.cache_evictions;
        self.cache_invalidations += o.cache_invalidations;
        self.cache_bytes += o.cache_bytes;
        self.cache_peak_bytes += o.cache_peak_bytes;
        self.probe_hits += o.probe_hits;
        self.probe_misses += o.probe_misses;
        self.upload_bytes += o.upload_bytes;
        self.download_bytes += o.download_bytes;
        self.outputs_retained += o.outputs_retained;
        self.shard_retries += o.shard_retries;
        self.workers_quarantined += o.workers_quarantined;
    }

    /// Traffic delta between two stat snapshots of the same worker
    /// set (`before` taken earlier): what one exclusive phase — a
    /// calibration pass, an eval sweep — shipped over the host/device
    /// boundary.  Saturating, so a worker restarted between snapshots
    /// degrades to zero rather than wrapping.
    pub fn traffic_since(&self, before: &ServiceStats) -> PhaseTraffic {
        let d = |a: u64, b: u64| a.saturating_sub(b);
        PhaseTraffic {
            executions: d(self.executions, before.executions),
            upload_bytes: d(self.upload_bytes, before.upload_bytes),
            download_bytes: d(self.download_bytes,
                              before.download_bytes),
            probe_hits: d(self.probe_hits, before.probe_hits),
            probe_misses: d(self.probe_misses, before.probe_misses),
        }
    }
}

/// Host/device traffic attributed to one phase of a run (calibration,
/// eval), computed as a [`ServiceStats::traffic_since`] snapshot delta
/// and merged across pool workers.  Surfaced in the prune CLI summary
/// (`calibration:` line) and carried by `GramStats`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseTraffic {
    pub executions: u64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
    pub probe_hits: u64,
    pub probe_misses: u64,
}

impl PhaseTraffic {
    /// Fold another phase (a recalibration, another worker's delta)
    /// into this one.
    pub fn merge(&mut self, o: &PhaseTraffic) {
        self.executions += o.executions;
        self.upload_bytes += o.upload_bytes;
        self.download_bytes += o.download_bytes;
        self.probe_hits += o.probe_hits;
        self.probe_misses += o.probe_misses;
    }

    /// Key-only probe hit rate within the phase (0 when none ran).
    pub fn probe_hit_rate(&self) -> f64 {
        let total = self.probe_hits + self.probe_misses;
        if total == 0 {
            0.0
        } else {
            self.probe_hits as f64 / total as f64
        }
    }
}

/// Default per-device buffer-cache budget (bytes).
pub const DEFAULT_DEVICE_MEM_BUDGET: u64 = 512 << 20;

/// Options for starting one runtime service worker.
#[derive(Clone, Debug)]
pub struct RuntimeOptions {
    /// Device-buffer cache budget in bytes; the LRU sweep reclaims
    /// beyond this after every call.  0 = unlimited.
    pub device_mem_budget: u64,
    /// Device index (pool worker id; 0 for a standalone runtime).
    pub device: usize,
    /// Pool-wide compile cache: the first worker to compile an
    /// artifact exports the serialized executable, later workers
    /// import it instead of recompiling
    /// ([`ServiceStats::compiles_shared`]).  `None` = every worker
    /// compiles everything itself (standalone runtimes).
    pub compile_cache: Option<Arc<CompileCache>>,
}

impl Default for RuntimeOptions {
    fn default() -> Self {
        Self {
            device_mem_budget: DEFAULT_DEVICE_MEM_BUDGET,
            device: 0,
            compile_cache: None,
        }
    }
}

impl RuntimeOptions {
    /// Ensure a compile cache is present (pool constructors call this
    /// before fanning the options out to their workers, so every
    /// worker of one pool shares one cache — the single place that
    /// policy lives).
    pub fn with_shared_compile_cache(mut self) -> RuntimeOptions {
        if self.compile_cache.is_none() {
            self.compile_cache = Some(CompileCache::shared());
        }
        self
    }
}

/// Handle to one runtime service worker; cheap to clone and `Send`.
#[derive(Clone)]
pub struct Runtime {
    tx: mpsc::Sender<Request>,
    manifest: Arc<Manifest>,
    device: usize,
    _join: Arc<JoinGuard>,
}

struct JoinGuard {
    tx: mpsc::Sender<Request>,
    handle: Option<JoinHandle<()>>,
}

impl Drop for JoinGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Runtime {
    /// Start a service over the artifact directory with the default
    /// backend and options.
    pub fn start(artifact_dir: impl AsRef<std::path::Path>)
        -> Result<Runtime, RuntimeError> {
        Self::start_opts(artifact_dir, RuntimeOptions::default())
    }

    /// [`Self::start`] with explicit options.
    pub fn start_opts(artifact_dir: impl AsRef<std::path::Path>,
                      opts: RuntimeOptions)
        -> Result<Runtime, RuntimeError> {
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        Self::start_with_backend(manifest, DefaultBackend::new_default,
                                 opts)
    }

    /// Start a service worker over an explicit backend.  The factory
    /// runs *on* the service thread, so the backend itself need not be
    /// `Send` (PJRT clients are not); only the factory is.
    pub fn start_with_backend<B, F>(manifest: Arc<Manifest>, factory: F,
                                    opts: RuntimeOptions)
        -> Result<Runtime, RuntimeError>
    where
        B: Backend + 'static,
        F: FnOnce() -> Result<B, RuntimeError> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let thread_manifest = Arc::clone(&manifest);
        // `opts` moves onto the service thread; keep the id out here.
        let device = opts.device;
        let handle = std::thread::Builder::new()
            .name(format!("runtime-service-{device}"))
            .spawn(move || service_main(rx, thread_manifest, factory,
                                        opts))
            .map_err(|e| RuntimeError::Msg(e.to_string()))?;
        Ok(Runtime {
            tx: tx.clone(),
            manifest,
            device,
            _join: Arc::new(JoinGuard { tx, handle: Some(handle) }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Device index this worker was started with.
    pub fn device(&self) -> usize {
        self.device
    }

    /// Execute an artifact by name; validates signatures against the
    /// manifest on both sides.  All inputs are uploaded per call.
    pub fn execute(&self, artifact: &str, inputs: Vec<TensorData>)
        -> ExecResult {
        self.execute_cached(
            artifact,
            inputs.into_iter().map(ExecInput::Inline).collect())
    }

    /// [`Self::execute`] with per-input cache control: `Cached` inputs
    /// upload once and stay resident under their [`BufferKey`].
    pub fn execute_cached(&self, artifact: &str, inputs: Vec<ExecInput>)
        -> ExecResult {
        self.execute_retained(artifact, inputs, Vec::new())
    }

    /// [`Self::execute_cached`] with output retention.  `retain` is
    /// either empty (every output returns to the caller) or has one
    /// slot per artifact output: an output paired with `Some(key)` is
    /// stored in the device-buffer cache under `key` — replacing any
    /// stale generation of the same `(layer, tensor)` — instead of
    /// travelling back to the caller; only `None` outputs are
    /// returned, in artifact output order.  This is what keeps
    /// calibration accumulators device-resident between batches: a
    /// chain of calls retains its running stats under a
    /// per-batch-bumped generation and names them back as
    /// [`ExecInput::CachedRef`] inputs, downloading them only once on
    /// the final call.
    pub fn execute_retained(&self, artifact: &str,
                            inputs: Vec<ExecInput>,
                            retain: Vec<Option<BufferKey>>)
        -> ExecResult {
        let entry = self.manifest.artifact(artifact)?;
        if inputs.len() != entry.inputs.len() {
            return Err(RuntimeError::Msg(format!(
                "{artifact}: expected {} inputs, got {}",
                entry.inputs.len(), inputs.len())));
        }
        if !retain.is_empty() && retain.len() != entry.outputs.len() {
            return Err(RuntimeError::Msg(format!(
                "{artifact}: retain plan names {} outputs, manifest \
                 declares {}", retain.len(), entry.outputs.len())));
        }
        for (i, (t, sig)) in inputs.iter().zip(&entry.inputs).enumerate() {
            // Key-only probes carry no host data to check; the
            // resident buffer was validated when it was uploaded.
            if let Some(data) = t.data() {
                data.check_sig(sig, &format!("{artifact} input {i}"))?;
            }
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx.send(Request::Exec {
            artifact: artifact.to_string(),
            inputs,
            retain,
            reply: reply_tx,
        }).map_err(|_| RuntimeError::Transient("service stopped".into()))?;
        reply_rx.recv()
            .map_err(|_| RuntimeError::Transient("service dropped reply".into()))?
    }

    /// Compile an artifact ahead of first use.
    pub fn preload(&self, artifact: &str) -> Result<(), RuntimeError> {
        let _ = self.manifest.artifact(artifact)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx.send(Request::Preload {
            artifact: artifact.to_string(),
            reply: reply_tx,
        }).map_err(|_| RuntimeError::Transient("service stopped".into()))?;
        reply_rx.recv()
            .map_err(|_| RuntimeError::Transient("service dropped reply".into()))?
    }

    pub fn stats(&self) -> ServiceStats {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self.tx.send(Request::Stats { reply: reply_tx }).is_err() {
            return ServiceStats::default();
        }
        reply_rx.recv().unwrap_or_default()
    }

    /// Release every device buffer cached under `layer` (fire and
    /// forget; the channel's FIFO order makes it take effect before
    /// any later call from this handle).  The LRU sweep would reclaim
    /// them eventually — releasing promptly keeps the budget for live
    /// layers.
    pub fn invalidate(&self, layer: u64) {
        let _ = self.tx.send(Request::Invalidate { layer });
    }
}

// --- service thread --------------------------------------------------------

struct CachedBuf<Buf> {
    buf: Buf,
    generation: u64,
    bytes: u64,
    last_used: u64,
}

struct Service<B: Backend> {
    backend: B,
    manifest: Arc<Manifest>,
    /// LRU budget in bytes (0 = unlimited).
    budget: u64,
    cache: HashMap<(u64, String), CachedBuf<B::Buf>>,
    tick: u64,
    stats: ServiceStats,
    /// Artifacts this worker has ensured (compiled or imported).
    compiled: HashSet<String>,
    /// Pool-wide serialized-executable handoff (see
    /// [`CompileCache`]).
    shared_compiles: Option<Arc<CompileCache>>,
}

fn service_main<B, F>(rx: mpsc::Receiver<Request>, manifest: Arc<Manifest>,
                      factory: F, opts: RuntimeOptions)
where
    B: Backend,
    F: FnOnce() -> Result<B, RuntimeError>,
{
    let backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            // Fail every request with the construction error.  A
            // sibling worker's backend may have come up fine, so this
            // is a worker-tied (transient) failure, not a job one.
            let msg = format!("backend init failed: {e}");
            for req in rx {
                match req {
                    Request::Exec { reply, .. } => {
                        let _ = reply.send(Err(RuntimeError::Transient(
                            msg.clone())));
                    }
                    Request::Preload { reply, .. } => {
                        let _ = reply.send(Err(RuntimeError::Transient(
                            msg.clone())));
                    }
                    Request::Stats { reply } => {
                        let _ = reply.send(ServiceStats::default());
                    }
                    Request::Invalidate { .. } => {}
                    Request::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut svc = Service {
        backend,
        manifest,
        budget: opts.device_mem_budget,
        cache: HashMap::new(),
        tick: 0,
        stats: ServiceStats::default(),
        compiled: HashSet::new(),
        shared_compiles: opts.compile_cache,
    };
    for req in rx {
        match req {
            Request::Exec { artifact, inputs, retain, reply } => {
                let _ = reply.send(svc.execute(&artifact, inputs,
                                               retain));
            }
            Request::Preload { artifact, reply } => {
                let _ = reply.send(svc.preload(&artifact));
            }
            Request::Stats { reply } => {
                let _ = reply.send(svc.stats.clone());
            }
            Request::Invalidate { layer } => svc.invalidate_layer(layer),
            Request::Shutdown => break,
        }
    }
}

impl<B: Backend> Service<B> {
    fn preload(&mut self, artifact: &str) -> Result<(), RuntimeError> {
        let manifest = Arc::clone(&self.manifest);
        let entry = manifest.artifact(artifact)?;
        self.ensure_compiled(entry)
    }

    fn ensure_compiled(&mut self, entry: &ArtifactEntry)
        -> Result<(), RuntimeError> {
        if self.compiled.contains(&entry.name) {
            return Ok(());
        }
        // Adopt a sibling worker's executable when the pool's shared
        // cache has one: compile cost is paid once per pool instead
        // of once per worker.
        if let Some(cache) = &self.shared_compiles {
            if let Some(bytes) = cache.get(&entry.name) {
                if self.backend.import_compiled(entry, &bytes)? {
                    self.stats.compiles_shared += 1;
                    self.compiled.insert(entry.name.clone());
                    return Ok(());
                }
            }
        }
        let t0 = Instant::now();
        if self.backend.compile(entry)? {
            self.stats.compiles += 1;
            self.stats.compile_nanos += t0.elapsed().as_nanos() as u64;
            if let Some(cache) = &self.shared_compiles {
                if let Some(bytes) = self.backend.export_compiled(entry)
                {
                    cache.put(&entry.name, bytes);
                }
            }
        }
        self.compiled.insert(entry.name.clone());
        Ok(())
    }

    /// Make one cacheable input resident: reuse on generation match,
    /// drop + re-upload on mismatch, upload + insert on first use.
    fn ensure_resident(&mut self, key: &BufferKey, data: &TensorData)
        -> Result<(), RuntimeError> {
        let mk = (key.layer, key.tensor.clone());
        if let Some(c) = self.cache.get_mut(&mk) {
            if c.generation == key.generation {
                self.tick += 1;
                c.last_used = self.tick;
                self.stats.cache_hits += 1;
                return Ok(());
            }
        }
        // Stale generation: drop the old buffer before re-uploading.
        if let Some(old) = self.cache.remove(&mk) {
            self.stats.cache_bytes -= old.bytes;
            self.stats.cache_invalidations += 1;
        }
        let t0 = Instant::now();
        let buf = self.backend.upload(data)?;
        self.stats.pack_nanos += t0.elapsed().as_nanos() as u64;
        self.stats.cache_misses += 1;
        self.stats.upload_bytes += data.byte_size() as u64;
        let bytes = data.byte_size() as u64;
        self.tick += 1;
        self.cache.insert(mk, CachedBuf {
            buf,
            generation: key.generation,
            bytes,
            last_used: self.tick,
        });
        self.stats.cache_bytes += bytes;
        self.stats.cache_peak_bytes =
            self.stats.cache_peak_bytes.max(self.stats.cache_bytes);
        Ok(())
    }

    /// Evict least-recently-used buffers until the budget holds.
    /// Runs only between calls, so an in-flight call's inputs are
    /// never reclaimed under it.
    fn trim_to_budget(&mut self) {
        if self.budget == 0 {
            return;
        }
        while self.stats.cache_bytes > self.budget
            && !self.cache.is_empty() {
            let victim = self.cache.iter()
                .min_by_key(|(_, c)| c.last_used)
                .map(|(k, _)| k.clone())
                .expect("cache non-empty");
            let old = self.cache.remove(&victim).expect("victim resident");
            self.stats.cache_bytes -= old.bytes;
            self.stats.cache_evictions += 1;
        }
    }

    fn invalidate_layer(&mut self, layer: u64) {
        let keys: Vec<(u64, String)> = self.cache.keys()
            .filter(|(l, _)| *l == layer)
            .cloned()
            .collect();
        for k in keys {
            let old = self.cache.remove(&k).expect("key resident");
            self.stats.cache_bytes -= old.bytes;
            self.stats.cache_invalidations += 1;
        }
    }

    fn execute(&mut self, artifact: &str, inputs: Vec<ExecInput>,
               retain: Vec<Option<BufferKey>>)
        -> ExecResult {
        // Borrow the entry through a local Arc clone so `self` stays
        // free for &mut calls — no per-call ArtifactEntry clone on the
        // hot path.
        let manifest = Arc::clone(&self.manifest);
        let entry = manifest.artifact(artifact)?;
        self.ensure_compiled(entry)?;

        // Duplicate cache keys within one call would both resolve to
        // the single surviving buffer in phase 2 (the second upload
        // replaces the first) — reject instead of executing with
        // wrong data.  Key-only probes count too: a CachedRef
        // aliasing a Cached upload is the same footgun.
        for (i, a) in inputs.iter().enumerate() {
            if let Some(ka) = a.key() {
                for b in &inputs[i + 1..] {
                    if let Some(kb) = b.key() {
                        if ka.layer == kb.layer && ka.tensor == kb.tensor
                        {
                            return Err(RuntimeError::Msg(format!(
                                "{artifact}: duplicate cached input \
                                 key ({}, {:?})", ka.layer, ka.tensor)));
                        }
                    }
                }
            }
        }
        // Same footgun on the retention side: two retained outputs
        // landing on one (layer, tensor) slot would silently keep only
        // the later one.  A retain key *aliasing an input key* is fine
        // — that is the accumulator-chain idiom (input at generation g,
        // output retained at g+1 replaces it).
        if !retain.is_empty() && retain.len() != entry.outputs.len() {
            return Err(RuntimeError::Msg(format!(
                "{artifact}: retain plan names {} outputs, manifest \
                 declares {}", retain.len(), entry.outputs.len())));
        }
        for (i, a) in retain.iter().enumerate() {
            if let Some(ka) = a {
                for kb in retain[i + 1..].iter().flatten() {
                    if ka.layer == kb.layer && ka.tensor == kb.tensor {
                        return Err(RuntimeError::Msg(format!(
                            "{artifact}: duplicate retained output \
                             key ({}, {:?})", ka.layer, ka.tensor)));
                    }
                }
            }
        }

        // Phase 0: key-only probes.  Checked before *anything* is
        // uploaded so a miss costs one round-trip and no work — the
        // caller falls back to the data-attached form.  A hit acts
        // like a Cached hit (LRU touch) but is counted separately so
        // probe traffic never skews the upload-cache hit rate.
        for inp in &inputs {
            if let ExecInput::CachedRef { key } = inp {
                let mk = (key.layer, key.tensor.clone());
                match self.cache.get_mut(&mk) {
                    Some(c) if c.generation == key.generation => {
                        self.tick += 1;
                        c.last_used = self.tick;
                        self.stats.probe_hits += 1;
                    }
                    _ => {
                        self.stats.probe_misses += 1;
                        return Err(RuntimeError::NotResident(
                            key.clone()));
                    }
                }
            }
        }

        // Phase 1: make every cached input resident and upload the
        // per-call inline inputs.  No buffer refs are held yet, so the
        // cache map stays freely mutable.
        for inp in &inputs {
            if let ExecInput::Cached { key, data } = inp {
                self.ensure_resident(key, data)?;
            }
        }
        let t0 = Instant::now();
        let mut temps: Vec<B::Buf> = Vec::new();
        for inp in &inputs {
            if let ExecInput::Inline(t) = inp {
                temps.push(self.backend.upload(t)?);
                self.stats.upload_bytes += t.byte_size() as u64;
            }
        }
        self.stats.pack_nanos += t0.elapsed().as_nanos() as u64;

        // Phase 2: assemble the argument refs (cache + temps) in the
        // artifact's input order and run.
        let mut refs: Vec<&B::Buf> = Vec::with_capacity(inputs.len());
        let mut ti = 0usize;
        for inp in &inputs {
            match inp {
                ExecInput::Inline(_) => {
                    refs.push(&temps[ti]);
                    ti += 1;
                }
                ExecInput::Cached { key, .. }
                | ExecInput::CachedRef { key } => {
                    let mk = (key.layer, key.tensor.clone());
                    refs.push(&self.cache[&mk].buf);
                }
            }
        }
        let t1 = Instant::now();
        let outputs = self.backend.execute(entry, &refs)?;
        drop(refs);
        drop(temps); // per-call input device memory freed here
        self.stats.exec_nanos += t1.elapsed().as_nanos() as u64;

        if outputs.len() != entry.outputs.len() {
            return Err(RuntimeError::BadOutputArity {
                artifact: artifact.to_string(),
                expected: entry.outputs.len(),
                got: outputs.len(),
            });
        }
        self.stats.executions += 1;

        // Output retention: keep `Some(key)` outputs resident in the
        // buffer cache (replacing any stale generation on the same
        // slot); only the rest travel back to the caller and count as
        // download traffic.
        let returned = if retain.is_empty() {
            for o in &outputs {
                self.stats.download_bytes += o.byte_size() as u64;
            }
            outputs
        } else {
            let mut kept = Vec::new();
            for (out, slot) in outputs.into_iter().zip(retain) {
                match slot {
                    Some(key) => self.retain_output(&key, &out)?,
                    None => {
                        self.stats.download_bytes +=
                            out.byte_size() as u64;
                        kept.push(out);
                    }
                }
            }
            kept
        };
        self.trim_to_budget();
        Ok(returned)
    }

    /// Store one just-computed output in the buffer cache under `key`.
    /// Always a fresh insert content-wise (the value was computed this
    /// call), so any resident buffer on the slot is dropped first.
    /// Device-side retention, not host traffic: counts toward
    /// [`ServiceStats::outputs_retained`] and the cache byte gauges,
    /// never toward `upload_bytes`.
    fn retain_output(&mut self, key: &BufferKey, data: &TensorData)
        -> Result<(), RuntimeError> {
        let mk = (key.layer, key.tensor.clone());
        if let Some(old) = self.cache.remove(&mk) {
            self.stats.cache_bytes -= old.bytes;
            self.stats.cache_invalidations += 1;
        }
        let buf = self.backend.upload(data)?;
        let bytes = data.byte_size() as u64;
        self.tick += 1;
        self.cache.insert(mk, CachedBuf {
            buf,
            generation: key.generation,
            bytes,
            last_used: self.tick,
        });
        self.stats.outputs_retained += 1;
        self.stats.cache_bytes += bytes;
        self.stats.cache_peak_bytes =
            self.stats.cache_peak_bytes.max(self.stats.cache_bytes);
        Ok(())
    }
}
