//! PJRT runtime service: a dedicated thread owning the (non-`Send`)
//! client and compiled executables, serving execute requests over
//! channels.
//!
//! Artifact flow (see /opt/xla-example/load_hlo for the pattern):
//!   HLO text --HloModuleProto::from_text_file--> proto
//!            --XlaComputation::from_proto--> computation
//!            --client.compile--> PjRtLoadedExecutable (cached)
//! Executions pack [`TensorData`] into `xla::Literal`s, run, then
//! decompose the single tuple output back into `TensorData`s (the PJRT
//! wrapper returns tupled results; see DESIGN.md runtime notes).

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::runtime::manifest::{DType, Manifest};
use crate::runtime::tensor_data::TensorData;

#[derive(Debug)]
pub enum RuntimeError {
    Msg(String),
    Xla(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Msg(s) => write!(f, "runtime: {s}"),
            RuntimeError::Xla(s) => write!(f, "xla: {s}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<String> for RuntimeError {
    fn from(s: String) -> Self {
        RuntimeError::Msg(s)
    }
}

type ExecResult = Result<Vec<TensorData>, RuntimeError>;

enum Request {
    Exec {
        artifact: String,
        inputs: Vec<TensorData>,
        reply: mpsc::Sender<ExecResult>,
    },
    /// Compile without executing (warm the cache).
    Preload {
        artifact: String,
        reply: mpsc::Sender<Result<(), RuntimeError>>,
    },
    Stats {
        reply: mpsc::Sender<ServiceStats>,
    },
    Shutdown,
}

#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub executions: u64,
    pub compiles: u64,
    pub exec_nanos: u64,
    pub pack_nanos: u64,
    pub unpack_nanos: u64,
    pub compile_nanos: u64,
}

impl ServiceStats {
    pub fn exec_seconds(&self) -> f64 {
        self.exec_nanos as f64 / 1e9
    }
}

/// Handle to the runtime service; cheap to clone and `Send`.
#[derive(Clone)]
pub struct Runtime {
    tx: mpsc::Sender<Request>,
    manifest: Arc<Manifest>,
    _join: Arc<JoinGuard>,
}

struct JoinGuard {
    tx: mpsc::Sender<Request>,
    handle: Option<JoinHandle<()>>,
}

impl Drop for JoinGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Runtime {
    /// Start the service: load the manifest and spawn the PJRT thread.
    pub fn start(artifact_dir: impl AsRef<std::path::Path>)
        -> Result<Runtime, RuntimeError> {
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        let (tx, rx) = mpsc::channel::<Request>();
        let thread_manifest = Arc::clone(&manifest);
        let handle = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || service_main(rx, thread_manifest))
            .map_err(|e| RuntimeError::Msg(e.to_string()))?;
        Ok(Runtime {
            tx: tx.clone(),
            manifest,
            _join: Arc::new(JoinGuard { tx, handle: Some(handle) }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact by name; validates signatures against the
    /// manifest on both sides.
    pub fn execute(&self, artifact: &str, inputs: Vec<TensorData>)
        -> ExecResult {
        let entry = self.manifest.artifact(artifact)?;
        if inputs.len() != entry.inputs.len() {
            return Err(RuntimeError::Msg(format!(
                "{artifact}: expected {} inputs, got {}",
                entry.inputs.len(), inputs.len())));
        }
        for (i, (t, sig)) in inputs.iter().zip(&entry.inputs).enumerate() {
            t.check_sig(sig, &format!("{artifact} input {i}"))?;
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx.send(Request::Exec {
            artifact: artifact.to_string(),
            inputs,
            reply: reply_tx,
        }).map_err(|_| RuntimeError::Msg("service stopped".into()))?;
        reply_rx.recv()
            .map_err(|_| RuntimeError::Msg("service dropped reply".into()))?
    }

    /// Compile an artifact ahead of first use.
    pub fn preload(&self, artifact: &str) -> Result<(), RuntimeError> {
        let _ = self.manifest.artifact(artifact)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx.send(Request::Preload {
            artifact: artifact.to_string(),
            reply: reply_tx,
        }).map_err(|_| RuntimeError::Msg("service stopped".into()))?;
        reply_rx.recv()
            .map_err(|_| RuntimeError::Msg("service dropped reply".into()))?
    }

    pub fn stats(&self) -> ServiceStats {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self.tx.send(Request::Stats { reply: reply_tx }).is_err() {
            return ServiceStats::default();
        }
        reply_rx.recv().unwrap_or_default()
    }
}

// --- service thread --------------------------------------------------------

struct Service {
    client: xla::PjRtClient,
    manifest: Arc<Manifest>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: ServiceStats,
}

fn service_main(rx: mpsc::Receiver<Request>, manifest: Arc<Manifest>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the construction error.
            for req in rx {
                match req {
                    Request::Exec { reply, .. } => {
                        let _ = reply.send(Err(RuntimeError::Xla(
                            format!("client init failed: {e:?}"))));
                    }
                    Request::Preload { reply, .. } => {
                        let _ = reply.send(Err(RuntimeError::Xla(
                            format!("client init failed: {e:?}"))));
                    }
                    Request::Stats { reply } => {
                        let _ = reply.send(ServiceStats::default());
                    }
                    Request::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut svc = Service {
        client,
        manifest,
        executables: HashMap::new(),
        stats: ServiceStats::default(),
    };
    for req in rx {
        match req {
            Request::Exec { artifact, inputs, reply } => {
                let _ = reply.send(svc.execute(&artifact, inputs));
            }
            Request::Preload { artifact, reply } => {
                let _ = reply.send(svc.ensure_compiled(&artifact)
                                   .map(|_| ()));
            }
            Request::Stats { reply } => {
                let _ = reply.send(svc.stats.clone());
            }
            Request::Shutdown => break,
        }
    }
}

impl Service {
    fn ensure_compiled(&mut self, artifact: &str)
        -> Result<&xla::PjRtLoadedExecutable, RuntimeError> {
        if !self.executables.contains_key(artifact) {
            let entry = self.manifest.artifact(artifact)?.clone();
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .map_err(|e| RuntimeError::Xla(format!(
                    "parse {}: {e:?}", entry.file.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)
                .map_err(|e| RuntimeError::Xla(format!(
                    "compile {artifact}: {e:?}")))?;
            self.stats.compiles += 1;
            self.stats.compile_nanos += t0.elapsed().as_nanos() as u64;
            self.executables.insert(artifact.to_string(), exe);
        }
        Ok(&self.executables[artifact])
    }

    fn execute(&mut self, artifact: &str, inputs: Vec<TensorData>)
        -> ExecResult {
        let entry = self.manifest.artifact(artifact)?.clone();
        self.ensure_compiled(artifact)?;

        // Upload inputs as PjRtBuffers we own and run via `execute_b`.
        // The crate's literal-based `execute` leaks every input device
        // buffer (xla_rs.cc releases them and never frees), which OOMs
        // long runs — see EXPERIMENTS.md §Perf iteration 4.
        let t0 = Instant::now();
        let buffers: Vec<xla::PjRtBuffer> = inputs.iter()
            .map(|t| pack_buffer(&self.client, t))
            .collect::<Result<_, _>>()?;
        let t_pack = t0.elapsed();

        let exe = &self.executables[artifact];
        let t1 = Instant::now();
        let result = exe.execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| RuntimeError::Xla(format!(
                "execute {artifact}: {e:?}")))?;
        drop(buffers); // input device memory freed here
        let t_exec = t1.elapsed();

        let t2 = Instant::now();
        let mut tuple = result[0][0].to_literal_sync()
            .map_err(|e| RuntimeError::Xla(format!("{e:?}")))?;
        let parts = tuple.decompose_tuple()
            .map_err(|e| RuntimeError::Xla(format!("{e:?}")))?;
        if parts.len() != entry.outputs.len() {
            return Err(RuntimeError::Msg(format!(
                "{artifact}: manifest declares {} outputs, PJRT returned {}",
                entry.outputs.len(), parts.len())));
        }
        let outputs: Vec<TensorData> = parts.iter().zip(&entry.outputs)
            .map(|(lit, sig)| unpack_literal(lit, sig.dtype,
                                             &sig.dims))
            .collect::<Result<_, _>>()?;
        let t_unpack = t2.elapsed();

        self.stats.executions += 1;
        self.stats.pack_nanos += t_pack.as_nanos() as u64;
        self.stats.exec_nanos += t_exec.as_nanos() as u64;
        self.stats.unpack_nanos += t_unpack.as_nanos() as u64;
        Ok(outputs)
    }
}

fn pack_buffer(client: &xla::PjRtClient, t: &TensorData)
    -> Result<xla::PjRtBuffer, RuntimeError> {
    // Use the *typed* upload: the crate's `buffer_from_host_raw_bytes`
    // passes an `ElementType` discriminant where the C side expects a
    // `PrimitiveType`, silently creating a buffer of the wrong dtype
    // (F32 -> F16).  The typed variant converts correctly.
    match t {
        TensorData::F32 { dims, data } => {
            client.buffer_from_host_buffer::<f32>(data, dims, None)
        }
        TensorData::I32 { dims, data } => {
            client.buffer_from_host_buffer::<i32>(data, dims, None)
        }
    }
    .map_err(|e| RuntimeError::Xla(format!("pack buffer: {e:?}")))
}

fn unpack_literal(lit: &xla::Literal, dtype: DType, dims: &[usize])
    -> Result<TensorData, RuntimeError> {
    match dtype {
        DType::F32 => {
            let data = lit.to_vec::<f32>()
                .map_err(|e| RuntimeError::Xla(format!("{e:?}")))?;
            Ok(TensorData::F32 { dims: dims.to_vec(), data })
        }
        DType::I32 => {
            let data = lit.to_vec::<i32>()
                .map_err(|e| RuntimeError::Xla(format!("{e:?}")))?;
            Ok(TensorData::I32 { dims: dims.to_vec(), data })
        }
    }
}
