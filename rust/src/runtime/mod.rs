//! Runtime layer: manifest-driven loading and execution of the AOT
//! HLO artifacts produced by `python/compile/aot.py`, through
//! pluggable backends (PJRT under `--features xla`, a pure-Rust
//! artifact interpreter otherwise), a per-device service thread with
//! a persistent device-buffer cache, and a multi-device
//! [`RuntimePool`] with work-stealing dispatch.

pub mod backend;
pub mod compile_cache;
pub mod faults;
pub mod interp_model;
pub mod manifest;
pub mod pool;
pub mod service;
pub mod tensor_data;
pub mod testutil;

pub use backend::{Backend, DefaultBackend, InterpBackend};
pub use compile_cache::CompileCache;
pub use faults::{FaultPlan, FaultyBackend};
pub use manifest::{ArtifactEntry, Manifest, ModelMeta, PrunableLayer};
pub use pool::RuntimePool;
pub use service::{
    BufferKey, ExecInput, PhaseTraffic, Runtime, RuntimeError,
    RuntimeOptions, ServiceStats, DEFAULT_DEVICE_MEM_BUDGET,
};
pub use tensor_data::TensorData;
