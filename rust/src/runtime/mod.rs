//! PJRT runtime: manifest-driven loading and execution of the AOT HLO
//! artifacts produced by `python/compile/aot.py`.

pub mod manifest;
pub mod service;
pub mod tensor_data;

pub use manifest::{ArtifactEntry, Manifest, ModelMeta, PrunableLayer};
pub use service::{Runtime, RuntimeError, ServiceStats};
pub use tensor_data::TensorData;
