//! Host-side tensors for crossing the runtime-service channel.
//!
//! `xla::Literal` wraps a raw pointer and is not `Send`; the service
//! thread owns all PJRT objects, and callers exchange [`TensorData`]
//! (plain `Vec`s + dims), which the service packs/unpacks at the
//! boundary.

use crate::runtime::manifest::{DType, TensorSig};
use crate::util::tensor::Matrix;

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl TensorData {
    pub fn scalar_f32(v: f32) -> TensorData {
        TensorData::F32 { dims: vec![], data: vec![v] }
    }

    pub fn scalar_i32(v: i32) -> TensorData {
        TensorData::I32 { dims: vec![], data: vec![v] }
    }

    pub fn zeros(sig: &TensorSig) -> TensorData {
        let n = sig.element_count();
        match sig.dtype {
            DType::F32 => TensorData::F32 { dims: sig.dims.clone(),
                                            data: vec![0.0; n] },
            DType::I32 => TensorData::I32 { dims: sig.dims.clone(),
                                            data: vec![0; n] },
        }
    }

    pub fn from_matrix(m: &Matrix) -> TensorData {
        TensorData::F32 { dims: vec![m.rows, m.cols],
                          data: m.data.clone() }
    }

    pub fn into_matrix(self) -> Result<Matrix, String> {
        match self {
            TensorData::F32 { dims, data } if dims.len() == 2 => {
                Ok(Matrix::from_vec(dims[0], dims[1], data))
            }
            other => Err(format!("not a 2-D f32 tensor: {:?}",
                                 other.sig())),
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            TensorData::F32 { dims, .. } | TensorData::I32 { dims, .. } =>
                dims,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32 { .. } => DType::F32,
            TensorData::I32 { .. } => DType::I32,
        }
    }

    pub fn sig(&self) -> TensorSig {
        TensorSig { dims: self.dims().to_vec(), dtype: self.dtype() }
    }

    pub fn element_count(&self) -> usize {
        self.dims().iter().product()
    }

    /// Host/device footprint in bytes (both dtypes are 4-byte).
    pub fn byte_size(&self) -> usize {
        self.element_count() * self.dtype().byte_size()
    }

    pub fn as_f32(&self) -> Result<&[f32], String> {
        match self {
            TensorData::F32 { data, .. } => Ok(data),
            _ => Err("expected f32 tensor".into()),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32], String> {
        match self {
            TensorData::F32 { data, .. } => Ok(data),
            _ => Err("expected f32 tensor".into()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32], String> {
        match self {
            TensorData::I32 { data, .. } => Ok(data),
            _ => Err("expected i32 tensor".into()),
        }
    }

    /// First element as f64 (scalar outputs: losses, counts, steps).
    pub fn scalar_value(&self) -> Result<f64, String> {
        match self {
            TensorData::F32 { data, .. } =>
                data.first().copied().map(|v| v as f64)
                    .ok_or_else(|| "empty tensor".into()),
            TensorData::I32 { data, .. } =>
                data.first().copied().map(|v| v as f64)
                    .ok_or_else(|| "empty tensor".into()),
        }
    }

    /// Validate against a manifest signature.
    pub fn check_sig(&self, want: &TensorSig, what: &str)
        -> Result<(), String> {
        let got = self.sig();
        if &got != want {
            return Err(format!(
                "{what}: tensor signature mismatch: got {:?} {:?}, \
                 want {:?} {:?}", got.dtype, got.dims, want.dtype,
                want.dims));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_round_trip() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let t = TensorData::from_matrix(&m);
        assert_eq!(t.dims(), &[3, 4]);
        let back = t.into_matrix().unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn zeros_matches_sig() {
        let sig = TensorSig { dims: vec![2, 3], dtype: DType::I32 };
        let t = TensorData::zeros(&sig);
        assert_eq!(t.element_count(), 6);
        assert_eq!(t.dtype(), DType::I32);
        t.check_sig(&sig, "t").unwrap();
    }

    #[test]
    fn sig_mismatch_detected() {
        let t = TensorData::scalar_f32(1.0);
        let bad = TensorSig { dims: vec![1], dtype: DType::F32 };
        assert!(t.check_sig(&bad, "t").is_err());
    }

    #[test]
    fn scalar_access() {
        assert_eq!(TensorData::scalar_f32(2.5).scalar_value().unwrap(), 2.5);
        assert_eq!(TensorData::scalar_i32(7).scalar_value().unwrap(), 7.0);
    }
}
