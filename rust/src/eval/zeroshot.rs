//! Synthetic zero-shot harness (the EleutherAI-suite stand-in; see
//! DESIGN.md section 2).
//!
//! Tasks are 4-way multiple-choice continuations built from the corpus
//! grammar: a context is sampled from the Markov chain, the gold answer
//! is the chain's most likely successor of the final word, distractors
//! are unigram-sampled words that are *not* successors.  Scoring follows
//! lm-eval: each (context + choice) sequence is scored by the summed NLL
//! of the choice tokens (via the `seq_nll_{cfg}` artifact); the lowest
//! NLL wins.  Chance accuracy is 25%.

use crate::data::Dataset;
use crate::eval::fan_indexed;
use crate::model::store::ParamStore;
use crate::runtime::pool::RuntimePool;
use crate::runtime::service::{Runtime, RuntimeError};
use crate::runtime::tensor_data::TensorData;
use crate::util::prng::Rng;

pub const N_CHOICES: usize = 4;

#[derive(Clone, Debug)]
pub struct Task {
    /// Token ids of (context + choice) per choice.
    pub choice_ids: Vec<Vec<i32>>,
    /// First target index of the choice span per choice.
    pub span_start: Vec<usize>,
    pub gold: usize,
}

/// Build `n_tasks` deterministic tasks from the grammar.
pub fn build_tasks(ds: &Dataset, meta_vocab: usize, n_tasks: usize,
                   seed: u64) -> Vec<Task> {
    let mut rng = Rng::new(seed ^ 0x5a45524f);
    let g = &ds.grammar;
    let mut tasks = Vec::with_capacity(n_tasks);
    while tasks.len() < n_tasks {
        // Sample a context path through the chain.
        let mut cur = rng.weighted_index(&g.unigram);
        let mut ctx_words = vec![g.words[cur].clone()];
        for _ in 0..7 {
            cur = g.next_word(cur, &mut rng);
            ctx_words.push(g.words[cur].clone());
        }
        let gold_word = g.best_successor(cur);
        let succ = g.successors(cur);
        // Distractors: non-successor words.
        let mut distractors = Vec::new();
        let mut guard = 0;
        while distractors.len() < N_CHOICES - 1 && guard < 1000 {
            guard += 1;
            let w = rng.weighted_index(&g.unigram);
            if w != gold_word && !succ.contains(&w)
                && !distractors.contains(&w) {
                distractors.push(w);
            }
        }
        if distractors.len() < N_CHOICES - 1 {
            continue;
        }
        let mut choices = vec![gold_word];
        choices.extend(distractors);
        // Shuffle choices, remembering the gold position.
        let mut order: Vec<usize> = (0..N_CHOICES).collect();
        rng.shuffle(&mut order);
        let gold = order.iter().position(|&i| i == 0).unwrap();
        let context = ctx_words.join(" ");
        let ctx_ids = encode_clamped(ds, meta_vocab, &context);
        let mut choice_ids = Vec::with_capacity(N_CHOICES);
        let mut span_start = Vec::with_capacity(N_CHOICES);
        let mut ok = true;
        for &oi in &order {
            let full = format!("{context} {}", g.words[choices[oi]]);
            let ids = encode_clamped(ds, meta_vocab, &full);
            if ids.len() <= ctx_ids.len() {
                ok = false;
                break;
            }
            // Targets are tokens shifted by one: predicting choice token
            // at position t means target index t-1.
            span_start.push(ctx_ids.len() - 1);
            choice_ids.push(ids);
        }
        if ok {
            tasks.push(Task { choice_ids, span_start, gold });
        }
    }
    tasks
}

fn encode_clamped(ds: &Dataset, vocab: usize, text: &str) -> Vec<i32> {
    ds.tokenizer.encode(text)
        .into_iter()
        .map(|t| (t as usize).min(vocab - 1) as i32)
        .collect()
}

/// Lowest-NLL choice index under lm-eval rules.  NaN scores never win
/// (treated as +∞ — a poisoned model must not get credit); `None`
/// when every choice is non-finite, which callers count as incorrect.
pub fn pick_best(nlls: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &raw) in nlls.iter().enumerate() {
        let v = if raw.is_nan() { f64::INFINITY } else { raw };
        if best.is_none_or(|(_, bv)| v < bv) {
            best = Some((i, v));
        }
    }
    best.and_then(|(i, v)| v.is_finite().then_some(i))
}

/// Summed choice-span NLL per (task, choice), batched through the
/// `seq_nll_{cfg}` artifact.  Sequences longer than seq_len + 1 keep
/// their tail (the choice span must survive the truncation); the mask
/// window is shifted accordingly.  Chunks fan across `workers` with
/// the weight tensors device-cached; each (task, choice) cell is
/// written exactly once from its own chunk's output, so the score
/// table is identical at any device count.
fn score_tasks_workers(workers: &[Runtime], pool: Option<&RuntimePool>,
                       store: &ParamStore, tasks: &[Task])
    -> Result<Vec<Vec<f64>>, RuntimeError> {
    let meta = &store.meta;
    let artifact = format!("seq_nll_{}", meta.name);
    let (b, l) = (meta.batch, meta.seq_len);

    // Flatten all (task, choice) sequences, then batch them.
    struct Seq {
        task: usize,
        choice: usize,
        ids: Vec<i32>,
        span_start: usize,
    }
    let mut seqs = Vec::new();
    for (ti, t) in tasks.iter().enumerate() {
        for c in 0..N_CHOICES {
            seqs.push(Seq {
                task: ti,
                choice: c,
                ids: t.choice_ids[c].clone(),
                span_start: t.span_start[c],
            });
        }
    }
    let chunks: Vec<&[Seq]> = seqs.chunks(b).collect();
    let mut items = Vec::with_capacity(chunks.len());
    for chunk in &chunks {
        let mut tokens = vec![0i32; b * l];
        let mut targets = vec![0i32; b * l];
        let mut mask = vec![0.0f32; b * l];
        for (row, s) in chunk.iter().enumerate() {
            let ids = if s.ids.len() > l + 1 {
                // Keep the tail (the choice span must survive).
                &s.ids[s.ids.len() - (l + 1)..]
            } else {
                &s.ids[..]
            };
            let shift = s.ids.len().saturating_sub(l + 1);
            let n = ids.len().min(l + 1);
            for t in 0..n.saturating_sub(1) {
                tokens[row * l + t] = ids[t];
                targets[row * l + t] = ids[t + 1];
            }
            let start = s.span_start.saturating_sub(shift);
            let end = (s.ids.len() - 1 - shift).min(l);
            for t in start..end {
                mask[row * l + t] = 1.0;
            }
        }
        items.push(vec![
            TensorData::I32 { dims: vec![b, l], data: tokens },
            TensorData::I32 { dims: vec![b, l], data: targets },
            TensorData::F32 { dims: vec![b, l], data: mask },
        ]);
    }
    let outs = fan_indexed(workers, pool, store, &artifact, &items)?;
    let mut nlls = vec![vec![f64::INFINITY; N_CHOICES]; tasks.len()];
    for (chunk, out) in chunks.iter().zip(&outs) {
        if out.is_empty() {
            return Err(RuntimeError::BadOutputArity {
                artifact: artifact.clone(),
                expected: 1,
                got: 0,
            });
        }
        let vals = out[0].as_f32()?;
        for (row, s) in chunk.iter().enumerate() {
            nlls[s.task][s.choice] = vals[row] as f64;
        }
    }
    Ok(nlls)
}

/// [`score_tasks_workers`] on a single runtime worker.
pub fn score_tasks(rt: &Runtime, store: &ParamStore, tasks: &[Task])
    -> Result<Vec<Vec<f64>>, RuntimeError> {
    score_tasks_workers(std::slice::from_ref(rt), None, store, tasks)
}

/// [`score_tasks`] fanned across a pool's healthy workers.
pub fn score_tasks_pool(pool: &RuntimePool, store: &ParamStore,
                        tasks: &[Task])
    -> Result<Vec<Vec<f64>>, RuntimeError> {
    score_tasks_workers(&pool.healthy_runtimes(), Some(pool), store,
                        tasks)
}

/// Score tasks with the model; returns accuracy in [0, 1].  A task
/// whose best score is NaN or otherwise non-finite counts as
/// incorrect (the old implementation panicked on NaN via
/// `partial_cmp(..).unwrap()`).
pub fn accuracy(rt: &Runtime, store: &ParamStore, tasks: &[Task])
    -> Result<f64, RuntimeError> {
    let nlls = score_tasks(rt, store, tasks)?;
    Ok(accuracy_from_scores(tasks, &nlls))
}

/// [`accuracy`] with scoring fanned across a pool's healthy workers.
pub fn accuracy_pool(pool: &RuntimePool, store: &ParamStore,
                     tasks: &[Task]) -> Result<f64, RuntimeError> {
    let nlls = score_tasks_pool(pool, store, tasks)?;
    Ok(accuracy_from_scores(tasks, &nlls))
}

fn accuracy_from_scores(tasks: &[Task], nlls: &[Vec<f64>]) -> f64 {
    let correct = tasks.iter()
        .zip(nlls)
        .filter(|(t, scores)| pick_best(scores) == Some(t.gold))
        .count();
    correct as f64 / tasks.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_meta;

    #[test]
    fn tasks_are_well_formed() {
        let meta = tiny_meta();
        let ds = Dataset::build(&meta, 3);
        let tasks = build_tasks(&ds, meta.vocab, 20, 1);
        assert_eq!(tasks.len(), 20);
        for t in &tasks {
            assert_eq!(t.choice_ids.len(), N_CHOICES);
            assert!(t.gold < N_CHOICES);
            for (ids, &start) in t.choice_ids.iter().zip(&t.span_start) {
                assert!(start < ids.len() - 1);
                assert!(ids.iter().all(|&i| (i as usize) < meta.vocab));
            }
        }
    }

    #[test]
    fn tasks_deterministic() {
        let meta = tiny_meta();
        let ds = Dataset::build(&meta, 3);
        let a = build_tasks(&ds, meta.vocab, 5, 9);
        let b = build_tasks(&ds, meta.vocab, 5, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.gold, y.gold);
            assert_eq!(x.choice_ids, y.choice_ids);
        }
    }

    #[test]
    fn pick_best_prefers_lowest_nll() {
        assert_eq!(pick_best(&[3.0, 1.0, 2.0, 4.0]), Some(1));
        assert_eq!(pick_best(&[0.5]), Some(0));
    }

    #[test]
    fn pick_best_treats_nan_as_never_winning() {
        // Poisoned gold row: NaN must lose to every finite score, not
        // panic (the old partial_cmp().unwrap() aborted here).
        assert_eq!(pick_best(&[f64::NAN, 2.0, 3.0, 4.0]), Some(1));
        assert_eq!(pick_best(&[2.0, f64::NAN, 1.5, 4.0]), Some(2));
        // All-poisoned (or never-scored) rows: no winner, so the task
        // counts as incorrect.
        assert_eq!(pick_best(&[f64::NAN; 4]), None);
        assert_eq!(pick_best(&[f64::INFINITY; 4]), None);
        assert_eq!(pick_best(&[f64::NAN, f64::INFINITY]), None);
    }

    #[test]
    fn gold_positions_are_shuffled() {
        let meta = tiny_meta();
        let ds = Dataset::build(&meta, 3);
        let tasks = build_tasks(&ds, meta.vocab, 40, 2);
        let positions: std::collections::HashSet<usize> =
            tasks.iter().map(|t| t.gold).collect();
        assert!(positions.len() >= 3, "{positions:?}");
    }
}
