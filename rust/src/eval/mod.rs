//! Evaluation harness: perplexity (WikiText stand-in) and synthetic
//! zero-shot accuracy (EleutherAI-suite stand-in).

pub mod zeroshot;

use crate::model::store::ParamStore;
use crate::runtime::service::{Runtime, RuntimeError};
use crate::runtime::tensor_data::TensorData;

/// Perplexity of `store` over held-out batches: exp(total_nll / tokens).
pub fn perplexity(rt: &Runtime, store: &ParamStore,
                  batches: &[(TensorData, TensorData)])
    -> Result<f64, RuntimeError> {
    let artifact = format!("eval_step_{}", store.meta.name);
    let mut nll = 0.0f64;
    let mut count = 0.0f64;
    for (tokens, targets) in batches {
        let mut inputs = store.tensor_args();
        inputs.push(tokens.clone());
        inputs.push(targets.clone());
        let out = rt.execute(&artifact, inputs)?;
        nll += out[0].scalar_value()?;
        count += out[1].scalar_value()?;
    }
    if count == 0.0 {
        return Err(RuntimeError::Msg("no eval tokens".into()));
    }
    Ok((nll / count).exp())
}

#[cfg(test)]
mod tests {
    // Runtime-dependent tests live in rust/tests/pipeline_e2e.rs; here we
    // only check the ppl arithmetic contract via a tiny helper.
    #[test]
    fn ppl_formula() {
        let nll = 2.0f64 * 100.0;
        let count = 100.0;
        assert!(((nll / count).exp() - 2.0f64.exp()).abs() < 1e-12);
    }
}
