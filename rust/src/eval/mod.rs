//! Evaluation harness: perplexity (WikiText stand-in) and synthetic
//! zero-shot accuracy (EleutherAI-suite stand-in).
//!
//! Both evaluators run as `RuntimePool` workloads: eval items (ppl
//! batches, zero-shot sequence chunks) fan round-robin across the
//! pool's healthy workers with the weight tensors cached per device
//! (shipped once, then key-only [`ExecInput::CachedRef`] probes), and
//! the per-item results reduce on the host in ascending item order.
//! Each item's numbers are computed independently — no cross-item f32
//! chain — so the f64 NLL reduction is bit-identical for any device
//! count, serial included (the serial path runs the same driver over
//! a one-worker set).

pub mod zeroshot;

use std::sync::Arc;

use crate::model::store::ParamStore;
use crate::runtime::pool::RuntimePool;
use crate::runtime::service::{
    next_buffer_layer_id, BufferKey, ExecInput, Runtime, RuntimeError,
};
use crate::runtime::tensor_data::TensorData;

/// Residency retries per worker before a batch gives up on the cached
/// protocol (covers weights evicted by a tiny device budget).
const RESIDENT_ATTEMPTS: usize = 4;

/// Execute `artifact` once per item, fanning items across `workers`
/// round-robin (item i → worker i mod n).  Every call's inputs are the
/// store's weight tensors followed by the item's inline tail
/// (tokens/targets/mask); weights upload once per worker and are
/// probed key-only afterwards, so steady-state items ship only their
/// own tensors.  Items are independent, so results are returned in
/// item order regardless of which worker produced them — the caller's
/// ordered reduction sees the same sequence at any device count.
/// Transient worker faults re-run the item on the next healthy worker
/// (weights attached) and feed the pool's quarantine accounting.
pub(crate) fn fan_indexed(workers: &[Runtime],
                          pool: Option<&RuntimePool>,
                          store: &ParamStore, artifact: &str,
                          items: &[Vec<TensorData>])
    -> Result<Vec<Vec<TensorData>>, RuntimeError> {
    assert!(!workers.is_empty(), "eval needs at least one worker");
    let n = workers.len();
    let weights_id = next_buffer_layer_id();

    // One call in the cached-weight protocol.  `attached` ships the
    // weights (first call per worker, or after a residency miss).
    let call = |rt: &Runtime, item: &[TensorData], attached: bool|
        -> Result<Vec<TensorData>, RuntimeError> {
        let mut inputs: Vec<ExecInput> =
            Vec::with_capacity(store.tensors.len() + item.len());
        for (pi, p) in store.tensors.iter().enumerate() {
            let key = BufferKey {
                layer: weights_id,
                tensor: format!("p{pi}"),
                generation: 0,
            };
            inputs.push(if attached {
                ExecInput::Cached { key, data: Arc::clone(p) }
            } else {
                ExecInput::CachedRef { key }
            });
        }
        inputs.extend(item.iter().cloned().map(ExecInput::Inline));
        rt.execute_cached(artifact, inputs)
    };

    // Phase 1: each worker walks its own item subset.  A transient
    // worker failure abandons the rest of that worker's items to the
    // fallback phase instead of spinning on a dead service.
    type WorkerOut = (Vec<(usize, Vec<TensorData>)>,
                      Option<RuntimeError>);
    let per_worker: Vec<WorkerOut> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n).map(|w| {
            let ids: Vec<usize> = (w..items.len()).step_by(n).collect();
            let rt = workers[w].clone();
            let call = &call;
            scope.spawn(move || {
                let mut done = Vec::with_capacity(ids.len());
                let mut attached = true;
                let mut residency_misses = 0usize;
                let mut pos = 0usize;
                while pos < ids.len() {
                    let i = ids[pos];
                    match call(&rt, &items[i], attached) {
                        Ok(out) => {
                            done.push((i, out));
                            attached = false;
                            pos += 1;
                        }
                        Err(RuntimeError::NotResident(_))
                            if residency_misses < RESIDENT_ATTEMPTS => {
                            residency_misses += 1;
                            attached = true;
                        }
                        Err(e) => return (done, Some(e)),
                    }
                }
                (done, None)
            })
        }).collect();
        handles.into_iter()
            .map(|h| h.join().unwrap_or_else(|_| (
                Vec::new(),
                Some(RuntimeError::Msg("eval worker panicked".into())))))
            .collect()
    });

    let mut slots: Vec<Option<Vec<TensorData>>> =
        (0..items.len()).map(|_| None).collect();
    let mut failed_workers = vec![false; n];
    let mut first_err: Option<RuntimeError> = None;
    for (w, (done, err)) in per_worker.into_iter().enumerate() {
        let ran = !done.is_empty();
        for (i, out) in done {
            slots[i] = Some(out);
        }
        if let Some(e) = err {
            failed_workers[w] = true;
            if let Some(p) = pool {
                p.report_worker_outcome(workers[w].device(), false);
            }
            if !e.is_transient() {
                // Deterministic failure: no worker can fix it.
                first_err = Some(first_err.unwrap_or(e));
            }
        } else if ran {
            // A worker with zero items ran nothing — no outcome.
            if let Some(p) = pool {
                p.report_worker_outcome(workers[w].device(), true);
            }
        }
    }
    if let Some(e) = first_err {
        for w in workers {
            w.invalidate(weights_id);
        }
        return Err(e);
    }

    // Phase 2: items stranded by a failed worker retry on the
    // surviving workers with the weights attached.
    let alive: Vec<usize> =
        (0..n).filter(|&w| !failed_workers[w]).collect();
    let mut next_alive = 0usize;
    for i in 0..slots.len() {
        if slots[i].is_some() {
            continue;
        }
        let mut attempts = 0usize;
        loop {
            if alive.is_empty() || attempts > alive.len() {
                for w in workers {
                    w.invalidate(weights_id);
                }
                return Err(RuntimeError::Transient(
                    "eval item failed on every healthy worker".into()));
            }
            let w = alive[next_alive % alive.len()];
            next_alive += 1;
            match call(&workers[w], &items[i], true) {
                Ok(out) => {
                    slots[i] = Some(out);
                    if let Some(p) = pool {
                        p.note_shard_retry();
                    }
                    break;
                }
                Err(e) if e.is_transient() => attempts += 1,
                Err(e) => {
                    for w in workers {
                        w.invalidate(weights_id);
                    }
                    return Err(e);
                }
            }
        }
    }
    for w in workers {
        w.invalidate(weights_id);
    }
    Ok(slots.into_iter().map(|s| s.expect("every item filled"))
        .collect())
}

fn perplexity_workers(workers: &[Runtime], pool: Option<&RuntimePool>,
                      store: &ParamStore,
                      batches: &[(TensorData, TensorData)])
    -> Result<f64, RuntimeError> {
    let artifact = format!("eval_step_{}", store.meta.name);
    let items: Vec<Vec<TensorData>> = batches.iter()
        .map(|(tokens, targets)| vec![tokens.clone(), targets.clone()])
        .collect();
    let outs = fan_indexed(workers, pool, store, &artifact, &items)?;
    let mut nll = 0.0f64;
    let mut count = 0.0f64;
    // Ordered f64 reduction in ascending batch index — the other half
    // of the any-device-count bit-identity contract.
    for out in &outs {
        if out.len() != 2 {
            return Err(RuntimeError::BadOutputArity {
                artifact: artifact.clone(),
                expected: 2,
                got: out.len(),
            });
        }
        nll += out[0].scalar_value()?;
        count += out[1].scalar_value()?;
    }
    if count == 0.0 {
        return Err(RuntimeError::Msg("no eval tokens".into()));
    }
    Ok((nll / count).exp())
}

/// Perplexity of `store` over held-out batches: exp(total_nll /
/// tokens), on a single runtime worker.  Redefined onto the fan +
/// ordered-reduce driver, so the result is bit-identical to
/// [`perplexity_pool`] at any device count.
pub fn perplexity(rt: &Runtime, store: &ParamStore,
                  batches: &[(TensorData, TensorData)])
    -> Result<f64, RuntimeError> {
    perplexity_workers(std::slice::from_ref(rt), None, store, batches)
}

/// [`perplexity`] fanned across a pool's healthy workers with an
/// ordered f64 NLL reduction.
pub fn perplexity_pool(pool: &RuntimePool, store: &ParamStore,
                       batches: &[(TensorData, TensorData)])
    -> Result<f64, RuntimeError> {
    perplexity_workers(&pool.healthy_runtimes(), Some(pool), store,
                       batches)
}

#[cfg(test)]
mod tests {
    // Runtime-dependent tests live in rust/tests/pipeline_e2e.rs and
    // rust/tests/calib.rs; here we only check the ppl arithmetic
    // contract via a tiny helper.
    #[test]
    fn ppl_formula() {
        let nll = 2.0f64 * 100.0;
        let count = 100.0;
        assert!(((nll / count).exp() - 2.0f64.exp()).abs() < 1e-12);
    }
}
