//! Calibration: streaming Gram-matrix accumulation through the
//! `calib_step_{cfg}` / `embed_{cfg}` / `calib_block_{cfg}` artifacts.
//!
//! The artifacts run the model forward on calibration batches and add
//! X^T X (plus feature sums) for each of the four activation streams of
//! every block (Sec 2.1.2: G accumulates on-the-fly; raw activations are
//! never materialised host-side).  Stats are stored per block so the
//! staged pipeline can release a block's Grams the moment its
//! refinement finishes — `GramView` borrows end with the block.
//!
//! # The striped accumulation contract
//!
//! f32 addition is not associative, so "sum the batches in whatever
//! order the devices finish" would make the Grams — and therefore the
//! refined masks — depend on the device count.  Instead *every* driver
//! (serial or pooled, stacked or streamed) decomposes the batch list
//! into the same [`CALIB_STRIPES`] fixed stripes: stripe `s` holds
//! batches `s, s + CALIB_STRIPES, ...`, accumulated in ascending batch
//! order as one device-side chain, and the stripe partials are reduced
//! on the host in ascending stripe order.  The decomposition is a
//! constant of the math, independent of how many workers happen to
//! execute the stripes, so Grams are **bit-identical for any device
//! count** — the same invariant style `refine_block` gives shard
//! schedules.  The stacked (`calib_step`) and streamed
//! (`embed`/`calib_block`) orders share the decomposition, so the two
//! paths stay bit-identical to each other as well.
//!
//! # Resident accumulators
//!
//! Within a stripe the running Gram/sum stacks never round-trip to the
//! host: the first batch uploads zeros inline and *retains* the
//! outputs in the device-buffer cache ([`Runtime::execute_retained`],
//! generation = batch index within the stripe); steady-state batches
//! name them back as key-only [`ExecInput::CachedRef`] probes and
//! upload only their token tensor (weights are cached under a per-pass
//! key and probed the same way); the stripe's last batch retains
//! nothing, so its outputs *are* the final download.  An evicted
//! accumulator (`RuntimeError::NotResident`) restarts the stripe, and
//! after repeated residency failures the stripe falls back to the
//! host-carried inline form — same adds in the same order, so the
//! result is bit-identical either way, just slower.

pub mod analysis;

use std::sync::Arc;

use crate::model::store::ParamStore;
use crate::pruning::dsnot::FeatureStats;
use crate::runtime::manifest::{ModelMeta, PrunableLayer};
use crate::runtime::pool::RuntimePool;
use crate::runtime::service::{
    next_buffer_layer_id, BufferKey, ExecInput, PhaseTraffic, Runtime,
    RuntimeError, ServiceStats,
};
use crate::runtime::tensor_data::TensorData;
use crate::util::tensor::GramView;

/// Stream order must match `calib_step`'s argument order (aot.py).
pub const STREAMS: [&str; 4] = ["qkv", "o", "gu", "down"];

/// Fixed stripe count of the deterministic batch decomposition (see
/// the module doc).  A constant, *not* a function of the worker count
/// and not CLI-tunable: it is mask-affecting, so changing it would
/// silently invalidate every journal fingerprint and golden curve.
/// Device counts 1/2/4 all divide it, so each worker owns a whole
/// number of stripes at the counts the benches gate.
pub const CALIB_STRIPES: usize = 4;

/// Residency-mode attempts per stripe before falling back to the
/// host-carried inline form (covers an accumulator evicted by a tiny
/// device budget — retrying resident would just evict again).
const RESIDENT_ATTEMPTS: usize = 2;

/// Accumulator tensor roles within a stripe's buffer-key namespace,
/// in `calib_step` / `calib_block` output order.
const ACC_TENSORS: [&str; 8] =
    ["g0", "g1", "g2", "g3", "s0", "s1", "s2", "s3"];

fn stream_index(stream: &str) -> usize {
    STREAMS.iter().position(|s| *s == stream)
        .unwrap_or_else(|| panic!("unknown stream {stream}"))
}

fn stream_width(meta: &ModelMeta, stream: &str) -> usize {
    if stream == "down" { meta.d_ff } else { meta.d_model }
}

/// Batch indices belonging to stripe `s` of an `n`-batch run, in the
/// ascending order the stripe's device chain consumes them.
fn stripe_batches(n: usize, s: usize) -> impl Iterator<Item = usize> {
    (s..n).step_by(CALIB_STRIPES)
}

/// Driver-side output-arity check: the service already validates
/// against the manifest, but the drivers additionally pin the counts
/// their split logic assumes, so a malformed calib artifact fails
/// loudly instead of corrupting stats.
fn expect_arity(artifact: &str, expected: usize, got: usize)
    -> Result<(), RuntimeError> {
    if got != expected {
        return Err(RuntimeError::BadOutputArity {
            artifact: artifact.to_string(),
            expected,
            got,
        });
    }
    Ok(())
}

/// Elementwise f32 `a += b` over one stat tensor pair (the host side
/// of the cross-stripe reduction; the add order is part of the
/// bit-identity contract).
fn add_tensor(a: &mut TensorData, b: &TensorData) {
    let dst = a.as_f32_mut().expect("stat tensors are f32");
    let src = b.as_f32().expect("stat tensors are f32");
    assert_eq!(dst.len(), src.len(), "stripe partial shape mismatch");
    for (x, y) in dst.iter_mut().zip(src) {
        *x += *y;
    }
}

/// One block's calibration statistics: a Gram matrix [d, d] and a
/// feature-sum vector [d] per activation stream, in [`STREAMS`] order.
#[derive(Clone, Debug)]
pub struct BlockStats {
    grams: Vec<TensorData>,
    sums: Vec<TensorData>,
}

impl BlockStats {
    pub fn zeros(meta: &ModelMeta) -> BlockStats {
        let grams = STREAMS.iter().map(|s| {
            let d = stream_width(meta, s);
            TensorData::F32 { dims: vec![d, d], data: vec![0.0; d * d] }
        }).collect();
        let sums = STREAMS.iter().map(|s| {
            let d = stream_width(meta, s);
            TensorData::F32 { dims: vec![d], data: vec![0.0; d] }
        }).collect();
        BlockStats { grams, sums }
    }

    /// Host bytes held by the stat tensors.
    pub fn byte_size(&self) -> usize {
        self.grams.iter().chain(self.sums.iter())
            .map(|t| t.byte_size()).sum()
    }

    /// Fold another stripe's partial into this one (ascending stripe
    /// order — see the module doc's determinism contract).
    fn add_assign(&mut self, o: &BlockStats) {
        for (a, b) in self.grams.iter_mut().zip(&o.grams) {
            add_tensor(a, b);
        }
        for (a, b) in self.sums.iter_mut().zip(&o.sums) {
            add_tensor(a, b);
        }
    }
}

#[derive(Clone, Debug)]
pub struct GramStats {
    pub meta: ModelMeta,
    /// Per-block stat slots; `None` once released (or not yet set, for
    /// hollow stats the streamed pipeline fills via [`set_block`]).
    ///
    /// [`set_block`]: GramStats::set_block
    blocks: Vec<Option<BlockStats>>,
    /// Total calibration tokens accumulated.
    pub tokens: usize,
    /// Batches consumed.
    pub batches: usize,
    /// Host/device traffic of the accumulation pass that produced
    /// these stats (zero for hollow/zeros stats filled elsewhere).
    pub traffic: PhaseTraffic,
}

impl GramStats {
    pub fn zeros(meta: &ModelMeta) -> GramStats {
        let blocks = (0..meta.n_blocks)
            .map(|_| Some(BlockStats::zeros(meta))).collect();
        GramStats {
            meta: meta.clone(),
            blocks,
            tokens: 0,
            batches: 0,
            traffic: PhaseTraffic::default(),
        }
    }

    /// Stats with every block slot empty — the streamed pipeline fills
    /// blocks one at a time as the prefetch stage produces them.
    pub fn hollow(meta: &ModelMeta) -> GramStats {
        GramStats {
            meta: meta.clone(),
            blocks: (0..meta.n_blocks).map(|_| None).collect(),
            tokens: 0,
            batches: 0,
            traffic: PhaseTraffic::default(),
        }
    }

    /// Install one block's stats (streamed accumulation).
    pub fn set_block(&mut self, block: usize, stats: BlockStats) {
        self.blocks[block] = Some(stats);
    }

    /// Drop one block's stats, returning the host bytes freed.
    /// Releasing an absent block is a no-op.
    pub fn release_block(&mut self, block: usize) -> usize {
        self.blocks[block].take().map_or(0, |s| s.byte_size())
    }

    pub fn block_resident(&self, block: usize) -> bool {
        self.blocks[block].is_some()
    }

    /// Host bytes currently held across all resident blocks.
    pub fn resident_bytes(&self) -> usize {
        self.blocks.iter().flatten().map(|s| s.byte_size()).sum()
    }

    fn block(&self, layer: &PrunableLayer) -> &BlockStats {
        self.blocks[layer.block].as_ref().unwrap_or_else(|| panic!(
            "gram stats for block {} are not resident \
             (released or not yet accumulated)", layer.block))
    }

    /// Raw Gram data of one (block, stream) pair — the exact-identity
    /// surface the pooled-vs-serial tests compare bitwise.
    pub fn stream_gram(&self, block: usize, si: usize) -> &[f32] {
        self.blocks[block].as_ref()
            .unwrap_or_else(|| panic!("block {block} not resident"))
            .grams[si].as_f32().unwrap()
    }

    /// Raw feature-sum data of one (block, stream) pair.
    pub fn stream_sum(&self, block: usize, si: usize) -> &[f32] {
        self.blocks[block].as_ref()
            .unwrap_or_else(|| panic!("block {block} not resident"))
            .sums[si].as_f32().unwrap()
    }

    /// Gram matrix for one prunable layer: a zero-copy [`GramView`]
    /// into its block's stream tensor (no d*d materialisation — at LLM
    /// widths the old per-access copy was 16M floats per layer).
    pub fn gram_for(&self, layer: &PrunableLayer) -> GramView<'_> {
        let si = stream_index(&layer.stream);
        let d = stream_width(&self.meta, &layer.stream);
        assert_eq!(d, layer.d_in);
        GramView::new(self.block(layer).grams[si].as_f32().unwrap(), d)
    }

    /// Gram diagonal for one layer (O(d) work — never materialises
    /// the d*d Gram).
    pub fn diag_for(&self, layer: &PrunableLayer) -> Vec<f32> {
        let si = stream_index(&layer.stream);
        let d = stream_width(&self.meta, &layer.stream);
        assert_eq!(d, layer.d_in);
        let data = self.block(layer).grams[si].as_f32().unwrap();
        (0..d).map(|i| data[i * d + i]).collect()
    }

    /// DSnoT feature statistics for one layer (diagonal + feature
    /// sums only; no Gram copy).
    pub fn feature_stats_for(&self, layer: &PrunableLayer) -> FeatureStats {
        let si = stream_index(&layer.stream);
        let sums = self.block(layer).sums[si].as_f32().unwrap();
        FeatureStats::from_gram(&self.diag_for(layer), sums, self.tokens)
    }
}

/// Stacked accumulator state for the resident `calib_step_{cfg}`
/// artifact: all-block Gram stacks [nb, d, d], split into per-block
/// [`BlockStats`] at the end.  The split is a bit-copy — the
/// per-(block, stream) accumulation order is exactly the pre-split
/// behaviour.
struct StackedAcc {
    grams: Vec<TensorData>,
    sums: Vec<TensorData>,
}

impl StackedAcc {
    fn zeros(meta: &ModelMeta) -> StackedAcc {
        let nb = meta.n_blocks;
        let grams = STREAMS.iter().map(|s| {
            let d = stream_width(meta, s);
            TensorData::F32 { dims: vec![nb, d, d],
                              data: vec![0.0; nb * d * d] }
        }).collect();
        let sums = STREAMS.iter().map(|s| {
            let d = stream_width(meta, s);
            TensorData::F32 { dims: vec![nb, d], data: vec![0.0; nb * d] }
        }).collect();
        StackedAcc { grams, sums }
    }

    /// Host bytes of the eight stacked tensors (the tests' byte model
    /// for one stripe's zero upload / final download).
    pub(crate) fn stacked_byte_size(meta: &ModelMeta) -> usize {
        let acc = StackedAcc::zeros(meta);
        acc.grams.iter().chain(acc.sums.iter())
            .map(|t| t.byte_size()).sum()
    }

    /// Fold another stripe's partial into this one.
    fn add_assign(&mut self, o: &StackedAcc) {
        for (a, b) in self.grams.iter_mut().zip(&o.grams) {
            add_tensor(a, b);
        }
        for (a, b) in self.sums.iter_mut().zip(&o.sums) {
            add_tensor(a, b);
        }
    }

    /// Run one calibration batch through `calib_step` with every
    /// tensor round-tripping through the host — the fallback arm of
    /// the stripe driver (and bit-identical to the resident arm: same
    /// adds, same order).
    fn accumulate_batch(&mut self, rt: &Runtime, store: &ParamStore,
                        tokens: &TensorData) -> Result<(), RuntimeError> {
        let artifact = format!("calib_step_{}", store.meta.name);
        let mut inputs = store.tensor_args();
        inputs.push(tokens.clone());
        inputs.extend(self.grams.iter().cloned());
        inputs.extend(self.sums.iter().cloned());
        let out = rt.execute(&artifact, inputs)?;
        expect_arity(&artifact, 8, out.len())?;
        let mut it = out.into_iter();
        for g in self.grams.iter_mut() {
            *g = it.next().unwrap();
        }
        for s in self.sums.iter_mut() {
            *s = it.next().unwrap();
        }
        Ok(())
    }

    /// Build a partial from the eight outputs of a stripe's final
    /// `calib_step` call.
    fn from_outputs(artifact: &str, out: Vec<TensorData>)
        -> Result<StackedAcc, RuntimeError> {
        expect_arity(artifact, 8, out.len())?;
        let mut it = out.into_iter();
        let grams = (0..4).map(|_| it.next().unwrap()).collect();
        let sums = (0..4).map(|_| it.next().unwrap()).collect();
        Ok(StackedAcc { grams, sums })
    }

    /// Split the stacks into per-block stats.
    fn into_stats(self, meta: &ModelMeta, tokens: usize, batches: usize)
        -> GramStats {
        let nb = meta.n_blocks;
        let blocks = (0..nb).map(|b| {
            let grams = STREAMS.iter().enumerate().map(|(si, s)| {
                let d = stream_width(meta, s);
                let data = self.grams[si].as_f32().unwrap();
                TensorData::F32 {
                    dims: vec![d, d],
                    data: data[b * d * d..(b + 1) * d * d].to_vec(),
                }
            }).collect();
            let sums = STREAMS.iter().enumerate().map(|(si, s)| {
                let d = stream_width(meta, s);
                let data = self.sums[si].as_f32().unwrap();
                TensorData::F32 {
                    dims: vec![d],
                    data: data[b * d..(b + 1) * d].to_vec(),
                }
            }).collect();
            Some(BlockStats { grams, sums })
        }).collect();
        GramStats {
            meta: meta.clone(),
            blocks,
            tokens,
            batches,
            traffic: PhaseTraffic::default(),
        }
    }
}

/// Outcome of one stripe's execution: the partial, plus the worker
/// outcomes the calling thread reports back to the pool (stripe
/// threads never touch the pool directly).
struct StripeRun<T> {
    result: Result<T, RuntimeError>,
    /// (worker index, ok) events in occurrence order.
    outcomes: Vec<(usize, bool)>,
    retries: u64,
}

/// Retry harness shared by every stripe driver: run `attempt` on the
/// stripe's preferred worker, rotating to the next worker on transient
/// failures and dropping to the inline (non-resident) form after
/// repeated residency failures.  Every arm recomputes the stripe from
/// its immutable inputs, so the partial is bit-identical no matter
/// which arm finally succeeds.
fn run_stripe_with_retry<T>(
    workers: &[Runtime], stripe: usize,
    mut attempt: impl FnMut(&Runtime, bool) -> Result<T, RuntimeError>)
    -> StripeRun<T> {
    let n = workers.len();
    let mut wi = stripe % n;
    let mut resident_failures = 0usize;
    let mut worker_failures = 0usize;
    let mut outcomes = Vec::new();
    let mut retries = 0u64;
    let result = loop {
        let resident = resident_failures < RESIDENT_ATTEMPTS;
        match attempt(&workers[wi], resident) {
            Ok(v) => {
                outcomes.push((workers[wi].device(), true));
                break Ok(v);
            }
            Err(RuntimeError::NotResident(_)) if resident => {
                // Evicted mid-stripe; the chain state was device-only,
                // so restart the stripe (same worker — residency, not
                // the worker, is the suspect).
                resident_failures += 1;
                retries += 1;
            }
            Err(e) if e.is_transient() && worker_failures + 1 < n + 2 => {
                outcomes.push((workers[wi].device(), false));
                worker_failures += 1;
                retries += 1;
                wi = (wi + 1) % n;
            }
            Err(e) => break Err(e),
        }
    };
    StripeRun { result, outcomes, retries }
}

/// Execute one stacked stripe (ascending batch order, device-resident
/// chain) on one worker.  `resident = false` is the host-round-trip
/// fallback arm.
fn stacked_stripe_once(rt: &Runtime, store: &ParamStore,
                       toks: &[&TensorData], weights_id: u64,
                       resident: bool)
    -> Result<StackedAcc, RuntimeError> {
    let meta = &store.meta;
    if !resident {
        let mut acc = StackedAcc::zeros(meta);
        for tokens in toks {
            acc.accumulate_batch(rt, store, tokens)?;
        }
        return Ok(acc);
    }
    let artifact = format!("calib_step_{}", meta.name);
    let acc_id = next_buffer_layer_id();
    let zeros = StackedAcc::zeros(meta);
    let run = || -> Result<StackedAcc, RuntimeError> {
        for (k, tokens) in toks.iter().enumerate() {
            let last = k + 1 == toks.len();
            let mut inputs: Vec<ExecInput> =
                Vec::with_capacity(store.tensors.len() + 9);
            for (i, p) in store.tensors.iter().enumerate() {
                let key = BufferKey {
                    layer: weights_id,
                    tensor: format!("p{i}"),
                    generation: 0,
                };
                // First batch ships the weights (a cache hit if a
                // sibling stripe on this worker got there first);
                // steady state probes key-only.
                inputs.push(if k == 0 {
                    ExecInput::Cached { key, data: Arc::clone(p) }
                } else {
                    ExecInput::CachedRef { key }
                });
            }
            inputs.push(ExecInput::Inline((*tokens).clone()));
            if k == 0 {
                for t in zeros.grams.iter().chain(zeros.sums.iter()) {
                    inputs.push(ExecInput::Inline(t.clone()));
                }
            } else {
                for name in ACC_TENSORS {
                    inputs.push(ExecInput::CachedRef {
                        key: BufferKey {
                            layer: acc_id,
                            tensor: name.to_string(),
                            generation: k as u64,
                        },
                    });
                }
            }
            // Retain the updated accumulators on-device (generation =
            // next batch index); the final batch retains nothing, so
            // its outputs are the stripe's one download.
            let retain: Vec<Option<BufferKey>> = if last {
                Vec::new()
            } else {
                ACC_TENSORS.iter().map(|name| Some(BufferKey {
                    layer: acc_id,
                    tensor: (*name).to_string(),
                    generation: k as u64 + 1,
                })).collect()
            };
            let out = rt.execute_retained(&artifact, inputs, retain)?;
            if last {
                return StackedAcc::from_outputs(&artifact, out);
            }
        }
        unreachable!("stripe has at least one batch")
    };
    let result = run();
    // Free the retained chain state whether we finished or bailed.
    rt.invalidate(acc_id);
    result
}

/// The one striped accumulation driver (module-doc contract).  Serial
/// callers pass a single-worker slice; the result is bit-identical to
/// any pooled run over the same batches.
fn accumulate_striped(workers: &[Runtime], pool: Option<&RuntimePool>,
                      store: &ParamStore,
                      batches: &[(TensorData, TensorData)])
    -> Result<GramStats, RuntimeError> {
    assert!(!workers.is_empty(), "accumulate needs at least one worker");
    let meta = &store.meta;
    let weights_id = next_buffer_layer_id();
    let runs: Vec<StripeRun<StackedAcc>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CALIB_STRIPES).map(|s| {
            let toks: Vec<&TensorData> = stripe_batches(batches.len(), s)
                .map(|i| &batches[i].0)
                .collect();
            // Channel handles are not shareable across threads; each
            // stripe thread gets owned clones of the worker set.
            let stripe_workers: Vec<Runtime> = workers.to_vec();
            scope.spawn(move || {
                if toks.is_empty() {
                    return None;
                }
                Some(run_stripe_with_retry(
                    &stripe_workers, s,
                    |rt, resident| stacked_stripe_once(
                        rt, store, &toks, weights_id, resident)))
            })
        }).collect();
        handles.into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Some(StripeRun {
                result: Err(RuntimeError::Msg(
                    "calibration stripe panicked".into())),
                outcomes: Vec::new(),
                retries: 0,
            })))
            .flatten()
            .collect()
    });
    for w in workers {
        w.invalidate(weights_id);
    }
    let mut total: Option<StackedAcc> = None;
    let mut err: Option<RuntimeError> = None;
    for run in runs {
        if let Some(p) = pool {
            for (worker, ok) in &run.outcomes {
                p.report_worker_outcome(*worker, *ok);
            }
            for _ in 0..run.retries {
                p.note_shard_retry();
            }
        }
        match run.result {
            Ok(part) => match &mut total {
                None => total = Some(part),
                Some(t) => t.add_assign(&part),
            },
            Err(e) => err = Some(err.unwrap_or(e)),
        }
    }
    if let Some(e) = err {
        return Err(e);
    }
    let acc = total.unwrap_or_else(|| StackedAcc::zeros(meta));
    Ok(acc.into_stats(meta,
                      batches.len() * meta.tokens_per_batch(),
                      batches.len()))
}

/// Accumulate Gram statistics over a set of calibration batches using
/// the (already masked, for sequential mode) parameter store, on a
/// single runtime worker.  Redefined onto the striped partial +
/// ordered-reduce form, so the result is bit-identical to
/// [`accumulate_pool`] at any device count.
pub fn accumulate(rt: &Runtime, store: &ParamStore,
                  batches: &[(TensorData, TensorData)])
    -> Result<GramStats, RuntimeError> {
    let before = rt.stats();
    let mut stats = accumulate_striped(std::slice::from_ref(rt), None,
                                       store, batches)?;
    stats.traffic = rt.stats().traffic_since(&before);
    Ok(stats)
}

/// [`accumulate`] fanned across a pool's healthy workers: each worker
/// runs whole stripes on its own device; the host reduces stripe
/// partials in ascending stripe order.  Transient worker faults retry
/// the stripe on the next healthy worker and feed the pool's
/// quarantine accounting.
pub fn accumulate_pool(pool: &RuntimePool, store: &ParamStore,
                       batches: &[(TensorData, TensorData)])
    -> Result<GramStats, RuntimeError> {
    let workers = pool.healthy_runtimes();
    let before = pool.stats_total();
    let mut stats = accumulate_striped(&workers, Some(pool), store,
                                       batches)?;
    stats.traffic = pool.stats_total().traffic_since(&before);
    Ok(stats)
}

/// Exact steady-state upload model for one [`accumulate`] /
/// [`accumulate_pool`] call, used by the byte-accounting tests and
/// the bench gate: weights ship once per worker that ran a stripe,
/// zeros ship once per non-empty stripe, and every batch ships its
/// token tensor — nothing else crosses the boundary host-to-device.
pub fn expected_upload_bytes(store: &ParamStore, workers: usize,
                             batches: &[(TensorData, TensorData)])
    -> u64 {
    // Stripe s is non-empty iff s < batches, so the non-empty stripes
    // are 0..min(batches, CALIB_STRIPES) and they land on
    // min(workers, non-empty) distinct workers (stripe s → worker
    // s % workers).
    let nonempty = batches.len().min(CALIB_STRIPES);
    let workers_used = workers.min(nonempty);
    let params: usize =
        store.tensors.iter().map(|t| t.byte_size()).sum();
    let tokens: usize = batches.iter().map(|(t, _)| t.byte_size()).sum();
    (workers_used * params
     + nonempty * StackedAcc::stacked_byte_size(&store.meta)
     + tokens) as u64
}

/// Host mirror of one batch's residual stream: the authoritative copy
/// (refreshed on every committed advance) shipped as
/// [`ExecInput::Cached`] so a device hit uploads nothing and an
/// evicted buffer self-heals from attached data.
#[derive(Clone)]
struct HostH {
    data: Arc<TensorData>,
    generation: u64,
}

/// Summed stats snapshot over a worker set, for per-phase traffic
/// deltas around a stream fan-out.  When other work shares the
/// workers concurrently (the one-shot prefetch stage overlapping
/// refinement) the delta includes that traffic too.
fn workers_stats(workers: &[Runtime]) -> ServiceStats {
    let mut total = ServiceStats::default();
    for w in workers {
        total.merge(&w.stats());
    }
    total
}

/// Streamed calibration driver over the `embed_{cfg}` /
/// `calib_block_{cfg}` artifacts.
///
/// Holds one residual-stream tensor per calibration batch and advances
/// them block by block, so Gram accumulation for block b+1 overlaps
/// block b's refinement and only O(1) blocks of weights need be
/// resident (the out-of-core pipeline's prefetch stage).  Batches fan
/// across the worker set by stripe (same decomposition as the stacked
/// driver — the bit-identity bridge between the two paths); each
/// batch's residual stream lives against a host mirror and is cached
/// device-side between the peek and push of a block.  Per block the
/// caller can:
///
/// * [`accumulate_and_push`]: stats + advance in one forward (one-shot
///   mode, where calibration is dense everywhere);
/// * [`accumulate_block`]: stats WITHOUT advancing (sequential mode
///   peeks a block's dense stats, refines, then pushes masked);
/// * [`push_block`]: advance without stats (journal-restored blocks,
///   sequential push with the refined mask applied).
///
/// [`accumulate_and_push`]: GramStream::accumulate_and_push
/// [`accumulate_block`]: GramStream::accumulate_block
/// [`push_block`]: GramStream::push_block
pub struct GramStream {
    meta: ModelMeta,
    /// Worker handles the stream fans stripes over (a single-element
    /// set for serial callers).
    workers: Vec<Runtime>,
    /// Buffer-key namespace of this stream (h mirrors, block params,
    /// embedding tensor).
    stream_id: u64,
    /// Bumped per `run_block` call: block params are cached under it,
    /// so each new block's tensors replace the previous block's slots.
    param_gen: u64,
    /// Residual stream h ([b*l, d_model]) per calibration batch.
    hs: Vec<HostH>,
    /// Calibration tokens represented by `hs`.
    pub tokens: usize,
    /// Calibration batches represented by `hs`.
    pub batches: usize,
    /// Worker traffic accumulated by this stream's embed and block
    /// advances (see [`GramStream::traffic`]).
    traffic: PhaseTraffic,
}

/// One stripe's `run_block` product: the stat partial plus the
/// committed residual-stream mirrors (applied by the calling thread
/// after the join, keeping `hs` single-writer).
struct BlockStripeOut {
    stats: Option<BlockStats>,
    new_hs: Vec<(usize, HostH)>,
}

impl Drop for GramStream {
    fn drop(&mut self) {
        // Release the stream's cached device buffers (h mirrors, block
        // params, embedding) on every worker; fire-and-forget.
        for w in &self.workers {
            w.invalidate(self.stream_id);
        }
    }
}

impl GramStream {
    /// Embed every calibration batch (`embed_{cfg}`), initialising the
    /// residual streams at the block-0 input.  `tok_emb` is the
    /// embedding tensor (param index 0) — leased, so the caller can
    /// release the globals right after.  `workers` is the worker set
    /// every later block advance fans over (serial callers pass one).
    pub fn start(workers: &[Runtime], meta: &ModelMeta,
                 tok_emb: &TensorData,
                 batches: &[(TensorData, TensorData)])
        -> Result<GramStream, RuntimeError> {
        assert!(!workers.is_empty(), "GramStream needs a worker");
        let stream_id = next_buffer_layer_id();
        let before = workers_stats(workers);
        let artifact = format!("embed_{}", meta.name);
        let emb = Arc::new(tok_emb.clone());
        let n = batches.len();
        let runs: Vec<StripeRun<Vec<(usize, TensorData)>>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..CALIB_STRIPES).map(|s| {
                    let ids: Vec<usize> = stripe_batches(n, s).collect();
                    let stripe_workers: Vec<Runtime> = workers.to_vec();
                    let emb = Arc::clone(&emb);
                    let artifact = &artifact;
                    scope.spawn(move || {
                        if ids.is_empty() {
                            return None;
                        }
                        Some(run_stripe_with_retry(
                            &stripe_workers, s, |rt, _resident| {
                                let mut hs = Vec::with_capacity(ids.len());
                                for &i in &ids {
                                    let inputs = vec![
                                        ExecInput::Cached {
                                            key: BufferKey {
                                                layer: stream_id,
                                                tensor: "emb".into(),
                                                generation: 0,
                                            },
                                            data: Arc::clone(&emb),
                                        },
                                        ExecInput::Inline(
                                            batches[i].0.clone()),
                                    ];
                                    let out = rt.execute_cached(
                                        artifact, inputs)?;
                                    let mut it = out.into_iter();
                                    let h = it.next().ok_or_else(|| {
                                        RuntimeError::BadOutputArity {
                                            artifact: artifact.clone(),
                                            expected: 1,
                                            got: 0,
                                        }
                                    })?;
                                    hs.push((i, h));
                                }
                                Ok(hs)
                            }))
                    })
                }).collect();
                handles.into_iter()
                    .map(|h| h.join().unwrap_or_else(|_| Some(StripeRun {
                        result: Err(RuntimeError::Msg(
                            "embed stripe panicked".into())),
                        outcomes: Vec::new(),
                        retries: 0,
                    })))
                    .flatten()
                    .collect()
            });
        let mut hs: Vec<Option<HostH>> = (0..n).map(|_| None).collect();
        for run in runs {
            for (i, h) in run.result? {
                hs[i] = Some(HostH {
                    data: Arc::new(h),
                    generation: 0,
                });
            }
        }
        Ok(GramStream {
            meta: meta.clone(),
            workers: workers.to_vec(),
            stream_id,
            param_gen: 0,
            hs: hs.into_iter().map(|h| h.expect("embedded")).collect(),
            tokens: n * meta.tokens_per_batch(),
            batches: n,
            traffic: workers_stats(workers).traffic_since(&before),
        })
    }

    /// Host bytes held by the residual streams.
    pub fn byte_size(&self) -> usize {
        self.hs.iter().map(|h| h.data.byte_size()).sum()
    }

    /// Worker traffic accumulated by this stream's embed and block
    /// advances so far.  Measured as stats deltas over the stream's
    /// worker set, so when the prefetch stage overlaps refinement on
    /// the same devices (one-shot streamed mode) the figure includes
    /// that concurrent traffic too.
    pub fn traffic(&self) -> PhaseTraffic {
        self.traffic
    }

    fn run_block(&mut self, params: &[TensorData], accum: bool,
                 commit: bool)
        -> Result<Option<BlockStats>, RuntimeError> {
        assert_eq!(params.len(), 9,
                   "calib_block takes the block's nine tensors");
        self.param_gen += 1;
        let pg = self.param_gen;
        let stream_id = self.stream_id;
        let before = workers_stats(&self.workers);
        // Owned copy: `self.hs` is mutated after the join while the
        // meta is still needed for the stripe reduce.
        let meta = self.meta.clone();
        let artifact = format!("calib_block_{}", meta.name);
        let params: Vec<Arc<TensorData>> =
            params.iter().map(|p| Arc::new(p.clone())).collect();
        let n = self.hs.len();
        let runs: Vec<StripeRun<BlockStripeOut>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..CALIB_STRIPES).map(|s| {
                    let ids: Vec<usize> = stripe_batches(n, s).collect();
                    let hs_in: Vec<HostH> =
                        ids.iter().map(|&i| self.hs[i].clone()).collect();
                    let stripe_workers: Vec<Runtime> =
                        self.workers.to_vec();
                    let params = &params;
                    let artifact = &artifact;
                    let meta = &meta;
                    scope.spawn(move || {
                        if ids.is_empty() {
                            return None;
                        }
                        Some(run_stripe_with_retry(
                            &stripe_workers, s,
                            |rt, resident| block_stripe_once(
                                rt, meta, artifact, params, pg,
                                stream_id, &ids, &hs_in, accum, commit,
                                resident)))
                    })
                }).collect();
                handles.into_iter()
                    .map(|h| h.join().unwrap_or_else(|_| Some(StripeRun {
                        result: Err(RuntimeError::Msg(
                            "calib_block stripe panicked".into())),
                        outcomes: Vec::new(),
                        retries: 0,
                    })))
                    .flatten()
                    .collect()
            });
        self.traffic.merge(
            &workers_stats(&self.workers).traffic_since(&before));
        let mut total: Option<BlockStats> = None;
        for run in runs {
            let out = run.result?;
            for (i, h) in out.new_hs {
                self.hs[i] = h;
            }
            if let Some(part) = out.stats {
                match &mut total {
                    None => total = Some(part),
                    Some(t) => t.add_assign(&part),
                }
            }
        }
        Ok(if accum {
            Some(total.unwrap_or_else(|| BlockStats::zeros(&meta)))
        } else {
            None
        })
    }

    /// Accumulate one block's stats and advance the residual streams
    /// through it, in a single forward per batch.
    pub fn accumulate_and_push(&mut self, params: &[TensorData])
        -> Result<BlockStats, RuntimeError> {
        Ok(self.run_block(params, true, true)?
               .expect("accumulating run returns stats"))
    }

    /// Accumulate one block's stats from the current residual streams
    /// without advancing them.
    pub fn accumulate_block(&mut self, params: &[TensorData])
        -> Result<BlockStats, RuntimeError> {
        Ok(self.run_block(params, true, false)?
               .expect("accumulating run returns stats"))
    }

    /// Advance the residual streams through one block without
    /// accumulating stats.
    pub fn push_block(&mut self, params: &[TensorData])
        -> Result<(), RuntimeError> {
        self.run_block(params, false, true).map(|_| ())
    }
}

/// Execute one streamed stripe of a block advance on one worker:
/// ascending batch order, stats chained device-resident (inline in the
/// fallback arm), residual streams shipped from their host mirrors
/// (device hit = no upload) and re-mirrored on commit.
#[allow(clippy::too_many_arguments)]
fn block_stripe_once(rt: &Runtime, meta: &ModelMeta, artifact: &str,
                     params: &[Arc<TensorData>], pg: u64, stream_id: u64,
                     ids: &[usize], hs_in: &[HostH], accum: bool,
                     commit: bool, resident: bool)
    -> Result<BlockStripeOut, RuntimeError> {
    let flag = TensorData::scalar_i32(accum as i32);
    let zeros = BlockStats::zeros(meta);
    let acc_id = next_buffer_layer_id();
    let mut new_hs = Vec::new();
    let run = |new_hs: &mut Vec<(usize, HostH)>|
        -> Result<Option<BlockStats>, RuntimeError> {
        // Fallback arm: host-carried stats, data-attached params.
        // Same adds in the same order as the resident arm.
        if !resident {
            let mut stats = zeros.clone();
            for (&i, h) in ids.iter().zip(hs_in) {
                let mut inputs = Vec::with_capacity(19);
                inputs.extend(params.iter()
                    .map(|p| ExecInput::Inline((**p).clone())));
                inputs.push(ExecInput::Inline((*h.data).clone()));
                inputs.push(ExecInput::Inline(flag.clone()));
                inputs.extend(stats.grams.iter().cloned()
                    .map(ExecInput::Inline));
                inputs.extend(stats.sums.iter().cloned()
                    .map(ExecInput::Inline));
                let out = rt.execute_cached(artifact, inputs)?;
                expect_arity(artifact, 9, out.len())?;
                let mut it = out.into_iter();
                for g in stats.grams.iter_mut() {
                    *g = it.next().unwrap();
                }
                for s in stats.sums.iter_mut() {
                    *s = it.next().unwrap();
                }
                let h_out = it.next().unwrap();
                if commit {
                    new_hs.push((i, HostH {
                        data: Arc::new(h_out),
                        generation: h.generation + 1,
                    }));
                }
            }
            return Ok(accum.then_some(stats));
        }
        for (k, (&i, h)) in ids.iter().zip(hs_in).enumerate() {
            let last = k + 1 == ids.len();
            let mut inputs = Vec::with_capacity(19);
            for (pi, p) in params.iter().enumerate() {
                let key = BufferKey {
                    layer: stream_id,
                    tensor: format!("bp{pi}"),
                    generation: pg,
                };
                inputs.push(if k == 0 {
                    ExecInput::Cached { key, data: Arc::clone(p) }
                } else {
                    ExecInput::CachedRef { key }
                });
            }
            inputs.push(ExecInput::Cached {
                key: BufferKey {
                    layer: stream_id,
                    tensor: format!("h{i}"),
                    generation: h.generation,
                },
                data: Arc::clone(&h.data),
            });
            inputs.push(ExecInput::Inline(flag.clone()));
            if k == 0 {
                inputs.extend(zeros.grams.iter().cloned()
                    .map(ExecInput::Inline));
                inputs.extend(zeros.sums.iter().cloned()
                    .map(ExecInput::Inline));
            } else {
                for name in ACC_TENSORS {
                    inputs.push(ExecInput::CachedRef {
                        key: BufferKey {
                            layer: acc_id,
                            tensor: name.to_string(),
                            generation: k as u64,
                        },
                    });
                }
            }
            // Stats stay device-resident between batches; h_out (the
            // ninth output) always returns — on commit it becomes the
            // fresh host mirror.  A non-accumulating pass retains the
            // pass-through stats on the last batch too, so nothing but
            // h travels back.
            let retain_stats_on_last = !accum;
            let retain: Vec<Option<BufferKey>> =
                if last && !retain_stats_on_last {
                    Vec::new()
                } else {
                    ACC_TENSORS.iter()
                        .map(|name| Some(BufferKey {
                            layer: acc_id,
                            tensor: (*name).to_string(),
                            generation: k as u64 + 1,
                        }))
                        .chain(std::iter::once(None))
                        .collect()
                };
            let out = rt.execute_retained(artifact, inputs, retain)?;
            let stats_attached = last && !retain_stats_on_last;
            expect_arity(artifact,
                         if stats_attached { 9 } else { 1 },
                         out.len())?;
            let mut it = out.into_iter();
            let stats = if stats_attached {
                let mut stats = zeros.clone();
                for g in stats.grams.iter_mut() {
                    *g = it.next().unwrap();
                }
                for s in stats.sums.iter_mut() {
                    *s = it.next().unwrap();
                }
                Some(stats)
            } else {
                None
            };
            let h_out = it.next().unwrap();
            if commit {
                new_hs.push((i, HostH {
                    data: Arc::new(h_out),
                    generation: h.generation + 1,
                }));
            }
            if last {
                return Ok(if accum { stats } else { None });
            }
        }
        unreachable!("stripe has at least one batch")
    };
    let result = run(&mut new_hs);
    if resident {
        rt.invalidate(acc_id);
    }
    match result {
        Ok(stats) => Ok(BlockStripeOut { stats, new_hs }),
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_meta;

    #[test]
    fn zeros_layout() {
        let meta = tiny_meta();
        let stats = GramStats::zeros(&meta);
        for b in 0..meta.n_blocks {
            assert!(stats.block_resident(b));
            let bs = stats.blocks[b].as_ref().unwrap();
            assert_eq!(bs.grams[0].dims(), &[meta.d_model, meta.d_model]);
            assert_eq!(bs.grams[3].dims(), &[meta.d_ff, meta.d_ff]);
        }
        for layer in &meta.prunable {
            let g = stats.gram_for(layer);
            assert_eq!(g.d, layer.d_in);
            assert!(g.as_slice().iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn gram_slicing_addresses_blocks() {
        let meta = tiny_meta();
        let mut stats = GramStats::zeros(&meta);
        // Mark block 1's qkv gram with a sentinel.
        stats.blocks[1].as_mut().unwrap().grams[0]
            .as_f32_mut().unwrap()[0] = 42.0;
        let l_b0 = meta.prunable.iter()
            .find(|l| l.block == 0 && l.stream == "qkv").unwrap();
        let l_b1 = meta.prunable.iter()
            .find(|l| l.block == 1 && l.stream == "qkv").unwrap();
        assert_eq!(stats.gram_for(l_b0).at(0, 0), 0.0);
        assert_eq!(stats.gram_for(l_b1).at(0, 0), 42.0);
    }

    #[test]
    fn diag_for_matches_gram_diagonal() {
        let meta = tiny_meta();
        let mut stats = GramStats::zeros(&meta);
        // Fill block 0's qkv gram with distinguishable values.
        let d = meta.d_model;
        for (i, v) in stats.blocks[0].as_mut().unwrap().grams[0]
            .as_f32_mut().unwrap()[..d * d]
            .iter_mut()
            .enumerate()
        {
            *v = i as f32;
        }
        let layer = meta.prunable.iter()
            .find(|l| l.block == 0 && l.stream == "qkv").unwrap();
        assert_eq!(stats.diag_for(layer), stats.gram_for(layer).diag());
    }

    #[test]
    fn release_and_hollow_accounting() {
        let meta = tiny_meta();
        let mut stats = GramStats::zeros(&meta);
        let per_block = BlockStats::zeros(&meta).byte_size();
        assert_eq!(stats.resident_bytes(), meta.n_blocks * per_block);
        let freed = stats.release_block(0);
        assert_eq!(freed, per_block);
        assert!(!stats.block_resident(0));
        assert_eq!(stats.release_block(0), 0);
        assert_eq!(stats.resident_bytes(),
                   (meta.n_blocks - 1) * per_block);

        let mut hollow = GramStats::hollow(&meta);
        assert_eq!(hollow.resident_bytes(), 0);
        hollow.set_block(1, BlockStats::zeros(&meta));
        assert!(hollow.block_resident(1) && !hollow.block_resident(0));
        assert_eq!(hollow.resident_bytes(), per_block);
    }

    #[test]
    fn stripes_partition_every_batch_count() {
        for n in 0..10 {
            let mut seen = vec![0usize; n];
            for s in 0..CALIB_STRIPES {
                for i in stripe_batches(n, s) {
                    seen[i] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1),
                    "batches covered exactly once for n={n}");
        }
    }

    #[test]
    fn stripe_reduce_order_is_fixed() {
        // The cross-stripe reduce must visit stripes in ascending
        // order with `acc += partial` — spot-check the helper's
        // operand order with values where f32 addition order matters.
        let meta = tiny_meta();
        let mut a = BlockStats::zeros(&meta);
        let mut b = BlockStats::zeros(&meta);
        a.grams[0].as_f32_mut().unwrap()[0] = 1.0e8;
        b.grams[0].as_f32_mut().unwrap()[0] = 1.0;
        a.add_assign(&b);
        assert_eq!(a.grams[0].as_f32().unwrap()[0], 1.0e8 + 1.0f32);
    }
}
