//! Calibration: streaming Gram-matrix accumulation through the
//! `calib_step_{cfg}` artifact.
//!
//! The artifact runs the model forward on one calibration batch and adds
//! X^T X (plus feature sums) for each of the four activation streams of
//! every block (Sec 2.1.2: G accumulates on-the-fly; raw activations are
//! never materialised host-side).  The coordinator threads the stat
//! tensors through successive executions and slices per-layer Gram
//! matrices out at the end.

pub mod analysis;

use crate::model::store::ParamStore;
use crate::pruning::dsnot::FeatureStats;
use crate::runtime::manifest::{ModelMeta, PrunableLayer};
use crate::runtime::service::{Runtime, RuntimeError};
use crate::runtime::tensor_data::TensorData;
use crate::util::tensor::GramView;

/// Stream order must match `calib_step`'s argument order (aot.py).
pub const STREAMS: [&str; 4] = ["qkv", "o", "gu", "down"];

#[derive(Clone, Debug)]
pub struct GramStats {
    pub meta: ModelMeta,
    /// Gram stacks per stream: tensors of dims [n_blocks, d, d].
    grams: Vec<TensorData>,
    /// Feature-sum stacks per stream: dims [n_blocks, d].
    sums: Vec<TensorData>,
    /// Total calibration tokens accumulated.
    pub tokens: usize,
    /// Batches consumed.
    pub batches: usize,
}

impl GramStats {
    pub fn zeros(meta: &ModelMeta) -> GramStats {
        let nb = meta.n_blocks;
        let width = |s: &str| if s == "down" { meta.d_ff }
                              else { meta.d_model };
        let grams = STREAMS.iter().map(|s| {
            let d = width(s);
            TensorData::F32 { dims: vec![nb, d, d],
                              data: vec![0.0; nb * d * d] }
        }).collect();
        let sums = STREAMS.iter().map(|s| {
            let d = width(s);
            TensorData::F32 { dims: vec![nb, d], data: vec![0.0; nb * d] }
        }).collect();
        GramStats { meta: meta.clone(), grams, sums, tokens: 0, batches: 0 }
    }

    fn stream_index(stream: &str) -> usize {
        STREAMS.iter().position(|s| *s == stream)
            .unwrap_or_else(|| panic!("unknown stream {stream}"))
    }

    fn stream_width(&self, stream: &str) -> usize {
        if stream == "down" { self.meta.d_ff } else { self.meta.d_model }
    }

    /// Gram matrix for one prunable layer: a zero-copy [`GramView`]
    /// into its stream stack (no d*d materialisation — at LLM widths
    /// the old per-access copy was 16M floats per layer).
    pub fn gram_for(&self, layer: &PrunableLayer) -> GramView<'_> {
        let si = Self::stream_index(&layer.stream);
        let d = self.stream_width(&layer.stream);
        assert_eq!(d, layer.d_in);
        let data = self.grams[si].as_f32().unwrap();
        let offset = layer.block * d * d;
        GramView::new(&data[offset..offset + d * d], d)
    }

    /// Gram diagonal for one layer, sliced with stride d directly from
    /// the stream stack (O(d) work — never materialises the d*d Gram).
    pub fn diag_for(&self, layer: &PrunableLayer) -> Vec<f32> {
        let si = Self::stream_index(&layer.stream);
        let d = self.stream_width(&layer.stream);
        assert_eq!(d, layer.d_in);
        let data = self.grams[si].as_f32().unwrap();
        let offset = layer.block * d * d;
        (0..d).map(|i| data[offset + i * d + i]).collect()
    }

    /// DSnoT feature statistics for one layer (diagonal + feature
    /// sums only; no Gram copy).
    pub fn feature_stats_for(&self, layer: &PrunableLayer) -> FeatureStats {
        let si = Self::stream_index(&layer.stream);
        let d = self.stream_width(&layer.stream);
        let sums = self.sums[si].as_f32().unwrap();
        let offset = layer.block * d;
        FeatureStats::from_gram(&self.diag_for(layer),
                                &sums[offset..offset + d], self.tokens)
    }

    /// Run one calibration batch through the artifact, updating stats.
    pub fn accumulate_batch(&mut self, rt: &Runtime, store: &ParamStore,
                            tokens: &TensorData)
        -> Result<(), RuntimeError> {
        let artifact = format!("calib_step_{}", self.meta.name);
        let mut inputs = store.tensor_args();
        inputs.push(tokens.clone());
        inputs.extend(self.grams.iter().cloned());
        inputs.extend(self.sums.iter().cloned());
        let out = rt.execute(&artifact, inputs)?;
        assert_eq!(out.len(), 8);
        let mut it = out.into_iter();
        for g in self.grams.iter_mut() {
            *g = it.next().unwrap();
        }
        for s in self.sums.iter_mut() {
            *s = it.next().unwrap();
        }
        self.tokens += self.meta.tokens_per_batch();
        self.batches += 1;
        Ok(())
    }
}

/// Accumulate Gram statistics over a set of calibration batches using
/// the (already masked, for sequential mode) parameter store.
pub fn accumulate(rt: &Runtime, store: &ParamStore,
                  batches: &[(TensorData, TensorData)])
    -> Result<GramStats, RuntimeError> {
    let mut stats = GramStats::zeros(&store.meta);
    for (tokens, _) in batches {
        stats.accumulate_batch(rt, store, tokens)?;
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_meta;

    #[test]
    fn zeros_layout() {
        let meta = tiny_meta();
        let stats = GramStats::zeros(&meta);
        assert_eq!(stats.grams.len(), 4);
        assert_eq!(stats.grams[0].dims(),
                   &[meta.n_blocks, meta.d_model, meta.d_model]);
        assert_eq!(stats.grams[3].dims(),
                   &[meta.n_blocks, meta.d_ff, meta.d_ff]);
        for layer in &meta.prunable {
            let g = stats.gram_for(layer);
            assert_eq!(g.d, layer.d_in);
            assert!(g.as_slice().iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn gram_slicing_addresses_blocks() {
        let meta = tiny_meta();
        let mut stats = GramStats::zeros(&meta);
        // Mark block 1's qkv gram with a sentinel.
        let d = meta.d_model;
        stats.grams[0].as_f32_mut().unwrap()[d * d] = 42.0;
        let l_b0 = meta.prunable.iter()
            .find(|l| l.block == 0 && l.stream == "qkv").unwrap();
        let l_b1 = meta.prunable.iter()
            .find(|l| l.block == 1 && l.stream == "qkv").unwrap();
        assert_eq!(stats.gram_for(l_b0).at(0, 0), 0.0);
        assert_eq!(stats.gram_for(l_b1).at(0, 0), 42.0);
    }

    #[test]
    fn diag_for_matches_gram_diagonal() {
        let meta = tiny_meta();
        let mut stats = GramStats::zeros(&meta);
        // Fill block 0's qkv gram with distinguishable values.
        let d = meta.d_model;
        for (i, v) in stats.grams[0].as_f32_mut().unwrap()[..d * d]
            .iter_mut()
            .enumerate()
        {
            *v = i as f32;
        }
        let layer = meta.prunable.iter()
            .find(|l| l.block == 0 && l.stream == "qkv").unwrap();
        assert_eq!(stats.diag_for(layer), stats.gram_for(layer).diag());
    }
}
