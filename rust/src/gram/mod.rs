//! Calibration: streaming Gram-matrix accumulation through the
//! `calib_step_{cfg}` / `embed_{cfg}` / `calib_block_{cfg}` artifacts.
//!
//! The artifacts run the model forward on calibration batches and add
//! X^T X (plus feature sums) for each of the four activation streams of
//! every block (Sec 2.1.2: G accumulates on-the-fly; raw activations are
//! never materialised host-side).  Stats are stored per block so the
//! staged pipeline can release a block's Grams the moment its
//! refinement finishes — `GramView` borrows end with the block.
//!
//! Two accumulation drivers share the same math:
//!
//! * the resident path executes `calib_step` (all blocks per batch)
//!   and splits the stacked outputs into per-block stats — a bit-copy;
//! * [`GramStream`] executes `embed` once per batch and `calib_block`
//!   per (block, batch), threading the residual stream between blocks,
//!   so only one block's weights need be resident at a time.
//!
//! Both orders accumulate each (block, stream) Gram over batches in
//! batch order, so the two paths are bit-identical.

pub mod analysis;

use crate::model::store::ParamStore;
use crate::pruning::dsnot::FeatureStats;
use crate::runtime::manifest::{ModelMeta, PrunableLayer};
use crate::runtime::service::{Runtime, RuntimeError};
use crate::runtime::tensor_data::TensorData;
use crate::util::tensor::GramView;

/// Stream order must match `calib_step`'s argument order (aot.py).
pub const STREAMS: [&str; 4] = ["qkv", "o", "gu", "down"];

fn stream_index(stream: &str) -> usize {
    STREAMS.iter().position(|s| *s == stream)
        .unwrap_or_else(|| panic!("unknown stream {stream}"))
}

fn stream_width(meta: &ModelMeta, stream: &str) -> usize {
    if stream == "down" { meta.d_ff } else { meta.d_model }
}

/// One block's calibration statistics: a Gram matrix [d, d] and a
/// feature-sum vector [d] per activation stream, in [`STREAMS`] order.
#[derive(Clone, Debug)]
pub struct BlockStats {
    grams: Vec<TensorData>,
    sums: Vec<TensorData>,
}

impl BlockStats {
    pub fn zeros(meta: &ModelMeta) -> BlockStats {
        let grams = STREAMS.iter().map(|s| {
            let d = stream_width(meta, s);
            TensorData::F32 { dims: vec![d, d], data: vec![0.0; d * d] }
        }).collect();
        let sums = STREAMS.iter().map(|s| {
            let d = stream_width(meta, s);
            TensorData::F32 { dims: vec![d], data: vec![0.0; d] }
        }).collect();
        BlockStats { grams, sums }
    }

    /// Host bytes held by the stat tensors.
    pub fn byte_size(&self) -> usize {
        self.grams.iter().chain(self.sums.iter())
            .map(|t| t.byte_size()).sum()
    }
}

#[derive(Clone, Debug)]
pub struct GramStats {
    pub meta: ModelMeta,
    /// Per-block stat slots; `None` once released (or not yet set, for
    /// hollow stats the streamed pipeline fills via [`set_block`]).
    ///
    /// [`set_block`]: GramStats::set_block
    blocks: Vec<Option<BlockStats>>,
    /// Total calibration tokens accumulated.
    pub tokens: usize,
    /// Batches consumed.
    pub batches: usize,
}

impl GramStats {
    pub fn zeros(meta: &ModelMeta) -> GramStats {
        let blocks = (0..meta.n_blocks)
            .map(|_| Some(BlockStats::zeros(meta))).collect();
        GramStats { meta: meta.clone(), blocks, tokens: 0, batches: 0 }
    }

    /// Stats with every block slot empty — the streamed pipeline fills
    /// blocks one at a time as the prefetch stage produces them.
    pub fn hollow(meta: &ModelMeta) -> GramStats {
        GramStats {
            meta: meta.clone(),
            blocks: (0..meta.n_blocks).map(|_| None).collect(),
            tokens: 0,
            batches: 0,
        }
    }

    /// Install one block's stats (streamed accumulation).
    pub fn set_block(&mut self, block: usize, stats: BlockStats) {
        self.blocks[block] = Some(stats);
    }

    /// Drop one block's stats, returning the host bytes freed.
    /// Releasing an absent block is a no-op.
    pub fn release_block(&mut self, block: usize) -> usize {
        self.blocks[block].take().map_or(0, |s| s.byte_size())
    }

    pub fn block_resident(&self, block: usize) -> bool {
        self.blocks[block].is_some()
    }

    /// Host bytes currently held across all resident blocks.
    pub fn resident_bytes(&self) -> usize {
        self.blocks.iter().flatten().map(|s| s.byte_size()).sum()
    }

    fn block(&self, layer: &PrunableLayer) -> &BlockStats {
        self.blocks[layer.block].as_ref().unwrap_or_else(|| panic!(
            "gram stats for block {} are not resident \
             (released or not yet accumulated)", layer.block))
    }

    /// Gram matrix for one prunable layer: a zero-copy [`GramView`]
    /// into its block's stream tensor (no d*d materialisation — at LLM
    /// widths the old per-access copy was 16M floats per layer).
    pub fn gram_for(&self, layer: &PrunableLayer) -> GramView<'_> {
        let si = stream_index(&layer.stream);
        let d = stream_width(&self.meta, &layer.stream);
        assert_eq!(d, layer.d_in);
        GramView::new(self.block(layer).grams[si].as_f32().unwrap(), d)
    }

    /// Gram diagonal for one layer (O(d) work — never materialises
    /// the d*d Gram).
    pub fn diag_for(&self, layer: &PrunableLayer) -> Vec<f32> {
        let si = stream_index(&layer.stream);
        let d = stream_width(&self.meta, &layer.stream);
        assert_eq!(d, layer.d_in);
        let data = self.block(layer).grams[si].as_f32().unwrap();
        (0..d).map(|i| data[i * d + i]).collect()
    }

    /// DSnoT feature statistics for one layer (diagonal + feature
    /// sums only; no Gram copy).
    pub fn feature_stats_for(&self, layer: &PrunableLayer) -> FeatureStats {
        let si = stream_index(&layer.stream);
        let sums = self.block(layer).sums[si].as_f32().unwrap();
        FeatureStats::from_gram(&self.diag_for(layer), sums, self.tokens)
    }
}

/// Stacked accumulator driving the resident `calib_step_{cfg}`
/// artifact: all-block Gram stacks [nb, d, d] threaded through
/// successive executions, split into per-block [`BlockStats`] at the
/// end.  The split is a bit-copy — the per-(block, stream)
/// accumulation order is exactly the pre-split behaviour.
struct StackedAcc {
    grams: Vec<TensorData>,
    sums: Vec<TensorData>,
}

impl StackedAcc {
    fn zeros(meta: &ModelMeta) -> StackedAcc {
        let nb = meta.n_blocks;
        let grams = STREAMS.iter().map(|s| {
            let d = stream_width(meta, s);
            TensorData::F32 { dims: vec![nb, d, d],
                              data: vec![0.0; nb * d * d] }
        }).collect();
        let sums = STREAMS.iter().map(|s| {
            let d = stream_width(meta, s);
            TensorData::F32 { dims: vec![nb, d], data: vec![0.0; nb * d] }
        }).collect();
        StackedAcc { grams, sums }
    }

    /// Run one calibration batch through `calib_step`, updating the
    /// stacks.
    fn accumulate_batch(&mut self, rt: &Runtime, store: &ParamStore,
                        tokens: &TensorData) -> Result<(), RuntimeError> {
        let artifact = format!("calib_step_{}", store.meta.name);
        let mut inputs = store.tensor_args();
        inputs.push(tokens.clone());
        inputs.extend(self.grams.iter().cloned());
        inputs.extend(self.sums.iter().cloned());
        let out = rt.execute(&artifact, inputs)?;
        assert_eq!(out.len(), 8);
        let mut it = out.into_iter();
        for g in self.grams.iter_mut() {
            *g = it.next().unwrap();
        }
        for s in self.sums.iter_mut() {
            *s = it.next().unwrap();
        }
        Ok(())
    }

    /// Split the stacks into per-block stats.
    fn into_stats(self, meta: &ModelMeta, tokens: usize, batches: usize)
        -> GramStats {
        let nb = meta.n_blocks;
        let blocks = (0..nb).map(|b| {
            let grams = STREAMS.iter().enumerate().map(|(si, s)| {
                let d = stream_width(meta, s);
                let data = self.grams[si].as_f32().unwrap();
                TensorData::F32 {
                    dims: vec![d, d],
                    data: data[b * d * d..(b + 1) * d * d].to_vec(),
                }
            }).collect();
            let sums = STREAMS.iter().enumerate().map(|(si, s)| {
                let d = stream_width(meta, s);
                let data = self.sums[si].as_f32().unwrap();
                TensorData::F32 {
                    dims: vec![d],
                    data: data[b * d..(b + 1) * d].to_vec(),
                }
            }).collect();
            Some(BlockStats { grams, sums })
        }).collect();
        GramStats { meta: meta.clone(), blocks, tokens, batches }
    }
}

/// Accumulate Gram statistics over a set of calibration batches using
/// the (already masked, for sequential mode) parameter store.
pub fn accumulate(rt: &Runtime, store: &ParamStore,
                  batches: &[(TensorData, TensorData)])
    -> Result<GramStats, RuntimeError> {
    let mut acc = StackedAcc::zeros(&store.meta);
    for (tokens, _) in batches {
        acc.accumulate_batch(rt, store, tokens)?;
    }
    Ok(acc.into_stats(&store.meta,
                      batches.len() * store.meta.tokens_per_batch(),
                      batches.len()))
}

/// Streamed calibration driver over the `embed_{cfg}` /
/// `calib_block_{cfg}` artifacts.
///
/// Holds one residual-stream tensor per calibration batch and advances
/// them block by block, so Gram accumulation for block b+1 overlaps
/// block b's refinement and only O(1) blocks of weights need be
/// resident (the out-of-core pipeline's prefetch stage).  Per block
/// the caller can:
///
/// * [`accumulate_and_push`]: stats + advance in one forward (one-shot
///   mode, where calibration is dense everywhere);
/// * [`accumulate_block`]: stats WITHOUT advancing (sequential mode
///   peeks a block's dense stats, refines, then pushes masked);
/// * [`push_block`]: advance without stats (journal-restored blocks,
///   sequential push with the refined mask applied).
///
/// [`accumulate_and_push`]: GramStream::accumulate_and_push
/// [`accumulate_block`]: GramStream::accumulate_block
/// [`push_block`]: GramStream::push_block
pub struct GramStream {
    meta: ModelMeta,
    /// Residual stream h ([b*l, d_model]) per calibration batch.
    hs: Vec<TensorData>,
    /// Calibration tokens represented by `hs`.
    pub tokens: usize,
    /// Calibration batches represented by `hs`.
    pub batches: usize,
}

impl GramStream {
    /// Embed every calibration batch (`embed_{cfg}`), initialising the
    /// residual streams at the block-0 input.  `tok_emb` is the
    /// embedding tensor (param index 0) — leased, so the caller can
    /// release the globals right after.
    pub fn start(rt: &Runtime, meta: &ModelMeta, tok_emb: &TensorData,
                 batches: &[(TensorData, TensorData)])
        -> Result<GramStream, RuntimeError> {
        let artifact = format!("embed_{}", meta.name);
        let mut hs = Vec::with_capacity(batches.len());
        for (tokens, _) in batches {
            let out = rt.execute(&artifact,
                                 vec![tok_emb.clone(), tokens.clone()])?;
            hs.push(out.into_iter().next().expect("embed returns h"));
        }
        Ok(GramStream {
            meta: meta.clone(),
            hs,
            tokens: batches.len() * meta.tokens_per_batch(),
            batches: batches.len(),
        })
    }

    /// Host bytes held by the residual streams.
    pub fn byte_size(&self) -> usize {
        self.hs.iter().map(|h| h.byte_size()).sum()
    }

    fn run_block(&mut self, rt: &Runtime, params: &[TensorData],
                 accum: bool, commit: bool)
        -> Result<Option<BlockStats>, RuntimeError> {
        assert_eq!(params.len(), 9,
                   "calib_block takes the block's nine tensors");
        let artifact = format!("calib_block_{}", self.meta.name);
        let mut stats = BlockStats::zeros(&self.meta);
        let flag = TensorData::scalar_i32(accum as i32);
        for h in self.hs.iter_mut() {
            let mut inputs = Vec::with_capacity(19);
            inputs.extend(params.iter().cloned());
            inputs.push(h.clone());
            inputs.push(flag.clone());
            inputs.extend(stats.grams.iter().cloned());
            inputs.extend(stats.sums.iter().cloned());
            let out = rt.execute(&artifact, inputs)?;
            assert_eq!(out.len(), 9);
            let mut it = out.into_iter();
            for g in stats.grams.iter_mut() {
                *g = it.next().unwrap();
            }
            for s in stats.sums.iter_mut() {
                *s = it.next().unwrap();
            }
            let h_out = it.next().unwrap();
            if commit {
                *h = h_out;
            }
        }
        Ok(if accum { Some(stats) } else { None })
    }

    /// Accumulate one block's stats and advance the residual streams
    /// through it, in a single forward per batch.
    pub fn accumulate_and_push(&mut self, rt: &Runtime,
                               params: &[TensorData])
        -> Result<BlockStats, RuntimeError> {
        Ok(self.run_block(rt, params, true, true)?
               .expect("accumulating run returns stats"))
    }

    /// Accumulate one block's stats from the current residual streams
    /// without advancing them.
    pub fn accumulate_block(&mut self, rt: &Runtime,
                            params: &[TensorData])
        -> Result<BlockStats, RuntimeError> {
        Ok(self.run_block(rt, params, true, false)?
               .expect("accumulating run returns stats"))
    }

    /// Advance the residual streams through one block without
    /// accumulating stats.
    pub fn push_block(&mut self, rt: &Runtime, params: &[TensorData])
        -> Result<(), RuntimeError> {
        self.run_block(rt, params, false, true).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_meta;

    #[test]
    fn zeros_layout() {
        let meta = tiny_meta();
        let stats = GramStats::zeros(&meta);
        for b in 0..meta.n_blocks {
            assert!(stats.block_resident(b));
            let bs = stats.blocks[b].as_ref().unwrap();
            assert_eq!(bs.grams[0].dims(), &[meta.d_model, meta.d_model]);
            assert_eq!(bs.grams[3].dims(), &[meta.d_ff, meta.d_ff]);
        }
        for layer in &meta.prunable {
            let g = stats.gram_for(layer);
            assert_eq!(g.d, layer.d_in);
            assert!(g.as_slice().iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn gram_slicing_addresses_blocks() {
        let meta = tiny_meta();
        let mut stats = GramStats::zeros(&meta);
        // Mark block 1's qkv gram with a sentinel.
        stats.blocks[1].as_mut().unwrap().grams[0]
            .as_f32_mut().unwrap()[0] = 42.0;
        let l_b0 = meta.prunable.iter()
            .find(|l| l.block == 0 && l.stream == "qkv").unwrap();
        let l_b1 = meta.prunable.iter()
            .find(|l| l.block == 1 && l.stream == "qkv").unwrap();
        assert_eq!(stats.gram_for(l_b0).at(0, 0), 0.0);
        assert_eq!(stats.gram_for(l_b1).at(0, 0), 42.0);
    }

    #[test]
    fn diag_for_matches_gram_diagonal() {
        let meta = tiny_meta();
        let mut stats = GramStats::zeros(&meta);
        // Fill block 0's qkv gram with distinguishable values.
        let d = meta.d_model;
        for (i, v) in stats.blocks[0].as_mut().unwrap().grams[0]
            .as_f32_mut().unwrap()[..d * d]
            .iter_mut()
            .enumerate()
        {
            *v = i as f32;
        }
        let layer = meta.prunable.iter()
            .find(|l| l.block == 0 && l.stream == "qkv").unwrap();
        assert_eq!(stats.diag_for(layer), stats.gram_for(layer).diag());
    }

    #[test]
    fn release_and_hollow_accounting() {
        let meta = tiny_meta();
        let mut stats = GramStats::zeros(&meta);
        let per_block = BlockStats::zeros(&meta).byte_size();
        assert_eq!(stats.resident_bytes(), meta.n_blocks * per_block);
        let freed = stats.release_block(0);
        assert_eq!(freed, per_block);
        assert!(!stats.block_resident(0));
        assert_eq!(stats.release_block(0), 0);
        assert_eq!(stats.resident_bytes(),
                   (meta.n_blocks - 1) * per_block);

        let mut hollow = GramStats::hollow(&meta);
        assert_eq!(hollow.resident_bytes(), 0);
        hollow.set_block(1, BlockStats::zeros(&meta));
        assert!(hollow.block_resident(1) && !hollow.block_resident(0));
        assert_eq!(hollow.resident_bytes(), per_block);
    }
}
