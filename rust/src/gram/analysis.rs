//! Calibration-statistics analysis: the activation-outlier /
//! feature-correlation diagnostics that motivate the paper's method.
//!
//! The paper argues magnitude pruning fails on transformers because of
//! systematic activation outliers (Dettmers et al.) and that Wanda's
//! diagonal bound ignores within-row interactions.  These diagnostics
//! quantify both on a given Gram matrix:
//!   * outlier ratio — max/median feature norm (sqrt diag G);
//!   * correlation mass — off-diagonal Frobenius share of the
//!     normalised Gram (0 = perfectly decorrelated features, where
//!     Wanda is already optimal and SparseSwaps can't help);
//!   * effective rank — exp(entropy of the normalised diag spectrum
//!     proxy).
//!
//! Exposed on the CLI as `sparseswaps analyze` and used by tests to
//! verify the synthetic corpus actually produces correlated features
//! (otherwise every experiment here would be trivial).

use crate::util::tensor::GramView;

#[derive(Clone, Debug)]
pub struct GramDiagnostics {
    pub dim: usize,
    /// max feature norm / median feature norm.
    pub outlier_ratio: f64,
    /// Off-diagonal share of ||Ghat||_F^2 for Ghat = D^-1/2 G D^-1/2.
    pub correlation_mass: f64,
    /// Mean absolute off-diagonal correlation.
    pub mean_abs_corr: f64,
    /// exp(Shannon entropy) of the normalised diagonal (participation
    /// number of feature energies).
    pub energy_participation: f64,
}

pub fn diagnose<'a>(g: impl Into<GramView<'a>>) -> GramDiagnostics {
    let g = g.into();
    let d = g.d;
    let diag: Vec<f64> =
        (0..d).map(|i| (g.at(i, i) as f64).max(0.0)).collect();
    let mut norms: Vec<f64> = diag.iter().map(|v| v.sqrt()).collect();
    norms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = norms[d / 2].max(1e-12);
    let outlier_ratio = norms[d - 1] / median;

    // Normalised correlation matrix statistics.
    let mut off_sq = 0.0f64;
    let mut diag_sq = 0.0f64;
    let mut abs_sum = 0.0f64;
    let mut count = 0usize;
    for i in 0..d {
        let di = diag[i].sqrt().max(1e-12);
        for j in 0..d {
            let dj = diag[j].sqrt().max(1e-12);
            let c = g.at(i, j) as f64 / (di * dj);
            if i == j {
                diag_sq += c * c;
            } else {
                off_sq += c * c;
                abs_sum += c.abs();
                count += 1;
            }
        }
    }
    let correlation_mass = off_sq / (off_sq + diag_sq).max(1e-12);
    let mean_abs_corr = abs_sum / count.max(1) as f64;

    let total: f64 = diag.iter().sum::<f64>().max(1e-12);
    let entropy: f64 = diag.iter()
        .map(|&v| {
            let p = (v / total).max(1e-300);
            -p * p.ln()
        })
        .sum();
    GramDiagnostics {
        dim: d,
        outlier_ratio,
        correlation_mass,
        mean_abs_corr,
        energy_participation: entropy.exp(),
    }
}

impl GramDiagnostics {
    pub fn summary(&self) -> String {
        format!(
            "d={:<5} outlier_ratio={:<8.2} corr_mass={:<8.4} \
             mean|corr|={:<8.4} energy_participation={:.1}",
            self.dim, self.outlier_ratio, self.correlation_mass,
            self.mean_abs_corr, self.energy_participation)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::tensor::Matrix;

    #[test]
    fn identity_gram_is_decorrelated() {
        let g = Matrix::eye(16);
        let diag = diagnose(&g);
        assert!(diag.correlation_mass < 1e-9);
        assert!((diag.outlier_ratio - 1.0).abs() < 1e-9);
        assert!((diag.energy_participation - 16.0).abs() < 1e-6);
    }

    #[test]
    fn iid_gaussian_features_have_low_correlation() {
        let mut rng = Rng::new(0);
        let x = Matrix::from_fn(4096, 16, |_, _| rng.gaussian_f32());
        let mut g = Matrix::zeros(16, 16);
        g.gram_accumulate(&x);
        let d = diagnose(&g);
        assert!(d.mean_abs_corr < 0.05, "{}", d.summary());
        assert!(d.outlier_ratio < 1.3, "{}", d.summary());
    }

    #[test]
    fn outlier_feature_detected() {
        let mut rng = Rng::new(1);
        let x = Matrix::from_fn(1024, 16, |_, j| {
            let scale = if j == 3 { 20.0 } else { 1.0 };
            rng.gaussian_f32() * scale
        });
        let mut g = Matrix::zeros(16, 16);
        g.gram_accumulate(&x);
        let d = diagnose(&g);
        assert!(d.outlier_ratio > 10.0, "{}", d.summary());
        assert!(d.energy_participation < 4.0, "{}", d.summary());
    }

    #[test]
    fn mixed_features_have_correlation_mass() {
        let mut rng = Rng::new(2);
        let d = 16;
        let base = Matrix::from_fn(1024, d, |_, _| rng.gaussian_f32());
        let mix = Matrix::from_fn(d, d, |i, j| {
            if i == j { 1.0 } else { 0.5 * rng.gaussian_f32()
                                     / (d as f32).sqrt() }
        });
        let x = base.matmul(&mix);
        let mut g = Matrix::zeros(d, d);
        g.gram_accumulate(&x);
        let diag = diagnose(&g);
        assert!(diag.correlation_mass > 0.01, "{}", diag.summary());
    }
}
