//! Data pipeline: synthetic corpus -> tokenizer -> batched token
//! streams with disjoint train / calibration / validation splits.

pub mod corpus;

use crate::runtime::manifest::ModelMeta;
use crate::runtime::tensor_data::TensorData;
use crate::tokenizer::Tokenizer;
use crate::util::prng::Rng;

pub use corpus::{generate_text, Grammar};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Calibration,
    Validation,
}

impl Split {
    fn seed_salt(&self) -> u64 {
        match self {
            Split::Train => 0x7472,
            Split::Calibration => 0x6361,
            Split::Validation => 0x7661,
        }
    }
}

/// The full data stack for one model config.
pub struct Dataset {
    pub grammar: Grammar,
    pub tokenizer: Tokenizer,
    pub seed: u64,
    vocab: usize,
}

impl Dataset {
    /// Build the dataset for a model: generates a training-sized corpus
    /// sample, trains the tokenizer on it, and keeps the grammar for
    /// streaming generation.
    pub fn build(meta: &ModelMeta, seed: u64) -> Dataset {
        let grammar = Grammar::new(seed, 400);
        let sample = generate_text(&grammar, seed ^ 0xBEEF, 30_000);
        let tokenizer = Tokenizer::train(&sample, meta.vocab);
        Dataset { grammar, tokenizer, seed, vocab: meta.vocab }
    }

    /// Tokenize split text into a clamped id stream.
    fn token_stream(&self, split: Split, n_words: usize) -> Vec<i32> {
        let text = generate_text(&self.grammar,
                                 self.seed ^ split.seed_salt(), n_words);
        self.tokenizer.encode(&text)
            .into_iter()
            .map(|t| (t as usize).min(self.vocab - 1) as i32)
            .collect()
    }

    /// `n_batches` of (tokens, targets) pairs shaped [batch, seq_len];
    /// targets are tokens shifted by one.
    pub fn batches(&self, meta: &ModelMeta, split: Split, n_batches: usize)
        -> Vec<(TensorData, TensorData)> {
        let per_batch = meta.batch * meta.seq_len;
        // ~5.5 bytes/word, ~1.4 tokens/word after BPE; over-generate.
        let needed_tokens = per_batch * n_batches + 1;
        let n_words = needed_tokens.max(64);
        let mut stream = self.token_stream(split, n_words);
        while stream.len() < needed_tokens + 1 {
            let extra = self.token_stream(
                Split::Train, needed_tokens);
            stream.extend(extra);
        }
        let mut rng = Rng::new(self.seed ^ split.seed_salt() ^ 0x0FF5E7);
        let max_start = stream.len() - per_batch - 1;
        (0..n_batches).map(|_| {
            let start = rng.usize_below(max_start.max(1));
            let tokens: Vec<i32> =
                stream[start..start + per_batch].to_vec();
            let targets: Vec<i32> =
                stream[start + 1..start + per_batch + 1].to_vec();
            let dims = vec![meta.batch, meta.seq_len];
            (TensorData::I32 { dims: dims.clone(), data: tokens },
             TensorData::I32 { dims, data: targets })
        }).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_meta;

    #[test]
    fn batches_have_right_shape_and_range() {
        let meta = tiny_meta();
        let ds = Dataset::build(&meta, 11);
        let batches = ds.batches(&meta, Split::Train, 3);
        assert_eq!(batches.len(), 3);
        for (tok, tgt) in &batches {
            assert_eq!(tok.dims(), &[meta.batch, meta.seq_len]);
            assert_eq!(tgt.dims(), &[meta.batch, meta.seq_len]);
            for &t in tok.as_i32().unwrap() {
                assert!((t as usize) < meta.vocab);
            }
        }
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let meta = tiny_meta();
        let ds = Dataset::build(&meta, 11);
        let (tok, tgt) = &ds.batches(&meta, Split::Train, 1)[0];
        let tok = tok.as_i32().unwrap();
        let tgt = tgt.as_i32().unwrap();
        // Within each flat stream the target is the next token.
        assert_eq!(&tok[1..], &tgt[..tok.len() - 1]);
    }

    #[test]
    fn splits_differ() {
        let meta = tiny_meta();
        let ds = Dataset::build(&meta, 11);
        let a = ds.batches(&meta, Split::Train, 1);
        let b = ds.batches(&meta, Split::Validation, 1);
        assert_ne!(a[0].0.as_i32().unwrap(), b[0].0.as_i32().unwrap());
    }

    #[test]
    fn deterministic() {
        let meta = tiny_meta();
        let a = Dataset::build(&meta, 11).batches(&meta, Split::Train, 2);
        let b = Dataset::build(&meta, 11).batches(&meta, Split::Train, 2);
        assert_eq!(a[0].0.as_i32().unwrap(), b[0].0.as_i32().unwrap());
        assert_eq!(a[1].0.as_i32().unwrap(), b[1].0.as_i32().unwrap());
    }
}
