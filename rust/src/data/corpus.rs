//! Synthetic corpus generator: the stand-in for C4 / WikiText.
//!
//! A seeded order-1 Markov grammar over an invented vocabulary with a
//! Zipfian marginal:
//!   * words are built from syllables, so the byte-BPE tokenizer has
//!     real sub-word structure to learn;
//!   * each word has a sparse successor distribution (few high-prob
//!     successors), giving the language predictable bigram structure —
//!     which is what makes calibration features *correlated* and
//!     perplexity a meaningful target;
//!   * sentence lengths and punctuation follow simple distributions.
//!
//! Determinism: the whole corpus is a pure function of (seed, n_words).

use crate::util::prng::Rng;

const SYLLABLES: &[&str] = &[
    "ka", "ri", "to", "ve", "mun", "sol", "ba", "du", "li", "zor",
    "fen", "gra", "hu", "pel", "qua", "nim", "tas", "wex", "yol", "cer",
];

#[derive(Clone, Debug)]
pub struct Grammar {
    pub words: Vec<String>,
    /// Zipfian unigram weights.
    pub unigram: Vec<f64>,
    /// Per word: (successor ids, cumulative weights).
    transitions: Vec<(Vec<usize>, Vec<f64>)>,
}

impl Grammar {
    pub fn new(seed: u64, vocab_words: usize) -> Grammar {
        let mut rng = Rng::new(seed ^ 0x6772616d);
        // Distinct invented words from 2-3 syllables.
        let mut words = Vec::with_capacity(vocab_words);
        let mut seen = std::collections::HashSet::new();
        while words.len() < vocab_words {
            let n = 2 + rng.usize_below(2);
            let w: String = (0..n)
                .map(|_| SYLLABLES[rng.usize_below(SYLLABLES.len())])
                .collect();
            if seen.insert(w.clone()) {
                words.push(w);
            }
        }
        // Zipf marginal: p(rank r) ~ 1 / (r + 2)^1.1
        let unigram: Vec<f64> = (0..vocab_words)
            .map(|r| 1.0 / ((r + 2) as f64).powf(1.1))
            .collect();
        // Sparse successors: 6 candidates biased toward frequent words,
        // with heavy-tailed weights.
        let transitions = (0..vocab_words).map(|_| {
            let k = 4 + rng.usize_below(4);
            let mut succ = Vec::with_capacity(k);
            let mut weights = Vec::with_capacity(k);
            for _ in 0..k {
                succ.push(rng.weighted_index(&unigram));
                weights.push(rng.f64().powi(2) + 0.05);
            }
            let mut cum = 0.0;
            let cums: Vec<f64> = weights.iter().map(|w| {
                cum += w;
                cum
            }).collect();
            (succ, cums)
        }).collect();
        Grammar { words, unigram, transitions }
    }

    pub fn next_word(&self, current: usize, rng: &mut Rng) -> usize {
        let (succ, cums) = &self.transitions[current];
        // Mostly follow the chain; occasionally jump via the unigram
        // (keeps the chain ergodic).
        if rng.bool(0.15) {
            rng.weighted_index(&self.unigram)
        } else {
            let total = *cums.last().unwrap();
            let t = rng.f64() * total;
            let idx = cums.partition_point(|&c| c < t);
            succ[idx.min(succ.len() - 1)]
        }
    }

    /// Most likely successor of `current` under the chain (for building
    /// zero-shot gold answers).
    pub fn best_successor(&self, current: usize) -> usize {
        let (succ, cums) = &self.transitions[current];
        let mut best = (0.0, succ[0]);
        let mut prev = 0.0;
        for (i, &c) in cums.iter().enumerate() {
            let w = c - prev;
            prev = c;
            if w > best.0 {
                best = (w, succ[i]);
            }
        }
        best.1
    }

    /// Successor ids of a word (unique, for negative sampling).
    pub fn successors(&self, current: usize) -> &[usize] {
        &self.transitions[current].0
    }
}

/// Generate `n_words` of text from the grammar.
pub fn generate_text(grammar: &Grammar, seed: u64, n_words: usize)
    -> String {
    let mut rng = Rng::new(seed ^ 0x74657874);
    let mut out = String::with_capacity(n_words * 7);
    let mut current = rng.weighted_index(&grammar.unigram);
    let mut sentence_left = 5 + rng.usize_below(12);
    for i in 0..n_words {
        if i > 0 {
            out.push(' ');
        }
        out.push_str(&grammar.words[current]);
        sentence_left -= 1;
        if sentence_left == 0 {
            out.push('.');
            sentence_left = 5 + rng.usize_below(12);
            current = rng.weighted_index(&grammar.unigram);
        } else {
            current = grammar.next_word(current, &mut rng);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let g1 = Grammar::new(1, 100);
        let g2 = Grammar::new(1, 100);
        assert_eq!(g1.words, g2.words);
        assert_eq!(generate_text(&g1, 5, 200), generate_text(&g2, 5, 200));
    }

    #[test]
    fn different_seeds_differ() {
        let g = Grammar::new(1, 100);
        assert_ne!(generate_text(&g, 5, 200), generate_text(&g, 6, 200));
    }

    #[test]
    fn zipf_marginal_realised() {
        // Frequent ranks must actually appear more often in generated
        // text than rare ranks.
        let g = Grammar::new(2, 200);
        let text = generate_text(&g, 7, 20_000);
        let mut counts = vec![0usize; 200];
        for w in text.split_whitespace() {
            let w = w.trim_end_matches('.');
            if let Some(i) = g.words.iter().position(|x| x == w) {
                counts[i] += 1;
            }
        }
        let head: usize = counts[..20].iter().sum();
        let tail: usize = counts[180..].iter().sum();
        assert!(head > 5 * tail.max(1), "head {head} tail {tail}");
    }

    #[test]
    fn chain_is_predictable() {
        // The best successor should appear after its predecessor far
        // more often than chance.
        let g = Grammar::new(3, 100);
        let mut rng = Rng::new(0);
        let mut hits = 0;
        let mut total = 0;
        let mut cur = 0;
        for _ in 0..5_000 {
            let next = g.next_word(cur, &mut rng);
            if next == g.best_successor(cur) {
                hits += 1;
            }
            total += 1;
            cur = next;
        }
        let rate = hits as f64 / total as f64;
        assert!(rate > 0.15, "predictability {rate}");
    }

    #[test]
    fn text_contains_sentences() {
        let g = Grammar::new(4, 50);
        let text = generate_text(&g, 1, 500);
        assert!(text.contains('.'));
        assert!(text.split_whitespace().count() >= 500);
    }
}
