//! Benchmark harness (no criterion offline): warmup, timed samples,
//! robust statistics, and Markdown table emission.
//!
//! Every `rust/benches/*.rs` target (`harness = false`) uses this to
//! regenerate one paper table/figure: benches both *measure* (wall-clock
//! stats) and *report* (the table rows the paper prints).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let pct = |p: f64| ns[(((n - 1) as f64) * p).round() as usize];
        Stats {
            n,
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: ns[0],
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
        }
    }

    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
}

pub fn fmt_duration_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark a closure: `warmup` untimed runs then `samples` timed runs.
pub fn bench<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut ns = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        f();
        ns.push(t0.elapsed().as_nanos() as f64);
    }
    Stats::from_samples(ns)
}

/// Time a single run (for expensive end-to-end pipelines).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Markdown table builder with alignment.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(),
                   "row width mismatch in table '{}'", self.title);
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&line(&self.headers));
        let sep: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&line(&sep));
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_markdown());
    }

    /// Also append to a report file (used to build EXPERIMENTS.md data).
    pub fn append_to(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        f.write_all(self.to_markdown().as_bytes())
    }
}

/// GFLOP/s given a flop count and mean nanoseconds (flops/ns happens
/// to equal GFLOP/s exactly).
pub fn gflops(flops: f64, mean_ns: f64) -> f64 {
    flops / mean_ns.max(1e-9)
}

/// Read-modify-write one top-level section of a JSON report file, so
/// several bench targets can contribute to a combined report (e.g.
/// `reports/bench_kernels.json`: `microbench` writes "kernels",
/// `ablation_engine` writes "engine").  A missing or unparsable file
/// starts from an empty object.
pub fn merge_json_section(path: &str, key: &str,
                          value: crate::util::jsonlite::Json)
    -> std::io::Result<()> {
    use crate::util::jsonlite::Json;
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|json| json.as_obj().cloned())
        .unwrap_or_default();
    root.insert(key.to_string(), value);
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, format!("{}\n", Json::Obj(root)))
}

/// ASCII series plot for figure-style outputs (Fig. 1 / Fig. 2).
pub fn ascii_plot(title: &str, xs: &[f64], series: &[(&str, Vec<f64>)],
                  width: usize, height: usize) -> String {
    let mut all: Vec<f64> = series.iter().flat_map(|(_, ys)| ys.clone())
        .filter(|y| y.is_finite())
        .collect();
    if all.is_empty() || xs.is_empty() {
        return format!("{title}: (no data)\n");
    }
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (ymin, ymax) = (all[0], all[all.len() - 1]);
    let span = (ymax - ymin).max(1e-12);
    let mut grid = vec![vec![b' '; width]; height];
    let marks = [b'*', b'o', b'+', b'x', b'#'];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (i, &y) in ys.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let col = if xs.len() == 1 { 0 } else {
                i * (width - 1) / (xs.len() - 1)
            };
            let rowf = (y - ymin) / span;
            let row = height - 1 - ((rowf * (height - 1) as f64).round()
                                    as usize);
            grid[row][col] = marks[si % marks.len()];
        }
    }
    let mut out = format!("{title}  [y: {ymin:.4} .. {ymax:.4}]\n");
    for row in grid {
        out.push_str("  |");
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!("   x: {:?}\n", xs));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("   {} = {}\n",
                              marks[si % marks.len()] as char, name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean_ns - 3.0).abs() < 1e-9);
        assert_eq!(s.p50_ns, 3.0);
        assert_eq!(s.min_ns, 1.0);
    }

    #[test]
    fn bench_runs_closure() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert_eq!(md.lines().filter(|l| l.starts_with('|')).count(), 3);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        Table::new("t", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn merge_json_section_combines_writers() {
        use crate::util::jsonlite::Json;
        let path = std::env::temp_dir()
            .join(format!("ss_bench_merge_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        merge_json_section(&path, "kernels",
                           Json::obj(vec![("n", Json::num(1.0))]))
            .unwrap();
        merge_json_section(&path, "engine",
                           Json::obj(vec![("d", Json::num(2.0))]))
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let root = Json::parse(text.trim()).unwrap();
        assert_eq!(root.path("kernels.n").unwrap(), &Json::num(1.0));
        assert_eq!(root.path("engine.d").unwrap(), &Json::num(2.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn gflops_is_flops_per_ns() {
        assert!((gflops(2e9, 1e9) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration_ns(500.0), "500 ns");
        assert_eq!(fmt_duration_ns(2_500.0), "2.50 µs");
        assert_eq!(fmt_duration_ns(3_000_000.0), "3.00 ms");
        assert_eq!(fmt_duration_ns(1.5e9), "1.500 s");
    }

    #[test]
    fn ascii_plot_handles_series() {
        let p = ascii_plot("t", &[0.0, 1.0, 2.0],
                           &[("a", vec![1.0, 2.0, 3.0]),
                             ("b", vec![3.0, 2.0, 1.0])], 20, 5);
        assert!(p.contains("t  [y:"));
        assert!(p.contains("* = a"));
    }
}
